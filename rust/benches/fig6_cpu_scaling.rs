//! Fig 6 reproduction: CPU TreeShap throughput vs thread count
//! (paper: linear to 40 cores, ~7000 rows/s on cal_housing-med).
//!
//! The thread-pool fans rows out exactly as the paper's OpenMP
//! parallel-for does; with one physical core the measured curve is flat
//! and the bench records it (the paper's dip-at-40-cores OS-contention
//! caveat becomes "everything contends" here).

use std::sync::Arc;

use gputreeshap::backend::{RecursiveBackend, ShapBackend};
use gputreeshap::bench::{dump_record, zoo, Table};
use gputreeshap::gbdt::ZooSize;
use gputreeshap::util::Json;

const ROWS: usize = 512; // paper: 1M rows — scaled (DESIGN.md §5)

fn main() {
    let entry = zoo::zoo_entries()
        .into_iter()
        .find(|e| e.spec.name == "cal_housing" && e.size == ZooSize::Medium)
        .unwrap();
    let (model, data) = zoo::build(&entry);
    println!("fig6: {} — {} rows\n", entry.name, ROWS);
    let m = model.num_features;
    let model = Arc::new(model);
    let rows = ROWS.min(data.rows);
    let x = &data.features[..rows * m];

    let mut table = Table::new(&["threads", "time(s)", "rows/s", "scaling"]);
    let mut base = None;
    let mut reference: Option<Vec<f32>> = None;
    for threads in [1usize, 2, 4, 8] {
        let backend = RecursiveBackend::new(model.clone(), threads);
        // median of 3
        let mut times = Vec::new();
        let mut out = Vec::new();
        for _ in 0..3 {
            let t = std::time::Instant::now();
            out = backend.contributions(x, rows).expect("contributions");
            times.push(t.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.total_cmp(b));
        if let Some(r) = &reference {
            assert_eq!(r, &out, "thread count changed results");
        } else {
            reference = Some(out);
        }
        let dt = times[1];
        let rps = rows as f64 / dt;
        let scaling = base.map_or(1.0, |b: f64| rps / b);
        if base.is_none() {
            base = Some(rps);
        }
        table.row(vec![
            threads.to_string(),
            format!("{dt:.3}"),
            format!("{rps:.0}"),
            format!("{scaling:.2}x"),
        ]);
        dump_record(
            "fig6",
            vec![
                ("threads", Json::from(threads)),
                ("time_s", Json::from(dt)),
                ("rows_per_s", Json::from(rps)),
            ],
        );
    }
    table.print();
    println!("\n(paper: linear to 40 cores; flat here = 1 physical core, see EXPERIMENTS.md)");
}
