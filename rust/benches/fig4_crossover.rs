//! Fig 4 reproduction: time-to-explain vs number of test rows for the
//! cal_housing model, recursive CPU backend vs the best accelerated
//! backend, locating the crossover where batch amortisation beats
//! per-row recursion — and checking the planner's crossover-aware choice
//! at batch sizes straddling its own predicted crossover.
//!
//! The sweep also closes the calibration loop: every measured `(rows,
//! latency)` point is fed back through `Planner::recalibrate`, and the
//! bench reports the predicted crossover **before** (a-priori
//! constants) and **after** calibration next to the measured one — on
//! any testbed the calibrated prediction should land near the measured
//! row count, which is the self-tuning claim the serving executor
//! relies on.
//!
//! Paper: V100 beats 40 cores from ~200 rows. Here the "device" may be
//! the CPU PJRT backend (or the host packed DP when built without
//! `--features xla`) on the same cores as the baseline, so the measured
//! crossover may not occur; the bench records the two latency curves and
//! the planner's decisions either way, which is the figure's actual
//! content (fixed overhead vs slope).
//!
//! Args (after `--`): `--rows N` caps the sweep's largest batch
//! (default 512), `--size small|med|large` picks the zoo model
//! (default med) — `--rows 16 --size small` is the CI calibration
//! smoke configuration.

use std::sync::Arc;

use gputreeshap::backend::{self, BackendConfig, BackendKind, Observations, Planner, ShapBackend};
use gputreeshap::bench::{dump_record, fmt_secs, zoo, Table};
use gputreeshap::cli::Args;
use gputreeshap::gbdt::ZooSize;
use gputreeshap::parallel::default_threads;
use gputreeshap::util::Json;

fn median3(mut f: impl FnMut() -> f64) -> f64 {
    let mut v = [f(), f(), f()];
    v.sort_by(|a, b| a.total_cmp(b));
    v[1]
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let max_rows = args.get_usize("rows", 512).expect("--rows").max(1);
    let size = match args.get_or("size", "med") {
        "small" => ZooSize::Small,
        "med" | "medium" => ZooSize::Medium,
        "large" => ZooSize::Large,
        other => panic!("unknown size '{other}' (small|med|large)"),
    };
    let threads = default_threads();
    let entry = zoo::zoo_entries()
        .into_iter()
        .find(|e| e.spec.name == "cal_housing" && e.size == size)
        .unwrap();
    let (model, data) = zoo::build(&entry);
    println!("fig4: {} ({}), {} thread(s)", entry.name, model.summary(), threads);
    let m = model.num_features;
    let model = Arc::new(model);
    let planner = Planner::for_model(&model);
    let cfg = BackendConfig { threads, rows_hint: max_rows, ..Default::default() };

    let cpu = backend::build(&model, BackendKind::Recursive, &cfg).expect("cpu backend");
    // accelerated side: the best non-recursive backend that constructs
    let mut accel = None;
    for kind in [BackendKind::XlaPadded, BackendKind::XlaWarp, BackendKind::Host] {
        match backend::build(&model, kind, &cfg) {
            Ok(b) => {
                accel = Some((kind, b));
                break;
            }
            Err(e) => eprintln!("  [skip {}: {e}]", kind.name()),
        }
    }
    let (akind, accel) = accel.expect("no accelerated backend available");
    // head-to-head planner over exactly the two measured backends
    let mut duel = Planner::with_candidates(
        planner.shape,
        vec![
            (
                BackendKind::Recursive,
                backend::planner::estimate(BackendKind::Recursive, &planner.shape),
            ),
            (akind, backend::planner::estimate(akind, &planner.shape)),
        ],
    );
    let predicted = duel.crossover_rows(BackendKind::Recursive, akind);
    println!("accel backend: {}", accel.describe());
    println!("prior predicted crossover: {predicted:?} rows\n");

    let mut table = Table::new(&["rows", "cpu", "accel", "cpu rows/s", "accel rows/s", "planner"]);
    let mut crossover = None;
    let mut obs = Observations::new();
    for &rows in &[1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512] {
        if rows > max_rows {
            break;
        }
        let rows = rows.min(data.rows);
        let x = &data.features[..rows * m];
        let cpu_t = median3(|| {
            let t = std::time::Instant::now();
            std::hint::black_box(cpu.contributions(x, rows).expect("cpu"));
            let dt = t.elapsed().as_secs_f64();
            obs.record_backend(BackendKind::Recursive.name(), rows, dt);
            dt
        });
        let accel_t = median3(|| {
            let t = std::time::Instant::now();
            std::hint::black_box(accel.contributions(x, rows).expect("accel"));
            let dt = t.elapsed().as_secs_f64();
            obs.record_backend(akind.name(), rows, dt);
            dt
        });
        if accel_t < cpu_t && crossover.is_none() {
            crossover = Some(rows);
        }
        table.row(vec![
            rows.to_string(),
            fmt_secs(cpu_t),
            fmt_secs(accel_t),
            format!("{:.0}", rows as f64 / cpu_t),
            format!("{:.0}", rows as f64 / accel_t),
            planner.choose(rows).kind.name().to_string(),
        ]);
        dump_record(
            "fig4",
            vec![
                ("rows", Json::from(rows)),
                ("cpu_s", Json::from(cpu_t)),
                ("accel_s", Json::from(accel_t)),
                ("accel_backend", Json::from(akind.name())),
                ("planner_choice", Json::from(planner.choose(rows).kind.name())),
            ],
        );
    }
    table.print();

    // exercise the planner at two batch sizes straddling its crossover
    if let Some(c) = predicted.filter(|&c| c >= 2) {
        let below = duel.choose(c / 2).kind;
        let above = duel.choose(c.saturating_mul(2)).kind;
        println!(
            "\nplanner straddle: {} rows → {}, {} rows → {}",
            c / 2,
            below.name(),
            c.saturating_mul(2),
            above.name()
        );
        assert_eq!(below, BackendKind::Recursive, "below crossover must stay on cpu");
        assert_eq!(above, akind, "above crossover must switch to {}", akind.name());
    }
    match crossover {
        Some(r) => println!("measured crossover at ~{r} rows (paper: ~200 rows, V100 vs 40 cores)"),
        None => println!("no measured crossover on this testbed (see EXPERIMENTS.md)"),
    }

    // close the loop: feed the sweep's samples back into the duel
    // planner and report where the calibrated line model now puts the
    // crossover (should track the measured one on any testbed)
    duel.recalibrate(&obs);
    let calibrated = duel.crossover_rows(BackendKind::Recursive, akind);
    println!("calibrated predicted crossover: {calibrated:?} rows");
    let cpu_cal = duel.cost(BackendKind::Recursive).expect("cpu candidate");
    let acc_cal = duel.cost(akind).expect("accel candidate");
    println!(
        "calibrated constants: cpu {{overhead {:.2e}s, {:.0} rows/s}}, {} {{overhead {:.2e}s, {:.0} rows/s}}",
        cpu_cal.batch_overhead_s,
        cpu_cal.rows_per_s,
        akind.name(),
        acc_cal.batch_overhead_s,
        acc_cal.rows_per_s
    );
    dump_record(
        "fig4_calibration",
        vec![
            ("prior_crossover", predicted.map(Json::from).unwrap_or(Json::Null)),
            ("measured_crossover", crossover.map(Json::from).unwrap_or(Json::Null)),
            ("calibrated_crossover", calibrated.map(Json::from).unwrap_or(Json::Null)),
            ("accel_backend", Json::from(akind.name())),
        ],
    );
}
