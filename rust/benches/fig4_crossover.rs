//! Fig 4 reproduction: time-to-explain vs number of test rows for the
//! cal_housing model, recursive CPU backend vs the best accelerated
//! backend vs the Linear TreeShap kernel, locating the crossovers where
//! batch amortisation (and the O(tree-size) per-row reformulation)
//! beat per-row recursion — and checking the planner's crossover-aware
//! choice at batch sizes straddling its own predicted crossover.
//!
//! **Third curve**: `BackendKind::Linear` is measured alongside the
//! recursive baseline and the packed backend. Its per-row cost scales
//! with path length instead of depth², so a depth sweep (fixed rows,
//! growing tree depth) records where the linear kernel overtakes the
//! packed host DP — the deep-ensemble win the Linear TreeShap paper
//! claims.
//!
//! **Fourth curve**: `BackendKind::FastV2` — the Fast TreeSHAP v2
//! weight-table kernel, whose per-row cost loses a whole depth factor
//! against the linear kernel at the price of O(leaves·2^D) precomputed
//! tables. The depth sweep carries a fastv2 column too; at depths where
//! the table memory blows the `--fastv2-max-mb` budget the backend
//! *refuses to construct* (the guardrail), and the sweep prints the
//! cut-off instead of a throughput — which is itself the figure: the
//! regime boundary of the precompute trade.
//!
//! **Prep vs per-batch separation**: construction (path extraction +
//! packing, through the prepared-model cache) is timed apart from
//! execution, and the first (prep-inclusive) batch is reported apart
//! from the steady-state median — so the cached-vs-uncached gap the
//! Fast-TreeSHAP-style cache exists for is visible in the output, and
//! the bench asserts steady-state stays strictly below the first batch
//! for the packed backend.
//!
//! The sweep also closes the calibration loop: every measured `(rows,
//! latency)` point is fed back through `Planner::recalibrate` (first
//! batches onto the first-batch line, the rest onto the steady line),
//! and the bench reports the predicted crossover **before** (a-priori
//! constants) and **after** calibration next to the measured one — on
//! any testbed the calibrated prediction should land near the measured
//! row count, which is the self-tuning claim the serving executor
//! relies on.
//!
//! Paper: V100 beats 40 cores from ~200 rows. Here the "device" may be
//! the CPU PJRT backend (or the host packed DP when built without
//! `--features xla`) on the same cores as the baseline, so the measured
//! crossover may not occur; the bench records the two latency curves and
//! the planner's decisions either way, which is the figure's actual
//! content (fixed overhead vs slope).
//!
//! Args (after `--`): `--rows N` caps the sweep's largest batch
//! (default 512), `--size small|med|large` picks the zoo model
//! (default med), `--json PATH` merges a machine-readable summary under
//! the `fig4` key of the report at PATH (CI's perf-tracking artifact) —
//! `--rows 16 --size small --json BENCH_pr.json` is the CI
//! configuration.

use std::sync::Arc;

use gputreeshap::backend::{self, BackendConfig, BackendKind, Observations, Planner, ShapBackend};
use gputreeshap::bench::{dump_record, fmt_secs, write_json_report, zoo, Table};
use gputreeshap::cli::Args;
use gputreeshap::data::SynthSpec;
use gputreeshap::gbdt::ZooSize;
use gputreeshap::parallel::default_threads;
use gputreeshap::util::{time_it, Json};

fn median3(mut f: impl FnMut() -> f64) -> f64 {
    let mut v = [f(), f(), f()];
    v.sort_by(|a, b| a.total_cmp(b));
    v[1]
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let max_rows = args.get_usize("rows", 512).expect("--rows").max(1);
    let json_path = args.get("json").map(std::path::PathBuf::from);
    let size = match args.get_or("size", "med") {
        "small" => ZooSize::Small,
        "med" | "medium" => ZooSize::Medium,
        "large" => ZooSize::Large,
        other => panic!("unknown size '{other}' (small|med|large)"),
    };
    let threads = default_threads();
    let entry = zoo::zoo_entries()
        .into_iter()
        .find(|e| e.spec.name == "cal_housing" && e.size == size)
        .unwrap();
    let (model, data) = zoo::build(&entry);
    println!("fig4: {} ({}), {} thread(s)", entry.name, model.summary(), threads);
    let m = model.num_features;
    let model = Arc::new(model);
    let planner = Planner::for_prepared(&backend::prepare(&model));
    let cfg = BackendConfig { threads, rows_hint: max_rows, ..Default::default() };

    // builds are timed: prep (path extraction + packing) happens here,
    // through the prepared-model cache, never inside the batch timings
    let (cpu, cpu_build_s) =
        time_it(|| backend::build(&model, BackendKind::Recursive, &cfg).expect("cpu backend"));
    // accelerated side: the best non-recursive backend that constructs
    let mut accel = None;
    let mut accel_build_s = 0.0;
    for kind in [BackendKind::XlaPadded, BackendKind::XlaWarp, BackendKind::Host] {
        let (built, build_s) = time_it(|| backend::build(&model, kind, &cfg));
        match built {
            Ok(b) => {
                accel = Some((kind, b));
                accel_build_s = build_s;
                break;
            }
            Err(e) => eprintln!("  [skip {}: {e}]", kind.name()),
        }
    }
    let (akind, accel) = accel.expect("no accelerated backend available");
    let accel_prep_s = accel.caps().setup_cost_s;
    // third curve: the Linear TreeShap kernel — built through the same
    // prepared-model cache, so its summary-table prep is timed here too
    let (linear, linear_build_s) =
        time_it(|| backend::build(&model, BackendKind::Linear, &cfg).expect("linear backend"));
    let linear_prep_s = linear.caps().setup_cost_s;
    // fourth curve: the Fast TreeSHAP v2 weight-table kernel — its
    // subset-table build is the setup the planner amortizes, measured
    // here through the same prepared-model cache
    let (fastv2, fastv2_build_s) =
        time_it(|| backend::build(&model, BackendKind::FastV2, &cfg).expect("fastv2 backend"));
    let fastv2_prep_s = fastv2.caps().setup_cost_s;
    // head-to-head planners over exactly the measured backend pairs
    let mut duel = Planner::with_candidates(
        planner.shape,
        vec![
            (
                BackendKind::Recursive,
                backend::planner::estimate(BackendKind::Recursive, &planner.shape),
            ),
            (akind, backend::planner::estimate(akind, &planner.shape)),
        ],
    );
    let predicted = duel.crossover_rows(BackendKind::Recursive, akind);
    let mut lduel = Planner::with_candidates(
        planner.shape,
        vec![
            (
                BackendKind::Recursive,
                backend::planner::estimate(BackendKind::Recursive, &planner.shape),
            ),
            (
                BackendKind::Linear,
                backend::planner::estimate(BackendKind::Linear, &planner.shape),
            ),
        ],
    );
    let predicted_linear = lduel.crossover_rows(BackendKind::Recursive, BackendKind::Linear);
    let mut fduel = Planner::with_candidates(
        planner.shape,
        vec![
            (
                BackendKind::Recursive,
                backend::planner::estimate(BackendKind::Recursive, &planner.shape),
            ),
            (
                BackendKind::FastV2,
                backend::planner::estimate(BackendKind::FastV2, &planner.shape),
            ),
        ],
    );
    let predicted_fastv2 = fduel.crossover_rows(BackendKind::Recursive, BackendKind::FastV2);
    println!("accel backend: {}", accel.describe());
    println!("linear backend: {}", linear.describe());
    println!("fastv2 backend: {}", fastv2.describe());
    println!(
        "prep: cpu build {} | {} build {} (measured layout prep {}) | linear build {} (summary prep {}) | fastv2 build {} (table prep {})",
        fmt_secs(cpu_build_s),
        akind.name(),
        fmt_secs(accel_build_s),
        fmt_secs(accel_prep_s),
        fmt_secs(linear_build_s),
        fmt_secs(linear_prep_s),
        fmt_secs(fastv2_build_s),
        fmt_secs(fastv2_prep_s)
    );
    println!(
        "prior predicted crossover: cpu→{} {predicted:?} rows, cpu→linear {predicted_linear:?} rows, cpu→fastv2 {predicted_fastv2:?} rows\n",
        akind.name()
    );

    // first (prep-inclusive) batch vs steady state at the largest batch:
    // the cached-pipeline claim is that every batch after the first
    // costs only execution. `first_batch` = build prep + first
    // execution; `steady` = later executions on the warm backend.
    let probe_rows = max_rows.min(data.rows).max(1);
    let xp = &data.features[..probe_rows * m];
    let mut obs = Observations::new();
    let (_, first_exec_s) =
        time_it(|| std::hint::black_box(accel.contributions(xp, probe_rows).expect("accel")));
    obs.record_backend_first(akind.name(), probe_rows, accel_prep_s + first_exec_s);
    let first_batch_s = accel_prep_s + first_exec_s;
    // the acceptance gate: a packed backend's steady-state per-batch
    // latency must sit strictly below its prep-inclusive first batch.
    // Timings at smoke scale are microseconds, so one scheduler stall
    // must not fail CI: re-measure the steady side a few times and gate
    // on the best attempt (the claim is about the workload, not about
    // the noisiest run the runner produced).
    let mut steady_min_s = f64::INFINITY;
    let mut steady_med_s = f64::INFINITY;
    for attempt in 0..3 {
        let mut steady_samples = [0.0f64; 3];
        for s in steady_samples.iter_mut() {
            let (_, dt) = time_it(|| {
                std::hint::black_box(accel.contributions(xp, probe_rows).expect("accel"))
            });
            *s = dt;
        }
        steady_samples.sort_by(|a, b| a.total_cmp(b));
        steady_min_s = steady_min_s.min(steady_samples[0]);
        steady_med_s = steady_med_s.min(steady_samples[1]);
        if steady_min_s < first_batch_s {
            break;
        }
        eprintln!("  [steady ≥ first batch on attempt {attempt} — re-measuring]");
    }
    println!(
        "{} @ {probe_rows} rows: first batch (prep-inclusive) {} → steady {} ({:.2}x)",
        akind.name(),
        fmt_secs(first_batch_s),
        fmt_secs(steady_med_s),
        first_batch_s / steady_med_s.max(1e-12)
    );
    assert!(
        steady_min_s < first_batch_s,
        "steady-state ({steady_min_s}s) must beat the prep-inclusive first batch \
         ({first_batch_s}s) on the packed backend"
    );

    // same gate for the linear kernel: its summary tables are built once
    // in the prepared-model cache, so every batch after the first costs
    // only the O(tree-size) sweep.
    let (_, linear_first_exec_s) =
        time_it(|| std::hint::black_box(linear.contributions(xp, probe_rows).expect("linear")));
    let linear_first_s = linear_prep_s + linear_first_exec_s;
    obs.record_backend_first(BackendKind::Linear.name(), probe_rows, linear_first_s);
    let mut linear_steady_min_s = f64::INFINITY;
    let mut linear_steady_med_s = f64::INFINITY;
    for attempt in 0..3 {
        let mut steady_samples = [0.0f64; 3];
        for s in steady_samples.iter_mut() {
            let (_, dt) = time_it(|| {
                std::hint::black_box(linear.contributions(xp, probe_rows).expect("linear"))
            });
            *s = dt;
        }
        steady_samples.sort_by(|a, b| a.total_cmp(b));
        linear_steady_min_s = linear_steady_min_s.min(steady_samples[0]);
        linear_steady_med_s = linear_steady_med_s.min(steady_samples[1]);
        if linear_steady_min_s < linear_first_s {
            break;
        }
        eprintln!("  [linear steady ≥ first batch on attempt {attempt} — re-measuring]");
    }
    println!(
        "linear @ {probe_rows} rows: first batch (prep-inclusive) {} → steady {} ({:.2}x)",
        fmt_secs(linear_first_s),
        fmt_secs(linear_steady_med_s),
        linear_first_s / linear_steady_med_s.max(1e-12)
    );
    assert!(
        linear_steady_min_s < linear_first_s,
        "steady-state ({linear_steady_min_s}s) must beat the prep-inclusive first batch \
         ({linear_first_s}s) on the linear backend"
    );

    // same gate again for fastv2: the subset weight tables are the
    // heaviest prep in the repo, built exactly once in the prepared
    // cache — every later batch is the O(d)-per-leaf sweep only.
    let (_, fastv2_first_exec_s) =
        time_it(|| std::hint::black_box(fastv2.contributions(xp, probe_rows).expect("fastv2")));
    let fastv2_first_s = fastv2_prep_s + fastv2_first_exec_s;
    obs.record_backend_first(BackendKind::FastV2.name(), probe_rows, fastv2_first_s);
    let mut fastv2_steady_min_s = f64::INFINITY;
    let mut fastv2_steady_med_s = f64::INFINITY;
    for attempt in 0..3 {
        let mut steady_samples = [0.0f64; 3];
        for s in steady_samples.iter_mut() {
            let (_, dt) = time_it(|| {
                std::hint::black_box(fastv2.contributions(xp, probe_rows).expect("fastv2"))
            });
            *s = dt;
        }
        steady_samples.sort_by(|a, b| a.total_cmp(b));
        fastv2_steady_min_s = fastv2_steady_min_s.min(steady_samples[0]);
        fastv2_steady_med_s = fastv2_steady_med_s.min(steady_samples[1]);
        if fastv2_steady_min_s < fastv2_first_s {
            break;
        }
        eprintln!("  [fastv2 steady ≥ first batch on attempt {attempt} — re-measuring]");
    }
    println!(
        "fastv2 @ {probe_rows} rows: first batch (prep-inclusive) {} → steady {} ({:.2}x)",
        fmt_secs(fastv2_first_s),
        fmt_secs(fastv2_steady_med_s),
        fastv2_first_s / fastv2_steady_med_s.max(1e-12)
    );
    assert!(
        fastv2_steady_min_s < fastv2_first_s,
        "steady-state ({fastv2_steady_min_s}s) must beat the prep-inclusive first batch \
         ({fastv2_first_s}s) on the fastv2 backend"
    );

    let mut table = Table::new(&[
        "rows",
        "cpu",
        "accel",
        "linear",
        "fastv2",
        "cpu rows/s",
        "accel rows/s",
        "linear rows/s",
        "fastv2 rows/s",
        "planner",
    ]);
    let mut crossover = None;
    let mut linear_crossover = None;
    let mut fastv2_crossover = None;
    let mut steady_points: Vec<Json> = Vec::new();
    let mut last_cpu_rps = 0.0f64;
    let mut last_accel_rps = 0.0f64;
    let mut last_linear_rps = 0.0f64;
    let mut last_fastv2_rps = 0.0f64;
    for &rows in &[1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512] {
        if rows > max_rows {
            break;
        }
        let rows = rows.min(data.rows);
        let x = &data.features[..rows * m];
        let cpu_t = median3(|| {
            let t = std::time::Instant::now();
            std::hint::black_box(cpu.contributions(x, rows).expect("cpu"));
            let dt = t.elapsed().as_secs_f64();
            obs.record_backend(BackendKind::Recursive.name(), rows, dt);
            dt
        });
        let accel_t = median3(|| {
            let t = std::time::Instant::now();
            std::hint::black_box(accel.contributions(x, rows).expect("accel"));
            let dt = t.elapsed().as_secs_f64();
            obs.record_backend(akind.name(), rows, dt);
            dt
        });
        let linear_t = median3(|| {
            let t = std::time::Instant::now();
            std::hint::black_box(linear.contributions(x, rows).expect("linear"));
            let dt = t.elapsed().as_secs_f64();
            obs.record_backend(BackendKind::Linear.name(), rows, dt);
            dt
        });
        let fastv2_t = median3(|| {
            let t = std::time::Instant::now();
            std::hint::black_box(fastv2.contributions(x, rows).expect("fastv2"));
            let dt = t.elapsed().as_secs_f64();
            obs.record_backend(BackendKind::FastV2.name(), rows, dt);
            dt
        });
        if accel_t < cpu_t && crossover.is_none() {
            crossover = Some(rows);
        }
        if linear_t < cpu_t && linear_crossover.is_none() {
            linear_crossover = Some(rows);
        }
        if fastv2_t < cpu_t && fastv2_crossover.is_none() {
            fastv2_crossover = Some(rows);
        }
        last_cpu_rps = rows as f64 / cpu_t;
        last_accel_rps = rows as f64 / accel_t;
        last_linear_rps = rows as f64 / linear_t;
        last_fastv2_rps = rows as f64 / fastv2_t;
        table.row(vec![
            rows.to_string(),
            fmt_secs(cpu_t),
            fmt_secs(accel_t),
            fmt_secs(linear_t),
            fmt_secs(fastv2_t),
            format!("{:.0}", last_cpu_rps),
            format!("{:.0}", last_accel_rps),
            format!("{:.0}", last_linear_rps),
            format!("{:.0}", last_fastv2_rps),
            planner.choose(rows).kind.name().to_string(),
        ]);
        steady_points.push(Json::obj(vec![
            ("rows", Json::from(rows)),
            ("cpu_s", Json::from(cpu_t)),
            ("accel_s", Json::from(accel_t)),
            ("linear_s", Json::from(linear_t)),
            ("fastv2_s", Json::from(fastv2_t)),
        ]));
        dump_record(
            "fig4",
            vec![
                ("rows", Json::from(rows)),
                ("cpu_s", Json::from(cpu_t)),
                ("accel_s", Json::from(accel_t)),
                ("linear_s", Json::from(linear_t)),
                ("fastv2_s", Json::from(fastv2_t)),
                ("accel_backend", Json::from(akind.name())),
                ("planner_choice", Json::from(planner.choose(rows).kind.name())),
            ],
        );
    }
    table.print();

    // exercise the planner at two batch sizes straddling its crossover
    if let Some(c) = predicted.filter(|&c| c >= 2) {
        let below = duel.choose(c / 2).kind;
        let above = duel.choose(c.saturating_mul(2)).kind;
        println!(
            "\nplanner straddle: {} rows → {}, {} rows → {}",
            c / 2,
            below.name(),
            c.saturating_mul(2),
            above.name()
        );
        assert_eq!(below, BackendKind::Recursive, "below crossover must stay on cpu");
        assert_eq!(above, akind, "above crossover must switch to {}", akind.name());
    }
    match crossover {
        Some(r) => println!("measured crossover at ~{r} rows (paper: ~200 rows, V100 vs 40 cores)"),
        None => println!("no measured crossover on this testbed (see EXPERIMENTS.md)"),
    }
    match linear_crossover {
        Some(r) => println!("measured cpu→linear crossover at ~{r} rows"),
        None => println!("no measured cpu→linear crossover on this testbed"),
    }
    match fastv2_crossover {
        Some(r) => println!("measured cpu→fastv2 crossover at ~{r} rows"),
        None => println!("no measured cpu→fastv2 crossover on this testbed"),
    }

    // close the loop: feed the sweep's samples back into the duel
    // planner and report where the calibrated line model now puts the
    // crossover (should track the measured one on any testbed)
    duel.recalibrate(&obs);
    let calibrated = duel.crossover_rows(BackendKind::Recursive, akind);
    println!("calibrated predicted crossover: {calibrated:?} rows");
    let cpu_cal = duel.cost(BackendKind::Recursive).expect("cpu candidate");
    let acc_cal = duel.cost(akind).expect("accel candidate");
    println!(
        "calibrated constants: cpu {{overhead {:.2e}s, {:.0} rows/s}}, {} {{overhead {:.2e}s, {:.0} rows/s, setup {:.2e}s from {} first batch(es)}}",
        cpu_cal.batch_overhead_s,
        cpu_cal.rows_per_s,
        akind.name(),
        acc_cal.batch_overhead_s,
        acc_cal.rows_per_s,
        acc_cal.setup_s,
        duel.calibration_first_samples(akind)
    );
    // the cpu-vs-linear duel closes the same loop on the third curve
    lduel.recalibrate(&obs);
    let linear_calibrated = lduel.crossover_rows(BackendKind::Recursive, BackendKind::Linear);
    println!("calibrated predicted cpu→linear crossover: {linear_calibrated:?} rows");
    // …and once more on the fourth curve
    fduel.recalibrate(&obs);
    let fastv2_calibrated = fduel.crossover_rows(BackendKind::Recursive, BackendKind::FastV2);
    println!("calibrated predicted cpu→fastv2 crossover: {fastv2_calibrated:?} rows");
    dump_record(
        "fig4_calibration",
        vec![
            ("prior_crossover", predicted.map(Json::from).unwrap_or(Json::Null)),
            ("measured_crossover", crossover.map(Json::from).unwrap_or(Json::Null)),
            ("calibrated_crossover", calibrated.map(Json::from).unwrap_or(Json::Null)),
            ("linear_prior_crossover", predicted_linear.map(Json::from).unwrap_or(Json::Null)),
            (
                "linear_measured_crossover",
                linear_crossover.map(Json::from).unwrap_or(Json::Null),
            ),
            (
                "linear_calibrated_crossover",
                linear_calibrated.map(Json::from).unwrap_or(Json::Null),
            ),
            ("fastv2_prior_crossover", predicted_fastv2.map(Json::from).unwrap_or(Json::Null)),
            (
                "fastv2_measured_crossover",
                fastv2_crossover.map(Json::from).unwrap_or(Json::Null),
            ),
            (
                "fastv2_calibrated_crossover",
                fastv2_calibrated.map(Json::from).unwrap_or(Json::Null),
            ),
            ("accel_backend", Json::from(akind.name())),
        ],
    );

    // depth sweep: fixed batch, growing tree depth. The recursive and
    // packed-DP kernels pay depth² per path (permutation weights / the
    // quadratic DP), the linear kernel pays depth × quadrature points —
    // the gap this sweep records is the Linear TreeShap deep-ensemble
    // claim. Models are tiny (20 rounds) and disk-cached so the smoke
    // configuration stays fast.
    let sweep_rows = probe_rows.min(64).max(1);
    let mut depth_points: Vec<Json> = Vec::new();
    let mut dtable = Table::new(&[
        "depth",
        "host rows/s",
        "linear rows/s",
        "fastv2 rows/s",
        "linear/host",
        "fastv2/host",
    ]);
    for &depth in &[3usize, 6, 10, 14] {
        let spec = SynthSpec::cal_housing(0.02);
        let (dmodel, ddata) = zoo::build_custom(&format!("cal_housing-d{depth}"), &spec, 20, depth);
        let dm = dmodel.num_features;
        let rows = sweep_rows.min(ddata.rows);
        let x = &ddata.features[..rows * dm];
        let dmodel = Arc::new(dmodel);
        let dcfg = BackendConfig { threads, rows_hint: rows, ..Default::default() };
        let host = backend::build(&dmodel, BackendKind::Host, &dcfg).expect("host backend");
        let lin = backend::build(&dmodel, BackendKind::Linear, &dcfg).expect("linear backend");
        // the fastv2 table build is guarded: at depths where the 2^D
        // tables exceed the (default) budget the build errs instead of
        // allocating, and the sweep records the cut-off — the shape of
        // the memory trade, not a failure
        let fv2 = match backend::build(&dmodel, BackendKind::FastV2, &dcfg) {
            Ok(b) => Some(b),
            Err(e) => {
                eprintln!("  [fastv2 @ depth {depth}: {e}]");
                None
            }
        };
        // warm every kernel so layout prep stays out of the throughput numbers
        std::hint::black_box(host.contributions(x, rows).expect("host"));
        std::hint::black_box(lin.contributions(x, rows).expect("linear"));
        if let Some(f) = &fv2 {
            std::hint::black_box(f.contributions(x, rows).expect("fastv2"));
        }
        let host_t = median3(|| {
            time_it(|| std::hint::black_box(host.contributions(x, rows).expect("host"))).1
        });
        let lin_t = median3(|| {
            time_it(|| std::hint::black_box(lin.contributions(x, rows).expect("linear"))).1
        });
        let fv2_t = fv2.as_ref().map(|f| {
            median3(|| {
                time_it(|| std::hint::black_box(f.contributions(x, rows).expect("fastv2"))).1
            })
        });
        let host_rps = rows as f64 / host_t;
        let lin_rps = rows as f64 / lin_t;
        let fv2_rps = fv2_t.map(|t| rows as f64 / t);
        dtable.row(vec![
            depth.to_string(),
            format!("{host_rps:.0}"),
            format!("{lin_rps:.0}"),
            match fv2_rps {
                Some(r) => format!("{r:.0}"),
                None => "over budget".to_string(),
            },
            format!("{:.2}x", lin_rps / host_rps.max(1e-12)),
            match fv2_rps {
                Some(r) => format!("{:.2}x", r / host_rps.max(1e-12)),
                None => "—".to_string(),
            },
        ]);
        depth_points.push(Json::obj(vec![
            ("depth", Json::from(depth)),
            ("rows", Json::from(rows)),
            ("host_rows_per_s", Json::from(host_rps)),
            ("linear_rows_per_s", Json::from(lin_rps)),
            ("fastv2_rows_per_s", fv2_rps.map(Json::from).unwrap_or(Json::Null)),
            ("fastv2_over_budget", Json::Bool(fv2_rps.is_none())),
        ]));
    }
    println!("\ndepth sweep ({sweep_rows} rows max, host packed DP vs linear vs fastv2):");
    dtable.print();

    if let Some(path) = json_path {
        let report = Json::obj(vec![
            ("model", Json::from(entry.name.as_str())),
            ("accel_backend", Json::from(akind.name())),
            (
                "prep",
                Json::obj(vec![
                    ("cpu_build_s", Json::from(cpu_build_s)),
                    ("accel_build_s", Json::from(accel_build_s)),
                    ("accel_layout_s", Json::from(accel_prep_s)),
                    ("linear_build_s", Json::from(linear_build_s)),
                    ("linear_layout_s", Json::from(linear_prep_s)),
                    ("fastv2_build_s", Json::from(fastv2_build_s)),
                    ("fastv2_table_s", Json::from(fastv2_prep_s)),
                ]),
            ),
            (
                "first_vs_steady",
                Json::obj(vec![
                    ("rows", Json::from(probe_rows)),
                    ("first_batch_s", Json::from(first_batch_s)),
                    ("steady_s", Json::from(steady_med_s)),
                ]),
            ),
            (
                "first_vs_steady_linear",
                Json::obj(vec![
                    ("rows", Json::from(probe_rows)),
                    ("first_batch_s", Json::from(linear_first_s)),
                    ("steady_s", Json::from(linear_steady_med_s)),
                ]),
            ),
            (
                "first_vs_steady_fastv2",
                Json::obj(vec![
                    ("rows", Json::from(probe_rows)),
                    ("first_batch_s", Json::from(fastv2_first_s)),
                    ("steady_s", Json::from(fastv2_steady_med_s)),
                ]),
            ),
            ("steady", Json::Arr(steady_points)),
            (
                "steady_rows_per_s",
                Json::obj(vec![
                    ("cpu", Json::from(last_cpu_rps)),
                    ("accel", Json::from(last_accel_rps)),
                    ("linear", Json::from(last_linear_rps)),
                    ("fastv2", Json::from(last_fastv2_rps)),
                ]),
            ),
            (
                "crossover",
                Json::obj(vec![
                    ("prior", predicted.map(Json::from).unwrap_or(Json::Null)),
                    ("measured", crossover.map(Json::from).unwrap_or(Json::Null)),
                    ("calibrated", calibrated.map(Json::from).unwrap_or(Json::Null)),
                ]),
            ),
            (
                "crossover_linear",
                Json::obj(vec![
                    ("prior", predicted_linear.map(Json::from).unwrap_or(Json::Null)),
                    ("measured", linear_crossover.map(Json::from).unwrap_or(Json::Null)),
                    (
                        "calibrated",
                        linear_calibrated.map(Json::from).unwrap_or(Json::Null),
                    ),
                ]),
            ),
            (
                "crossover_fastv2",
                Json::obj(vec![
                    ("prior", predicted_fastv2.map(Json::from).unwrap_or(Json::Null)),
                    ("measured", fastv2_crossover.map(Json::from).unwrap_or(Json::Null)),
                    (
                        "calibrated",
                        fastv2_calibrated.map(Json::from).unwrap_or(Json::Null),
                    ),
                ]),
            ),
            ("depth_sweep", Json::Arr(depth_points)),
        ]);
        write_json_report(&path, "fig4", report).expect("write --json report");
        println!("json report merged into {}", path.display());
    }
}
