//! Fig 4 reproduction: time-to-explain vs number of test rows for the
//! cal_housing-med model, CPU baseline vs the batched engine, locating
//! the crossover where batch amortisation beats per-row recursion.
//!
//! Paper: V100 beats 40 cores from ~200 rows. Here the "device" is the
//! CPU PJRT backend on the same single core as the baseline, so the
//! crossover may not occur; the bench records the two latency curves
//! and the per-row marginal costs either way, which is the figure's
//! actual content (fixed overhead vs slope).

use gputreeshap::bench::{dump_record, fmt_secs, zoo, Table};
use gputreeshap::gbdt::ZooSize;
use gputreeshap::parallel::default_threads;
use gputreeshap::runtime::{default_artifacts_dir, ArtifactKind, ShapEngine};
use gputreeshap::shap::{pack_model, treeshap, Packing};
use gputreeshap::util::Json;

fn median3(mut f: impl FnMut() -> f64) -> f64 {
    let mut v = [f(), f(), f()];
    v.sort_by(|a, b| a.total_cmp(b));
    v[1]
}

fn main() {
    let threads = default_threads();
    let entry = zoo::zoo_entries()
        .into_iter()
        .find(|e| e.spec.name == "cal_housing" && e.size == ZooSize::Medium)
        .unwrap();
    let (model, data) = zoo::build(&entry);
    println!("fig4: {} ({}), {} thread(s)\n", entry.name, model.summary(), threads);
    let m = model.num_features;
    let pm = pack_model(&model, Packing::BestFitDecreasing);
    let mut engine = ShapEngine::new(&default_artifacts_dir()).expect("artifacts");
    let prep = engine.prepare(&pm, ArtifactKind::Shap, usize::MAX).expect("prepare");

    let mut table = Table::new(&["rows", "cpu", "xla", "cpu rows/s", "xla rows/s"]);
    let mut crossover = None;
    for &rows in &[1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512] {
        let rows = rows.min(data.rows);
        let x = &data.features[..rows * m];
        let cpu = median3(|| {
            let t = std::time::Instant::now();
            std::hint::black_box(treeshap::shap_values(&model, x, rows, threads));
            t.elapsed().as_secs_f64()
        });
        let xla = median3(|| {
            let t = std::time::Instant::now();
            std::hint::black_box(engine.shap_values(&pm, &prep, x, rows).unwrap());
            t.elapsed().as_secs_f64()
        });
        if xla < cpu && crossover.is_none() {
            crossover = Some(rows);
        }
        table.row(vec![
            rows.to_string(),
            fmt_secs(cpu),
            fmt_secs(xla),
            format!("{:.0}", rows as f64 / cpu),
            format!("{:.0}", rows as f64 / xla),
        ]);
        dump_record(
            "fig4",
            vec![
                ("rows", Json::from(rows)),
                ("cpu_s", Json::from(cpu)),
                ("xla_s", Json::from(xla)),
            ],
        );
    }
    table.print();
    match crossover {
        Some(r) => println!("\ncrossover at ~{r} rows (paper: ~200 rows, V100 vs 40 cores)"),
        None => println!("\nno crossover on this 1-core testbed (see EXPERIMENTS.md)"),
    }
}
