//! Table 3 reproduction: the model-zoo summary (trees / leaves /
//! max_depth per dataset × size), bench-scaled.

use gputreeshap::bench::{dump_record, zoo, Table};
use gputreeshap::util::Json;

fn main() {
    let mut table = Table::new(&["model", "trees", "leaves", "max_depth"]);
    for entry in zoo::zoo_entries() {
        let (model, _) = zoo::build(&entry);
        table.row(vec![
            entry.name.clone(),
            model.trees.len().to_string(),
            model.total_leaves().to_string(),
            model.max_depth().to_string(),
        ]);
        dump_record(
            "table3",
            vec![
                ("model", Json::from(entry.name.as_str())),
                ("trees", Json::from(model.trees.len())),
                ("leaves", Json::from(model.total_leaves())),
                ("max_depth", Json::from(model.max_depth())),
            ],
        );
        // paper invariants: depth grows small→large; ≤ warp size after merge
        assert!(model.max_depth() <= 16);
    }
    table.print();
}
