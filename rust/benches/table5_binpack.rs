//! Table 5 reproduction: bin-packing time, utilisation, and bin count
//! for NONE / NF / FFD / BFD across the 12-model zoo.
//!
//! Paper claims to verify: utilisation(none) ≪ utilisation(nf) ≤
//! utilisation(ffd) == utilisation(bfd); NF is fastest of the real
//! heuristics; utilisation(none) worsens with shallower trees.

use gputreeshap::bench::{dump_record, zoo, Table};
use gputreeshap::shap::{model_paths, pack, Packing, LANES};
use gputreeshap::util::{time_it, Json};

fn main() {
    let mut table = Table::new(&["model", "alg", "time(s)", "utilisation", "bins"]);
    let mut ordering_violations = 0;
    for entry in zoo::zoo_entries() {
        let (model, _) = zoo::build(&entry);
        let sizes: Vec<usize> = model_paths(&model).iter().map(|(_, p)| p.len()).collect();
        let mut utils = std::collections::HashMap::new();
        for alg in Packing::ALL {
            // median of 3 timing runs (packing is deterministic)
            let mut times = Vec::new();
            let mut result = None;
            for _ in 0..3 {
                let (r, dt) = time_it(|| pack(&sizes, alg, LANES));
                times.push(dt);
                result = Some(r);
            }
            times.sort_by(|a, b| a.total_cmp(b));
            let r = result.unwrap();
            utils.insert(alg.name(), r.utilisation);
            table.row(vec![
                entry.name.clone(),
                alg.name().to_uppercase(),
                format!("{:.4}", times[1]),
                format!("{:.6}", r.utilisation),
                r.bins.len().to_string(),
            ]);
            dump_record(
                "table5",
                vec![
                    ("model", Json::from(entry.name.as_str())),
                    ("alg", Json::from(alg.name())),
                    ("time_s", Json::from(times[1])),
                    ("utilisation", Json::from(r.utilisation)),
                    ("bins", Json::from(r.bins.len())),
                ],
            );
        }
        // the paper's qualitative ordering
        let (n, nf, ffd, bfd) =
            (utils["none"], utils["nf"], utils["ffd"], utils["bfd"]);
        if !(n <= nf + 1e-9 && nf <= ffd + 1e-9 && (ffd - bfd).abs() < 1e-9) {
            ordering_violations += 1;
            eprintln!("ordering violation on {}: none={n} nf={nf} ffd={ffd} bfd={bfd}", entry.name);
        }
    }
    table.print();
    println!("\nutilisation ordering (none ≤ nf ≤ ffd == bfd): {} violations", ordering_violations);
    assert_eq!(ordering_violations, 0);
}
