//! Ablation (DESIGN.md §Perf): warp-packed layout (faithful CUDA
//! adaptation, gathers for lane shuffles) vs padded-path layout
//! (gather-free slices/shifts, element axis padded to the depth bucket).
//!
//! Measures both engines on the model zoo's medium models plus a
//! large, and verifies identical φ. The padded layout trades lane
//! utilisation (Σlen/(P·(D+1)) vs BFD's ~0.95) for the removal of every
//! gather in the DP inner loop — the right trade on both this CPU
//! testbed and a real TPU VPU.

use gputreeshap::bench::{dump_record, fmt_secs, zoo, Table};
use gputreeshap::gbdt::ZooSize;
use gputreeshap::runtime::{default_artifacts_dir, ArtifactKind, ShapEngine};
use gputreeshap::shap::{pack_model, pad_model, Packing};
use gputreeshap::util::Json;

const ROWS: usize = 256;
const ITERS: usize = 3;

fn median(mut v: Vec<f64>) -> f64 {
    v.sort_by(|a, b| a.total_cmp(b));
    v[v.len() / 2]
}

fn main() {
    let mut engine = ShapEngine::new(&default_artifacts_dir()).expect("artifacts");
    let mut table = Table::new(&[
        "model", "warp util", "pad util", "warp(s)", "padded(s)", "speedup",
    ]);
    for entry in zoo::zoo_entries() {
        if entry.size == ZooSize::Small {
            continue; // launch-overhead dominated either way
        }
        let (model, data) = zoo::build(&entry);
        let m = model.num_features;
        let rows = ROWS.min(data.rows);
        let x = &data.features[..rows * m];

        let pm = pack_model(&model, Packing::BestFitDecreasing);
        // pick the padded width from the artifact the manifest will choose
        let spec_depth = engine
            .manifest
            .select(ArtifactKind::ShapPadded, m, pm.max_depth.max(1), rows)
            .expect("padded bucket")
            .depth;
        let pad = pad_model(&model, spec_depth + 1);

        let prep_w = engine.prepare(&pm, ArtifactKind::Shap, rows).expect("warp prep");
        let prep_p = engine.prepare_padded(&pad, rows).expect("padded prep");

        let mut warp_t = Vec::new();
        let mut pad_t = Vec::new();
        let mut a = Vec::new();
        let mut b = Vec::new();
        for _ in 0..ITERS {
            let t = std::time::Instant::now();
            a = engine.shap_values(&pm, &prep_w, x, rows).expect("warp");
            warp_t.push(t.elapsed().as_secs_f64());
            let t = std::time::Instant::now();
            b = engine.shap_values_padded(&pad, &prep_p, x, rows).expect("padded");
            pad_t.push(t.elapsed().as_secs_f64());
        }
        for (i, (p, q)) in a.iter().zip(&b).enumerate() {
            assert!(
                (p - q).abs() < 5e-2 + 5e-3 * p.abs(),
                "{}: layout mismatch idx {i}: {p} vs {q}",
                entry.name
            );
        }
        let wu = pm.groups.iter().map(|g| g.utilisation).fold(f64::MAX, f64::min);
        let pu = pad.groups.iter().map(|g| g.utilisation).fold(f64::MAX, f64::min);
        let (wt, pt) = (median(warp_t), median(pad_t));
        table.row(vec![
            entry.name.clone(),
            format!("{wu:.3}"),
            format!("{pu:.3}"),
            fmt_secs(wt),
            fmt_secs(pt),
            format!("{:.2}x", wt / pt),
        ]);
        dump_record(
            "ablation_layout",
            vec![
                ("model", Json::from(entry.name.as_str())),
                ("warp_s", Json::from(wt)),
                ("padded_s", Json::from(pt)),
                ("speedup", Json::from(wt / pt)),
                ("warp_util", Json::from(wu)),
                ("padded_util", Json::from(pu)),
            ],
        );
    }
    table.print();
    println!("\n(padded layout is the §Perf outcome; warp layout is the faithful CUDA mapping)");
}
