//! Ablation (DESIGN.md §Perf): warp-packed layout (faithful CUDA
//! adaptation, gathers for lane shuffles) vs padded-path layout
//! (gather-free slices/shifts, element axis padded to the depth bucket),
//! both behind `backend::ShapBackend`.
//!
//! Measures both engines on the model zoo's medium+large models and
//! verifies identical φ. The padded layout trades lane utilisation for
//! the removal of every gather in the DP inner loop — the right trade on
//! both this CPU testbed and a real TPU VPU. Requires the `xla` feature
//! and built artifacts; prints a note and exits cleanly otherwise.

use std::sync::Arc;

use gputreeshap::backend::{self, BackendConfig, BackendKind, ShapBackend};
use gputreeshap::bench::{dump_record, fmt_secs, zoo, Table};
use gputreeshap::gbdt::ZooSize;
use gputreeshap::util::Json;

const ROWS: usize = 256;
const ITERS: usize = 3;

fn median(mut v: Vec<f64>) -> f64 {
    v.sort_by(|a, b| a.total_cmp(b));
    v[v.len() / 2]
}

fn main() {
    let mut table = Table::new(&["model", "warp(s)", "padded(s)", "speedup"]);
    let mut measured = false;
    for entry in zoo::zoo_entries() {
        if entry.size == ZooSize::Small {
            continue; // launch-overhead dominated either way
        }
        let (model, data) = zoo::build(&entry);
        let m = model.num_features;
        let rows = ROWS.min(data.rows);
        let x = &data.features[..rows * m];
        let model = Arc::new(model);
        let cfg = BackendConfig { rows_hint: rows, ..Default::default() };

        let warp = match backend::build(&model, BackendKind::XlaWarp, &cfg) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("  [skip {}: {e}]", entry.name);
                continue;
            }
        };
        let padded = match backend::build(&model, BackendKind::XlaPadded, &cfg) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("  [skip {}: {e}]", entry.name);
                continue;
            }
        };
        measured = true;

        let mut warp_t = Vec::new();
        let mut pad_t = Vec::new();
        let mut a = Vec::new();
        let mut b = Vec::new();
        for _ in 0..ITERS {
            let t = std::time::Instant::now();
            a = warp.contributions(x, rows).expect("warp");
            warp_t.push(t.elapsed().as_secs_f64());
            let t = std::time::Instant::now();
            b = padded.contributions(x, rows).expect("padded");
            pad_t.push(t.elapsed().as_secs_f64());
        }
        for (i, (p, q)) in a.iter().zip(&b).enumerate() {
            assert!(
                (p - q).abs() < 5e-2 + 5e-3 * p.abs(),
                "{}: layout mismatch idx {i}: {p} vs {q}",
                entry.name
            );
        }
        let (wt, pt) = (median(warp_t), median(pad_t));
        table.row(vec![
            entry.name.clone(),
            fmt_secs(wt),
            fmt_secs(pt),
            format!("{:.2}x", wt / pt),
        ]);
        dump_record(
            "ablation_layout",
            vec![
                ("model", Json::from(entry.name.as_str())),
                ("warp_s", Json::from(wt)),
                ("padded_s", Json::from(pt)),
                ("speedup", Json::from(wt / pt)),
            ],
        );
    }
    table.print();
    if measured {
        println!("\n(padded layout is the §Perf outcome; warp layout is the faithful CUDA mapping)");
    } else {
        println!("\n(no XLA backends available — build with --features xla and run `make artifacts`)");
    }
}
