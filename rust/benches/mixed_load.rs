//! Mixed-load scheduling bench (fig-5 style): interactive tail latency
//! while a bulk backfill saturates the executor.
//!
//! Two scenarios over the same model, backend and traffic shape:
//!
//! * **fifo** — the pre-scheduler baseline: every request rides the
//!   batch class and the class targets are parked at 60 s, so batch
//!   formation degenerates to the old `max_batch_rows`/`max_wait` FIFO
//!   and the probe requests queue strictly behind the backfill.
//! * **slo** — the probes are submitted at [`Class::Interactive`] with
//!   a deadline; the batcher lets them lead batch formation and closes
//!   their batches early against the interactive class target.
//!
//! Each scenario runs [`RUNS`] times: a flood thread keeps a fixed
//! number of bulk contribution requests in flight while the main
//! thread fires `--probes` single-row probes and times each round
//! trip client-side (identical measurement in both scenarios). The
//! report carries the median interactive p50/p99 across runs and a
//! `{min, median}` bulk `rows_per_s` variance band per scenario — the
//! bands are what `bench-compare` gates, so a scheduler change that
//! buys tail latency by collapsing bulk throughput fails the perf job.
//! The headline acceptance ratios (slo p99 vs fifo p99, slo bulk
//! throughput vs fifo) are printed and written into the JSON report.
//!
//! Args (after `--`): `--rows N` bulk rows per backfill request
//! (default 64), `--probes N` interactive probes per run (default 40),
//! `--target-ms T` interactive class target (default 50),
//! `--json PATH` merges the summary under the `mixed_load` key.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::{Duration, Instant};

use gputreeshap::backend::{BackendConfig, BackendKind};
use gputreeshap::bench::{band_json, dump_record, write_json_report, zoo, Table};
use gputreeshap::cli::Args;
use gputreeshap::coordinator::{Class, Request, Response, ServiceConfig, ShapService};
use gputreeshap::gbdt::{Model, ZooSize};
use gputreeshap::util::Json;

/// Timed repetitions per scenario (min/median variance band).
const RUNS: usize = 3;

/// Bulk requests kept in flight by the flood thread.
const INFLIGHT: usize = 6;

struct RunResult {
    p50_s: f64,
    p99_s: f64,
    bulk_rows_per_s: f64,
    interactive_batches: usize,
    scheduler: Json,
}

fn pctl(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn median(samples: &[f64]) -> f64 {
    let mut s = samples.to_vec();
    s.sort_by(f64::total_cmp);
    pctl(&s, 0.5)
}

#[allow(clippy::too_many_arguments)]
fn run_once(
    model: &Arc<Model>,
    slo: bool,
    bulk_rows: usize,
    probes: usize,
    target_ms: u64,
    x_bulk: &Arc<Vec<f32>>,
    x_probe: &[f32],
) -> RunResult {
    let max_batch_rows = (bulk_rows * 4).max(32);
    let class_targets = if slo {
        [Duration::from_millis(target_ms), Duration::from_secs(2)]
    } else {
        // parked targets: batch formation falls back to the plain
        // max_batch_rows/max_wait FIFO the scheduler replaced
        [Duration::from_secs(60), Duration::from_secs(60)]
    };
    let scfg = ServiceConfig {
        max_batch_rows,
        max_wait: Duration::from_millis(20),
        recalibrate_every: 8,
        class_targets,
        ..Default::default()
    };
    let bcfg = BackendConfig { rows_hint: max_batch_rows, ..Default::default() };
    let svc = Arc::new(
        ShapService::start(model.clone(), BackendKind::Host, bcfg, scfg)
            .expect("service start"),
    );

    // warm the backend (prepared-model pack, first-batch setup) before
    // the timed window
    for _ in 0..2 {
        svc.explain(x_probe.to_vec(), 1).expect("warmup probe");
    }

    let stop = Arc::new(AtomicBool::new(false));
    let done_rows = Arc::new(AtomicU64::new(0));
    let flood = {
        let svc = svc.clone();
        let stop = stop.clone();
        let done_rows = done_rows.clone();
        let x_bulk = x_bulk.clone();
        std::thread::spawn(move || {
            let mut inflight: Vec<Receiver<Response>> = Vec::new();
            while !stop.load(Ordering::Relaxed) {
                while inflight.len() < INFLIGHT {
                    match svc.submit(Request::contributions(x_bulk.to_vec(), bulk_rows)) {
                        Ok(rx) => inflight.push(rx),
                        Err(_) => break, // backpressure: retry next turn
                    }
                }
                if inflight.is_empty() {
                    std::thread::sleep(Duration::from_micros(200));
                    continue;
                }
                if let Ok(resp) = inflight.remove(0).recv() {
                    if resp.values.is_ok() {
                        done_rows.fetch_add(bulk_rows as u64, Ordering::Relaxed);
                    }
                }
            }
            // drain what is still in flight so the service can stop
            for rx in inflight {
                if let Ok(resp) = rx.recv() {
                    if resp.values.is_ok() {
                        done_rows.fetch_add(bulk_rows as u64, Ordering::Relaxed);
                    }
                }
            }
        })
    };

    // let the backfill build a standing queue before probing
    std::thread::sleep(Duration::from_millis(30));
    let rows0 = done_rows.load(Ordering::Relaxed);
    let t0 = Instant::now();
    let mut latencies = Vec::with_capacity(probes);
    for _ in 0..probes {
        let mut req = Request::contributions(x_probe.to_vec(), 1);
        if slo {
            req = req
                .with_priority(Class::Interactive)
                .with_deadline_ms(target_ms.saturating_mul(4).max(1));
        }
        let t = Instant::now();
        svc.run(req).expect("probe");
        latencies.push(t.elapsed().as_secs_f64());
        std::thread::sleep(Duration::from_millis(2));
    }
    let window_s = t0.elapsed().as_secs_f64();
    let window_rows = done_rows.load(Ordering::Relaxed) - rows0;
    stop.store(true, Ordering::Relaxed);
    let _ = flood.join();

    let scheduler = svc.metrics.scheduler_snapshot();
    let interactive_batches = scheduler
        .get(Class::Interactive.name())
        .and_then(|c| c.get("batches"))
        .and_then(|b| b.as_usize())
        .unwrap_or(0);
    svc.drain();

    latencies.sort_by(f64::total_cmp);
    RunResult {
        p50_s: pctl(&latencies, 0.5),
        p99_s: pctl(&latencies, 0.99),
        bulk_rows_per_s: window_rows as f64 / window_s.max(1e-9),
        interactive_batches,
        scheduler,
    }
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let bulk_rows = args.get_usize("rows", 64).expect("--rows").max(1);
    let probes = args.get_usize("probes", 40).expect("--probes").max(1);
    let target_ms = args.get_usize("target-ms", 50).expect("--target-ms").max(1) as u64;
    let json_path = args.get("json").map(std::path::PathBuf::from);

    let entry = zoo::zoo_entries()
        .into_iter()
        .find(|e| e.spec.name == "cal_housing" && e.size == ZooSize::Small)
        .unwrap();
    let (model, data) = zoo::build(&entry);
    let m = model.num_features;
    let bulk_rows = bulk_rows.min(data.rows);
    let x_bulk = Arc::new(data.features[..bulk_rows * m].to_vec());
    let x_probe = data.features[..m].to_vec();
    let model = Arc::new(model);
    println!(
        "mixed_load: {} — {}-row backfill × {} in flight, {} probes/run, \
         interactive target {} ms, {} runs/scenario\n",
        entry.name, bulk_rows, INFLIGHT, probes, target_ms, RUNS
    );

    let mut table = Table::new(&[
        "scenario",
        "probe p50(ms)",
        "probe p99(ms)",
        "bulk rows/s",
        "interactive batches",
    ]);
    let mut report_fields: Vec<(&str, Json)> = vec![
        ("model", Json::from(entry.name.as_str())),
        ("bulk_rows", Json::from(bulk_rows)),
        ("probes", Json::from(probes)),
        ("target_ms", Json::from(target_ms as usize)),
        ("runs", Json::from(RUNS)),
    ];
    let mut summary: Vec<(bool, f64, f64)> = Vec::new(); // (slo, p99, bulk_rps)
    let mut slo_scheduler = Json::Null;

    for &slo in &[false, true] {
        let name = if slo { "slo" } else { "fifo" };
        let mut p50s = Vec::with_capacity(RUNS);
        let mut p99s = Vec::with_capacity(RUNS);
        let mut bulk_rps = Vec::with_capacity(RUNS);
        let mut batches = 0usize;
        for _ in 0..RUNS {
            let r = run_once(
                &model, slo, bulk_rows, probes, target_ms, &x_bulk, &x_probe,
            );
            p50s.push(r.p50_s);
            p99s.push(r.p99_s);
            bulk_rps.push(r.bulk_rows_per_s);
            batches = batches.max(r.interactive_batches);
            if slo {
                slo_scheduler = r.scheduler;
            }
        }
        let (p50, p99) = (median(&p50s), median(&p99s));
        table.row(vec![
            name.into(),
            format!("{:.2}", p50 * 1e3),
            format!("{:.2}", p99 * 1e3),
            format!("{:.0}", median(&bulk_rps)),
            batches.to_string(),
        ]);
        println!("{name} interactive_batches={batches}");
        summary.push((slo, p99, median(&bulk_rps)));
        report_fields.push((
            if slo { "slo" } else { "fifo" },
            Json::obj(vec![
                ("interactive_p50_s", Json::from(p50)),
                ("interactive_p99_s", Json::from(p99)),
                ("bulk_rows_per_s", band_json(&bulk_rps)),
                ("interactive_batches", Json::from(batches)),
            ]),
        ));
        dump_record(
            "mixed_load",
            vec![
                ("scenario", Json::from(name)),
                ("interactive_p99_s", Json::from(p99)),
                ("bulk_rows_per_s", Json::from(median(&bulk_rps))),
                ("interactive_batches", Json::from(batches)),
            ],
        );
    }

    table.print();
    println!("\nscheduler stats (last slo run): {}", slo_scheduler.to_string_pretty());

    let fifo = summary.iter().find(|s| !s.0).unwrap();
    let slo = summary.iter().find(|s| s.0).unwrap();
    let p99_ratio = if fifo.1 > 0.0 { slo.1 / fifo.1 } else { 1.0 };
    let bulk_ratio = if fifo.2 > 0.0 { slo.2 / fifo.2 } else { 1.0 };
    println!(
        "interactive p99: fifo {:.2} ms -> slo {:.2} ms ({:.2}x); \
         bulk throughput slo/fifo = {:.2}",
        fifo.1 * 1e3,
        slo.1 * 1e3,
        p99_ratio,
        bulk_ratio
    );
    report_fields.push(("p99_ratio_slo_over_fifo", Json::from(p99_ratio)));
    report_fields.push(("bulk_ratio_slo_over_fifo", Json::from(bulk_ratio)));

    if let Some(path) = json_path {
        write_json_report(&path, "mixed_load", Json::obj(report_fields))
            .expect("write --json report");
        println!("json report merged into {}", path.display());
    }
}
