//! Table 6 reproduction: SHAP-value throughput, CPU baseline (recursive
//! Algorithm 1, all cores) vs the batched packed-DP engines — `host`
//! (rust-native, the GPU algorithm on CPU) and `xla` (AOT Pallas kernel
//! via PJRT).
//!
//! The paper ran a V100 against 40 Xeon cores; this testbed has one CPU
//! core and a CPU PJRT backend, so absolute speedups differ — what must
//! reproduce is the *structure*: per-model ranking of work (small ≪ med
//! ≪ large), engine overhead amortising with model size, and identical
//! outputs across all engines (checked here row-for-row).

use gputreeshap::bench::{dump_record, fmt_secs, zoo, Table};
use gputreeshap::parallel::default_threads;
use gputreeshap::runtime::{default_artifacts_dir, ArtifactKind, ShapEngine};
use gputreeshap::shap::{host_kernel, pack_model, pad_model, treeshap, Packing};
use gputreeshap::util::{Json, Stats};

const ROWS: usize = 256; // paper: 10 000 — scaled (DESIGN.md §5)
const ITERS: usize = 3;

fn main() {
    let threads = default_threads();
    println!("table6: {ROWS} test rows, {threads} cpu thread(s), median of {ITERS}\n");
    let mut table = Table::new(&[
        "model", "cpu(s)", "std", "host(s)", "xla-warp(s)", "xla-pad(s)", "warp/cpu", "pad/cpu",
    ]);
    let mut engine = ShapEngine::new(&default_artifacts_dir()).expect("artifacts");
    for entry in zoo::zoo_entries() {
        let (model, data) = zoo::build(&entry);
        let m = model.num_features;
        let rows = ROWS.min(data.rows);
        let x = &data.features[..rows * m];
        let pm = pack_model(&model, Packing::BestFitDecreasing);

        let mut cpu_s = Vec::new();
        let mut host_s = Vec::new();
        let mut xla_s = Vec::new();
        let mut pad_s = Vec::new();
        let mut outs: Vec<Vec<f32>> = Vec::new();
        let prep = engine.prepare(&pm, ArtifactKind::Shap, rows).expect("prepare");
        let width = engine
            .manifest
            .select(ArtifactKind::ShapPadded, m, pm.max_depth.max(1), rows)
            .expect("padded bucket")
            .depth
            + 1;
        let pad = pad_model(&model, width);
        let pad_prep = engine.prepare_padded(&pad, rows).expect("padded prepare");
        for i in 0..ITERS {
            let t = std::time::Instant::now();
            let a = treeshap::shap_values(&model, x, rows, threads);
            cpu_s.push(t.elapsed().as_secs_f64());
            let t = std::time::Instant::now();
            let b = host_kernel::shap_values(&pm, x, rows, threads);
            host_s.push(t.elapsed().as_secs_f64());
            let t = std::time::Instant::now();
            let c = engine.shap_values(&pm, &prep, x, rows).expect("xla");
            xla_s.push(t.elapsed().as_secs_f64());
            let t = std::time::Instant::now();
            let p = engine.shap_values_padded(&pad, &pad_prep, x, rows).expect("padded");
            pad_s.push(t.elapsed().as_secs_f64());
            if i == 0 {
                outs = vec![a, b, c, p];
            }
        }
        // all engines agree
        for (i, (a, b)) in outs[0].iter().zip(&outs[1]).enumerate() {
            assert!((a - b).abs() < 5e-3, "{}: host mismatch idx {i}", entry.name);
        }
        for (i, (a, c)) in outs[0].iter().zip(&outs[2]).enumerate() {
            assert!((a - c).abs() < 5e-2 + 5e-3 * a.abs(), "{}: xla mismatch idx {i}: {a} vs {c}", entry.name);
        }
        for (i, (a, c)) in outs[0].iter().zip(&outs[3]).enumerate() {
            assert!((a - c).abs() < 5e-2 + 5e-3 * a.abs(), "{}: padded mismatch idx {i}: {a} vs {c}", entry.name);
        }
        let cpu = Stats::from_samples(&cpu_s);
        let xla = Stats::from_samples(&xla_s);
        let host = Stats::from_samples(&host_s);
        let pad_st = Stats::from_samples(&pad_s);
        table.row(vec![
            entry.name.clone(),
            fmt_secs(cpu.p50),
            fmt_secs(cpu.std),
            fmt_secs(host.p50),
            fmt_secs(xla.p50),
            fmt_secs(pad_st.p50),
            format!("{:.2}x", cpu.p50 / xla.p50),
            format!("{:.2}x", cpu.p50 / pad_st.p50),
        ]);
        dump_record(
            "table6",
            vec![
                ("model", Json::from(entry.name.as_str())),
                ("rows", Json::from(ROWS)),
                ("cpu_s", Json::from(cpu.p50)),
                ("host_s", Json::from(host.p50)),
                ("xla_s", Json::from(xla.p50)),
                ("xla_padded_s", Json::from(pad_st.p50)),
                ("speedup_xla_over_cpu", Json::from(cpu.p50 / xla.p50)),
                ("speedup_padded_over_cpu", Json::from(cpu.p50 / pad_st.p50)),
            ],
        );
    }
    table.print();
}
