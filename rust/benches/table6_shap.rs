//! Table 6 reproduction: SHAP-value throughput across every registered
//! backend — recursive Algorithm 1 (`cpu`), the host packed DP (`host`),
//! and the XLA engines (`xla`, `xla-padded`) when compiled in and
//! artifacts exist. All execution goes through `backend::ShapBackend`.
//!
//! The paper ran a V100 against 40 Xeon cores; this testbed has one CPU
//! core and a CPU PJRT backend, so absolute speedups differ — what must
//! reproduce is the *structure*: per-model ranking of work (small ≪ med
//! ≪ large), engine overhead amortising with model size, and identical
//! outputs across all backends (checked here row-for-row).

use std::sync::Arc;

use gputreeshap::backend::{self, BackendConfig, BackendKind, ShapBackend};
use gputreeshap::bench::{dump_record, fmt_secs, zoo, Table};
use gputreeshap::parallel::default_threads;
use gputreeshap::util::{Json, Stats};

const ROWS: usize = 256; // paper: 10 000 — scaled (DESIGN.md §5)
const ITERS: usize = 3;

fn main() {
    let threads = default_threads();
    println!("table6: {ROWS} test rows, {threads} cpu thread(s), median of {ITERS}\n");
    let mut table = Table::new(&["model", "backend", "time(s)", "std", "rows/s", "vs cpu"]);
    for entry in zoo::zoo_entries() {
        let (model, data) = zoo::build(&entry);
        let m = model.num_features;
        let rows = ROWS.min(data.rows);
        let x = &data.features[..rows * m];
        let model = Arc::new(model);
        let cfg = BackendConfig { threads, rows_hint: rows, ..Default::default() };

        let mut cpu_p50: Option<f64> = None;
        let mut reference: Option<Vec<f32>> = None;
        for kind in BackendKind::ALL {
            let b = match backend::build(&model, kind, &cfg) {
                Ok(b) => b,
                Err(e) => {
                    eprintln!("  [skip {} on {}: {e}]", kind.name(), entry.name);
                    continue;
                }
            };
            let mut times = Vec::new();
            let mut out = Vec::new();
            for _ in 0..ITERS {
                let t = std::time::Instant::now();
                out = b.contributions(x, rows).expect("contributions");
                times.push(t.elapsed().as_secs_f64());
            }
            // every backend must agree with the recursive oracle
            match &reference {
                Some(r) => {
                    for (i, (a, c)) in r.iter().zip(&out).enumerate() {
                        assert!(
                            (a - c).abs() < 5e-2 + 5e-3 * a.abs(),
                            "{} / {}: mismatch idx {i}: {a} vs {c}",
                            entry.name,
                            kind.name()
                        );
                    }
                }
                None => reference = Some(out),
            }
            let st = Stats::from_samples(&times);
            if kind == BackendKind::Recursive {
                cpu_p50 = Some(st.p50);
            }
            let vs_cpu = cpu_p50
                .map(|c| format!("{:.2}x", c / st.p50))
                .unwrap_or_else(|| "-".to_string());
            table.row(vec![
                entry.name.clone(),
                kind.name().to_string(),
                fmt_secs(st.p50),
                fmt_secs(st.std),
                format!("{:.0}", rows as f64 / st.p50),
                vs_cpu,
            ]);
            dump_record(
                "table6",
                vec![
                    ("model", Json::from(entry.name.as_str())),
                    ("backend", Json::from(kind.name())),
                    ("rows", Json::from(rows)),
                    ("p50_s", Json::from(st.p50)),
                    ("speedup_over_cpu", Json::from(cpu_p50.map_or(1.0, |c| c / st.p50))),
                ],
            );
        }
    }
    table.print();
}
