//! Table 7 reproduction: SHAP *interaction* values — the paper's
//! headline algorithmic win. Three engines:
//!
//! - `cpu`:  the O(T·L·D²·M) baseline (conditioning on every feature in
//!           the tree, Algorithm 1 twice per feature) — what XGBoost does
//! - `host`: the paper's O(T·L·D³) reformulation (condition only on
//!           on-path features), rust-native
//! - `xla`:  the same reformulation through the AOT Pallas kernel
//!
//! On this 1-core testbed, the *algorithmic* gap (M/D ratio) is the
//! reproducible signal: covtype (M=54, D≤8) and fashion_mnist96 (M=96)
//! must show host ≫ cpu, while cal_housing (M=8 ≈ D) shows little —
//! exactly the pattern of the paper's Table 7 (340× on fashion_mnist vs
//! 11× on cal_housing).

use gputreeshap::bench::{dump_record, fmt_secs, zoo, Table};
use gputreeshap::gbdt::ZooSize;
use gputreeshap::parallel::default_threads;
use gputreeshap::runtime::{default_artifacts_dir, ArtifactKind, ShapEngine};
use gputreeshap::shap::{host_kernel, interactions, pack_model, pad_model, Packing};
use gputreeshap::util::Json;

const ROWS: usize = 8; // paper: 200 — scaled (DESIGN.md §5)

fn main() {
    let threads = default_threads();
    println!("table7: {ROWS} test rows, {threads} cpu thread(s)\n");
    let mut table = Table::new(&[
        "model", "M", "D", "cpu(s)", "host(s)", "xla(s)", "xla-pad(s)", "host/cpu", "pad/cpu",
    ]);
    let mut engine = ShapEngine::new(&default_artifacts_dir()).expect("artifacts");

    // interaction zoo: covtype / cal_housing / adult (small+med) and the
    // reduced-feature fashion variant (M=96; XLA buckets cap at M=128)
    let mut entries: Vec<(String, gputreeshap::gbdt::Model, gputreeshap::data::Dataset)> =
        Vec::new();
    for entry in zoo::zoo_entries() {
        if entry.spec.name == "fashion_mnist" || entry.size == ZooSize::Large {
            continue;
        }
        let (model, data) = zoo::build(&entry);
        entries.push((entry.name.clone(), model, data));
    }
    for size in [ZooSize::Small, ZooSize::Medium] {
        let (rounds, depth) = size.rounds_depth();
        let spec = zoo::fashion96(0.005);
        let (model, data) =
            zoo::build_custom(&format!("fashion_mnist96-{}", size.name()), &spec, rounds, depth);
        entries.push((format!("fashion_mnist96-{}", size.name()), model, data));
    }

    for (name, model, data) in entries {
        let m = model.num_features;
        let rows = ROWS.min(data.rows);
        let x = &data.features[..rows * m];
        let pm = pack_model(&model, Packing::BestFitDecreasing);

        let t = std::time::Instant::now();
        let a = interactions::interaction_values(&model, x, rows, threads);
        let cpu = t.elapsed().as_secs_f64();

        let t = std::time::Instant::now();
        let b = host_kernel::interaction_values(&pm, x, rows, threads);
        let host = t.elapsed().as_secs_f64();

        let prep = engine.prepare(&pm, ArtifactKind::Interactions, rows).expect("prepare");
        let t = std::time::Instant::now();
        let c = engine.interactions(&pm, &prep, x, rows).expect("xla");
        let xla = t.elapsed().as_secs_f64();

        let width = engine
            .manifest
            .select(ArtifactKind::InteractionsPadded, m, pm.max_depth.max(2), rows)
            .expect("padded int bucket")
            .depth
            + 1;
        let pad = pad_model(&model, width);
        let pad_prep = engine
            .prepare_padded_kind(&pad, ArtifactKind::InteractionsPadded, rows)
            .expect("padded int prepare");
        let t = std::time::Instant::now();
        let cp = engine.interactions_padded(&pad, &pad_prep, x, rows).expect("padded");
        let pad_t = t.elapsed().as_secs_f64();

        for (i, (p, q)) in a.iter().zip(&b).enumerate() {
            assert!((p - q).abs() < 5e-3, "{name}: host mismatch idx {i}: {p} vs {q}");
        }
        for (i, (p, q)) in a.iter().zip(&c).enumerate() {
            assert!(
                (p - q).abs() < 5e-2 + 5e-3 * p.abs(),
                "{name}: xla mismatch idx {i}: {p} vs {q}"
            );
        }
        for (i, (p, q)) in a.iter().zip(&cp).enumerate() {
            assert!(
                (p - q).abs() < 5e-2 + 5e-3 * p.abs(),
                "{name}: padded mismatch idx {i}: {p} vs {q}"
            );
        }

        table.row(vec![
            name.clone(),
            m.to_string(),
            pm.max_depth.to_string(),
            fmt_secs(cpu),
            fmt_secs(host),
            fmt_secs(xla),
            fmt_secs(pad_t),
            format!("{:.2}x", cpu / host),
            format!("{:.2}x", cpu / pad_t),
        ]);
        dump_record(
            "table7",
            vec![
                ("model", Json::from(name.as_str())),
                ("features", Json::from(m)),
                ("depth", Json::from(pm.max_depth)),
                ("cpu_s", Json::from(cpu)),
                ("host_s", Json::from(host)),
                ("xla_s", Json::from(xla)),
                ("xla_padded_s", Json::from(pad_t)),
                ("speedup_host_over_cpu", Json::from(cpu / host)),
                ("speedup_xla_over_cpu", Json::from(cpu / xla)),
                ("speedup_padded_over_cpu", Json::from(cpu / pad_t)),
            ],
        );
    }
    table.print();
    println!("\nexpected pattern (paper Table 7): speedups grow with M/D —");
    println!("fashion_mnist96 & covtype ≫ adult > cal_housing");
}
