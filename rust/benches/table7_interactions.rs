//! Table 7 reproduction: SHAP *interaction* values — the paper's
//! headline algorithmic win — across every backend that supports them:
//!
//! - `cpu`:  the O(T·L·D²·M) baseline (conditioning on every feature in
//!           the tree, Algorithm 1 twice per feature) — what XGBoost does
//! - `host`: the paper's O(T·L·D³) reformulation (condition only on
//!           on-path features) over packed tensors, rust-native
//! - `xla`/`xla-padded`: the same reformulation through the AOT kernels
//!
//! On this 1-core testbed, the *algorithmic* gap (M/D ratio) is the
//! reproducible signal: covtype (M=54, D≤8) and fashion_mnist96 (M=96)
//! must show host ≫ cpu, while cal_housing (M=8 ≈ D) shows little —
//! exactly the pattern of the paper's Table 7 (340× on fashion_mnist vs
//! 11× on cal_housing). All execution goes through `backend::ShapBackend`.

use std::sync::Arc;

use gputreeshap::backend::{self, BackendConfig, BackendKind, ShapBackend};
use gputreeshap::bench::{dump_record, fmt_secs, zoo, Table};
use gputreeshap::gbdt::ZooSize;
use gputreeshap::parallel::default_threads;
use gputreeshap::util::Json;

const ROWS: usize = 8; // paper: 200 — scaled (DESIGN.md §5)

fn main() {
    let threads = default_threads();
    println!("table7: {ROWS} test rows, {threads} cpu thread(s)\n");
    let mut table =
        Table::new(&["model", "M", "D", "backend", "time(s)", "vs cpu"]);

    // interaction zoo: covtype / cal_housing / adult (small+med) and the
    // reduced-feature fashion variant (M=96; XLA buckets cap at M=128)
    let mut entries: Vec<(String, gputreeshap::gbdt::Model, gputreeshap::data::Dataset)> =
        Vec::new();
    for entry in zoo::zoo_entries() {
        if entry.spec.name == "fashion_mnist" || entry.size == ZooSize::Large {
            continue;
        }
        let (model, data) = zoo::build(&entry);
        entries.push((entry.name.clone(), model, data));
    }
    for size in [ZooSize::Small, ZooSize::Medium] {
        let (rounds, depth) = size.rounds_depth();
        let spec = zoo::fashion96(0.005);
        let (model, data) =
            zoo::build_custom(&format!("fashion_mnist96-{}", size.name()), &spec, rounds, depth);
        entries.push((format!("fashion_mnist96-{}", size.name()), model, data));
    }

    for (name, model, data) in entries {
        let m = model.num_features;
        let depth = model.max_depth();
        let rows = ROWS.min(data.rows);
        let x = &data.features[..rows * m];
        let model = Arc::new(model);
        let cfg = BackendConfig {
            threads,
            rows_hint: rows,
            with_interactions: true,
            ..Default::default()
        };

        let mut cpu_t: Option<f64> = None;
        let mut reference: Option<Vec<f32>> = None;
        for kind in BackendKind::ALL {
            let b = match backend::build(&model, kind, &cfg) {
                Ok(b) => b,
                Err(e) => {
                    eprintln!("  [skip {} on {name}: {e}]", kind.name());
                    continue;
                }
            };
            if !b.caps().supports_interactions {
                eprintln!("  [skip {} on {name}: no interaction support]", kind.name());
                continue;
            }
            let t = std::time::Instant::now();
            let out = b.interactions(x, rows).expect("interactions");
            let dt = t.elapsed().as_secs_f64();
            match &reference {
                Some(r) => {
                    for (i, (a, c)) in r.iter().zip(&out).enumerate() {
                        assert!(
                            (a - c).abs() < 5e-2 + 5e-3 * a.abs(),
                            "{name} / {}: mismatch idx {i}: {a} vs {c}",
                            kind.name()
                        );
                    }
                }
                None => reference = Some(out),
            }
            if kind == BackendKind::Recursive {
                cpu_t = Some(dt);
            }
            let vs_cpu =
                cpu_t.map(|c| format!("{:.2}x", c / dt)).unwrap_or_else(|| "-".to_string());
            table.row(vec![
                name.clone(),
                m.to_string(),
                depth.to_string(),
                kind.name().to_string(),
                fmt_secs(dt),
                vs_cpu,
            ]);
            dump_record(
                "table7",
                vec![
                    ("model", Json::from(name.as_str())),
                    ("backend", Json::from(kind.name())),
                    ("features", Json::from(m)),
                    ("depth", Json::from(depth)),
                    ("time_s", Json::from(dt)),
                    ("speedup_over_cpu", Json::from(cpu_t.map_or(1.0, |c| c / dt))),
                ],
            );
        }
    }
    table.print();
    println!("\nexpected pattern (paper Table 7): speedups grow with M/D —");
    println!("fashion_mnist96 & covtype ≫ adult > cal_housing");
}
