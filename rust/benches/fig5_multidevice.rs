//! Fig 5 reproduction: throughput scaling across simulated devices
//! (paper: 1–8 V100s reach 1.2 M rows/s on cal_housing-med).
//!
//! Each "device" is an independent PJRT CPU client on its own thread
//! with its own compiled executables and device-resident model — the
//! same topology as the paper's multi-GPU run. On this 1-core testbed
//! the devices time-share the core, so the curve is flat; the bench
//! still verifies the sharding produces identical results and records
//! rows/s per device count.

use gputreeshap::bench::{dump_record, zoo, Table};
use gputreeshap::gbdt::ZooSize;
use gputreeshap::runtime::default_artifacts_dir;
use gputreeshap::runtime::pool::shap_values_multi;
use gputreeshap::shap::{pack_model, Packing};
use gputreeshap::util::Json;

const ROWS: usize = 512; // paper: 1M — scaled (DESIGN.md §5)

fn main() {
    let entry = zoo::zoo_entries()
        .into_iter()
        .find(|e| e.spec.name == "cal_housing" && e.size == ZooSize::Medium)
        .unwrap();
    let (model, data) = zoo::build(&entry);
    println!("fig5: {} — {} rows\n", entry.name, ROWS);
    let m = model.num_features;
    let rows = ROWS.min(data.rows);
    let x = &data.features[..rows * m];
    let pm = pack_model(&model, Packing::BestFitDecreasing);
    let dir = default_artifacts_dir();

    let mut table = Table::new(&["devices", "time(s)", "rows/s", "scaling"]);
    let mut base = None;
    let mut reference: Option<Vec<f32>> = None;
    for devices in [1usize, 2, 4] {
        let t = std::time::Instant::now();
        let out = shap_values_multi(&pm, x, rows, devices, &dir).expect("pool");
        let dt = t.elapsed().as_secs_f64();
        if let Some(r) = &reference {
            for (a, b) in r.iter().zip(&out) {
                assert!((a - b).abs() < 1e-5, "sharded result differs");
            }
        } else {
            reference = Some(out);
        }
        let rps = rows as f64 / dt;
        let scaling = base.map_or(1.0, |b: f64| rps / b);
        if base.is_none() {
            base = Some(rps);
        }
        table.row(vec![
            devices.to_string(),
            format!("{dt:.2}"),
            format!("{rps:.0}"),
            format!("{scaling:.2}x"),
        ]);
        dump_record(
            "fig5",
            vec![
                ("devices", Json::from(devices)),
                ("time_s", Json::from(dt)),
                ("rows_per_s", Json::from(rps)),
            ],
        );
    }
    table.print();
    println!("\n(paper: near-linear to 8 GPUs; flat here = 1 physical core, see EXPERIMENTS.md)");
}
