//! Fig 5 reproduction: throughput scaling across device shards
//! (paper: 1–8 V100s reach 1.2 M rows/s on cal_housing-med), extended
//! with the tree axis the backend layer adds on top of the paper's
//! row-axis scheme.
//!
//! Runs entirely through the `ShapBackend` trait: each "device" is an
//! independent backend instance inside a `ShardedBackend` (on a DGX,
//! 8 PJRT GPU clients; on this testbed, CPU instances that time-share
//! the cores, so the curve flattens once physical cores saturate — the
//! bench records rows/s per (axis, devices) either way, DESIGN.md §5
//! scale substitutions). Result parity against the unsharded oracle is
//! asserted in `rust/tests/backends.rs`, not here.
//!
//! Build time is reported per configuration, **outside** the timed
//! batch region: row-axis shards share one prepared-model cache entry,
//! so after the first configuration packs the model, every later
//! row-axis build costs a cache lookup — the `build(s)` column makes
//! the cache visible (compare the first row-axis line to the rest).
//!
//! Args (after `--`): `--rows N` (default 512), `--devices N` max shard
//! count (default 4), `--backend cpu|host|…` (default host),
//! `--size small|med|large` (default med), `--json PATH` merges a
//! machine-readable summary under the `fig5` key at PATH.

use std::sync::Arc;

use gputreeshap::backend::{BackendConfig, BackendKind, ShapBackend, ShardAxis, ShardedBackend};
use gputreeshap::bench::{dump_record, write_json_report, zoo, Table};
use gputreeshap::cli::Args;
use gputreeshap::gbdt::ZooSize;
use gputreeshap::util::{time_it, Json};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let rows_req = args.get_usize("rows", 512).expect("--rows");
    let max_devices = args.get_usize("devices", 4).expect("--devices").max(1);
    let json_path = args.get("json").map(std::path::PathBuf::from);
    let kind = {
        let name = args.get_or("backend", "host");
        BackendKind::parse(name).unwrap_or_else(|| panic!("unknown backend '{name}'"))
    };
    let size = match args.get_or("size", "med") {
        "small" => ZooSize::Small,
        "med" | "medium" => ZooSize::Medium,
        "large" => ZooSize::Large,
        other => panic!("unknown size '{other}' (small|med|large)"),
    };

    let entry = zoo::zoo_entries()
        .into_iter()
        .find(|e| e.spec.name == "cal_housing" && e.size == size)
        .unwrap();
    let (model, data) = zoo::build(&entry);
    let m = model.num_features;
    let rows = rows_req.min(data.rows);
    let x = &data.features[..rows * m];
    let model = Arc::new(model);
    println!(
        "fig5: {} — {} rows, backend {}, up to {} device(s)\n",
        entry.name,
        rows,
        kind.name(),
        max_devices
    );

    let device_counts: Vec<usize> =
        [1usize, 2, 4, 8].into_iter().filter(|&d| d <= max_devices).collect();
    let mut table = Table::new(&["axis", "devices", "build(s)", "time(s)", "rows/s", "scaling"]);
    let mut configs: Vec<Json> = Vec::new();
    let mut best_rps = 0.0f64;
    for axis in ShardAxis::ALL {
        let mut base: Option<f64> = None;
        let mut measured: Vec<usize> = Vec::new();
        for &devices in &device_counts {
            let cfg = BackendConfig { rows_hint: rows.max(1), ..Default::default() };
            let (sharded, build_s) = time_it(|| {
                ShardedBackend::build(&model, kind, &cfg, devices, axis)
                    .expect("sharded backend")
            });
            // the tree axis clamps shards to the tree count: don't
            // re-measure (and re-record) an identical configuration
            if measured.contains(&sharded.shards()) {
                continue;
            }
            measured.push(sharded.shards());
            let t = std::time::Instant::now();
            sharded.contributions(x, rows).expect("contributions");
            let dt = t.elapsed().as_secs_f64();
            let rps = rows as f64 / dt;
            best_rps = best_rps.max(rps);
            let scaling = base.map_or(1.0, |b| rps / b);
            if base.is_none() {
                base = Some(rps);
            }
            table.row(vec![
                axis.name().into(),
                sharded.shards().to_string(),
                format!("{build_s:.3}"),
                format!("{dt:.3}"),
                format!("{rps:.0}"),
                format!("{scaling:.2}x"),
            ]);
            configs.push(Json::obj(vec![
                ("axis", Json::from(axis.name())),
                ("devices", Json::from(sharded.shards())),
                ("build_s", Json::from(build_s)),
                ("time_s", Json::from(dt)),
            ]));
            dump_record(
                "fig5",
                vec![
                    ("axis", Json::from(axis.name())),
                    ("devices", Json::from(sharded.shards())),
                    ("build_s", Json::from(build_s)),
                    ("time_s", Json::from(dt)),
                    ("rows_per_s", Json::from(rps)),
                ],
            );
        }
    }
    table.print();
    println!(
        "\n(paper: near-linear row-axis scaling to 8 GPUs; flat here = shared cores, see EXPERIMENTS.md)"
    );

    if let Some(path) = json_path {
        let report = Json::obj(vec![
            ("model", Json::from(entry.name.as_str())),
            ("backend", Json::from(kind.name())),
            ("rows", Json::from(rows)),
            ("configs", Json::Arr(configs)),
            ("best_rows_per_s", Json::from(best_rps)),
        ]);
        write_json_report(&path, "fig5", report).expect("write --json report");
        println!("json report merged into {}", path.display());
    }
}
