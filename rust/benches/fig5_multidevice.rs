//! Fig 5 reproduction: throughput scaling across device shards
//! (paper: 1–8 V100s reach 1.2 M rows/s on cal_housing-med), extended
//! with the tree axis the backend layer adds on top of the paper's
//! row-axis scheme, and with rows × trees **grid** topologies (nested
//! sharding) for the configurations where one axis saturates.
//!
//! Runs entirely through the `ShapBackend` trait: each "device" is an
//! independent backend instance inside a `ShardedBackend` (or a
//! `GridBackend` cell; on a DGX, 8 PJRT GPU clients; on this testbed,
//! CPU instances that time-share the cores, so the curve flattens once
//! physical cores saturate — the bench records rows/s per
//! (axis, devices) either way, DESIGN.md §5 scale substitutions).
//! Result parity against the unsharded oracle is asserted in
//! `rust/tests/backends.rs`, not here.
//!
//! Build time is reported per configuration, **outside** the timed
//! batch region: row-axis shards share one prepared-model cache entry
//! and a grid's row replicas share one entry per tree slice, so after
//! the first configuration packs a (sub-)model, later builds over it
//! cost a cache lookup — the `build(s)` column makes the cache visible.
//!
//! The timed region runs [`RUNS`] times per configuration and reports a
//! `{min, median}` rows/s variance band (`bench::band_json`), which
//! `bench-compare` gates as current-median vs baseline-min — the
//! ROADMAP's "perf baseline variance bands".
//!
//! A second section sweeps **interaction-value** throughput in the
//! wide-model (`M ≫ D`) regime at M ∈ {96, 256} — past the XLA padded
//! bucket cap — comparing the feature-tile axis against row shards and
//! the single-shard host kernel (`steady_rows_per_s.tiles` in the JSON
//! report). Φ cost scales with the conditioned-feature count, so this
//! is the regime the fourth shard axis exists for.
//!
//! Args (after `--`): `--rows N` (default 512), `--devices N` max shard
//! count (default 4), `--backend cpu|host|…` (default host),
//! `--size small|med|large` (default med), `--shard-axis tiles|rows`
//! restricts the interactions sweep to one sharded axis (default both;
//! the φ section always sweeps every axis), `--json PATH` merges a
//! machine-readable summary under the `fig5` key at PATH.

use std::sync::Arc;

use gputreeshap::backend::{
    self, BackendConfig, BackendKind, GridBackend, Planner, ShapBackend, ShardAxis,
    ShardGrid, ShardedBackend, TilesBackend,
};
use gputreeshap::bench::{band_json, dump_record, write_json_report, zoo, Table};
use gputreeshap::cli::Args;
use gputreeshap::gbdt::ZooSize;
use gputreeshap::util::{time_it, Json};

/// Timed repetitions per configuration (min/median variance band).
const RUNS: usize = 3;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let rows_req = args.get_usize("rows", 512).expect("--rows");
    let max_devices = args.get_usize("devices", 4).expect("--devices").max(1);
    let json_path = args.get("json").map(std::path::PathBuf::from);
    let kind = {
        let name = args.get_or("backend", "host");
        BackendKind::parse(name).unwrap_or_else(|| panic!("unknown backend '{name}'"))
    };
    let size = match args.get_or("size", "med") {
        "small" => ZooSize::Small,
        "med" | "medium" => ZooSize::Medium,
        "large" => ZooSize::Large,
        other => panic!("unknown size '{other}' (small|med|large)"),
    };

    let entry = zoo::zoo_entries()
        .into_iter()
        .find(|e| e.spec.name == "cal_housing" && e.size == size)
        .unwrap();
    let (model, data) = zoo::build(&entry);
    let m = model.num_features;
    let rows = rows_req.min(data.rows);
    let x = &data.features[..rows * m];
    let model = Arc::new(model);
    println!(
        "fig5: {} — {} rows, backend {}, up to {} device(s), {} timed runs/config\n",
        entry.name,
        rows,
        kind.name(),
        max_devices,
        RUNS
    );

    let device_counts: Vec<usize> =
        [1usize, 2, 4, 8].into_iter().filter(|&d| d <= max_devices).collect();
    let mut table =
        Table::new(&["axis", "devices", "build(s)", "time(s)", "rows/s", "scaling"]);
    let mut configs: Vec<Json> = Vec::new();
    let mut best_rps = 0.0f64;

    // measure one built configuration RUNS times; returns median rows/s
    let mut measure = |axis_name: &str,
                       devices_label: String,
                       shards: usize,
                       build_s: f64,
                       backend: &dyn ShapBackend,
                       table: &mut Table,
                       configs: &mut Vec<Json>,
                       base: &mut Option<f64>| {
        let mut times = Vec::with_capacity(RUNS);
        for _ in 0..RUNS {
            let t = std::time::Instant::now();
            backend.contributions(x, rows).expect("contributions");
            times.push(t.elapsed().as_secs_f64());
        }
        times.sort_by(f64::total_cmp);
        let median_t = times[times.len() / 2];
        let rps_samples: Vec<f64> = times.iter().map(|t| rows as f64 / t).collect();
        let median_rps = rows as f64 / median_t;
        best_rps = best_rps.max(median_rps);
        let scaling = base.map_or(1.0, |b| median_rps / b);
        if base.is_none() {
            *base = Some(median_rps);
        }
        table.row(vec![
            axis_name.into(),
            devices_label.clone(),
            format!("{build_s:.3}"),
            format!("{median_t:.3}"),
            format!("{median_rps:.0}"),
            format!("{scaling:.2}x"),
        ]);
        configs.push(Json::obj(vec![
            ("axis", Json::from(axis_name)),
            ("devices", Json::from(shards)),
            ("layout", Json::from(devices_label.as_str())),
            ("build_s", Json::from(build_s)),
            ("time_s", Json::from(median_t)),
            ("rows_per_s", band_json(&rps_samples)),
        ]));
        dump_record(
            "fig5",
            vec![
                ("axis", Json::from(axis_name)),
                ("devices", Json::from(shards)),
                ("layout", Json::from(devices_label.as_str())),
                ("build_s", Json::from(build_s)),
                ("time_s", Json::from(median_t)),
                ("rows_per_s", Json::from(median_rps)),
            ],
        );
    };

    // the 1-device rows-axis median anchors every section's scaling
    // column (the grid section has no 1-cell config of its own, and
    // normalizing it to itself would always print 1.00x)
    let mut single_base: Option<f64> = None;
    for axis in ShardAxis::ALL {
        let mut base: Option<f64> = single_base;
        let mut seen: Vec<usize> = Vec::new();
        for &devices in &device_counts {
            let cfg = BackendConfig { rows_hint: rows.max(1), ..Default::default() };
            let (sharded, build_s) = time_it(|| {
                ShardedBackend::build(&model, kind, &cfg, devices, axis)
                    .expect("sharded backend")
            });
            // the tree axis clamps shards to the tree count: don't
            // re-measure (and re-record) an identical configuration
            if seen.contains(&sharded.shards()) {
                continue;
            }
            seen.push(sharded.shards());
            measure(
                axis.name(),
                sharded.shards().to_string(),
                sharded.shards(),
                build_s,
                &sharded as &dyn ShapBackend,
                &mut table,
                &mut configs,
                &mut base,
            );
            if single_base.is_none() {
                single_base = base; // first measured config = 1 device
            }
        }
    }

    // grid configurations: for each device budget, the planner's best
    // genuinely 2-D factorization (skipped where none exists, e.g. 1–2
    // devices) — the nested-sharding topologies neither axis covers
    {
        let planner = Planner::for_model(&model).with_devices(max_devices);
        let mut base: Option<f64> = single_base;
        let mut seen: Vec<ShardGrid> = Vec::new();
        for &devices in &device_counts {
            let Some(plan) = planner.plan_pinned(kind, rows.max(1), ShardAxis::Grid, devices)
            else {
                continue;
            };
            let Some(g) = plan.grid else { continue };
            if seen.contains(&g) {
                continue;
            }
            seen.push(g);
            let cfg = BackendConfig { rows_hint: rows.max(1), ..Default::default() };
            let (grid_backend, build_s) = time_it(|| {
                GridBackend::build(&model, kind, &cfg, g).expect("grid backend")
            });
            measure(
                "grid",
                g.to_string(),
                g.total(),
                build_s,
                &grid_backend as &dyn ShapBackend,
                &mut table,
                &mut configs,
                &mut base,
            );
        }
    }

    table.print();
    println!(
        "\n(paper: near-linear row-axis scaling to 8 GPUs; flat here = shared cores, see EXPERIMENTS.md)"
    );

    // ── interactions throughput: the wide-model (M ≫ D) Φ regime ──────
    // The feature-tile axis splits the conditioned-feature loop, so its
    // win grows with M while row shards only split the batch. Small-size
    // ensembles keep this tractable in CI; rows are capped per width
    // because the output matrix is (M+1)² per row × group.
    let inter_axis = match args.get_or("shard-axis", "both") {
        "tiles" | "tile" => Some(ShardAxis::FeatureTiles),
        "rows" => Some(ShardAxis::Rows),
        "both" => None,
        other => panic!("unknown --shard-axis '{other}' (tiles|rows)"),
    };
    println!(
        "\nfig5 interactions: feature tiles vs row shards, {} device(s), M ∈ {{96, 256}}",
        max_devices
    );
    let mut inter_table =
        Table::new(&["M", "axis", "devices", "build(s)", "time(s)", "rows/s", "vs host-1"]);
    let mut inter_configs: Vec<Json> = Vec::new();
    let (mut tiles96_rps, mut host96_rps, mut rows96_rps) = (None, None, None);
    let (rounds, depth) = ZooSize::Small.rounds_depth();
    for &(cols, row_cap) in &[(96usize, 24usize), (256, 8)] {
        let spec = zoo::fashion_wide(cols, 0.005);
        let (wmodel, wdata) =
            zoo::build_custom(&format!("fig5_inter_m{cols}-small"), &spec, rounds, depth);
        let wm = wmodel.num_features;
        let irows = row_cap.min(rows_req).min(wdata.rows).max(1);
        let wx = &wdata.features[..irows * wm];
        let wmodel = Arc::new(wmodel);
        let cfg = BackendConfig {
            rows_hint: irows,
            with_interactions: true,
            ..Default::default()
        };

        let mut measure_inter = |axis_name: &str,
                                 devices: usize,
                                 build_s: f64,
                                 b: &dyn ShapBackend,
                                 host1: Option<f64>|
         -> f64 {
            let mut times = Vec::with_capacity(RUNS);
            for _ in 0..RUNS {
                let t = std::time::Instant::now();
                b.interactions(wx, irows).expect("interactions");
                times.push(t.elapsed().as_secs_f64());
            }
            times.sort_by(f64::total_cmp);
            let median_t = times[times.len() / 2];
            let rps_samples: Vec<f64> = times.iter().map(|t| irows as f64 / t).collect();
            let median_rps = irows as f64 / median_t;
            let speedup = host1.map(|h| median_rps / h);
            inter_table.row(vec![
                format!("m={cols}"),
                axis_name.into(),
                devices.to_string(),
                format!("{build_s:.3}"),
                format!("{median_t:.3}"),
                format!("{median_rps:.1}"),
                speedup.map_or("—".into(), |s| format!("{s:.2}x")),
            ]);
            inter_configs.push(Json::obj(vec![
                ("m", Json::from(cols)),
                ("axis", Json::from(axis_name)),
                ("devices", Json::from(devices)),
                ("rows", Json::from(irows)),
                ("build_s", Json::from(build_s)),
                ("time_s", Json::from(median_t)),
                ("rows_per_s", band_json(&rps_samples)),
                ("speedup_vs_host1", speedup.map(Json::from).unwrap_or(Json::Null)),
            ]));
            dump_record(
                "fig5-interactions",
                vec![
                    ("m", Json::from(cols)),
                    ("axis", Json::from(axis_name)),
                    ("devices", Json::from(devices)),
                    ("rows_per_s", Json::from(median_rps)),
                ],
            );
            median_rps
        };

        // the single-shard host kernel anchors every ratio at this width
        let (host1, build_s) = time_it(|| {
            backend::build(&wmodel, BackendKind::Host, &cfg).expect("host backend")
        });
        let host1_rps = measure_inter("host-1", 1, build_s, host1.as_ref(), None);
        if cols == 96 {
            host96_rps = Some(host1_rps);
        }
        if inter_axis != Some(ShardAxis::Rows) {
            let (tiled, build_s) = time_it(|| {
                TilesBackend::build(&wmodel, BackendKind::Host, &cfg, max_devices)
                    .expect("tiles backend")
            });
            let rps = measure_inter(
                ShardAxis::FeatureTiles.name(),
                tiled.shard_count(),
                build_s,
                &tiled,
                Some(host1_rps),
            );
            if cols == 96 {
                tiles96_rps = Some(rps);
            }
        }
        if inter_axis != Some(ShardAxis::FeatureTiles) && max_devices > 1 {
            let (rsharded, build_s) = time_it(|| {
                ShardedBackend::build(&wmodel, BackendKind::Host, &cfg, max_devices, ShardAxis::Rows)
                    .expect("row-sharded backend")
            });
            let rps = measure_inter(
                ShardAxis::Rows.name(),
                rsharded.shards(),
                build_s,
                &rsharded,
                Some(host1_rps),
            );
            if cols == 96 {
                rows96_rps = Some(rps);
            }
        }
    }
    inter_table.print();

    if let Some(path) = json_path {
        let mut steady = Vec::new();
        if let Some(v) = tiles96_rps {
            steady.push(("tiles", Json::from(v)));
        }
        if let Some(v) = host96_rps {
            steady.push(("host_single", Json::from(v)));
        }
        if let Some(v) = rows96_rps {
            steady.push(("rows_axis", Json::from(v)));
        }
        let tiles_speedup = match (tiles96_rps, host96_rps) {
            (Some(t), Some(h)) if h > 0.0 => Json::from(t / h),
            _ => Json::Null,
        };
        let report = Json::obj(vec![
            ("model", Json::from(entry.name.as_str())),
            ("backend", Json::from(kind.name())),
            ("rows", Json::from(rows)),
            ("runs", Json::from(RUNS)),
            ("configs", Json::Arr(configs)),
            ("best_rows_per_s", Json::from(best_rps)),
            ("interactions", Json::Arr(inter_configs)),
            // steady-state interactions throughput at M=96 (rows/s):
            // tiles vs the single-shard host kernel is the acceptance
            // ratio for the feature-tile axis
            ("steady_rows_per_s", Json::obj(steady)),
            ("tiles_speedup_m96", tiles_speedup),
        ]);
        write_json_report(&path, "fig5", report).expect("write --json report");
        println!("json report merged into {}", path.display());
    }
}
