//! Compile-only stub of the `xla` PJRT bindings (see Cargo.toml).
//!
//! Every constructor that would touch a device returns [`Error`], so a
//! binary built against this stub degrades exactly like a machine with
//! no PJRT plugin: `ShapEngine::new` fails, the XLA backends report
//! unavailable, and the CPU backends keep serving.

use std::fmt;
use std::path::Path;

/// The stub's only runtime behaviour: a descriptive error.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn stub(what: &str) -> Error {
        Error(format!("xla stub: {what} requires the real PJRT bindings"))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Parsed HLO module text (opaque).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error::stub("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module (opaque).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A PJRT client over one device.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::stub("PjRtClient::buffer_from_host_buffer"))
    }
}

/// A compiled executable loaded on a device.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute_b"))
    }
}

/// A device-resident buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("PjRtBuffer::to_literal_sync"))
    }
}

/// A host-side literal value.
pub struct Literal;

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::stub("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::stub("Literal::to_vec"))
    }
}
