//! Tiny CLI argument parser substrate (no `clap` offline).
//!
//! Syntax: `command [subcommand] [--key value | --key=value | --flag] [positional…]`
//!
//! [`opts`] holds the shared option-resolution layer (datasets, models,
//! backend/service config) every subcommand goes through.

pub mod opts;

use std::collections::BTreeMap;

use crate::anyhow;
use crate::util::error::Result;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    out.options.insert(rest.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Like [`Args::get_or`], but a valueless `--name` is an error
    /// instead of silently falling back to the default.
    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> Result<&'a str> {
        match self.get(name) {
            Some(v) => Ok(v),
            None => {
                self.check_valueless(name)?;
                Ok(default)
            }
        }
    }

    /// Errs when `--name` was given with no value (a trailing flag, or
    /// one directly followed by another `--option`): silently falling
    /// back to the default would hide the user's intent.
    fn check_valueless(&self, name: &str) -> Result<()> {
        if self.has_flag(name) {
            return Err(anyhow!("--{name} expects a value, but none was given"));
        }
        Ok(())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => {
                self.check_valueless(name)?;
                Ok(default)
            }
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => {
                self.check_valueless(name)?;
                Ok(default)
            }
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn key_value_styles() {
        // NB: a bare `--name` followed by a non-flag token is parsed as
        // `name=token` (no schema to disambiguate); boolean flags go
        // last or use `--name=value`.
        let a = parse("train ds1 --rounds 20 --depth=8 --verbose");
        assert_eq!(a.subcommand(), Some("train"));
        assert_eq!(a.get_usize("rounds", 0).unwrap(), 20);
        assert_eq!(a.get_usize("depth", 0).unwrap(), 8);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["train", "ds1"]);
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("x --n abc");
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(a.get_usize("n", 0).is_err());
        assert_eq!(a.get_or("alg", "bfd"), "bfd");
    }

    #[test]
    fn trailing_flag() {
        let a = parse("serve --quiet");
        assert!(a.has_flag("quiet"));
        assert_eq!(a.get("quiet"), None);
    }

    #[test]
    fn trailing_valueless_option_is_an_error_not_a_silent_default() {
        // regression: `shap --rows` used to fall through to the default
        // (256) as if the flag had not been typed at all
        for cmdline in ["shap --rows", "shap --rows --devices 2"] {
            let a = parse(cmdline);
            let err = a.get_usize("rows", 256).unwrap_err();
            assert!(
                format!("{err:#}").contains("--rows"),
                "{cmdline}: error must name the flag: {err:#}"
            );
        }
        let a = parse("train --lr");
        assert!(format!("{:#}", a.get_f64("lr", 0.01).unwrap_err()).contains("--lr"));
        // string options get the same treatment through get_str
        let a = parse("serve --backend");
        assert!(format!("{:#}", a.get_str("backend", "auto").unwrap_err()).contains("--backend"));
        assert_eq!(parse("serve").get_str("backend", "auto").unwrap(), "auto");
        assert_eq!(parse("serve --backend host").get_str("backend", "auto").unwrap(), "host");
        // boolean flags that no code queries as values are unaffected,
        // and absent options still default cleanly
        let a = parse("serve --quiet");
        assert_eq!(a.get_usize("rows", 256).unwrap(), 256);
    }
}
