//! Shared CLI option resolution: every subcommand (and the `client`
//! front end) resolves datasets, models, shard topology, backend and
//! service config through these helpers, so a flag like `--shard-axis`
//! or `--fastv2-max-mb` means exactly one thing everywhere and unknown
//! values fail with the same `name_list()`-backed error text.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use crate::backend::{self, BackendConfig, BackendKind, ShapBackend, ShardAxis};
use crate::cli::Args;
use crate::coordinator::{Class, ClassPolicy, ServiceConfig};
use crate::data::csv::{load_csv, CsvOptions};
use crate::data::{Dataset, SynthSpec};
use crate::gbdt::Model;
use crate::runtime::default_artifacts_dir;
use crate::shap::Packing;
use crate::util::error::{Error, Result};
use crate::{anyhow, bail};

/// Resolve `--dataset` (+ `--scale`, `--csv`, `--classes`).
pub fn load_dataset(args: &Args) -> Result<Dataset> {
    let scale = args.get_f64("scale", 0.01)?;
    match args.get_str("dataset", "cal_housing")? {
        "covtype" => Ok(SynthSpec::covtype(scale).generate()),
        "cal_housing" => Ok(SynthSpec::cal_housing(scale).generate()),
        "fashion_mnist" => Ok(SynthSpec::fashion_mnist(scale).generate()),
        "adult" => Ok(SynthSpec::adult(scale).generate()),
        "csv" => {
            let path = args.get("csv").ok_or_else(|| anyhow!("--csv <path> required"))?;
            let opts = CsvOptions {
                num_classes: args.get_usize("classes", 0)?,
                ..Default::default()
            };
            load_csv(Path::new(path), &opts)
        }
        other => bail!("unknown dataset '{other}'"),
    }
}

/// Load a model artifact by path: `.json` routes through the XGBoost
/// importer (the paper's integration target), everything else through
/// the native format.
pub fn load_model_path(path: &Path) -> Result<Model> {
    if path.extension().is_some_and(|e| e == "json") {
        crate::gbdt::xgb_import::load_xgboost_json(path)
    } else {
        crate::gbdt::io::load(path)
    }
}

/// Resolve `--model <path>` into a loaded model.
pub fn load_model(args: &Args) -> Result<Model> {
    let path = args.get("model").ok_or_else(|| anyhow!("--model <path> required"))?;
    load_model_path(Path::new(path))
}

pub fn artifacts_dir(args: &Args) -> PathBuf {
    args.get("artifacts").map(PathBuf::from).unwrap_or_else(default_artifacts_dir)
}

/// Resolve `--shard-axis` (`auto` → `None`, letting the planner pick).
pub fn shard_axis(args: &Args) -> Result<Option<ShardAxis>> {
    match args.get_str("shard-axis", "auto")? {
        "auto" => Ok(None),
        s => ShardAxis::parse(s)
            .map(Some)
            .ok_or_else(|| anyhow!("unknown shard axis '{s}' (auto|{})", ShardAxis::name_list())),
    }
}

/// Assemble the backend config every explain-path command shares.
pub fn backend_config(args: &Args, rows_hint: usize) -> Result<BackendConfig> {
    let packing = args.get_str("packing", "bfd")?;
    Ok(BackendConfig {
        threads: args.get_usize("threads", crate::parallel::default_threads())?,
        packing: Packing::parse(packing)
            .ok_or_else(|| anyhow!("unknown packing '{packing}' (none|nf|ffd|bfd)"))?,
        artifacts_dir: artifacts_dir(args),
        rows_hint,
        with_interactions: false,
        with_predict: false,
        devices: args.get_usize("devices", 1)?.max(1),
        shard_axis: shard_axis(args)?,
        fastv2_max_mb: args.get_usize("fastv2-max-mb", backend::DEFAULT_FASTV2_MAX_MB)?,
    })
}

/// The error for an unrecognized `--backend` value: names every valid
/// kind (parse is case-insensitive, so any casing of these works).
pub fn unknown_backend(s: &str) -> Error {
    anyhow!("unknown backend '{s}' (auto|{})", BackendKind::name_list())
}

/// Resolve `--backend` (`auto` → `None`, pinning otherwise) without
/// building anything — the registry/serve path wants the kind, not an
/// instance.
pub fn backend_kind(args: &Args, default: &str) -> Result<Option<BackendKind>> {
    match args.get_str("backend", default)? {
        "auto" => Ok(None),
        s => BackendKind::parse(s).map(Some).ok_or_else(|| unknown_backend(s)),
    }
}

/// Resolve `--backend` (with a per-command default) into a built
/// backend plus a printable label.
pub fn build_backend(
    model: &Arc<Model>,
    args: &Args,
    cfg: &BackendConfig,
    default: &str,
) -> Result<(String, Box<dyn ShapBackend>)> {
    match args.get_str("backend", default)? {
        "auto" => {
            let (plan, b) = backend::build_auto(model, cfg)?;
            let layout = if let Some(g) = plan.grid {
                format!(", {g}-grid")
            } else if plan.shards > 1 {
                format!(", {}×{}-sharded", plan.shards, plan.axis.name())
            } else {
                String::new()
            };
            Ok((
                format!(
                    "auto→{}{} (planner est {:.1} ms)",
                    plan.kind.name(),
                    layout,
                    plan.est_latency_s * 1e3
                ),
                b,
            ))
        }
        s => {
            let kind = BackendKind::parse(s).ok_or_else(|| unknown_backend(s))?;
            Ok((kind.name().to_string(), backend::build(model, kind, cfg)?))
        }
    }
}

/// Resolve `--calibration`: calibrated cost constants persist next to
/// the model artifact by default (`<model>.calib.json`), so a restarted
/// service plans from measurements immediately; `none` disables, an
/// explicit path overrides.
pub fn calibration_path(args: &Args) -> Result<Option<PathBuf>> {
    Ok(match args.get_str("calibration", "")? {
        "none" => None,
        "" => args.get("model").map(|mp| PathBuf::from(format!("{mp}.calib.json"))),
        explicit => Some(PathBuf::from(explicit)),
    })
}

/// Resolve `--class-target interactive=50,batch=2000` (milliseconds per
/// class; unnamed classes keep their [`ClassPolicy::defaults`] targets).
pub fn class_targets(args: &Args) -> Result<[Duration; Class::COUNT]> {
    let defaults = ClassPolicy::defaults();
    let mut targets = [defaults[0].target, defaults[1].target];
    let Some(spec) = args.get("class-target") else {
        return Ok(targets);
    };
    for pair in spec.split(',').filter(|s| !s.is_empty()) {
        let (name, ms) = pair.split_once('=').ok_or_else(|| {
            anyhow!("bad --class-target entry '{pair}' (want class=milliseconds)")
        })?;
        let class = Class::parse(name).ok_or_else(|| {
            anyhow!("unknown class '{name}' in --class-target (one of: {})", Class::name_list())
        })?;
        let ms: u64 = ms
            .parse()
            .map_err(|_| anyhow!("bad --class-target milliseconds '{ms}' for '{name}'"))?;
        targets[class.index()] = Duration::from_millis(ms);
    }
    Ok(targets)
}

/// Resolve `--priority` / `--deadline-ms` into the scheduling fields a
/// client-side request carries.
pub fn request_class(args: &Args) -> Result<(Class, Option<u64>)> {
    let class = match args.get("priority") {
        Some(s) => Class::parse(s).ok_or_else(|| {
            anyhow!("unknown priority '{s}' (one of: {})", Class::name_list())
        })?,
        None => Class::default(),
    };
    let deadline = match args.get("deadline-ms") {
        Some(s) => Some(
            s.parse::<u64>().map_err(|_| anyhow!("bad --deadline-ms '{s}' (want integer)"))?,
        ),
        None => None,
    };
    Ok((class, deadline))
}

/// Assemble the service config the serve paths share (`--devices`,
/// `--shard-axis`, `--max-batch`, `--max-wait-ms`,
/// `--recalibrate-every`, `--calibration`, `--class-target`).
pub fn service_config(args: &Args) -> Result<ServiceConfig> {
    Ok(ServiceConfig {
        devices: args.get_usize("devices", 1)?,
        shard_axis: shard_axis(args)?,
        max_batch_rows: args.get_usize("max-batch", 256)?,
        max_wait: Duration::from_millis(args.get_usize("max-wait-ms", 5)? as u64),
        // measure→calibrate→plan cadence in executed batches (0 = static)
        recalibrate_every: args.get_usize("recalibrate-every", 64)?,
        calibration_path: calibration_path(args)?,
        class_targets: class_targets(args)?,
        ..Default::default()
    })
}

/// Parse a `name=path[;weight=W][,…]` model manifest (`serve --models`):
/// `weight` sets the entry's fairness share of the device pool under
/// cross-model interactive pressure (default 1.0).
pub fn parse_model_manifest(spec: &str) -> Result<Vec<(String, PathBuf, f64)>> {
    spec.split(',')
        .filter(|s| !s.is_empty())
        .map(|entry| {
            let mut parts = entry.split(';');
            let pair = parts.next().unwrap_or("");
            let (name, path) = pair.split_once('=').ok_or_else(|| {
                anyhow!("bad --models entry '{pair}' (want name=path[;weight=W])")
            })?;
            let mut weight = 1.0f64;
            for opt in parts {
                let (key, value) = opt.split_once('=').ok_or_else(|| {
                    anyhow!("bad --models option '{opt}' for '{name}' (want weight=W)")
                })?;
                match key {
                    "weight" => {
                        weight = value.parse().map_err(|_| {
                            anyhow!("bad --models weight '{value}' for '{name}'")
                        })?;
                        if !weight.is_finite() || weight <= 0.0 {
                            bail!("--models weight for '{name}' must be positive, got {value}");
                        }
                    }
                    other => bail!(
                        "unknown --models option '{other}' for '{name}' (known: weight)"
                    ),
                }
            }
            Ok((name.to_string(), PathBuf::from(path), weight))
        })
        .collect()
}

/// The registry name for a model loaded via `--model <path>`: an
/// explicit `--name` wins, else the artifact's file stem.
pub fn model_name(args: &Args, path: &Path) -> Result<String> {
    if let Some(name) = args.get("name") {
        return Ok(name.to_string());
    }
    path.file_stem()
        .and_then(|s| s.to_str())
        .map(str::to_string)
        .ok_or_else(|| anyhow!("cannot derive a model name from '{}'; pass --name", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn calibration_modes() {
        let a = parse("serve --model m.gtsm");
        assert_eq!(
            calibration_path(&a).unwrap(),
            Some(PathBuf::from("m.gtsm.calib.json"))
        );
        let a = parse("serve --model m.gtsm --calibration none");
        assert_eq!(calibration_path(&a).unwrap(), None);
        let a = parse("serve --model m.gtsm --calibration /tmp/c.json");
        assert_eq!(calibration_path(&a).unwrap(), Some(PathBuf::from("/tmp/c.json")));
        // no --model and no explicit path: nowhere to persist
        assert_eq!(calibration_path(&parse("serve")).unwrap(), None);
    }

    #[test]
    fn model_manifest() {
        let got = parse_model_manifest("m1=a/b.gtsm,m2=c.json").unwrap();
        assert_eq!(
            got,
            vec![
                ("m1".to_string(), PathBuf::from("a/b.gtsm"), 1.0),
                ("m2".to_string(), PathBuf::from("c.json"), 1.0),
            ]
        );
        assert!(parse_model_manifest("nopath").is_err());
        assert_eq!(parse_model_manifest("").unwrap(), vec![]);
    }

    #[test]
    fn model_manifest_weights() {
        let got = parse_model_manifest("bulk=a.gtsm;weight=1,chat=b.gtsm;weight=4.5").unwrap();
        assert_eq!(
            got,
            vec![
                ("bulk".to_string(), PathBuf::from("a.gtsm"), 1.0),
                ("chat".to_string(), PathBuf::from("b.gtsm"), 4.5),
            ]
        );
        let err = format!("{:#}", parse_model_manifest("m=a.gtsm;weight=-1").unwrap_err());
        assert!(err.contains("positive"), "{err}");
        let err = format!("{:#}", parse_model_manifest("m=a.gtsm;wieght=2").unwrap_err());
        assert!(err.contains("unknown --models option 'wieght'"), "{err}");
        assert!(err.contains("weight"), "error names the fix: {err}");
    }

    #[test]
    fn class_targets_parse_and_default() {
        let defaults = ClassPolicy::defaults();
        let t = class_targets(&parse("serve")).unwrap();
        assert_eq!(t[Class::Interactive.index()], defaults[Class::Interactive.index()].target);
        assert_eq!(t[Class::Batch.index()], defaults[Class::Batch.index()].target);
        // one named class overrides only itself
        let t = class_targets(&parse("serve --class-target interactive=40")).unwrap();
        assert_eq!(t[Class::Interactive.index()], Duration::from_millis(40));
        assert_eq!(t[Class::Batch.index()], defaults[Class::Batch.index()].target);
        let t = class_targets(&parse("serve --class-target interactive=40,batch=3000")).unwrap();
        assert_eq!(t[Class::Batch.index()], Duration::from_millis(3000));
        let err =
            format!("{:#}", class_targets(&parse("serve --class-target vip=1")).unwrap_err());
        assert!(err.contains("unknown class 'vip'"), "{err}");
        assert!(class_targets(&parse("serve --class-target interactive=abc")).is_err());
    }

    #[test]
    fn request_class_flags() {
        assert_eq!(request_class(&parse("client")).unwrap(), (Class::Batch, None));
        assert_eq!(
            request_class(&parse("client --priority interactive --deadline-ms 40")).unwrap(),
            (Class::Interactive, Some(40))
        );
        assert!(request_class(&parse("client --priority vip")).is_err());
        assert!(request_class(&parse("client --deadline-ms soon")).is_err());
    }

    #[test]
    fn names_default_to_file_stem() {
        let a = parse("serve --model artifacts/houses.gtsm");
        assert_eq!(model_name(&a, Path::new("artifacts/houses.gtsm")).unwrap(), "houses");
        let a = parse("serve --model artifacts/houses.gtsm --name prod");
        assert_eq!(model_name(&a, Path::new("artifacts/houses.gtsm")).unwrap(), "prod");
    }

    #[test]
    fn backend_kind_auto_vs_pinned() {
        assert_eq!(backend_kind(&parse("serve"), "auto").unwrap(), None);
        assert_eq!(
            backend_kind(&parse("serve --backend cpu"), "auto").unwrap(),
            Some(BackendKind::Recursive)
        );
        assert!(backend_kind(&parse("serve --backend nope"), "auto").is_err());
    }
}
