//! Shared CLI option resolution: every subcommand (and the `client`
//! front end) resolves datasets, models, shard topology, backend and
//! service config through these helpers, so a flag like `--shard-axis`
//! or `--fastv2-max-mb` means exactly one thing everywhere and unknown
//! values fail with the same `name_list()`-backed error text.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use crate::backend::{self, BackendConfig, BackendKind, ShapBackend, ShardAxis};
use crate::cli::Args;
use crate::coordinator::ServiceConfig;
use crate::data::csv::{load_csv, CsvOptions};
use crate::data::{Dataset, SynthSpec};
use crate::gbdt::Model;
use crate::runtime::default_artifacts_dir;
use crate::shap::Packing;
use crate::util::error::{Error, Result};
use crate::{anyhow, bail};

/// Resolve `--dataset` (+ `--scale`, `--csv`, `--classes`).
pub fn load_dataset(args: &Args) -> Result<Dataset> {
    let scale = args.get_f64("scale", 0.01)?;
    match args.get_str("dataset", "cal_housing")? {
        "covtype" => Ok(SynthSpec::covtype(scale).generate()),
        "cal_housing" => Ok(SynthSpec::cal_housing(scale).generate()),
        "fashion_mnist" => Ok(SynthSpec::fashion_mnist(scale).generate()),
        "adult" => Ok(SynthSpec::adult(scale).generate()),
        "csv" => {
            let path = args.get("csv").ok_or_else(|| anyhow!("--csv <path> required"))?;
            let opts = CsvOptions {
                num_classes: args.get_usize("classes", 0)?,
                ..Default::default()
            };
            load_csv(Path::new(path), &opts)
        }
        other => bail!("unknown dataset '{other}'"),
    }
}

/// Load a model artifact by path: `.json` routes through the XGBoost
/// importer (the paper's integration target), everything else through
/// the native format.
pub fn load_model_path(path: &Path) -> Result<Model> {
    if path.extension().is_some_and(|e| e == "json") {
        crate::gbdt::xgb_import::load_xgboost_json(path)
    } else {
        crate::gbdt::io::load(path)
    }
}

/// Resolve `--model <path>` into a loaded model.
pub fn load_model(args: &Args) -> Result<Model> {
    let path = args.get("model").ok_or_else(|| anyhow!("--model <path> required"))?;
    load_model_path(Path::new(path))
}

pub fn artifacts_dir(args: &Args) -> PathBuf {
    args.get("artifacts").map(PathBuf::from).unwrap_or_else(default_artifacts_dir)
}

/// Resolve `--shard-axis` (`auto` → `None`, letting the planner pick).
pub fn shard_axis(args: &Args) -> Result<Option<ShardAxis>> {
    match args.get_str("shard-axis", "auto")? {
        "auto" => Ok(None),
        s => ShardAxis::parse(s)
            .map(Some)
            .ok_or_else(|| anyhow!("unknown shard axis '{s}' (auto|{})", ShardAxis::name_list())),
    }
}

/// Assemble the backend config every explain-path command shares.
pub fn backend_config(args: &Args, rows_hint: usize) -> Result<BackendConfig> {
    let packing = args.get_str("packing", "bfd")?;
    Ok(BackendConfig {
        threads: args.get_usize("threads", crate::parallel::default_threads())?,
        packing: Packing::parse(packing)
            .ok_or_else(|| anyhow!("unknown packing '{packing}' (none|nf|ffd|bfd)"))?,
        artifacts_dir: artifacts_dir(args),
        rows_hint,
        with_interactions: false,
        with_predict: false,
        devices: args.get_usize("devices", 1)?.max(1),
        shard_axis: shard_axis(args)?,
        fastv2_max_mb: args.get_usize("fastv2-max-mb", backend::DEFAULT_FASTV2_MAX_MB)?,
    })
}

/// The error for an unrecognized `--backend` value: names every valid
/// kind (parse is case-insensitive, so any casing of these works).
pub fn unknown_backend(s: &str) -> Error {
    anyhow!("unknown backend '{s}' (auto|{})", BackendKind::name_list())
}

/// Resolve `--backend` (`auto` → `None`, pinning otherwise) without
/// building anything — the registry/serve path wants the kind, not an
/// instance.
pub fn backend_kind(args: &Args, default: &str) -> Result<Option<BackendKind>> {
    match args.get_str("backend", default)? {
        "auto" => Ok(None),
        s => BackendKind::parse(s).map(Some).ok_or_else(|| unknown_backend(s)),
    }
}

/// Resolve `--backend` (with a per-command default) into a built
/// backend plus a printable label.
pub fn build_backend(
    model: &Arc<Model>,
    args: &Args,
    cfg: &BackendConfig,
    default: &str,
) -> Result<(String, Box<dyn ShapBackend>)> {
    match args.get_str("backend", default)? {
        "auto" => {
            let (plan, b) = backend::build_auto(model, cfg)?;
            let layout = if let Some(g) = plan.grid {
                format!(", {g}-grid")
            } else if plan.shards > 1 {
                format!(", {}×{}-sharded", plan.shards, plan.axis.name())
            } else {
                String::new()
            };
            Ok((
                format!(
                    "auto→{}{} (planner est {:.1} ms)",
                    plan.kind.name(),
                    layout,
                    plan.est_latency_s * 1e3
                ),
                b,
            ))
        }
        s => {
            let kind = BackendKind::parse(s).ok_or_else(|| unknown_backend(s))?;
            Ok((kind.name().to_string(), backend::build(model, kind, cfg)?))
        }
    }
}

/// Resolve `--calibration`: calibrated cost constants persist next to
/// the model artifact by default (`<model>.calib.json`), so a restarted
/// service plans from measurements immediately; `none` disables, an
/// explicit path overrides.
pub fn calibration_path(args: &Args) -> Result<Option<PathBuf>> {
    Ok(match args.get_str("calibration", "")? {
        "none" => None,
        "" => args.get("model").map(|mp| PathBuf::from(format!("{mp}.calib.json"))),
        explicit => Some(PathBuf::from(explicit)),
    })
}

/// Assemble the service config the serve paths share (`--devices`,
/// `--shard-axis`, `--max-batch`, `--max-wait-ms`,
/// `--recalibrate-every`, `--calibration`).
pub fn service_config(args: &Args) -> Result<ServiceConfig> {
    Ok(ServiceConfig {
        devices: args.get_usize("devices", 1)?,
        shard_axis: shard_axis(args)?,
        max_batch_rows: args.get_usize("max-batch", 256)?,
        max_wait: Duration::from_millis(args.get_usize("max-wait-ms", 5)? as u64),
        // measure→calibrate→plan cadence in executed batches (0 = static)
        recalibrate_every: args.get_usize("recalibrate-every", 64)?,
        calibration_path: calibration_path(args)?,
        ..Default::default()
    })
}

/// Parse a `name=path[,name=path…]` model manifest (`serve --models`).
pub fn parse_model_manifest(spec: &str) -> Result<Vec<(String, PathBuf)>> {
    spec.split(',')
        .filter(|s| !s.is_empty())
        .map(|pair| {
            let (name, path) = pair
                .split_once('=')
                .ok_or_else(|| anyhow!("bad --models entry '{pair}' (want name=path)"))?;
            Ok((name.to_string(), PathBuf::from(path)))
        })
        .collect()
}

/// The registry name for a model loaded via `--model <path>`: an
/// explicit `--name` wins, else the artifact's file stem.
pub fn model_name(args: &Args, path: &Path) -> Result<String> {
    if let Some(name) = args.get("name") {
        return Ok(name.to_string());
    }
    path.file_stem()
        .and_then(|s| s.to_str())
        .map(str::to_string)
        .ok_or_else(|| anyhow!("cannot derive a model name from '{}'; pass --name", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn calibration_modes() {
        let a = parse("serve --model m.gtsm");
        assert_eq!(
            calibration_path(&a).unwrap(),
            Some(PathBuf::from("m.gtsm.calib.json"))
        );
        let a = parse("serve --model m.gtsm --calibration none");
        assert_eq!(calibration_path(&a).unwrap(), None);
        let a = parse("serve --model m.gtsm --calibration /tmp/c.json");
        assert_eq!(calibration_path(&a).unwrap(), Some(PathBuf::from("/tmp/c.json")));
        // no --model and no explicit path: nowhere to persist
        assert_eq!(calibration_path(&parse("serve")).unwrap(), None);
    }

    #[test]
    fn model_manifest() {
        let got = parse_model_manifest("m1=a/b.gtsm,m2=c.json").unwrap();
        assert_eq!(
            got,
            vec![
                ("m1".to_string(), PathBuf::from("a/b.gtsm")),
                ("m2".to_string(), PathBuf::from("c.json")),
            ]
        );
        assert!(parse_model_manifest("nopath").is_err());
        assert_eq!(parse_model_manifest("").unwrap(), vec![]);
    }

    #[test]
    fn names_default_to_file_stem() {
        let a = parse("serve --model artifacts/houses.gtsm");
        assert_eq!(model_name(&a, Path::new("artifacts/houses.gtsm")).unwrap(), "houses");
        let a = parse("serve --model artifacts/houses.gtsm --name prod");
        assert_eq!(model_name(&a, Path::new("artifacts/houses.gtsm")).unwrap(), "prod");
    }

    #[test]
    fn backend_kind_auto_vs_pinned() {
        assert_eq!(backend_kind(&parse("serve"), "auto").unwrap(), None);
        assert_eq!(
            backend_kind(&parse("serve --backend cpu"), "auto").unwrap(),
            Some(BackendKind::Recursive)
        );
        assert!(backend_kind(&parse("serve --backend nope"), "auto").is_err());
    }
}
