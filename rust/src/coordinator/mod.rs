//! L3 serving coordinator: dynamic batching, device workers,
//! backpressure, metrics — SHAP explanations as a service with python
//! nowhere on the request path.

pub mod batcher;
pub mod metrics;
pub mod service;

pub use batcher::Batcher;
pub use metrics::Metrics;
pub use service::{ModelRep, ServiceConfig, ShapService};
