//! L3 serving coordinator: dynamic batching, backend workers,
//! backpressure, metrics — SHAP explanations as a service with python
//! nowhere on the request path. Workers execute through the
//! `backend::ShapBackend` trait, so any registered backend (recursive,
//! host packed DP, XLA warp/padded) can serve.

pub mod batcher;
pub mod metrics;
pub mod service;

pub use batcher::Batcher;
pub use metrics::{BackendCounters, Metrics};
pub use service::{BackendFactory, ServiceConfig, ShapService, Task};
