//! L3 serving coordinator: dynamic batching, a sharding-aware executor,
//! backpressure, metrics — SHAP explanations as a service with python
//! nowhere on the request path. The executor dispatches through the
//! `backend::ShapBackend` trait, so any registered backend (recursive,
//! host packed DP, XLA warp/padded) can serve, and with `devices > 1`
//! each batch fans out across every device shard of one
//! `ShardedBackend` (per-shard rows/p50/p99 land in `Metrics`).

pub mod batcher;
pub mod metrics;
pub mod service;

pub use batcher::Batcher;
pub use metrics::{BackendCounters, Metrics};
pub use service::{BackendFactory, ServiceConfig, ShapService, Task};
