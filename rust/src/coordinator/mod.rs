//! L3 serving coordinator: dynamic batching, a sharding-aware executor,
//! backpressure, metrics — SHAP explanations as a service with python
//! nowhere on the request path. The executor dispatches through the
//! `backend::ShapBackend` trait, so any registered backend (recursive,
//! host packed DP, XLA warp/padded) can serve, and with `devices > 1`
//! each batch fans out across every device shard of one
//! `ShardedBackend` (per-shard rows/p50/p99 land in `Metrics`).
//!
//! On top of the single-model service sits the [`registry`]: named,
//! hot-swappable serving targets (`load`/`unload`/`alias`/`deploy`)
//! sharing one device pool and the process-wide prepared-model cache —
//! the routing layer the network ingress speaks to.

pub mod batcher;
pub mod metrics;
pub mod registry;
pub mod service;

pub use batcher::{Batcher, Class, ClassPolicy, CostLine, PoolPressure, PoolShare};
pub use metrics::{BackendCounters, Metrics};
pub use registry::{DeployOutcome, ModelEntry, ModelRegistry, RegistryConfig};
pub use service::{BackendFactory, Request, Response, ServiceConfig, ShapService, Task};
