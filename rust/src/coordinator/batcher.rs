//! Dynamic batching policy: coalesce queued explain requests into device
//! batches that fill the artifact's row bucket (throughput) without
//! letting small requests wait longer than `max_wait` (latency) — the
//! trade-off Fig 4 of the paper quantifies.

use std::time::{Duration, Instant};

/// A request's rows as admitted to the batcher.
#[derive(Debug)]
pub struct PendingRequest<T> {
    pub rows: usize,
    pub payload: T,
    pub arrived: Instant,
}

/// Accumulates requests; `take_batch` drains a prefix obeying the policy.
pub struct Batcher<T> {
    queue: std::collections::VecDeque<PendingRequest<T>>,
    pub max_batch_rows: usize,
    pub max_wait: Duration,
    queued_rows: usize,
}

impl<T> Batcher<T> {
    pub fn new(max_batch_rows: usize, max_wait: Duration) -> Self {
        Batcher {
            queue: Default::default(),
            max_batch_rows,
            max_wait,
            queued_rows: 0,
        }
    }

    pub fn push(&mut self, rows: usize, payload: T) {
        self.queued_rows += rows;
        self.queue.push_back(PendingRequest { rows, payload, arrived: Instant::now() });
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn queued_rows(&self) -> usize {
        self.queued_rows
    }

    /// Should we flush now? Either the bucket is full or the oldest
    /// request has waited long enough.
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.is_empty() {
            return false;
        }
        self.queued_rows >= self.max_batch_rows
            || now.duration_since(self.queue[0].arrived) >= self.max_wait
    }

    /// Drain requests up to `max_batch_rows` (always at least one).
    ///
    /// Fairness guarantee: requests leave in strict FIFO arrival order —
    /// this drains a *prefix* of the queue, never skips around it. A
    /// request at the head that is larger than `max_batch_rows` is
    /// admitted alone rather than held (no starvation of oversized
    /// requests), and later small requests can never overtake an
    /// earlier large one, so per-request queueing delay is bounded by
    /// the work admitted ahead of it plus `max_wait`.
    pub fn take_batch(&mut self) -> Vec<PendingRequest<T>> {
        let mut out = Vec::new();
        let mut rows = 0;
        while let Some(front) = self.queue.front() {
            if !out.is_empty() && rows + front.rows > self.max_batch_rows {
                break;
            }
            let req = self.queue.pop_front().unwrap();
            rows += req.rows;
            self.queued_rows -= req.rows;
            out.push(req);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flushes_when_full() {
        let mut b: Batcher<u32> = Batcher::new(100, Duration::from_secs(10));
        b.push(60, 1);
        assert!(!b.ready(Instant::now()));
        b.push(50, 2);
        assert!(b.ready(Instant::now()));
        let batch = b.take_batch();
        // second request would exceed the bucket -> batch is just the first
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].rows, 60);
        assert_eq!(b.queued_rows(), 50);
    }

    #[test]
    fn flushes_on_timeout() {
        let mut b: Batcher<u32> = Batcher::new(1000, Duration::from_millis(1));
        b.push(3, 1);
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.ready(Instant::now()));
        assert_eq!(b.take_batch().len(), 1);
        assert!(b.is_empty());
    }

    #[test]
    fn oversized_request_still_dispatches() {
        let mut b: Batcher<u32> = Batcher::new(10, Duration::from_secs(1));
        b.push(25, 1);
        assert!(b.ready(Instant::now()));
        let batch = b.take_batch();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].rows, 25);
    }

    #[test]
    fn oversized_first_request_is_admitted_alone_in_fifo_order() {
        // a request larger than the bucket, with smaller ones queued
        // behind it: it must dispatch alone, immediately, and the
        // followers must keep their arrival order in the next batch
        let mut b: Batcher<u32> = Batcher::new(10, Duration::from_secs(1));
        b.push(25, 1);
        b.push(2, 2);
        b.push(3, 3);
        assert!(b.ready(Instant::now()), "full bucket must flush without waiting");
        let first = b.take_batch();
        assert_eq!(first.len(), 1, "oversized head dispatches alone");
        assert_eq!((first[0].rows, first[0].payload), (25, 1));
        assert_eq!(b.queued_rows(), 5);
        let second = b.take_batch();
        let payloads: Vec<u32> = second.iter().map(|p| p.payload).collect();
        assert_eq!(payloads, vec![2, 3], "followers coalesce in FIFO order");
        assert!(b.is_empty());
    }

    #[test]
    fn exact_max_wait_boundary_is_inclusive() {
        let max_wait = Duration::from_millis(50);
        let mut b: Batcher<u32> = Batcher::new(1000, max_wait);
        b.push(1, 9);
        let arrived = b.queue[0].arrived;
        assert!(!b.ready(arrived), "fresh request must not flush");
        assert!(
            !b.ready(arrived + max_wait - Duration::from_nanos(1)),
            "just under the deadline must keep waiting"
        );
        assert!(b.ready(arrived + max_wait), "exactly max_wait must flush (>=)");
        assert!(b.ready(arrived + max_wait + Duration::from_millis(1)));
    }

    #[test]
    fn batches_coalesce_small_requests() {
        let mut b: Batcher<u32> = Batcher::new(100, Duration::from_secs(1));
        for i in 0..10 {
            b.push(10, i);
        }
        let batch = b.take_batch();
        assert_eq!(batch.len(), 10);
        assert!(b.is_empty());
        assert_eq!(b.queued_rows(), 0);
    }
}
