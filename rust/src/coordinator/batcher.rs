//! Dynamic batching policy with SLO-aware priority classes: coalesce
//! queued explain requests into device batches that fill the artifact's
//! row bucket (throughput) without letting small requests wait longer
//! than `max_wait` (latency) — the trade-off Fig 4 of the paper
//! quantifies — and schedule across two priority [`Class`]es on top:
//!
//! - **interactive** requests lead batch formation and carry a tight
//!   latency target; **batch** (bulk) work fills the remaining bucket
//!   capacity behind them,
//! - a weighted deficit counter per class accumulates the bulk class's
//!   unserved row entitlement while interactive leads, so bulk work is
//!   delayed boundedly, never starved,
//! - the executor's calibrated [`CostLine`] lets `ready` *predict* a
//!   batch's completion time, closing a batch early when the oldest
//!   request could no longer meet its class target (or its own
//!   `deadline`) by waiting for more coalescing,
//! - strict FIFO order is preserved within each class (queues drain as
//!   prefixes, never reordered),
//! - cross-model fairness: a [`PoolShare`] caps how much of the bucket
//!   bulk work may fill while another model on the same device pool has
//!   interactive work queued ([`PoolPressure`]).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Priority class of a request: `Interactive` requests lead batch
/// formation under a tight latency target; `Batch` (the default) is
/// bulk work that fills remaining capacity behind them.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum Class {
    Interactive,
    #[default]
    Batch,
}

impl Class {
    pub const ALL: [Class; 2] = [Class::Interactive, Class::Batch];
    pub const COUNT: usize = 2;

    pub fn index(self) -> usize {
        match self {
            Class::Interactive => 0,
            Class::Batch => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Class::Interactive => "interactive",
            Class::Batch => "batch",
        }
    }

    /// Parse a class name (case-insensitive); `None` for unknown names.
    pub fn parse(s: &str) -> Option<Class> {
        match s.to_ascii_lowercase().as_str() {
            "interactive" => Some(Class::Interactive),
            "batch" => Some(Class::Batch),
            _ => None,
        }
    }

    /// The valid class names, `|`-joined for error messages.
    pub fn name_list() -> String {
        Class::ALL.iter().map(|c| c.name()).collect::<Vec<_>>().join("|")
    }
}

/// The calibrated `latency ≈ overhead + rows/throughput` line of the
/// executor's current backend, published by the executor thread so the
/// batcher can predict a batch's completion time at enqueue time
/// instead of only measuring it retrospectively.
#[derive(Clone, Copy, Debug)]
pub struct CostLine {
    pub batch_overhead_s: f64,
    pub rows_per_s: f64,
}

impl CostLine {
    /// Predicted execution latency of a `rows`-row batch, seconds.
    pub fn predict_s(&self, rows: usize) -> f64 {
        self.batch_overhead_s + rows as f64 / self.rows_per_s.max(1e-9)
    }
}

/// Per-class scheduling policy: the latency target (SLO) responses are
/// judged against and the deficit-round-robin weight (the class's share
/// of bucket capacity under contention).
#[derive(Clone, Copy, Debug)]
pub struct ClassPolicy {
    pub target: Duration,
    pub weight: f64,
}

impl ClassPolicy {
    /// Default policies: interactive targets 50 ms at 4× the bulk
    /// class's capacity share; bulk targets 1 s.
    pub fn defaults() -> [ClassPolicy; Class::COUNT] {
        [
            ClassPolicy { target: Duration::from_millis(50), weight: 4.0 },
            ClassPolicy { target: Duration::from_secs(1), weight: 1.0 },
        ]
    }
}

/// Cross-model fairness gauge shared by every service on one device
/// pool: how many interactive requests are queued pool-wide and the
/// total share weight of running services. Services forming bulk-led
/// batches consult it through their [`PoolShare`].
#[derive(Debug, Default)]
pub struct PoolPressure {
    /// interactive requests currently queued across all services
    interactive: AtomicU64,
    /// sum of running services' share weights, stored in thousandths so
    /// an atomic suffices
    weight_milli: AtomicU64,
}

impl PoolPressure {
    pub fn new() -> Arc<PoolPressure> {
        Arc::new(PoolPressure::default())
    }

    pub fn add_interactive(&self, n: u64) {
        self.interactive.fetch_add(n, Ordering::Relaxed);
    }

    pub fn sub_interactive(&self, n: u64) {
        let _ = self.interactive.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            Some(v.saturating_sub(n))
        });
    }

    pub fn queued_interactive(&self) -> u64 {
        self.interactive.load(Ordering::Relaxed)
    }

    pub fn add_weight(&self, w: f64) {
        self.weight_milli.fetch_add((w.max(0.0) * 1e3) as u64, Ordering::Relaxed);
    }

    pub fn remove_weight(&self, w: f64) {
        let milli = (w.max(0.0) * 1e3) as u64;
        let _ = self.weight_milli.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            Some(v.saturating_sub(milli))
        });
    }

    pub fn total_weight(&self) -> f64 {
        self.weight_milli.load(Ordering::Relaxed) as f64 / 1e3
    }
}

/// One service's stake in the pool-wide fairness gauge: the shared
/// [`PoolPressure`] plus this model's share weight.
#[derive(Clone, Debug)]
pub struct PoolShare {
    pub pressure: Arc<PoolPressure>,
    pub weight: f64,
}

impl PoolShare {
    /// Rows of the bucket that bulk-class requests may fill right now:
    /// the full bucket while the pool is otherwise idle, but only this
    /// model's weighted share while *another* model has interactive
    /// work queued (`own_interactive` subtracts this service's own
    /// queue, so a model never yields to its own interactive traffic —
    /// the in-batcher class scheduling already handles that).
    pub fn batch_fill(&self, own_interactive: u64, max_rows: usize) -> usize {
        if self.pressure.queued_interactive() <= own_interactive {
            return max_rows;
        }
        let total = self.pressure.total_weight().max(self.weight);
        (((max_rows as f64) * self.weight / total).ceil() as usize).clamp(1, max_rows)
    }
}

/// A request's rows as admitted to the batcher.
#[derive(Debug)]
pub struct PendingRequest<T> {
    pub rows: usize,
    pub payload: T,
    pub arrived: Instant,
    pub class: Class,
    /// absolute completion deadline, when the request carried one
    pub deadline: Option<Instant>,
}

/// Accumulates requests in per-class queues; `take_batch` drains class
/// prefixes obeying the policy.
pub struct Batcher<T> {
    queues: [std::collections::VecDeque<PendingRequest<T>>; Class::COUNT],
    pub max_batch_rows: usize,
    pub max_wait: Duration,
    policies: [ClassPolicy; Class::COUNT],
    /// unserved row entitlement per class (deficit round-robin)
    deficit: [f64; Class::COUNT],
    queued_rows: [usize; Class::COUNT],
    cost: Option<CostLine>,
}

impl<T> Batcher<T> {
    pub fn new(max_batch_rows: usize, max_wait: Duration) -> Self {
        Batcher {
            queues: Default::default(),
            max_batch_rows,
            max_wait,
            policies: ClassPolicy::defaults(),
            deficit: [0.0; Class::COUNT],
            queued_rows: [0; Class::COUNT],
            cost: None,
        }
    }

    /// Replace the per-class targets/weights (builder style).
    pub fn with_policies(mut self, policies: [ClassPolicy; Class::COUNT]) -> Self {
        self.policies = policies;
        self
    }

    /// Publish the executor's current calibrated cost line (`None`
    /// disables predictive early close; the `max_wait` bound remains).
    pub fn set_cost_line(&mut self, cost: Option<CostLine>) {
        self.cost = cost;
    }

    /// Admit a bulk-class request with no deadline (the default class).
    pub fn push(&mut self, rows: usize, payload: T) {
        self.push_in(Class::Batch, rows, None, payload);
    }

    /// Admit a request under `class`, optionally with an absolute
    /// completion deadline (tightens the class target for this request).
    pub fn push_in(&mut self, class: Class, rows: usize, deadline: Option<Instant>, payload: T) {
        self.queued_rows[class.index()] += rows;
        self.queues[class.index()].push_back(PendingRequest {
            rows,
            payload,
            arrived: Instant::now(),
            class,
            deadline,
        });
    }

    pub fn is_empty(&self) -> bool {
        self.queues.iter().all(|q| q.is_empty())
    }

    pub fn queued_rows(&self) -> usize {
        self.queued_rows.iter().sum()
    }

    /// Should we flush now? Yes when the bucket is full, when any class
    /// head has waited `max_wait` (the hard cap), or — with a published
    /// cost line — when a class head's *predicted* completion (wait so
    /// far + calibrated execution cost of what is queued) would breach
    /// its class target or its own deadline: waiting for more
    /// coalescing could only make it later.
    ///
    /// Invariant: the timeout clocks from each *current* head's own
    /// `arrived`. A later-arriving request that becomes head (e.g.
    /// after an oversized head drained alone) waits out its own
    /// `max_wait`; it never inherits the drained head's older
    /// timestamp.
    pub fn ready(&self, now: Instant) -> bool {
        if self.is_empty() {
            return false;
        }
        if self.queued_rows() >= self.max_batch_rows {
            return true;
        }
        let exec_s = self
            .cost
            .map(|c| c.predict_s(self.queued_rows().min(self.max_batch_rows)))
            .filter(|s| s.is_finite() && *s >= 0.0)
            .unwrap_or(0.0);
        let exec = Duration::from_secs_f64(exec_s.min(3600.0));
        for class in Class::ALL {
            let Some(head) = self.queues[class.index()].front() else { continue };
            let waited = now.saturating_duration_since(head.arrived);
            if waited >= self.max_wait {
                return true;
            }
            if waited + exec >= self.policies[class.index()].target {
                return true;
            }
            if let Some(deadline) = head.deadline {
                if now + exec >= deadline {
                    return true;
                }
            }
        }
        false
    }

    /// Drain one batch up to `max_batch_rows` (always at least one
    /// request).
    pub fn take_batch(&mut self) -> Vec<PendingRequest<T>> {
        self.take_batch_capped(self.max_batch_rows)
    }

    /// Drain one batch; `batch_fill` additionally caps the rows
    /// *bulk-class* requests may contribute (cross-model yielding via
    /// [`PoolShare::batch_fill`]). The leading request is always
    /// admitted whole regardless of caps, so capping never starves.
    ///
    /// Scheduling guarantees:
    /// - strict FIFO within a class: each class queue drains as a
    ///   *prefix*, later arrivals never overtake earlier ones in the
    ///   same class;
    /// - interactive leads, bulk fills the remaining bucket capacity;
    /// - a weighted deficit counter accumulates the bulk class's
    ///   unserved row entitlement (`weight`-proportional) while
    ///   interactive leads; once a full bucket of entitlement is owed,
    ///   the next batch is bulk-led — bounded bypass, no starvation;
    /// - a head larger than the bucket is admitted alone rather than
    ///   held (no starvation of oversized requests).
    pub fn take_batch_capped(&mut self, batch_fill: usize) -> Vec<PendingRequest<T>> {
        let active: Vec<Class> = Class::ALL
            .into_iter()
            .filter(|c| !self.queues[c.index()].is_empty())
            .collect();
        let order = if self.lead_class() == Class::Batch {
            [Class::Batch, Class::Interactive]
        } else {
            [Class::Interactive, Class::Batch]
        };
        let mut out = Vec::new();
        let mut rows = 0usize;
        let mut taken = [0usize; Class::COUNT];
        for class in order {
            let i = class.index();
            while let Some(front) = self.queues[i].front() {
                if !out.is_empty() {
                    if rows + front.rows > self.max_batch_rows {
                        break;
                    }
                    if class == Class::Batch && taken[i] + front.rows > batch_fill {
                        break;
                    }
                }
                let req = self.queues[i].pop_front().unwrap();
                rows += req.rows;
                taken[i] += req.rows;
                self.queued_rows[i] -= req.rows;
                out.push(req);
            }
        }
        // deficit round-robin bookkeeping: with both classes queued,
        // each class was entitled to its weight-share of this batch's
        // rows; what it did not get accrues as deficit (clamped so old
        // debt cannot buy unbounded bursts)
        if active.len() > 1 {
            let w_total: f64 = active.iter().map(|c| self.policies[c.index()].weight).sum();
            for c in &active {
                let i = c.index();
                let entitle = rows as f64 * self.policies[i].weight / w_total.max(1e-9);
                self.deficit[i] = (self.deficit[i] + entitle - taken[i] as f64)
                    .clamp(0.0, 2.0 * self.max_batch_rows as f64);
            }
        } else if let Some(c) = active.first() {
            // sole class gets full service: pay down its deficit
            let i = c.index();
            self.deficit[i] = (self.deficit[i] - taken[i] as f64).max(0.0);
        }
        out
    }

    /// Which class leads the next batch: interactive whenever it has
    /// work, unless the bulk class is owed a full bucket of entitlement
    /// (the anti-starvation bypass).
    fn lead_class(&self) -> Class {
        if self.queues[Class::Interactive.index()].is_empty() {
            return Class::Batch;
        }
        if self.queues[Class::Batch.index()].is_empty() {
            return Class::Interactive;
        }
        if self.deficit[Class::Batch.index()] >= self.max_batch_rows as f64 {
            Class::Batch
        } else {
            Class::Interactive
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flushes_when_full() {
        let mut b: Batcher<u32> = Batcher::new(100, Duration::from_secs(10));
        b.push(60, 1);
        assert!(!b.ready(Instant::now()));
        b.push(50, 2);
        assert!(b.ready(Instant::now()));
        let batch = b.take_batch();
        // second request would exceed the bucket -> batch is just the first
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].rows, 60);
        assert_eq!(b.queued_rows(), 50);
    }

    #[test]
    fn flushes_on_timeout() {
        let mut b: Batcher<u32> = Batcher::new(1000, Duration::from_millis(1));
        b.push(3, 1);
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.ready(Instant::now()));
        assert_eq!(b.take_batch().len(), 1);
        assert!(b.is_empty());
    }

    #[test]
    fn oversized_request_still_dispatches() {
        let mut b: Batcher<u32> = Batcher::new(10, Duration::from_secs(1));
        b.push(25, 1);
        assert!(b.ready(Instant::now()));
        let batch = b.take_batch();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].rows, 25);
    }

    #[test]
    fn oversized_first_request_is_admitted_alone_in_fifo_order() {
        // a request larger than the bucket, with smaller ones queued
        // behind it: it must dispatch alone, immediately, and the
        // followers must keep their arrival order in the next batch
        let mut b: Batcher<u32> = Batcher::new(10, Duration::from_secs(1));
        b.push(25, 1);
        b.push(2, 2);
        b.push(3, 3);
        assert!(b.ready(Instant::now()), "full bucket must flush without waiting");
        let first = b.take_batch();
        assert_eq!(first.len(), 1, "oversized head dispatches alone");
        assert_eq!((first[0].rows, first[0].payload), (25, 1));
        assert_eq!(b.queued_rows(), 5);
        let second = b.take_batch();
        let payloads: Vec<u32> = second.iter().map(|p| p.payload).collect();
        assert_eq!(payloads, vec![2, 3], "followers coalesce in FIFO order");
        assert!(b.is_empty());
    }

    #[test]
    fn exact_max_wait_boundary_is_inclusive() {
        let max_wait = Duration::from_millis(50);
        let mut b: Batcher<u32> = Batcher::new(1000, max_wait);
        b.push(1, 9);
        let arrived = b.queues[Class::Batch.index()][0].arrived;
        assert!(!b.ready(arrived), "fresh request must not flush");
        assert!(
            !b.ready(arrived + max_wait - Duration::from_nanos(1)),
            "just under the deadline must keep waiting"
        );
        assert!(b.ready(arrived + max_wait), "exactly max_wait must flush (>=)");
        assert!(b.ready(arrived + max_wait + Duration::from_millis(1)));
    }

    #[test]
    fn drained_head_does_not_backdate_followers() {
        // regression for the `ready` timeout invariant: the flush clock
        // runs from the *current* head's own arrival. After an
        // oversized head drains alone, the later-arriving small
        // follower must wait out its own `max_wait` — it must not
        // inherit the drained head's older timestamp and flush ahead of
        // schedule.
        let max_wait = Duration::from_millis(50);
        let mut b: Batcher<u32> = Batcher::new(10, max_wait);
        let t0 = Instant::now();
        b.push(25, 1); // oversized head
        b.push(2, 2); // small follower, arrives "now"
        // age the head far past max_wait; the follower stays fresh
        b.queues[Class::Batch.index()][0].arrived = t0 - Duration::from_millis(200);
        assert!(b.ready(t0), "aged oversized head must flush");
        let first = b.take_batch();
        assert_eq!((first[0].rows, first[0].payload), (25, 1), "head drains alone");
        // the follower is now head — its own arrival governs the clock
        assert!(
            !b.ready(t0 + Duration::from_millis(30)),
            "follower must not inherit the drained head's age"
        );
        assert!(
            b.ready(t0 + Duration::from_millis(200)),
            "follower flushes once its own max_wait elapses"
        );
    }

    #[test]
    fn batches_coalesce_small_requests() {
        let mut b: Batcher<u32> = Batcher::new(100, Duration::from_secs(1));
        for i in 0..10 {
            b.push(10, i);
        }
        let batch = b.take_batch();
        assert_eq!(batch.len(), 10);
        assert!(b.is_empty());
        assert_eq!(b.queued_rows(), 0);
    }

    #[test]
    fn interactive_leads_and_bulk_fills_capacity() {
        let mut b: Batcher<u32> = Batcher::new(100, Duration::from_secs(1));
        b.push_in(Class::Batch, 50, None, 1); // bulk arrived first
        b.push_in(Class::Interactive, 30, None, 2);
        b.push_in(Class::Interactive, 20, None, 3);
        let batch = b.take_batch();
        let payloads: Vec<u32> = batch.iter().map(|p| p.payload).collect();
        // interactive pair leads (FIFO within its class), bulk fills
        // the remaining 50 rows of the bucket
        assert_eq!(payloads, vec![2, 3, 1]);
        assert!(b.is_empty());
    }

    #[test]
    fn bulk_only_traffic_behaves_fifo() {
        // with a single class queued, scheduling degenerates to the
        // plain FIFO policy — the pre-class behavior
        let mut b: Batcher<u32> = Batcher::new(25, Duration::from_secs(1));
        for i in 0..5 {
            b.push(10, i);
        }
        let payloads: Vec<u32> = b.take_batch().iter().map(|p| p.payload).collect();
        assert_eq!(payloads, vec![0, 1]);
        let payloads: Vec<u32> = b.take_batch().iter().map(|p| p.payload).collect();
        assert_eq!(payloads, vec![2, 3]);
    }

    #[test]
    fn deficit_counter_prevents_bulk_starvation() {
        let mut b: Batcher<u32> = Batcher::new(10, Duration::from_secs(10));
        b.push_in(Class::Batch, 10, None, 999);
        // interactive keeps the bucket saturated; with weights 4:1 the
        // bulk class accrues 1/5 of each 10-row batch as entitlement
        // and must be served within ~5 buckets
        let mut bypassed = 0u32;
        loop {
            b.push_in(Class::Interactive, 10, None, bypassed);
            let batch = b.take_batch();
            assert_eq!(batch.len(), 1);
            if batch[0].class == Class::Batch {
                break;
            }
            bypassed += 1;
            assert!(bypassed < 50, "bulk request starved");
        }
        assert!(bypassed <= 6, "bulk served after {bypassed} interactive batches");
    }

    #[test]
    fn cost_line_closes_batches_early_for_interactive() {
        // bucket far from full, max_wait far away — but the calibrated
        // cost line predicts ~100ms of execution for what is queued,
        // past the 50ms interactive target: the batch must close now
        let mut b: Batcher<u32> = Batcher::new(1000, Duration::from_secs(10));
        b.set_cost_line(Some(CostLine { batch_overhead_s: 0.0, rows_per_s: 1000.0 }));
        b.push_in(Class::Batch, 100, None, 1);
        assert!(!b.ready(Instant::now()), "bulk target (1s) tolerates 100ms");
        b.push_in(Class::Interactive, 1, None, 2);
        assert!(
            b.ready(Instant::now()),
            "interactive head cannot make its 50ms target by waiting longer"
        );
    }

    #[test]
    fn explicit_deadline_tightens_the_class_target() {
        let mut b: Batcher<u32> = Batcher::new(1000, Duration::from_secs(10));
        b.set_cost_line(Some(CostLine { batch_overhead_s: 0.0, rows_per_s: 1e6 }));
        let now = Instant::now();
        b.push_in(Class::Batch, 1, Some(now + Duration::from_millis(20)), 1);
        assert!(!b.ready(now), "deadline 20ms out, exec ~1µs: keep coalescing");
        assert!(
            b.ready(now + Duration::from_millis(20)),
            "predicted completion past the request deadline must flush"
        );
    }

    #[test]
    fn pool_share_caps_bulk_fill_under_interactive_pressure() {
        let pressure = PoolPressure::new();
        pressure.add_weight(1.0);
        pressure.add_weight(3.0);
        let bulk = PoolShare { pressure: pressure.clone(), weight: 1.0 };
        // idle pool: bulk saturates the bucket
        assert_eq!(bulk.batch_fill(0, 100), 100);
        // another model has interactive queued: bulk yields to its share
        pressure.add_interactive(2);
        assert_eq!(bulk.batch_fill(0, 100), 25);
        // a model's own interactive queue does not make it yield to itself
        assert_eq!(bulk.batch_fill(2, 100), 100);
        pressure.sub_interactive(2);
        assert_eq!(bulk.batch_fill(0, 100), 100);
        // weight accounting survives remove; sub below zero saturates
        pressure.remove_weight(3.0);
        assert!((pressure.total_weight() - 1.0).abs() < 1e-9);
        pressure.sub_interactive(5);
        assert_eq!(pressure.queued_interactive(), 0);
    }
}
