//! The multi-model serving registry: named, versioned, hot-swappable
//! serving targets in one process (the `pgml.train` → `pgml.deploy` →
//! `pgml.predict` idiom, scaled to this coordinator).
//!
//! Each loaded model gets its own [`ShapService`] executor — its own
//! batcher, adaptive planner and metrics namespace — while all entries
//! share the process-wide prepared-model cache (`backend::prepare` is
//! keyed by `Arc<Model>` identity) and lease their device slots from
//! one [`DevicePool`], so co-resident models cannot oversubscribe the
//! topology. Calibration state persists per registry entry
//! (`<name>.calib.json` next to the model artifact, or under an
//! explicit calibration directory keyed by entry name), so a model
//! unloaded and reloaded — or parked by an alias swap and redeployed —
//! plans from its own measurements.
//!
//! **Hot deploy**: [`ModelRegistry::deploy`] atomically repoints an
//! alias at another loaded model. Requests resolve alias → entry per
//! submission, and in-flight requests hold the old entry's service
//! `Arc`, so a swap loses nothing: work admitted before the swap
//! completes on the old executor, work after it lands on the new one.
//! With `retire_old`, the abandoned target is *parked* after the swap —
//! its executor drains gracefully ([`ShapService::drain`], `&self`) and
//! its device lease returns to the pool, but the model `Arc` (and with
//! it the prepared-model cache entry) and calibration file stay warm,
//! so redeploying it later restarts in cache-hit time.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, RwLock};

use crate::anyhow;
use crate::backend::{BackendConfig, BackendKind, DeviceLease, DevicePool};
use crate::coordinator::batcher::{PoolPressure, PoolShare};
use crate::coordinator::service::{Request, Response, ServiceConfig, ShapService};
use crate::gbdt::Model;
use crate::util::error::Result;
use crate::util::Json;

/// How the registry builds each entry's executor: the service/backend
/// templates are cloned per model (the per-model calibration path is
/// derived, not taken from the template).
#[derive(Clone, Debug)]
pub struct RegistryConfig {
    /// per-entry service template; `calibration_path` in it is ignored
    /// (derived per entry — see [`RegistryConfig::calibration_dir`])
    pub service: ServiceConfig,
    /// per-entry backend template
    pub backend: BackendConfig,
    /// `Some` pins every entry's backend kind; `None` lets each entry's
    /// planner choose (and keep choosing, on the recalibrate cadence)
    pub kind: Option<BackendKind>,
    /// when set, entry calibration persists to
    /// `<calibration_dir>/<name>.calib.json` (keyed by registry entry
    /// name); otherwise file-loaded models use `<model path>.calib.json`
    /// and in-memory models skip persistence
    pub calibration_dir: Option<PathBuf>,
}

impl Default for RegistryConfig {
    fn default() -> Self {
        RegistryConfig {
            // serve every task the wire protocol can ask for
            backend: BackendConfig {
                with_interactions: true,
                with_predict: true,
                ..Default::default()
            },
            service: ServiceConfig::default(),
            kind: None,
            calibration_dir: None,
        }
    }
}

/// One running executor plus the device slots it holds; dropping it
/// (park/unload, after the drain) returns the slots to the pool.
struct Running {
    service: Arc<ShapService>,
    kind_label: String,
    _lease: DeviceLease,
    /// keeps this entry's fairness weight registered on the shared
    /// pool-pressure gauge for as long as the executor runs
    _share: ShareGuard,
}

/// RAII registration of one running entry's fairness weight on the
/// registry-wide [`PoolPressure`] gauge: other models' batchers divide
/// the bulk fill by the total registered weight, so a weight must leave
/// the denominator the moment its executor parks or unloads.
struct ShareGuard {
    pressure: Arc<PoolPressure>,
    weight: f64,
}

impl ShareGuard {
    fn new(pressure: Arc<PoolPressure>, weight: f64) -> ShareGuard {
        pressure.add_weight(weight);
        ShareGuard { pressure, weight }
    }
}

impl Drop for ShareGuard {
    fn drop(&mut self) {
        self.pressure.remove_weight(self.weight);
    }
}

/// One registered model: the shared `Arc<Model>` (which pins its
/// prepared-cache entry while loaded or parked), its provenance, and
/// its executor slot (`None` = parked).
pub struct ModelEntry {
    name: String,
    model: Arc<Model>,
    source: Option<PathBuf>,
    calibration_path: Option<PathBuf>,
    /// fairness share of the device pool relative to the other running
    /// entries' weights (see [`ModelRegistry::load_weighted`])
    weight: f64,
    runtime: RwLock<Option<Running>>,
    /// serializes park/restart transitions so concurrent deploys cannot
    /// double-build or double-drain one entry
    transition: Mutex<()>,
}

impl ModelEntry {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn model(&self) -> &Arc<Model> {
        &self.model
    }

    /// This entry's fairness weight on the shared device pool.
    pub fn weight(&self) -> f64 {
        self.weight
    }

    /// The entry's executor, or an error naming the parked state.
    pub fn service(&self) -> Result<Arc<ShapService>> {
        match self.runtime.read().unwrap().as_ref() {
            Some(r) => Ok(r.service.clone()),
            None => Err(anyhow!(
                "model '{}' is parked (retired by an alias swap); deploy it to restart",
                self.name
            )),
        }
    }

    pub fn is_running(&self) -> bool {
        self.runtime.read().unwrap().is_some()
    }

    fn kind_label(&self) -> Option<String> {
        self.runtime.read().unwrap().as_ref().map(|r| r.kind_label.clone())
    }
}

struct State {
    models: BTreeMap<String, Arc<ModelEntry>>,
    /// alias → model name (single level: aliases never chain)
    aliases: BTreeMap<String, String>,
}

/// Named, hot-swappable serving targets behind one handle — the thing
/// the network ingress routes requests into.
pub struct ModelRegistry {
    cfg: RegistryConfig,
    pool: Arc<DevicePool>,
    /// cross-model interactive-pressure gauge shared by every entry's
    /// batcher: a bulk-heavy model yields device-pool capacity while any
    /// co-resident model has interactive work queued
    pressure: Arc<PoolPressure>,
    state: RwLock<State>,
}

impl ModelRegistry {
    pub fn new(cfg: RegistryConfig, pool: Arc<DevicePool>) -> ModelRegistry {
        ModelRegistry {
            cfg,
            pool,
            pressure: PoolPressure::new(),
            state: RwLock::new(State { models: BTreeMap::new(), aliases: BTreeMap::new() }),
        }
    }

    /// A registry with default templates and no device budget.
    pub fn unbounded(cfg: RegistryConfig) -> ModelRegistry {
        ModelRegistry::new(cfg, DevicePool::unbounded())
    }

    pub fn pool(&self) -> &Arc<DevicePool> {
        &self.pool
    }

    /// Where this entry's calibration persists: the explicit
    /// calibration dir keyed by entry name wins, else next to the model
    /// artifact (`<path>.calib.json`), else nowhere (in-memory model).
    fn calibration_path(&self, name: &str, source: Option<&Path>) -> Option<PathBuf> {
        if let Some(dir) = &self.cfg.calibration_dir {
            return Some(dir.join(format!("{name}.calib.json")));
        }
        source.map(|p| PathBuf::from(format!("{}.calib.json", p.display())))
    }

    /// Build one executor for `entry`-shaped serving: lease devices,
    /// start the (pinned or planner-driven) service with the entry's
    /// own calibration file.
    fn start_service(
        &self,
        model: &Arc<Model>,
        calibration_path: Option<PathBuf>,
        weight: f64,
    ) -> Result<Running> {
        let lease = self.pool.lease(self.cfg.service.devices.max(1))?;
        let share = ShareGuard::new(self.pressure.clone(), weight);
        let scfg = ServiceConfig {
            calibration_path,
            share: Some(PoolShare { pressure: self.pressure.clone(), weight }),
            ..self.cfg.service.clone()
        };
        let bcfg = self.cfg.backend.clone();
        let (kind_label, service) = match self.cfg.kind {
            Some(kind) => (
                kind.name().to_string(),
                ShapService::start(model.clone(), kind, bcfg, scfg)?,
            ),
            None => {
                let (kind, svc) = ShapService::start_planned(model.clone(), bcfg, scfg)?;
                (format!("auto→{}", kind.name()), svc)
            }
        };
        Ok(Running { service: Arc::new(service), kind_label, _lease: lease, _share: share })
    }

    /// Register `model` under `name` and start serving it with the
    /// default fairness weight (1.0). Fails when the name is taken (by
    /// a model or an alias) or the device pool cannot cover another
    /// `devices`-slot executor.
    pub fn load(&self, name: &str, model: Arc<Model>, source: Option<PathBuf>) -> Result<()> {
        self.load_weighted(name, model, source, 1.0)
    }

    /// [`ModelRegistry::load`] with an explicit fairness weight: while
    /// another entry has interactive work queued, this entry's batch
    /// fill is capped at `weight / Σ running weights` of the batch
    /// bucket, so heavier models keep proportionally more capacity
    /// under cross-model interactive pressure.
    pub fn load_weighted(
        &self,
        name: &str,
        model: Arc<Model>,
        source: Option<PathBuf>,
        weight: f64,
    ) -> Result<()> {
        validate_name(name)?;
        if !weight.is_finite() || weight <= 0.0 {
            return Err(anyhow!("model weight must be a positive number, got {weight}"));
        }
        {
            let state = self.state.read().unwrap();
            state.check_name_free(name)?;
        }
        let calibration_path = self.calibration_path(name, source.as_deref());
        // build outside the state lock: model prep can be slow and must
        // not stall serving reads of other entries
        let running = self.start_service(&model, calibration_path.clone(), weight)?;
        let entry = Arc::new(ModelEntry {
            name: name.to_string(),
            model,
            source,
            calibration_path,
            weight,
            runtime: RwLock::new(Some(running)),
            transition: Mutex::new(()),
        });
        let mut state = self.state.write().unwrap();
        // re-check under the write lock: a concurrent load may have won
        state.check_name_free(name)?;
        state.models.insert(name.to_string(), entry);
        Ok(())
    }

    /// Load a model artifact from disk (`.gtsm`, or XGBoost
    /// `model.json`) and register it under `name`.
    pub fn load_path(&self, name: &str, path: &Path) -> Result<()> {
        self.load_path_weighted(name, path, 1.0)
    }

    /// [`ModelRegistry::load_path`] with an explicit fairness weight.
    pub fn load_path_weighted(&self, name: &str, path: &Path, weight: f64) -> Result<()> {
        let model = if path.extension().is_some_and(|e| e == "json") {
            crate::gbdt::xgb_import::load_xgboost_json(path)?
        } else {
            crate::gbdt::io::load(path)?
        };
        self.load_weighted(name, Arc::new(model), Some(path.to_path_buf()), weight)
    }

    /// Remove `name` from the registry (cascading away any aliases that
    /// point at it), then gracefully drain its executor: in-flight
    /// requests complete, threads join, the device lease returns, and —
    /// once the entry drops — the prepared-model cache entry with it.
    pub fn unload(&self, name: &str) -> Result<()> {
        let entry = {
            let mut state = self.state.write().unwrap();
            let entry = state
                .models
                .remove(name)
                .ok_or_else(|| anyhow!("unknown model '{name}'"))?;
            state.aliases.retain(|_, target| target != name);
            entry
        };
        // drain outside the state lock: new resolutions already miss
        // the entry, and in-flight holders finish against their own
        // Arc. The transition lock fences a concurrent deploy's
        // restart-if-parked from racing this teardown.
        let _t = entry.transition.lock().unwrap();
        let running = entry.runtime.write().unwrap().take();
        if let Some(r) = running {
            r.service.drain();
        }
        Ok(())
    }

    /// Atomically repoint `alias` at loaded model `model` (creating the
    /// alias if new) — the hot-deploy primitive. In-flight requests on
    /// the old target keep their executor; new resolutions see the new
    /// target immediately. A parked target restarts (warm: its model
    /// kept its prepared-cache entry and calibration file). With
    /// `retire_old`, the previous target is parked after the swap —
    /// drained via [`ShapService::drain`] and its device slots released
    /// — unless it is still referenced by another alias.
    pub fn deploy(&self, alias: &str, model: &str, retire_old: bool) -> Result<DeployOutcome> {
        validate_name(alias)?;
        let target = {
            let state = self.state.read().unwrap();
            if state.models.contains_key(alias) {
                return Err(anyhow!(
                    "'{alias}' is a loaded model name, not an alias; unload it first"
                ));
            }
            if state.aliases.contains_key(model) {
                return Err(anyhow!(
                    "deploy target '{model}' is itself an alias; aliases never chain \
                     (point '{alias}' at the underlying model instead)"
                ));
            }
            state
                .models
                .get(model)
                .cloned()
                .ok_or_else(|| anyhow!("unknown model '{model}'"))?
        };
        // restart a parked target before the swap, so the alias never
        // points at an entry that cannot serve
        self.ensure_running(&target)?;
        let previous = {
            let mut state = self.state.write().unwrap();
            state.aliases.insert(alias.to_string(), model.to_string())
        };
        let mut retired = None;
        if retire_old {
            if let Some(prev) = previous.as_deref() {
                if prev != model && self.park_if_unreferenced(prev) {
                    retired = Some(prev.to_string());
                }
            }
        }
        Ok(DeployOutcome { previous, retired })
    }

    /// Restart a parked entry's executor in place (no-op when running).
    fn ensure_running(&self, entry: &Arc<ModelEntry>) -> Result<()> {
        let _t = entry.transition.lock().unwrap();
        if entry.is_running() {
            return Ok(());
        }
        // a concurrent unload may have removed the entry between the
        // caller's resolve and this lock; restarting it now would leak
        // an executor nothing can ever drain
        let still_registered = self
            .state
            .read()
            .unwrap()
            .models
            .get(&entry.name)
            .is_some_and(|e| Arc::ptr_eq(e, entry));
        if !still_registered {
            return Err(anyhow!("model '{}' was unloaded", entry.name));
        }
        let running =
            self.start_service(&entry.model, entry.calibration_path.clone(), entry.weight)?;
        *entry.runtime.write().unwrap() = Some(running);
        Ok(())
    }

    /// Park `name`'s executor if no alias references it: drain
    /// gracefully and release the device lease, keeping the entry (and
    /// its prepared-cache pin) registered. Returns whether it parked.
    fn park_if_unreferenced(&self, name: &str) -> bool {
        let entry = {
            let state = self.state.read().unwrap();
            if state.aliases.values().any(|t| t == name) {
                return false;
            }
            match state.models.get(name) {
                Some(e) => e.clone(),
                None => return false,
            }
        };
        let _t = entry.transition.lock().unwrap();
        let running = entry.runtime.write().unwrap().take();
        match running {
            Some(r) => {
                r.service.drain();
                true
            }
            None => false,
        }
    }

    /// Resolve a model name or alias to its entry (aliases are a single
    /// hop by construction).
    pub fn resolve(&self, name: &str) -> Result<Arc<ModelEntry>> {
        let state = self.state.read().unwrap();
        let target = state.aliases.get(name).map(|s| s.as_str()).unwrap_or(name);
        state.models.get(target).cloned().ok_or_else(|| {
            let known: Vec<&str> = state
                .models
                .keys()
                .map(|s| s.as_str())
                .chain(state.aliases.keys().map(|s| s.as_str()))
                .collect();
            anyhow!("unknown model or alias '{name}' (serving: {})", known.join(", "))
        })
    }

    /// Submit one request routed by model name/alias. Retries the
    /// resolve+submit once when the resolved executor stopped
    /// underneath the request (alias swap + retire racing the submit),
    /// so a hot deploy drops nothing.
    pub fn submit(&self, name: &str, req: Request) -> Result<std::sync::mpsc::Receiver<Response>> {
        let mut last_err = None;
        for _ in 0..3 {
            let entry = self.resolve(name)?;
            match entry.service() {
                Ok(svc) => match svc.submit(req.clone()) {
                    Ok(rx) => return Ok(rx),
                    Err(e) if format!("{e:#}").contains("service stopped") => {
                        last_err = Some(e);
                        continue;
                    }
                    Err(e) => return Err(e),
                },
                Err(e) => {
                    last_err = Some(e);
                    continue;
                }
            }
        }
        Err(last_err.unwrap_or_else(|| anyhow!("model '{name}' unavailable")))
    }

    /// Blocking submit: route, wait for the [`Response`], re-routing
    /// once when the executor drained between admission and delivery
    /// (deploy retire racing the queue) — the zero-drop half the
    /// `submit` retry doesn't cover.
    pub fn run_response(&self, name: &str, req: Request) -> Result<Response> {
        for _ in 0..2 {
            let rx = self.submit(name, req.clone())?;
            match rx.recv() {
                Ok(resp) => return Ok(resp),
                Err(_) => continue,
            }
        }
        Err(anyhow!("service dropped response for model '{name}'"))
    }

    /// Blocking convenience over [`ModelRegistry::run_response`]: wait
    /// and unwrap the response values.
    pub fn run(&self, name: &str, req: Request) -> Result<Vec<f32>> {
        self.run_response(name, req)?.into_values()
    }

    /// Model/alias names currently routable.
    pub fn names(&self) -> Vec<String> {
        let state = self.state.read().unwrap();
        state.models.keys().chain(state.aliases.keys()).cloned().collect()
    }

    /// The registry roster: per-model state (running|parked, kind,
    /// devices, aliases, source) without the metric payloads.
    pub fn list(&self) -> Json {
        let state = self.state.read().unwrap();
        let models = state
            .models
            .iter()
            .map(|(name, e)| {
                let aliases: Vec<Json> = state
                    .aliases
                    .iter()
                    .filter(|(_, t)| *t == name)
                    .map(|(a, _)| Json::from(a.as_str()))
                    .collect();
                let mut fields = vec![
                    ("state", Json::from(if e.is_running() { "running" } else { "parked" })),
                    ("trees", Json::from(e.model.trees.len())),
                    ("features", Json::from(e.model.num_features)),
                    ("groups", Json::from(e.model.num_groups)),
                    ("weight", Json::from(e.weight)),
                    ("aliases", Json::Arr(aliases)),
                ];
                if let Some(k) = e.kind_label() {
                    fields.push(("backend", Json::from(k)));
                }
                if let Some(src) = &e.source {
                    fields.push(("source", Json::from(src.display().to_string())));
                }
                (name.clone(), Json::obj(fields))
            })
            .collect::<BTreeMap<String, Json>>();
        let aliases = state
            .aliases
            .iter()
            .map(|(a, t)| (a.clone(), Json::from(t.as_str())))
            .collect::<BTreeMap<String, Json>>();
        Json::obj(vec![
            ("models", Json::Obj(models)),
            ("aliases", Json::Obj(aliases)),
            (
                "device_pool",
                Json::obj(vec![
                    (
                        "total",
                        if self.pool.total() == usize::MAX {
                            Json::Str("unbounded".into())
                        } else {
                            Json::from(self.pool.total())
                        },
                    ),
                    ("in_use", Json::from(self.pool.in_use())),
                ]),
            ),
        ])
    }

    /// Full stats: the roster plus each running model's metrics
    /// snapshot under its own namespace, and the process-wide
    /// prepared-model cache counters. `model` narrows to one entry.
    pub fn stats(&self, model: Option<&str>) -> Result<Json> {
        let entries: Vec<Arc<ModelEntry>> = match model {
            Some(name) => vec![self.resolve(name)?],
            None => self.state.read().unwrap().models.values().cloned().collect(),
        };
        let per_model = entries
            .iter()
            .map(|e| {
                let metrics = match e.runtime.read().unwrap().as_ref() {
                    Some(r) => r.service.metrics.snapshot(),
                    None => Json::from("parked"),
                };
                (e.name.clone(), metrics)
            })
            .collect::<BTreeMap<String, Json>>();
        Ok(Json::obj(vec![
            ("registry", self.list()),
            ("models", Json::Obj(per_model)),
            ("prepared", crate::backend::prepared::registry_snapshot()),
        ]))
    }

    /// Drain every running executor (process shutdown): models stay
    /// listed but stop serving; per-entry calibration persists as part
    /// of each executor's drain.
    pub fn drain_all(&self) {
        let entries: Vec<Arc<ModelEntry>> =
            self.state.read().unwrap().models.values().cloned().collect();
        for e in entries {
            let _t = e.transition.lock().unwrap();
            let running = e.runtime.write().unwrap().take();
            if let Some(r) = running {
                r.service.drain();
            }
        }
    }
}

/// What a [`ModelRegistry::deploy`] did: the alias's previous target
/// (None when newly created) and the target it parked, if any.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeployOutcome {
    pub previous: Option<String>,
    pub retired: Option<String>,
}

impl State {
    fn check_name_free(&self, name: &str) -> Result<()> {
        if self.models.contains_key(name) {
            return Err(anyhow!("model '{name}' is already loaded (unload it first)"));
        }
        if self.aliases.contains_key(name) {
            return Err(anyhow!("'{name}' is already an alias (deploy it elsewhere first)"));
        }
        Ok(())
    }
}

fn validate_name(name: &str) -> Result<()> {
    if name.is_empty() || name.len() > 128 {
        return Err(anyhow!("model names must be 1–128 characters"));
    }
    if !name.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '_' | '-' | '.')) {
        return Err(anyhow!(
            "invalid model name '{name}': use ASCII letters, digits, '_', '-', '.'"
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;
    use crate::gbdt::{train, TrainParams};

    fn tiny_model(rounds: usize) -> Arc<Model> {
        let d = SynthSpec::cal_housing(0.004).generate();
        Arc::new(train(&d, &TrainParams { rounds, max_depth: 3, ..Default::default() }))
    }

    fn quick_cfg() -> RegistryConfig {
        RegistryConfig {
            kind: Some(BackendKind::Recursive),
            backend: BackendConfig {
                threads: 1,
                with_interactions: true,
                with_predict: true,
                ..Default::default()
            },
            service: ServiceConfig {
                max_batch_rows: 32,
                max_wait: std::time::Duration::from_millis(1),
                recalibrate_every: 0,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn names_validate_and_collide() {
        let reg = ModelRegistry::unbounded(quick_cfg());
        assert!(reg.load("bad name", tiny_model(1), None).is_err());
        assert!(reg.load("", tiny_model(1), None).is_err());
        reg.load("m1", tiny_model(1), None).unwrap();
        let err = reg.load("m1", tiny_model(1), None).unwrap_err();
        assert!(format!("{err:#}").contains("already loaded"));
        reg.deploy("best", "m1", false).unwrap();
        let err = reg.load("best", tiny_model(1), None).unwrap_err();
        assert!(format!("{err:#}").contains("alias"));
        // an alias cannot shadow a model, nor chain onto another alias
        assert!(reg.deploy("m1", "m1", false).is_err());
        assert!(reg.deploy("best2", "best", false).is_err());
        reg.drain_all();
    }

    #[test]
    fn device_pool_gates_admission() {
        let pool = DevicePool::new(3);
        let cfg = RegistryConfig {
            service: ServiceConfig { devices: 2, ..quick_cfg().service },
            ..quick_cfg()
        };
        let reg = ModelRegistry::new(cfg, pool.clone());
        reg.load("m1", tiny_model(1), None).unwrap();
        assert_eq!(pool.in_use(), 2);
        let err = reg.load("m2", tiny_model(1), None).unwrap_err();
        assert!(format!("{err:#}").contains("device pool exhausted"), "{err:#}");
        // unload returns the slots, after which the load succeeds
        reg.unload("m1").unwrap();
        assert_eq!(pool.in_use(), 0);
        reg.load("m2", tiny_model(1), None).unwrap();
        assert_eq!(pool.in_use(), 2);
        reg.drain_all();
        assert_eq!(pool.in_use(), 0, "drain_all releases every lease");
    }

    #[test]
    fn deploy_retire_parks_and_redeploy_restarts() {
        let reg = ModelRegistry::unbounded(quick_cfg());
        reg.load("m1", tiny_model(1), None).unwrap();
        reg.load("m2", tiny_model(2), None).unwrap();
        let out = reg.deploy("best", "m1", true).unwrap();
        assert_eq!(out, DeployOutcome { previous: None, retired: None });
        // swap to m2 retires m1 (nothing else references it)
        let out = reg.deploy("best", "m2", true).unwrap();
        assert_eq!(out.previous.as_deref(), Some("m1"));
        assert_eq!(out.retired.as_deref(), Some("m1"));
        let m1 = reg.resolve("m1").unwrap();
        assert!(!m1.is_running(), "retired target parks");
        let err = reg.run("m1", Request::contributions(vec![0.0; 8], 1)).unwrap_err();
        assert!(format!("{err:#}").contains("parked"), "{err:#}");
        // redeploying the parked model restarts it in place
        reg.deploy("best", "m1", true).unwrap();
        assert!(reg.resolve("m1").unwrap().is_running());
        assert!(!reg.resolve("m2").unwrap().is_running(), "m2 retired in turn");
        // a second alias protects the target from retirement
        reg.deploy("canary", "m1", false).unwrap();
        reg.deploy("best", "m1", true).unwrap();
        let out = reg.deploy("canary", "m1", true).unwrap();
        assert_eq!(out.retired, None, "self-swap retires nothing");
        reg.drain_all();
    }
}
