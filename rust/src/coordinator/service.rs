//! The serving coordinator: SHAP-as-a-service over the XLA runtime.
//!
//! Topology (vLLM-router-like, scaled to this problem):
//!
//! ```text
//!   clients --submit()--> bounded ingress --batcher thread--+
//!                                                           v
//!                                             job queue (batches)
//!                                                           v
//!                      worker threads (one engine+device each) --responses-->
//! ```
//!
//! Backpressure: the ingress channel is bounded; `submit` fails fast when
//! the queue is full (callers see `Rejected`). The batcher coalesces
//! requests up to the artifact row bucket or `max_wait`, whichever first.

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::batcher::Batcher;
use crate::coordinator::metrics::Metrics;
use crate::runtime::engine::ShapEngine;
use crate::runtime::manifest::ArtifactKind;
use crate::shap::packed::{PackedModel, PaddedModel};

/// Which device layout the workers execute (DESIGN.md §Perf: padded is
/// the optimized default; warp is the faithful CUDA adaptation).
pub enum ModelRep {
    Warp(Arc<PackedModel>),
    Padded(Arc<PaddedModel>),
}

impl ModelRep {
    fn num_features(&self) -> usize {
        match self {
            ModelRep::Warp(m) => m.num_features,
            ModelRep::Padded(m) => m.num_features,
        }
    }
    fn num_groups(&self) -> usize {
        match self {
            ModelRep::Warp(m) => m.num_groups,
            ModelRep::Padded(m) => m.num_groups,
        }
    }
}

#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub devices: usize,
    pub artifacts_dir: std::path::PathBuf,
    /// flush threshold (defaults to the artifact row bucket)
    pub max_batch_rows: usize,
    pub max_wait: Duration,
    /// ingress queue capacity (requests) — the backpressure bound
    pub queue_cap: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            devices: 1,
            artifacts_dir: crate::runtime::default_artifacts_dir(),
            max_batch_rows: 256,
            max_wait: Duration::from_millis(5),
            queue_cap: 1024,
        }
    }
}

/// One explain request: feature rows in, φ rows out.
struct Request {
    x: Vec<f32>,
    rows: usize,
    resp: Sender<Result<Vec<f32>>>,
    submitted: Instant,
}

struct Batch {
    requests: Vec<Request>,
    rows: usize,
}

enum Ingress {
    Req(Request),
    Shutdown,
}

/// Handle to a running SHAP service.
pub struct ShapService {
    ingress: SyncSender<Ingress>,
    batcher_handle: Option<JoinHandle<()>>,
    worker_handles: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
}

enum WorkerEngine {
    Warp(crate::runtime::engine::Prepared),
    Padded(crate::runtime::engine::PreparedPadded),
}

impl ShapService {
    /// Start the service with the warp-packed layout.
    pub fn start(pm: Arc<PackedModel>, cfg: ServiceConfig) -> Result<ShapService> {
        Self::start_rep(Arc::new(ModelRep::Warp(pm)), cfg)
    }

    /// Start the service with the padded-path layout (optimized default).
    pub fn start_padded(pm: Arc<PaddedModel>, cfg: ServiceConfig) -> Result<ShapService> {
        Self::start_rep(Arc::new(ModelRep::Padded(pm)), cfg)
    }

    /// Start the service for one device-layout model representation.
    pub fn start_rep(pm: Arc<ModelRep>, cfg: ServiceConfig) -> Result<ShapService> {
        let metrics = Arc::new(Metrics::new());
        let (ingress_tx, ingress_rx) = sync_channel::<Ingress>(cfg.queue_cap);
        let (job_tx, job_rx) = sync_channel::<Batch>(cfg.devices * 2);
        let job_rx = Arc::new(Mutex::new(job_rx));

        // worker threads: one engine (device + compiled artifacts) each
        let mut worker_handles = Vec::new();
        let ready = Arc::new(std::sync::Barrier::new(cfg.devices + 1));
        let init_err: Arc<Mutex<Option<String>>> = Arc::new(Mutex::new(None));
        for _ in 0..cfg.devices {
            let pm = pm.clone();
            let dir = cfg.artifacts_dir.clone();
            let job_rx = job_rx.clone();
            let metrics = metrics.clone();
            let ready = ready.clone();
            let init_err = init_err.clone();
            worker_handles.push(std::thread::spawn(move || {
                let built = (|| -> Result<_> {
                    let mut engine = ShapEngine::new(&dir)?;
                    let prep = match pm.as_ref() {
                        ModelRep::Warp(m) => WorkerEngine::Warp(
                            engine.prepare(m, ArtifactKind::Shap, usize::MAX)?,
                        ),
                        ModelRep::Padded(m) => {
                            WorkerEngine::Padded(engine.prepare_padded(m, usize::MAX)?)
                        }
                    };
                    Ok((engine, prep))
                })();
                let (engine, prep) = match built {
                    Ok(v) => {
                        ready.wait();
                        v
                    }
                    Err(e) => {
                        *init_err.lock().unwrap() = Some(format!("{e:#}"));
                        ready.wait();
                        return;
                    }
                };
                loop {
                    let batch = {
                        let guard = job_rx.lock().unwrap();
                        guard.recv()
                    };
                    let Ok(batch) = batch else { return };
                    process_batch(&engine, &prep, &pm, batch, &metrics);
                }
            }));
        }
        ready.wait();
        if let Some(e) = init_err.lock().unwrap().take() {
            drop(job_tx);
            drop(ingress_tx);
            for h in worker_handles {
                let _ = h.join();
            }
            return Err(anyhow!("worker init failed: {e}"));
        }

        // batcher thread
        let batcher_metrics = metrics.clone();
        let max_wait = cfg.max_wait;
        let max_rows = cfg.max_batch_rows;
        let batcher_handle = std::thread::spawn(move || {
            run_batcher(ingress_rx, job_tx, max_rows, max_wait, batcher_metrics);
        });

        Ok(ShapService {
            ingress: ingress_tx,
            batcher_handle: Some(batcher_handle),
            worker_handles,
            metrics,
        })
    }

    /// Submit rows for explanation; returns the response channel.
    /// Fails fast with `Rejected` when the ingress queue is full.
    pub fn submit(&self, x: Vec<f32>, rows: usize) -> Result<Receiver<Result<Vec<f32>>>> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.metrics.record_request(rows);
        let req = Request { x, rows, resp: tx, submitted: Instant::now() };
        match self.ingress.try_send(Ingress::Req(req)) {
            Ok(()) => Ok(rx),
            Err(TrySendError::Full(_)) => {
                self.metrics.record_rejected();
                Err(anyhow!("rejected: ingress queue full (backpressure)"))
            }
            Err(TrySendError::Disconnected(_)) => Err(anyhow!("service stopped")),
        }
    }

    /// Blocking convenience: submit and wait.
    pub fn explain(&self, x: Vec<f32>, rows: usize) -> Result<Vec<f32>> {
        self.submit(x, rows)?
            .recv()
            .map_err(|_| anyhow!("service dropped response"))?
    }

    /// Graceful shutdown: drain queues, join threads.
    pub fn shutdown(mut self) {
        let _ = self.ingress.send(Ingress::Shutdown);
        if let Some(h) = self.batcher_handle.take() {
            let _ = h.join();
        }
        for h in self.worker_handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn run_batcher(
    ingress: Receiver<Ingress>,
    job_tx: SyncSender<Batch>,
    max_rows: usize,
    max_wait: Duration,
    metrics: Arc<Metrics>,
) {
    let mut batcher: Batcher<Request> = Batcher::new(max_rows, max_wait);
    loop {
        let timeout = if batcher.is_empty() { Duration::from_millis(50) } else { max_wait };
        match ingress.recv_timeout(timeout) {
            Ok(Ingress::Req(req)) => {
                let rows = req.rows;
                batcher.push(rows, req);
            }
            Ok(Ingress::Shutdown) => break,
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
        while batcher.ready(Instant::now()) {
            dispatch(&mut batcher, &job_tx, &metrics);
        }
    }
    // drain on shutdown
    while !batcher.is_empty() {
        dispatch(&mut batcher, &job_tx, &metrics);
    }
}

fn dispatch(batcher: &mut Batcher<Request>, job_tx: &SyncSender<Batch>, metrics: &Metrics) {
    let pending = batcher.take_batch();
    if pending.is_empty() {
        return;
    }
    let rows: usize = pending.iter().map(|p| p.rows).sum();
    metrics.record_batch(rows);
    let batch = Batch { requests: pending.into_iter().map(|p| p.payload).collect(), rows };
    // blocking send: workers apply backpressure to the batcher
    let _ = job_tx.send(batch);
}

fn process_batch(
    engine: &ShapEngine,
    prep: &WorkerEngine,
    pm: &ModelRep,
    batch: Batch,
    metrics: &Metrics,
) {
    let m = pm.num_features();
    // concatenate request rows into one device batch
    let mut x = Vec::with_capacity(batch.rows * m);
    for r in &batch.requests {
        x.extend_from_slice(&r.x);
    }
    let result = match (pm, prep) {
        (ModelRep::Warp(pm), WorkerEngine::Warp(prep)) => {
            engine.shap_values(pm, prep, &x, batch.rows)
        }
        (ModelRep::Padded(pm), WorkerEngine::Padded(prep)) => {
            engine.shap_values_padded(pm, prep, &x, batch.rows)
        }
        _ => unreachable!("layout mismatch"),
    };
    match result {
        Ok(all) => {
            let stride = pm.num_groups() * (m + 1);
            let mut offset = 0;
            for req in batch.requests {
                let vals = all[offset * stride..(offset + req.rows) * stride].to_vec();
                offset += req.rows;
                metrics.record_latency(req.submitted.elapsed());
                let _ = req.resp.send(Ok(vals));
            }
        }
        Err(e) => {
            metrics.record_error();
            let msg = format!("{e:#}");
            for req in batch.requests {
                let _ = req.resp.send(Err(anyhow!("{msg}")));
            }
        }
    }
}
