//! The serving coordinator: SHAP-as-a-service over any [`ShapBackend`].
//!
//! Topology (vLLM-router-like, scaled to this problem):
//!
//! ```text
//!   clients --submit()--> bounded ingress --batcher thread--+
//!                                                           v
//!                                   per-task job queues (batches)
//!                                                           v
//!                  worker threads (one ShapBackend each) --responses-->
//! ```
//!
//! The executor is backend-agnostic: it builds one backend instance
//! from a [`BackendFactory`] on its own thread (device clients and
//! buffers are constructed on the thread that uses them) and dispatches
//! through the trait, so the recursive CPU path, the host packed DP and
//! the XLA engines are all served by the same coordinator. With
//! `devices > 1` that single instance is a `ShardedBackend` spanning
//! the device topology — each batch fans out across every device at
//! once (row- or tree-axis, see `backend::shard`) instead of the old
//! per-worker model duplication, and per-shard rows/p50/p99 surface in
//! [`Metrics`]. Contributions *and* interactions flow through the same
//! ingress → batcher → executor pipeline; batches are kept
//! task-homogeneous by batching per [`Task`].
//!
//! Backpressure: the ingress channel is bounded; `submit` fails fast when
//! the queue is full (callers see `Rejected`). The batcher coalesces
//! requests up to `max_batch_rows` or `max_wait`, whichever first.

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::anyhow;
use crate::backend::{self, BackendConfig, BackendKind, ShapBackend, ShardAxis};
use crate::coordinator::batcher::Batcher;
use crate::coordinator::metrics::Metrics;
use crate::gbdt::Model;
use crate::util::error::Result;

/// Which computation a request wants; batches are task-homogeneous.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    Contributions,
    Interactions,
}

impl Task {
    const ALL: [Task; 2] = [Task::Contributions, Task::Interactions];

    fn index(self) -> usize {
        match self {
            Task::Contributions => 0,
            Task::Interactions => 1,
        }
    }
}

/// Builds the executor's backend instance (possibly sharded).
pub type BackendFactory = dyn Fn() -> Result<Box<dyn ShapBackend>> + Send + Sync;

#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// device shards of the executor's one backend: every batch fans
    /// out across all of them through a `ShardedBackend`
    pub devices: usize,
    /// shard axis for `devices > 1`; `None` lets the planner pick
    pub shard_axis: Option<ShardAxis>,
    /// flush threshold in rows
    pub max_batch_rows: usize,
    pub max_wait: Duration,
    /// ingress queue capacity (requests) — the backpressure bound
    pub queue_cap: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            devices: 1,
            shard_axis: None,
            max_batch_rows: 256,
            max_wait: Duration::from_millis(5),
            queue_cap: 1024,
        }
    }
}

/// One explain request: feature rows in, φ (or Φ) rows out.
struct Request {
    x: Vec<f32>,
    rows: usize,
    task: Task,
    resp: Sender<Result<Vec<f32>>>,
    submitted: Instant,
}

struct Batch {
    task: Task,
    requests: Vec<Request>,
    rows: usize,
}

enum Ingress {
    Req(Request),
    Shutdown,
}

/// Handle to a running SHAP service.
pub struct ShapService {
    ingress: SyncSender<Ingress>,
    batcher_handle: Option<JoinHandle<()>>,
    worker_handles: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
}

impl ShapService {
    /// Start the executor over the backend built by `factory` (a
    /// `ShardedBackend` when the factory shards; its per-shard
    /// executions are recorded into the service metrics).
    pub fn start_with_factory(factory: Arc<BackendFactory>, cfg: ServiceConfig) -> Result<ShapService> {
        let metrics = Arc::new(Metrics::new());
        let (ingress_tx, ingress_rx) = sync_channel::<Ingress>(cfg.queue_cap);
        let (job_tx, job_rx) = sync_channel::<Batch>(2);

        // the executor thread: builds the (possibly sharded) backend on
        // the thread that uses it, then drains batches through it — each
        // batch fans out across every device shard inside the backend
        let ready = Arc::new(std::sync::Barrier::new(2));
        let init_err: Arc<Mutex<Option<String>>> = Arc::new(Mutex::new(None));
        let mut worker_handles = Vec::new();
        {
            let metrics = metrics.clone();
            let ready = ready.clone();
            let init_err = init_err.clone();
            worker_handles.push(std::thread::spawn(move || {
                let mut backend = match factory() {
                    Ok(b) => {
                        ready.wait();
                        b
                    }
                    Err(e) => {
                        *init_err.lock().unwrap() = Some(format!("{e:#}"));
                        ready.wait();
                        return;
                    }
                };
                let shard_metrics = metrics.clone();
                backend.set_shard_observer(Arc::new(move |shard, rows, dt| {
                    shard_metrics.record_shard_batch(shard, rows, dt);
                }));
                while let Ok(batch) = job_rx.recv() {
                    process_batch(backend.as_ref(), batch, &metrics);
                }
            }));
        }
        ready.wait();
        if let Some(e) = init_err.lock().unwrap().take() {
            drop(job_tx);
            drop(ingress_tx);
            for h in worker_handles {
                let _ = h.join();
            }
            return Err(anyhow!("worker init failed: {e}"));
        }

        // batcher thread
        let batcher_metrics = metrics.clone();
        let max_wait = cfg.max_wait;
        let max_rows = cfg.max_batch_rows;
        let batcher_handle = std::thread::spawn(move || {
            run_batcher(ingress_rx, job_tx, max_rows, max_wait, batcher_metrics);
        });

        Ok(ShapService {
            ingress: ingress_tx,
            batcher_handle: Some(batcher_handle),
            worker_handles,
            metrics,
        })
    }

    /// Start with one concrete backend kind over `model`. The service
    /// topology (`cfg.devices`, `cfg.shard_axis`) is forwarded into the
    /// backend build, so `devices > 1` serves through one sharded
    /// backend spanning every device.
    pub fn start(
        model: Arc<Model>,
        kind: BackendKind,
        bcfg: BackendConfig,
        cfg: ServiceConfig,
    ) -> Result<ShapService> {
        let mut bcfg = bcfg;
        bcfg.devices = cfg.devices.max(1);
        if bcfg.shard_axis.is_none() {
            bcfg.shard_axis = cfg.shard_axis;
        }
        bcfg.rows_hint = bcfg.rows_hint.max(1);
        let factory: Arc<BackendFactory> =
            Arc::new(move || backend::build(&model, kind, &bcfg));
        Self::start_with_factory(factory, cfg)
    }

    /// Planner-driven start: rank backend kinds by estimated latency for
    /// `max_batch_rows`-row batches over the service's device topology
    /// and probe-build through `backend::build_auto` (so capability
    /// gaps, e.g. a model with no interaction artifact bucket,
    /// disqualify a kind up front), then start the executor on the
    /// winning kind — with the plan's shard axis pinned so the executor
    /// builds the same layout. Returns the chosen kind alongside the
    /// service.
    pub fn start_planned(
        model: Arc<Model>,
        bcfg: BackendConfig,
        cfg: ServiceConfig,
    ) -> Result<(BackendKind, ShapService)> {
        let mut probe_cfg = bcfg;
        probe_cfg.rows_hint = cfg.max_batch_rows.clamp(1, 1 << 24);
        probe_cfg.devices = cfg.devices.max(1);
        let (plan, probe) = backend::build_auto(&model, &probe_cfg)?;
        drop(probe); // the executor builds its own instance on its thread
        // serve exactly the layout the plan priced: shard count AND axis
        // (the planner may have chosen fewer shards than devices, or 1)
        let mut cfg = cfg;
        cfg.devices = plan.shards.max(1);
        if plan.shards > 1 {
            cfg.shard_axis = Some(plan.axis);
        }
        let svc = Self::start(model, plan.kind, probe_cfg, cfg)?;
        Ok((plan.kind, svc))
    }

    /// Submit rows for a task; returns the response channel.
    /// Fails fast with `Rejected` when the ingress queue is full.
    pub fn submit_task(
        &self,
        task: Task,
        x: Vec<f32>,
        rows: usize,
    ) -> Result<Receiver<Result<Vec<f32>>>> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.metrics.record_request(rows);
        let req = Request { x, rows, task, resp: tx, submitted: Instant::now() };
        match self.ingress.try_send(Ingress::Req(req)) {
            Ok(()) => Ok(rx),
            Err(TrySendError::Full(_)) => {
                self.metrics.record_rejected();
                Err(anyhow!("rejected: ingress queue full (backpressure)"))
            }
            Err(TrySendError::Disconnected(_)) => Err(anyhow!("service stopped")),
        }
    }

    /// Submit a contributions request.
    pub fn submit(&self, x: Vec<f32>, rows: usize) -> Result<Receiver<Result<Vec<f32>>>> {
        self.submit_task(Task::Contributions, x, rows)
    }

    /// Submit an interactions request.
    pub fn submit_interactions(
        &self,
        x: Vec<f32>,
        rows: usize,
    ) -> Result<Receiver<Result<Vec<f32>>>> {
        self.submit_task(Task::Interactions, x, rows)
    }

    /// Blocking convenience: submit contributions and wait.
    pub fn explain(&self, x: Vec<f32>, rows: usize) -> Result<Vec<f32>> {
        self.submit(x, rows)?
            .recv()
            .map_err(|_| anyhow!("service dropped response"))?
    }

    /// Blocking convenience: submit interactions and wait.
    pub fn explain_interactions(&self, x: Vec<f32>, rows: usize) -> Result<Vec<f32>> {
        self.submit_interactions(x, rows)?
            .recv()
            .map_err(|_| anyhow!("service dropped response"))?
    }

    /// Graceful shutdown: drain queues, join threads.
    pub fn shutdown(mut self) {
        let _ = self.ingress.send(Ingress::Shutdown);
        if let Some(h) = self.batcher_handle.take() {
            let _ = h.join();
        }
        for h in self.worker_handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn run_batcher(
    ingress: Receiver<Ingress>,
    job_tx: SyncSender<Batch>,
    max_rows: usize,
    max_wait: Duration,
    metrics: Arc<Metrics>,
) {
    let mut batchers: [Batcher<Request>; 2] =
        [Batcher::new(max_rows, max_wait), Batcher::new(max_rows, max_wait)];
    loop {
        let timeout = if batchers.iter().all(|b| b.is_empty()) {
            Duration::from_millis(50)
        } else {
            max_wait
        };
        match ingress.recv_timeout(timeout) {
            Ok(Ingress::Req(req)) => {
                let (rows, i) = (req.rows, req.task.index());
                batchers[i].push(rows, req);
            }
            Ok(Ingress::Shutdown) => break,
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
        for task in Task::ALL {
            while batchers[task.index()].ready(Instant::now()) {
                dispatch(&mut batchers[task.index()], task, &job_tx, &metrics);
            }
        }
    }
    // drain on shutdown
    for task in Task::ALL {
        while !batchers[task.index()].is_empty() {
            dispatch(&mut batchers[task.index()], task, &job_tx, &metrics);
        }
    }
}

fn dispatch(
    batcher: &mut Batcher<Request>,
    task: Task,
    job_tx: &SyncSender<Batch>,
    metrics: &Metrics,
) {
    let pending = batcher.take_batch();
    if pending.is_empty() {
        return;
    }
    let rows: usize = pending.iter().map(|p| p.rows).sum();
    metrics.record_batch(rows);
    let batch =
        Batch { task, requests: pending.into_iter().map(|p| p.payload).collect(), rows };
    // blocking send: workers apply backpressure to the batcher
    let _ = job_tx.send(batch);
}

fn process_batch(backend: &dyn ShapBackend, batch: Batch, metrics: &Metrics) {
    let m = backend.num_features();
    let groups = backend.num_groups();
    // concatenate request rows into one backend batch
    let mut x = Vec::with_capacity(batch.rows * m);
    for r in &batch.requests {
        x.extend_from_slice(&r.x);
    }
    let t0 = Instant::now();
    let result = match batch.task {
        Task::Contributions => backend.contributions(&x, batch.rows),
        Task::Interactions => backend.interactions(&x, batch.rows),
    };
    let stride = match batch.task {
        Task::Contributions => groups * (m + 1),
        Task::Interactions => groups * (m + 1) * (m + 1),
    };
    match result {
        Ok(all) => {
            metrics.record_backend_batch(backend.name(), batch.rows, t0.elapsed());
            let mut offset = 0;
            for req in batch.requests {
                let vals = all[offset * stride..(offset + req.rows) * stride].to_vec();
                offset += req.rows;
                metrics.record_latency(req.submitted.elapsed());
                let _ = req.resp.send(Ok(vals));
            }
        }
        Err(e) => {
            metrics.record_error();
            let msg = format!("{e:#}");
            for req in batch.requests {
                let _ = req.resp.send(Err(anyhow!("{msg}")));
            }
        }
    }
}
