//! The serving coordinator: SHAP-as-a-service over any [`ShapBackend`].
//!
//! Topology (vLLM-router-like, scaled to this problem):
//!
//! ```text
//!   clients --submit()--> bounded ingress --batcher thread--+
//!                                                           v
//!                                   per-task job queues (batches)
//!                                                           v
//!                  worker threads (one ShapBackend each) --responses-->
//! ```
//!
//! The executor is backend-agnostic: it builds one backend instance on
//! its own thread (device clients and buffers are constructed on the
//! thread that uses them) and dispatches through the trait, so the
//! recursive CPU path, the host packed DP and the XLA engines are all
//! served by the same coordinator. With `devices > 1` that single
//! instance is a `ShardedBackend` spanning the device topology — each
//! batch fans out across every device at once (row- or tree-axis, see
//! `backend::shard`) and per-shard rows/p50/p99 surface in [`Metrics`].
//! Contributions *and* interactions flow through the same ingress →
//! batcher → executor pipeline; batches are kept task-homogeneous by
//! batching per [`Task`].
//!
//! **Adaptive planning** closes the measure→calibrate→plan loop: every
//! [`ServiceConfig::recalibrate_every`] batches the executor exports the
//! windowed `(rows, latency)` samples its metrics recorded, re-fits the
//! planner's cost lines against them ([`Planner::recalibrate`]), seeds
//! the sharded backend's per-shard throughput estimates (heterogeneous
//! chunk sizing), and — when the calibrated model says a different
//! backend/shard layout now wins — rebuilds the executor's backend to
//! the new plan without dropping the service. The current plan and its
//! prior-vs-measured constants surface under `"planner"` in the metrics
//! snapshot.
//!
//! **Elastic topology**: when a batch fails and the backend names the
//! failed shards, the executor quarantines them (the sharded backend
//! keeps serving from the survivors) and the recalibration cadence
//! hot-adds shards back toward the planned topology once builds succeed
//! again — device loss degrades capacity instead of killing the
//! service.
//!
//! Backpressure: the ingress channel is bounded; `submit` fails fast when
//! the queue is full (callers see `Rejected`). The batcher coalesces
//! requests up to `max_batch_rows` or `max_wait`, whichever first.

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::anyhow;
use crate::backend::{
    self, BackendConfig, BackendKind, CostEstimate, Plan, Planner, ShapBackend, ShardAxis,
};
use crate::coordinator::batcher::{Batcher, Class, ClassPolicy, CostLine, PoolShare};
use crate::coordinator::metrics::Metrics;
use crate::gbdt::Model;
use crate::util::error::Result;
use crate::util::Json;

/// Which computation a request wants; batches are task-homogeneous.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    Contributions,
    Interactions,
    Predictions,
}

impl Task {
    pub const ALL: [Task; 3] = [Task::Contributions, Task::Interactions, Task::Predictions];

    /// The alias table behind [`Task::parse`]/[`Task::name_list`] (same
    /// idiom as `BackendKind::NAMES`): first alias of each row is the
    /// canonical [`Task::name`], and the wire protocol's command verbs
    /// are aliases here so one parse serves CLI and ingress.
    const NAMES: &'static [crate::util::NameRow<Task>] = &[
        (Task::Contributions, &["explain", "contributions", "shap", "phi"]),
        (Task::Interactions, &["interactions", "phi2"]),
        (Task::Predictions, &["predict", "predictions"]),
    ];

    fn index(self) -> usize {
        match self {
            Task::Contributions => 0,
            Task::Interactions => 1,
            Task::Predictions => 2,
        }
    }

    pub fn name(&self) -> &'static str {
        Self::NAMES[self.index()].1[0]
    }

    /// Parse a task/command name (case-insensitive); `None` for unknown
    /// names — callers list the valid set via [`Task::name_list`].
    pub fn parse(s: &str) -> Option<Task> {
        crate::util::parse_named(Self::NAMES, s)
    }

    /// The canonical task names, `|`-joined for error messages.
    pub fn name_list() -> String {
        crate::util::name_list(Self::NAMES)
    }

    /// Output values per input row for a model with `m` features and
    /// `groups` output groups — everything batch slicing needs, so the
    /// executor and clients never carry parallel per-task stride logic.
    pub fn stride(&self, m: usize, groups: usize) -> usize {
        match self {
            Task::Contributions => groups * (m + 1),
            Task::Interactions => groups * (m + 1) * (m + 1),
            Task::Predictions => groups,
        }
    }
}

/// Builds the executor's backend instance (possibly sharded).
pub type BackendFactory = dyn Fn() -> Result<Box<dyn ShapBackend>> + Send + Sync;

#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// device shards of the executor's one backend: every batch fans
    /// out across all of them through a `ShardedBackend`
    pub devices: usize,
    /// shard axis for `devices > 1`; `None` lets the planner pick
    pub shard_axis: Option<ShardAxis>,
    /// flush threshold in rows
    pub max_batch_rows: usize,
    pub max_wait: Duration,
    /// ingress queue capacity (requests) — the backpressure bound
    pub queue_cap: usize,
    /// executed-batch cadence of the measure→calibrate→plan loop
    /// (recalibrate planner, seed shard throughputs, rebuild on plan
    /// change, hot-add quarantined shards); 0 disables adaptation
    pub recalibrate_every: usize,
    /// persist calibrated cost estimates here (typically next to the
    /// model artifact): loaded at startup so a restarted service plans
    /// from measurements immediately, saved whenever recalibration
    /// moves an estimate and again at shutdown; `None` disables
    pub calibration_path: Option<std::path::PathBuf>,
    /// per-class latency targets (SLOs), indexed by [`Class::index`]:
    /// the batcher closes batches early when a head's predicted
    /// completion would breach its class target, and responses landing
    /// past it count as `slo_violations` in the metrics
    pub class_targets: [Duration; Class::COUNT],
    /// per-class deficit-round-robin weights ([`Class::index`]): the
    /// bulk class's share of bucket capacity while interactive leads
    pub class_weights: [f64; Class::COUNT],
    /// cross-model fairness stake on a shared device pool (set by the
    /// registry): bulk-led batches yield bucket capacity to this
    /// weighted share while other models have interactive work queued
    pub share: Option<PoolShare>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        let policies = ClassPolicy::defaults();
        ServiceConfig {
            devices: 1,
            shard_axis: None,
            max_batch_rows: 256,
            max_wait: Duration::from_millis(5),
            queue_cap: 1024,
            recalibrate_every: 64,
            calibration_path: None,
            class_targets: [policies[0].target, policies[1].target],
            class_weights: [policies[0].weight, policies[1].weight],
            share: None,
        }
    }
}

/// One service request — the single typed unit every entry point
/// (in-process API, wire protocol, CLI client, tests) speaks: feature
/// rows in, `task`-shaped value rows out.
#[derive(Clone, Debug)]
pub struct Request {
    pub task: Task,
    /// row-major `rows × num_features` feature matrix
    pub x: Vec<f32>,
    pub rows: usize,
    /// scheduling class (default [`Class::Batch`]): interactive
    /// requests lead batch formation under the tight class target
    pub priority: Class,
    /// optional per-request completion deadline, milliseconds from
    /// submission — tightens the class target for this request only
    pub deadline_ms: Option<u64>,
}

impl Request {
    pub fn new(task: Task, x: Vec<f32>, rows: usize) -> Request {
        Request { task, x, rows, priority: Class::Batch, deadline_ms: None }
    }

    /// Builder: schedule this request under `class`.
    pub fn with_priority(mut self, class: Class) -> Request {
        self.priority = class;
        self
    }

    /// Builder: attach a completion deadline (ms from submission).
    pub fn with_deadline_ms(mut self, ms: u64) -> Request {
        self.deadline_ms = Some(ms);
        self
    }

    pub fn contributions(x: Vec<f32>, rows: usize) -> Request {
        Request::new(Task::Contributions, x, rows)
    }

    pub fn interactions(x: Vec<f32>, rows: usize) -> Request {
        Request::new(Task::Interactions, x, rows)
    }

    pub fn predictions(x: Vec<f32>, rows: usize) -> Request {
        Request::new(Task::Predictions, x, rows)
    }
}

/// What comes back for one [`Request`]: the task echoed, the per-row
/// output stride (`Task::stride` of the serving model), and the values
/// or the per-request error. The wire protocol serializes this struct
/// verbatim.
#[derive(Debug)]
pub struct Response {
    pub task: Task,
    pub rows: usize,
    /// output values per row ([`Task::stride`]); 0 on error
    pub cols: usize,
    pub values: Result<Vec<f32>>,
}

impl Response {
    /// Unwrap into the flat value vector, surfacing the request error.
    pub fn into_values(self) -> Result<Vec<f32>> {
        self.values
    }
}

/// A queued request: the caller's [`Request`] plus the response channel
/// and admission timestamp the executor needs.
struct Queued {
    req: Request,
    resp: Sender<Response>,
    submitted: Instant,
}

struct Batch {
    task: Task,
    requests: Vec<Queued>,
    rows: usize,
}

enum Ingress {
    Req(Queued),
    Shutdown,
}

/// Everything the executor thread needs to (re)build its backend and
/// keep the plan calibrated while serving.
struct AdaptiveCtx {
    model: Arc<Model>,
    bcfg: BackendConfig,
    /// `Some` pins the backend kind (the caller chose); `None` lets the
    /// (re)calibrated planner choose
    pinned_kind: Option<BackendKind>,
    /// `Some` pins the shard axis; `None` lets the planner choose
    pinned_axis: Option<ShardAxis>,
    devices: usize,
    /// batch size plans are priced at (the batcher's flush threshold)
    plan_rows: usize,
    /// recalibration cadence in executed batches (0 = static)
    every: usize,
    /// where calibrated estimates persist across restarts (None = off)
    calibration_path: Option<std::path::PathBuf>,
}

/// Handle to a running SHAP service. Thread handles live behind a
/// mutex so graceful shutdown ([`ShapService::drain`]) works through
/// `&self` — registry-held (`Arc`-shared) services drain in place.
pub struct ShapService {
    ingress: SyncSender<Ingress>,
    batcher_handle: Mutex<Option<JoinHandle<()>>>,
    worker_handles: Mutex<Vec<JoinHandle<()>>>,
    pub metrics: Arc<Metrics>,
}

impl ShapService {
    /// Start the executor over the backend built by `factory` (a
    /// `ShardedBackend` when the factory shards; its per-shard
    /// executions are recorded into the service metrics). The factory
    /// path serves statically — no planner, no recalibration — but the
    /// executor still quarantines failed shards after batch errors and
    /// probes them back on the `recalibrate_every` cadence (recovery
    /// needs a self-built sharded backend; `from_backends` topologies
    /// carry no rebuild recipe and stay at reduced width).
    pub fn start_with_factory(
        factory: Arc<BackendFactory>,
        cfg: ServiceConfig,
    ) -> Result<ShapService> {
        let metrics = Arc::new(Metrics::new());
        metrics.set_class_targets(class_targets_secs(&cfg));
        let (ingress_tx, ingress_rx) = sync_channel::<Ingress>(cfg.queue_cap);
        let (job_tx, job_rx) = sync_channel::<Batch>(2);

        let ready = Arc::new(std::sync::Barrier::new(2));
        let init_err: Arc<Mutex<Option<String>>> = Arc::new(Mutex::new(None));
        let mut worker_handles = Vec::new();
        {
            let metrics = metrics.clone();
            let ready = ready.clone();
            let init_err = init_err.clone();
            let every = cfg.recalibrate_every;
            worker_handles.push(std::thread::spawn(move || {
                let mut backend = match factory() {
                    Ok(b) => {
                        ready.wait();
                        b
                    }
                    Err(e) => {
                        *init_err.lock().unwrap() = Some(format!("{e:#}"));
                        ready.wait();
                        return;
                    }
                };
                install_shard_observer(backend.as_mut(), &metrics);
                let full_width = backend.shard_count();
                let mut since = 0usize;
                let mut backoff = ProbeBackoff::new();
                while let Ok(batch) = job_rx.recv() {
                    let ok = process_batch(backend.as_ref(), batch, &metrics);
                    if !ok && try_quarantine(backend.as_mut(), &metrics) {
                        backoff.on_quarantine();
                    }
                    since += 1;
                    if every > 0 && since >= every {
                        since = 0;
                        if backoff.may_probe() {
                            if let Ok(added) = backend.hot_add(full_width) {
                                if added > 0 {
                                    backoff.on_probe();
                                    install_shard_observer(backend.as_mut(), &metrics);
                                    reset_measurement_windows(&metrics);
                                }
                            }
                        }
                    }
                }
            }));
        }
        ready.wait();
        if let Some(e) = init_err.lock().unwrap().take() {
            drop(job_tx);
            drop(ingress_tx);
            for h in worker_handles {
                let _ = h.join();
            }
            return Err(anyhow!("worker init failed: {e}"));
        }

        // no planner on the factory path: the batcher schedules from
        // targets and `max_wait` alone (cost line stays unpublished)
        let cost_line: SharedCost = Arc::new(Mutex::new(None));
        let batcher_handle =
            spawn_batcher(ingress_rx, job_tx, batcher_cfg(&cfg), cost_line, metrics.clone());
        Ok(ShapService {
            ingress: ingress_tx,
            batcher_handle: Mutex::new(Some(batcher_handle)),
            worker_handles: Mutex::new(worker_handles),
            metrics,
        })
    }

    /// Start with one concrete backend kind over `model`. The service
    /// topology (`cfg.devices`, `cfg.shard_axis`) is forwarded into the
    /// backend build, so `devices > 1` serves through one sharded
    /// backend spanning every device. The kind stays pinned, but the
    /// recalibration cadence still self-tunes shard chunk sizing and
    /// shard count, and quarantines failing shards.
    pub fn start(
        model: Arc<Model>,
        kind: BackendKind,
        bcfg: BackendConfig,
        cfg: ServiceConfig,
    ) -> Result<ShapService> {
        let (_plan, svc) = Self::start_adaptive(model, Some(kind), bcfg, cfg)?;
        Ok(svc)
    }

    /// Planner-driven start: rank backend kinds by estimated latency for
    /// `max_batch_rows`-row batches over the service's device topology,
    /// build the best constructible one (capability gaps, e.g. a model
    /// with no interaction artifact bucket, disqualify a kind), and keep
    /// the choice calibrated while serving: measured batch samples feed
    /// back into the planner on the `recalibrate_every` cadence and the
    /// executor rebuilds onto whatever backend/shard layout the
    /// calibrated crossover now picks. Returns the initially chosen
    /// kind alongside the service.
    pub fn start_planned(
        model: Arc<Model>,
        bcfg: BackendConfig,
        cfg: ServiceConfig,
    ) -> Result<(BackendKind, ShapService)> {
        let (plan, svc) = Self::start_adaptive(model, None, bcfg, cfg)?;
        Ok((plan.kind, svc))
    }

    /// The shared executor start: builds the initial backend from the
    /// planner (pinned kind or auto) on the executor thread, then serves
    /// with the adaptive loop.
    fn start_adaptive(
        model: Arc<Model>,
        pinned_kind: Option<BackendKind>,
        bcfg: BackendConfig,
        cfg: ServiceConfig,
    ) -> Result<(Plan, ShapService)> {
        let mut bcfg = bcfg;
        bcfg.devices = cfg.devices.max(1);
        if bcfg.shard_axis.is_none() {
            bcfg.shard_axis = cfg.shard_axis;
        }
        if pinned_kind.is_none() {
            // auto mode prices and buckets for the batcher's flush size
            bcfg.rows_hint = cfg.max_batch_rows.clamp(1, 1 << 24);
        }
        bcfg.rows_hint = bcfg.rows_hint.max(1);
        let ctx = AdaptiveCtx {
            pinned_axis: bcfg.shard_axis,
            devices: cfg.devices.max(1),
            plan_rows: cfg.max_batch_rows.clamp(1, 1 << 24),
            every: cfg.recalibrate_every,
            calibration_path: cfg.calibration_path.clone(),
            model,
            bcfg,
            pinned_kind,
        };

        let metrics = Arc::new(Metrics::new());
        metrics.set_class_targets(class_targets_secs(&cfg));
        let (ingress_tx, ingress_rx) = sync_channel::<Ingress>(cfg.queue_cap);
        let (job_tx, job_rx) = sync_channel::<Batch>(2);

        // executor → batcher: the calibrated cost line of the current
        // plan, re-published on every (re)calibration so deadline-aware
        // batch formation predicts with live constants
        let cost_line: SharedCost = Arc::new(Mutex::new(None));
        let ready = Arc::new(std::sync::Barrier::new(2));
        let init_err: Arc<Mutex<Option<String>>> = Arc::new(Mutex::new(None));
        let chosen: Arc<Mutex<Option<Plan>>> = Arc::new(Mutex::new(None));
        let mut worker_handles = Vec::new();
        {
            let metrics = metrics.clone();
            let ready = ready.clone();
            let init_err = init_err.clone();
            let chosen = chosen.clone();
            let cost_line = cost_line.clone();
            worker_handles.push(std::thread::spawn(move || {
                // the planner shares the executor's prepared-model cache
                // entry (shape statistics come from the cached paths),
                // amortizes prep cost over the recalibration cadence,
                // and — when a calibration file survives from a previous
                // run — starts from measured constants, not priors
                let prep = backend::prepare(&ctx.model);
                let mut planner = Planner::for_prepared(&prep)
                    .with_devices(ctx.devices)
                    .with_fastv2_budget_mb(ctx.bcfg.fastv2_max_mb);
                if ctx.every > 0 {
                    planner = planner.with_expected_batches(ctx.every);
                }
                if let Some(path) = &ctx.calibration_path {
                    if path.exists() {
                        match backend::calibrate::load_calibration(path) {
                            Ok(entries) => {
                                planner.seed_calibration(&entries);
                            }
                            // a broken file must not be silently treated
                            // as "planning from measurements"
                            Err(e) => eprintln!(
                                "calibration: ignoring {}: {e:#} (planning from priors)",
                                path.display()
                            ),
                        }
                    }
                }
                let (mut plan, mut backend) = match build_adaptive(&planner, &ctx) {
                    Ok((plan, b)) => {
                        *chosen.lock().unwrap() = Some(plan);
                        ready.wait();
                        (plan, b)
                    }
                    Err(e) => {
                        *init_err.lock().unwrap() = Some(format!("{e:#}"));
                        ready.wait();
                        return;
                    }
                };
                install_shard_observer(backend.as_mut(), &metrics);
                metrics.set_plan_info(plan_info(&planner, &plan, &*backend));
                publish_cost_line(&cost_line, &planner, &plan);
                let mut since = 0usize;
                let mut backoff = ProbeBackoff::new();
                while let Ok(batch) = job_rx.recv() {
                    let ok = process_batch(backend.as_ref(), batch, &metrics);
                    if !ok && try_quarantine(backend.as_mut(), &metrics) {
                        backoff.on_quarantine();
                        metrics.set_plan_info(plan_info(&planner, &plan, &*backend));
                    }
                    since += 1;
                    if ctx.every > 0 && since >= ctx.every {
                        since = 0;
                        recalibrate_step(
                            &mut planner,
                            &mut plan,
                            &mut backend,
                            &ctx,
                            &metrics,
                            &mut backoff,
                        );
                        publish_cost_line(&cost_line, &planner, &plan);
                    }
                }
                // shutdown: persist whatever the service learned so the
                // next process plans from measurements immediately
                if let Some(path) = &ctx.calibration_path {
                    let _ = backend::calibrate::save_calibration(
                        path,
                        &planner.calibration_snapshot(),
                    );
                }
            }));
        }
        ready.wait();
        if let Some(e) = init_err.lock().unwrap().take() {
            drop(job_tx);
            drop(ingress_tx);
            for h in worker_handles {
                let _ = h.join();
            }
            return Err(anyhow!("worker init failed: {e}"));
        }
        let plan = chosen.lock().unwrap().take().expect("executor published its plan");

        let batcher_handle =
            spawn_batcher(ingress_rx, job_tx, batcher_cfg(&cfg), cost_line, metrics.clone());
        Ok((
            plan,
            ShapService {
                ingress: ingress_tx,
                batcher_handle: Mutex::new(Some(batcher_handle)),
                worker_handles: Mutex::new(worker_handles),
                metrics,
            },
        ))
    }

    /// THE entry point: submit one typed [`Request`]; returns the
    /// response channel. Every other submit/explain name is a one-line
    /// wrapper over this, and the wire protocol carries this exact
    /// struct. Fails fast with `Rejected` when the ingress queue is
    /// full (backpressure).
    pub fn submit(&self, req: Request) -> Result<Receiver<Response>> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.metrics.record_request(req.rows);
        self.metrics.record_class_request(req.priority, req.rows);
        let queued = Queued { req, resp: tx, submitted: Instant::now() };
        match self.ingress.try_send(Ingress::Req(queued)) {
            Ok(()) => Ok(rx),
            Err(TrySendError::Full(_)) => {
                self.metrics.record_rejected();
                Err(anyhow!("rejected: ingress queue full (backpressure)"))
            }
            Err(TrySendError::Disconnected(_)) => Err(anyhow!("service stopped")),
        }
    }

    /// Blocking convenience over [`ShapService::submit`]: wait for the
    /// response and unwrap its values.
    pub fn run(&self, req: Request) -> Result<Vec<f32>> {
        self.submit(req)?
            .recv()
            .map_err(|_| anyhow!("service dropped response"))?
            .into_values()
    }

    /// Submit rows for a task (wrapper over [`ShapService::submit`]).
    pub fn submit_task(&self, task: Task, x: Vec<f32>, rows: usize) -> Result<Receiver<Response>> {
        self.submit(Request::new(task, x, rows))
    }

    /// Submit an interactions request (wrapper).
    pub fn submit_interactions(&self, x: Vec<f32>, rows: usize) -> Result<Receiver<Response>> {
        self.submit(Request::interactions(x, rows))
    }

    /// Blocking convenience: submit contributions and wait (wrapper).
    pub fn explain(&self, x: Vec<f32>, rows: usize) -> Result<Vec<f32>> {
        self.run(Request::contributions(x, rows))
    }

    /// Blocking convenience: submit interactions and wait (wrapper).
    pub fn explain_interactions(&self, x: Vec<f32>, rows: usize) -> Result<Vec<f32>> {
        self.run(Request::interactions(x, rows))
    }

    /// Graceful shutdown through `&self`: enqueue the shutdown marker,
    /// let the batcher drain every request admitted before it, then
    /// join the threads. Safe to call from multiple holders of an
    /// `Arc<ShapService>` (the first caller joins; later calls no-op),
    /// which is what makes registry-held services — unload, alias
    /// retire on deploy — drainable without consuming the handle.
    /// Requests submitted after the drain see "service stopped".
    pub fn drain(&self) {
        let _ = self.ingress.send(Ingress::Shutdown);
        if let Some(h) = self.batcher_handle.lock().unwrap().take() {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> =
            self.worker_handles.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }

    /// Graceful shutdown, consuming flavor (wrapper over
    /// [`ShapService::drain`] for callers that own the service).
    pub fn shutdown(self) {
        self.drain();
    }
}

/// The plans the executor should try, best first, honoring pinned kind
/// and axis. A pinned kind spans the full device topology (matching the
/// static `backend::build` semantics); auto mode ranks every candidate
/// at its own best layout.
fn desired_plans(planner: &Planner, ctx: &AdaptiveCtx) -> Vec<Plan> {
    let mut plans = match (ctx.pinned_kind, ctx.pinned_axis) {
        (Some(kind), Some(axis)) => {
            planner.plan_pinned(kind, ctx.plan_rows, axis, ctx.devices).into_iter().collect()
        }
        (Some(kind), None) => {
            if ctx.devices > 1 {
                let axis = planner
                    .plan_for(kind, ctx.plan_rows)
                    .map(|p| p.axis)
                    .unwrap_or(ShardAxis::Rows);
                planner.plan_pinned(kind, ctx.plan_rows, axis, ctx.devices).into_iter().collect()
            } else {
                planner.plan_for(kind, ctx.plan_rows).into_iter().collect()
            }
        }
        (None, Some(axis)) => planner.ranked_pinned(ctx.plan_rows, axis, ctx.devices),
        (None, None) => planner.ranked(ctx.plan_rows),
    };
    if plans.is_empty() {
        if let Some(kind) = ctx.pinned_kind {
            // the pinned kind is not a planner candidate (e.g. compiled
            // out): try the build anyway so the caller sees the real
            // construction error instead of "no backend available"
            plans.push(Plan::fallback(kind, ctx.devices, ctx.pinned_axis));
        }
    }
    plans
}

/// Build the backend for one concrete plan (grids route to the grid
/// executor, simple multi-shard plans to `ShardedBackend`).
fn build_plan(
    model: &Arc<Model>,
    bcfg: &BackendConfig,
    plan: &Plan,
) -> Result<Box<dyn ShapBackend>> {
    backend::build_for_plan(model, bcfg, plan)
}

/// Build the best constructible plan, filtering auto-mode candidates
/// that cannot serve the configured interaction pipeline.
fn build_adaptive(
    planner: &Planner,
    ctx: &AdaptiveCtx,
) -> Result<(Plan, Box<dyn ShapBackend>)> {
    let mut last_err = None;
    for plan in desired_plans(planner, ctx) {
        match build_plan(&ctx.model, &ctx.bcfg, &plan) {
            Ok(b) => {
                if ctx.pinned_kind.is_none()
                    && ctx.bcfg.with_interactions
                    && !b.caps().supports_interactions
                {
                    continue;
                }
                return Ok((plan, b));
            }
            Err(e) => last_err = Some(e),
        }
    }
    Err(last_err.unwrap_or_else(|| anyhow!("no backend available")))
}

/// Wire the sharded backend's per-chunk executions into the metrics.
fn install_shard_observer(backend: &mut dyn ShapBackend, metrics: &Arc<Metrics>) {
    let shard_metrics = metrics.clone();
    backend.set_shard_observer(Arc::new(move |shard, rows, dt| {
        shard_metrics.record_shard_batch(shard, rows, dt);
    }));
}

/// After a failed batch: if the backend names failed shards, quarantine
/// them so the survivors keep serving. Returns whether the topology
/// changed.
fn try_quarantine(backend: &mut dyn ShapBackend, metrics: &Metrics) -> bool {
    let failed = backend.failed_shards();
    if failed.is_empty() {
        return false;
    }
    match backend.quarantine(&failed) {
        Ok(removed) if removed > 0 => {
            metrics.record_quarantine(removed);
            if backend.quarantine_remaps_survivors() {
                // survivors kept their identity, only their indices
                // shifted: remap the per-shard windows to the new
                // indices so throughput seeding stays aligned with its
                // devices (clearing them cold-started chunk sizing, and
                // seeding from unshifted keys attributed a dead device's
                // latencies to a survivor). The whole-batch line still
                // changes with the topology, so it is dropped.
                metrics.remap_shards(&failed);
                metrics.reset_backend_samples();
            } else {
                reset_measurement_windows(metrics);
            }
            true
        }
        _ => false,
    }
}

/// Drop the measurement windows after any topology change: shard
/// indices shift (per-shard samples would attribute one device's
/// history to another) and whole-batch latencies measured under the old
/// layout fit a different cost line than the new one.
fn reset_measurement_windows(metrics: &Metrics) {
    metrics.reset_shard_window();
    metrics.reset_backend_samples();
}

/// Exponential backoff for hot-add recovery probes: re-adding a shard
/// whose device still fails costs one live batch per attempt, so each
/// failed probe doubles (up to 16 ticks) the wait before the next one;
/// a probe that survives a full cadence without a quarantine resets the
/// backoff.
struct ProbeBackoff {
    /// ticks left before the next hot-add attempt
    cooldown: usize,
    /// cooldown to apply after the next failed probe
    next: usize,
    /// a quarantine happened since the last tick
    tripped: bool,
    /// a hot-add probe went live on the last tick
    probing: bool,
}

impl ProbeBackoff {
    fn new() -> ProbeBackoff {
        ProbeBackoff { cooldown: 0, next: 1, tripped: false, probing: false }
    }

    fn on_quarantine(&mut self) {
        self.cooldown = self.next;
        if self.probing {
            // the re-added shard failed again: back off harder
            self.next = (self.next * 2).min(16);
            self.probing = false;
        }
        self.tripped = true;
    }

    /// Mark that a hot-add probe actually added shards this tick.
    fn on_probe(&mut self) {
        self.probing = true;
    }

    /// Called once per recalibration tick; returns whether hot-add may
    /// probe this tick.
    fn may_probe(&mut self) -> bool {
        if self.probing && !self.tripped {
            // the last probe survived a full cadence: trust again
            self.next = 1;
            self.probing = false;
        }
        self.tripped = false;
        if self.cooldown > 0 {
            self.cooldown -= 1;
            return false;
        }
        true
    }
}

/// The planner's cost lines are per backend *instance*, but a sharded
/// executor's whole-batch samples measure the sharded line — feeding
/// them to `recalibrate` would divide the parallelism out twice (once
/// in the measurement, once in the planner's layout cost). Remap:
/// unsharded batches calibrate directly; row-axis shard chunks are
/// per-instance executions of the full model, so they pool under the
/// backend's name; tree-axis, grid and feature-tile samples measure
/// sub-ensemble or sub-matrix slices, which fit no per-instance line
/// and are dropped.
fn calibration_observations(
    obs: &crate::backend::Observations,
    plan: &Plan,
) -> crate::backend::Observations {
    let mut out = crate::backend::Observations::new();
    let name = plan.kind.name();
    if plan.shards <= 1 {
        if let Some(samples) = obs.per_backend.get(name) {
            out.per_backend.insert(name.to_string(), samples.clone());
        }
        // first-batch (prep-inclusive) samples calibrate the setup term
        if let Some(firsts) = obs.per_backend_first.get(name) {
            out.per_backend_first.insert(name.to_string(), firsts.clone());
        }
    } else if plan.axis == ShardAxis::Rows {
        let pooled: Vec<(f64, f64)> =
            obs.per_shard.values().flat_map(|v| v.iter().copied()).collect();
        if !pooled.is_empty() {
            out.per_backend.insert(name.to_string(), pooled);
        }
        // sharded first-batch samples measure the sharded line, and
        // shard chunks carry no prep (it is paid at build): drop them
    }
    out
}

/// One tick of the measure→calibrate→plan loop.
fn recalibrate_step(
    planner: &mut Planner,
    plan: &mut Plan,
    backend: &mut Box<dyn ShapBackend>,
    ctx: &AdaptiveCtx,
    metrics: &Arc<Metrics>,
    backoff: &mut ProbeBackoff,
) {
    let obs = metrics.observations();
    let mut changed = planner.recalibrate(&calibration_observations(&obs, plan));
    // when no first-batch (in-band) evidence exists yet, fall back to
    // the construction cost measured at build time so the amortized
    // prep term starts from a real number instead of the a-priori
    // guess. First-batch samples take precedence once they arrive —
    // they observe warmup on the serving path itself, and must not be
    // clobbered by a cache-warm rebuild's near-zero construction time
    if planner.calibration_first_samples(plan.kind) == 0 {
        changed |= planner.observe_setup(plan.kind, backend.caps().setup_cost_s);
    }
    // heterogeneous chunk sizing: seed the executor's per-shard
    // throughput estimates from the recorded per-shard samples
    backend.set_shard_throughputs(&obs.shard_throughputs());
    // persist what the loop learned so a restart plans from it
    if changed {
        if let Some(path) = &ctx.calibration_path {
            let _ = crate::backend::calibrate::save_calibration(
                path,
                &planner.calibration_snapshot(),
            );
        }
    }
    // hot-add recovery: grow a quarantined topology back toward the
    // planned shard count (no-op when already there or unsharded),
    // backing off exponentially while re-added shards keep failing
    if backoff.may_probe() {
        if let Ok(added) = backend.hot_add(plan.shards) {
            if added > 0 {
                backoff.on_probe();
                install_shard_observer(backend.as_mut(), metrics);
                reset_measurement_windows(metrics);
            }
        }
    }
    if changed {
        // walk the (re-priced) ranked plans like the initial build: stop
        // at the current plan (nothing better is constructible), adopt
        // the first candidate that builds and can serve the pipeline
        for want in desired_plans(planner, ctx) {
            // grid dims count as plan identity too: an 8-cell grid can
            // re-factorize (4r×2t → 2r×4t) without changing kind,
            // shard count or axis, and must still be adoptable
            let differs = want.kind != plan.kind
                || want.shards != plan.shards
                || want.axis != plan.axis
                || want.grid != plan.grid;
            if !differs {
                break;
            }
            match build_plan(&ctx.model, &ctx.bcfg, &want) {
                Ok(mut b) => {
                    if ctx.pinned_kind.is_none()
                        && ctx.bcfg.with_interactions
                        && !b.caps().supports_interactions
                    {
                        continue;
                    }
                    install_shard_observer(b.as_mut(), metrics);
                    *backend = b;
                    *plan = want;
                    metrics.record_replan();
                    reset_measurement_windows(metrics);
                    break;
                }
                // unbuildable candidate: try the next ranked plan now,
                // and again next cadence
                Err(_) => continue,
            }
        }
    }
    metrics.set_plan_info(plan_info(planner, plan, &**backend));
}

fn cost_json(c: &CostEstimate) -> Json {
    Json::obj(vec![
        ("setup_s", Json::from(c.setup_s)),
        ("batch_overhead_s", Json::from(c.batch_overhead_s)),
        ("rows_per_s", Json::from(c.rows_per_s)),
    ])
}

/// The executor's current plan + prior-vs-measured planner constants +
/// prepared-model cache state.
fn plan_info(planner: &Planner, plan: &Plan, backend: &dyn ShapBackend) -> Json {
    let mut fields = vec![
        ("backend", Json::from(plan.kind.name())),
        ("shards", Json::from(plan.shards)),
        ("axis", Json::from(plan.axis.name())),
        ("est_latency_s", Json::from(plan.est_latency_s)),
    ];
    if let Some(g) = plan.grid {
        fields.push(("row_shards", Json::from(g.row_shards)));
        fields.push(("tree_shards", Json::from(g.tree_shards)));
    }
    if plan.axis == ShardAxis::FeatureTiles {
        // planned vs live tile count diverge under quarantine; the live
        // ranges themselves are in `describe`
        fields.push(("tile_shards", Json::from(plan.shards)));
        fields.push(("tile_units", Json::from(backend.shard_count())));
    }
    fields.extend(vec![
        ("describe", Json::from(backend.describe())),
        (
            "calibration_samples",
            Json::from(planner.calibration_samples(plan.kind)),
        ),
        (
            "first_batch_samples",
            Json::from(planner.calibration_first_samples(plan.kind)),
        ),
    ]);
    if let Some(prior) = planner.prior(plan.kind) {
        fields.push(("prior", cost_json(&prior)));
    }
    if let Some(cost) = planner.cost(plan.kind) {
        fields.push(("measured", cost_json(&cost)));
    }
    fields.push(("prepared", crate::backend::prepared::registry_snapshot()));
    Json::obj(fields)
}

/// Executor → batcher handoff for the calibrated cost line.
type SharedCost = Arc<Mutex<Option<CostLine>>>;

/// Publish the current plan's calibrated cost line for the batcher's
/// deadline-aware batch formation. The planner's line prices one
/// backend *instance*; a sharded plan divides row work across `shards`
/// of them, so the steady slope scales by the plan's parallel width
/// (exact for the row axis, optimistic for others — an optimistic
/// throughput predicts lower latency and only delays an early close,
/// never the `max_wait` hard cap).
fn publish_cost_line(shared: &SharedCost, planner: &Planner, plan: &Plan) {
    let line = planner.cost(plan.kind).map(|c| CostLine {
        batch_overhead_s: c.batch_overhead_s,
        rows_per_s: c.rows_per_s * plan.shards.max(1) as f64,
    });
    *shared.lock().unwrap() = line;
}

/// Everything the batcher thread needs to form batches: flush policy,
/// per-class scheduling and the optional cross-model pool share.
struct BatcherCfg {
    max_rows: usize,
    max_wait: Duration,
    policies: [ClassPolicy; Class::COUNT],
    share: Option<PoolShare>,
}

fn batcher_cfg(cfg: &ServiceConfig) -> BatcherCfg {
    BatcherCfg {
        max_rows: cfg.max_batch_rows,
        max_wait: cfg.max_wait,
        policies: [
            ClassPolicy { target: cfg.class_targets[0], weight: cfg.class_weights[0] },
            ClassPolicy { target: cfg.class_targets[1], weight: cfg.class_weights[1] },
        ],
        share: cfg.share.clone(),
    }
}

/// The per-class targets in seconds, [`Class::index`]-ordered, for the
/// metrics' SLO-violation accounting.
fn class_targets_secs(cfg: &ServiceConfig) -> [f64; Class::COUNT] {
    [cfg.class_targets[0].as_secs_f64(), cfg.class_targets[1].as_secs_f64()]
}

fn spawn_batcher(
    ingress_rx: Receiver<Ingress>,
    job_tx: SyncSender<Batch>,
    cfg: BatcherCfg,
    cost_line: SharedCost,
    metrics: Arc<Metrics>,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        run_batcher(ingress_rx, job_tx, cfg, cost_line, metrics);
    })
}

fn run_batcher(
    ingress: Receiver<Ingress>,
    job_tx: SyncSender<Batch>,
    cfg: BatcherCfg,
    cost_line: SharedCost,
    metrics: Arc<Metrics>,
) {
    let mk = || Batcher::new(cfg.max_rows, cfg.max_wait).with_policies(cfg.policies);
    let mut batchers: [Batcher<Queued>; 3] = [mk(), mk(), mk()];
    // interactive requests this service currently holds queued —
    // subtracted from the pool-wide gauge so a model never yields
    // bucket capacity to its own interactive traffic
    let mut own_interactive: u64 = 0;
    loop {
        let timeout = if batchers.iter().all(|b| b.is_empty()) {
            Duration::from_millis(50)
        } else {
            cfg.max_wait
        };
        match ingress.recv_timeout(timeout) {
            Ok(Ingress::Req(q)) => {
                let (rows, i) = (q.req.rows, q.req.task.index());
                let class = q.req.priority;
                // the deadline clock starts at submission, not at
                // batcher admission: ingress queueing counts against it
                let deadline =
                    q.req.deadline_ms.map(|ms| q.submitted + Duration::from_millis(ms));
                if class == Class::Interactive {
                    own_interactive += 1;
                    if let Some(s) = &cfg.share {
                        s.pressure.add_interactive(1);
                    }
                }
                batchers[i].push_in(class, rows, deadline, q);
            }
            Ok(Ingress::Shutdown) => break,
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
        let line = *cost_line.lock().unwrap();
        for b in batchers.iter_mut() {
            b.set_cost_line(line);
        }
        for task in Task::ALL {
            while batchers[task.index()].ready(Instant::now()) {
                dispatch(
                    &mut batchers[task.index()],
                    task,
                    &job_tx,
                    &metrics,
                    &cfg.share,
                    &mut own_interactive,
                );
            }
        }
    }
    // drain on shutdown
    for task in Task::ALL {
        while !batchers[task.index()].is_empty() {
            dispatch(
                &mut batchers[task.index()],
                task,
                &job_tx,
                &metrics,
                &cfg.share,
                &mut own_interactive,
            );
        }
    }
}

fn dispatch(
    batcher: &mut Batcher<Queued>,
    task: Task,
    job_tx: &SyncSender<Batch>,
    metrics: &Metrics,
    share: &Option<PoolShare>,
    own_interactive: &mut u64,
) {
    // cross-model fairness: cap the bulk fill at this model's weighted
    // share while another model on the pool has interactive queued
    let fill = match share {
        Some(s) => s.batch_fill(*own_interactive, batcher.max_batch_rows),
        None => batcher.max_batch_rows,
    };
    let pending = batcher.take_batch_capped(fill);
    if pending.is_empty() {
        return;
    }
    let rows: usize = pending.iter().map(|p| p.rows).sum();
    debug_assert!(pending.iter().all(|p| p.rows == p.payload.req.rows));
    let lead = pending[0].class;
    let n_interactive =
        pending.iter().filter(|p| p.class == Class::Interactive).count() as u64;
    if n_interactive > 0 {
        *own_interactive = own_interactive.saturating_sub(n_interactive);
        if let Some(s) = share {
            s.pressure.sub_interactive(n_interactive);
        }
    }
    metrics.record_batch(rows);
    metrics.record_class_batch(lead, rows);
    let batch =
        Batch { task, requests: pending.into_iter().map(|p| p.payload).collect(), rows };
    // blocking send: workers apply backpressure to the batcher
    let _ = job_tx.send(batch);
}

/// Execute one batch and fan responses back out; returns whether the
/// batch succeeded.
fn process_batch(backend: &dyn ShapBackend, batch: Batch, metrics: &Metrics) -> bool {
    let m = backend.num_features();
    let groups = backend.num_groups();
    // concatenate request rows into one backend batch
    let mut x = Vec::with_capacity(batch.rows * m);
    for q in &batch.requests {
        x.extend_from_slice(&q.req.x);
    }
    let t0 = Instant::now();
    let result = match batch.task {
        Task::Contributions => backend.contributions(&x, batch.rows),
        Task::Interactions => backend.interactions(&x, batch.rows),
        Task::Predictions => backend.predictions(&x, batch.rows),
    };
    let stride = batch.task.stride(m, groups);
    match result {
        Ok(all) => {
            metrics.record_backend_batch(backend.name(), batch.rows, t0.elapsed());
            let mut offset = 0;
            for q in batch.requests {
                let vals = all[offset * stride..(offset + q.req.rows) * stride].to_vec();
                offset += q.req.rows;
                let latency = q.submitted.elapsed();
                metrics.record_latency(latency);
                metrics.record_class_latency(q.req.priority, latency, q.req.deadline_ms);
                metrics.record_completed();
                let _ = q.resp.send(Response {
                    task: batch.task,
                    rows: q.req.rows,
                    cols: stride,
                    values: Ok(vals),
                });
            }
            true
        }
        Err(e) => {
            metrics.record_error();
            let msg = format!("{e:#}");
            for q in batch.requests {
                metrics.record_completed();
                let _ = q.resp.send(Response {
                    task: batch.task,
                    rows: q.req.rows,
                    cols: 0,
                    values: Err(anyhow!("{msg}")),
                });
            }
            false
        }
    }
}
