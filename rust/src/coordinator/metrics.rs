//! Serving metrics: counters + latency histogram, lock-light, plus
//! per-backend execution counters (rows served, batches, latency
//! percentiles) so multi-backend deployments can be compared in the
//! service stats output, and per-shard counters (fed by the sharded
//! backend's observer) so multi-device deployments can see how work and
//! tail latency distribute across devices.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::{Json, Stats};

/// Cap on retained per-backend latency samples: a sliding window keeps
/// p50/p99 meaningful at O(1) memory on long-running services.
const LATENCY_WINDOW: usize = 4096;

/// Per-backend execution tallies (batch-granular).
#[derive(Clone, Debug, Default)]
pub struct BackendCounters {
    pub rows: u64,
    pub batches: u64,
    /// per-batch execution latencies, seconds (last `LATENCY_WINDOW`)
    pub latencies: Vec<f64>,
    /// ring cursor once `latencies` is full
    next: usize,
}

impl BackendCounters {
    fn push_latency(&mut self, v: f64) {
        if self.latencies.len() < LATENCY_WINDOW {
            self.latencies.push(v);
        } else {
            self.latencies[self.next] = v;
            self.next = (self.next + 1) % LATENCY_WINDOW;
        }
    }
}

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub rows: AtomicU64,
    pub batches: AtomicU64,
    pub rejected: AtomicU64,
    pub errors: AtomicU64,
    latencies: Mutex<Vec<f64>>,
    batch_sizes: Mutex<Vec<f64>>,
    per_backend: Mutex<BTreeMap<String, BackendCounters>>,
    per_shard: Mutex<BTreeMap<usize, BackendCounters>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_request(&self, rows: usize) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(rows as u64, Ordering::Relaxed);
    }

    pub fn record_batch(&self, rows: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_sizes.lock().unwrap().push(rows as f64);
    }

    pub fn record_latency(&self, d: Duration) {
        self.latencies.lock().unwrap().push(d.as_secs_f64());
    }

    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// One executed batch on the named backend.
    pub fn record_backend_batch(&self, backend: &str, rows: usize, d: Duration) {
        let mut map = self.per_backend.lock().unwrap();
        let c = map.entry(backend.to_string()).or_default();
        c.rows += rows as u64;
        c.batches += 1;
        c.push_latency(d.as_secs_f64());
    }

    /// One executed chunk on device shard `shard` (sharded-backend
    /// observer hook).
    pub fn record_shard_batch(&self, shard: usize, rows: usize, d: Duration) {
        let mut map = self.per_shard.lock().unwrap();
        let c = map.entry(shard).or_default();
        c.rows += rows as u64;
        c.batches += 1;
        c.push_latency(d.as_secs_f64());
    }

    pub fn latency_stats(&self) -> Stats {
        Stats::from_samples(&self.latencies.lock().unwrap())
    }

    pub fn batch_stats(&self) -> Stats {
        Stats::from_samples(&self.batch_sizes.lock().unwrap())
    }

    /// Per-backend counters, cloned out of the lock.
    pub fn backend_counters(&self) -> BTreeMap<String, BackendCounters> {
        self.per_backend.lock().unwrap().clone()
    }

    /// Per-shard counters, cloned out of the lock. Empty unless the
    /// service runs a sharded backend.
    pub fn shard_counters(&self) -> BTreeMap<usize, BackendCounters> {
        self.per_shard.lock().unwrap().clone()
    }

    /// Per-shard stats as JSON: "shardN" → {rows, batches, p50_s, p99_s}.
    pub fn shard_snapshot(&self) -> Json {
        let map = self.shard_counters();
        Json::Obj(
            map.into_iter()
                .map(|(shard, c)| {
                    let lat = Stats::from_samples(&c.latencies);
                    (
                        format!("shard{shard}"),
                        Json::obj(vec![
                            ("rows", Json::from(c.rows as usize)),
                            ("batches", Json::from(c.batches as usize)),
                            ("p50_s", Json::from(lat.p50)),
                            ("p99_s", Json::from(lat.p99)),
                        ]),
                    )
                })
                .collect(),
        )
    }

    /// Per-backend stats as JSON: name → {rows, batches, p50_s, p99_s}.
    pub fn backend_snapshot(&self) -> Json {
        let map = self.backend_counters();
        Json::Obj(
            map.into_iter()
                .map(|(name, c)| {
                    let lat = Stats::from_samples(&c.latencies);
                    (
                        name,
                        Json::obj(vec![
                            ("rows", Json::from(c.rows as usize)),
                            ("batches", Json::from(c.batches as usize)),
                            ("batch_p50_s", Json::from(lat.p50)),
                            ("batch_p99_s", Json::from(lat.p99)),
                        ]),
                    )
                })
                .collect(),
        )
    }

    pub fn snapshot(&self) -> Json {
        let lat = self.latency_stats();
        let bat = self.batch_stats();
        Json::obj(vec![
            ("requests", Json::from(self.requests.load(Ordering::Relaxed) as usize)),
            ("rows", Json::from(self.rows.load(Ordering::Relaxed) as usize)),
            ("batches", Json::from(self.batches.load(Ordering::Relaxed) as usize)),
            ("rejected", Json::from(self.rejected.load(Ordering::Relaxed) as usize)),
            ("errors", Json::from(self.errors.load(Ordering::Relaxed) as usize)),
            ("latency_p50_s", Json::from(lat.p50)),
            ("latency_p95_s", Json::from(lat.p95)),
            ("latency_p99_s", Json::from(lat.p99)),
            ("latency_mean_s", Json::from(lat.mean)),
            ("mean_batch_rows", Json::from(bat.mean)),
            ("backends", self.backend_snapshot()),
            ("shards", self.shard_snapshot()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_request(10);
        m.record_request(5);
        m.record_batch(15);
        m.record_latency(Duration::from_millis(10));
        m.record_latency(Duration::from_millis(30));
        let snap = m.snapshot();
        assert_eq!(snap.get("requests").unwrap().as_usize().unwrap(), 2);
        assert_eq!(snap.get("rows").unwrap().as_usize().unwrap(), 15);
        let p50 = snap.get("latency_p50_s").unwrap().as_f64().unwrap();
        assert!(p50 >= 0.01 && p50 <= 0.03);
    }

    #[test]
    fn per_shard_counters_surface_in_snapshot() {
        let m = Metrics::new();
        // no sharded backend → empty map, still present in the snapshot
        assert!(m.shard_counters().is_empty());
        m.record_shard_batch(0, 32, Duration::from_millis(4));
        m.record_shard_batch(0, 32, Duration::from_millis(6));
        m.record_shard_batch(1, 64, Duration::from_millis(2));
        let counters = m.shard_counters();
        assert_eq!(counters[&0].rows, 64);
        assert_eq!(counters[&0].batches, 2);
        assert_eq!(counters[&1].rows, 64);
        let snap = m.snapshot();
        let shards = snap.get("shards").unwrap();
        assert_eq!(shards.get("shard0").unwrap().get("rows").unwrap().as_usize().unwrap(), 64);
        assert_eq!(
            shards.get("shard1").unwrap().get("batches").unwrap().as_usize().unwrap(),
            1
        );
        let p50 = shards.get("shard0").unwrap().get("p50_s").unwrap().as_f64().unwrap();
        assert!(p50 >= 0.004 && p50 <= 0.006);
        let p99 = shards.get("shard1").unwrap().get("p99_s").unwrap().as_f64().unwrap();
        assert!(p99 >= 0.002);
    }

    #[test]
    fn per_backend_counters_aggregate() {
        let m = Metrics::new();
        m.record_backend_batch("host", 32, Duration::from_millis(4));
        m.record_backend_batch("host", 16, Duration::from_millis(8));
        m.record_backend_batch("xla", 256, Duration::from_millis(2));
        let counters = m.backend_counters();
        assert_eq!(counters["host"].rows, 48);
        assert_eq!(counters["host"].batches, 2);
        assert_eq!(counters["xla"].rows, 256);
        // the latency window is bounded
        for _ in 0..(LATENCY_WINDOW + 100) {
            m.record_backend_batch("host", 1, Duration::from_micros(5));
        }
        assert_eq!(m.backend_counters()["host"].latencies.len(), LATENCY_WINDOW);
        let snap = m.snapshot();
        let be = snap.get("backends").unwrap();
        assert_eq!(be.get("host").unwrap().get("rows").unwrap().as_usize().unwrap(), 48);
        assert_eq!(be.get("xla").unwrap().get("batches").unwrap().as_usize().unwrap(), 1);
        let p99 = be.get("host").unwrap().get("batch_p99_s").unwrap().as_f64().unwrap();
        assert!(p99 >= 0.004);
    }
}
