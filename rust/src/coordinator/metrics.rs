//! Serving metrics: counters + latency histogram, lock-light, plus
//! per-backend execution counters (rows served, batches, latency
//! percentiles) so multi-backend deployments can be compared in the
//! service stats output, and per-shard counters (fed by the sharded
//! backend's observer) so multi-device deployments can see how work and
//! tail latency distribute across devices.
//!
//! Every retained sample set — the global latency/batch-size histograms
//! and the per-backend/per-shard windows — lives in a fixed-capacity
//! ring ([`SAMPLE_WINDOW`]), so a long-running service holds O(1)
//! memory no matter how many batches it serves. The per-backend and
//! per-shard windows also retain paired `(rows, latency)` samples;
//! [`Metrics::observations`] exports them as a
//! [`calibrate::Observations`], the input to the planner's measured
//! cost calibration and the executor's heterogeneous chunk sizing.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::backend::calibrate::Observations;
use crate::coordinator::batcher::Class;
use crate::util::{Json, Stats};

/// Cap on every retained sample window: keeps p50/p99 (and calibration
/// fits) meaningful at O(1) memory on long-running services.
pub const SAMPLE_WINDOW: usize = 4096;

/// A fixed-capacity sliding window: pushes overwrite the oldest sample
/// once `SAMPLE_WINDOW` is reached.
#[derive(Clone, Debug, Default)]
struct Ring<T> {
    buf: Vec<T>,
    /// overwrite cursor once `buf` is full
    next: usize,
}

impl<T: Copy> Ring<T> {
    fn push(&mut self, v: T) {
        if self.buf.len() < SAMPLE_WINDOW {
            self.buf.push(v);
        } else {
            self.buf[self.next] = v;
            self.next = (self.next + 1) % SAMPLE_WINDOW;
        }
    }

    fn as_slice(&self) -> &[T] {
        &self.buf
    }
}

/// Per-backend execution tallies (batch-granular).
#[derive(Clone, Debug)]
pub struct BackendCounters {
    pub rows: u64,
    pub batches: u64,
    /// windowed steady-state `(rows, latency_s)` samples — the latency
    /// percentiles and the per-batch calibration fits read from this
    samples: Ring<(f64, f64)>,
    /// first-batch (prep-inclusive) samples, one per (re)build — kept
    /// off the steady window so warmup never contaminates the fitted
    /// per-batch slope, and exported separately to calibrate `setup_s`
    first: Ring<(f64, f64)>,
    /// the next recorded batch is the first since the last (re)build
    awaiting_first: bool,
}

impl Default for BackendCounters {
    fn default() -> BackendCounters {
        BackendCounters {
            rows: 0,
            batches: 0,
            samples: Ring::default(),
            first: Ring::default(),
            awaiting_first: true,
        }
    }
}

impl BackendCounters {
    /// Record straight onto the steady window — shard chunks use this:
    /// their prep is paid at backend build, so every chunk is steady
    /// state and must feed throughput seeding from the first one.
    fn push_sample(&mut self, rows: usize, latency_s: f64) {
        self.samples.push((rows as f64, latency_s));
    }

    /// Record a whole-backend batch, routing the first one since the
    /// last (re)build onto the first-batch (prep-inclusive) line.
    fn push_batch_sample(&mut self, rows: usize, latency_s: f64) {
        if self.awaiting_first {
            self.awaiting_first = false;
            self.first.push((rows as f64, latency_s));
        } else {
            self.push_sample(rows, latency_s);
        }
    }

    /// The windowed steady-state `(rows, latency_s)` batch samples,
    /// oldest-first order not guaranteed once the window wraps.
    pub fn samples(&self) -> &[(f64, f64)] {
        self.samples.as_slice()
    }

    /// The windowed first-batch (prep-inclusive) samples.
    pub fn first_batch_samples(&self) -> &[(f64, f64)] {
        self.first.as_slice()
    }

    /// The windowed steady-state per-batch latencies, seconds.
    pub fn latencies(&self) -> Vec<f64> {
        self.samples.as_slice().iter().map(|s| s.1).collect()
    }
}

/// Per-priority-class scheduling tallies: request/batch counts plus a
/// windowed latency ring so the scheduler stats can report per-class
/// p50/p99 and SLO violations independently of the global window.
#[derive(Clone, Debug, Default)]
struct ClassCounters {
    requests: u64,
    rows: u64,
    /// batches whose lead (head-of-batch) request was this class
    batches: u64,
    batch_rows: u64,
    /// completed requests whose latency exceeded the effective SLO
    /// (class target tightened by any explicit per-request deadline)
    slo_violations: u64,
    latencies: Ring<f64>,
}

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub rows: AtomicU64,
    pub batches: AtomicU64,
    pub rejected: AtomicU64,
    pub errors: AtomicU64,
    /// requests whose response (ok or error) has been delivered; with
    /// `requests` and `rejected` this derives the in-flight gauge the
    /// registry's drain paths assert on
    pub completed: AtomicU64,
    /// device shards quarantined by the executor after batch failures
    pub quarantines: AtomicU64,
    /// executor backend rebuilds triggered by recalibrated plans
    pub replans: AtomicU64,
    latencies: Mutex<Ring<f64>>,
    batch_sizes: Mutex<Ring<f64>>,
    per_backend: Mutex<BTreeMap<String, BackendCounters>>,
    per_shard: Mutex<BTreeMap<usize, BackendCounters>>,
    /// the executor's current plan + calibration state, for `snapshot`
    plan_info: Mutex<Option<Json>>,
    /// per-priority-class scheduling tallies, [`Class::index`]-ordered
    per_class: Mutex<[ClassCounters; Class::COUNT]>,
    /// per-class latency targets (seconds) the SLO-violation counter
    /// judges against; ≤ 0 disables the class-target check
    class_targets: Mutex<[f64; Class::COUNT]>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_request(&self, rows: usize) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(rows as u64, Ordering::Relaxed);
    }

    pub fn record_batch(&self, rows: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_sizes.lock().unwrap().push(rows as f64);
    }

    pub fn record_latency(&self, d: Duration) {
        self.latencies.lock().unwrap().push(d.as_secs_f64());
    }

    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// One request's response left the executor (ok or error).
    pub fn record_completed(&self) {
        self.completed.fetch_add(1, Ordering::Relaxed);
    }

    /// Accepted requests whose response has not been delivered yet.
    /// Zero after a graceful drain — the registry's alias-swap and
    /// unload paths pin this.
    pub fn in_flight(&self) -> u64 {
        let requests = self.requests.load(Ordering::Relaxed);
        let done = self.completed.load(Ordering::Relaxed)
            + self.rejected.load(Ordering::Relaxed);
        requests.saturating_sub(done)
    }

    pub fn record_quarantine(&self, shards: usize) {
        self.quarantines.fetch_add(shards as u64, Ordering::Relaxed);
    }

    pub fn record_replan(&self) {
        self.replans.fetch_add(1, Ordering::Relaxed);
    }

    /// Publish the executor's current plan/calibration state; surfaces
    /// under `"planner"` in [`Metrics::snapshot`].
    pub fn set_plan_info(&self, info: Json) {
        *self.plan_info.lock().unwrap() = Some(info);
    }

    /// Install the per-class latency targets (seconds) used for SLO
    /// accounting; call once at service start before traffic flows.
    pub fn set_class_targets(&self, targets: [f64; Class::COUNT]) {
        *self.class_targets.lock().unwrap() = targets;
    }

    /// One admitted request of the given class.
    pub fn record_class_request(&self, class: Class, rows: usize) {
        let mut per = self.per_class.lock().unwrap();
        let c = &mut per[class.index()];
        c.requests += 1;
        c.rows += rows as u64;
    }

    /// One dispatched batch, attributed to the class of its lead
    /// (head-of-batch) request — interactive-led batches may still carry
    /// batch-class fill rows, which is the point of the scheduler.
    pub fn record_class_batch(&self, lead: Class, rows: usize) {
        let mut per = self.per_class.lock().unwrap();
        let c = &mut per[lead.index()];
        c.batches += 1;
        c.batch_rows += rows as u64;
    }

    /// One completed request's end-to-end latency, judged against the
    /// class target tightened by any explicit per-request deadline.
    pub fn record_class_latency(&self, class: Class, d: Duration, deadline_ms: Option<u64>) {
        let secs = d.as_secs_f64();
        let target = self.class_targets.lock().unwrap()[class.index()];
        let mut slo = if target > 0.0 { target } else { f64::INFINITY };
        if let Some(ms) = deadline_ms {
            slo = slo.min(ms as f64 / 1e3);
        }
        let mut per = self.per_class.lock().unwrap();
        let c = &mut per[class.index()];
        c.latencies.push(secs);
        if secs > slo {
            c.slo_violations += 1;
        }
    }

    /// Per-class scheduling stats as JSON:
    /// class name → {requests, rows, batches, batch_rows, target_s,
    /// latency_p50_s, latency_p99_s, slo_violations}.
    pub fn scheduler_snapshot(&self) -> Json {
        let per = self.per_class.lock().unwrap().clone();
        let targets = *self.class_targets.lock().unwrap();
        Json::Obj(
            Class::ALL
                .iter()
                .map(|&class| {
                    let c = &per[class.index()];
                    let lat = Stats::from_samples(c.latencies.as_slice());
                    (
                        class.name().to_string(),
                        Json::obj(vec![
                            ("requests", Json::from(c.requests as usize)),
                            ("rows", Json::from(c.rows as usize)),
                            ("batches", Json::from(c.batches as usize)),
                            ("batch_rows", Json::from(c.batch_rows as usize)),
                            ("target_s", Json::from(targets[class.index()])),
                            ("latency_p50_s", Json::from(lat.p50)),
                            ("latency_p99_s", Json::from(lat.p99)),
                            ("slo_violations", Json::from(c.slo_violations as usize)),
                        ]),
                    )
                })
                .collect(),
        )
    }

    /// One executed batch on the named backend.
    pub fn record_backend_batch(&self, backend: &str, rows: usize, d: Duration) {
        let mut map = self.per_backend.lock().unwrap();
        let c = map.entry(backend.to_string()).or_default();
        c.rows += rows as u64;
        c.batches += 1;
        c.push_batch_sample(rows, d.as_secs_f64());
    }

    /// One executed chunk on device shard `shard` (sharded-backend
    /// observer hook).
    pub fn record_shard_batch(&self, shard: usize, rows: usize, d: Duration) {
        let mut map = self.per_shard.lock().unwrap();
        let c = map.entry(shard).or_default();
        c.rows += rows as u64;
        c.batches += 1;
        c.push_sample(rows, d.as_secs_f64());
    }

    pub fn latency_stats(&self) -> Stats {
        Stats::from_samples(self.latencies.lock().unwrap().as_slice())
    }

    pub fn batch_stats(&self) -> Stats {
        Stats::from_samples(self.batch_sizes.lock().unwrap().as_slice())
    }

    /// Per-backend counters, cloned out of the lock.
    pub fn backend_counters(&self) -> BTreeMap<String, BackendCounters> {
        self.per_backend.lock().unwrap().clone()
    }

    /// Per-shard counters, cloned out of the lock. Empty unless the
    /// service runs a sharded backend.
    pub fn shard_counters(&self) -> BTreeMap<usize, BackendCounters> {
        self.per_shard.lock().unwrap().clone()
    }

    /// Drop all per-shard counters. Called by the executor whenever the
    /// shard topology is *rebuilt* (tree-axis quarantine, hot-add,
    /// replan rebuild): shard indices change meaning, so retained
    /// samples would attribute one device's history to another — both
    /// in the stats snapshot and in the throughput seeding derived from
    /// it.
    pub fn reset_shard_window(&self) {
        self.per_shard.lock().unwrap().clear();
    }

    /// Remap the per-shard counters after a quarantine that removed the
    /// given shard indices but kept every survivor's identity (row-axis
    /// and grid-replica quarantines): survivor `i` becomes
    /// `i − |{removed < i}|`, the removed shards' samples are dropped.
    /// Without the remap, throughput seeding read per-shard samples at
    /// their pre-quarantine keys and attributed a dead device's
    /// latencies to whichever survivor inherited its index; keeping the
    /// (shifted) survivor history also means seeding does not
    /// cold-start after every quarantine.
    pub fn remap_shards(&self, removed: &[usize]) {
        let mut map = self.per_shard.lock().unwrap();
        let old = std::mem::take(&mut *map);
        for (idx, c) in old {
            if removed.contains(&idx) {
                continue;
            }
            let shift = removed.iter().filter(|&&r| r < idx).count();
            map.insert(idx - shift, c);
        }
    }

    /// Drop every backend's windowed `(rows, latency)` samples, keeping
    /// the cumulative rows/batches tallies. Called alongside
    /// [`Metrics::reset_shard_window`] on topology changes: whole-batch
    /// latencies measured under the old shard layout fit a different
    /// line than the new layout's, so carrying them into the next
    /// calibration would mis-price it.
    pub fn reset_backend_samples(&self) {
        for c in self.per_backend.lock().unwrap().values_mut() {
            c.samples = Ring::default();
            // the next batch runs on a freshly (re)built backend: it is
            // a first batch again (prep-inclusive, off the steady line)
            c.awaiting_first = true;
        }
    }

    /// Export the windowed per-backend and per-shard `(rows, latency)`
    /// samples as calibration observations — the measure half of the
    /// measure→calibrate→plan loop.
    pub fn observations(&self) -> Observations {
        let mut obs = Observations::new();
        for (name, c) in self.per_backend.lock().unwrap().iter() {
            obs.per_backend.insert(name.clone(), c.samples().to_vec());
            let firsts = c.first_batch_samples();
            if !firsts.is_empty() {
                obs.per_backend_first.insert(name.clone(), firsts.to_vec());
            }
        }
        for (&shard, c) in self.per_shard.lock().unwrap().iter() {
            obs.per_shard.insert(shard, c.samples().to_vec());
        }
        obs
    }

    /// Per-shard stats as JSON: "shardN" → {rows, batches, p50_s, p99_s}.
    pub fn shard_snapshot(&self) -> Json {
        let map = self.shard_counters();
        Json::Obj(
            map.into_iter()
                .map(|(shard, c)| {
                    let lat = Stats::from_samples(&c.latencies());
                    (
                        format!("shard{shard}"),
                        Json::obj(vec![
                            ("rows", Json::from(c.rows as usize)),
                            ("batches", Json::from(c.batches as usize)),
                            ("p50_s", Json::from(lat.p50)),
                            ("p99_s", Json::from(lat.p99)),
                        ]),
                    )
                })
                .collect(),
        )
    }

    /// Per-backend stats as JSON: name → {rows, batches, p50_s, p99_s}.
    pub fn backend_snapshot(&self) -> Json {
        let map = self.backend_counters();
        Json::Obj(
            map.into_iter()
                .map(|(name, c)| {
                    let lat = Stats::from_samples(&c.latencies());
                    (
                        name,
                        Json::obj(vec![
                            ("rows", Json::from(c.rows as usize)),
                            ("batches", Json::from(c.batches as usize)),
                            ("batch_p50_s", Json::from(lat.p50)),
                            ("batch_p99_s", Json::from(lat.p99)),
                        ]),
                    )
                })
                .collect(),
        )
    }

    pub fn snapshot(&self) -> Json {
        let lat = self.latency_stats();
        let bat = self.batch_stats();
        let planner = self.plan_info.lock().unwrap().clone().unwrap_or(Json::Null);
        Json::obj(vec![
            ("requests", Json::from(self.requests.load(Ordering::Relaxed) as usize)),
            ("rows", Json::from(self.rows.load(Ordering::Relaxed) as usize)),
            ("batches", Json::from(self.batches.load(Ordering::Relaxed) as usize)),
            ("rejected", Json::from(self.rejected.load(Ordering::Relaxed) as usize)),
            ("errors", Json::from(self.errors.load(Ordering::Relaxed) as usize)),
            ("in_flight", Json::from(self.in_flight() as usize)),
            ("quarantines", Json::from(self.quarantines.load(Ordering::Relaxed) as usize)),
            ("replans", Json::from(self.replans.load(Ordering::Relaxed) as usize)),
            ("latency_p50_s", Json::from(lat.p50)),
            ("latency_p95_s", Json::from(lat.p95)),
            ("latency_p99_s", Json::from(lat.p99)),
            ("latency_mean_s", Json::from(lat.mean)),
            ("mean_batch_rows", Json::from(bat.mean)),
            ("planner", planner),
            ("scheduler", self.scheduler_snapshot()),
            ("backends", self.backend_snapshot()),
            ("shards", self.shard_snapshot()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_request(10);
        m.record_request(5);
        m.record_batch(15);
        m.record_latency(Duration::from_millis(10));
        m.record_latency(Duration::from_millis(30));
        let snap = m.snapshot();
        assert_eq!(snap.get("requests").unwrap().as_usize().unwrap(), 2);
        assert_eq!(snap.get("rows").unwrap().as_usize().unwrap(), 15);
        let p50 = snap.get("latency_p50_s").unwrap().as_f64().unwrap();
        assert!(p50 >= 0.01 && p50 <= 0.03);
        // no plan published yet → null placeholder, present in the snapshot
        assert_eq!(snap.get("planner").unwrap(), &Json::Null);
        m.set_plan_info(Json::obj(vec![("backend", Json::from("host"))]));
        let snap = m.snapshot();
        assert_eq!(
            snap.get("planner").unwrap().get("backend").unwrap().as_str().unwrap(),
            "host"
        );
    }

    #[test]
    fn global_sample_windows_are_bounded() {
        // regression: the global latency/batch-size vecs grew forever on
        // a long-running service; they get the same ring treatment as
        // the per-backend windows
        let m = Metrics::new();
        for i in 0..(SAMPLE_WINDOW + 500) {
            m.record_batch(1 + i % 7);
            m.record_latency(Duration::from_micros(10 + (i as u64 % 50)));
        }
        assert_eq!(m.latencies.lock().unwrap().as_slice().len(), SAMPLE_WINDOW);
        assert_eq!(m.batch_sizes.lock().unwrap().as_slice().len(), SAMPLE_WINDOW);
        // counters keep exact totals even though samples are windowed
        assert_eq!(
            m.batches.load(Ordering::Relaxed) as usize,
            SAMPLE_WINDOW + 500
        );
        // stats still computable off the window
        assert!(m.latency_stats().p50 > 0.0);
        assert!(m.batch_stats().mean >= 1.0);
    }

    #[test]
    fn per_shard_counters_surface_in_snapshot() {
        let m = Metrics::new();
        // no sharded backend → empty map, still present in the snapshot
        assert!(m.shard_counters().is_empty());
        m.record_shard_batch(0, 32, Duration::from_millis(4));
        m.record_shard_batch(0, 32, Duration::from_millis(6));
        m.record_shard_batch(1, 64, Duration::from_millis(2));
        let counters = m.shard_counters();
        assert_eq!(counters[&0].rows, 64);
        assert_eq!(counters[&0].batches, 2);
        assert_eq!(counters[&1].rows, 64);
        let snap = m.snapshot();
        let shards = snap.get("shards").unwrap();
        assert_eq!(shards.get("shard0").unwrap().get("rows").unwrap().as_usize().unwrap(), 64);
        assert_eq!(
            shards.get("shard1").unwrap().get("batches").unwrap().as_usize().unwrap(),
            1
        );
        let p50 = shards.get("shard0").unwrap().get("p50_s").unwrap().as_f64().unwrap();
        assert!(p50 >= 0.004 && p50 <= 0.006);
        let p99 = shards.get("shard1").unwrap().get("p99_s").unwrap().as_f64().unwrap();
        assert!(p99 >= 0.002);
    }

    #[test]
    fn per_backend_counters_aggregate() {
        let m = Metrics::new();
        m.record_backend_batch("host", 32, Duration::from_millis(4));
        m.record_backend_batch("host", 16, Duration::from_millis(8));
        m.record_backend_batch("xla", 256, Duration::from_millis(2));
        let counters = m.backend_counters();
        assert_eq!(counters["host"].rows, 48);
        assert_eq!(counters["host"].batches, 2);
        assert_eq!(counters["xla"].rows, 256);
        // each backend's first batch lands on the first-batch line, the
        // rest on the steady window
        assert_eq!(counters["host"].first_batch_samples(), &[(32.0, 0.004)]);
        assert_eq!(counters["host"].samples(), &[(16.0, 0.008)]);
        assert_eq!(counters["xla"].first_batch_samples().len(), 1);
        assert!(counters["xla"].samples().is_empty());
        // the steady latency window is bounded
        for _ in 0..(SAMPLE_WINDOW + 100) {
            m.record_backend_batch("host", 1, Duration::from_micros(5));
        }
        assert_eq!(m.backend_counters()["host"].latencies().len(), SAMPLE_WINDOW);
        let snap = m.snapshot();
        let be = snap.get("backends").unwrap();
        let total_rows = 48 + SAMPLE_WINDOW + 100;
        assert_eq!(be.get("host").unwrap().get("rows").unwrap().as_usize().unwrap(), total_rows);
        assert_eq!(be.get("xla").unwrap().get("batches").unwrap().as_usize().unwrap(), 1);
        // the flooded steady window holds only 5µs samples: the 4ms
        // first batch lives on the first-batch line, and the 8ms steady
        // sample was overwritten by the ring wrap — p99 must reflect
        // the window, not the excluded/expired outliers
        let p99 = be.get("host").unwrap().get("batch_p99_s").unwrap().as_f64().unwrap();
        assert!(p99 >= 4e-6 && p99 < 0.004, "{p99}");
    }

    #[test]
    fn topology_resets_drop_windows_but_keep_tallies() {
        let m = Metrics::new();
        m.record_backend_batch("host", 32, Duration::from_millis(4)); // first batch
        m.record_backend_batch("host", 16, Duration::from_millis(2)); // steady
        m.record_shard_batch(0, 16, Duration::from_millis(2));
        m.reset_shard_window();
        m.reset_backend_samples();
        assert!(m.shard_counters().is_empty(), "shard counters drop entirely");
        let host = &m.backend_counters()["host"];
        assert!(host.samples().is_empty(), "backend sample window drops");
        assert_eq!(host.rows, 48, "cumulative tallies survive");
        assert_eq!(host.batches, 2);
        assert!(m.observations().per_backend["host"].is_empty());
        // the reset marks the next batch as a first batch again — a
        // rebuilt backend's warmup goes back onto the first-batch line
        m.record_backend_batch("host", 8, Duration::from_millis(6));
        let host = &m.backend_counters()["host"];
        assert!(host.samples().is_empty(), "post-reset batch is a first batch");
        assert_eq!(host.first_batch_samples().len(), 2, "first-batch window is retained");
    }

    #[test]
    fn remap_shards_shifts_survivors_and_drops_the_dead() {
        // regression (index-aligned seeding): shards 0/1/2 record
        // distinct throughputs; quarantining shard 1 must shift shard
        // 2's history to index 1 — NOT leave it keyed at 2, where the
        // seeding would attribute it to a shard that no longer exists —
        // and must drop the dead shard's samples entirely
        let m = Metrics::new();
        m.record_shard_batch(0, 100, Duration::from_millis(100)); // 1000 rows/s
        m.record_shard_batch(1, 100, Duration::from_millis(10)); // dead: 10000 rows/s
        m.record_shard_batch(2, 100, Duration::from_millis(200)); // 500 rows/s
        m.remap_shards(&[1]);
        let counters = m.shard_counters();
        assert_eq!(counters.len(), 2);
        assert!(counters.contains_key(&0) && counters.contains_key(&1));
        let tputs = m.observations().shard_throughputs();
        assert_eq!(tputs.len(), 2);
        assert!((tputs[0].1 - 1000.0).abs() < 1.0, "shard 0 untouched");
        assert!(
            (tputs[1].1 - 500.0).abs() < 1.0,
            "old shard 2's history now seeds index 1, got {}",
            tputs[1].1
        );
        // removing multiple indices shifts by the count below each key
        let m = Metrics::new();
        for s in 0..5 {
            m.record_shard_batch(s, 10 * (s + 1), Duration::from_millis(10));
        }
        m.remap_shards(&[0, 3]);
        let counters = m.shard_counters();
        assert_eq!(counters.len(), 3);
        assert_eq!(counters[&0].rows, 20, "old 1 → 0");
        assert_eq!(counters[&1].rows, 30, "old 2 → 1");
        assert_eq!(counters[&2].rows, 50, "old 4 → 2");
    }

    #[test]
    fn scheduler_snapshot_splits_classes_and_counts_violations() {
        let m = Metrics::new();
        m.set_class_targets([0.05, 1.0]);
        m.record_class_request(Class::Interactive, 1);
        m.record_class_request(Class::Batch, 100);
        m.record_class_batch(Class::Interactive, 41);
        m.record_class_batch(Class::Batch, 60);
        // interactive: 10ms ok, 80ms breaches the 50ms target
        m.record_class_latency(Class::Interactive, Duration::from_millis(10), None);
        m.record_class_latency(Class::Interactive, Duration::from_millis(80), None);
        // batch: 500ms within the 1s target, but an explicit 200ms
        // deadline tightens the effective SLO
        m.record_class_latency(Class::Batch, Duration::from_millis(500), Some(200));
        let sched = m.scheduler_snapshot();
        let it = sched.get("interactive").unwrap();
        assert_eq!(it.get("requests").unwrap().as_usize().unwrap(), 1);
        assert_eq!(it.get("batches").unwrap().as_usize().unwrap(), 1);
        assert_eq!(it.get("batch_rows").unwrap().as_usize().unwrap(), 41);
        assert_eq!(it.get("slo_violations").unwrap().as_usize().unwrap(), 1);
        assert!((it.get("target_s").unwrap().as_f64().unwrap() - 0.05).abs() < 1e-12);
        let ba = sched.get("batch").unwrap();
        assert_eq!(ba.get("rows").unwrap().as_usize().unwrap(), 100);
        assert_eq!(ba.get("slo_violations").unwrap().as_usize().unwrap(), 1);
        // the full snapshot carries the block under "scheduler"
        let snap = m.snapshot();
        assert!(snap.get("scheduler").unwrap().get("interactive").is_ok());
    }

    #[test]
    fn disabled_class_target_never_violates_without_deadline() {
        let m = Metrics::new();
        m.set_class_targets([0.0, 0.0]);
        m.record_class_latency(Class::Batch, Duration::from_secs(10), None);
        let sched = m.scheduler_snapshot();
        let ba = sched.get("batch").unwrap();
        assert_eq!(ba.get("slo_violations").unwrap().as_usize().unwrap(), 0);
        // an explicit deadline still applies even with the target off
        m.record_class_latency(Class::Batch, Duration::from_secs(10), Some(100));
        let sched = m.scheduler_snapshot();
        let ba = sched.get("batch").unwrap();
        assert_eq!(ba.get("slo_violations").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn observations_export_paired_samples() {
        let m = Metrics::new();
        m.record_backend_batch("host", 64, Duration::from_millis(8)); // first batch
        m.record_backend_batch("host", 128, Duration::from_millis(16));
        m.record_backend_batch("host", 32, Duration::from_millis(4));
        m.record_shard_batch(1, 32, Duration::from_millis(4));
        let obs = m.observations();
        // steady and first-batch samples export on separate lines
        let host = &obs.per_backend["host"];
        assert_eq!(host.len(), 2);
        assert_eq!(host[0].0, 128.0);
        assert!((host[0].1 - 0.016).abs() < 1e-9);
        assert_eq!(host[1].0, 32.0);
        let first = &obs.per_backend_first["host"];
        assert_eq!(first.as_slice(), &[(64.0, 0.008)]);
        let shard = &obs.per_shard[&1];
        assert_eq!(shard.len(), 1);
        assert_eq!(shard[0].0, 32.0);
        // and throughput derivation reads straight off the samples
        let tputs = obs.shard_throughputs();
        assert_eq!(tputs.len(), 1);
        assert!((tputs[0].1 - 32.0 / 0.004).abs() < 1.0);
    }
}
