//! Serving metrics: counters + latency histogram, lock-light.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::{Json, Stats};

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub rows: AtomicU64,
    pub batches: AtomicU64,
    pub rejected: AtomicU64,
    pub errors: AtomicU64,
    latencies: Mutex<Vec<f64>>,
    batch_sizes: Mutex<Vec<f64>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_request(&self, rows: usize) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(rows as u64, Ordering::Relaxed);
    }

    pub fn record_batch(&self, rows: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_sizes.lock().unwrap().push(rows as f64);
    }

    pub fn record_latency(&self, d: Duration) {
        self.latencies.lock().unwrap().push(d.as_secs_f64());
    }

    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn latency_stats(&self) -> Stats {
        Stats::from_samples(&self.latencies.lock().unwrap())
    }

    pub fn batch_stats(&self) -> Stats {
        Stats::from_samples(&self.batch_sizes.lock().unwrap())
    }

    pub fn snapshot(&self) -> Json {
        let lat = self.latency_stats();
        let bat = self.batch_stats();
        Json::obj(vec![
            ("requests", Json::from(self.requests.load(Ordering::Relaxed) as usize)),
            ("rows", Json::from(self.rows.load(Ordering::Relaxed) as usize)),
            ("batches", Json::from(self.batches.load(Ordering::Relaxed) as usize)),
            ("rejected", Json::from(self.rejected.load(Ordering::Relaxed) as usize)),
            ("errors", Json::from(self.errors.load(Ordering::Relaxed) as usize)),
            ("latency_p50_s", Json::from(lat.p50)),
            ("latency_p95_s", Json::from(lat.p95)),
            ("latency_mean_s", Json::from(lat.mean)),
            ("mean_batch_rows", Json::from(bat.mean)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_request(10);
        m.record_request(5);
        m.record_batch(15);
        m.record_latency(Duration::from_millis(10));
        m.record_latency(Duration::from_millis(30));
        let snap = m.snapshot();
        assert_eq!(snap.get("requests").unwrap().as_usize().unwrap(), 2);
        assert_eq!(snap.get("rows").unwrap().as_usize().unwrap(), 15);
        let p50 = snap.get("latency_p50_s").unwrap().as_f64().unwrap();
        assert!(p50 >= 0.01 && p50 <= 0.03);
    }
}
