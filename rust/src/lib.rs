//! # GPUTreeShap (reproduction)
//!
//! A three-layer Rust + JAX + Pallas reproduction of *GPUTreeShap:
//! Massively Parallel Exact Calculation of SHAP Scores for Tree
//! Ensembles* (Mitchell, Frank, Holmes, 2020).
//!
//! - **L1/L2** (build time, `python/`): the SHAP dynamic program as a
//!   Pallas kernel inside JAX graphs, AOT-lowered to HLO artifacts.
//! - **L3** (this crate): everything on the request path — GBDT model
//!   substrate, path extraction + duplicate merging, bin packing, the
//!   CPU TreeShap baseline, the PJRT runtime executing the artifacts,
//!   a batching/serving coordinator with a multi-model registry, and a
//!   std-only TCP ingress speaking length-prefixed JSON frames.
//!
//! See `DESIGN.md` for the architecture and `EXPERIMENTS.md` for the
//! paper-vs-measured evaluation.

pub mod backend;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod gbdt;
pub mod ingress;
pub mod parallel;
pub mod runtime;
pub mod shap;
pub mod util;
