//! Benchmark harness substrate (no `criterion` offline): warmup + timed
//! iterations with summary stats, aligned table printing matching the
//! paper's table layouts, JSON dumps for EXPERIMENTS.md, and the
//! machine-readable report + comparison machinery behind CI's
//! perf-tracking job (`--json` on fig4/fig5, `bench-compare` in the
//! CLI).

pub mod compare;
pub mod zoo;

use crate::util::{Json, Stats};

/// Run `f` `warmup` times untimed, then `iters` times timed.
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = std::time::Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed().as_secs_f64());
    }
    Stats::from_samples(&samples)
}

/// Fixed-width table printer (paper-style rows).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!("{:<width$}  ", c, width = w));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", widths.iter().map(|w| "-".repeat(*w + 2)).collect::<String>());
        for row in &self.rows {
            line(row);
        }
    }
}

/// Append one benchmark record to `bench_results.jsonl` (cwd).
pub fn dump_record(bench_name: &str, fields: Vec<(&str, Json)>) {
    let mut all = vec![("bench", Json::from(bench_name))];
    all.extend(fields);
    let rec = Json::obj(all);
    let mut line = String::new();
    line.push_str(&rec.to_string_pretty().replace('\n', " "));
    line.push('\n');
    use std::io::Write;
    if let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open("bench_results.jsonl")
    {
        let _ = f.write_all(line.as_bytes());
    }
}

/// Merge `value` under `key` into the JSON report object at `path`,
/// creating the file when absent. Several benches write into one report
/// (fig4 + fig5 → `BENCH_pr.json` in CI), each under its own key; an
/// unparseable existing file is replaced rather than appended to.
pub fn write_json_report(
    path: &std::path::Path,
    key: &str,
    value: Json,
) -> crate::util::error::Result<()> {
    let mut root = match std::fs::read_to_string(path) {
        Ok(text) => Json::parse(&text).unwrap_or(Json::Obj(Default::default())),
        Err(_) => Json::Obj(Default::default()),
    };
    if !matches!(root, Json::Obj(_)) {
        root = Json::Obj(Default::default());
    }
    if let Json::Obj(map) = &mut root {
        map.insert(key.to_string(), value);
    }
    std::fs::write(path, root.to_string_pretty())
        .map_err(|e| crate::anyhow!("writing report {}: {e}", path.display()))
}

/// A `{min, median}` variance band over repeated measurements of one
/// metric (ROADMAP "perf baseline variance bands"). Reports written
/// with bands let `bench-compare` gate the current *median* against the
/// baseline *min* — runner noise widens the band instead of flaking the
/// gate, so the tolerance can stay tight. Non-finite samples are
/// dropped; an empty sample set collapses to zeros (ignored by the
/// comparison, which skips non-positive baselines).
pub fn band_json(samples: &[f64]) -> Json {
    let mut v: Vec<f64> = samples.iter().copied().filter(|s| s.is_finite()).collect();
    v.sort_by(f64::total_cmp);
    let min = v.first().copied().unwrap_or(0.0);
    let median = if v.is_empty() { 0.0 } else { v[v.len() / 2] };
    Json::obj(vec![("min", Json::from(min)), ("median", Json::from(median))])
}

/// Format seconds with sensible precision.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_stats() {
        let s = bench(1, 5, || 2 + 2);
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["model", "time"]);
        t.row(vec!["covtype-small".into(), "0.1s".into()]);
        t.print(); // smoke: no panic
    }

    #[test]
    fn band_json_orders_and_guards() {
        let b = band_json(&[300.0, 100.0, 200.0]);
        assert_eq!(b.get("min").unwrap().as_f64().unwrap(), 100.0);
        assert_eq!(b.get("median").unwrap().as_f64().unwrap(), 200.0);
        // non-finite samples are dropped, empties collapse to zero
        let b = band_json(&[f64::NAN, 50.0]);
        assert_eq!(b.get("min").unwrap().as_f64().unwrap(), 50.0);
        let b = band_json(&[]);
        assert_eq!(b.get("median").unwrap().as_f64().unwrap(), 0.0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(1e-5).ends_with("us"));
        assert!(fmt_secs(0.01).ends_with("ms"));
        assert!(fmt_secs(2.0).ends_with('s'));
    }
}
