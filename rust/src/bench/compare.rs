//! Benchmark report comparison — the decision logic behind CI's
//! perf-tracking job. Two JSON reports (as written by
//! [`super::write_json_report`]) are walked in parallel; every shared
//! **throughput** metric (a numeric leaf whose key contains
//! `rows_per_s`, higher is better) is compared, and a metric counts as
//! a regression when the current value falls more than `tolerance`
//! below the baseline.
//!
//! Only throughput leaves are compared: absolute latencies vary with
//! machine load far more than sustained rows/s, and throughput is the
//! quantity the prepared-model cache is supposed to protect. Throughput
//! metrics present on one side only don't gate, but they are *reported*
//! — `new_metrics` (current only: a bench grew a config, e.g. the
//! linear-backend fig4 curves) and `dropped_metrics` (baseline only: a
//! config disappeared) — so the perf job log shows coverage changes
//! instead of silently ignoring them until the next baseline refresh.
//!
//! A throughput metric may be a plain number or a `{min, median}`
//! **variance band** over repeated runs (`bench::band_json`, ROADMAP
//! "perf baseline variance bands"). Bands gate the current *median*
//! against the baseline *min* — the most forgiving reading of the
//! baseline's own noise — so the tolerance can tighten without flaking
//! on runner variance. Plain numbers are one-sample bands, and the two
//! forms compare against each other, so a baseline written before a
//! bench grew bands keeps gating.

use crate::util::Json;

/// A throughput reading: `min == median` for plain numeric leaves.
#[derive(Clone, Copy, Debug)]
struct Band {
    min: f64,
    median: f64,
}

/// Read a throughput leaf as a band: a number, or an object carrying
/// numeric `min` and `median`. Anything else is not a leaf (e.g. a
/// `steady_rows_per_s: {cpu, accel}` grouping) and keeps recursing.
fn band_of(j: &Json) -> Option<Band> {
    match j {
        Json::Num(v) => Some(Band { min: *v, median: *v }),
        Json::Obj(map) => match (map.get("min"), map.get("median")) {
            (Some(Json::Num(min)), Some(Json::Num(median))) => {
                Some(Band { min: *min, median: *median })
            }
            _ => None,
        },
        _ => None,
    }
}

/// One metric whose current value regressed past the tolerance.
#[derive(Clone, Debug, PartialEq)]
pub struct Regression {
    /// dotted path to the metric inside the report
    pub metric: String,
    pub baseline: f64,
    pub current: f64,
}

impl Regression {
    /// Fractional drop vs the baseline (0.25 = 25% slower).
    pub fn drop_fraction(&self) -> f64 {
        if self.baseline <= 0.0 {
            return 0.0;
        }
        1.0 - self.current / self.baseline
    }
}

/// Outcome of a report comparison.
#[derive(Clone, Debug, Default)]
pub struct Comparison {
    /// throughput metrics present in both reports
    pub compared: usize,
    pub regressions: Vec<Regression>,
    /// throughput metrics present only in the current report (a bench
    /// grew a config); visible but not gating
    pub new_metrics: Vec<String>,
    /// throughput metrics present only in the baseline (a config
    /// disappeared); visible but not gating
    pub dropped_metrics: Vec<String>,
}

impl Comparison {
    pub fn is_pass(&self) -> bool {
        self.regressions.is_empty()
    }
}

/// Compare `current` against `baseline` with the given fractional
/// `tolerance` (0.2 ⇒ fail on >20% throughput drop).
pub fn compare_reports(baseline: &Json, current: &Json, tolerance: f64) -> Comparison {
    let mut out = Comparison::default();
    walk(baseline, current, "", tolerance, &mut out);
    out.regressions.sort_by(|a, b| b.drop_fraction().total_cmp(&a.drop_fraction()));
    out.new_metrics.sort();
    out.dropped_metrics.sort();
    out
}

fn is_throughput_key(path: &str) -> bool {
    // a `rows_per_s` anywhere on the path marks the subtree as
    // throughput (covers both `accel_rows_per_s` leaves and
    // `steady_rows_per_s: {cpu, accel}` groupings)
    path.contains("rows_per_s")
}

/// Collect the dotted paths of every throughput leaf under `j` into
/// `out` — used for subtrees present on only one side of the
/// comparison, where there is nothing to compare against but the
/// coverage change should still be visible.
fn collect_throughput(j: &Json, path: &str, out: &mut Vec<String>) {
    if is_throughput_key(path) && band_of(j).is_some() {
        out.push(path.to_string());
        return;
    }
    match j {
        Json::Obj(map) => {
            for (k, v) in map {
                let sub = if path.is_empty() { k.clone() } else { format!("{path}.{k}") };
                collect_throughput(v, &sub, out);
            }
        }
        Json::Arr(items) => {
            for (i, v) in items.iter().enumerate() {
                collect_throughput(v, &format!("{path}[{i}]"), out);
            }
        }
        _ => {}
    }
}

fn walk(base: &Json, cur: &Json, path: &str, tolerance: f64, out: &mut Comparison) {
    if is_throughput_key(path) {
        // leaf comparison first (numbers and {min, median} bands, in
        // any combination) — band objects must not recurse, or their
        // min/median members would be compared as two separate metrics
        if let (Some(b), Some(c)) = (band_of(base), band_of(cur)) {
            if b.min.is_finite() && c.median.is_finite() && b.min > 0.0 {
                out.compared += 1;
                if c.median < b.min * (1.0 - tolerance) {
                    out.regressions.push(Regression {
                        metric: path.to_string(),
                        baseline: b.min,
                        current: c.median,
                    });
                }
            }
            return;
        }
    }
    match (base, cur) {
        (Json::Obj(b), Json::Obj(c)) => {
            for (k, bv) in b {
                let sub = if path.is_empty() { k.clone() } else { format!("{path}.{k}") };
                match c.get(k) {
                    Some(cv) => walk(bv, cv, &sub, tolerance, out),
                    None => collect_throughput(bv, &sub, &mut out.dropped_metrics),
                }
            }
            for (k, cv) in c {
                if !b.contains_key(k) {
                    let sub = if path.is_empty() { k.clone() } else { format!("{path}.{k}") };
                    collect_throughput(cv, &sub, &mut out.new_metrics);
                }
            }
        }
        (Json::Arr(b), Json::Arr(c)) => {
            // compare by index up to the shorter side; reports written
            // at different sweep lengths overlap on their common prefix
            for (i, (bv, cv)) in b.iter().zip(c).enumerate() {
                walk(bv, cv, &format!("{path}[{i}]"), tolerance, out);
            }
            // the longer side's tail is a coverage change, not a gate
            for (i, bv) in b.iter().enumerate().skip(c.len()) {
                collect_throughput(bv, &format!("{path}[{i}]"), &mut out.dropped_metrics);
            }
            for (i, cv) in c.iter().enumerate().skip(b.len()) {
                collect_throughput(cv, &format!("{path}[{i}]"), &mut out.new_metrics);
            }
        }
        _ => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(cpu: f64, accel: f64) -> Json {
        Json::parse(&format!(
            r#"{{"fig4": {{
                "steady_rows_per_s": {{"cpu": {cpu}, "accel": {accel}}},
                "prep": {{"accel_s": 0.01}},
                "steady": [{{"rows": 16, "cpu_s": 0.001, "accel_rows_per_s": {accel}}}]
            }}}}"#
        ))
        .unwrap()
    }

    #[test]
    fn equal_reports_pass_and_count_metrics() {
        let a = report(1000.0, 5000.0);
        let cmp = compare_reports(&a, &a, 0.2);
        assert!(cmp.is_pass());
        // cpu + accel under steady_rows_per_s, plus the array entry
        assert_eq!(cmp.compared, 3);
    }

    #[test]
    fn regression_beyond_tolerance_fails() {
        let base = report(1000.0, 5000.0);
        // accel throughput drops 40%, cpu improves
        let cur = report(1200.0, 3000.0);
        let cmp = compare_reports(&base, &cur, 0.2);
        assert!(!cmp.is_pass());
        assert_eq!(cmp.regressions.len(), 2, "both accel leaves regressed");
        assert!(cmp.regressions[0].metric.contains("accel"));
        assert!((cmp.regressions[0].drop_fraction() - 0.4).abs() < 1e-9);
        // a 19% drop stays within the 20% tolerance
        let cur = report(1000.0, 4050.0);
        assert!(compare_reports(&base, &cur, 0.2).is_pass());
    }

    #[test]
    fn disjoint_or_non_throughput_metrics_are_ignored() {
        let base = Json::parse(r#"{"fig5": {"best_rows_per_s": 100.0, "time_s": 9.0}}"#).unwrap();
        // different shape entirely: nothing shared → pass, 0 compared
        let cur = Json::parse(r#"{"fig4": {"steady_rows_per_s": {"cpu": 1.0}}}"#).unwrap();
        let cmp = compare_reports(&base, &cur, 0.2);
        assert!(cmp.is_pass());
        assert_eq!(cmp.compared, 0);
        // latency-like keys never compare, even when they worsen
        let slow = Json::parse(r#"{"fig5": {"best_rows_per_s": 100.0, "time_s": 90.0}}"#).unwrap();
        let base2 = Json::parse(r#"{"fig5": {"best_rows_per_s": 100.0, "time_s": 9.0}}"#).unwrap();
        let cmp = compare_reports(&base2, &slow, 0.2);
        assert_eq!(cmp.compared, 1, "only the throughput leaf compares");
        assert!(cmp.is_pass());
    }

    #[test]
    fn variance_bands_gate_current_median_against_baseline_min() {
        // band vs band: the gate reads baseline.min and current.median
        let base = Json::parse(r#"{"s": {"rows_per_s": {"min": 800.0, "median": 1000.0}}}"#)
            .unwrap();
        let ok = Json::parse(r#"{"s": {"rows_per_s": {"min": 100.0, "median": 700.0}}}"#)
            .unwrap();
        let cmp = compare_reports(&base, &ok, 0.2);
        assert_eq!(cmp.compared, 1, "a band is ONE metric, not two");
        assert!(cmp.is_pass(), "median 700 ≥ min 800 × 0.8 = 640");
        let bad = Json::parse(r#"{"s": {"rows_per_s": {"min": 100.0, "median": 600.0}}}"#)
            .unwrap();
        let cmp = compare_reports(&base, &bad, 0.2);
        assert!(!cmp.is_pass(), "median 600 < 640");
        assert_eq!(cmp.regressions[0].baseline, 800.0);
        assert_eq!(cmp.regressions[0].current, 600.0);
        // mixed forms stay comparable: a pre-band scalar baseline gates
        // a banded current report, and vice versa
        let scalar_base = Json::parse(r#"{"s": {"rows_per_s": 1000.0}}"#).unwrap();
        let cmp = compare_reports(&scalar_base, &ok, 0.2);
        assert_eq!(cmp.compared, 1);
        assert!(!cmp.is_pass(), "median 700 < scalar 1000 × 0.8");
        let cmp = compare_reports(&base, &scalar_base, 0.2);
        assert_eq!(cmp.compared, 1);
        assert!(cmp.is_pass(), "scalar 1000 ≥ min 800 × 0.8");
    }

    #[test]
    fn one_sided_configs_surface_as_new_and_dropped() {
        // the linear backend lands: current grows configs the baseline
        // doesn't know — they must be visible, not silently skipped
        let base = Json::parse(
            r#"{"fig4": {"steady_rows_per_s": {"cpu": 1000.0, "accel": 5000.0},
                         "legacy_rows_per_s": 42.0}}"#,
        )
        .unwrap();
        let cur = Json::parse(
            r#"{"fig4": {"steady_rows_per_s": {"cpu": 1000.0, "accel": 5000.0,
                                               "linear": 8000.0},
                         "depth_sweep": [{"depth": 6, "linear_rows_per_s": 9000.0}]}}"#,
        )
        .unwrap();
        let cmp = compare_reports(&base, &cur, 0.2);
        assert!(cmp.is_pass(), "new/dropped configs never gate");
        assert_eq!(cmp.compared, 2, "only cpu+accel exist on both sides");
        assert_eq!(
            cmp.new_metrics,
            vec![
                "fig4.depth_sweep[0].linear_rows_per_s".to_string(),
                "fig4.steady_rows_per_s.linear".to_string(),
            ]
        );
        assert_eq!(cmp.dropped_metrics, vec!["fig4.legacy_rows_per_s".to_string()]);
        // bands count as one leaf on the one-sided paths too
        let banded = Json::parse(r#"{"g_rows_per_s": {"min": 1.0, "median": 2.0}}"#).unwrap();
        let cmp = compare_reports(&Json::parse("{}").unwrap(), &banded, 0.2);
        assert_eq!(cmp.new_metrics, vec!["g_rows_per_s".to_string()]);
    }

    #[test]
    fn array_tails_surface_as_new_and_dropped() {
        let base = Json::parse(
            r#"{"s": [{"rows_per_s": 100.0}, {"rows_per_s": 200.0}, {"rows_per_s": 300.0}]}"#,
        )
        .unwrap();
        let cur = Json::parse(r#"{"s": [{"rows_per_s": 100.0}]}"#).unwrap();
        let cmp = compare_reports(&base, &cur, 0.2);
        assert_eq!(cmp.compared, 1);
        assert_eq!(
            cmp.dropped_metrics,
            vec!["s[1].rows_per_s".to_string(), "s[2].rows_per_s".to_string()]
        );
        let cmp = compare_reports(&cur, &base, 0.2);
        assert_eq!(
            cmp.new_metrics,
            vec!["s[1].rows_per_s".to_string(), "s[2].rows_per_s".to_string()]
        );
        assert!(cmp.dropped_metrics.is_empty());
    }

    #[test]
    fn arrays_compare_on_common_prefix() {
        let base = Json::parse(
            r#"{"s": [{"rows_per_s": 100.0}, {"rows_per_s": 200.0}, {"rows_per_s": 300.0}]}"#,
        )
        .unwrap();
        let cur = Json::parse(r#"{"s": [{"rows_per_s": 100.0}, {"rows_per_s": 50.0}]}"#).unwrap();
        let cmp = compare_reports(&base, &cur, 0.2);
        assert_eq!(cmp.compared, 2, "third entry has no counterpart");
        assert_eq!(cmp.regressions.len(), 1);
        assert_eq!(cmp.regressions[0].metric, "s[1].rows_per_s");
    }
}
