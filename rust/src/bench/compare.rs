//! Benchmark report comparison — the decision logic behind CI's
//! perf-tracking job. Two JSON reports (as written by
//! [`super::write_json_report`]) are walked in parallel; every shared
//! **throughput** metric (a numeric leaf whose key contains
//! `rows_per_s`, higher is better) is compared, and a metric counts as
//! a regression when the current value falls more than `tolerance`
//! below the baseline.
//!
//! Only throughput leaves are compared: absolute latencies vary with
//! machine load far more than sustained rows/s, and throughput is the
//! quantity the prepared-model cache is supposed to protect. Metrics
//! present on one side only are ignored (benches evolve; the baseline
//! refresh on main catches the report shape up).
//!
//! A throughput metric may be a plain number or a `{min, median}`
//! **variance band** over repeated runs (`bench::band_json`, ROADMAP
//! "perf baseline variance bands"). Bands gate the current *median*
//! against the baseline *min* — the most forgiving reading of the
//! baseline's own noise — so the tolerance can tighten without flaking
//! on runner variance. Plain numbers are one-sample bands, and the two
//! forms compare against each other, so a baseline written before a
//! bench grew bands keeps gating.

use crate::util::Json;

/// A throughput reading: `min == median` for plain numeric leaves.
#[derive(Clone, Copy, Debug)]
struct Band {
    min: f64,
    median: f64,
}

/// Read a throughput leaf as a band: a number, or an object carrying
/// numeric `min` and `median`. Anything else is not a leaf (e.g. a
/// `steady_rows_per_s: {cpu, accel}` grouping) and keeps recursing.
fn band_of(j: &Json) -> Option<Band> {
    match j {
        Json::Num(v) => Some(Band { min: *v, median: *v }),
        Json::Obj(map) => match (map.get("min"), map.get("median")) {
            (Some(Json::Num(min)), Some(Json::Num(median))) => {
                Some(Band { min: *min, median: *median })
            }
            _ => None,
        },
        _ => None,
    }
}

/// One metric whose current value regressed past the tolerance.
#[derive(Clone, Debug, PartialEq)]
pub struct Regression {
    /// dotted path to the metric inside the report
    pub metric: String,
    pub baseline: f64,
    pub current: f64,
}

impl Regression {
    /// Fractional drop vs the baseline (0.25 = 25% slower).
    pub fn drop_fraction(&self) -> f64 {
        if self.baseline <= 0.0 {
            return 0.0;
        }
        1.0 - self.current / self.baseline
    }
}

/// Outcome of a report comparison.
#[derive(Clone, Debug, Default)]
pub struct Comparison {
    /// throughput metrics present in both reports
    pub compared: usize,
    pub regressions: Vec<Regression>,
}

impl Comparison {
    pub fn is_pass(&self) -> bool {
        self.regressions.is_empty()
    }
}

/// Compare `current` against `baseline` with the given fractional
/// `tolerance` (0.2 ⇒ fail on >20% throughput drop).
pub fn compare_reports(baseline: &Json, current: &Json, tolerance: f64) -> Comparison {
    let mut out = Comparison::default();
    walk(baseline, current, "", tolerance, &mut out);
    out.regressions.sort_by(|a, b| b.drop_fraction().total_cmp(&a.drop_fraction()));
    out
}

fn is_throughput_key(path: &str) -> bool {
    // a `rows_per_s` anywhere on the path marks the subtree as
    // throughput (covers both `accel_rows_per_s` leaves and
    // `steady_rows_per_s: {cpu, accel}` groupings)
    path.contains("rows_per_s")
}

fn walk(base: &Json, cur: &Json, path: &str, tolerance: f64, out: &mut Comparison) {
    if is_throughput_key(path) {
        // leaf comparison first (numbers and {min, median} bands, in
        // any combination) — band objects must not recurse, or their
        // min/median members would be compared as two separate metrics
        if let (Some(b), Some(c)) = (band_of(base), band_of(cur)) {
            if b.min.is_finite() && c.median.is_finite() && b.min > 0.0 {
                out.compared += 1;
                if c.median < b.min * (1.0 - tolerance) {
                    out.regressions.push(Regression {
                        metric: path.to_string(),
                        baseline: b.min,
                        current: c.median,
                    });
                }
            }
            return;
        }
    }
    match (base, cur) {
        (Json::Obj(b), Json::Obj(c)) => {
            for (k, bv) in b {
                if let Some(cv) = c.get(k) {
                    let sub = if path.is_empty() { k.clone() } else { format!("{path}.{k}") };
                    walk(bv, cv, &sub, tolerance, out);
                }
            }
        }
        (Json::Arr(b), Json::Arr(c)) => {
            // compare by index up to the shorter side; reports written
            // at different sweep lengths overlap on their common prefix
            for (i, (bv, cv)) in b.iter().zip(c).enumerate() {
                walk(bv, cv, &format!("{path}[{i}]"), tolerance, out);
            }
        }
        _ => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(cpu: f64, accel: f64) -> Json {
        Json::parse(&format!(
            r#"{{"fig4": {{
                "steady_rows_per_s": {{"cpu": {cpu}, "accel": {accel}}},
                "prep": {{"accel_s": 0.01}},
                "steady": [{{"rows": 16, "cpu_s": 0.001, "accel_rows_per_s": {accel}}}]
            }}}}"#
        ))
        .unwrap()
    }

    #[test]
    fn equal_reports_pass_and_count_metrics() {
        let a = report(1000.0, 5000.0);
        let cmp = compare_reports(&a, &a, 0.2);
        assert!(cmp.is_pass());
        // cpu + accel under steady_rows_per_s, plus the array entry
        assert_eq!(cmp.compared, 3);
    }

    #[test]
    fn regression_beyond_tolerance_fails() {
        let base = report(1000.0, 5000.0);
        // accel throughput drops 40%, cpu improves
        let cur = report(1200.0, 3000.0);
        let cmp = compare_reports(&base, &cur, 0.2);
        assert!(!cmp.is_pass());
        assert_eq!(cmp.regressions.len(), 2, "both accel leaves regressed");
        assert!(cmp.regressions[0].metric.contains("accel"));
        assert!((cmp.regressions[0].drop_fraction() - 0.4).abs() < 1e-9);
        // a 19% drop stays within the 20% tolerance
        let cur = report(1000.0, 4050.0);
        assert!(compare_reports(&base, &cur, 0.2).is_pass());
    }

    #[test]
    fn disjoint_or_non_throughput_metrics_are_ignored() {
        let base = Json::parse(r#"{"fig5": {"best_rows_per_s": 100.0, "time_s": 9.0}}"#).unwrap();
        // different shape entirely: nothing shared → pass, 0 compared
        let cur = Json::parse(r#"{"fig4": {"steady_rows_per_s": {"cpu": 1.0}}}"#).unwrap();
        let cmp = compare_reports(&base, &cur, 0.2);
        assert!(cmp.is_pass());
        assert_eq!(cmp.compared, 0);
        // latency-like keys never compare, even when they worsen
        let slow = Json::parse(r#"{"fig5": {"best_rows_per_s": 100.0, "time_s": 90.0}}"#).unwrap();
        let base2 = Json::parse(r#"{"fig5": {"best_rows_per_s": 100.0, "time_s": 9.0}}"#).unwrap();
        let cmp = compare_reports(&base2, &slow, 0.2);
        assert_eq!(cmp.compared, 1, "only the throughput leaf compares");
        assert!(cmp.is_pass());
    }

    #[test]
    fn variance_bands_gate_current_median_against_baseline_min() {
        // band vs band: the gate reads baseline.min and current.median
        let base = Json::parse(r#"{"s": {"rows_per_s": {"min": 800.0, "median": 1000.0}}}"#)
            .unwrap();
        let ok = Json::parse(r#"{"s": {"rows_per_s": {"min": 100.0, "median": 700.0}}}"#)
            .unwrap();
        let cmp = compare_reports(&base, &ok, 0.2);
        assert_eq!(cmp.compared, 1, "a band is ONE metric, not two");
        assert!(cmp.is_pass(), "median 700 ≥ min 800 × 0.8 = 640");
        let bad = Json::parse(r#"{"s": {"rows_per_s": {"min": 100.0, "median": 600.0}}}"#)
            .unwrap();
        let cmp = compare_reports(&base, &bad, 0.2);
        assert!(!cmp.is_pass(), "median 600 < 640");
        assert_eq!(cmp.regressions[0].baseline, 800.0);
        assert_eq!(cmp.regressions[0].current, 600.0);
        // mixed forms stay comparable: a pre-band scalar baseline gates
        // a banded current report, and vice versa
        let scalar_base = Json::parse(r#"{"s": {"rows_per_s": 1000.0}}"#).unwrap();
        let cmp = compare_reports(&scalar_base, &ok, 0.2);
        assert_eq!(cmp.compared, 1);
        assert!(!cmp.is_pass(), "median 700 < scalar 1000 × 0.8");
        let cmp = compare_reports(&base, &scalar_base, 0.2);
        assert_eq!(cmp.compared, 1);
        assert!(cmp.is_pass(), "scalar 1000 ≥ min 800 × 0.8");
    }

    #[test]
    fn arrays_compare_on_common_prefix() {
        let base = Json::parse(
            r#"{"s": [{"rows_per_s": 100.0}, {"rows_per_s": 200.0}, {"rows_per_s": 300.0}]}"#,
        )
        .unwrap();
        let cur = Json::parse(r#"{"s": [{"rows_per_s": 100.0}, {"rows_per_s": 50.0}]}"#).unwrap();
        let cmp = compare_reports(&base, &cur, 0.2);
        assert_eq!(cmp.compared, 2, "third entry has no counterpart");
        assert_eq!(cmp.regressions.len(), 1);
        assert_eq!(cmp.regressions[0].metric, "s[1].rows_per_s");
    }
}
