//! The benchmark model zoo (Table 3, scaled): small/medium/large GBDTs
//! per dataset, trained once and cached on disk under `target/zoo/`.
//!
//! Scale substitutions vs the paper (DESIGN.md §5): training rows are
//! scaled down so the zoo builds in minutes on one core, and `large`
//! uses 100 rounds instead of 1000. The (depth, dataset-shape) grid —
//! which drives path lengths, packing behaviour, and the interaction
//! complexity gap — matches the paper.

use std::path::PathBuf;

use crate::data::{Dataset, SynthSpec};
use crate::gbdt::{io, train, Model, TrainParams, ZooSize};

/// One zoo entry: dataset spec + size variant.
#[derive(Clone, Debug)]
pub struct ZooEntry {
    pub name: String,
    pub spec: SynthSpec,
    pub size: ZooSize,
}

/// The 12-model grid of Table 3 (4 datasets × 3 sizes), bench-scaled.
pub fn zoo_entries() -> Vec<ZooEntry> {
    let mut out = Vec::new();
    let data_scales: &[(fn(f64) -> SynthSpec, f64)] = &[
        (SynthSpec::covtype as fn(f64) -> SynthSpec, 0.002),
        (SynthSpec::cal_housing, 0.02),
        (SynthSpec::fashion_mnist, 0.002),
        (SynthSpec::adult, 0.01),
    ];
    for (make, scale) in data_scales {
        for size in [ZooSize::Small, ZooSize::Medium, ZooSize::Large] {
            let spec = make(*scale);
            out.push(ZooEntry {
                name: format!("{}-{}", spec.name, size.name()),
                spec,
                size,
            });
        }
    }
    out
}

/// A reduced-feature fashion_mnist stand-in for interaction benches:
/// the XLA interaction buckets cap at M=128 because the output matrix is
/// (M+1)² per row (784 would need 2.5 MB/row). The paper's qualitative
/// claim — the O(TLD³) reformulation wins big when M ≫ D — is exercised
/// at M=96 just as well.
pub fn fashion96(scale: f64) -> SynthSpec {
    let mut s = SynthSpec::fashion_mnist(scale);
    s.name = "fashion_mnist96";
    s.cols = 96;
    s
}

fn zoo_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("target/zoo")
}

/// Train (or load cached) model + return its dataset.
pub fn build(entry: &ZooEntry) -> (Model, Dataset) {
    let data = entry.spec.generate();
    let (rounds, depth) = entry.size.rounds_depth();
    let dir = zoo_dir();
    std::fs::create_dir_all(&dir).ok();
    let path = dir.join(format!("{}.gtsm", entry.name));
    if let Ok(model) = io::load(&path) {
        return (model, data);
    }
    let model = train(
        &data,
        &TrainParams { rounds, max_depth: depth, ..Default::default() },
    );
    io::save(&model, &path).ok();
    (model, data)
}

/// Build a model for an arbitrary spec with explicit (rounds, depth),
/// cached under `name`.
pub fn build_custom(name: &str, spec: &SynthSpec, rounds: usize, depth: usize) -> (Model, Dataset) {
    let data = spec.generate();
    let dir = zoo_dir();
    std::fs::create_dir_all(&dir).ok();
    let path = dir.join(format!("{name}.gtsm"));
    if let Ok(model) = io::load(&path) {
        return (model, data);
    }
    let model = train(
        &data,
        &TrainParams { rounds, max_depth: depth, ..Default::default() },
    );
    io::save(&model, &path).ok();
    (model, data)
}
