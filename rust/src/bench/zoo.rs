//! The benchmark model zoo (Table 3, scaled): small/medium/large GBDTs
//! per dataset, trained once and cached on disk under `target/zoo/`.
//!
//! Scale substitutions vs the paper (DESIGN.md §5): training rows are
//! scaled down so the zoo builds in minutes on one core, and `large`
//! uses 100 rounds instead of 1000. The (depth, dataset-shape) grid —
//! which drives path lengths, packing behaviour, and the interaction
//! complexity gap — matches the paper.

use std::path::PathBuf;

use crate::data::{Dataset, SynthSpec};
use crate::gbdt::{io, train, Model, Objective, TrainParams, Tree, ZooSize};

/// One zoo entry: dataset spec + size variant.
#[derive(Clone, Debug)]
pub struct ZooEntry {
    pub name: String,
    pub spec: SynthSpec,
    pub size: ZooSize,
}

/// The 12-model grid of Table 3 (4 datasets × 3 sizes), bench-scaled.
pub fn zoo_entries() -> Vec<ZooEntry> {
    let mut out = Vec::new();
    let data_scales: &[(fn(f64) -> SynthSpec, f64)] = &[
        (SynthSpec::covtype as fn(f64) -> SynthSpec, 0.002),
        (SynthSpec::cal_housing, 0.02),
        (SynthSpec::fashion_mnist, 0.002),
        (SynthSpec::adult, 0.01),
    ];
    for (make, scale) in data_scales {
        for size in [ZooSize::Small, ZooSize::Medium, ZooSize::Large] {
            let spec = make(*scale);
            out.push(ZooEntry {
                name: format!("{}-{}", spec.name, size.name()),
                spec,
                size,
            });
        }
    }
    out
}

/// Hand-built ensemble where one feature appears **multiple times on a
/// single root→leaf path** — the case the trained zoo rarely produces
/// but every kernel must merge correctly (the recursive algorithm's
/// duplicate-merge, the packed layouts' path merge, and Linear
/// TreeShap's telescoping add/subtract terms). Tree 1 repeats `f0`
/// twice on two different paths; tree 2 splits on `f0` three times down
/// one spine. Covers are consistent (parent = Σ children) so the
/// cover-ratio probabilities are well-formed.
pub fn repeated_feature_model() -> Model {
    // tree 1:        f0 < 0.0            (100)
    //              /          \
    //        f1 < 0.5 (60)   f0 < 2.0 (40)   ← f0 again, right path
    //        /       \         /     \
    //  f0 < -1.0(25) leaf(35) leaf(30) leaf(10)  ← f0 again, left path
    //    /    \
    // leaf(10) leaf(15)
    let mut t1 = Tree::new();
    for _ in 0..9 {
        t1.add_node();
    }
    let set_split = |t: &mut Tree, i: usize, f: i32, thr: f32, l: usize, r: usize, cov: f32| {
        t.feature[i] = f;
        t.threshold[i] = thr;
        t.left[i] = l as i32;
        t.right[i] = r as i32;
        t.cover[i] = cov;
    };
    let set_leaf = |t: &mut Tree, i: usize, v: f32, cov: f32| {
        t.value[i] = v;
        t.cover[i] = cov;
    };
    set_split(&mut t1, 0, 0, 0.0, 1, 2, 100.0);
    set_split(&mut t1, 1, 1, 0.5, 3, 4, 60.0);
    set_split(&mut t1, 2, 0, 2.0, 5, 6, 40.0);
    set_split(&mut t1, 3, 0, -1.0, 7, 8, 25.0);
    set_leaf(&mut t1, 4, -0.7, 35.0);
    set_leaf(&mut t1, 5, 1.3, 30.0);
    set_leaf(&mut t1, 6, 2.1, 10.0);
    set_leaf(&mut t1, 7, -1.8, 10.0);
    set_leaf(&mut t1, 8, 0.4, 15.0);
    // tree 2: a spine of three f0 splits on one root→leaf path
    //   f0 < 1.0 (80) → f0 < 0.0 (50) → f0 < -1.0 (30) → leaves
    let mut t2 = Tree::new();
    for _ in 0..7 {
        t2.add_node();
    }
    set_split(&mut t2, 0, 0, 1.0, 1, 2, 80.0);
    set_split(&mut t2, 1, 0, 0.0, 3, 4, 50.0);
    set_split(&mut t2, 3, 0, -1.0, 5, 6, 30.0);
    set_leaf(&mut t2, 2, 0.9, 30.0);
    set_leaf(&mut t2, 4, -0.3, 20.0);
    set_leaf(&mut t2, 5, -1.1, 12.0);
    set_leaf(&mut t2, 6, 0.6, 18.0);
    Model {
        trees: vec![t1, t2],
        tree_group: vec![0, 0],
        num_groups: 1,
        num_features: 2,
        base_score: 0.5,
        objective: Objective::SquaredError,
    }
}

/// A reduced-feature fashion_mnist stand-in for interaction benches:
/// the XLA interaction buckets cap at M=128 because the output matrix is
/// (M+1)² per row (784 would need 2.5 MB/row). The paper's qualitative
/// claim — the O(TLD³) reformulation wins big when M ≫ D — is exercised
/// at M=96 just as well.
pub fn fashion96(scale: f64) -> SynthSpec {
    let mut s = SynthSpec::fashion_mnist(scale);
    s.name = "fashion_mnist96";
    s.cols = 96;
    s
}

/// Arbitrary-width fashion_mnist stand-in for the wide-model (`M ≫ D`)
/// interaction sweeps — the feature-tile shard axis is priced by how
/// many conditioned columns each device owns, so its benches vary `M`
/// while holding the ensemble fixed. `fashion96` is `fashion_wide(96)`
/// with the historical cache name kept stable.
pub fn fashion_wide(cols: usize, scale: f64) -> SynthSpec {
    let mut s = SynthSpec::fashion_mnist(scale);
    s.name = "fashion_mnist_wide";
    s.cols = cols;
    s
}

fn zoo_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("target/zoo")
}

/// Train (or load cached) model + return its dataset.
pub fn build(entry: &ZooEntry) -> (Model, Dataset) {
    let data = entry.spec.generate();
    let (rounds, depth) = entry.size.rounds_depth();
    let dir = zoo_dir();
    std::fs::create_dir_all(&dir).ok();
    let path = dir.join(format!("{}.gtsm", entry.name));
    if let Ok(model) = io::load(&path) {
        return (model, data);
    }
    let model = train(
        &data,
        &TrainParams { rounds, max_depth: depth, ..Default::default() },
    );
    io::save(&model, &path).ok();
    (model, data)
}

/// Build a model for an arbitrary spec with explicit (rounds, depth),
/// cached under `name`.
pub fn build_custom(name: &str, spec: &SynthSpec, rounds: usize, depth: usize) -> (Model, Dataset) {
    let data = spec.generate();
    let dir = zoo_dir();
    std::fs::create_dir_all(&dir).ok();
    let path = dir.join(format!("{name}.gtsm"));
    if let Ok(model) = io::load(&path) {
        return (model, data);
    }
    let model = train(
        &data,
        &TrainParams { rounds, max_depth: depth, ..Default::default() },
    );
    io::save(&model, &path).ok();
    (model, data)
}
