//! Minimal data-parallel substrate (no `rayon` offline).
//!
//! Work-stealing-lite: a shared atomic cursor hands out fixed-size chunks
//! of the index range to scoped worker threads, which keeps load balanced
//! even when per-item cost varies wildly (deep vs shallow decision-tree
//! paths — exactly the imbalance §3 of the paper describes for warps).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use when the caller does not care.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Run `f(start..end)` over chunks of `0..total` on `threads` threads.
///
/// `f` must be safe to call concurrently on disjoint ranges.
pub fn parallel_for_chunks<F>(threads: usize, total: usize, chunk: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1);
    let chunk = chunk.max(1);
    if threads == 1 || total <= chunk {
        let mut s = 0;
        while s < total {
            let e = (s + chunk).min(total);
            f(s..e);
            s = e;
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let s = cursor.fetch_add(chunk, Ordering::Relaxed);
                if s >= total {
                    break;
                }
                let e = (s + chunk).min(total);
                f(s..e);
            });
        }
    });
}

/// Parallel map over `0..total`, writing into a preallocated output via a
/// per-index closure. The closure gets (index, &mut slot).
pub fn parallel_fill<T, F>(threads: usize, out: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let total = out.len();
    let base = out.as_mut_ptr() as usize;
    let f = &f;
    parallel_for_chunks(threads, total, chunk, move |range| {
        // Disjoint ranges => exclusive access to these slots.
        for i in range {
            let slot = unsafe { &mut *(base as *mut T).add(i) };
            f(i, slot);
        }
    });
}

/// Map each index to a value, collecting results in order.
pub fn parallel_map<T, F>(threads: usize, total: usize, chunk: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); total];
    parallel_fill(threads, &mut out, chunk, |i, slot| *slot = f(i));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_all_indices_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_for_chunks(8, 1000, 7, |range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_thread_path() {
        let hits: Vec<AtomicU64> = (0..57).map(|_| AtomicU64::new(0)).collect();
        parallel_for_chunks(1, 57, 10, |range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_map_order() {
        let v = parallel_map(4, 100, 3, |i| i * i);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn empty_total() {
        parallel_for_chunks(4, 0, 8, |_| panic!("should not run"));
        let v: Vec<usize> = parallel_map(4, 0, 8, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn parallel_fill_disjoint() {
        let mut out = vec![0usize; 513];
        parallel_fill(8, &mut out, 5, |i, s| *s = i + 1);
        for (i, x) in out.iter().enumerate() {
            assert_eq!(*x, i + 1);
        }
    }
}
