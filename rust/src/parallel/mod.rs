//! Minimal data-parallel substrate (no `rayon` offline).
//!
//! Work-stealing-lite: a shared atomic cursor hands out fixed-size chunks
//! of the index range to scoped worker threads, which keeps load balanced
//! even when per-item cost varies wildly (deep vs shallow decision-tree
//! paths — exactly the imbalance §3 of the paper describes for warps).
//!
//! Output-writing helpers are safe by construction: the output slice is
//! pre-split with `chunks_mut` into disjoint sub-slices, each wrapped in
//! its own (uncontended) `Mutex`; a worker claims a chunk index from the
//! cursor and locks exactly that chunk, so no two threads can ever hold
//! overlapping `&mut` views. No raw pointers, no `unsafe`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use when the caller does not care.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Run `f(start..end)` over chunks of `0..total` on `threads` threads.
///
/// `f` must be safe to call concurrently on disjoint ranges.
pub fn parallel_for_chunks<F>(threads: usize, total: usize, chunk: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1);
    let chunk = chunk.max(1);
    if threads == 1 || total <= chunk {
        let mut s = 0;
        while s < total {
            let e = (s + chunk).min(total);
            f(s..e);
            s = e;
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let s = cursor.fetch_add(chunk, Ordering::Relaxed);
                if s >= total {
                    break;
                }
                let e = (s + chunk).min(total);
                f(s..e);
            });
        }
    });
}

/// Parallel fill of a row-major output: `out` is viewed as
/// `out.len() / stride` logical rows of `stride` elements, and
/// `f(rows, chunk)` receives a row range plus the exclusive sub-slice
/// holding exactly those rows (`chunk.len() == rows.len() * stride`).
///
/// The chunking is static (`rows_per_chunk` rows each) but assignment is
/// dynamic via an atomic cursor, so imbalanced rows still load-balance.
pub fn parallel_for_rows<T, F>(threads: usize, out: &mut [T], stride: usize, rows_per_chunk: usize, f: F)
where
    T: Send,
    F: Fn(std::ops::Range<usize>, &mut [T]) + Sync,
{
    let stride = stride.max(1);
    let rows_per_chunk = rows_per_chunk.max(1);
    let total_rows = out.len() / stride;
    debug_assert_eq!(out.len(), total_rows * stride, "out not a whole number of rows");
    let threads = threads.max(1);
    if threads == 1 || total_rows <= rows_per_chunk {
        let mut r = 0;
        while r < total_rows {
            let e = (r + rows_per_chunk).min(total_rows);
            f(r..e, &mut out[r * stride..e * stride]);
            r = e;
        }
        return;
    }
    // Disjoint &mut sub-slices, one lock each. Every chunk is claimed by
    // exactly one thread (cursor), so locks never contend.
    let chunks: Vec<Mutex<&mut [T]>> =
        out.chunks_mut(rows_per_chunk * stride).map(Mutex::new).collect();
    let num_chunks = chunks.len();
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads.min(num_chunks) {
            scope.spawn(|| loop {
                let ci = cursor.fetch_add(1, Ordering::Relaxed);
                if ci >= num_chunks {
                    break;
                }
                let mut guard = chunks[ci].lock().unwrap();
                let r0 = ci * rows_per_chunk;
                let r1 = (r0 + rows_per_chunk).min(total_rows);
                f(r0..r1, &mut **guard);
            });
        }
    });
}

/// Parallel map over `0..total`, writing into a preallocated output via a
/// per-index closure. The closure gets (index, &mut slot).
pub fn parallel_fill<T, F>(threads: usize, out: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let f = &f;
    parallel_for_rows(threads, out, 1, chunk, move |range, slots| {
        for (k, slot) in slots.iter_mut().enumerate() {
            f(range.start + k, slot);
        }
    });
}

/// Map each index to a value, collecting results in order.
pub fn parallel_map<T, F>(threads: usize, total: usize, chunk: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); total];
    parallel_fill(threads, &mut out, chunk, |i, slot| *slot = f(i));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_all_indices_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_for_chunks(8, 1000, 7, |range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_thread_path() {
        let hits: Vec<AtomicU64> = (0..57).map(|_| AtomicU64::new(0)).collect();
        parallel_for_chunks(1, 57, 10, |range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_map_order() {
        let v = parallel_map(4, 100, 3, |i| i * i);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn empty_total() {
        parallel_for_chunks(4, 0, 8, |_| panic!("should not run"));
        let v: Vec<usize> = parallel_map(4, 0, 8, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn parallel_fill_disjoint() {
        let mut out = vec![0usize; 513];
        parallel_fill(8, &mut out, 5, |i, s| *s = i + 1);
        for (i, x) in out.iter().enumerate() {
            assert_eq!(*x, i + 1);
        }
    }

    #[test]
    fn parallel_fill_writes_each_slot_exactly_once() {
        // count closure invocations per index: overlapping chunk hand-out
        // would double-invoke; a dropped chunk would zero-invoke
        let n = 777;
        let calls: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let mut out = vec![0u8; n];
        parallel_fill(6, &mut out, 13, |i, s| {
            calls[i].fetch_add(1, Ordering::Relaxed);
            *s = 1;
        });
        assert!(calls.iter().all(|c| c.load(Ordering::Relaxed) == 1));
        assert!(out.iter().all(|&b| b == 1));
    }

    #[test]
    fn parallel_for_rows_partitions_exactly() {
        let stride = 7;
        let rows = 101;
        let mut out = vec![0usize; rows * stride];
        parallel_for_rows(5, &mut out, stride, 4, |range, chunk| {
            assert_eq!(chunk.len(), range.len() * stride);
            for (k, r) in range.enumerate() {
                for c in 0..stride {
                    chunk[k * stride + c] = r * stride + c + 1;
                }
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i + 1);
        }
    }

    #[test]
    fn parallel_for_rows_single_row() {
        let mut out = vec![0u32; 16];
        parallel_for_rows(4, &mut out, 16, 8, |range, chunk| {
            assert_eq!(range, 0..1);
            chunk.iter_mut().for_each(|v| *v = 9);
        });
        assert!(out.iter().all(|&v| v == 9));
    }
}
