//! Histogram-based gradient boosting trainer (XGBoost-style substrate).
//!
//! Depth-wise growth with exact row partitioning, second-order gain
//!   gain = ½·[G_L²/(H_L+λ) + G_R²/(H_R+λ) − G²/(H+λ)] − γ
//! and leaf weight −G/(H+λ)·η. Cover (Σ hessian) is recorded per node —
//! TreeShap's missing-branch probabilities come from it.

use crate::data::Dataset;
use crate::gbdt::histogram::{build_histograms, BinnedMatrix, GradPair};
use crate::gbdt::loss::Objective;
use crate::gbdt::tree::Tree;
use crate::gbdt::Model;
use crate::parallel;

#[derive(Clone, Debug)]
pub struct TrainParams {
    pub rounds: usize,
    pub max_depth: usize,
    pub learning_rate: f32,
    pub reg_lambda: f64,
    pub gamma: f64,
    pub min_child_weight: f64,
    pub max_bins: usize,
    pub threads: usize,
}

impl Default for TrainParams {
    fn default() -> Self {
        TrainParams {
            rounds: 10,
            max_depth: 6,
            // the paper uses 0.01 to keep trees non-trivial across rounds
            learning_rate: 0.01,
            reg_lambda: 1.0,
            gamma: 0.0,
            min_child_weight: 1.0,
            max_bins: 64,
            threads: parallel::default_threads(),
        }
    }
}

struct SplitChoice {
    feature: usize,
    bin: usize, // split at cuts[bin]: left iff value-bin < bin+1 … see below
    gain: f64,
    left: GradPair,
    right: GradPair,
}

/// One boosting ensemble trained on a dataset.
pub fn train(data: &Dataset, params: &TrainParams) -> Model {
    let objective = match data.num_classes {
        0 => Objective::SquaredError,
        2 => Objective::Logistic,
        k => Objective::Softmax(k),
    };
    let groups = objective.num_groups();
    let binned = BinnedMatrix::build(data, params.max_bins, params.threads);

    let rows = data.rows;
    let mut scores = vec![0.0f32; rows * groups];
    let mut grad = vec![0.0f32; rows];
    let mut hess = vec![0.0f32; rows];
    let mut trees = Vec::with_capacity(params.rounds * groups);
    let mut tree_group = Vec::with_capacity(params.rounds * groups);

    for _round in 0..params.rounds {
        for k in 0..groups {
            objective.grad_hess(&scores, &data.labels, k, &mut grad, &mut hess);
            let tree = grow_tree(&binned, &grad, &hess, params);
            // update raw scores for group k
            parallel::parallel_for_rows(params.threads, &mut scores, groups, 512, |range, chunk| {
                for (i, r) in range.enumerate() {
                    chunk[i * groups + k] += tree.predict_row(data.row(r));
                }
            });
            trees.push(tree);
            tree_group.push(k);
        }
    }

    Model {
        trees,
        tree_group,
        num_groups: groups,
        num_features: data.cols,
        base_score: 0.0,
        objective,
    }
}

fn grow_tree(binned: &BinnedMatrix, grad: &[f32], hess: &[f32], params: &TrainParams) -> Tree {
    let mut tree = Tree::new();
    let root_rows: Vec<u32> = (0..binned.rows as u32).collect();
    let total = root_rows.iter().fold(GradPair::default(), |mut acc, &r| {
        acc.add(grad[r as usize] as f64, hess[r as usize] as f64);
        acc
    });
    let root = tree.add_node();
    grow_node(&mut tree, root, root_rows, total, 0, binned, grad, hess, params);
    tree
}

#[allow(clippy::too_many_arguments)]
fn grow_node(
    tree: &mut Tree,
    node: usize,
    rows: Vec<u32>,
    // Σ(g, h) over `rows`, carried from the parent's split statistics so
    // each node avoids an O(rows) rescan
    total: GradPair,
    depth: usize,
    binned: &BinnedMatrix,
    grad: &[f32],
    hess: &[f32],
    params: &TrainParams,
) {
    tree.cover[node] = total.h as f32;

    let make_leaf = |tree: &mut Tree, node: usize| {
        tree.value[node] =
            (-total.g / (total.h + params.reg_lambda)) as f32 * params.learning_rate;
    };

    if depth >= params.max_depth || total.h < 2.0 * params.min_child_weight {
        make_leaf(tree, node);
        return;
    }

    let hist = build_histograms(binned, &rows, grad, hess, params.threads);
    let best = find_best_split(&hist, &total, params);
    let Some(best) = best else {
        make_leaf(tree, node);
        return;
    };

    // partition rows: left iff bin ≤ best.bin (split threshold = cuts[best.bin])
    let mut left_rows = Vec::with_capacity(rows.len() / 2);
    let mut right_rows = Vec::with_capacity(rows.len() / 2);
    for &r in &rows {
        if binned.bin(r as usize, best.feature) as usize <= best.bin {
            left_rows.push(r);
        } else {
            right_rows.push(r);
        }
    }
    drop(rows);
    debug_assert!(!left_rows.is_empty() && !right_rows.is_empty());

    let l = tree.add_node();
    let r = tree.add_node();
    tree.feature[node] = best.feature as i32;
    tree.threshold[node] = binned.cuts[best.feature][best.bin];
    tree.left[node] = l as i32;
    tree.right[node] = r as i32;

    grow_node(tree, l, left_rows, best.left, depth + 1, binned, grad, hess, params);
    grow_node(tree, r, right_rows, best.right, depth + 1, binned, grad, hess, params);
}

fn find_best_split(
    hist: &[Vec<GradPair>],
    total: &GradPair,
    params: &TrainParams,
) -> Option<SplitChoice> {
    let lam = params.reg_lambda;
    let parent_score = total.g * total.g / (total.h + lam);
    let mut best: Option<SplitChoice> = None;
    for (f, hf) in hist.iter().enumerate() {
        if hf.len() < 2 {
            continue;
        }
        let mut left = GradPair::default();
        // candidate split after bin b (i.e. threshold = cuts[b]) for b in 0..bins-1
        for b in 0..hf.len() - 1 {
            left.add(hf[b].g, hf[b].h);
            let right = total.sub(&left);
            if left.h < params.min_child_weight || right.h < params.min_child_weight {
                continue;
            }
            let gain = 0.5
                * (left.g * left.g / (left.h + lam)
                    + right.g * right.g / (right.h + lam)
                    - parent_score)
                - params.gamma;
            if gain > best.as_ref().map_or(1e-9, |s| s.gain) {
                best = Some(SplitChoice { feature: f, bin: b, gain, left, right });
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;

    #[test]
    fn learns_simple_regression() {
        // y = x0 clipped — one feature carries everything
        let mut d = Dataset::new("t", 400, 3, 0);
        let mut rng = crate::util::Rng::new(1);
        for r in 0..400 {
            for c in 0..3 {
                d.set(r, c, rng.normal() as f32);
            }
            d.labels[r] = if d.get(r, 0) > 0.0 { 1.0 } else { -1.0 };
        }
        let params = TrainParams {
            rounds: 50,
            max_depth: 3,
            learning_rate: 0.3,
            ..Default::default()
        };
        let model = train(&d, &params);
        let mut mse = 0.0;
        for r in 0..d.rows {
            let p = model.predict_row_raw(d.row(r))[0];
            mse += (p - d.labels[r]).powi(2) as f64;
        }
        mse /= d.rows as f64;
        assert!(mse < 0.1, "mse {mse}");
    }

    #[test]
    fn trains_multiclass_with_group_per_tree() {
        let d = SynthSpec::covtype(0.001).generate();
        let params = TrainParams { rounds: 3, max_depth: 3, ..Default::default() };
        let model = train(&d, &params);
        assert_eq!(model.num_groups, 8);
        assert_eq!(model.trees.len(), 3 * 8);
        assert_eq!(model.tree_group[..8], [0, 1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn depth_is_bounded() {
        let d = SynthSpec::cal_housing(0.02).generate();
        let params = TrainParams { rounds: 4, max_depth: 4, ..Default::default() };
        let model = train(&d, &params);
        assert!(model.trees.iter().all(|t| t.max_depth() <= 4));
        assert!(model.trees.iter().any(|t| t.max_depth() >= 2), "trees too shallow");
    }

    #[test]
    fn cover_decreases_down_the_tree() {
        let d = SynthSpec::adult(0.01).generate();
        let params = TrainParams { rounds: 2, max_depth: 5, ..Default::default() };
        let model = train(&d, &params);
        for t in &model.trees {
            for i in 0..t.num_nodes() {
                if !t.is_leaf(i) {
                    let (l, r) = (t.left[i] as usize, t.right[i] as usize);
                    let sum = t.cover[l] + t.cover[r];
                    assert!((sum - t.cover[i]).abs() / t.cover[i].max(1.0) < 1e-3);
                    assert!(t.cover[l] > 0.0 && t.cover[r] > 0.0);
                }
            }
        }
    }

    #[test]
    fn boosting_reduces_logistic_loss() {
        let d = SynthSpec::adult(0.01).generate();
        let loss_of = |model: &Model| {
            let mut total = 0.0f64;
            for r in 0..d.rows {
                let p = crate::gbdt::loss::sigmoid(model.predict_row_raw(d.row(r))[0]) as f64;
                let y = d.labels[r] as f64;
                total -= y * p.max(1e-9).ln() + (1.0 - y) * (1.0 - p).max(1e-9).ln();
            }
            total / d.rows as f64
        };
        let small = train(&d, &TrainParams { rounds: 2, learning_rate: 0.1, ..Default::default() });
        let big = train(&d, &TrainParams { rounds: 30, learning_rate: 0.1, ..Default::default() });
        assert!(loss_of(&big) < loss_of(&small), "boosting did not help");
    }
}
