//! Gradient-boosted decision tree substrate (XGBoost stand-in).
//!
//! The paper evaluates GPUTreeShap on XGBoost ensembles; this module
//! provides the equivalent model producer: a histogram-based trainer
//! with squared-error / logistic / softmax objectives, per-node cover
//! statistics (needed by TreeShap's missing-feature weighting), binary
//! model serialization, and the model zoo of Table 3
//! (small/medium/large per dataset).

pub mod histogram;
pub mod io;
pub mod loss;
pub mod trainer;
pub mod xgb_import;
pub mod tree;

pub use loss::Objective;
pub use trainer::{train, TrainParams};
pub use tree::Tree;

use crate::data::Dataset;
use crate::parallel;

/// A trained boosted ensemble. `tree_group[i]` is the output group
/// (class) tree `i` contributes to; regression/binary have one group.
#[derive(Clone, Debug)]
pub struct Model {
    pub trees: Vec<Tree>,
    pub tree_group: Vec<usize>,
    pub num_groups: usize,
    pub num_features: usize,
    pub base_score: f32,
    pub objective: Objective,
}

impl Model {
    /// Raw (margin) scores per group for one row.
    pub fn predict_row_raw(&self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![self.base_score; self.num_groups];
        for (t, &g) in self.trees.iter().zip(&self.tree_group) {
            out[g] += t.predict_row(x);
        }
        out
    }

    /// Raw scores for a dataset: [rows × groups] row-major.
    pub fn predict_raw(&self, data: &Dataset, threads: usize) -> Vec<f32> {
        let groups = self.num_groups;
        let mut out = vec![0.0f32; data.rows * groups];
        parallel::parallel_for_rows(threads, &mut out, groups, 256, |range, chunk| {
            for (k, r) in range.enumerate() {
                let p = self.predict_row_raw(data.row(r));
                chunk[k * groups..(k + 1) * groups].copy_from_slice(&p);
            }
        });
        out
    }

    pub fn total_leaves(&self) -> usize {
        self.trees.iter().map(|t| t.num_leaves()).sum()
    }

    pub fn max_depth(&self) -> usize {
        self.trees.iter().map(|t| t.max_depth()).max().unwrap_or(0)
    }

    /// Model summary line (Table 3 row).
    pub fn summary(&self) -> String {
        format!(
            "trees={} leaves={} max_depth={} groups={} features={}",
            self.trees.len(),
            self.total_leaves(),
            self.max_depth(),
            self.num_groups,
            self.num_features
        )
    }
}

/// Model-zoo size variants used throughout the evaluation (Table 3):
/// (boosting rounds, max depth). Row counts of the training data are
/// scaled separately.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ZooSize {
    Small,
    Medium,
    Large,
}

impl ZooSize {
    pub fn rounds_depth(&self) -> (usize, usize) {
        match self {
            // paper: (10, 3) / (100, 8) / (1000, 16); rounds here are the
            // paper's ÷10 to keep the CPU baseline tractable on this
            // testbed — DESIGN.md §5 "scale substitutions".
            ZooSize::Small => (10, 3),
            ZooSize::Medium => (50, 8),
            ZooSize::Large => (100, 16),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ZooSize::Small => "small",
            ZooSize::Medium => "med",
            ZooSize::Large => "large",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;

    #[test]
    fn predict_raw_matches_row() {
        let d = SynthSpec::covtype(0.0008).generate();
        let model = train(&d, &TrainParams { rounds: 2, max_depth: 3, ..Default::default() });
        let all = model.predict_raw(&d, 4);
        for r in [0usize, 3, d.rows - 1] {
            let row = model.predict_row_raw(d.row(r));
            assert_eq!(&all[r * 8..(r + 1) * 8], &row[..]);
        }
    }

    #[test]
    fn summary_counts() {
        let d = SynthSpec::cal_housing(0.004).generate();
        let model = train(&d, &TrainParams { rounds: 3, max_depth: 3, ..Default::default() });
        assert_eq!(model.trees.len(), 3);
        assert!(model.total_leaves() >= 3);
        assert!(model.summary().contains("trees=3"));
    }
}
