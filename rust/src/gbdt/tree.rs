//! Array-of-nodes regression tree (XGBoost layout) with cover statistics.
//!
//! `cover` (sum of training hessians through each node) is what TreeShap's
//! "cover weighting" uses for the missing-feature Bernoulli probabilities,
//! so it is a first-class part of the model, not a training by-product.

/// Binary regression tree. Node `i` is a leaf iff `left[i] < 0`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Tree {
    pub left: Vec<i32>,
    pub right: Vec<i32>,
    pub feature: Vec<i32>,
    /// split: go left iff x[feature] < threshold
    pub threshold: Vec<f32>,
    /// leaf value (interior nodes: unused)
    pub value: Vec<f32>,
    /// training weight (Σ hessian) through the node
    pub cover: Vec<f32>,
}

impl Tree {
    pub fn new() -> Tree {
        Tree::default()
    }

    /// Append a node, returning its index.
    pub fn add_node(&mut self) -> usize {
        self.left.push(-1);
        self.right.push(-1);
        self.feature.push(-1);
        self.threshold.push(0.0);
        self.value.push(0.0);
        self.cover.push(0.0);
        self.left.len() - 1
    }

    /// Single leaf tree with the given value and cover.
    pub fn leaf(value: f32, cover: f32) -> Tree {
        let mut t = Tree::new();
        let i = t.add_node();
        t.value[i] = value;
        t.cover[i] = cover;
        t
    }

    #[inline]
    pub fn is_leaf(&self, i: usize) -> bool {
        self.left[i] < 0
    }

    pub fn num_nodes(&self) -> usize {
        self.left.len()
    }

    pub fn num_leaves(&self) -> usize {
        self.left.iter().filter(|&&l| l < 0).count()
    }

    pub fn max_depth(&self) -> usize {
        if self.left.is_empty() {
            return 0;
        }
        // iterative DFS to avoid recursion limits on deep trees
        let mut best = 0;
        let mut stack = vec![(0usize, 0usize)];
        while let Some((i, d)) = stack.pop() {
            if self.is_leaf(i) {
                best = best.max(d);
            } else {
                stack.push((self.left[i] as usize, d + 1));
                stack.push((self.right[i] as usize, d + 1));
            }
        }
        best
    }

    /// Evaluate on one row. NaN features route to the heavier-cover child
    /// (the "majority direction", a common missing-value policy).
    pub fn predict_row(&self, x: &[f32]) -> f32 {
        let mut i = 0usize;
        while !self.is_leaf(i) {
            let v = x[self.feature[i] as usize];
            let (l, r) = (self.left[i] as usize, self.right[i] as usize);
            i = if v.is_nan() {
                if self.cover[l] >= self.cover[r] { l } else { r }
            } else if v < self.threshold[i] {
                l
            } else {
                r
            };
        }
        self.value[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// x0 < 0 ? (x1 < 1 ? 1 : 2) : 3
    pub fn sample_tree() -> Tree {
        let mut t = Tree::new();
        let root = t.add_node();
        let l = t.add_node();
        let r = t.add_node();
        let ll = t.add_node();
        let lr = t.add_node();
        t.feature[root] = 0;
        t.threshold[root] = 0.0;
        t.left[root] = l as i32;
        t.right[root] = r as i32;
        t.cover[root] = 10.0;
        t.feature[l] = 1;
        t.threshold[l] = 1.0;
        t.left[l] = ll as i32;
        t.right[l] = lr as i32;
        t.cover[l] = 6.0;
        t.value[r] = 3.0;
        t.cover[r] = 4.0;
        t.value[ll] = 1.0;
        t.cover[ll] = 2.0;
        t.value[lr] = 2.0;
        t.cover[lr] = 4.0;
        t
    }

    #[test]
    fn predict_and_shape() {
        let t = sample_tree();
        assert_eq!(t.num_leaves(), 3);
        assert_eq!(t.max_depth(), 2);
        assert_eq!(t.predict_row(&[-1.0, 0.0]), 1.0);
        assert_eq!(t.predict_row(&[-1.0, 2.0]), 2.0);
        assert_eq!(t.predict_row(&[1.0, 0.0]), 3.0);
    }

    #[test]
    fn nan_routes_to_heavier_child() {
        let t = sample_tree();
        // root: left cover 6 >= right 4 -> left; inner: ll 2 < lr 4 -> lr
        assert_eq!(t.predict_row(&[f32::NAN, f32::NAN]), 2.0);
    }

    #[test]
    fn leaf_tree() {
        let t = Tree::leaf(7.0, 3.0);
        assert_eq!(t.predict_row(&[1.0]), 7.0);
        assert_eq!(t.max_depth(), 0);
        assert_eq!(t.num_leaves(), 1);
    }
}
