//! XGBoost model importer: the paper ships GPUTreeShap as an XGBoost
//! backend, so this repo accepts real XGBoost models too.
//!
//! Two accepted shapes of `booster.save_model("model.json")` output:
//! the full v1/v2 JSON (`learner.gradient_booster.model.trees[*]` with
//! parallel arrays) — the format XGBoost ≥ 1.0 writes.
//!
//! XGBoost arrays used: `left_children`, `right_children`,
//! `split_indices`, `split_conditions` (also the leaf value when the
//! node is a leaf), `sum_hessian` (cover), plus per-tree `tree_info`
//! group ids and learner metadata (num_feature, num_class, objective,
//! base_score).

use std::path::Path;

use crate::bail;
use crate::util::error::{Context, Result};

use crate::gbdt::loss::Objective;
use crate::gbdt::tree::Tree;
use crate::gbdt::Model;
use crate::util::Json;

pub fn load_xgboost_json(path: &Path) -> Result<Model> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse_xgboost_json(&text)
}

pub fn parse_xgboost_json(text: &str) -> Result<Model> {
    let root = Json::parse(text).context("invalid JSON")?;
    let learner = root.get("learner").context("missing learner (not an XGBoost model.json?)")?;
    let model = learner
        .get("gradient_booster")?
        .get("model")
        .context("missing gradient_booster.model")?;

    let lmp = learner.get("learner_model_param")?;
    let num_features = parse_num(lmp.get("num_feature")?)? as usize;
    let num_class = parse_num(lmp.get("num_class")?)? as usize;
    let base_score = parse_num(lmp.get("base_score")?)? as f32;

    let objective_name = learner
        .get("objective")
        .and_then(|o| o.get("name"))
        .and_then(|n| n.as_str().map(str::to_string))
        .unwrap_or_else(|_| "reg:squarederror".to_string());
    let objective = match objective_name.as_str() {
        "binary:logistic" | "binary:logitraw" => Objective::Logistic,
        "multi:softmax" | "multi:softprob" => Objective::Softmax(num_class.max(2)),
        _ => Objective::SquaredError,
    };
    let num_groups = objective.num_groups();

    let trees_json = model.get("trees")?.as_arr()?;
    let tree_info: Vec<usize> = match model.get("tree_info") {
        Ok(ti) => ti
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<_>>()?,
        Err(_) => vec![0; trees_json.len()],
    };
    if tree_info.len() != trees_json.len() {
        bail!("tree_info length mismatch");
    }

    let mut trees = Vec::with_capacity(trees_json.len());
    for t in trees_json {
        trees.push(parse_tree(t)?);
    }
    for (t, &g) in trees.iter().zip(&tree_info) {
        if g >= num_groups {
            bail!("tree_info group {g} out of range (num_groups {num_groups})");
        }
        for i in 0..t.num_nodes() {
            if !t.is_leaf(i) && t.feature[i] as usize >= num_features {
                bail!("split feature {} out of range", t.feature[i]);
            }
        }
    }

    Ok(Model {
        trees,
        tree_group: tree_info,
        num_groups,
        num_features,
        base_score,
        objective,
    })
}

/// XGBoost stores numbers either as JSON numbers or as strings.
fn parse_num(v: &Json) -> Result<f64> {
    match v {
        Json::Num(n) => Ok(*n),
        Json::Str(s) => s.trim().parse::<f64>().context("numeric string"),
        other => bail!("expected number, got {other:?}"),
    }
}

fn num_arr(t: &Json, key: &str) -> Result<Vec<f64>> {
    t.get(key)?
        .as_arr()?
        .iter()
        .map(parse_num)
        .collect::<Result<Vec<f64>>>()
        .with_context(|| format!("parsing {key}"))
}

fn parse_tree(t: &Json) -> Result<Tree> {
    let left: Vec<f64> = num_arr(t, "left_children")?;
    let right: Vec<f64> = num_arr(t, "right_children")?;
    let split_idx: Vec<f64> = num_arr(t, "split_indices")?;
    let split_cond: Vec<f64> = num_arr(t, "split_conditions")?;
    let cover: Vec<f64> = num_arr(t, "sum_hessian")?;
    let n = left.len();
    if [right.len(), split_idx.len(), split_cond.len(), cover.len()]
        .iter()
        .any(|&l| l != n)
    {
        bail!("inconsistent node array lengths");
    }
    let mut tree = Tree::new();
    for i in 0..n {
        tree.add_node();
        tree.left[i] = left[i] as i32;
        tree.right[i] = right[i] as i32;
        tree.cover[i] = cover[i] as f32;
        if left[i] < 0.0 {
            // leaf: split_conditions holds the leaf value
            tree.value[i] = split_cond[i] as f32;
            tree.feature[i] = -1;
        } else {
            tree.feature[i] = split_idx[i] as i32;
            tree.threshold[i] = split_cond[i] as f32;
        }
    }
    // sanity: children must point inside the array and form a tree
    for i in 0..n {
        if !tree.is_leaf(i) {
            let (l, r) = (tree.left[i], tree.right[i]);
            if l < 0 || r < 0 || l as usize >= n || r as usize >= n {
                bail!("child pointer out of range at node {i}");
            }
        }
    }
    Ok(tree)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A hand-built two-tree binary-logistic model in XGBoost v2 JSON.
    /// Tree 0: f0 < 0.5 ? (f1 < 1.5 ? 0.1 : 0.2) : -0.3
    fn sample_json() -> String {
        r#"{
          "learner": {
            "learner_model_param": {
              "num_feature": "3", "num_class": "0", "base_score": "0.0"
            },
            "objective": { "name": "binary:logistic" },
            "gradient_booster": {
              "model": {
                "trees": [
                  {
                    "left_children":  [1, 3, -1, -1, -1],
                    "right_children": [2, 4, -1, -1, -1],
                    "split_indices":  [0, 1, 0, 0, 0],
                    "split_conditions": [0.5, 1.5, -0.3, 0.1, 0.2],
                    "sum_hessian": [10.0, 6.0, 4.0, 2.0, 4.0]
                  },
                  {
                    "left_children":  [-1],
                    "right_children": [-1],
                    "split_indices":  [0],
                    "split_conditions": [0.05],
                    "sum_hessian": [10.0]
                  }
                ],
                "tree_info": [0, 0]
              }
            }
          }
        }"#
        .to_string()
    }

    #[test]
    fn imports_model_and_predicts() {
        let model = parse_xgboost_json(&sample_json()).unwrap();
        assert_eq!(model.num_features, 3);
        assert_eq!(model.objective, Objective::Logistic);
        assert_eq!(model.trees.len(), 2);
        // x = [0.0, 1.0]: tree0 -> left,left -> 0.1; tree1 -> 0.05
        let p = model.predict_row_raw(&[0.0, 1.0, 0.0])[0];
        assert!((p - 0.15).abs() < 1e-6);
        let p = model.predict_row_raw(&[1.0, 0.0, 0.0])[0];
        assert!((p - (-0.3 + 0.05)).abs() < 1e-6);
    }

    #[test]
    fn imported_model_explains_with_local_accuracy() {
        let model = parse_xgboost_json(&sample_json()).unwrap();
        let x = vec![0.2f32, 2.0, -1.0, 0.9, 0.5, 0.0];
        let phis = crate::shap::treeshap::shap_values(&model, &x, 2, 1);
        for r in 0..2 {
            let pred = model.predict_row_raw(&x[r * 3..(r + 1) * 3])[0] as f64;
            let total: f64 = phis[r * 4..(r + 1) * 4].iter().map(|&v| v as f64).sum();
            assert!((total - pred).abs() < 1e-5, "{total} vs {pred}");
        }
    }

    #[test]
    fn cover_statistics_preserved() {
        let model = parse_xgboost_json(&sample_json()).unwrap();
        assert_eq!(model.trees[0].cover, vec![10.0, 6.0, 4.0, 2.0, 4.0]);
    }

    #[test]
    fn rejects_malformed_models() {
        assert!(parse_xgboost_json("{}").is_err());
        assert!(parse_xgboost_json("not json").is_err());
        let bad = sample_json().replace("\"tree_info\": [0, 0]", "\"tree_info\": [0]");
        assert!(parse_xgboost_json(&bad).is_err());
        let bad = sample_json().replace("[1, 3, -1, -1, -1]", "[1, 99, -1, -1, -1]");
        assert!(parse_xgboost_json(&bad).is_err());
    }

    #[test]
    fn multiclass_groups_parsed() {
        let json = sample_json()
            .replace("\"num_class\": \"0\"", "\"num_class\": \"3\"")
            .replace("binary:logistic", "multi:softprob")
            .replace("\"tree_info\": [0, 0]", "\"tree_info\": [0, 2]");
        let model = parse_xgboost_json(&json).unwrap();
        assert_eq!(model.num_groups, 3);
        assert_eq!(model.tree_group, vec![0, 2]);
    }
}
