//! Quantile binning + gradient histograms for the histogram-based GBDT
//! trainer (the approach of XGBoost `hist` / LightGBM).

use crate::data::Dataset;
use crate::parallel;

/// Per-feature quantile cut points and the binned (u8) feature matrix.
pub struct BinnedMatrix {
    /// cuts[f] sorted ascending; bin b covers [cuts[b-1], cuts[b])
    pub cuts: Vec<Vec<f32>>,
    /// bin index per (row, feature), row-major
    pub bins: Vec<u8>,
    pub rows: usize,
    pub cols: usize,
}

impl BinnedMatrix {
    /// Build cut points from per-feature quantiles (max_bins ≤ 256).
    pub fn build(data: &Dataset, max_bins: usize, threads: usize) -> BinnedMatrix {
        let max_bins = max_bins.clamp(2, 256);
        let (rows, cols) = (data.rows, data.cols);
        let mut cuts: Vec<Vec<f32>> = vec![Vec::new(); cols];
        let cuts_slice = &mut cuts[..];
        parallel::parallel_fill(threads, cuts_slice, 1, |f, out| {
            let mut vals: Vec<f32> = (0..rows)
                .map(|r| data.get(r, f))
                .filter(|v| !v.is_nan())
                .collect();
            vals.sort_by(|a, b| a.total_cmp(b));
            vals.dedup();
            let n = vals.len();
            if n <= 1 {
                return; // constant feature: no cuts, single bin
            }
            let k = (max_bins - 1).min(n - 1);
            let mut c = Vec::with_capacity(k);
            for i in 1..=k {
                // midpoint between the quantile neighbours, like xgboost
                let idx = i * (n - 1) / (k + 1) + 1;
                let cut = 0.5 * (vals[idx - 1] + vals[idx]);
                if c.last().map_or(true, |&last| cut > last) {
                    c.push(cut);
                }
            }
            *out = c;
        });

        let mut bins = vec![0u8; rows * cols];
        let cuts_ref = &cuts;
        parallel::parallel_for_rows(threads, &mut bins, cols, 256, |range, chunk| {
            for (i, r) in range.enumerate() {
                for f in 0..cols {
                    let v = data.get(r, f);
                    chunk[i * cols + f] = bin_of(&cuts_ref[f], v);
                }
            }
        });
        BinnedMatrix { cuts, bins, rows, cols }
    }

    #[inline]
    pub fn bin(&self, r: usize, f: usize) -> u8 {
        self.bins[r * self.cols + f]
    }

    pub fn num_bins(&self, f: usize) -> usize {
        self.cuts[f].len() + 1
    }
}

/// bin = #{cuts ≤ v}; NaN maps to bin 0 (treated as smallest).
#[inline]
pub fn bin_of(cuts: &[f32], v: f32) -> u8 {
    if v.is_nan() {
        return 0;
    }
    // cuts are short (≤255): linear partition-point is competitive and
    // branch-predictable; binary search for long cut lists.
    if cuts.len() <= 16 {
        let mut b = 0u8;
        for &c in cuts {
            if v >= c {
                b += 1;
            } else {
                break;
            }
        }
        b
    } else {
        cuts.partition_point(|&c| v >= c) as u8
    }
}

/// (Σ gradient, Σ hessian) accumulator per histogram bin.
#[derive(Clone, Copy, Debug, Default)]
pub struct GradPair {
    pub g: f64,
    pub h: f64,
}

impl GradPair {
    #[inline]
    pub fn add(&mut self, g: f64, h: f64) {
        self.g += g;
        self.h += h;
    }
    #[inline]
    pub fn sub(&self, other: &GradPair) -> GradPair {
        GradPair { g: self.g - other.g, h: self.h - other.h }
    }
}

/// Build per-feature histograms for the rows of one tree node.
/// `hist` is laid out [feature][bin].
pub fn build_histograms(
    binned: &BinnedMatrix,
    rows: &[u32],
    grad: &[f32],
    hess: &[f32],
    threads: usize,
) -> Vec<Vec<GradPair>> {
    let cols = binned.cols;
    let mut hist: Vec<Vec<GradPair>> =
        (0..cols).map(|f| vec![GradPair::default(); binned.num_bins(f)]).collect();
    let hist_slice = &mut hist[..];
    parallel::parallel_fill(threads, hist_slice, 1, |f, hf| {
        for &r in rows {
            let r = r as usize;
            let b = binned.bin(r, f) as usize;
            hf[b].add(grad[r] as f64, hess[r] as f64);
        }
    });
    hist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;

    fn tiny() -> Dataset {
        let mut d = Dataset::new("t", 6, 2, 0);
        for (r, v) in [0.0f32, 1.0, 2.0, 3.0, 4.0, 5.0].iter().enumerate() {
            d.set(r, 0, *v);
            d.set(r, 1, if r % 2 == 0 { -1.0 } else { 1.0 });
        }
        d
    }

    #[test]
    fn bins_are_monotone_in_value() {
        let d = tiny();
        let m = BinnedMatrix::build(&d, 4, 1);
        let b: Vec<u8> = (0..6).map(|r| m.bin(r, 0)).collect();
        for w in b.windows(2) {
            assert!(w[0] <= w[1], "{b:?}");
        }
        assert!(*b.last().unwrap() > 0);
    }

    #[test]
    fn binary_feature_two_bins() {
        let d = tiny();
        let m = BinnedMatrix::build(&d, 16, 1);
        assert_eq!(m.num_bins(1), 2);
        assert_eq!(m.bin(0, 1), 0);
        assert_eq!(m.bin(1, 1), 1);
    }

    #[test]
    fn bin_of_nan_is_zero() {
        assert_eq!(bin_of(&[0.5, 1.0], f32::NAN), 0);
        assert_eq!(bin_of(&[0.5, 1.0], 0.7), 1);
        assert_eq!(bin_of(&[0.5, 1.0], 2.0), 2);
    }

    #[test]
    fn bin_of_linear_matches_binary() {
        let cuts: Vec<f32> = (0..40).map(|i| i as f32 * 0.25).collect();
        for v in [-1.0f32, 0.0, 0.1, 3.3, 9.9, 100.0] {
            let lin = {
                let mut b = 0u8;
                for &c in &cuts {
                    if v >= c { b += 1 } else { break }
                }
                b
            };
            assert_eq!(bin_of(&cuts, v), lin);
        }
    }

    #[test]
    fn histogram_sums_match_totals() {
        let d = tiny();
        let m = BinnedMatrix::build(&d, 8, 1);
        let rows: Vec<u32> = (0..6).collect();
        let grad = vec![1.0f32; 6];
        let hess = vec![0.5f32; 6];
        let hist = build_histograms(&m, &rows, &grad, &hess, 2);
        for f in 0..2 {
            let g: f64 = hist[f].iter().map(|p| p.g).sum();
            let h: f64 = hist[f].iter().map(|p| p.h).sum();
            assert!((g - 6.0).abs() < 1e-9);
            assert!((h - 3.0).abs() < 1e-9);
        }
    }
}
