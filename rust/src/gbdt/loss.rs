//! Training objectives: gradients/hessians in raw-score space.

/// Objective selects gradient computation and number of output groups.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// squared error, 1 group
    SquaredError,
    /// binary cross-entropy on logits, 1 group
    Logistic,
    /// softmax cross-entropy, K groups (one tree per class per round)
    Softmax(usize),
}

impl Objective {
    pub fn num_groups(&self) -> usize {
        match self {
            Objective::SquaredError | Objective::Logistic => 1,
            Objective::Softmax(k) => *k,
        }
    }

    pub fn id(&self) -> u32 {
        match self {
            Objective::SquaredError => 0,
            Objective::Logistic => 1,
            Objective::Softmax(_) => 2,
        }
    }

    pub fn from_id(id: u32, groups: usize) -> Objective {
        match id {
            0 => Objective::SquaredError,
            1 => Objective::Logistic,
            _ => Objective::Softmax(groups),
        }
    }

    /// Fill grad/hess for group `k` given raw scores [rows × groups]
    /// (row-major) and labels.
    pub fn grad_hess(
        &self,
        scores: &[f32],
        labels: &[f32],
        k: usize,
        grad: &mut [f32],
        hess: &mut [f32],
    ) {
        let groups = self.num_groups();
        let rows = labels.len();
        match self {
            Objective::SquaredError => {
                for r in 0..rows {
                    grad[r] = scores[r] - labels[r];
                    hess[r] = 1.0;
                }
            }
            Objective::Logistic => {
                for r in 0..rows {
                    let p = sigmoid(scores[r]);
                    grad[r] = p - labels[r];
                    hess[r] = (p * (1.0 - p)).max(1e-6);
                }
            }
            Objective::Softmax(_) => {
                for r in 0..rows {
                    let row = &scores[r * groups..(r + 1) * groups];
                    let maxv = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                    let sum: f32 = row.iter().map(|&s| (s - maxv).exp()).sum();
                    let p = (row[k] - maxv).exp() / sum;
                    let y = if labels[r] as usize == k { 1.0 } else { 0.0 };
                    grad[r] = p - y;
                    hess[r] = (2.0 * p * (1.0 - p)).max(1e-6);
                }
            }
        }
    }

    /// Transform raw scores to the reporting space (probability / value).
    pub fn transform(&self, raw: &mut [f32]) {
        match self {
            Objective::SquaredError => {}
            Objective::Logistic => {
                for v in raw.iter_mut() {
                    *v = sigmoid(*v);
                }
            }
            Objective::Softmax(k) => {
                for row in raw.chunks_mut(*k) {
                    let maxv = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                    let mut sum = 0.0;
                    for v in row.iter_mut() {
                        *v = (*v - maxv).exp();
                        sum += *v;
                    }
                    for v in row.iter_mut() {
                        *v /= sum;
                    }
                }
            }
        }
    }
}

#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn squared_error_grads() {
        let mut g = vec![0.0; 2];
        let mut h = vec![0.0; 2];
        Objective::SquaredError.grad_hess(&[3.0, -1.0], &[1.0, -1.0], 0, &mut g, &mut h);
        assert_eq!(g, vec![2.0, 0.0]);
        assert_eq!(h, vec![1.0, 1.0]);
    }

    #[test]
    fn logistic_grad_signs() {
        let mut g = vec![0.0; 2];
        let mut h = vec![0.0; 2];
        Objective::Logistic.grad_hess(&[0.0, 0.0], &[1.0, 0.0], 0, &mut g, &mut h);
        assert!(g[0] < 0.0 && g[1] > 0.0);
        assert!(h.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn softmax_probs_sum_to_one() {
        let obj = Objective::Softmax(3);
        let mut raw = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        obj.transform(&mut raw);
        for row in raw.chunks(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_grads_sum_to_zero_over_classes() {
        let obj = Objective::Softmax(3);
        let scores = vec![0.3, -0.2, 0.5];
        let labels = vec![2.0];
        let mut total = 0.0;
        for k in 0..3 {
            let mut g = vec![0.0];
            let mut h = vec![0.0];
            obj.grad_hess(&scores, &labels, k, &mut g, &mut h);
            total += g[0];
        }
        assert!(total.abs() < 1e-6);
    }

    #[test]
    fn objective_id_roundtrip() {
        for obj in [Objective::SquaredError, Objective::Logistic, Objective::Softmax(5)] {
            let back = Objective::from_id(obj.id(), obj.num_groups());
            assert_eq!(back, obj);
        }
    }
}
