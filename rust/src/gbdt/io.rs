//! Model serialization: compact little-endian binary format.
//!
//! Layout: magic "GTSM", u32 version, header (u32 counts + f32
//! base_score), then per tree: u32 node count + the six node arrays as
//! raw LE bytes. Large zoo models (10⁵–10⁶ nodes) load in milliseconds.

use std::io::{Read, Write};
use std::path::Path;

use crate::bail;
use crate::util::error::{Context, Result};

use crate::gbdt::loss::Objective;
use crate::gbdt::tree::Tree;
use crate::gbdt::Model;

const MAGIC: &[u8; 4] = b"GTSM";
const VERSION: u32 = 1;

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_i32s(out: &mut Vec<u8>, vs: &[i32]) {
    for v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_f32s(out: &mut Vec<u8>, vs: &[f32]) {
    for v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated model file");
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn i32s(&mut self, n: usize) -> Result<Vec<i32>> {
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
    }
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

pub fn encode(model: &Model) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, VERSION);
    put_u32(&mut out, model.trees.len() as u32);
    put_u32(&mut out, model.num_groups as u32);
    put_u32(&mut out, model.num_features as u32);
    put_u32(&mut out, model.objective.id());
    put_f32(&mut out, model.base_score);
    for g in &model.tree_group {
        put_u32(&mut out, *g as u32);
    }
    for t in &model.trees {
        put_u32(&mut out, t.num_nodes() as u32);
        put_i32s(&mut out, &t.left);
        put_i32s(&mut out, &t.right);
        put_i32s(&mut out, &t.feature);
        put_f32s(&mut out, &t.threshold);
        put_f32s(&mut out, &t.value);
        put_f32s(&mut out, &t.cover);
    }
    out
}

pub fn decode(buf: &[u8]) -> Result<Model> {
    let mut r = Reader { buf, pos: 0 };
    if r.take(4)? != MAGIC {
        bail!("not a GTSM model file");
    }
    let version = r.u32()?;
    if version != VERSION {
        bail!("unsupported model version {version}");
    }
    let n_trees = r.u32()? as usize;
    let num_groups = r.u32()? as usize;
    let num_features = r.u32()? as usize;
    let obj_id = r.u32()?;
    let base_score = r.f32()?;
    let mut tree_group = Vec::with_capacity(n_trees);
    for _ in 0..n_trees {
        tree_group.push(r.u32()? as usize);
    }
    let mut trees = Vec::with_capacity(n_trees);
    for _ in 0..n_trees {
        let n = r.u32()? as usize;
        trees.push(Tree {
            left: r.i32s(n)?,
            right: r.i32s(n)?,
            feature: r.i32s(n)?,
            threshold: r.f32s(n)?,
            value: r.f32s(n)?,
            cover: r.f32s(n)?,
        });
    }
    if r.pos != buf.len() {
        bail!("trailing bytes in model file");
    }
    Ok(Model {
        trees,
        tree_group,
        num_groups,
        num_features,
        base_score,
        objective: Objective::from_id(obj_id, num_groups),
    })
}

pub fn save(model: &Model, path: &Path) -> Result<()> {
    let bytes = encode(model);
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    f.write_all(&bytes)?;
    Ok(())
}

pub fn load(path: &Path) -> Result<Model> {
    let mut buf = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?
        .read_to_end(&mut buf)?;
    decode(&buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;
    use crate::gbdt::trainer::{train, TrainParams};

    #[test]
    fn roundtrip_preserves_model() {
        let d = SynthSpec::adult(0.005).generate();
        let model = train(&d, &TrainParams { rounds: 3, max_depth: 4, ..Default::default() });
        let back = decode(&encode(&model)).unwrap();
        assert_eq!(back.trees.len(), model.trees.len());
        assert_eq!(back.tree_group, model.tree_group);
        assert_eq!(back.objective, model.objective);
        for (a, b) in model.trees.iter().zip(&back.trees) {
            assert_eq!(a, b);
        }
        // predictions identical
        for r in 0..10.min(d.rows) {
            assert_eq!(model.predict_row_raw(d.row(r)), back.predict_row_raw(d.row(r)));
        }
    }

    #[test]
    fn rejects_corruption() {
        let d = SynthSpec::cal_housing(0.003).generate();
        let model = train(&d, &TrainParams { rounds: 1, ..Default::default() });
        let mut bytes = encode(&model);
        assert!(decode(&bytes[..bytes.len() - 3]).is_err());
        bytes[0] = b'X';
        assert!(decode(&bytes).is_err());
    }
}
