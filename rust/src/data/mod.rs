//! Dataset substrate: dense row-major matrices with labels, synthetic
//! generators mirroring the paper's Table 2 corpus, and a CSV loader for
//! bringing real data.

pub mod csv;
pub mod synth;

pub use synth::{SynthSpec, TaskKind};

/// Dense row-major feature matrix + labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub rows: usize,
    pub cols: usize,
    /// row-major [rows * cols]
    pub features: Vec<f32>,
    /// regression target or class index as f32
    pub labels: Vec<f32>,
    /// 0 for regression, ≥ 2 for classification
    pub num_classes: usize,
    pub name: String,
}

impl Dataset {
    pub fn new(name: &str, rows: usize, cols: usize, num_classes: usize) -> Self {
        Dataset {
            rows,
            cols,
            features: vec![0.0; rows * cols],
            labels: vec![0.0; rows],
            num_classes,
            name: name.to_string(),
        }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.features[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.features[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.features[r * self.cols + c] = v;
    }

    pub fn is_regression(&self) -> bool {
        self.num_classes == 0
    }

    /// Take the first `n` rows (for train/test style splits of synthetic data).
    pub fn head(&self, n: usize) -> Dataset {
        let n = n.min(self.rows);
        Dataset {
            rows: n,
            cols: self.cols,
            features: self.features[..n * self.cols].to_vec(),
            labels: self.labels[..n].to_vec(),
            num_classes: self.num_classes,
            name: self.name.clone(),
        }
    }

    /// Rows `[start, end)` as a new dataset.
    pub fn slice_rows(&self, start: usize, end: usize) -> Dataset {
        let end = end.min(self.rows);
        let start = start.min(end);
        Dataset {
            rows: end - start,
            cols: self.cols,
            features: self.features[start * self.cols..end * self.cols].to_vec(),
            labels: self.labels[start..end].to_vec(),
            num_classes: self.num_classes,
            name: self.name.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let mut d = Dataset::new("t", 3, 2, 0);
        d.set(1, 1, 5.0);
        assert_eq!(d.get(1, 1), 5.0);
        assert_eq!(d.row(1), &[0.0, 5.0]);
    }

    #[test]
    fn slicing() {
        let mut d = Dataset::new("t", 4, 2, 3);
        for r in 0..4 {
            d.set(r, 0, r as f32);
            d.labels[r] = r as f32;
        }
        let s = d.slice_rows(1, 3);
        assert_eq!(s.rows, 2);
        assert_eq!(s.get(0, 0), 1.0);
        assert_eq!(s.labels, vec![1.0, 2.0]);
        assert_eq!(d.head(2).rows, 2);
    }
}
