//! Synthetic dataset generators standing in for the paper's Table 2.
//!
//! The original corpus (covtype, cal_housing, fashion_mnist, adult) is
//! not redistributable here, so we generate datasets with the same
//! (rows, cols, task, classes) signature and *learnable structure*: the
//! label is produced by a hidden random rule ensemble (axis-aligned
//! threshold conjunctions — i.e. tree-shaped signal) plus noise, so a
//! GBDT trained on it grows non-trivial trees of the depths the paper's
//! model zoo requires. DESIGN.md §5 records this substitution.

use crate::data::Dataset;
use crate::util::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    Regression,
    Classification,
}

/// Shape + generation parameters for one synthetic dataset.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub name: &'static str,
    pub rows: usize,
    pub cols: usize,
    pub task: TaskKind,
    pub classes: usize,
    /// number of hidden rules generating the signal
    pub rules: usize,
    /// max conjunction depth of a hidden rule
    pub rule_depth: usize,
    pub noise: f64,
    pub seed: u64,
}

impl SynthSpec {
    /// Table 2 signatures, row counts scaled by `scale` (1.0 = paper size).
    pub fn covtype(scale: f64) -> SynthSpec {
        SynthSpec {
            name: "covtype",
            rows: ((581_012 as f64) * scale) as usize,
            cols: 54,
            task: TaskKind::Classification,
            classes: 8,
            rules: 40,
            rule_depth: 4,
            noise: 0.1,
            seed: 0xC0541,
        }
    }

    pub fn cal_housing(scale: f64) -> SynthSpec {
        SynthSpec {
            name: "cal_housing",
            rows: ((20_640 as f64) * scale) as usize,
            cols: 8,
            task: TaskKind::Regression,
            classes: 0,
            rules: 24,
            rule_depth: 3,
            noise: 0.2,
            seed: 0xCA11F,
        }
    }

    pub fn fashion_mnist(scale: f64) -> SynthSpec {
        SynthSpec {
            name: "fashion_mnist",
            rows: ((70_000 as f64) * scale) as usize,
            cols: 784,
            task: TaskKind::Classification,
            classes: 10,
            rules: 60,
            rule_depth: 4,
            noise: 0.1,
            seed: 0xFA510,
        }
    }

    pub fn adult(scale: f64) -> SynthSpec {
        SynthSpec {
            name: "adult",
            rows: ((48_842 as f64) * scale) as usize,
            cols: 14,
            task: TaskKind::Classification,
            classes: 2,
            rules: 24,
            rule_depth: 3,
            noise: 0.15,
            seed: 0xAD011,
        }
    }

    pub fn all(scale: f64) -> Vec<SynthSpec> {
        vec![
            Self::covtype(scale),
            Self::cal_housing(scale),
            Self::fashion_mnist(scale),
            Self::adult(scale),
        ]
    }

    pub fn generate(&self) -> Dataset {
        generate(self)
    }
}

/// One hidden rule: a conjunction of (feature, threshold, direction)
/// literals firing a per-class (or scalar) vote.
struct Rule {
    lits: Vec<(usize, f32, bool)>,
    votes: Vec<f64>,
}

pub fn generate(spec: &SynthSpec) -> Dataset {
    let mut rng = Rng::new(spec.seed);
    let classes = match spec.task {
        TaskKind::Regression => 1,
        TaskKind::Classification => spec.classes.max(2),
    };
    // Informative features are a subset; the rest are noise (mirrors
    // e.g. fashion_mnist where border pixels carry nothing).
    let informative = (spec.cols as f64 * 0.6).ceil() as usize;
    let informative = informative.clamp(1, spec.cols);

    let rules: Vec<Rule> = (0..spec.rules)
        .map(|_| {
            let depth = 1 + rng.below(spec.rule_depth as u64) as usize;
            let lits = (0..depth)
                .map(|_| {
                    (
                        rng.below(informative as u64) as usize,
                        rng.normal() as f32 * 0.8,
                        rng.bool(0.5),
                    )
                })
                .collect();
            let votes = (0..classes).map(|_| rng.normal() * 2.0).collect();
            Rule { lits, votes }
        })
        .collect();

    let mut d = Dataset::new(
        spec.name,
        spec.rows,
        spec.cols,
        if spec.task == TaskKind::Regression { 0 } else { classes },
    );
    let mut scores = vec![0.0f64; classes];
    for r in 0..spec.rows {
        for c in 0..spec.cols {
            d.set(r, c, rng.normal() as f32);
        }
        scores.iter_mut().for_each(|s| *s = 0.0);
        for rule in &rules {
            let fires = rule
                .lits
                .iter()
                .all(|&(f, t, dir)| (d.get(r, f) < t) == dir);
            if fires {
                for (s, v) in scores.iter_mut().zip(&rule.votes) {
                    *s += v;
                }
            }
        }
        match spec.task {
            TaskKind::Regression => {
                d.labels[r] = (scores[0] + rng.normal() * spec.noise) as f32;
            }
            TaskKind::Classification => {
                for s in scores.iter_mut() {
                    *s += rng.normal() * spec.noise;
                }
                let best = scores
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .unwrap()
                    .0;
                d.labels[r] = best as f32;
            }
        }
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_table2() {
        let d = SynthSpec::cal_housing(0.01).generate();
        assert_eq!(d.cols, 8);
        assert!(d.is_regression());
        let d = SynthSpec::adult(0.002).generate();
        assert_eq!(d.cols, 14);
        assert_eq!(d.num_classes, 2);
    }

    #[test]
    fn classification_labels_in_range() {
        let d = SynthSpec::covtype(0.0005).generate();
        assert_eq!(d.num_classes, 8);
        assert!(d.labels.iter().all(|&l| (0.0..8.0).contains(&l)));
        // all classes used is not guaranteed at tiny scale, but >1 must be
        let distinct: std::collections::BTreeSet<i32> =
            d.labels.iter().map(|&l| l as i32).collect();
        assert!(distinct.len() > 1, "degenerate labels");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = SynthSpec::adult(0.001).generate();
        let b = SynthSpec::adult(0.001).generate();
        assert_eq!(a.features, b.features);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn signal_is_learnable() {
        // A depth-1 threshold on an informative feature should beat chance.
        let d = SynthSpec::adult(0.01).generate();
        let n = d.rows;
        let base_rate = d.labels.iter().filter(|&&l| l == 1.0).count() as f64 / n as f64;
        let mut best_gap: f64 = 0.0;
        for f in 0..d.cols {
            let pos_rate_left = {
                let (mut c1, mut n1) = (0usize, 0usize);
                for r in 0..n {
                    if d.get(r, f) < 0.0 {
                        n1 += 1;
                        if d.labels[r] == 1.0 {
                            c1 += 1;
                        }
                    }
                }
                if n1 == 0 { base_rate } else { c1 as f64 / n1 as f64 }
            };
            best_gap = best_gap.max((pos_rate_left - base_rate).abs());
        }
        assert!(best_gap > 0.02, "no feature carries signal: {best_gap}");
    }
}
