//! CSV loading so users can explain models over real data.
//!
//! Minimal dialect: comma separator, optional header, numeric columns,
//! label in a designated column. Non-numeric cells become NaN (the GBDT
//! treats NaN as "missing" by routing to the majority-cover child).

use std::path::Path;

use crate::bail;
use crate::util::error::{Context, Result};

use crate::data::Dataset;

pub struct CsvOptions {
    pub has_header: bool,
    /// column index of the label; negative counts from the end
    pub label_col: i64,
    /// 0 = regression, else number of classes
    pub num_classes: usize,
}

impl Default for CsvOptions {
    fn default() -> Self {
        CsvOptions { has_header: true, label_col: -1, num_classes: 0 }
    }
}

pub fn load_csv(path: &Path, opts: &CsvOptions) -> Result<Dataset> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse_csv(&text, opts, path.file_stem().and_then(|s| s.to_str()).unwrap_or("csv"))
}

pub fn parse_csv(text: &str, opts: &CsvOptions, name: &str) -> Result<Dataset> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    if opts.has_header {
        lines.next();
    }
    let rows: Vec<&str> = lines.collect();
    if rows.is_empty() {
        bail!("no data rows");
    }
    let ncols_total = rows[0].split(',').count();
    if ncols_total < 2 {
        bail!("need at least 2 columns (features + label)");
    }
    let label_col = if opts.label_col < 0 {
        (ncols_total as i64 + opts.label_col) as usize
    } else {
        opts.label_col as usize
    };
    if label_col >= ncols_total {
        bail!("label column {label_col} out of range ({ncols_total} cols)");
    }
    let cols = ncols_total - 1;
    let mut d = Dataset::new(name, rows.len(), cols, opts.num_classes);
    for (r, line) in rows.iter().enumerate() {
        let mut c_out = 0;
        let mut seen = 0;
        for (c, cell) in line.split(',').enumerate() {
            let v: f32 = cell.trim().parse().unwrap_or(f32::NAN);
            if c == label_col {
                d.labels[r] = v;
            } else {
                d.set(r, c_out, v);
                c_out += 1;
            }
            seen += 1;
        }
        if seen != ncols_total {
            bail!("row {r} has {seen} columns, expected {ncols_total}");
        }
    }
    Ok(d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic() {
        let text = "a,b,y\n1,2,0\n3,4,1\n";
        let d = parse_csv(text, &CsvOptions { num_classes: 2, ..Default::default() }, "t").unwrap();
        assert_eq!((d.rows, d.cols), (2, 2));
        assert_eq!(d.labels, vec![0.0, 1.0]);
        assert_eq!(d.get(1, 0), 3.0);
    }

    #[test]
    fn label_col_first() {
        let text = "0.5,1,2\n1.5,3,4\n";
        let opts = CsvOptions { has_header: false, label_col: 0, num_classes: 0 };
        let d = parse_csv(text, &opts, "t").unwrap();
        assert_eq!(d.labels, vec![0.5, 1.5]);
        assert_eq!(d.row(0), &[1.0, 2.0]);
    }

    #[test]
    fn non_numeric_becomes_nan() {
        let text = "x,?,1\n";
        let opts = CsvOptions { has_header: false, label_col: 2, num_classes: 0 };
        let d = parse_csv(text, &opts, "t").unwrap();
        assert!(d.get(0, 0).is_nan() && d.get(0, 1).is_nan());
    }

    #[test]
    fn ragged_rows_rejected() {
        let text = "1,2,3\n1,2\n";
        let opts = CsvOptions { has_header: false, ..Default::default() };
        assert!(parse_csv(text, &opts, "t").is_err());
    }
}
