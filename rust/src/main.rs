//! gputreeshap — CLI for the GPUTreeShap reproduction.
//!
//! ```text
//! gputreeshap train    --dataset cal_housing --scale 0.05 --rounds 50 --depth 8 --out model.gtsm
//! gputreeshap info     --model model.gtsm
//! gputreeshap pack     --model model.gtsm
//! gputreeshap backends --model model.gtsm --devices 4 --calibrated
//! gputreeshap explain  --model model.gtsm --dataset cal_housing --rows 256 \
//!                      --backend auto|cpu|host|linear|fastv2|xla|xla-padded --devices 4 --shard-axis auto|rows|trees|tiles
//! gputreeshap shap     …  (alias of explain)
//! gputreeshap interactions --model model.gtsm --dataset adult --rows 32 --backend auto --devices 2
//! gputreeshap predict  --model model.gtsm --dataset adult --rows 16
//! gputreeshap serve    --model model.gtsm --dataset adult --devices 2 --shard-axis rows \
//!                      --clients 4 --requests 32 --recalibrate-every 64
//! gputreeshap serve    --listen 127.0.0.1:7878 --models m1=a.gtsm,m2=b.gtsm --pool-devices 4
//! gputreeshap client explain --addr 127.0.0.1:7878 --name m1 --dataset cal_housing --rows 4
//! gputreeshap client deploy  --addr 127.0.0.1:7878 --alias best --name m2
//! gputreeshap zoo      --scale 0.02
//! ```
//!
//! Every SHAP execution goes through the `backend::ShapBackend` trait;
//! `--backend auto` lets the crossover-aware planner pick, and
//! `--devices N` shards any backend across N device instances
//! (`--shard-axis rows|trees|grid|tiles`; `auto` lets the planner
//! choose — including rows×trees grids like 2×4 when 8 devices meet a
//! 4-tree model and neither simple axis can use them all; `tiles`
//! splits the conditioned-feature set for interaction values on wide
//! models and is opt-in only).
//!
//! The planner starts from a-priori cost constants and self-tunes:
//! `backends --calibrated` micro-measures every constructible backend
//! and prints the measured constants, plans and crossovers next to the
//! priors; `serve --recalibrate-every N` sets the serving executor's
//! measure→calibrate→plan cadence (0 disables adaptation), whose state
//! surfaces under `"planner"` in the final metrics snapshot.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use gputreeshap::backend::{self, BackendKind, DevicePool, Planner};
use gputreeshap::cli::opts::{
    self, backend_config, build_backend, load_dataset, load_model, unknown_backend,
};
use gputreeshap::cli::Args;
use gputreeshap::coordinator::{ModelRegistry, RegistryConfig, Request, ShapService, Task};
use gputreeshap::data::{Dataset, SynthSpec};
use gputreeshap::gbdt::{io as model_io, train, TrainParams, ZooSize};
use gputreeshap::ingress::{Client, IngressServer, ServerConfig};
use gputreeshap::shap::{pack_model, Packing};
use gputreeshap::util::error::Result;
use gputreeshap::util::time_it;
use gputreeshap::{anyhow, bail};

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand() {
        Some("train") => cmd_train(&args),
        Some("info") => cmd_info(&args),
        Some("pack") => cmd_pack(&args),
        Some("backends") => cmd_backends(&args),
        Some("shap") | Some("explain") => cmd_shap(&args),
        Some("interactions") => cmd_interactions(&args),
        Some("predict") => cmd_predict(&args),
        Some("serve") => cmd_serve(&args),
        Some("client") => cmd_client(&args),
        Some("zoo") => cmd_zoo(&args),
        Some("bench-compare") => cmd_bench_compare(&args),
        _ => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const USAGE: &str = "usage: gputreeshap <train|info|pack|backends|explain|shap|interactions|predict|serve|client|zoo|bench-compare> [options]
multi-device: --devices N shards execution; --shard-axis auto|rows|trees|grid|tiles picks the split
  (grid = tree slices × row replicas, for topologies where one axis saturates;
   tiles = conditioned-feature tiles, for interactions on wide models)
memory: --fastv2-max-mb M caps the fastv2 backend's precomputed weight tables (default 512);
  over budget the planner skips fastv2 and an explicit --backend fastv2 errors instead of OOMing
calibration: backends --calibrated measures real constants; serve --recalibrate-every N self-tunes
  and persists learned constants next to the model (--calibration <path|none>)
serving: serve --listen <addr> exposes a multi-model TCP service (--models n=path[;weight=W],…;
  --pool-devices N caps total device slots; weight = fairness share under cross-model pressure);
  client <explain|interactions|predict|load|unload|deploy|list|stats|ping|shutdown>
  --addr <host:port> drives it (deploy: --alias a --name m hot-swaps; --keep-old skips retiring)
scheduling: requests carry --priority interactive|batch (default batch) + optional --deadline-ms D;
  serve --class-target interactive=50,batch=2000 sets per-class latency targets (ms) the batcher
  closes batches against; per-class p50/p99 + slo_violations surface under \"scheduler\" in stats
perf CI: bench-compare --baseline a.json --current b.json [--tolerance 0.2] gates throughput
see rust/src/main.rs header for examples";

fn cmd_train(args: &Args) -> Result<()> {
    let data = load_dataset(args)?;
    let params = TrainParams {
        rounds: args.get_usize("rounds", 50)?,
        max_depth: args.get_usize("depth", 8)?,
        learning_rate: args.get_f64("lr", 0.01)? as f32,
        max_bins: args.get_usize("bins", 64)?,
        threads: args.get_usize("threads", gputreeshap::parallel::default_threads())?,
        ..Default::default()
    };
    println!("training on {} ({} rows × {} cols)…", data.name, data.rows, data.cols);
    let (model, dt) = time_it(|| train(&data, &params));
    println!("trained in {dt:.2}s: {}", model.summary());
    let out = args.get_str("out", "model.gtsm")?;
    model_io::save(&model, Path::new(out))?;
    println!("saved to {out}");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let model = load_model(args)?;
    println!("{}", model.summary());
    let pm = pack_model(&model, Packing::BestFitDecreasing);
    let bins: usize = pm.groups.iter().map(|g| g.num_bins).sum();
    println!(
        "packed: {} bins (bfd), max path depth {}, E[f] = {:?}",
        bins, pm.max_depth, pm.expected_values
    );
    Ok(())
}

fn cmd_pack(args: &Args) -> Result<()> {
    let model = load_model(args)?;
    let mut table = gputreeshap::bench::Table::new(&["alg", "time(s)", "utilisation", "bins"]);
    for alg in Packing::ALL {
        let (pm, dt) = time_it(|| pack_model(&model, alg));
        let bins: usize = pm.groups.iter().map(|g| g.num_bins).sum();
        let active: f64 = pm
            .groups
            .iter()
            .map(|g| g.utilisation * (g.num_bins * gputreeshap::shap::LANES) as f64)
            .sum();
        let util = active / ((bins * gputreeshap::shap::LANES) as f64).max(1.0);
        table.row(vec![
            alg.name().into(),
            format!("{dt:.4}"),
            format!("{util:.6}"),
            bins.to_string(),
        ]);
    }
    table.print();
    Ok(())
}

fn print_plan_table(planner: &Planner) {
    let mut t = gputreeshap::bench::Table::new(&[
        "batch rows",
        "planner choice",
        "shards",
        "axis",
        "est latency(s)",
    ]);
    // 4 sits in the grid regime (1 < rows < devices) where neither
    // simple axis can use a wide topology — keep it in the sweep so
    // `backends --devices 8` shows the nested plan when it wins
    for rows in [1usize, 4, 16, 64, 256, 1024, 4096, 16384] {
        let plan = planner.choose(rows);
        let shards = match plan.grid {
            Some(g) => g.to_string(),
            None => plan.shards.to_string(),
        };
        t.row(vec![
            rows.to_string(),
            plan.kind.name().into(),
            shards,
            plan.axis.name().into(),
            format!("{:.5}", plan.est_latency_s),
        ]);
    }
    t.print();
}

fn print_crossovers(planner: &Planner, label: &str) {
    for fast in [BackendKind::XlaPadded, BackendKind::XlaWarp, BackendKind::Host] {
        if let Some(cross) = planner.crossover_rows(BackendKind::Recursive, fast) {
            println!("\n{label} cpu→{} crossover: ~{cross} rows", fast.name());
        }
    }
}

fn cmd_backends(args: &Args) -> Result<()> {
    let model = Arc::new(load_model(args)?);
    let devices = args.get_usize("devices", 1)?.max(1);
    let fastv2_mb = args.get_usize("fastv2-max-mb", gputreeshap::backend::DEFAULT_FASTV2_MAX_MB)?;
    let planner = Planner::for_model(&model)
        .with_devices(devices)
        .with_fastv2_budget_mb(fastv2_mb);
    println!("{}\n", model.summary());
    let mut table =
        gputreeshap::bench::Table::new(&["backend", "compiled", "setup(s)", "overhead(s)", "rows/s"]);
    for kind in BackendKind::ALL {
        let est = backend::planner::estimate(kind, &planner.shape);
        table.row(vec![
            kind.name().into(),
            kind.compiled_in().to_string(),
            format!("{:.3}", est.setup_s),
            format!("{:.4}", est.batch_overhead_s),
            format!("{:.0}", est.rows_per_s),
        ]);
    }
    table.print();
    println!("\nplanner decisions over {devices} device(s), a-priori:");
    print_plan_table(&planner);
    print_crossovers(&planner, "predicted");

    if args.has_flag("calibrated") {
        // micro-measure every backend that constructs here, feed the
        // samples through the calibration fit, and show what actually
        // changed: constants, plans, crossovers
        let mut planner = planner;
        let mut cfg = backend_config(args, 256)?;
        cfg.devices = 1; // the cost lines are per-instance; sharding math is the planner's
        let m = model.num_features;
        let sizes = [1usize, 16, 128, 512];
        let reps = 3usize;
        let max_rows = *sizes.iter().max().unwrap();
        let mut rng = gputreeshap::util::Rng::new(17);
        let x: Vec<f32> = (0..max_rows * m).map(|_| rng.f32()).collect();
        println!(
            "\nmeasuring each backend over {reps} reps × {sizes:?} synthetic batch rows…"
        );
        let mut obs = backend::Observations::new();
        for (kind, b) in backend::available(&model, &cfg) {
            for _ in 0..reps {
                for &rows in &sizes {
                    let t0 = std::time::Instant::now();
                    if b.contributions(&x[..rows * m], rows).is_ok() {
                        obs.record_backend(kind.name(), rows, t0.elapsed().as_secs_f64());
                    }
                }
            }
        }
        planner.recalibrate(&obs);
        let mut t3 = gputreeshap::bench::Table::new(&[
            "backend",
            "overhead(s) prior→measured",
            "rows/s prior→measured",
            "samples",
        ]);
        for kind in BackendKind::ALL {
            let (Some(prior), Some(cost)) = (planner.prior(kind), planner.cost(kind)) else {
                continue;
            };
            t3.row(vec![
                kind.name().into(),
                format!("{:.5} → {:.5}", prior.batch_overhead_s, cost.batch_overhead_s),
                format!("{:.0} → {:.0}", prior.rows_per_s, cost.rows_per_s),
                planner.calibration_samples(kind).to_string(),
            ]);
        }
        t3.print();
        println!("\nplanner decisions over {devices} device(s), calibrated:");
        print_plan_table(&planner);
        print_crossovers(&planner, "calibrated");
    }
    let (hits, misses) = gputreeshap::backend::prepared::registry_counters();
    println!(
        "\nprepared-model cache: {} live entr(y/ies), {hits} lookup hit(s), {misses} miss(es)",
        gputreeshap::backend::prepared::registry_len()
    );
    Ok(())
}

fn take_rows(data: &Dataset, rows: usize) -> (Vec<f32>, usize) {
    let rows = rows.min(data.rows);
    (data.features[..rows * data.cols].to_vec(), rows)
}

fn cmd_shap(args: &Args) -> Result<()> {
    let model = load_model(args)?;
    let data = load_dataset(args)?;
    if data.cols != model.num_features {
        bail!("dataset has {} features, model expects {}", data.cols, model.num_features);
    }
    let (x, rows) = take_rows(&data, args.get_usize("rows", 256)?);
    let m = model.num_features;
    let groups = model.num_groups;
    let cfg = backend_config(args, rows)?;
    let model = Arc::new(model);
    let (label, b) = build_backend(&model, args, &cfg, "auto")?;
    let (phis, dt) = time_it(|| b.contributions(&x, rows));
    let phis = phis?;
    println!(
        "{} rows × {} groups in {:.3}s ({:.0} rows/s) [{} — {}]",
        rows,
        groups,
        dt,
        rows as f64 / dt,
        label,
        b.describe()
    );
    println!(
        "prep {:.2}ms (measured at build; ~0 on a prepared-model cache hit)",
        b.caps().setup_cost_s * 1e3
    );
    let mut imp: Vec<(usize, f64)> = (0..m)
        .map(|f| {
            let s: f64 = (0..rows)
                .map(|r| (phis[r * groups * (m + 1) + f] as f64).abs())
                .sum();
            (f, s / rows as f64)
        })
        .collect();
    imp.sort_by(|a, b| b.1.total_cmp(&a.1));
    println!("top features by mean |φ| (group 0):");
    for (f, v) in imp.iter().take(8) {
        println!("  f{f:<4} {v:.5}");
    }
    Ok(())
}

fn cmd_interactions(args: &Args) -> Result<()> {
    let model = load_model(args)?;
    let data = load_dataset(args)?;
    let (x, rows) = take_rows(&data, args.get_usize("rows", 32)?);
    let m = model.num_features;
    let groups = model.num_groups;
    let mut cfg = backend_config(args, rows)?;
    cfg.with_interactions = true;
    let model = Arc::new(model);
    let (label, b) = build_backend(&model, args, &cfg, "auto")?;
    let (inter, dt) = time_it(|| b.interactions(&x, rows));
    let inter = inter?;
    println!("{rows} rows interactions in {dt:.3}s [{label} — {}]", b.describe());
    let ms = (m + 1) * (m + 1);
    let mut pairs: Vec<(usize, usize, f64)> = Vec::new();
    for i in 0..m {
        for j in (i + 1)..m {
            let s: f64 = (0..rows)
                .map(|r| (inter[r * groups * ms + i * (m + 1) + j] as f64).abs())
                .sum();
            pairs.push((i, j, s / rows as f64));
        }
    }
    pairs.sort_by(|a, b| b.2.total_cmp(&a.2));
    println!("top interacting pairs by mean |φ_ij|:");
    for (i, j, v) in pairs.iter().take(8) {
        println!("  (f{i}, f{j})  {v:.6}");
    }
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<()> {
    let model = load_model(args)?;
    let data = load_dataset(args)?;
    let (x, rows) = take_rows(&data, args.get_usize("rows", 16)?);
    let groups = model.num_groups;
    let mut cfg = backend_config(args, rows)?;
    cfg.with_predict = true;
    let model = Arc::new(model);
    let (label, b) = build_backend(&model, args, &cfg, "cpu")?;
    let preds = b.predictions(&x, rows)?;
    println!("[{label}]");
    for r in 0..rows.min(16) {
        println!("row {r}: {:?}", &preds[r * groups..(r + 1) * groups]);
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    // `--listen <addr>` switches from the loopback load demo to the
    // network ingress + multi-model registry
    if let Some(listen) = args.get("listen") {
        return cmd_serve_listen(args, listen);
    }
    let model = load_model(args)?;
    let data = load_dataset(args)?;
    let m = model.num_features;
    let devices = args.get_usize("devices", 1)?;
    let clients = args.get_usize("clients", 4)?;
    let requests = args.get_usize("requests", 32)?;
    let req_rows = args.get_usize("req-rows", 16)?;

    let cfg = opts::service_config(args)?;
    if let Some(p) = &cfg.calibration_path {
        if p.exists() {
            println!("calibration: reloading measured constants from {}", p.display());
        } else {
            println!("calibration: will persist measured constants to {}", p.display());
        }
    }
    let bcfg = backend_config(args, cfg.max_batch_rows)?;
    let model = Arc::new(model);
    let (label, svc) = match args.get_str("backend", "auto")? {
        "auto" => {
            let (kind, svc) = ShapService::start_planned(model.clone(), bcfg, cfg)?;
            (format!("auto→{}", kind.name()), svc)
        }
        s => {
            let kind = BackendKind::parse(s).ok_or_else(|| unknown_backend(s))?;
            (
                kind.name().to_string(),
                ShapService::start(model.clone(), kind, bcfg, cfg)?,
            )
        }
    };
    println!(
        "service up [{label}]: {devices} device(s); {clients} clients × {requests} requests × {req_rows} rows"
    );

    let svc = Arc::new(svc);
    let data = Arc::new(data);
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let svc = svc.clone();
            let data = data.clone();
            scope.spawn(move || {
                for q in 0..requests {
                    let start = (c * 31 + q * 7) % (data.rows.saturating_sub(req_rows).max(1));
                    let x = data.features[start * m..(start + req_rows) * m].to_vec();
                    if let Err(e) = svc.explain(x, req_rows) {
                        eprintln!("client {c} request {q}: {e:#}");
                    }
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let total_rows = clients * requests * req_rows;
    println!(
        "done in {wall:.2}s → {:.0} rows/s, {:.1} req/s",
        total_rows as f64 / wall,
        (clients * requests) as f64 / wall
    );
    let svc = Arc::try_unwrap(svc).ok().expect("clients done");
    println!("metrics: {}", svc.metrics.snapshot().to_string_pretty());
    svc.shutdown();
    Ok(())
}

/// `serve --listen <addr>`: the network ingress — a TCP front end over
/// a multi-model registry. Models come from `--model <path>`
/// (`--name` optional, defaults to the file stem) and/or
/// `--models name=path,…`; more can be loaded at runtime via
/// `client load`. `--pool-devices N` caps total device slots across
/// all models (0 = unbounded); each model's executor takes `--devices`
/// slots. Runs until `client shutdown` arrives, then drains every
/// executor gracefully.
fn cmd_serve_listen(args: &Args, listen: &str) -> Result<()> {
    let mut scfg = opts::service_config(args)?;
    // per-model calibration is keyed by the registry (entry name under
    // --calibration-dir, else <source>.calib.json); the single-model
    // template path would smear one model's constants over all of them
    scfg.calibration_path = None;
    let mut bcfg = backend_config(args, scfg.max_batch_rows)?;
    bcfg.with_interactions = true;
    bcfg.with_predict = true;
    let rcfg = RegistryConfig {
        service: scfg,
        backend: bcfg,
        kind: opts::backend_kind(args, "auto")?,
        calibration_dir: args.get("calibration-dir").map(PathBuf::from),
    };
    let pool = match args.get_usize("pool-devices", 0)? {
        0 => DevicePool::unbounded(),
        n => DevicePool::new(n),
    };
    let registry = Arc::new(ModelRegistry::new(rcfg, pool));

    if let Some(mp) = args.get("model") {
        let path = Path::new(mp);
        let name = opts::model_name(args, path)?;
        registry.load_path(&name, path)?;
        println!("loaded '{name}' from {mp}");
    }
    if let Some(spec) = args.get("models") {
        for (name, path, weight) in opts::parse_model_manifest(spec)? {
            registry.load_path_weighted(&name, &path, weight)?;
            if weight != 1.0 {
                println!("loaded '{name}' from {} (weight {weight})", path.display());
            } else {
                println!("loaded '{name}' from {}", path.display());
            }
        }
    }

    let server = IngressServer::bind(
        listen,
        registry.clone(),
        ServerConfig {
            max_conns: args.get_usize("max-conns", 64)?,
            ..Default::default()
        },
    )?;
    println!("listening on {}", server.local_addr()?);
    // under redirection stdout is block-buffered: flush so drivers
    // (the CI smoke) can read the bound address while we serve
    use std::io::Write as _;
    let _ = std::io::stdout().flush();
    server.run()?;
    println!("shutting down: draining executors…");
    registry.drain_all();
    println!("final stats: {}", registry.stats(None)?.to_string_pretty());
    Ok(())
}

/// `client <verb> --addr <host:port> […]`: drive a `serve --listen`
/// server over the wire. Explain verbs read `--dataset`/`--rows` rows
/// and route them to `--name <model|alias>`; `deploy` hot-swaps
/// `--alias` onto `--name` (retiring the old target unless
/// `--keep-old`).
fn cmd_client(args: &Args) -> Result<()> {
    let addr =
        args.get("addr").ok_or_else(|| anyhow!("--addr <host:port> required"))?;
    let verb = args.positional.get(1).map(|s| s.as_str()).ok_or_else(|| {
        anyhow!(
            "usage: client <{}|load|unload|deploy|list|stats|ping|shutdown> --addr <host:port>",
            Task::name_list()
        )
    })?;
    let mut client = Client::connect(addr)?;
    if let Some(task) = Task::parse(verb) {
        let name =
            args.get("name").ok_or_else(|| anyhow!("--name <model|alias> required"))?;
        let data = load_dataset(args)?;
        let rows = args.get_usize("rows", 4)?.min(data.rows);
        let x = data.features[..rows * data.cols].to_vec();
        let (class, deadline) = opts::request_class(args)?;
        let mut req = Request::new(task, x, rows).with_priority(class);
        if let Some(ms) = deadline {
            req = req.with_deadline_ms(ms);
        }
        let resp = client.submit(name, req)?;
        let (rows, cols) = (resp.rows, resp.cols);
        let values = resp.into_values()?;
        println!("ok: {} via '{name}' → {rows} rows × {cols} cols", task.name());
        let peek = cols.min(8).min(values.len());
        println!("row 0: {:?}…", &values[..peek]);
        return Ok(());
    }
    match verb {
        "load" => {
            let name = args.get("name").ok_or_else(|| anyhow!("--name required"))?;
            let path = args.get("path").ok_or_else(|| anyhow!("--path required"))?;
            client.load(name, path)?;
            println!("ok: loaded '{name}' from {path}");
        }
        "unload" => {
            let name = args.get("name").ok_or_else(|| anyhow!("--name required"))?;
            client.unload(name)?;
            println!("ok: unloaded '{name}'");
        }
        "deploy" => {
            let alias = args.get("alias").ok_or_else(|| anyhow!("--alias required"))?;
            let name = args.get("name").ok_or_else(|| anyhow!("--name <model> required"))?;
            let reply = client.deploy(alias, name, !args.has_flag("keep-old"))?;
            let retired = match reply.get("retired") {
                Ok(gputreeshap::util::Json::Str(s)) => format!(" (retired '{s}')"),
                _ => String::new(),
            };
            println!("ok: deployed '{alias}' → '{name}'{retired}");
        }
        "list" => println!("{}", client.list()?.to_string_pretty()),
        "stats" => println!("{}", client.stats(args.get("name"))?.to_string_pretty()),
        "ping" => println!("ok: serving {:?}", client.ping()?),
        "shutdown" => {
            client.shutdown()?;
            println!("ok: server stopping");
        }
        other => bail!(
            "unknown client verb '{other}' ({}|load|unload|deploy|list|stats|ping|shutdown)",
            Task::name_list()
        ),
    }
    Ok(())
}

fn cmd_bench_compare(args: &Args) -> Result<()> {
    use gputreeshap::bench::compare::compare_reports;
    use gputreeshap::util::Json;
    let baseline_path = args
        .get("baseline")
        .ok_or_else(|| anyhow!("--baseline <path> required"))?;
    let current_path = args
        .get("current")
        .ok_or_else(|| anyhow!("--current <path> required"))?;
    let tolerance = args.get_f64("tolerance", 0.2)?;
    // a missing baseline is a warning-pass, not a failure: the first
    // run on a fresh branch has nothing to compare against, and the
    // refresh step on main writes the real one
    let baseline_text = match std::fs::read_to_string(baseline_path) {
        Ok(t) => t,
        Err(_) => {
            println!("bench-compare: no baseline at {baseline_path} — skipping (pass)");
            return Ok(());
        }
    };
    let baseline = Json::parse(&baseline_text)
        .map_err(|e| anyhow!("parsing baseline {baseline_path}: {e:#}"))?;
    let current_text = std::fs::read_to_string(current_path)
        .map_err(|e| anyhow!("reading current report {current_path}: {e}"))?;
    let current =
        Json::parse(&current_text).map_err(|e| anyhow!("parsing {current_path}: {e:#}"))?;

    let cmp = compare_reports(&baseline, &current, tolerance);
    // coverage changes are visible but never gate: the baseline refresh
    // on main catches the report shape up
    for m in &cmp.new_metrics {
        println!("bench-compare: new metric (not in baseline): {m}");
    }
    for m in &cmp.dropped_metrics {
        println!("bench-compare: dropped metric (baseline only): {m}");
    }
    if cmp.compared == 0 {
        println!(
            "bench-compare: no shared throughput metrics between {baseline_path} and \
             {current_path} — nothing to gate (pass)"
        );
        return Ok(());
    }
    let mut table = gputreeshap::bench::Table::new(&["metric", "baseline", "current", "drop"]);
    for r in &cmp.regressions {
        table.row(vec![
            r.metric.clone(),
            format!("{:.0}", r.baseline),
            format!("{:.0}", r.current),
            format!("{:.0}%", r.drop_fraction() * 100.0),
        ]);
    }
    if cmp.is_pass() {
        println!(
            "bench-compare: {} throughput metric(s) within {:.0}% of baseline (pass)",
            cmp.compared,
            tolerance * 100.0
        );
        Ok(())
    } else {
        table.print();
        bail!(
            "bench-compare: {}/{} throughput metric(s) regressed more than {:.0}% vs baseline",
            cmp.regressions.len(),
            cmp.compared,
            tolerance * 100.0
        )
    }
}

fn cmd_zoo(args: &Args) -> Result<()> {
    let scale = args.get_f64("scale", 0.01)?;
    let mut table = gputreeshap::bench::Table::new(&["model", "trees", "leaves", "max_depth"]);
    for spec in SynthSpec::all(scale) {
        let data = spec.generate();
        for size in [ZooSize::Small, ZooSize::Medium, ZooSize::Large] {
            let (rounds, depth) = size.rounds_depth();
            let model =
                train(&data, &TrainParams { rounds, max_depth: depth, ..Default::default() });
            table.row(vec![
                format!("{}-{}", spec.name, size.name()),
                model.trees.len().to_string(),
                model.total_leaves().to_string(),
                model.max_depth().to_string(),
            ]);
        }
    }
    table.print();
    Ok(())
}
