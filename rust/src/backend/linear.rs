//! The Linear TreeShap [`ShapBackend`]: exact φ in time linear in tree
//! size (`shap::linear`), built from per-tree polynomial summaries
//! cached in the prepared model. φ-only — `supports_interactions` is
//! `false`, so `build_auto` routes Φ requests past it to a capable
//! backend; predictions are served by raw tree routing.
//!
//! Construction goes through the prepared-model cache: the summary
//! tables (interpolation grid, per-node cover ratios and heights) are
//! built once per model and shared by every instance — row shards, grid
//! replicas, executor rebuilds. The setup cost reported is the
//! *measured* time to obtain them, which collapses to the cache-lookup
//! cost on a warm rebuild.

use std::sync::Arc;

use crate::backend::{planner, prepared, BackendCaps, BackendKind, PreparedModel, ShapBackend};
use crate::gbdt::Model;
use crate::shap::linear::{self, LinearModel};
use crate::util::error::Result;
use crate::util::time_it;

pub struct LinearBackend {
    lm: Arc<LinearModel>,
    model: Arc<Model>,
    prep: Arc<PreparedModel>,
    threads: usize,
    caps: BackendCaps,
}

impl LinearBackend {
    pub fn new(model: &Arc<Model>, threads: usize) -> LinearBackend {
        LinearBackend::with_prepared(prepared::prepare(model), threads)
    }

    /// Construct over an existing prepared-model cache entry (the path
    /// every `backend::build` takes; `new` is the one-model shorthand).
    pub fn with_prepared(prep: Arc<PreparedModel>, threads: usize) -> LinearBackend {
        let shape = prep.shape();
        let (lm, setup_s) = time_it(|| prep.linear());
        let est = planner::estimate(BackendKind::Linear, &shape);
        LinearBackend {
            lm,
            model: Arc::clone(prep.model()),
            prep,
            threads,
            caps: BackendCaps {
                supports_interactions: false,
                setup_cost_s: setup_s,
                batch_overhead_s: est.batch_overhead_s,
                rows_per_s: est.rows_per_s,
            },
        }
    }
}

impl ShapBackend for LinearBackend {
    fn name(&self) -> &'static str {
        BackendKind::Linear.name()
    }

    fn caps(&self) -> BackendCaps {
        self.caps
    }

    fn num_features(&self) -> usize {
        self.lm.num_features
    }

    fn num_groups(&self) -> usize {
        self.lm.num_groups
    }

    fn contributions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        Ok(linear::shap_values(&self.lm, x, rows, self.threads))
    }

    fn interactions(&self, _x: &[f32], _rows: usize) -> Result<Vec<f32>> {
        Err(crate::anyhow!(
            "backend 'linear' computes φ only; request interactions via --backend auto \
             so a Φ-capable backend serves them"
        ))
    }

    fn predictions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        let m = self.model.num_features;
        let g = self.model.num_groups;
        let mut out = Vec::with_capacity(rows * g);
        for r in 0..rows {
            out.extend(self.model.predict_row_raw(&x[r * m..(r + 1) * m]));
        }
        Ok(out)
    }

    fn prepared(&self) -> Option<&Arc<PreparedModel>> {
        Some(&self.prep)
    }

    fn describe(&self) -> String {
        format!(
            "linear[tree-summaries, {} interpolation points, {} threads]",
            self.lm.points(),
            self.threads
        )
    }
}
