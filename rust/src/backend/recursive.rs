//! The recursive Algorithm 1 baseline as a [`ShapBackend`]: zero setup,
//! zero batch overhead, per-row cost quadratic in path depth. The
//! planner's pick for small, latency-sensitive batches, and the parity
//! oracle every other backend is checked against.
//!
//! Even this "no-prep" backend goes through the prepared-model cache:
//! its cost metadata needs the model's shape statistics, whose path
//! extraction is the same walk the packed layouts start from — cached,
//! it is paid once per model instead of once per construction.

use std::sync::Arc;

use crate::backend::{planner, prepared, BackendCaps, BackendKind, PreparedModel, ShapBackend};
use crate::gbdt::Model;
use crate::shap::{interactions, treeshap};
use crate::util::error::Result;

pub struct RecursiveBackend {
    model: Arc<Model>,
    prep: Arc<PreparedModel>,
    threads: usize,
    caps: BackendCaps,
}

impl RecursiveBackend {
    pub fn new(model: Arc<Model>, threads: usize) -> RecursiveBackend {
        RecursiveBackend::with_prepared(prepared::prepare(&model), threads)
    }

    /// Construct over an existing prepared-model cache entry.
    pub fn with_prepared(prep: Arc<PreparedModel>, threads: usize) -> RecursiveBackend {
        let shape = prep.shape();
        let est = planner::estimate(BackendKind::Recursive, &shape);
        RecursiveBackend {
            model: Arc::clone(prep.model()),
            prep,
            threads,
            caps: BackendCaps {
                supports_interactions: true,
                setup_cost_s: est.setup_s,
                batch_overhead_s: est.batch_overhead_s,
                rows_per_s: est.rows_per_s,
            },
        }
    }
}

impl ShapBackend for RecursiveBackend {
    fn name(&self) -> &'static str {
        BackendKind::Recursive.name()
    }

    fn caps(&self) -> BackendCaps {
        self.caps
    }

    fn num_features(&self) -> usize {
        self.model.num_features
    }

    fn num_groups(&self) -> usize {
        self.model.num_groups
    }

    fn contributions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        Ok(treeshap::shap_values(&self.model, x, rows, self.threads))
    }

    fn interactions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        // route the per-tree feature lists and expected values through
        // the prepared cache instead of re-deriving them per call
        let feats = self.prep.tile_features();
        Ok(interactions::interaction_values_with(
            &self.model,
            x,
            rows,
            self.threads,
            &feats.per_tree,
            self.prep.expected_values(),
        ))
    }

    fn interactions_block(
        &self,
        x: &[f32],
        rows: usize,
        lo: usize,
        hi: usize,
    ) -> Result<Vec<f64>> {
        let feats = self.prep.tile_features();
        Ok(interactions::interaction_block(
            &self.model,
            x,
            rows,
            self.threads,
            lo,
            hi,
            &feats.per_tree,
        ))
    }

    fn contributions_f64(&self, x: &[f32], rows: usize) -> Result<Vec<f64>> {
        Ok(interactions::phis_f64(&self.model, x, rows, self.threads))
    }

    fn predictions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        let m = self.model.num_features;
        let g = self.model.num_groups;
        let mut out = Vec::with_capacity(rows * g);
        for r in 0..rows {
            out.extend(self.model.predict_row_raw(&x[r * m..(r + 1) * m]));
        }
        Ok(out)
    }

    fn prepared(&self) -> Option<&Arc<PreparedModel>> {
        Some(&self.prep)
    }

    fn describe(&self) -> String {
        format!("cpu[recursive, {} threads]", self.threads)
    }
}
