//! The multi-device [`ShapBackend`]: wraps N inner backend instances
//! (one per device) and executes contributions, interactions and
//! predictions across them along a [`ShardAxis`].
//!
//! - **Rows**: inner instances all hold the full model; row chunks are
//!   handed out through a shared cursor (finer than one chunk per shard,
//!   so a failed shard aborts the remaining work promptly) and outputs
//!   are written into disjoint ranges of one buffer.
//! - **Trees**: inner instances each hold a leaf-balanced slice of the
//!   ensemble; every shard runs the full batch and the per-shard φ/Φ are
//!   summed with the `(shards − 1) · base_score` correction of
//!   [`shard::correct_base`].
//!
//! Failure semantics (the fix for the old `runtime/pool.rs`): a failed
//! shard sets an abort flag that stops idle shards from taking more
//! work, every shard error is aggregated into the returned error, and
//! no result is returned unless every chunk completed — no hang, no
//! silent partial output.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::backend::shard::{self, row_chunks, split_trees, ShardAxis, ShardTask};
use crate::backend::{self, BackendCaps, BackendConfig, BackendKind, ShapBackend, ShardObserver};
use crate::gbdt::Model;
use crate::util::error::{Error, Result};

/// How many row chunks per shard the rows-axis queue is cut into:
/// finer chunks mean prompter abort on failure and better balance when
/// devices run at different speeds, at a small per-chunk dispatch cost.
const CHUNKS_PER_SHARD: usize = 4;

pub struct ShardedBackend {
    inner: Vec<Box<dyn ShapBackend>>,
    axis: ShardAxis,
    /// the wrapped kind's name — metrics keep aggregating per backend
    /// kind; shard granularity is reported through the observer
    kind_name: &'static str,
    num_features: usize,
    num_groups: usize,
    base_score: f32,
    observer: Option<ShardObserver>,
    caps: BackendCaps,
}

impl ShardedBackend {
    /// Build `shards` instances of `kind` over `model`, split along
    /// `axis`. `shards` is clamped to the tree count on the tree axis.
    pub fn build(
        model: &Arc<Model>,
        kind: BackendKind,
        cfg: &BackendConfig,
        shards: usize,
        axis: ShardAxis,
    ) -> Result<ShardedBackend> {
        let mut inner_cfg = cfg.clone();
        inner_cfg.devices = 1; // inner builds must not re-shard
        inner_cfg.shard_axis = None;
        let shards = match axis {
            ShardAxis::Rows => shards.max(1),
            ShardAxis::Trees => shards.clamp(1, model.trees.len().max(1)),
        };
        if let ShardAxis::Rows = axis {
            // row shards execute rows/(shards·CHUNKS_PER_SHARD)-row
            // chunks, so size the inner backends' batch bucket to the
            // chunk, not the full batch — device backends pad every
            // execution to their prepared bucket, and a full-batch
            // bucket would cost chunk-count× the unsharded device work
            let per_chunk = shards * CHUNKS_PER_SHARD;
            inner_cfg.rows_hint = (cfg.rows_hint.max(1) + per_chunk - 1) / per_chunk;
        }
        // one (sub-)model per shard; Rows shards all hold the full model
        let sub_models: Vec<Arc<Model>> = match axis {
            ShardAxis::Rows => (0..shards).map(|_| Arc::clone(model)).collect(),
            ShardAxis::Trees => split_trees(model, shards).into_iter().map(Arc::new).collect(),
        };
        // build the inner instances concurrently, one per thread — setup
        // (packing, device client + executable compilation) is the
        // dominant cost at high shard counts, and on device backends the
        // client should be constructed on its own thread anyway
        let inner = build_concurrently(&sub_models, kind, &inner_cfg)?;
        Ok(ShardedBackend::from_backends(inner, axis, model.base_score))
    }

    /// Wrap pre-built shard backends. On the tree axis the caller is
    /// responsible for the inner backends holding disjoint tree slices
    /// whose union is the full ensemble (as [`split_trees`] produces).
    pub fn from_backends(
        inner: Vec<Box<dyn ShapBackend>>,
        axis: ShardAxis,
        base_score: f32,
    ) -> ShardedBackend {
        assert!(!inner.is_empty(), "sharded backend needs ≥1 shard");
        let supports_interactions = inner.iter().all(|b| b.caps().supports_interactions);
        let setup = inner.iter().map(|b| b.caps().setup_cost_s).fold(0.0, f64::max);
        let overhead =
            inner.iter().map(|b| b.caps().batch_overhead_s).fold(0.0, f64::max);
        // rows: devices run disjoint rows concurrently (rates add);
        // trees: every device runs every row (slowest slice gates)
        let rows_per_s = match axis {
            ShardAxis::Rows => inner.iter().map(|b| b.caps().rows_per_s).sum(),
            ShardAxis::Trees => inner
                .iter()
                .map(|b| b.caps().rows_per_s)
                .fold(f64::INFINITY, f64::min),
        };
        ShardedBackend {
            kind_name: inner[0].name(),
            num_features: inner[0].num_features(),
            num_groups: inner[0].num_groups(),
            base_score,
            axis,
            observer: None,
            caps: BackendCaps {
                supports_interactions,
                setup_cost_s: setup,
                batch_overhead_s: overhead,
                rows_per_s,
            },
            inner,
        }
    }

    pub fn shards(&self) -> usize {
        self.inner.len()
    }

    pub fn axis(&self) -> ShardAxis {
        self.axis
    }

    fn observe(&self, shard: usize, rows: usize, started: Instant) {
        if let Some(obs) = &self.observer {
            (obs.as_ref())(shard, rows, started.elapsed());
        }
    }

    /// Rows axis: shards pull `(start, len)` chunks from a shared queue
    /// and write into disjoint ranges of one output buffer.
    fn run_rows<F>(&self, x: &[f32], rows: usize, stride: usize, f: F) -> Result<Vec<f32>>
    where
        F: Fn(&dyn ShapBackend, &[f32], usize) -> Result<Vec<f32>> + Sync,
    {
        let m = self.num_features;
        let n = self.inner.len();
        if n == 1 || rows <= 1 {
            let t0 = Instant::now();
            let out = f(self.inner[0].as_ref(), x, rows)?;
            self.observe(0, rows, t0);
            return Ok(out);
        }
        let chunks = row_chunks(rows, n * CHUNKS_PER_SHARD);
        let cursor = AtomicUsize::new(0);
        let abort = AtomicBool::new(false);
        let errs: Mutex<Vec<Error>> = Mutex::new(Vec::new());
        let mut out = vec![0.0f32; rows * stride];
        let mut done = 0usize;
        let (tx, rx) = std::sync::mpsc::channel::<(usize, Vec<f32>)>();
        std::thread::scope(|scope| {
            for (si, b) in self.inner.iter().enumerate() {
                let (cursor, abort, errs) = (&cursor, &abort, &errs);
                let (chunks, f, this) = (&chunks, &f, &*self);
                let b = b.as_ref();
                let tx = tx.clone();
                scope.spawn(move || loop {
                    if abort.load(Ordering::Relaxed) {
                        return;
                    }
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    let Some(&(r0, rc)) = chunks.get(i) else { return };
                    let t0 = Instant::now();
                    match f(b, &x[r0 * m..(r0 + rc) * m], rc) {
                        Ok(vals) if vals.len() == rc * stride => {
                            this.observe(si, rc, t0);
                            // the receiver lives until every sender is
                            // dropped; a failed send means the call is
                            // being torn down — stop instead of ignoring
                            if tx.send((r0, vals)).is_err() {
                                return;
                            }
                        }
                        Ok(vals) => {
                            abort.store(true, Ordering::Relaxed);
                            errs.lock().unwrap().push(crate::anyhow!(
                                "shard {si}: expected {} output floats, got {}",
                                rc * stride,
                                vals.len()
                            ));
                            return;
                        }
                        Err(e) => {
                            abort.store(true, Ordering::Relaxed);
                            errs.lock().unwrap().push(e.context(format!("shard {si}")));
                            return;
                        }
                    }
                });
            }
            drop(tx);
            // assemble chunks into their disjoint ranges as they arrive
            // (no shared output lock); `rx` closes once every worker has
            // dropped its sender, which also bounds this loop
            for (r0, vals) in rx.iter() {
                let rc = vals.len() / stride;
                out[r0 * stride..(r0 + rc) * stride].copy_from_slice(&vals);
                done += rc;
            }
        });
        let errs = errs.into_inner().unwrap();
        if !errs.is_empty() {
            return Err(aggregate(errs));
        }
        debug_assert_eq!(done, rows);
        Ok(out)
    }

    /// Trees axis: every shard runs the full batch over its slice of the
    /// ensemble; partial outputs are summed and the base surplus removed.
    fn run_trees<F>(
        &self,
        x: &[f32],
        rows: usize,
        task: ShardTask,
        f: F,
    ) -> Result<Vec<f32>>
    where
        F: Fn(&dyn ShapBackend, &[f32], usize) -> Result<Vec<f32>> + Sync,
    {
        let stride = task.stride(self.num_groups, self.num_features);
        let n = self.inner.len();
        if n == 1 {
            let t0 = Instant::now();
            let out = f(self.inner[0].as_ref(), x, rows)?;
            self.observe(0, rows, t0);
            return Ok(out);
        }
        let errs: Mutex<Vec<Error>> = Mutex::new(Vec::new());
        let partials = Mutex::new(vec![None::<Vec<f32>>; n]);
        std::thread::scope(|scope| {
            for (si, b) in self.inner.iter().enumerate() {
                let (errs, partials) = (&errs, &partials);
                let (f, this) = (&f, &*self);
                let b = b.as_ref();
                scope.spawn(move || {
                    let t0 = Instant::now();
                    match f(b, x, rows) {
                        Ok(vals) if vals.len() == rows * stride => {
                            this.observe(si, rows, t0);
                            partials.lock().unwrap()[si] = Some(vals);
                        }
                        Ok(vals) => {
                            errs.lock().unwrap().push(crate::anyhow!(
                                "shard {si}: expected {} output floats, got {}",
                                rows * stride,
                                vals.len()
                            ));
                        }
                        Err(e) => {
                            errs.lock().unwrap().push(e.context(format!("shard {si}")));
                        }
                    }
                });
            }
        });
        let errs = errs.into_inner().unwrap();
        if !errs.is_empty() {
            return Err(aggregate(errs));
        }
        let mut acc = vec![0.0f32; rows * stride];
        for partial in partials.into_inner().unwrap() {
            let partial = partial.expect("no error ⇒ every shard produced output");
            for (a, v) in acc.iter_mut().zip(&partial) {
                *a += v;
            }
        }
        shard::correct_base(
            &mut acc,
            task,
            n,
            self.base_score,
            rows,
            self.num_groups,
            self.num_features,
        );
        Ok(acc)
    }

    fn run<F>(&self, x: &[f32], rows: usize, task: ShardTask, f: F) -> Result<Vec<f32>>
    where
        F: Fn(&dyn ShapBackend, &[f32], usize) -> Result<Vec<f32>> + Sync,
    {
        match self.axis {
            ShardAxis::Rows => {
                self.run_rows(x, rows, task.stride(self.num_groups, self.num_features), f)
            }
            ShardAxis::Trees => self.run_trees(x, rows, task, f),
        }
    }
}

/// Build one backend instance per (sub-)model, each on its own thread.
fn build_concurrently(
    sub_models: &[Arc<Model>],
    kind: BackendKind,
    cfg: &BackendConfig,
) -> Result<Vec<Box<dyn ShapBackend>>> {
    if sub_models.len() == 1 {
        return Ok(vec![backend::build(&sub_models[0], kind, cfg)?]);
    }
    let slots: Mutex<Vec<Option<Result<Box<dyn ShapBackend>>>>> =
        Mutex::new(sub_models.iter().map(|_| None).collect());
    std::thread::scope(|scope| {
        for (i, sub) in sub_models.iter().enumerate() {
            let slots = &slots;
            scope.spawn(move || {
                let built = backend::build(sub, kind, cfg);
                slots.lock().unwrap()[i] = Some(built);
            });
        }
    });
    slots
        .into_inner()
        .unwrap()
        .into_iter()
        .enumerate()
        .map(|(i, slot)| {
            slot.expect("every build thread fills its slot")
                .map_err(|e| e.context(format!("shard {i}")))
        })
        .collect()
}

/// One error per failed shard, folded into a single aggregate.
fn aggregate(mut errs: Vec<Error>) -> Error {
    if errs.len() == 1 {
        return errs.pop().unwrap();
    }
    let msgs: Vec<String> = errs.iter().map(|e| format!("{e:#}")).collect();
    crate::anyhow!("{} shard(s) failed: {}", errs.len(), msgs.join("; "))
}

impl ShapBackend for ShardedBackend {
    fn name(&self) -> &'static str {
        self.kind_name
    }

    fn caps(&self) -> BackendCaps {
        self.caps
    }

    fn num_features(&self) -> usize {
        self.num_features
    }

    fn num_groups(&self) -> usize {
        self.num_groups
    }

    fn contributions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        self.run(x, rows, ShardTask::Contributions, |b, x, r| b.contributions(x, r))
    }

    fn interactions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        self.run(x, rows, ShardTask::Interactions, |b, x, r| b.interactions(x, r))
    }

    fn predictions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        self.run(x, rows, ShardTask::Predictions, |b, x, r| b.predictions(x, r))
    }

    fn set_shard_observer(&mut self, obs: ShardObserver) {
        self.observer = Some(obs);
    }

    fn describe(&self) -> String {
        format!(
            "sharded[{}×{} axis, {}]",
            self.inner.len(),
            self.axis.name(),
            self.inner[0].describe()
        )
    }
}
