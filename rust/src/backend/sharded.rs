//! The multi-device [`ShapBackend`]: wraps N inner backend instances
//! (one per device) and executes contributions, interactions and
//! predictions across them along a [`ShardAxis`].
//!
//! - **Rows**: inner instances all hold the full model; each shard gets
//!   a queue of row chunks sized to its measured throughput (equal on a
//!   cold start), drains its own queue first and steals from slower
//!   shards when idle, and outputs are written into disjoint ranges of
//!   one buffer. Per-chunk wall times feed an EWMA throughput estimate
//!   per shard, so chunk sizing adapts to heterogeneous devices —
//!   straggler mitigation for mixed CPU/GPU topologies; the coordinator
//!   can also seed the estimates from its recorded per-shard latencies
//!   via [`ShapBackend::set_shard_throughputs`].
//! - **Trees**: inner instances each hold a leaf-balanced slice of the
//!   ensemble; every shard runs the full batch and the per-shard φ/Φ are
//!   summed with the `(shards − 1) · base_score` correction of
//!   [`shard::correct_base`].
//!
//! Failure semantics (the fix for the old `runtime/pool.rs`): a failed
//! shard sets an abort flag that stops idle shards from taking more
//! work, every shard error is aggregated into the returned error, and
//! no result is returned unless every chunk completed — no hang, no
//! silent partial output. The indices of failed shards are retained
//! ([`ShapBackend::failed_shards`]) so callers can go further than
//! reporting: [`ShapBackend::quarantine`] removes the failed shards
//! from the topology (rebuilding the ensemble split on the tree axis)
//! and [`ShapBackend::hot_add`] grows it back once the device recovers
//! — the elastic paths the serving coordinator drives.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::backend::shard::{
    self, split_trees, weighted_chunks, ShardAxis, ShardTask, CHUNKS_PER_SHARD,
};
use crate::backend::{self, BackendCaps, BackendConfig, BackendKind, ShapBackend, ShardObserver};
use crate::gbdt::Model;
use crate::util::error::{Error, Result};

/// Weight of the newest per-chunk throughput sample in the per-shard
/// EWMA (the rest stays on the running estimate).
const TPUT_EWMA: f64 = 0.3;

/// Everything needed to rebuild the topology at a different shard count
/// — present when the backend was built through [`ShardedBackend::build`]
/// (the elastic quarantine/hot-add paths need it on the tree axis, where
/// survivors must re-cover the full ensemble).
struct Recipe {
    model: Arc<Model>,
    kind: BackendKind,
    cfg: BackendConfig,
}

pub struct ShardedBackend {
    inner: Vec<Box<dyn ShapBackend>>,
    axis: ShardAxis,
    /// the wrapped kind's name — metrics keep aggregating per backend
    /// kind; shard granularity is reported through the observer
    kind_name: &'static str,
    num_features: usize,
    num_groups: usize,
    base_score: f32,
    observer: Option<ShardObserver>,
    caps: BackendCaps,
    /// per-shard throughput estimate (rows/s), `None` until measured;
    /// drives the weighted row-chunk split
    tput: Mutex<Vec<Option<f64>>>,
    /// shard indices that failed in the most recent execution
    last_failed: Mutex<Vec<usize>>,
    rebuild: Option<Recipe>,
    /// shards removed by quarantine since construction (stats/describe)
    quarantined: usize,
}

impl ShardedBackend {
    /// Build `shards` instances of `kind` over `model`, split along
    /// `axis`. `shards` is clamped to the tree count on the tree axis.
    pub fn build(
        model: &Arc<Model>,
        kind: BackendKind,
        cfg: &BackendConfig,
        shards: usize,
        axis: ShardAxis,
    ) -> Result<ShardedBackend> {
        let mut inner_cfg = cfg.clone();
        inner_cfg.devices = 1; // inner builds must not re-shard
        inner_cfg.shard_axis = None;
        let shards = match axis {
            ShardAxis::Rows => shards.max(1),
            ShardAxis::Trees => shards.clamp(1, model.trees.len().max(1)),
            ShardAxis::Grid => {
                return Err(crate::anyhow!(
                    "grid topologies are executed by GridBackend, not ShardedBackend"
                ))
            }
            ShardAxis::FeatureTiles => {
                return Err(crate::anyhow!(
                    "feature-tile topologies are executed by TilesBackend, not ShardedBackend"
                ))
            }
        };
        if let ShardAxis::Rows = axis {
            // row shards execute rows/(shards·CHUNKS_PER_SHARD)-row
            // chunks, so size the inner backends' batch bucket to the
            // chunk, not the full batch — device backends pad every
            // execution to their prepared bucket, and a full-batch
            // bucket would cost chunk-count× the unsharded device work
            let per_chunk = shards * CHUNKS_PER_SHARD;
            inner_cfg.rows_hint = (cfg.rows_hint.max(1) + per_chunk - 1) / per_chunk;
        }
        // one (sub-)model per shard; Rows shards all hold the full model
        // and therefore share ONE prepared-model cache entry — warm it
        // here so the N concurrent inner builds below all hit (the model
        // packs once, not once per device). Tree shards hold disjoint
        // sub-ensembles with their own entries, built per shard and
        // invalidated naturally when quarantine/hot-add re-split the
        // ensemble (the old sub-models drop, their entries with them).
        if let ShardAxis::Rows = axis {
            backend::prepare(model);
        }
        let sub_models: Vec<Arc<Model>> = match axis {
            ShardAxis::Rows => (0..shards).map(|_| Arc::clone(model)).collect(),
            ShardAxis::Trees => split_trees(model, shards).into_iter().map(Arc::new).collect(),
            ShardAxis::Grid | ShardAxis::FeatureTiles => unreachable!("rejected above"),
        };
        // build the inner instances concurrently, one per thread — setup
        // (packing, device client + executable compilation) is the
        // dominant cost at high shard counts, and on device backends the
        // client should be constructed on its own thread anyway
        let inner = build_concurrently(&sub_models, kind, &inner_cfg)?;
        let mut built = ShardedBackend::from_backends(inner, axis, model.base_score);
        built.rebuild =
            Some(Recipe { model: Arc::clone(model), kind, cfg: cfg.clone() });
        Ok(built)
    }

    /// Wrap pre-built shard backends. On the tree axis the caller is
    /// responsible for the inner backends holding disjoint tree slices
    /// whose union is the full ensemble (as [`split_trees`] produces).
    /// Carries no rebuild recipe, so tree-axis quarantine and hot-add
    /// are unavailable (row-axis quarantine still works: survivors hold
    /// the full model).
    pub fn from_backends(
        inner: Vec<Box<dyn ShapBackend>>,
        axis: ShardAxis,
        base_score: f32,
    ) -> ShardedBackend {
        assert!(!inner.is_empty(), "sharded backend needs ≥1 shard");
        assert!(
            !matches!(axis, ShardAxis::Grid | ShardAxis::FeatureTiles),
            "composite topologies are executed by GridBackend/TilesBackend, not ShardedBackend"
        );
        ShardedBackend {
            kind_name: inner[0].name(),
            num_features: inner[0].num_features(),
            num_groups: inner[0].num_groups(),
            base_score,
            axis,
            observer: None,
            caps: caps_over(&inner, axis),
            tput: Mutex::new(vec![None; inner.len()]),
            last_failed: Mutex::new(Vec::new()),
            rebuild: None,
            quarantined: 0,
            inner,
        }
    }

    pub fn shards(&self) -> usize {
        self.inner.len()
    }

    pub fn axis(&self) -> ShardAxis {
        self.axis
    }

    /// Shards removed by quarantine since construction.
    pub fn quarantined_shards(&self) -> usize {
        self.quarantined
    }

    /// The current per-shard throughput estimates (rows/s), `None` where
    /// nothing has been measured or seeded yet.
    pub fn shard_throughput_estimates(&self) -> Vec<Option<f64>> {
        self.tput.lock().unwrap().clone()
    }

    /// Remove failed shards from the topology. Row-axis survivors hold
    /// the full model, so the failed instances are simply dropped; the
    /// tree axis rebuilds the survivors over a fresh ensemble split
    /// (needs the rebuild recipe, i.e. a self-built backend). At least
    /// one shard must survive.
    pub fn quarantine_shards(&mut self, failed: &[usize]) -> Result<usize> {
        let n = self.inner.len();
        let mut targets: Vec<usize> = failed.iter().copied().filter(|&s| s < n).collect();
        targets.sort_unstable();
        targets.dedup();
        if targets.is_empty() {
            return Ok(0);
        }
        if targets.len() >= n {
            return Err(crate::anyhow!(
                "cannot quarantine all {n} shard(s): no survivors to serve from"
            ));
        }
        match self.axis {
            ShardAxis::Rows => {
                let mut idx = 0usize;
                self.inner.retain(|_| {
                    let keep = !targets.contains(&idx);
                    idx += 1;
                    keep
                });
                // survivors keep their measured EWMAs, remapped to their
                // shifted indices — the devices behind them are unchanged,
                // and wiping the estimates here sent chunk sizing back to
                // the cold-start equal split on every quarantine
                {
                    let mut t = self.tput.lock().unwrap();
                    let old = std::mem::take(&mut *t);
                    *t = old
                        .into_iter()
                        .enumerate()
                        .filter(|(i, _)| !targets.contains(i))
                        .map(|(_, v)| v)
                        .collect();
                    debug_assert_eq!(t.len(), self.inner.len());
                }
                self.last_failed.lock().unwrap().clear();
                self.caps = caps_over(&self.inner, self.axis);
                self.quarantined += targets.len();
                Ok(targets.len())
            }
            ShardAxis::Trees => {
                let recipe = self.rebuild.as_ref().ok_or_else(|| {
                    crate::anyhow!(
                        "tree-axis quarantine needs a rebuild recipe (self-built backend)"
                    )
                })?;
                let survivors = n - targets.len();
                let rebuilt = ShardedBackend::build(
                    &recipe.model,
                    recipe.kind,
                    &recipe.cfg,
                    survivors,
                    ShardAxis::Trees,
                )?;
                let quarantined = self.quarantined + targets.len();
                let observer = self.observer.take();
                *self = rebuilt;
                self.quarantined = quarantined;
                self.observer = observer;
                Ok(targets.len())
            }
            ShardAxis::Grid | ShardAxis::FeatureTiles => {
                unreachable!("ShardedBackend never carries a composite axis")
            },
        }
    }

    /// Hot-add: rebuild the topology out to `target` shards (recovery
    /// after quarantine, or scaling up). Needs the rebuild recipe. The
    /// tree axis may end below `target` when the tree count clamps.
    pub fn grow_to(&mut self, target: usize) -> Result<usize> {
        let n = self.inner.len();
        if target <= n {
            return Ok(0);
        }
        let recipe = self.rebuild.as_ref().ok_or_else(|| {
            crate::anyhow!("shard hot-add needs a rebuild recipe (self-built backend)")
        })?;
        let rebuilt = ShardedBackend::build(
            &recipe.model,
            recipe.kind,
            &recipe.cfg,
            target,
            self.axis,
        )?;
        let quarantined = self.quarantined;
        let observer = self.observer.take();
        // row-axis survivors keep their identity across the rebuild (the
        // first n instances replace the first n, all over the full
        // model), so their measured throughput estimates carry over —
        // only the freshly added shards start cold. Tree-axis estimates
        // describe sub-ensembles the re-split just dissolved, and the
        // tree axis never consumes them, so they are left behind.
        let old_tput = if matches!(self.axis, ShardAxis::Rows) {
            Some(self.tput.lock().unwrap().clone())
        } else {
            None
        };
        *self = rebuilt;
        self.quarantined = quarantined;
        self.observer = observer;
        if let Some(old) = old_tput {
            let mut t = self.tput.lock().unwrap();
            for (slot, prev) in t.iter_mut().zip(old) {
                if prev.is_some() {
                    *slot = prev;
                }
            }
        }
        Ok(self.inner.len().saturating_sub(n))
    }

    /// Append one pre-built shard instance — the grid executor's
    /// cache-friendly hot-add path, restoring a tree slice's row
    /// replicas without rebuilding the survivors. Existing shards keep
    /// their indices and throughput estimates; the new shard starts
    /// cold. Row-axis only (tree-axis widths come from the ensemble
    /// split and must go through the rebuild recipe).
    pub fn push_backend(&mut self, b: Box<dyn ShapBackend>) {
        assert!(
            matches!(self.axis, ShardAxis::Rows),
            "push_backend is a row-axis operation"
        );
        self.inner.push(b);
        self.tput.lock().unwrap().push(None);
        self.caps = caps_over(&self.inner, self.axis);
    }

    fn observe(&self, shard: usize, rows: usize, started: Instant) {
        if let Some(obs) = &self.observer {
            (obs.as_ref())(shard, rows, started.elapsed());
        }
    }

    /// Fold one successful chunk execution into the shard's throughput
    /// EWMA (rows-axis only — that's where chunk sizing uses it).
    fn learn(&self, shard: usize, rows: usize, started: Instant) {
        let secs = started.elapsed().as_secs_f64().max(1e-9);
        let rate = rows as f64 / secs;
        let mut t = self.tput.lock().unwrap();
        if let Some(slot) = t.get_mut(shard) {
            *slot = Some(match *slot {
                None => rate,
                Some(prev) => prev * (1.0 - TPUT_EWMA) + rate * TPUT_EWMA,
            });
        }
    }

    /// Relative chunk-sizing weights: measured throughput where known,
    /// the mean of the known estimates elsewhere (equal shares when
    /// nothing is measured yet — the cold-start split).
    fn shard_weights(&self) -> Vec<f64> {
        let t = self.tput.lock().unwrap();
        let known: Vec<f64> = t.iter().filter_map(|&v| v).collect();
        let default = if known.is_empty() {
            1.0
        } else {
            known.iter().sum::<f64>() / known.len() as f64
        };
        t.iter().map(|&v| v.unwrap_or(default)).collect()
    }

    /// Rows axis: each shard drains its own throughput-weighted chunk
    /// queue (stealing from others when idle) and writes into disjoint
    /// ranges of one output buffer.
    fn run_rows<F>(&self, x: &[f32], rows: usize, stride: usize, f: F) -> Result<Vec<f32>>
    where
        F: Fn(&dyn ShapBackend, &[f32], usize) -> Result<Vec<f32>> + Sync,
    {
        let m = self.num_features;
        let n = self.inner.len();
        self.last_failed.lock().unwrap().clear();
        if n == 1 || rows <= 1 {
            let t0 = Instant::now();
            match f(self.inner[0].as_ref(), x, rows) {
                Ok(out) => {
                    // the fast path must feed the EWMA too: a service
                    // dominated by 1-row explains otherwise never
                    // calibrates shard 0's throughput estimate and the
                    // weighted split stays at cold-start equal shares
                    self.learn(0, rows, t0);
                    self.observe(0, rows, t0);
                    return Ok(out);
                }
                Err(e) => {
                    self.last_failed.lock().unwrap().push(0);
                    return Err(e);
                }
            }
        }
        let queues: Vec<Mutex<VecDeque<(usize, usize)>>> =
            weighted_chunks(rows, &self.shard_weights(), CHUNKS_PER_SHARD)
                .into_iter()
                .map(|chunks| Mutex::new(chunks.into_iter().collect()))
                .collect();
        let abort = AtomicBool::new(false);
        let errs: Mutex<Vec<Error>> = Mutex::new(Vec::new());
        let mut out = vec![0.0f32; rows * stride];
        let mut done = 0usize;
        let (tx, rx) = std::sync::mpsc::channel::<(usize, Vec<f32>)>();
        std::thread::scope(|scope| {
            for (si, b) in self.inner.iter().enumerate() {
                let (abort, errs) = (&abort, &errs);
                let (queues, f, this) = (&queues, &f, &*self);
                let b = b.as_ref();
                let tx = tx.clone();
                scope.spawn(move || loop {
                    if abort.load(Ordering::Relaxed) {
                        return;
                    }
                    let Some((r0, rc)) = pop_chunk(queues, si) else { return };
                    let t0 = Instant::now();
                    match f(b, &x[r0 * m..(r0 + rc) * m], rc) {
                        Ok(vals) if vals.len() == rc * stride => {
                            this.learn(si, rc, t0);
                            this.observe(si, rc, t0);
                            // the receiver lives until every sender is
                            // dropped; a failed send means the call is
                            // being torn down — stop instead of ignoring
                            if tx.send((r0, vals)).is_err() {
                                return;
                            }
                        }
                        Ok(vals) => {
                            abort.store(true, Ordering::Relaxed);
                            errs.lock().unwrap().push(crate::anyhow!(
                                "shard {si}: expected {} output floats, got {}",
                                rc * stride,
                                vals.len()
                            ));
                            this.last_failed.lock().unwrap().push(si);
                            return;
                        }
                        Err(e) => {
                            abort.store(true, Ordering::Relaxed);
                            errs.lock().unwrap().push(e.context(format!("shard {si}")));
                            this.last_failed.lock().unwrap().push(si);
                            return;
                        }
                    }
                });
            }
            drop(tx);
            // assemble chunks into their disjoint ranges as they arrive
            // (no shared output lock); `rx` closes once every worker has
            // dropped its sender, which also bounds this loop
            for (r0, vals) in rx.iter() {
                let rc = vals.len() / stride;
                out[r0 * stride..(r0 + rc) * stride].copy_from_slice(&vals);
                done += rc;
            }
        });
        let errs = errs.into_inner().unwrap();
        if !errs.is_empty() {
            return Err(aggregate(errs));
        }
        debug_assert_eq!(done, rows);
        Ok(out)
    }

    /// Trees axis: every shard runs the full batch over its slice of the
    /// ensemble; partial outputs are summed and the base surplus removed.
    fn run_trees<F>(
        &self,
        x: &[f32],
        rows: usize,
        task: ShardTask,
        f: F,
    ) -> Result<Vec<f32>>
    where
        F: Fn(&dyn ShapBackend, &[f32], usize) -> Result<Vec<f32>> + Sync,
    {
        let n = self.inner.len();
        self.last_failed.lock().unwrap().clear();
        if n == 1 {
            let t0 = Instant::now();
            match f(self.inner[0].as_ref(), x, rows) {
                Ok(out) => {
                    self.observe(0, rows, t0);
                    return Ok(out);
                }
                Err(e) => {
                    self.last_failed.lock().unwrap().push(0);
                    return Err(e);
                }
            }
        }
        let units: Vec<&dyn ShapBackend> = self.inner.iter().map(|b| b.as_ref()).collect();
        run_additive(
            &units,
            x,
            rows,
            task,
            self.num_groups,
            self.num_features,
            self.base_score,
            "shard",
            &|si, t0| self.observe(si, rows, t0),
            &|si| self.last_failed.lock().unwrap().push(si),
            &f,
        )
    }

    fn run<F>(&self, x: &[f32], rows: usize, task: ShardTask, f: F) -> Result<Vec<f32>>
    where
        F: Fn(&dyn ShapBackend, &[f32], usize) -> Result<Vec<f32>> + Sync,
    {
        match self.axis {
            ShardAxis::Rows => {
                self.run_rows(x, rows, task.stride(self.num_groups, self.num_features), f)
            }
            ShardAxis::Trees => self.run_trees(x, rows, task, f),
            ShardAxis::Grid | ShardAxis::FeatureTiles => {
                unreachable!("ShardedBackend never carries a composite axis")
            },
        }
    }
}

/// Take the next chunk for shard `si`: its own queue front first, then
/// steal from the back of the first non-empty other queue. Queues only
/// shrink, so one full sweep finding nothing means the work is gone.
fn pop_chunk(
    queues: &[Mutex<VecDeque<(usize, usize)>>],
    si: usize,
) -> Option<(usize, usize)> {
    if let Some(c) = queues[si].lock().unwrap().pop_front() {
        return Some(c);
    }
    for (j, q) in queues.iter().enumerate() {
        if j == si {
            continue;
        }
        if let Some(c) = q.lock().unwrap().pop_back() {
            return Some(c);
        }
    }
    None
}

/// Aggregate capability/cost metadata over the shard set.
fn caps_over(inner: &[Box<dyn ShapBackend>], axis: ShardAxis) -> BackendCaps {
    let supports_interactions = inner.iter().all(|b| b.caps().supports_interactions);
    let setup = inner.iter().map(|b| b.caps().setup_cost_s).fold(0.0, f64::max);
    let overhead = inner.iter().map(|b| b.caps().batch_overhead_s).fold(0.0, f64::max);
    // rows: devices run disjoint rows concurrently (rates add);
    // trees: every device runs every row (slowest slice gates)
    let rows_per_s = match axis {
        ShardAxis::Rows => inner.iter().map(|b| b.caps().rows_per_s).sum(),
        ShardAxis::Trees => inner
            .iter()
            .map(|b| b.caps().rows_per_s)
            .fold(f64::INFINITY, f64::min),
        ShardAxis::Grid | ShardAxis::FeatureTiles => {
            unreachable!("ShardedBackend never carries a composite axis")
        }
    };
    BackendCaps {
        supports_interactions,
        setup_cost_s: setup,
        batch_overhead_s: overhead,
        rows_per_s,
    }
}

/// Build one backend instance per (sub-)model, each on its own thread.
/// Shared with the grid executor, whose row-replica groups are built the
/// same way (several instances over one `Arc<Model>`).
pub(crate) fn build_concurrently(
    sub_models: &[Arc<Model>],
    kind: BackendKind,
    cfg: &BackendConfig,
) -> Result<Vec<Box<dyn ShapBackend>>> {
    if sub_models.len() == 1 {
        return Ok(vec![backend::build(&sub_models[0], kind, cfg)?]);
    }
    let slots: Mutex<Vec<Option<Result<Box<dyn ShapBackend>>>>> =
        Mutex::new(sub_models.iter().map(|_| None).collect());
    std::thread::scope(|scope| {
        for (i, sub) in sub_models.iter().enumerate() {
            let slots = &slots;
            scope.spawn(move || {
                let built = backend::build(sub, kind, cfg);
                slots.lock().unwrap()[i] = Some(built);
            });
        }
    });
    slots
        .into_inner()
        .unwrap()
        .into_iter()
        .enumerate()
        .map(|(i, slot)| {
            slot.expect("every build thread fills its slot")
                .map_err(|e| e.context(format!("shard {i}")))
        })
        .collect()
}

/// The additive fan-out shared by the tree axis and the grid's slice
/// merge: every unit runs the full batch concurrently, outputs are
/// length-validated, summed in index order (bit-identical association
/// for both callers — pinned by the grid parity tests) and the
/// `(n − 1) · base_score` surplus removed. `label` names a failing unit
/// in errors ("shard" / "tree slice"); `on_ok` observes each successful
/// unit's wall time; `on_fail` records failure attribution for the
/// quarantine path — including units that returned a malformed output
/// length, which must still be quarantinable.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_additive<F>(
    units: &[&dyn ShapBackend],
    x: &[f32],
    rows: usize,
    task: ShardTask,
    num_groups: usize,
    num_features: usize,
    base_score: f32,
    label: &str,
    on_ok: &(dyn Fn(usize, Instant) + Sync),
    on_fail: &(dyn Fn(usize) + Sync),
    f: &F,
) -> Result<Vec<f32>>
where
    F: Fn(&dyn ShapBackend, &[f32], usize) -> Result<Vec<f32>> + Sync,
{
    let stride = task.stride(num_groups, num_features);
    let n = units.len();
    let errs: Mutex<Vec<Error>> = Mutex::new(Vec::new());
    let partials = Mutex::new(vec![None::<Vec<f32>>; n]);
    std::thread::scope(|scope| {
        for (si, unit) in units.iter().enumerate() {
            let (errs, partials) = (&errs, &partials);
            let b: &dyn ShapBackend = *unit;
            scope.spawn(move || {
                let t0 = Instant::now();
                match f(b, x, rows) {
                    Ok(vals) if vals.len() == rows * stride => {
                        on_ok(si, t0);
                        partials.lock().unwrap()[si] = Some(vals);
                    }
                    Ok(vals) => {
                        errs.lock().unwrap().push(crate::anyhow!(
                            "{label} {si}: expected {} output floats, got {}",
                            rows * stride,
                            vals.len()
                        ));
                        on_fail(si);
                    }
                    Err(e) => {
                        errs.lock().unwrap().push(e.context(format!("{label} {si}")));
                        on_fail(si);
                    }
                }
            });
        }
    });
    let errs = errs.into_inner().unwrap();
    if !errs.is_empty() {
        return Err(aggregate(errs));
    }
    let mut acc = vec![0.0f32; rows * stride];
    for partial in partials.into_inner().unwrap() {
        let partial = partial.expect("no error ⇒ every unit produced output");
        for (a, v) in acc.iter_mut().zip(&partial) {
            *a += v;
        }
    }
    shard::correct_base(&mut acc, task, n, base_score, rows, num_groups, num_features);
    Ok(acc)
}

/// One error per failed shard, folded into a single aggregate.
pub(crate) fn aggregate(mut errs: Vec<Error>) -> Error {
    if errs.len() == 1 {
        return errs.pop().unwrap();
    }
    let msgs: Vec<String> = errs.iter().map(|e| format!("{e:#}")).collect();
    crate::anyhow!("{} shard(s) failed: {}", errs.len(), msgs.join("; "))
}

impl ShapBackend for ShardedBackend {
    fn name(&self) -> &'static str {
        self.kind_name
    }

    fn caps(&self) -> BackendCaps {
        self.caps
    }

    fn num_features(&self) -> usize {
        self.num_features
    }

    fn num_groups(&self) -> usize {
        self.num_groups
    }

    fn contributions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        self.run(x, rows, ShardTask::Contributions, |b, x, r| b.contributions(x, r))
    }

    fn interactions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        self.run(x, rows, ShardTask::Interactions, |b, x, r| b.interactions(x, r))
    }

    fn predictions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        self.run(x, rows, ShardTask::Predictions, |b, x, r| b.predictions(x, r))
    }

    fn set_shard_observer(&mut self, obs: ShardObserver) {
        self.observer = Some(obs);
    }

    fn shard_count(&self) -> usize {
        self.inner.len()
    }

    fn failed_shards(&self) -> Vec<usize> {
        let mut v = self.last_failed.lock().unwrap().clone();
        v.sort_unstable();
        v.dedup();
        v
    }

    fn quarantine(&mut self, failed: &[usize]) -> Result<usize> {
        self.quarantine_shards(failed)
    }

    fn quarantine_remaps_survivors(&self) -> bool {
        // row-axis quarantine only drops instances: each survivor is the
        // same device shifted down in index. The tree axis rebuilds the
        // survivors over a fresh ensemble split, so old per-shard
        // history describes slices that no longer exist.
        matches!(self.axis, ShardAxis::Rows)
    }

    fn hot_add(&mut self, target: usize) -> Result<usize> {
        self.grow_to(target)
    }

    fn prepared(&self) -> Option<&Arc<crate::backend::PreparedModel>> {
        // rows axis: every shard shares one entry, so the first speaks
        // for all; trees axis: the first sub-ensemble's entry (stats
        // inspection — per-shard entries stay reachable via the shards)
        self.inner[0].prepared()
    }

    fn set_shard_throughputs(&self, rows_per_s: &[(usize, f64)]) {
        let mut t = self.tput.lock().unwrap();
        for &(s, rate) in rows_per_s {
            if rate.is_finite() && rate > 0.0 {
                if let Some(slot) = t.get_mut(s) {
                    *slot = Some(rate);
                }
            }
        }
    }

    fn describe(&self) -> String {
        let quarantined = if self.quarantined > 0 {
            format!(", {} quarantined", self.quarantined)
        } else {
            String::new()
        };
        format!(
            "sharded[{}×{} axis, {}{}]",
            self.inner.len(),
            self.axis.name(),
            self.inner[0].describe(),
            quarantined
        )
    }
}
