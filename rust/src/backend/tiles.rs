//! The feature-tile [`ShapBackend`]: interaction values sharded along
//! the conditioned-feature axis — the fourth shard axis, for the
//! wide-model (`M ≫ D`) Φ regime the ROADMAP's "lift the interaction
//! cap" item targets.
//!
//! Layout: every unit holds the FULL model (one shared `Arc`, so the
//! prepared-model registry carries exactly one entry for the whole
//! topology) and the conditioned-feature set `{0..M}` is cut into
//! contiguous tiles by [`shard::split_feature_tiles`], balanced by how
//! many trees actually test each feature — [`PreparedModel::
//! tile_features`]'s cached index. A batch fans every unit out over the
//! full rows with its own `(lo, hi)` range; each unit answers with a
//! f64 column-block of the `(M+1)²` matrix containing only the cells
//! its conditioned passes price ([`ShapBackend::interactions_block`]),
//! skipping trees that split on none of its features. The coordinator
//! places the blocks, computes the Eq. 6 diagonal from one f64 φ pass
//! ([`ShapBackend::contributions_f64`]) and drops the base value at
//! `[M, M]` from the prepared expected values.
//!
//! Two block layouts, declared by the inner kind:
//! - **recursive** units emit full off-diagonal columns whose f64 cell
//!   sums run over trees in model order — the assembled matrix is
//!   **bit-identical** to the unsharded recursive oracle (pinned by
//!   `interactions::blocks_assemble_to_full_matrix_bitwise`).
//! - every other kind maps to **host** units, whose packed kernel
//!   prices each unordered pair once (owner-symmetric upper triangle,
//!   one DP + O(len) unwinds per conditioned position instead of a
//!   fresh O(len²) DP each); the assembler mirrors the triangle, so the
//!   output is exactly symmetric and agrees with the legacy kernel to
//!   float round-off (≤ 1e-6 — the Φ acceptance tolerance).
//!
//! **Elastic**: tile ranges are assigned at call time from the live
//! unit count, so quarantine just drops the dead units — the next batch
//! re-splits the feature range across the survivors with no rebuild
//! (every unit already holds the full model). Per-shard history
//! describes tiles that shifted, so survivors are NOT remapped. Hot-add
//! builds fresh full-model units against the live prepared entry.
//!
//! φ and predictions have no conditioned-feature loop to split; they
//! are served by the first unit directly (callers that only want φ on a
//! tile plan never reach here — `build_for_plan` degrades them to the
//! rows axis).

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::backend::shard::split_feature_tiles;
use crate::backend::sharded::{aggregate, build_concurrently};
use crate::backend::{
    self, BackendCaps, BackendConfig, BackendKind, PreparedModel, ShapBackend, ShardObserver,
};
use crate::gbdt::Model;
use crate::util::error::{Error, Result};

/// How a unit's `interactions_block` output maps into the matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum BlockLayout {
    /// full off-diagonal columns `(i, j)` for every `i` and `j` in the
    /// tile — the recursive kernel; assembly is bit-identical to the
    /// unsharded oracle
    Column,
    /// only `i < j` cells are populated (the packed host kernel prices
    /// each unordered pair once); the assembler mirrors them, so tile
    /// `(lo, hi)` owns every pair whose larger feature is in the tile
    OwnerSymmetric,
}

/// Everything needed to build replacement units (hot-add after
/// quarantine) — present when built through [`TilesBackend::build`].
struct Recipe {
    model: Arc<Model>,
    kind: BackendKind,
    cfg: BackendConfig,
}

pub struct TilesBackend {
    /// full-model units, one prospective tile each; all share one
    /// `Arc<Model>` and therefore one prepared-model registry entry
    units: Vec<Box<dyn ShapBackend>>,
    prep: Arc<PreparedModel>,
    layout: BlockLayout,
    /// the tile count the plan asked for — quarantine shrinks the live
    /// set, hot-add grows it back toward this
    planned: usize,
    kind_name: &'static str,
    num_features: usize,
    num_groups: usize,
    caps: BackendCaps,
    observer: Option<ShardObserver>,
    rebuild: Option<Recipe>,
    /// unit indices that failed in the most recent execution
    last_failed: Mutex<Vec<usize>>,
    /// the `(lo, hi)` ranges of the most recent execution, in unit
    /// order (metrics/describe; re-derived per batch from the live set)
    last_ranges: Mutex<Vec<(usize, usize)>>,
    /// units removed by quarantine since construction
    quarantined: usize,
}

impl TilesBackend {
    /// Build `tiles` full-model units of `kind` over `model`. The tile
    /// count clamps to the feature count (one feature cannot split).
    /// Kinds without a ranged block kernel execute on host units — the
    /// packed kernel serves any model the kind could have — keeping the
    /// reported name on the inner kind for metrics continuity.
    pub fn build(
        model: &Arc<Model>,
        kind: BackendKind,
        cfg: &BackendConfig,
        tiles: usize,
    ) -> Result<TilesBackend> {
        let tiles = tiles.clamp(1, model.num_features.max(1));
        // recursive keeps its own units (column blocks, bit-compatible);
        // every other kind executes on host units (owner-symmetric
        // blocks) — `from_units` infers the layout from the unit kind
        let unit_kind = match kind {
            BackendKind::Recursive => BackendKind::Recursive,
            _ => BackendKind::Host,
        };
        let mut inner_cfg = cfg.clone();
        inner_cfg.devices = 1; // inner builds must not re-shard
        inner_cfg.shard_axis = None;
        // warm the single shared entry so the concurrent unit builds
        // below all hit (the model preps/packs once, not once per tile)
        let prep = backend::prepare(model);
        let sub_models: Vec<Arc<Model>> = (0..tiles).map(|_| Arc::clone(model)).collect();
        let units = build_concurrently(&sub_models, unit_kind, &inner_cfg)?;
        let mut built = TilesBackend::from_units(units, prep);
        built.rebuild = Some(Recipe { model: Arc::clone(model), kind: unit_kind, cfg: inner_cfg });
        Ok(built)
    }

    /// Wrap pre-built full-model units (tests, embedders). Every unit
    /// must hold the same model as `prep` and serve
    /// [`ShapBackend::interactions_block`]. The layout is inferred from
    /// the unit kind (recursive → columns, anything else →
    /// owner-symmetric). Carries no rebuild recipe, so hot-add is
    /// unavailable; quarantine still works (survivors re-split).
    pub fn from_units(units: Vec<Box<dyn ShapBackend>>, prep: Arc<PreparedModel>) -> TilesBackend {
        assert!(!units.is_empty(), "tiles backend needs ≥1 unit");
        let layout = if units[0].name() == BackendKind::Recursive.name() {
            BlockLayout::Column
        } else {
            BlockLayout::OwnerSymmetric
        };
        TilesBackend {
            kind_name: units[0].name(),
            num_features: units[0].num_features(),
            num_groups: units[0].num_groups(),
            caps: tile_caps(&units),
            observer: None,
            rebuild: None,
            last_failed: Mutex::new(Vec::new()),
            last_ranges: Mutex::new(Vec::new()),
            quarantined: 0,
            planned: units.len(),
            layout,
            prep,
            units,
        }
    }

    /// The planned tile count (hot-add's recovery target).
    pub fn planned_tiles(&self) -> usize {
        self.planned
    }

    /// Units removed by quarantine since construction.
    pub fn quarantined_units(&self) -> usize {
        self.quarantined
    }

    /// The `(lo, hi)` feature ranges of the most recent execution, in
    /// unit order — empty before the first interactions batch.
    pub fn tile_ranges(&self) -> Vec<(usize, usize)> {
        self.last_ranges.lock().unwrap().clone()
    }

    /// Drop failed units; the next batch re-splits the feature range
    /// across the survivors (no rebuild — every unit holds the full
    /// model). At least one unit must survive.
    pub fn quarantine_units(&mut self, failed: &[usize]) -> Result<usize> {
        let n = self.units.len();
        let mut targets: Vec<usize> = failed.iter().copied().filter(|&s| s < n).collect();
        targets.sort_unstable();
        targets.dedup();
        if targets.is_empty() {
            return Ok(0);
        }
        if targets.len() >= n {
            return Err(crate::anyhow!(
                "cannot quarantine all {n} tile unit(s): no survivors to serve from"
            ));
        }
        let mut idx = 0usize;
        self.units.retain(|_| {
            let dead = targets.contains(&idx);
            idx += 1;
            !dead
        });
        self.quarantined += targets.len();
        self.last_failed.lock().unwrap().clear();
        self.last_ranges.lock().unwrap().clear();
        self.caps = tile_caps(&self.units);
        Ok(targets.len())
    }

    /// Grow back toward `target` units (recovery after quarantine).
    /// New units are full-model replicas built against the live
    /// prepared entry, so they pack nothing. Needs the rebuild recipe.
    pub fn grow_to(&mut self, target: usize) -> Result<usize> {
        let before = self.units.len();
        let target = target.min(self.planned);
        if target <= before {
            return Ok(0);
        }
        let recipe = self.rebuild.as_ref().ok_or_else(|| {
            crate::anyhow!("tile hot-add needs a rebuild recipe (self-built backend)")
        })?;
        for _ in before..target {
            let b = backend::build(&recipe.model, recipe.kind, &recipe.cfg)
                .map_err(|e| e.context("tile unit hot-add"))?;
            self.units.push(b);
        }
        self.caps = tile_caps(&self.units);
        Ok(self.units.len() - before)
    }

    fn observe(&self, unit: usize, rows: usize, started: Instant) {
        if let Some(obs) = &self.observer {
            (obs.as_ref())(unit, rows, started.elapsed());
        }
    }

    /// Fan one interactions batch out: each live unit computes the f64
    /// column-block for its tile; the coordinator assembles, fills the
    /// Eq. 6 diagonal from a f64 φ pass and the base cell from the
    /// prepared expected values. Same failure semantics as the other
    /// executors: any unit failure aborts the batch with an aggregated
    /// error and attributed [`ShapBackend::failed_shards`].
    fn run_interactions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        self.last_failed.lock().unwrap().clear();
        let n = self.units.len();
        if n == 1 {
            // one tile = the full conditioned loop: the unit's own full
            // kernel is the same work with zero assembly
            self.last_ranges.lock().unwrap().clear();
            let t0 = Instant::now();
            let out = self.units[0].interactions(x, rows).map_err(|e| {
                self.last_failed.lock().unwrap().push(0);
                e
            })?;
            self.observe(0, rows, t0);
            return Ok(out);
        }
        let m = self.num_features;
        let tf = self.prep.tile_features();
        let ranges = split_feature_tiles(&tf.tree_counts, n);
        *self.last_ranges.lock().unwrap() = ranges.clone();
        let errs: Mutex<Vec<Error>> = Mutex::new(Vec::new());
        let blocks = Mutex::new(vec![None::<Vec<f64>>; ranges.len()]);
        std::thread::scope(|scope| {
            // fewer tiles than units (m < n after clamping upstream, or
            // post-quarantine shapes): trailing units idle this batch
            for (ui, &(lo, hi)) in ranges.iter().enumerate() {
                let (errs, blocks) = (&errs, &blocks);
                let b: &dyn ShapBackend = self.units[ui].as_ref();
                scope.spawn(move || {
                    let t0 = Instant::now();
                    match b.interactions_block(x, rows, lo, hi) {
                        Ok(vals)
                            if vals.len() == rows * self.num_groups * m * (hi - lo) =>
                        {
                            self.observe(ui, rows, t0);
                            blocks.lock().unwrap()[ui] = Some(vals);
                        }
                        Ok(vals) => {
                            errs.lock().unwrap().push(crate::anyhow!(
                                "tile {ui} [{lo}, {hi}): expected {} block floats, got {}",
                                rows * self.num_groups * m * (hi - lo),
                                vals.len()
                            ));
                            self.last_failed.lock().unwrap().push(ui);
                        }
                        Err(e) => {
                            errs.lock()
                                .unwrap()
                                .push(e.context(format!("tile {ui} [{lo}, {hi})")));
                            self.last_failed.lock().unwrap().push(ui);
                        }
                    }
                });
            }
        });
        let errs = errs.into_inner().unwrap();
        if !errs.is_empty() {
            return Err(aggregate(errs));
        }
        let blocks: Vec<Vec<f64>> = blocks
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|b| b.expect("no error ⇒ every tile produced a block"))
            .collect();
        // the diagonal needs full-precision φ (Eq. 6 subtracts the f64
        // row sums); served by the first unit — any unit would do, they
        // hold the same model
        let phis = self.units[0].contributions_f64(x, rows).map_err(|e| {
            self.last_failed.lock().unwrap().push(0);
            e
        })?;
        Ok(self.assemble(&blocks, &ranges, &phis, rows))
    }

    /// Place the tile blocks into `[rows × groups × (M+1)²]` matrices,
    /// fill diagonals (Eq. 6) and the base cell. Off-diagonal cells are
    /// copied in ascending-`j` order per row `i` — with `Column` blocks
    /// this reproduces the unsharded kernel's f64 values bit-for-bit;
    /// `OwnerSymmetric` blocks are mirrored across the diagonal.
    fn assemble(
        &self,
        blocks: &[Vec<f64>],
        ranges: &[(usize, usize)],
        phis: &[f64],
        rows: usize,
    ) -> Vec<f32> {
        let m = self.num_features;
        let groups = self.num_groups;
        let msq = (m + 1) * (m + 1);
        let stride = groups * msq;
        let ev = self.prep.expected_values();
        let mut out = vec![0.0f32; rows * stride];
        let mut mat = vec![0.0f64; msq];
        for r in 0..rows {
            for g in 0..groups {
                mat.iter_mut().for_each(|v| *v = 0.0);
                for (bi, &(lo, hi)) in ranges.iter().enumerate() {
                    let width = hi - lo;
                    let gb = &blocks[bi]
                        [(r * groups + g) * m * width..(r * groups + g + 1) * m * width];
                    match self.layout {
                        BlockLayout::Column => {
                            for i in 0..m {
                                mat[i * (m + 1) + lo..i * (m + 1) + hi]
                                    .copy_from_slice(&gb[i * width..(i + 1) * width]);
                            }
                        }
                        BlockLayout::OwnerSymmetric => {
                            for j in lo..hi {
                                for i in 0..j {
                                    let v = gb[i * width + (j - lo)];
                                    mat[i * (m + 1) + j] = v;
                                    mat[j * (m + 1) + i] = v;
                                }
                            }
                        }
                    }
                }
                for i in 0..m {
                    let row_sum: f64 = (0..m)
                        .filter(|&j| j != i)
                        .map(|j| mat[i * (m + 1) + j])
                        .sum();
                    mat[i * (m + 1) + i] = phis[(r * groups + g) * m + i] - row_sum;
                }
                mat[m * (m + 1) + m] = ev[g];
                let dst = &mut out[r * stride + g * msq..r * stride + (g + 1) * msq];
                for (d, s) in dst.iter_mut().zip(&mat) {
                    *d = *s as f32;
                }
            }
        }
        out
    }
}

/// Aggregate capability/cost metadata over the units. Every unit is the
/// same full-model backend, so setup/overhead take the max; the
/// reported φ throughput is a single unit's (φ is served unsplit — the
/// tile win is in the Φ path, which caps has no slot for).
fn tile_caps(units: &[Box<dyn ShapBackend>]) -> BackendCaps {
    BackendCaps {
        supports_interactions: units.iter().all(|b| b.caps().supports_interactions),
        setup_cost_s: units.iter().map(|b| b.caps().setup_cost_s).fold(0.0, f64::max),
        batch_overhead_s: units
            .iter()
            .map(|b| b.caps().batch_overhead_s)
            .fold(0.0, f64::max),
        rows_per_s: units.iter().map(|b| b.caps().rows_per_s).fold(0.0, f64::max),
    }
}

impl ShapBackend for TilesBackend {
    fn name(&self) -> &'static str {
        self.kind_name
    }

    fn caps(&self) -> BackendCaps {
        self.caps
    }

    fn num_features(&self) -> usize {
        self.num_features
    }

    fn num_groups(&self) -> usize {
        self.num_groups
    }

    fn contributions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        // no conditioned loop to tile: one full-model unit serves φ
        self.units[0].contributions(x, rows)
    }

    fn interactions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        self.run_interactions(x, rows)
    }

    fn predictions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        self.units[0].predictions(x, rows)
    }

    fn set_shard_observer(&mut self, obs: ShardObserver) {
        self.observer = Some(obs);
    }

    fn shard_count(&self) -> usize {
        self.units.len()
    }

    fn failed_shards(&self) -> Vec<usize> {
        let mut v = self.last_failed.lock().unwrap().clone();
        v.sort_unstable();
        v.dedup();
        v
    }

    fn quarantine(&mut self, failed: &[usize]) -> Result<usize> {
        self.quarantine_units(failed)
    }

    fn quarantine_remaps_survivors(&self) -> bool {
        // survivors keep their devices, but the feature range re-splits
        // underneath them — old per-shard history describes tiles that
        // no longer exist, so callers must reset it
        false
    }

    fn hot_add(&mut self, target: usize) -> Result<usize> {
        self.grow_to(target)
    }

    fn prepared(&self) -> Option<&Arc<PreparedModel>> {
        Some(&self.prep)
    }

    fn describe(&self) -> String {
        let ranges = self.last_ranges.lock().unwrap();
        let tiles = if ranges.is_empty() {
            format!("{}×features", self.units.len())
        } else {
            let spans: Vec<String> =
                ranges.iter().map(|(lo, hi)| format!("[{lo},{hi})")).collect();
            format!("{}×features {}", ranges.len(), spans.join("/"))
        };
        let quarantined = if self.quarantined > 0 {
            format!(", {} quarantined", self.quarantined)
        } else {
            String::new()
        };
        format!("tiles[{tiles}, {}{quarantined}]", self.units[0].describe())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::RecursiveBackend;
    use crate::data::SynthSpec;
    use crate::gbdt::{train, TrainParams};

    fn setup() -> (Arc<Model>, Vec<f32>, usize) {
        let d = SynthSpec::cal_housing(0.006).generate();
        let model = Arc::new(train(
            &d,
            &TrainParams { rounds: 5, max_depth: 4, ..Default::default() },
        ));
        let rows = 7;
        let x = d.features[..rows * model.num_features].to_vec();
        (model, x, rows)
    }

    #[test]
    fn tiled_interactions_match_oracle_bitwise_on_recursive_units() {
        let (model, x, rows) = setup();
        let oracle = RecursiveBackend::new(Arc::clone(&model), 1).interactions(&x, rows).unwrap();
        for tiles in [2usize, 3, 5] {
            let cfg = BackendConfig { threads: 1, ..Default::default() };
            let b = TilesBackend::build(&model, BackendKind::Recursive, &cfg, tiles).unwrap();
            let got = b.interactions(&x, rows).unwrap();
            assert_eq!(got.len(), oracle.len());
            for (i, (a, o)) in got.iter().zip(&oracle).enumerate() {
                assert!(*a == *o, "{tiles} tiles: cell {i}: {a} vs {o} (must be bitwise)");
            }
            assert_eq!(b.shard_count(), tiles.min(model.num_features));
            assert!(b.describe().starts_with("tiles["), "{}", b.describe());
        }
    }

    #[test]
    fn host_units_match_oracle_to_tolerance_and_stay_symmetric() {
        let (model, x, rows) = setup();
        let m = model.num_features;
        let oracle = RecursiveBackend::new(Arc::clone(&model), 1).interactions(&x, rows).unwrap();
        let cfg = BackendConfig { threads: 1, ..Default::default() };
        let b = TilesBackend::build(&model, BackendKind::Host, &cfg, 3).unwrap();
        let got = b.interactions(&x, rows).unwrap();
        let msq = (m + 1) * (m + 1);
        for (i, (a, o)) in got.iter().zip(&oracle).enumerate() {
            assert!((a - o).abs() < 1e-6, "cell {i}: {a} vs {o}");
        }
        for r in 0..rows {
            for i in 0..=m {
                for j in 0..=m {
                    let a = got[r * msq + i * (m + 1) + j];
                    let t = got[r * msq + j * (m + 1) + i];
                    assert_eq!(a, t, "owner-symmetric assembly must be exactly symmetric");
                }
            }
        }
    }

    #[test]
    fn quarantine_resplits_over_survivors() {
        let (model, x, rows) = setup();
        let cfg = BackendConfig { threads: 1, ..Default::default() };
        let mut b = TilesBackend::build(&model, BackendKind::Recursive, &cfg, 4).unwrap();
        let before = b.interactions(&x, rows).unwrap();
        let ranges4 = b.tile_ranges();
        assert_eq!(ranges4.len(), 4.min(model.num_features));
        assert_eq!(b.quarantine_units(&[1, 3]).unwrap(), 2);
        assert_eq!(b.shard_count(), 2);
        assert!(!b.quarantine_remaps_survivors(), "tiles shift under survivors");
        let after = b.interactions(&x, rows).unwrap();
        assert_eq!(b.tile_ranges().len(), 2, "survivors re-split the feature range");
        for (a, o) in after.iter().zip(&before) {
            assert!(*a == *o, "values must survive re-splitting bitwise: {a} vs {o}");
        }
        // no survivors is refused
        let err = b.quarantine_units(&[0, 1]).unwrap_err();
        assert!(err.to_string().contains("no survivors"), "{err}");
        // hot-add grows back toward the plan and serving still works
        assert_eq!(b.hot_add(4).unwrap(), 2);
        assert_eq!(b.shard_count(), 4);
        let grown = b.interactions(&x, rows).unwrap();
        assert_eq!(grown.len(), before.len());
    }

    #[test]
    fn single_tile_and_overwide_requests_degrade_cleanly() {
        let (model, x, rows) = setup();
        let m = model.num_features;
        let cfg = BackendConfig { threads: 1, ..Default::default() };
        let oracle = RecursiveBackend::new(Arc::clone(&model), 1).interactions(&x, rows).unwrap();
        // 1 tile: delegates to the unit's full kernel
        let one = TilesBackend::build(&model, BackendKind::Recursive, &cfg, 1).unwrap();
        assert_eq!(one.shard_count(), 1);
        let got = one.interactions(&x, rows).unwrap();
        for (a, o) in got.iter().zip(&oracle) {
            assert!(*a == *o);
        }
        assert!(one.tile_ranges().is_empty(), "single tile never splits");
        // more tiles than features: clamps to M (1-feature tiles)
        let wide = TilesBackend::build(&model, BackendKind::Recursive, &cfg, m + 5).unwrap();
        assert_eq!(wide.shard_count(), m);
        let got = wide.interactions(&x, rows).unwrap();
        for (a, o) in got.iter().zip(&oracle) {
            assert!(*a == *o, "1-feature tiles: {a} vs {o}");
        }
        let ranges = wide.tile_ranges();
        assert_eq!(ranges.len(), m);
        assert!(ranges.iter().all(|(lo, hi)| hi - lo == 1));
        // φ and predictions pass through a single unit untiled
        let phis = wide.contributions(&x, rows).unwrap();
        let direct = RecursiveBackend::new(Arc::clone(&model), 1).contributions(&x, rows).unwrap();
        assert_eq!(phis, direct);
    }
}
