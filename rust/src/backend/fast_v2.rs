//! The Fast TreeSHAP v2 [`ShapBackend`]: exact φ from precomputed
//! per-leaf subset weight tables (`shap::fast_v2`), cached in the
//! prepared model. φ-only — `supports_interactions` is `false`, so
//! `build_auto` routes Φ requests past it to a capable backend;
//! predictions are served by raw tree routing.
//!
//! Construction goes through the prepared-model cache and is **gated by
//! the memory guardrail**: the tables cost O(leaves · 2^D) bytes, so the
//! exact requirement (computed from the cached paths, before anything is
//! allocated) is checked against the `--fastv2-max-mb` budget and the
//! build errors instead of OOMing on deep ensembles. Within budget, the
//! tables build once per model and are shared by every instance — row
//! shards, grid replicas, executor rebuilds — with the *measured* time
//! to obtain them reported as setup cost (≈0 on a warm rebuild).

use std::sync::Arc;

use crate::backend::{planner, prepared, BackendCaps, BackendKind, PreparedModel, ShapBackend};
use crate::gbdt::Model;
use crate::shap::fast_v2::{self, FastV2Model};
use crate::util::error::Result;
use crate::util::time_it;

pub struct FastV2Backend {
    fm: Arc<FastV2Model>,
    model: Arc<Model>,
    prep: Arc<PreparedModel>,
    threads: usize,
    caps: BackendCaps,
}

impl FastV2Backend {
    pub fn new(model: &Arc<Model>, threads: usize, max_table_mb: usize) -> Result<FastV2Backend> {
        FastV2Backend::with_prepared(prepared::prepare(model), threads, max_table_mb)
    }

    /// Construct over an existing prepared-model cache entry (the path
    /// every `backend::build` takes; `new` is the one-model shorthand).
    /// Errs — before any table is allocated — when the exact table bytes
    /// exceed `max_table_mb`.
    pub fn with_prepared(
        prep: Arc<PreparedModel>,
        threads: usize,
        max_table_mb: usize,
    ) -> Result<FastV2Backend> {
        let need = prep.fastv2_table_bytes();
        let budget = max_table_mb as f64 * 1024.0 * 1024.0;
        if need > budget {
            return Err(crate::anyhow!(
                "backend 'fastv2' needs {:.0} MiB of subset weight tables, over the \
                 {max_table_mb} MiB budget — raise --fastv2-max-mb or use a shallower \
                 model (table size grows as leaves × 2^depth)",
                need / (1024.0 * 1024.0)
            ));
        }
        let shape = prep.shape();
        let (fm, setup_s) = time_it(|| prep.fastv2());
        let est = planner::estimate(BackendKind::FastV2, &shape);
        Ok(FastV2Backend {
            fm,
            model: Arc::clone(prep.model()),
            prep,
            threads,
            caps: BackendCaps {
                supports_interactions: false,
                setup_cost_s: setup_s,
                batch_overhead_s: est.batch_overhead_s,
                rows_per_s: est.rows_per_s,
            },
        })
    }
}

impl ShapBackend for FastV2Backend {
    fn name(&self) -> &'static str {
        BackendKind::FastV2.name()
    }

    fn caps(&self) -> BackendCaps {
        self.caps
    }

    fn num_features(&self) -> usize {
        self.fm.num_features
    }

    fn num_groups(&self) -> usize {
        self.fm.num_groups
    }

    fn contributions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        Ok(fast_v2::shap_values(&self.fm, x, rows, self.threads))
    }

    fn interactions(&self, _x: &[f32], _rows: usize) -> Result<Vec<f32>> {
        Err(crate::anyhow!(
            "backend 'fastv2' computes φ only; request interactions via --backend auto \
             so a Φ-capable backend serves them"
        ))
    }

    fn predictions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        let m = self.model.num_features;
        let g = self.model.num_groups;
        let mut out = Vec::with_capacity(rows * g);
        for r in 0..rows {
            out.extend(self.model.predict_row_raw(&x[r * m..(r + 1) * m]));
        }
        Ok(out)
    }

    fn prepared(&self) -> Option<&Arc<PreparedModel>> {
        Some(&self.prep)
    }

    fn describe(&self) -> String {
        format!(
            "fastv2[weight-tables, {:.1} MiB over {} paths, d ≤ {}, {} threads]",
            self.fm.table_bytes() as f64 / (1024.0 * 1024.0),
            self.fm.num_paths(),
            self.fm.max_unique_features(),
            self.threads
        )
    }
}
