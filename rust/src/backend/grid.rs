//! The rows × trees grid [`ShapBackend`]: an outer tree-axis split
//! whose shards are inner row-axis replica groups — the nested sharding
//! the ROADMAP calls for when one axis saturates (8 devices over a
//! 4-tree model → e.g. 2 row-shards × 4 tree-shards).
//!
//! Layout: the ensemble is cut into `tree_shards` leaf-balanced slices
//! ([`shard::split_trees`]); each slice is served by a row-axis
//! [`ShardedBackend`] of `row_shards` replicas that split the batch via
//! the usual throughput-weighted chunk queues. A batch fans out to
//! every slice concurrently, each slice fans its rows across its
//! replicas, the per-slice φ/Φ are summed and the
//! `(slices − 1) · base_score` surplus removed — so a grid's output is
//! bit-identical to a tree-axis `ShardedBackend` at the same slice
//! count (the per-row values come from the same sub-ensembles, and the
//! slice sums associate in the same order).
//!
//! **Cache-aware**: all `row_shards` replicas of one slice are built
//! from ONE shared sub-model `Arc`, so the prepared-model registry
//! ([`backend::prepare`]) holds exactly `tree_shards` entries — each
//! sub-ensemble packs once, not once per replica. An r×t grid pays the
//! preparation of a t-way tree split, not of r·t models.
//!
//! **Elastic**, cell-granular: a failed cell (slice `t`, replica `r`)
//! is quarantined by dropping that one replica — the slice's surviving
//! replicas hold the same sub-model, so only their chunk shares shift
//! (and their throughput EWMAs are kept, remapped). Only when a slice
//! loses its *last* replica does the grid fall back to the tree-axis
//! rebuild: the survivors re-split the full ensemble at reduced slice
//! count. Hot-add refills replica gaps in place (the slice's prepared
//! entry is still live, so new replicas hit the cache) and only
//! re-splits when a whole slice has to come back.

use std::sync::{Arc, Mutex};

use crate::backend::shard::{split_trees, ShardAxis, ShardGrid, ShardTask, CHUNKS_PER_SHARD};
use crate::backend::sharded::{build_concurrently, run_additive};
use crate::backend::{
    self, BackendCaps, BackendConfig, BackendKind, ShapBackend, ShardObserver, ShardedBackend,
};
use crate::gbdt::Model;
use crate::util::error::Result;

/// Everything needed to re-split the ensemble at a different slice
/// count or refill replicas — present when the grid was built through
/// [`GridBackend::build`].
struct Recipe {
    model: Arc<Model>,
    kind: BackendKind,
    cfg: BackendConfig,
    /// the shared sub-model behind each slice, in slice order — replica
    /// hot-add rebuilds from these so the prepared entries are reused
    slices: Vec<Arc<Model>>,
}

pub struct GridBackend {
    /// one row-axis replica group per tree slice, in slice order
    groups: Vec<ShardedBackend>,
    /// the planned grid shape — quarantine shrinks the live topology,
    /// hot-add grows it back toward this
    planned: ShardGrid,
    kind_name: &'static str,
    num_features: usize,
    num_groups: usize,
    base_score: f32,
    caps: BackendCaps,
    observer: Option<ShardObserver>,
    rebuild: Option<Recipe>,
    /// slices that failed in the most recent execution — the groups name
    /// their own failed cells; this catches slice-level failures with no
    /// cell attribution (e.g. a malformed output length), which must
    /// still be quarantinable
    failed_slices: Mutex<Vec<usize>>,
    /// cells removed by quarantine since construction
    quarantined: usize,
    /// whether the most recent quarantine only dropped replicas (cells
    /// kept their identity) as opposed to re-splitting the ensemble
    last_quarantine_remapped: bool,
}

impl GridBackend {
    /// Build a `grid.row_shards × grid.tree_shards` topology of `kind`
    /// over `model`. The tree side clamps to the tree count. Each
    /// slice's replicas share one sub-model `Arc`, so the prepared-model
    /// registry ends up with one entry per slice.
    pub fn build(
        model: &Arc<Model>,
        kind: BackendKind,
        cfg: &BackendConfig,
        grid: ShardGrid,
    ) -> Result<GridBackend> {
        let grid = ShardGrid::new(
            grid.row_shards,
            grid.tree_shards.min(model.trees.len().max(1)),
        );
        let widths = vec![grid.row_shards; grid.tree_shards];
        GridBackend::build_with_widths(model, kind, cfg, grid, &widths)
    }

    /// As [`GridBackend::build`], but with an explicit replica width per
    /// slice (each clamped to ≥ 1; `widths.len()` must be the clamped
    /// tree side). The recovery paths use this to build
    /// partially-degraded topologies directly — constructing full
    /// slices only to discard replicas would pay device setup for cells
    /// that are quarantined on arrival. The replica chunk bucket is
    /// still sized for `grid.row_shards`, so later hot-adds refill with
    /// cache-compatible replicas.
    fn build_with_widths(
        model: &Arc<Model>,
        kind: BackendKind,
        cfg: &BackendConfig,
        grid: ShardGrid,
        widths: &[usize],
    ) -> Result<GridBackend> {
        let slices: Vec<Arc<Model>> =
            split_trees(model, grid.tree_shards).into_iter().map(Arc::new).collect();
        debug_assert_eq!(slices.len(), widths.len());
        let groups = build_groups(&slices, widths, grid.row_shards, kind, cfg)?;
        let mut built = GridBackend::from_parts(groups, grid, model.base_score);
        built.rebuild = Some(Recipe {
            model: Arc::clone(model),
            kind,
            cfg: cfg.clone(),
            slices,
        });
        Ok(built)
    }

    /// Wrap pre-built row-replica groups as a grid (tests, embedders).
    /// The caller is responsible for the groups' sub-ensembles being
    /// disjoint tree slices whose union is the full model, in slice
    /// order. Carries no rebuild recipe: replica-drop quarantine works
    /// (survivor replicas hold their slice), but slice-death rebuild and
    /// hot-add need a self-built grid.
    pub fn from_groups(groups: Vec<ShardedBackend>, base_score: f32) -> GridBackend {
        let planned = ShardGrid::new(
            groups.iter().map(|g| g.shard_count()).max().unwrap_or(1),
            groups.len(),
        );
        GridBackend::from_parts(groups, planned, base_score)
    }

    fn from_parts(groups: Vec<ShardedBackend>, planned: ShardGrid, base_score: f32) -> GridBackend {
        assert!(!groups.is_empty(), "grid backend needs ≥1 tree slice");
        GridBackend {
            kind_name: groups[0].name(),
            num_features: groups[0].num_features(),
            num_groups: groups[0].num_groups(),
            base_score,
            caps: grid_caps(&groups),
            observer: None,
            rebuild: None,
            failed_slices: Mutex::new(Vec::new()),
            quarantined: 0,
            last_quarantine_remapped: false,
            planned,
            groups,
        }
    }

    /// The planned grid shape (hot-add's recovery target).
    pub fn grid(&self) -> ShardGrid {
        self.planned
    }

    /// Live tree slices (shrinks when a slice loses its last replica).
    pub fn tree_slices(&self) -> usize {
        self.groups.len()
    }

    /// The live row-replica groups, in slice order (tests, stats).
    pub fn groups(&self) -> &[ShardedBackend] {
        &self.groups
    }

    /// Cells removed by quarantine since construction.
    pub fn quarantined_cells(&self) -> usize {
        self.quarantined
    }

    /// Flat cell index boundaries per group: cell `(g, r)` has flat
    /// index `offsets[g] + r`; `offsets[groups.len()]` is the total.
    fn offsets(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.groups.len() + 1);
        let mut acc = 0usize;
        for g in &self.groups {
            out.push(acc);
            acc += g.shard_count();
        }
        out.push(acc);
        out
    }

    /// Remove failed cells. Replica failures drop the one instance from
    /// their slice's group (survivor EWMAs kept, indices shifted); a
    /// slice whose every replica failed triggers the tree-axis rebuild
    /// over the surviving slice count (needs the rebuild recipe). At
    /// least one cell must survive.
    pub fn quarantine_cells(&mut self, failed: &[usize]) -> Result<usize> {
        let offs = self.offsets();
        let total = *offs.last().unwrap();
        let mut valid: Vec<usize> = failed.iter().copied().filter(|&c| c < total).collect();
        valid.sort_unstable();
        valid.dedup();
        if valid.is_empty() {
            return Ok(0);
        }
        if valid.len() >= total {
            return Err(crate::anyhow!(
                "cannot quarantine all {total} grid cell(s): no survivors to serve from"
            ));
        }
        let mut per_group: Vec<Vec<usize>> = vec![Vec::new(); self.groups.len()];
        for &c in &valid {
            let gi = offs.partition_point(|&o| o <= c) - 1;
            per_group[gi].push(c - offs[gi]);
        }
        let dead_slice = per_group
            .iter()
            .enumerate()
            .any(|(gi, locals)| locals.len() >= self.groups[gi].shard_count());
        if dead_slice {
            // a slice lost its last replica: the survivors cannot cover
            // the ensemble at this split — re-split over the slices that
            // still have a live replica (≥1, by the all-cells guard).
            // Record each survivor's (pre-rebuild live width, this-call
            // failures): the rebuild must not hand back more replicas
            // than the slice had live going in, minus what just failed.
            let survivors: Vec<(usize, Vec<usize>)> = per_group
                .iter()
                .enumerate()
                .filter(|(gi, locals)| locals.len() < self.groups[*gi].shard_count())
                .map(|(gi, locals)| (self.groups[gi].shard_count(), locals.clone()))
                .collect();
            let recipe = self.rebuild.as_ref().ok_or_else(|| {
                crate::anyhow!(
                    "grid slice rebuild needs a rebuild recipe (self-built backend)"
                )
            })?;
            let planned = self.planned;
            // each surviving slice rebuilds at its pre-rebuild live
            // width minus this call's failures (≥ 1 by the survivor
            // definition) — building full slices and discarding
            // replicas would pay device setup for cells quarantined on
            // arrival, and neither the cells that just died nor cells
            // quarantined in EARLIER calls may re-enter service here;
            // like every other quarantined cell they come back only
            // through the hot-add probe cycle
            let widths: Vec<usize> =
                survivors.iter().map(|(w, locals)| w - locals.len()).collect();
            let rebuilt = GridBackend::build_with_widths(
                &recipe.model,
                recipe.kind,
                &recipe.cfg,
                ShardGrid::new(planned.row_shards, widths.len()),
                &widths,
            )?;
            let quarantined = self.quarantined + valid.len();
            let observer = self.observer.take();
            *self = rebuilt;
            self.planned = planned; // hot-add still targets the full grid
            self.quarantined = quarantined;
            self.last_quarantine_remapped = false;
            if let Some(obs) = observer {
                self.install_observer(obs);
            }
            return Ok(valid.len());
        }
        // replica-only failures: drop each failed cell from its group —
        // the row-axis quarantine keeps the surviving replicas' measured
        // throughput estimates, remapped to their shifted indices
        let mut removed = 0usize;
        for (gi, locals) in per_group.iter().enumerate() {
            if locals.is_empty() {
                continue;
            }
            removed += self.groups[gi].quarantine_shards(locals)?;
        }
        self.quarantined += removed;
        self.last_quarantine_remapped = true;
        self.failed_slices.lock().unwrap().clear();
        self.caps = grid_caps(&self.groups);
        self.reinstall_observer(); // flat offsets shifted
        Ok(removed)
    }

    /// Grow the topology back toward the planned grid, adding at most
    /// `target − shard_count()` cells (recovery after quarantine; the
    /// serving executor passes the planned total, incremental probes may
    /// pass less). When every slice is still alive, the gaps are
    /// refilled in place: new replicas are built over each slice's
    /// existing sub-model `Arc`, so they hit the slice's live
    /// prepared-model entry instead of re-packing, and the surviving
    /// replicas keep their indices and throughput estimates. A missing
    /// slice forces the full re-split — and because a slice can only
    /// return whole (the ensemble must stay covered at one replica per
    /// slice minimum), that path may overshoot a `target` below the
    /// slice count. Needs the rebuild recipe.
    pub fn grow_to(&mut self, target: usize) -> Result<usize> {
        let before = self.shard_count();
        if target <= before {
            return Ok(0);
        }
        let recipe = self.rebuild.as_ref().ok_or_else(|| {
            crate::anyhow!("grid hot-add needs a rebuild recipe (self-built backend)")
        })?;
        if self.groups.len() < self.planned.tree_shards {
            // a whole slice is gone: the live groups serve a coarser
            // split, so recovery is a fresh re-split — at `target` cells
            // spread near-equally over the planned slices (the slowest
            // slice gates throughput, so a lopsided refill would waste
            // the even cells), never below one replica per slice
            let planned = self.planned;
            let widths = balanced_widths(planned.tree_shards, target.min(planned.total()));
            let rebuilt = GridBackend::build_with_widths(
                &recipe.model,
                recipe.kind,
                &recipe.cfg,
                planned,
                &widths,
            )?;
            let quarantined = self.quarantined;
            let observer = self.observer.take();
            *self = rebuilt;
            self.quarantined = quarantined;
            if let Some(obs) = observer {
                self.install_observer(obs);
            }
            return Ok(self.shard_count().saturating_sub(before));
        }
        // all slices alive: refill replica gaps from the shared
        // sub-model Arcs (prepared-cache hits, survivors untouched).
        // The refill MUST use the same per-replica config as the
        // original build — a different rows_hint bucket would size a
        // device backend's executable differently and miss the cache
        let kind = recipe.kind;
        let inner_cfg = replica_cfg(&recipe.cfg, self.planned.row_shards);
        let slices = recipe.slices.clone();
        let row_shards = self.planned.row_shards;
        let budget = target - before;
        let mut added = 0usize;
        'refill: for (gi, group) in self.groups.iter_mut().enumerate() {
            while group.shard_count() < row_shards {
                if added >= budget {
                    break 'refill;
                }
                let b = backend::build(&slices[gi], kind, &inner_cfg)
                    .map_err(|e| e.context(format!("tree slice {gi} replica hot-add")))?;
                group.push_backend(b);
                added += 1;
            }
        }
        if added > 0 {
            self.caps = grid_caps(&self.groups);
            self.reinstall_observer();
        }
        Ok(added)
    }

    fn install_observer(&mut self, obs: ShardObserver) {
        self.observer = Some(obs);
        self.reinstall_observer();
    }

    /// (Re)wire each group's observer to report flat cell indices —
    /// called whenever the topology (and therefore the offsets) changes.
    fn reinstall_observer(&mut self) {
        let Some(obs) = self.observer.clone() else { return };
        let offs = self.offsets();
        for (gi, g) in self.groups.iter_mut().enumerate() {
            let obs = Arc::clone(&obs);
            let off = offs[gi];
            g.set_shard_observer(Arc::new(move |s, rows, dt| {
                (obs.as_ref())(off + s, rows, dt)
            }));
        }
    }

    /// Fan one task out: every slice runs the full batch over its own
    /// row-replica group; per-slice φ/Φ are summed and the base surplus
    /// removed — the tree-axis additive merge ([`run_additive`], shared
    /// with `ShardedBackend::run_trees` so the summation order and base
    /// correction cannot drift between the two executors).
    fn run<F>(&self, x: &[f32], rows: usize, task: ShardTask, f: F) -> Result<Vec<f32>>
    where
        F: Fn(&dyn ShapBackend, &[f32], usize) -> Result<Vec<f32>> + Sync,
    {
        self.failed_slices.lock().unwrap().clear();
        let n = self.groups.len();
        if n == 1 {
            // one slice = the full ensemble: its row group's output is
            // already complete (no surplus to correct)
            return f(&self.groups[0] as &dyn ShapBackend, x, rows);
        }
        let units: Vec<&dyn ShapBackend> =
            self.groups.iter().map(|g| g as &dyn ShapBackend).collect();
        run_additive(
            &units,
            x,
            rows,
            task,
            self.num_groups,
            self.num_features,
            self.base_score,
            "tree slice",
            // groups observe their own cells (flat-indexed observers are
            // installed per group), so slice-level timing is a no-op
            &|_si, _t0| {},
            &|si| self.failed_slices.lock().unwrap().push(si),
            &f,
        )
    }
}

/// The per-replica construction config: no re-sharding, and the batch
/// bucket sized to the row chunk a cell actually executes
/// (`~rows/(r·CHUNKS_PER_SHARD)`, mirroring `ShardedBackend::build`).
/// One definition shared by the initial build and replica hot-add, so a
/// refilled replica is built exactly like the originals (same device
/// executable bucket → same prepared-cache entry).
fn replica_cfg(cfg: &BackendConfig, row_shards: usize) -> BackendConfig {
    let mut inner_cfg = cfg.clone();
    inner_cfg.devices = 1; // inner builds must not re-shard
    inner_cfg.shard_axis = None;
    let per_chunk = row_shards.max(1) * CHUNKS_PER_SHARD;
    inner_cfg.rows_hint = (cfg.rows_hint.max(1) + per_chunk - 1) / per_chunk;
    inner_cfg
}

/// One row-axis replica group per slice (`widths[i]` replicas of slice
/// `i`, each clamped to ≥ 1). Every replica of a slice is built over
/// the SAME sub-model `Arc`, so `backend::prepare`'s registry dedupes
/// the preparation: an r×t grid prepares `t` sub-ensembles, not `r·t`.
/// All cells of all slices build in ONE concurrent wave — setup
/// (packing, device clients, compilation) dominates at high cell
/// counts, and a per-slice sequence would pay it `t` times over.
fn build_groups(
    slices: &[Arc<Model>],
    widths: &[usize],
    bucket_replicas: usize,
    kind: BackendKind,
    cfg: &BackendConfig,
) -> Result<Vec<ShardedBackend>> {
    let inner_cfg = replica_cfg(cfg, bucket_replicas);
    let mut flat: Vec<Arc<Model>> = Vec::new();
    for (sub, &w) in slices.iter().zip(widths) {
        // warm the slice's one shared entry so the concurrent replica
        // builds below all hit (the sub-ensemble packs once)
        backend::prepare(sub);
        for _ in 0..w.max(1) {
            flat.push(Arc::clone(sub));
        }
    }
    let mut inner = build_concurrently(&flat, kind, &inner_cfg)
        .map_err(|e| e.context("grid replica build"))?;
    let mut groups = Vec::with_capacity(slices.len());
    for (sub, &w) in slices.iter().zip(widths) {
        let tail = inner.split_off(w.max(1));
        let replicas = std::mem::replace(&mut inner, tail);
        groups.push(ShardedBackend::from_backends(replicas, ShardAxis::Rows, sub.base_score));
    }
    Ok(groups)
}

/// Near-equal replica widths for `cells` total over `slices` groups,
/// each at least 1 (every slice must keep a replica or the ensemble is
/// uncovered). Used by hot-add's missing-slice rebuild so a `target`
/// below the planned total lands on a balanced grid — the slowest
/// slice gates throughput, so `[1, 3]` serves half as fast as `[2, 2]`.
fn balanced_widths(slices: usize, cells: usize) -> Vec<usize> {
    let slices = slices.max(1);
    let cells = cells.max(slices);
    (0..slices).map(|i| cells * (i + 1) / slices - cells * i / slices).collect()
}

/// Aggregate capability/cost metadata over the slice groups: every
/// slice runs every row, so the slowest slice gates throughput (a
/// group's own rate is already the sum of its replicas).
fn grid_caps(groups: &[ShardedBackend]) -> BackendCaps {
    BackendCaps {
        supports_interactions: groups.iter().all(|g| g.caps().supports_interactions),
        setup_cost_s: groups.iter().map(|g| g.caps().setup_cost_s).fold(0.0, f64::max),
        batch_overhead_s: groups
            .iter()
            .map(|g| g.caps().batch_overhead_s)
            .fold(0.0, f64::max),
        rows_per_s: groups
            .iter()
            .map(|g| g.caps().rows_per_s)
            .fold(f64::INFINITY, f64::min),
    }
}

impl ShapBackend for GridBackend {
    fn name(&self) -> &'static str {
        self.kind_name
    }

    fn caps(&self) -> BackendCaps {
        self.caps
    }

    fn num_features(&self) -> usize {
        self.num_features
    }

    fn num_groups(&self) -> usize {
        self.num_groups
    }

    fn contributions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        self.run(x, rows, ShardTask::Contributions, |b, x, r| b.contributions(x, r))
    }

    fn interactions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        self.run(x, rows, ShardTask::Interactions, |b, x, r| b.interactions(x, r))
    }

    fn predictions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        self.run(x, rows, ShardTask::Predictions, |b, x, r| b.predictions(x, r))
    }

    fn set_shard_observer(&mut self, obs: ShardObserver) {
        self.install_observer(obs);
    }

    fn shard_count(&self) -> usize {
        self.groups.iter().map(|g| g.shard_count()).sum()
    }

    fn failed_shards(&self) -> Vec<usize> {
        let offs = self.offsets();
        let failed_slices = self.failed_slices.lock().unwrap().clone();
        let mut out = Vec::new();
        for (gi, g) in self.groups.iter().enumerate() {
            let cells = g.failed_shards();
            if cells.is_empty() && failed_slices.contains(&gi) {
                // the slice failed as a unit without naming a cell
                // (e.g. a malformed output length): attribute every
                // cell so the executor can still quarantine the slice
                out.extend((0..g.shard_count()).map(|s| offs[gi] + s));
            } else {
                out.extend(cells.into_iter().map(|s| offs[gi] + s));
            }
        }
        out.sort_unstable();
        out
    }

    fn quarantine(&mut self, failed: &[usize]) -> Result<usize> {
        self.quarantine_cells(failed)
    }

    fn quarantine_remaps_survivors(&self) -> bool {
        self.last_quarantine_remapped
    }

    fn hot_add(&mut self, target: usize) -> Result<usize> {
        self.grow_to(target)
    }

    fn prepared(&self) -> Option<&Arc<crate::backend::PreparedModel>> {
        // the first slice's entry (stats inspection — every slice's
        // entry stays reachable through `groups()`)
        self.groups[0].prepared()
    }

    fn set_shard_throughputs(&self, rows_per_s: &[(usize, f64)]) {
        let offs = self.offsets();
        for (gi, g) in self.groups.iter().enumerate() {
            let (lo, hi) = (offs[gi], offs[gi + 1]);
            let local: Vec<(usize, f64)> = rows_per_s
                .iter()
                .filter(|(s, _)| *s >= lo && *s < hi)
                .map(|(s, r)| (s - lo, *r))
                .collect();
            if !local.is_empty() {
                g.set_shard_throughputs(&local);
            }
        }
    }

    fn describe(&self) -> String {
        let widths: Vec<String> =
            self.groups.iter().map(|g| g.shard_count().to_string()).collect();
        let quarantined = if self.quarantined > 0 {
            format!(", {} quarantined", self.quarantined)
        } else {
            String::new()
        };
        format!(
            "grid[{}×trees × {}×rows ({} replicas/slice), {}{}]",
            self.groups.len(),
            self.planned.row_shards,
            widths.join("/"),
            self.groups[0].describe(),
            quarantined
        )
    }
}
