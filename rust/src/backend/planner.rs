//! Crossover-aware backend planner (the Fig 4 heuristic as code).
//!
//! Every backend's batch latency is modelled as the paper's two-term
//! line: `latency(rows) = batch_overhead + rows / throughput`. CPU-side
//! backends have ~zero overhead but a per-row cost quadratic in path
//! length (the DP unwind); the accelerator backends pay a fixed
//! launch/upload overhead per batch but a far smaller per-row marginal.
//! The planner picks the backend minimising estimated latency for the
//! requested batch size — reproducing Fig 4's CPU/accelerator crossover
//! — and exposes the predicted crossover point for benches to check
//! against measurement.
//!
//! With a device topology (`with_devices`) the heuristic generalizes to
//! N devices: every candidate is additionally scored across shard
//! counts `1..=devices`, on both simple shard axes **and** on every
//! rows × trees grid factorization of the device count. Row sharding
//! divides the per-row term by `min(r, rows)` (each device pays its own
//! batch overhead, and each row shard pays it once per dispatched chunk
//! — `CHUNKS_PER_SHARD` serial dispatches, not one); tree sharding
//! divides it by `min(t, trees)` and adds a merge pass per extra slice.
//! A grid multiplies both divisors, which is why 8 devices over a
//! 4-tree model plan onto a 2×4 grid for batches too small to fill the
//! row axis — the regime where both simple axes saturate.

use crate::backend::calibrate::{self, Observations};
use crate::backend::shard::{ShardAxis, ShardGrid, CHUNKS_PER_SHARD};
use crate::backend::BackendKind;
use crate::gbdt::Model;
use crate::shap::model_paths;

/// Shape statistics the cost model keys on, derivable from the model
/// alone (no packing or artifacts needed).
#[derive(Clone, Copy, Debug)]
pub struct ModelShape {
    pub features: usize,
    pub groups: usize,
    pub trees: usize,
    pub leaves: usize,
    pub max_depth: usize,
    /// mean merged-path length (elements incl. the root element)
    pub avg_path_len: f64,
    /// longest merged-path length — the padded layout's element width
    pub max_path_len: usize,
}

impl ModelShape {
    pub fn of(model: &Model) -> ModelShape {
        ModelShape::from_paths(model, &model_paths(model))
    }

    /// As [`ModelShape::of`], over already-extracted tagged paths — the
    /// prepared-model cache derives the shape from its cached extraction
    /// instead of walking the ensemble again.
    pub fn from_paths(model: &Model, paths: &[(usize, crate::shap::Path)]) -> ModelShape {
        let total: usize = paths.iter().map(|(_, p)| p.len()).sum();
        let max_path_len = paths.iter().map(|(_, p)| p.len()).max().unwrap_or(1);
        ModelShape {
            features: model.num_features,
            groups: model.num_groups,
            trees: model.trees.len(),
            leaves: model.total_leaves(),
            max_depth: model.max_depth(),
            avg_path_len: total as f64 / paths.len().max(1) as f64,
            max_path_len,
        }
    }
}

/// Shape-estimated bytes of [`BackendKind::FastV2`]'s subset weight
/// tables: `leaves × 2^D × 8` with `D = max_path_len − 1` unique
/// features on the deepest merged path. A deliberate upper bound on the
/// exact per-path sum (`shap::fast_v2::table_bytes_for_paths`): the
/// planner guards with the shape alone so planning never touches path
/// data, and conservative refusal is the safe direction for a memory
/// guardrail. `f64` so deep ensembles report a huge number instead of
/// overflowing.
pub fn fastv2_table_bytes(s: &ModelShape) -> f64 {
    let d = s.max_path_len.saturating_sub(1) as i32;
    s.leaves.max(1) as f64 * (2f64).powi(d) * 8.0
}

/// The two-term latency model for one backend, plus its one-time setup.
#[derive(Clone, Copy, Debug)]
pub struct CostEstimate {
    pub setup_s: f64,
    pub batch_overhead_s: f64,
    pub rows_per_s: f64,
}

/// Default a-priori cost estimate for a backend on a model shape. The
/// constants are rough single-core calibrations; what matters is the
/// *structure* (overhead ordering vs per-row ordering), which produces
/// the crossover. Benches record reality next to these predictions.
pub fn estimate(kind: BackendKind, s: &ModelShape) -> CostEstimate {
    let l = s.leaves.max(1) as f64;
    let a = s.avg_path_len.max(1.0);
    let w = s.max_path_len.max(1) as f64; // padded element-axis width
    match kind {
        // recursive Algorithm 1: no setup, no batch cost, O(L·a²) per row
        BackendKind::Recursive => CostEstimate {
            setup_s: 0.0,
            batch_overhead_s: 0.0,
            rows_per_s: 1.0 / (l * a * a * 40e-9),
        },
        // packed DP on host: pays packing once, smaller per-row constant
        BackendKind::Host => CostEstimate {
            setup_s: l * 2e-7,
            batch_overhead_s: 1e-5,
            rows_per_s: 1.0 / (l * a * a * 15e-9),
        },
        // Linear TreeShap: summary-table setup, per-row cost linear in
        // depth (w, not a²) — overtakes the quadratic CPU kernels as
        // trees deepen; calibration pins the constant empirically
        BackendKind::Linear => CostEstimate {
            setup_s: l * 4e-7,
            batch_overhead_s: 1e-5,
            rows_per_s: 1.0 / (l * w * 35e-9),
        },
        // Fast TreeSHAP v2: per-row cost loses a whole depth factor
        // (O(l·a), the smallest CPU constant) but setup pays the
        // O(l·2^D) table build — the planner only amortizes that over
        // expected batches, and the byte guardrail excludes the kind
        // outright when the table would blow the memory budget
        BackendKind::FastV2 => CostEstimate {
            setup_s: fastv2_table_bytes(s) / 8.0 * 4e-9,
            batch_overhead_s: 1e-5,
            rows_per_s: 1.0 / (l * a * 8e-9),
        },
        // warp-packed accelerator: compile+upload setup, launch overhead
        // per batch, vectorised per-row marginal (linear in path length)
        BackendKind::XlaWarp => CostEstimate {
            setup_s: 0.5,
            batch_overhead_s: 5e-3,
            rows_per_s: 1.0 / (l * a * 0.4e-9),
        },
        // padded layout: gather-free (≈2× the warp constant) but pays
        // the padding waste w/a on every element
        BackendKind::XlaPadded => CostEstimate {
            setup_s: 0.5,
            batch_overhead_s: 4e-3,
            rows_per_s: 1.0 / (l * w * 0.2e-9),
        },
    }
}

/// One planning decision: the chosen backend, how many device shards to
/// spread it over and along which axis, and the estimated latency.
#[derive(Clone, Copy, Debug)]
pub struct Plan {
    pub kind: BackendKind,
    /// device shards (1 = unsharded; for a grid, `row·tree` cells)
    pub shards: usize,
    pub axis: ShardAxis,
    /// the rows × trees shape when `axis` is [`ShardAxis::Grid`]
    /// (`None` on the simple axes)
    pub grid: Option<ShardGrid>,
    pub est_latency_s: f64,
}

impl Plan {
    /// The build-anyway fallback for a kind that is not a planner
    /// candidate (e.g. compiled out): span the full device count on the
    /// pinned simple axis so the caller sees the real construction
    /// error instead of "no backend available". A pinned grid degrades
    /// to rows — without a cost model there is nothing to pick a
    /// factorization with. Shared by `backend::build` and the serving
    /// executor so the two paths cannot drift.
    pub fn fallback(kind: BackendKind, devices: usize, pinned_axis: Option<ShardAxis>) -> Plan {
        Plan {
            kind,
            shards: devices.max(1),
            axis: match pinned_axis {
                Some(ShardAxis::Grid) | None => ShardAxis::Rows,
                Some(axis) => axis,
            },
            grid: None,
            est_latency_s: f64::INFINITY,
        }
    }
}

/// Picks backend + representation + shard layout from model shape,
/// batch size and device topology.
pub struct Planner {
    pub shape: ModelShape,
    candidates: Vec<(BackendKind, CostEstimate)>,
    /// the a-priori estimates the candidates started from; calibration
    /// always re-blends against these, never against its own output
    priors: Vec<(BackendKind, CostEstimate)>,
    /// measured samples behind each candidate's current estimate:
    /// `(kind, steady-state samples, first-batch samples)`
    samples: Vec<(BackendKind, usize, usize)>,
    /// device topology: how many shards a plan may spread over
    devices: usize,
    /// batches the one-time prep cost amortizes over when pricing plans:
    /// a long-lived service spreads `setup_s` across its whole cadence
    /// (the default, `INFINITY`, prices prep at zero — pure steady
    /// state); a one-shot caller sets 1 and pays it in full
    expected_batches: f64,
    /// memory budget for `FastV2`'s weight tables, bytes: plans for that
    /// kind are refused when [`fastv2_table_bytes`] exceeds this, so a
    /// deep ensemble never OOMs building tables the planner picked
    fastv2_budget_bytes: f64,
}

impl Planner {
    /// Planner over every backend kind compiled into this binary,
    /// single-device. Chain [`Planner::with_devices`] for a topology.
    pub fn for_model(model: &Model) -> Planner {
        Planner::from_shape(ModelShape::of(model))
    }

    /// Planner over a prepared model: the shape comes from the cache's
    /// one-time path extraction instead of a fresh ensemble walk.
    pub fn for_prepared(prepared: &crate::backend::PreparedModel) -> Planner {
        Planner::from_shape(prepared.shape())
    }

    fn from_shape(shape: ModelShape) -> Planner {
        let candidates: Vec<(BackendKind, CostEstimate)> = BackendKind::ALL
            .iter()
            .copied()
            .filter(|k| k.compiled_in())
            .map(|k| (k, estimate(k, &shape)))
            .collect();
        Planner::with_candidates(shape, candidates)
    }

    /// Planner with explicit candidates (tests, measured calibrations).
    pub fn with_candidates(
        shape: ModelShape,
        candidates: Vec<(BackendKind, CostEstimate)>,
    ) -> Planner {
        Planner {
            shape,
            priors: candidates.clone(),
            samples: Vec::new(),
            candidates,
            devices: 1,
            expected_batches: f64::INFINITY,
            fastv2_budget_bytes: crate::backend::DEFAULT_FASTV2_MAX_MB as f64 * 1024.0 * 1024.0,
        }
    }

    /// Set the device topology plans may shard across.
    pub fn with_devices(mut self, devices: usize) -> Planner {
        self.devices = devices.max(1);
        self
    }

    /// Amortize each candidate's one-time prep cost (`setup_s`) over
    /// `batches` expected executions when pricing plans. A serving
    /// executor passes its recalibration cadence; one-shot callers pass
    /// 1 so a heavy-setup backend must win by enough to pay for its own
    /// preparation. The default (no call) prices prep at zero.
    pub fn with_expected_batches(mut self, batches: usize) -> Planner {
        self.expected_batches = batches.max(1) as f64;
        self
    }

    /// Set the `FastV2` weight-table memory budget (`--fastv2-max-mb`).
    pub fn with_fastv2_budget_mb(mut self, mb: usize) -> Planner {
        self.fastv2_budget_bytes = mb as f64 * 1024.0 * 1024.0;
        self
    }

    pub fn devices(&self) -> usize {
        self.devices
    }

    /// The memory guardrail: does `FastV2`'s shape-estimated table fit
    /// the configured budget? `false` removes the kind from
    /// [`Planner::plan_for`], [`Planner::plan_pinned`] and every ranking
    /// built on them — the planner *refuses* rather than deprioritizes,
    /// because a cost model cannot price an OOM.
    pub fn fastv2_fits(&self) -> bool {
        fastv2_table_bytes(&self.shape) <= self.fastv2_budget_bytes
    }

    /// Estimated latency to explain `rows` rows in one unsharded batch.
    pub fn batch_cost(&self, kind: BackendKind, rows: usize) -> Option<f64> {
        self.candidates
            .iter()
            .find(|(k, _)| *k == kind)
            .map(|(_, c)| c.batch_overhead_s + rows as f64 / c.rows_per_s)
    }

    /// Estimated latency for `rows` rows over an `r × t` layout
    /// (`r` row shards per tree slice, `t` slices; `r = 1` or `t = 1`
    /// recover the simple axes, `1 × 1` the unsharded line).
    ///
    /// - The per-row term divides across the *effective* parallelism
    ///   `min(r, rows) · min(t, trees)` — rows can't split below one row
    ///   per replica, trees below one tree per slice.
    /// - Row shards drain their chunk queues serially: each pays the
    ///   backend's batch overhead once per dispatched chunk — up to
    ///   [`CHUNKS_PER_SHARD`] dispatches, not one. (On device backends
    ///   this is a 4× term; pricing it at 1× underpriced row sharding
    ///   and skewed every rows-vs-trees-vs-grid decision.)
    /// - Tree slices pay one output-merge pass per extra slice.
    fn layout_cost(&self, c: &CostEstimate, rows: usize, r: usize, t: usize) -> f64 {
        let r = r.max(1);
        let t = t.clamp(1, self.shape.trees.max(1));
        let r_eff = r.min(rows.max(1)) as f64;
        let t_eff = t as f64;
        let merge = if t > 1 {
            (t as f64 - 1.0) * rows as f64 * (self.shape.features as f64 + 1.0) * 2e-9
        } else {
            0.0
        };
        let dispatches = if r > 1 {
            let per_shard = (rows as f64 / r as f64).ceil().max(1.0);
            per_shard.min(CHUNKS_PER_SHARD as f64)
        } else {
            1.0
        };
        // prep amortization: the one-time setup (packing, upload,
        // compilation — or ~0 on a prepared-model cache hit) spread over
        // the expected batch count; zero under the default (∞) horizon
        let prep = c.setup_s / self.expected_batches;
        dispatches * c.batch_overhead_s + (rows as f64 / (r_eff * t_eff)) / c.rows_per_s
            + merge
            + prep
    }

    /// A concrete plan for one `r × t` layout, labelled by shape:
    /// `t = 1` is the row axis, `r = 1` the tree axis, both > 1 a grid.
    fn layout_plan(
        &self,
        kind: BackendKind,
        c: &CostEstimate,
        rows: usize,
        r: usize,
        t: usize,
    ) -> Plan {
        let r = r.max(1);
        let t = t.clamp(1, self.shape.trees.max(1));
        let (axis, grid) = if t == 1 {
            (ShardAxis::Rows, None)
        } else if r == 1 {
            (ShardAxis::Trees, None)
        } else {
            (ShardAxis::Grid, Some(ShardGrid::new(r, t)))
        };
        Plan {
            kind,
            shards: r * t,
            axis,
            grid,
            est_latency_s: self.layout_cost(c, rows, r, t),
        }
    }

    /// Best shard layout for one backend kind at this batch size, or
    /// `None` when the kind is not a candidate. Scores every device
    /// count on the row axis, the tree axis, and every rows × trees
    /// factorization. Ties prefer fewer shards, then the row axis (the
    /// paper's scheme), then trees, then grids.
    pub fn plan_for(&self, kind: BackendKind, rows: usize) -> Option<Plan> {
        if kind == BackendKind::FastV2 && !self.fastv2_fits() {
            return None;
        }
        let c = self.candidates.iter().find(|(k, _)| *k == kind)?.1;
        let trees = self.shape.trees.max(1);
        let mut best: Option<Plan> = None;
        for shards in 1..=self.devices {
            // simple axes first (tie-breaks keep the earliest candidate),
            // then the genuinely 2-D factorizations of this device count
            let mut layouts: Vec<(usize, usize)> = vec![(shards, 1), (1, shards.min(trees))];
            layouts.extend(
                ShardGrid::factorizations(shards, trees)
                    .into_iter()
                    .filter(|g| !g.is_trivial())
                    .map(|g| (g.row_shards, g.tree_shards)),
            );
            for (r, t) in layouts {
                let plan = self.layout_plan(kind, &c, rows, r, t);
                let better = match &best {
                    None => true,
                    Some(b) => plan.est_latency_s < b.est_latency_s - 1e-15,
                };
                if better {
                    best = Some(plan);
                }
            }
        }
        best
    }

    /// The plan for one backend kind with the shard layout pinned by the
    /// caller (`--shard-axis`): the tree axis clamps to the tree count,
    /// and the estimate prices the pinned layout, not the kind's best.
    /// A pinned grid picks the cheapest genuinely 2-D factorization of
    /// at most `shards` cells; when none exists (prime device counts,
    /// `devices < 4`, single-tree models) it degrades to the best simple
    /// layout within the budget.
    pub fn plan_pinned(
        &self,
        kind: BackendKind,
        rows: usize,
        axis: ShardAxis,
        shards: usize,
    ) -> Option<Plan> {
        if kind == BackendKind::FastV2 && !self.fastv2_fits() {
            return None;
        }
        let c = self.candidates.iter().find(|(k, _)| *k == kind)?.1;
        let shards = shards.max(1);
        match axis {
            ShardAxis::Rows => Some(self.layout_plan(kind, &c, rows, shards, 1)),
            ShardAxis::Trees => Some(self.layout_plan(kind, &c, rows, 1, shards)),
            ShardAxis::FeatureTiles => {
                // tiles split Φ's conditioned-feature loop: per-row work
                // divides by the effective tile count (clamped to the
                // feature count — one feature cannot split further), and
                // the coordinator pays one assembly pass over the
                // (M+1)² output matrix. Priced on the same per-row line
                // as the other axes so cross-axis rankings compare.
                // Never auto-picked ([`Planner::plan_for`] sweeps only
                // rows/trees/grid): the axis only helps interaction
                // workloads, which the batch-size argument can't see.
                let t = shards.clamp(1, self.shape.features.max(1));
                let t_eff = t as f64;
                let m = self.shape.features as f64;
                let assemble = if t > 1 {
                    rows as f64 * (m + 1.0) * (m + 1.0) * 2e-9
                } else {
                    0.0
                };
                Some(Plan {
                    kind,
                    shards: t,
                    axis: ShardAxis::FeatureTiles,
                    grid: None,
                    est_latency_s: c.batch_overhead_s
                        + (rows as f64 / t_eff) / c.rows_per_s
                        + assemble
                        + c.setup_s / self.expected_batches,
                })
            }
            ShardAxis::Grid => {
                let trees = self.shape.trees.max(1);
                let pick = |require_2d: bool| -> Option<Plan> {
                    let mut best: Option<Plan> = None;
                    for total in 1..=shards {
                        for g in ShardGrid::factorizations(total, trees) {
                            if require_2d && g.is_trivial() {
                                continue;
                            }
                            let plan =
                                self.layout_plan(kind, &c, rows, g.row_shards, g.tree_shards);
                            let better = match &best {
                                None => true,
                                Some(b) => plan.est_latency_s < b.est_latency_s - 1e-15,
                            };
                            if better {
                                best = Some(plan);
                            }
                        }
                    }
                    best
                };
                pick(true).or_else(|| pick(false))
            }
        }
    }

    /// All candidates (each with its best shard layout) ordered by
    /// estimated latency for this batch size.
    pub fn ranked(&self, rows: usize) -> Vec<Plan> {
        let mut plans: Vec<Plan> = self
            .candidates
            .iter()
            .filter_map(|(k, _)| self.plan_for(*k, rows))
            .collect();
        plans.sort_by(|a, b| a.est_latency_s.total_cmp(&b.est_latency_s));
        plans
    }

    /// All candidates priced at one pinned shard layout, ordered by
    /// estimated latency.
    pub fn ranked_pinned(&self, rows: usize, axis: ShardAxis, shards: usize) -> Vec<Plan> {
        let mut plans: Vec<Plan> = self
            .candidates
            .iter()
            .filter_map(|(k, _)| self.plan_pinned(*k, rows, axis, shards))
            .collect();
        plans.sort_by(|a, b| a.est_latency_s.total_cmp(&b.est_latency_s));
        plans
    }

    /// The winning backend + shard layout for this batch size.
    pub fn choose(&self, rows: usize) -> Plan {
        self.ranked(rows)
            .into_iter()
            .next()
            .expect("planner has no candidate backends")
    }

    /// Batch size at which `fast` overtakes `slow` (Fig 4's crossover):
    /// `None` if `fast` never catches up, `Some(0)` if it always wins.
    pub fn crossover_rows(&self, slow: BackendKind, fast: BackendKind) -> Option<usize> {
        let cs = self.candidates.iter().find(|(k, _)| *k == slow)?.1;
        let cf = self.candidates.iter().find(|(k, _)| *k == fast)?.1;
        let d_over = cf.batch_overhead_s - cs.batch_overhead_s;
        let d_rate = 1.0 / cs.rows_per_s - 1.0 / cf.rows_per_s;
        if d_rate <= 0.0 {
            return None;
        }
        if d_over <= 0.0 {
            return Some(0);
        }
        Some((d_over / d_rate).ceil() as usize)
    }

    /// Re-fit every candidate's cost line from measured batch samples
    /// (keyed by backend *name* — how the metrics record them), blending
    /// against the a-priori estimate so thin evidence nudges rather than
    /// replaces. Steady-state samples fit the two-term per-batch line;
    /// first-batch (prep-inclusive) samples, kept separate by the
    /// metrics, re-fit the one-time `setup_s` term against that line —
    /// so warmup cost never contaminates the steady slope and the
    /// amortized-prep pricing reflects what prep actually costs here.
    /// Returns `true` when any candidate's estimate moved, so callers
    /// know a cached plan may be stale. Idempotent for a fixed
    /// observation set: the blend always starts from the stored prior.
    pub fn recalibrate(&mut self, obs: &Observations) -> bool {
        let mut changed = false;
        for (kind, cost) in &mut self.candidates {
            let steady = obs.per_backend.get(kind.name());
            let first = obs.per_backend_first.get(kind.name());
            if steady.is_none() && first.is_none() {
                continue;
            }
            let prior = self
                .priors
                .iter()
                .find(|(k, _)| k == kind)
                .map(|(_, c)| *c)
                .unwrap_or(*cost);
            let mut new = *cost;
            let mut n_steady = 0usize;
            if let Some(samples) = steady {
                if let Some(cal) = calibrate::calibrate(&prior, samples) {
                    new = cal;
                    n_steady = samples.len();
                }
            }
            let mut n_first = 0usize;
            if let Some(firsts) = first {
                if let Some(setup) = calibrate::calibrate_setup(&prior, &new, firsts) {
                    new.setup_s = setup;
                    n_first = firsts.len();
                }
            }
            if n_steady == 0 && n_first == 0 {
                continue;
            }
            let moved = (new.batch_overhead_s - cost.batch_overhead_s).abs()
                > 1e-12 + 1e-6 * cost.batch_overhead_s.abs()
                || (new.rows_per_s - cost.rows_per_s).abs() > 1e-6 * cost.rows_per_s.abs()
                || (new.setup_s - cost.setup_s).abs() > 1e-12 + 1e-6 * cost.setup_s.abs();
            if moved {
                *cost = new;
                changed = true;
            }
            match self.samples.iter_mut().find(|(k, _, _)| k == kind) {
                Some(entry) => {
                    entry.1 = n_steady;
                    entry.2 = n_first;
                }
                None => self.samples.push((*kind, n_steady, n_first)),
            }
        }
        changed
    }

    /// Feed a directly measured construction cost (a built backend's
    /// `caps().setup_cost_s` — which the prepared-model cache drives
    /// toward zero on rebuilds) into the candidate's estimate.
    /// Construction time is observed exactly rather than inferred, so
    /// the measurement replaces the estimate outright — and it anchors
    /// the *prior's* `setup_s` too, like [`Planner::seed_calibration`]:
    /// every later [`Planner::recalibrate`] blend restarts from the
    /// stored prior, so without re-anchoring, the first thin-window
    /// recalibration would snap the setup term back to the shipped
    /// constant and forget the measurement (the FastV2 table-build
    /// `prep_s` path hit exactly this). Returns whether the estimate
    /// moved.
    pub fn observe_setup(&mut self, kind: BackendKind, setup_s: f64) -> bool {
        if !setup_s.is_finite() || setup_s < 0.0 {
            return false;
        }
        if let Some((_, p)) = self.priors.iter_mut().find(|(k, _)| *k == kind) {
            p.setup_s = setup_s;
        }
        match self.candidates.iter_mut().find(|(k, _)| *k == kind) {
            Some((_, c)) => {
                let moved = (c.setup_s - setup_s).abs() > 1e-12 + 1e-6 * c.setup_s.abs();
                c.setup_s = setup_s;
                moved
            }
            None => false,
        }
    }

    /// Export the current (possibly calibrated) estimates with their
    /// steady-state sample counts, for persistence next to the model
    /// artifact (`calibrate::save_calibration`).
    pub fn calibration_snapshot(&self) -> Vec<(String, CostEstimate, usize)> {
        self.candidates
            .iter()
            .map(|(k, c)| (k.name().to_string(), *c, self.calibration_samples(*k)))
            .collect()
    }

    /// Seed candidates from persisted calibration (`name → estimate,
    /// sample count`): a restarted service plans from its previous
    /// measurements immediately instead of re-learning from the prior.
    /// The persisted estimate becomes the new blend *anchor* too —
    /// otherwise the first in-process recalibration (thin fresh window,
    /// low blend weight) would snap most of the way back to the shipped
    /// constants and forget what the previous run learned. Unknown
    /// names are skipped. Returns how many candidates were seeded.
    pub fn seed_calibration(&mut self, entries: &[(String, CostEstimate, usize)]) -> usize {
        let mut applied = 0usize;
        for (name, est, n) in entries {
            let Some(kind) = BackendKind::parse(name) else { continue };
            let Some((_, c)) = self.candidates.iter_mut().find(|(k, _)| *k == kind) else {
                continue;
            };
            *c = *est;
            if let Some((_, p)) = self.priors.iter_mut().find(|(k, _)| *k == kind) {
                *p = *est;
            }
            match self.samples.iter_mut().find(|(k, _, _)| *k == kind) {
                Some(entry) => entry.1 = entry.1.max(*n),
                None => self.samples.push((kind, *n, 0)),
            }
            applied += 1;
        }
        applied
    }

    /// The candidate's *current* estimate (calibrated when observations
    /// have been fed through [`Planner::recalibrate`]).
    pub fn cost(&self, kind: BackendKind) -> Option<CostEstimate> {
        self.candidates.iter().find(|(k, _)| *k == kind).map(|(_, c)| *c)
    }

    /// The candidate's a-priori estimate, untouched by calibration.
    pub fn prior(&self, kind: BackendKind) -> Option<CostEstimate> {
        self.priors.iter().find(|(k, _)| *k == kind).map(|(_, c)| *c)
    }

    /// Measured steady-state samples behind the candidate's current
    /// estimate (0 ⇒ still running on the prior).
    pub fn calibration_samples(&self, kind: BackendKind) -> usize {
        self.samples.iter().find(|(k, _, _)| *k == kind).map_or(0, |(_, n, _)| *n)
    }

    /// Measured first-batch (prep-inclusive) samples behind the
    /// candidate's current `setup_s`.
    pub fn calibration_first_samples(&self, kind: BackendKind) -> usize {
        self.samples.iter().find(|(k, _, _)| *k == kind).map_or(0, |(_, _, n)| *n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;
    use crate::gbdt::{train, TrainParams};

    fn synthetic_planner() -> Planner {
        let shape = ModelShape {
            features: 8,
            groups: 1,
            trees: 10,
            leaves: 100,
            max_depth: 6,
            avg_path_len: 5.0,
            max_path_len: 7,
        };
        Planner::with_candidates(
            shape,
            vec![
                (
                    BackendKind::Recursive,
                    CostEstimate { setup_s: 0.0, batch_overhead_s: 0.0, rows_per_s: 1e4 },
                ),
                (
                    BackendKind::XlaWarp,
                    CostEstimate { setup_s: 0.5, batch_overhead_s: 0.05, rows_per_s: 1e6 },
                ),
            ],
        )
    }

    #[test]
    fn choice_straddles_the_crossover() {
        // overhead 0.05s ÷ (1e-4 − 1e-6 s/row) ⇒ crossover ≈ 506 rows
        let p = synthetic_planner();
        let cross = p
            .crossover_rows(BackendKind::Recursive, BackendKind::XlaWarp)
            .expect("crossover exists");
        assert!(cross > 1, "degenerate crossover {cross}");
        let below = p.choose((cross / 2).max(1));
        let above = p.choose(cross * 2);
        assert_eq!(below.kind, BackendKind::Recursive, "below crossover → CPU");
        assert_eq!(above.kind, BackendKind::XlaWarp, "above crossover → accelerator");
        // and exactly at the crossover the accelerated backend has caught up
        assert!(
            p.batch_cost(BackendKind::XlaWarp, cross).unwrap()
                <= p.batch_cost(BackendKind::Recursive, cross).unwrap() + 1e-9
        );
    }

    #[test]
    fn observe_setup_anchors_the_prior() {
        // regression (FastV2 prep_s): a measured setup cost must survive
        // the next recalibration. `calibrate()` rebuilds each estimate
        // with `setup_s: prior.setup_s`, so observing setup only on the
        // candidate reverted to the shipped constant one recalibrate
        // later.
        let mut p = synthetic_planner();
        assert!(p.observe_setup(BackendKind::XlaWarp, 0.02));
        assert_eq!(p.cost(BackendKind::XlaWarp).unwrap().setup_s, 0.02);
        assert_eq!(p.prior(BackendKind::XlaWarp).unwrap().setup_s, 0.02, "prior anchored");
        // a steady-only recalibration keeps the measured setup term
        let mut obs = Observations::new();
        let line: Vec<(f64, f64)> =
            (1..40).map(|i| (i as f64 * 10.0, 0.05 + i as f64 * 10.0 / 1e6)).collect();
        obs.per_backend.insert(BackendKind::XlaWarp.name().to_string(), line);
        p.recalibrate(&obs);
        assert_eq!(p.cost(BackendKind::XlaWarp).unwrap().setup_s, 0.02);
        // rejects junk, repeat observation reports "unmoved"
        assert!(!p.observe_setup(BackendKind::XlaWarp, f64::NAN));
        assert!(!p.observe_setup(BackendKind::XlaWarp, -1.0));
        assert!(!p.observe_setup(BackendKind::XlaWarp, 0.02));
    }

    #[test]
    fn crossover_edge_cases() {
        let p = synthetic_planner();
        // slower per-row AND more overhead: never catches up
        assert_eq!(p.crossover_rows(BackendKind::XlaWarp, BackendKind::Recursive), None);
        // a backend vs itself: d_rate = 0 ⇒ None
        assert_eq!(p.crossover_rows(BackendKind::Recursive, BackendKind::Recursive), None);
        // unknown candidate ⇒ None
        assert_eq!(p.crossover_rows(BackendKind::Recursive, BackendKind::Host), None);
    }

    #[test]
    fn device_topology_generalizes_the_crossover() {
        let p = synthetic_planner().with_devices(4);
        // large batch: shard by rows across the full topology
        let big = p.plan_for(BackendKind::Recursive, 100_000).unwrap();
        assert_eq!(big.shards, 4);
        assert_eq!(big.axis, ShardAxis::Rows);
        assert!(
            big.est_latency_s < p.batch_cost(BackendKind::Recursive, 100_000).unwrap(),
            "sharding must beat the unsharded estimate"
        );
        // one-row batch: rows cannot split, the tree axis takes over
        let one = p.plan_for(BackendKind::Recursive, 1).unwrap();
        assert_eq!(one.axis, ShardAxis::Trees);
        assert!(one.shards > 1, "tree axis should engage spare devices");
        // single-device planning is unchanged by the new fields
        let single = synthetic_planner().plan_for(BackendKind::Recursive, 100_000).unwrap();
        assert_eq!((single.shards, single.axis), (1, ShardAxis::Rows));
        assert!(
            (single.est_latency_s - p.batch_cost(BackendKind::Recursive, 100_000).unwrap())
                .abs()
                < 1e-12
        );
    }

    #[test]
    fn grid_plans_engage_when_both_axes_saturate() {
        // the ISSUE scenario: 8 devices over a 4-tree model. The tree
        // axis caps at 4 shards; a 4-row batch starves the row axis at
        // 4 effective shards; a 2×4 grid reaches 8-way parallelism.
        let mut shape = synthetic_planner().shape;
        shape.trees = 4;
        let p = Planner::with_candidates(
            shape,
            vec![(
                BackendKind::Recursive,
                CostEstimate { setup_s: 0.0, batch_overhead_s: 0.0, rows_per_s: 1e4 },
            )],
        )
        .with_devices(8);
        let mid = p.plan_for(BackendKind::Recursive, 4).unwrap();
        assert_eq!(mid.axis, ShardAxis::Grid, "{mid:?}");
        let g = mid.grid.expect("grid plans carry their shape");
        assert!(g.row_shards > 1 && g.tree_shards > 1, "{g:?}");
        assert_eq!(g.total(), mid.shards);
        assert!(g.total() <= 8);
        assert!(g.tree_shards <= 4, "tree side clamps to the ensemble");
        // the grid beats both simple axes at this batch size
        let rows4 = p.plan_pinned(BackendKind::Recursive, 4, ShardAxis::Rows, 8).unwrap();
        let trees4 = p.plan_pinned(BackendKind::Recursive, 4, ShardAxis::Trees, 8).unwrap();
        assert!(mid.est_latency_s < rows4.est_latency_s);
        assert!(mid.est_latency_s < trees4.est_latency_s);
        // outside the regime the simple axes keep winning: huge batches
        // fill the row axis, 1-row batches leave rows nothing to split
        let big = p.plan_for(BackendKind::Recursive, 100_000).unwrap();
        assert_eq!((big.axis, big.grid), (ShardAxis::Rows, None));
        let one = p.plan_for(BackendKind::Recursive, 1).unwrap();
        assert_eq!((one.axis, one.grid), (ShardAxis::Trees, None));
    }

    #[test]
    fn pinned_grid_picks_a_factorization_or_degrades() {
        let mut shape = synthetic_planner().shape;
        shape.trees = 4;
        let p = Planner::with_candidates(
            shape,
            vec![(
                BackendKind::Recursive,
                CostEstimate { setup_s: 0.0, batch_overhead_s: 0.0, rows_per_s: 1e4 },
            )],
        )
        .with_devices(8);
        let pinned = p.plan_pinned(BackendKind::Recursive, 64, ShardAxis::Grid, 8).unwrap();
        let g = pinned.grid.expect("a 2-D factorization of 8 exists");
        assert_eq!(pinned.axis, ShardAxis::Grid);
        assert!(g.row_shards > 1 && g.tree_shards > 1);
        assert!(g.total() <= 8);
        // two devices admit no 2-D grid: degrade to a simple layout
        let p2 = Planner::with_candidates(
            p.shape,
            vec![(
                BackendKind::Recursive,
                CostEstimate { setup_s: 0.0, batch_overhead_s: 0.0, rows_per_s: 1e4 },
            )],
        )
        .with_devices(2);
        let degraded = p2.plan_pinned(BackendKind::Recursive, 64, ShardAxis::Grid, 2).unwrap();
        assert!(degraded.grid.is_none());
        assert_ne!(degraded.axis, ShardAxis::Grid);
    }

    #[test]
    fn row_axis_overhead_is_priced_per_dispatched_chunk() {
        // regression: `run_rows` dispatches CHUNKS_PER_SHARD chunks per
        // shard, each paying the backend's batch overhead — pricing one
        // overhead per shard underpriced row sharding 4× on
        // overhead-heavy backends and skewed the layout decision
        let shape = ModelShape {
            features: 8,
            groups: 1,
            trees: 1, // no tree axis to hide behind
            leaves: 100,
            max_depth: 6,
            avg_path_len: 5.0,
            max_path_len: 7,
        };
        let heavy = CostEstimate { setup_s: 0.0, batch_overhead_s: 1.0, rows_per_s: 1e3 };
        let p = Planner::with_candidates(shape, vec![(BackendKind::XlaWarp, heavy)])
            .with_devices(4);
        // 1000 rows: unsharded = 1.0 + 1.0 = 2.0s. Four row shards save
        // 0.75s of per-row time but pay 4 serial chunk dispatches
        // (4×1.0s overhead) — sharding must NOT win here
        let plan = p.plan_for(BackendKind::XlaWarp, 1000).unwrap();
        assert_eq!(plan.shards, 1, "{plan:?}");
        let pinned = p.plan_pinned(BackendKind::XlaWarp, 1000, ShardAxis::Rows, 4).unwrap();
        assert!(
            (pinned.est_latency_s - (4.0 + 250.0 / 1e3)).abs() < 1e-9,
            "4 chunk dispatches × 1s overhead + 250 rows/shard: {}",
            pinned.est_latency_s
        );
        // a low-overhead backend still shards by rows
        let light = CostEstimate { setup_s: 0.0, batch_overhead_s: 1e-6, rows_per_s: 1e3 };
        let p = Planner::with_candidates(p.shape, vec![(BackendKind::Host, light)])
            .with_devices(4);
        let plan = p.plan_for(BackendKind::Host, 1000).unwrap();
        assert_eq!((plan.shards, plan.axis), (4, ShardAxis::Rows), "{plan:?}");
        // shards that see fewer rows than CHUNKS_PER_SHARD dispatch one
        // chunk per row, not four
        let few = p.plan_pinned(BackendKind::Host, 8, ShardAxis::Rows, 4).unwrap();
        assert!(
            (few.est_latency_s - (2.0 * 1e-6 + 2.0 / 1e3)).abs() < 1e-12,
            "2 rows/shard ⇒ 2 dispatches: {}",
            few.est_latency_s
        );
    }

    #[test]
    fn pinned_tiles_clamp_and_stay_opt_in() {
        let p = synthetic_planner().with_devices(4);
        let pinned =
            p.plan_pinned(BackendKind::Recursive, 64, ShardAxis::FeatureTiles, 4).unwrap();
        assert_eq!(pinned.axis, ShardAxis::FeatureTiles);
        assert_eq!(pinned.shards, 4);
        assert!(pinned.grid.is_none());
        assert!(pinned.est_latency_s.is_finite());
        // splitting the conditioned loop must price below unsharded
        assert!(
            pinned.est_latency_s < p.batch_cost(BackendKind::Recursive, 64).unwrap(),
            "{pinned:?}"
        );
        // tile count clamps to the feature count (shape has 8 features)
        let over =
            p.plan_pinned(BackendKind::Recursive, 64, ShardAxis::FeatureTiles, 100).unwrap();
        assert_eq!(over.shards, 8);
        // the axis is opt-in: the auto sweep never lands on it
        let auto = p.plan_for(BackendKind::Recursive, 64).unwrap();
        assert_ne!(auto.axis, ShardAxis::FeatureTiles);
        // and the build-anyway fallback keeps the pinned tiles axis
        let fb = Plan::fallback(BackendKind::Recursive, 4, Some(ShardAxis::FeatureTiles));
        assert_eq!((fb.axis, fb.shards), (ShardAxis::FeatureTiles, 4));
    }

    #[test]
    fn tree_axis_shards_clamp_to_tree_count() {
        let mut shape = synthetic_planner().shape;
        shape.trees = 2;
        let p = Planner::with_candidates(
            shape,
            vec![(
                BackendKind::Recursive,
                CostEstimate { setup_s: 0.0, batch_overhead_s: 0.0, rows_per_s: 1e4 },
            )],
        )
        .with_devices(8);
        let one = p.plan_for(BackendKind::Recursive, 1).unwrap();
        assert_eq!(one.axis, ShardAxis::Trees);
        assert_eq!(one.shards, 2, "cannot split 2 trees over more than 2 shards");
    }

    #[test]
    fn recalibrate_blends_measurement_over_prior() {
        let mut p = synthetic_planner();
        let prior = p.cost(BackendKind::XlaWarp).unwrap();
        assert_eq!(p.calibration_samples(BackendKind::XlaWarp), 0);
        // measured: the accelerator's overhead is 100× smaller than the
        // prior believed (0.0005s vs 0.05s) at the same throughput
        let mut obs = Observations::new();
        for _ in 0..8 {
            for rows in [1usize, 16, 256, 1024] {
                obs.record_backend("xla", rows, 5e-4 + rows as f64 / 1e6);
            }
        }
        assert!(p.recalibrate(&obs), "estimates must move");
        let cal = p.cost(BackendKind::XlaWarp).unwrap();
        assert!(cal.batch_overhead_s < prior.batch_overhead_s / 10.0, "{cal:?}");
        assert_eq!(p.prior(BackendKind::XlaWarp).unwrap().batch_overhead_s, 0.05);
        assert_eq!(p.calibration_samples(BackendKind::XlaWarp), 32);
        // the crossover moves accordingly: with ~0.5ms overhead it takes
        // far fewer rows for the accelerator to win
        let cross = p.crossover_rows(BackendKind::Recursive, BackendKind::XlaWarp).unwrap();
        assert!(cross < 50, "calibrated crossover {cross}");
        // feeding the same observations again is a no-op (prior-anchored)
        assert!(!p.recalibrate(&obs), "idempotent for identical observations");
        // cpu backend untouched: no samples for it
        let cpu = p.cost(BackendKind::Recursive).unwrap();
        assert_eq!(cpu.rows_per_s, 1e4);
    }

    #[test]
    fn seeded_calibration_survives_recalibration() {
        let mut p = synthetic_planner();
        let persisted = CostEstimate { setup_s: 0.1, batch_overhead_s: 5e-4, rows_per_s: 1e6 };
        let applied = p.seed_calibration(&[
            ("xla".to_string(), persisted, 40),
            ("bogus".to_string(), persisted, 9),
        ]);
        assert_eq!(applied, 1, "unknown names are skipped");
        assert_eq!(p.calibration_samples(BackendKind::XlaWarp), 40);
        let cost = p.cost(BackendKind::XlaWarp).unwrap();
        assert_eq!(cost.rows_per_s, 1e6);
        assert_eq!(cost.setup_s, 0.1);
        // a thin fresh window must blend against the seeded anchor, not
        // the shipped constants: the estimate stays on the measured line
        // instead of snapping back toward the 0.05s-overhead prior
        let mut obs = Observations::new();
        for rows in [1usize, 16, 256, 1024] {
            obs.record_backend("xla", rows, 5e-4 + rows as f64 / 1e6);
        }
        p.recalibrate(&obs);
        let cal = p.cost(BackendKind::XlaWarp).unwrap();
        assert!((cal.rows_per_s - 1e6).abs() < 0.2e6, "{}", cal.rows_per_s);
        assert!(cal.batch_overhead_s < 1e-3, "{}", cal.batch_overhead_s);
    }

    #[test]
    fn expected_batches_amortizes_setup_into_plans() {
        // an accelerator with 0.5s setup cannot win a 2-batch horizon,
        // but dominates once prep amortizes over many batches
        let p_short = synthetic_planner().with_expected_batches(2);
        let p_long = synthetic_planner().with_expected_batches(100_000);
        let rows = 1000; // above the steady-state crossover (~506)
        assert_eq!(p_short.choose(rows).kind, BackendKind::Recursive);
        assert_eq!(p_long.choose(rows).kind, BackendKind::XlaWarp);
        // the default horizon prices prep at zero (pure steady state)
        assert_eq!(synthetic_planner().choose(rows).kind, BackendKind::XlaWarp);
    }

    #[test]
    fn fastv2_guardrail_excludes_deep_models() {
        // depth-14 ensemble: 16384 leaves × 2^14 subsets × 8 B ≈ 2 GiB
        // of tables — far over the default 512 MiB budget, so the
        // planner must refuse FastV2 on every planning surface
        let deep = ModelShape {
            features: 20,
            groups: 1,
            trees: 64,
            leaves: 16384,
            max_depth: 14,
            avg_path_len: 12.0,
            max_path_len: 15,
        };
        assert!(fastv2_table_bytes(&deep) > 512.0 * 1024.0 * 1024.0);
        let p = Planner::from_shape(deep);
        assert!(!p.fastv2_fits());
        assert!(p.plan_for(BackendKind::FastV2, 1 << 20).is_none());
        assert!(p.plan_pinned(BackendKind::FastV2, 1 << 20, ShardAxis::Rows, 4).is_none());
        assert!(p.ranked(1 << 20).iter().all(|pl| pl.kind != BackendKind::FastV2));
        // a raised budget re-admits the kind (the refusal is the budget,
        // not the shape)
        let roomy = Planner::from_shape(deep).with_fastv2_budget_mb(4096);
        assert!(roomy.fastv2_fits());
        assert!(roomy.plan_for(BackendKind::FastV2, 1 << 20).is_some());
        // other kinds are untouched by the guardrail
        assert!(p.plan_for(BackendKind::Host, 1 << 20).is_some());
    }

    #[test]
    fn fastv2_wins_shallow_high_volume_within_budget() {
        // shallow ensemble: 320 leaves × 2^5 × 8 B ≈ 80 KiB of tables —
        // comfortably within budget. With prep amortized over a long
        // horizon the depth-factor win makes FastV2 the CPU pick; with a
        // 1-batch horizon the table build must be paid in full and a
        // no-setup backend wins
        let shallow = ModelShape {
            features: 8,
            groups: 1,
            trees: 40,
            leaves: 320,
            max_depth: 5,
            avg_path_len: 5.0,
            max_path_len: 6,
        };
        let p = Planner::from_shape(shallow).with_expected_batches(100_000);
        assert!(p.fastv2_fits());
        let plan = p.plan_for(BackendKind::FastV2, 4096).expect("within budget");
        assert!(plan.est_latency_s.is_finite());
        let cpu_kinds = [BackendKind::Recursive, BackendKind::Host, BackendKind::Linear];
        for other in cpu_kinds {
            assert!(
                plan.est_latency_s < p.plan_for(other, 4096).unwrap().est_latency_s,
                "fastv2 must beat {other:?} at 4096 rows on a shallow model"
            );
        }
        assert_eq!(p.choose(4096).kind, BackendKind::FastV2);
        // one-shot horizon on a *deeper* (still in-budget) shape: ~134 MB
        // of tables (≈67 ms of build at the prior's constant) must be
        // paid in full against a ~16 µs single-row recursive pass, so a
        // no-setup backend wins. (On the shallow shape above the table is
        // ~80 KiB and fastv2 wins even one-shot — the guard horizon only
        // bites once 2^D work dominates.)
        let deeper = ModelShape {
            features: 16,
            groups: 1,
            trees: 32,
            leaves: 4096,
            max_depth: 12,
            avg_path_len: 10.0,
            max_path_len: 13,
        };
        let once = Planner::from_shape(deeper).with_expected_batches(1);
        assert!(once.fastv2_fits(), "134 MB of tables fit the 512 MiB default budget");
        assert_ne!(once.choose(1).kind, BackendKind::FastV2);
        // …and the same shape flips back to fastv2 once the horizon
        // amortizes the build away
        let served = Planner::from_shape(deeper).with_expected_batches(1_000_000);
        assert_eq!(served.choose(4096).kind, BackendKind::FastV2);
    }

    #[test]
    fn for_model_prefers_cheap_backends_on_tiny_batches() {
        let d = SynthSpec::cal_housing(0.004).generate();
        let model = train(&d, &TrainParams { rounds: 2, max_depth: 3, ..Default::default() });
        let p = Planner::for_model(&model);
        assert!(p.shape.leaves > 0 && p.shape.avg_path_len >= 1.0);
        let one = p.choose(1);
        assert!(
            matches!(
                one.kind,
                BackendKind::Recursive | BackendKind::Host | BackendKind::FastV2
            ),
            "1-row batch should stay on a CPU backend, got {:?}",
            one.kind
        );
        // cost is monotone in rows for every candidate
        for k in [BackendKind::Recursive, BackendKind::Host] {
            let c1 = p.batch_cost(k, 1).unwrap();
            let c2 = p.batch_cost(k, 1000).unwrap();
            assert!(c2 > c1);
        }
    }
}
