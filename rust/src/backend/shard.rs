//! Shard-plan vocabulary: how a SHAP workload is split across devices.
//!
//! Two simple axes, both exact (φ and Φ are additive over trees, and
//! rows are independent), plus their 2-D composition:
//!
//! - [`ShardAxis::Rows`] — split the batch, run every shard over the
//!   full ensemble, concatenate outputs. The paper's Fig 5 scheme;
//!   throughput-optimal when `rows ≫ devices`.
//! - [`ShardAxis::Trees`] — split the packed ensemble, run every shard
//!   over the full batch, sum the per-shard φ/Φ with a base-value
//!   correction (each shard's output carries `base_score` once, so the
//!   sum over-counts it `shards − 1` times). Helps wide-ensemble /
//!   small-batch workloads where there are no rows left to split.
//! - [`ShardAxis::Grid`] — a [`ShardGrid`] of `tree_shards` ensemble
//!   slices, each replicated over `row_shards` row workers. Engages the
//!   topologies neither simple axis can fill: with 8 devices over a
//!   4-tree model the tree axis caps at 4 and a 4-row batch starves the
//!   row axis, but a 2×4 grid uses all 8.
//! - [`ShardAxis::FeatureTiles`] — interactions only: partition the
//!   conditioned-feature set `{0..M}` into contiguous tiles, one per
//!   device. Each shard runs the full model over the full batch but
//!   performs the two conditioned passes only for its tile's features,
//!   producing a column-block of the `(M+1)²` matrix; the coordinator
//!   assembles blocks and fills diagonals/base from one unconditioned φ
//!   pass. The only axis whose per-device work shrinks with `M`, so the
//!   wide-model (`M ≫ D`) Φ regime scales past the padded engine's
//!   feature cap. Executed by [`super::tiles::TilesBackend`], never by
//!   `ShardedBackend`.
//!
//! This module holds the pure planning math — axis parsing, row
//! chunking, leaf-balanced tree splitting, grid factorizations, and the
//! base correction — with no threads or devices;
//! [`super::sharded::ShardedBackend`] (simple axes) and
//! [`super::grid::GridBackend`] (grids) are the executors built on top.

use crate::gbdt::Model;

/// How many row chunks per shard the rows-axis queues are cut into:
/// finer chunks mean prompter abort on failure and better balance when
/// devices run at different speeds, at a small per-chunk dispatch cost.
/// Lives here (not in the executor) because the planner prices the
/// per-chunk dispatch overhead with the same constant.
pub const CHUNKS_PER_SHARD: usize = 4;

/// The axis a sharded backend splits work along.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ShardAxis {
    /// split the batch across devices (Fig 5)
    Rows,
    /// split the ensemble across devices (additivity over trees)
    Trees,
    /// both: tree slices × row replicas (see [`ShardGrid`]); executed by
    /// [`super::grid::GridBackend`], never by `ShardedBackend`
    Grid,
    /// split the conditioned-feature set across devices (interactions
    /// only); executed by [`super::tiles::TilesBackend`], never by
    /// `ShardedBackend`
    FeatureTiles,
}

impl ShardAxis {
    /// The simple (1-D) axes — the iteration set for executors and
    /// benches that sweep `ShardedBackend` layouts. `Grid` is not here:
    /// it is a composition with its own executor and its own `(r, t)`
    /// shape, enumerated via [`ShardGrid::factorizations`].
    pub const ALL: [ShardAxis; 2] = [ShardAxis::Rows, ShardAxis::Trees];

    pub fn name(&self) -> &'static str {
        match self {
            ShardAxis::Rows => "rows",
            ShardAxis::Trees => "trees",
            ShardAxis::Grid => "grid",
            ShardAxis::FeatureTiles => "tiles",
        }
    }

    /// The alias table behind [`ShardAxis::parse`]/[`ShardAxis::name_list`]
    /// (same idiom as `BackendKind::NAMES`): first alias of each row is
    /// the canonical [`ShardAxis::name`]. Includes `grid` and `tiles`
    /// (parseable and executable) even though [`ShardAxis::ALL`]
    /// deliberately excludes them from 1-D sweeps.
    const NAMES: &'static [crate::util::NameRow<ShardAxis>] = &[
        (ShardAxis::Rows, &["rows", "row"]),
        (ShardAxis::Trees, &["trees", "tree"]),
        (ShardAxis::Grid, &["grid"]),
        (ShardAxis::FeatureTiles, &["tiles", "tile"]),
    ];

    /// Parse an axis name (case-insensitive). `None` for unknown names —
    /// callers list the valid set via [`ShardAxis::name_list`].
    pub fn parse(s: &str) -> Option<ShardAxis> {
        crate::util::parse_named(Self::NAMES, s)
    }

    /// Every parseable axis name, `|`-joined for CLI error messages —
    /// the counterpart of `BackendKind::name_list`.
    pub fn name_list() -> String {
        crate::util::name_list(Self::NAMES)
    }
}

/// A rows × trees device grid: `tree_shards` disjoint ensemble slices,
/// each served by `row_shards` replicas that split the batch among
/// themselves. `1×t` and `r×1` grids are the simple axes; the planner
/// only labels a layout `Grid` when both sides exceed 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ShardGrid {
    /// row replicas per tree slice (the inner, batch-splitting side)
    pub row_shards: usize,
    /// ensemble slices (the outer, additive side)
    pub tree_shards: usize,
}

impl ShardGrid {
    pub fn new(row_shards: usize, tree_shards: usize) -> ShardGrid {
        ShardGrid { row_shards: row_shards.max(1), tree_shards: tree_shards.max(1) }
    }

    /// Total device cells in the grid.
    pub fn total(&self) -> usize {
        self.row_shards * self.tree_shards
    }

    /// A grid with one side of length 1 is really a simple axis.
    pub fn is_trivial(&self) -> bool {
        self.row_shards == 1 || self.tree_shards == 1
    }

    /// Every `(row_shards, tree_shards)` factorization of exactly
    /// `total` cells whose tree side fits the ensemble (`t ≤ trees`),
    /// trivial shapes included, ordered by ascending tree side. The
    /// planner scores these next to the simple axes when a device
    /// topology is in play.
    pub fn factorizations(total: usize, trees: usize) -> Vec<ShardGrid> {
        let total = total.max(1);
        let trees = trees.max(1);
        let mut out = Vec::new();
        for t in 1..=total.min(trees) {
            if total % t == 0 {
                out.push(ShardGrid { row_shards: total / t, tree_shards: t });
            }
        }
        out
    }
}

impl std::fmt::Display for ShardGrid {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}r×{}t", self.row_shards, self.tree_shards)
    }
}

/// Contiguous `(start, len)` row chunks, near-equal sized, empties
/// dropped — at most `chunks` of them, fewer when `rows < chunks`.
pub fn row_chunks(rows: usize, chunks: usize) -> Vec<(usize, usize)> {
    let chunks = chunks.max(1).min(rows.max(1));
    let mut out = Vec::with_capacity(chunks);
    let mut start = 0usize;
    for c in 0..chunks {
        let end = rows * (c + 1) / chunks;
        if end > start {
            out.push((start, end - start));
            start = end;
        }
    }
    out
}

/// Heterogeneous row split: shard `s` is assigned a contiguous span of
/// rows proportional to `weights[s]` (a throughput estimate, any scale),
/// cut into up to `chunks_per_shard` near-equal chunks. Returns one
/// chunk list per shard; spans may be empty for shards whose weight
/// rounds to zero rows (work stealing keeps them busy anyway).
/// Non-finite or non-positive weights are treated as equal shares, so a
/// cold start (no throughput estimates yet) degrades to the even split.
pub fn weighted_chunks(
    rows: usize,
    weights: &[f64],
    chunks_per_shard: usize,
) -> Vec<Vec<(usize, usize)>> {
    let n = weights.len().max(1);
    let sane: Vec<f64> = weights
        .iter()
        .map(|&w| if w.is_finite() && w > 0.0 { w } else { 0.0 })
        .collect();
    let total: f64 = sane.iter().sum();
    let sane: Vec<f64> = if total > 0.0 { sane } else { vec![1.0; n] };
    let total: f64 = sane.iter().sum();

    // proportional boundaries, cumulative-rounded so spans tile exactly
    let mut out = Vec::with_capacity(n);
    let mut cum = 0.0f64;
    let mut start = 0usize;
    for (s, w) in sane.iter().enumerate() {
        cum += w;
        let end = if s + 1 == n {
            rows // last boundary pins to the row count exactly
        } else {
            ((rows as f64 * cum / total).round() as usize).clamp(start, rows)
        };
        let chunks: Vec<(usize, usize)> = row_chunks(end - start, chunks_per_shard)
            .into_iter()
            .map(|(r0, rc)| (start + r0, rc))
            .collect();
        out.push(chunks);
        start = end;
    }
    out
}

/// Split `model` into `shards` contiguous sub-ensembles, balanced by
/// leaf count (per-row SHAP cost is proportional to leaves, not trees).
/// Every shard gets at least one tree; `shards` is clamped to the tree
/// count. Concatenating the shards' tree lists reproduces the model.
pub fn split_trees(model: &Model, shards: usize) -> Vec<Model> {
    let n = model.trees.len();
    let shards = shards.clamp(1, n.max(1));
    let leaves: Vec<usize> = model.trees.iter().map(|t| t.num_leaves()).collect();
    let total: usize = leaves.iter().sum();

    // boundary b_s = first tree of shard s; advance each boundary until
    // the cumulative leaf count reaches its proportional target, while
    // keeping ≥1 tree on both sides of every cut
    let mut bounds = Vec::with_capacity(shards + 1);
    bounds.push(0usize);
    let mut idx = 0usize;
    let mut cum = 0usize;
    for s in 1..shards {
        let target = total * s / shards;
        let min_idx = bounds[s - 1] + 1;
        let max_idx = n - (shards - s);
        while idx < max_idx && (cum < target || idx < min_idx) {
            cum += leaves[idx];
            idx += 1;
        }
        bounds.push(idx);
    }
    bounds.push(n);

    bounds
        .windows(2)
        .map(|w| Model {
            trees: model.trees[w[0]..w[1]].to_vec(),
            tree_group: model.tree_group[w[0]..w[1]].to_vec(),
            num_groups: model.num_groups,
            num_features: model.num_features,
            base_score: model.base_score,
            objective: model.objective,
        })
        .collect()
}

/// Split the conditioned-feature set `{0..weights.len()}` into at most
/// `tiles` contiguous `(lo, hi)` half-open ranges, balanced by the
/// per-feature weights (for Φ tiling: `weights[f]` = number of trees
/// that test feature `f`, so a tile's weight tracks the conditioned
/// passes it actually runs after tree skipping). Every returned tile is
/// non-empty, ranges are contiguous and tile `0..m` exactly; `tiles` is
/// clamped to the feature count. Zero-weight features (tested by no
/// tree) still get a slot — their conditioned passes are near-free but
/// their matrix columns must exist.
pub fn split_feature_tiles(weights: &[u32], tiles: usize) -> Vec<(usize, usize)> {
    let m = weights.len();
    if m == 0 {
        return vec![(0, 0)];
    }
    let tiles = tiles.clamp(1, m);
    let total: u64 = weights.iter().map(|&w| w as u64).sum();

    // boundary b_s = first feature of tile s; advance each boundary
    // until the cumulative weight reaches its proportional target,
    // keeping ≥1 feature on both sides of every cut (mirrors
    // `split_trees`, which balances by leaves the same way)
    let mut bounds = Vec::with_capacity(tiles + 1);
    bounds.push(0usize);
    let mut idx = 0usize;
    let mut cum = 0u64;
    for s in 1..tiles {
        let target = total * s as u64 / tiles as u64;
        let min_idx = bounds[s - 1] + 1;
        let max_idx = m - (tiles - s);
        while idx < max_idx && (cum < target || idx < min_idx) {
            cum += weights[idx] as u64;
            idx += 1;
        }
        bounds.push(idx);
    }
    bounds.push(m);

    bounds.windows(2).map(|w| (w[0], w[1])).collect()
}

/// The summed tree-shard outputs carry `base_score` once per shard;
/// subtract the surplus `(shards − 1) · base_score` at the base-value
/// positions of the given task layout (slot `M` for contributions,
/// `[M, M]` for interactions, every group entry for predictions).
pub fn correct_base(
    out: &mut [f32],
    task: ShardTask,
    shards: usize,
    base_score: f32,
    rows: usize,
    groups: usize,
    features: usize,
) {
    if shards <= 1 || base_score == 0.0 {
        return;
    }
    let surplus = (shards - 1) as f32 * base_score;
    let m = features;
    match task {
        ShardTask::Contributions => {
            let stride = groups * (m + 1);
            for r in 0..rows {
                for g in 0..groups {
                    out[r * stride + g * (m + 1) + m] -= surplus;
                }
            }
        }
        ShardTask::Interactions => {
            let ms = (m + 1) * (m + 1);
            let stride = groups * ms;
            for r in 0..rows {
                for g in 0..groups {
                    out[r * stride + g * ms + m * (m + 1) + m] -= surplus;
                }
            }
        }
        ShardTask::Predictions => {
            for v in out.iter_mut().take(rows * groups) {
                *v -= surplus;
            }
        }
    }
}

/// Which output layout a sharded execution produces (drives the
/// per-task base correction and output stride).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardTask {
    Contributions,
    Interactions,
    Predictions,
}

impl ShardTask {
    /// Output floats per row for this task.
    pub fn stride(&self, groups: usize, features: usize) -> usize {
        match self {
            ShardTask::Contributions => groups * (features + 1),
            ShardTask::Interactions => groups * (features + 1) * (features + 1),
            ShardTask::Predictions => groups,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;
    use crate::gbdt::{train, TrainParams};

    #[test]
    fn axis_parse_roundtrip() {
        for a in ShardAxis::ALL {
            assert_eq!(ShardAxis::parse(a.name()), Some(a));
        }
        assert_eq!(ShardAxis::parse("tree"), Some(ShardAxis::Trees));
        assert_eq!(ShardAxis::parse("grid"), Some(ShardAxis::Grid));
        assert_eq!(ShardAxis::parse(ShardAxis::Grid.name()), Some(ShardAxis::Grid));
        assert_eq!(ShardAxis::parse("nope"), None);
        assert_eq!(ShardAxis::parse("tiles"), Some(ShardAxis::FeatureTiles));
        assert_eq!(ShardAxis::parse("tile"), Some(ShardAxis::FeatureTiles));
        assert_eq!(ShardAxis::FeatureTiles.name(), "tiles");
        assert!(ShardAxis::name_list().contains("tiles"));
        // Grid and FeatureTiles are deliberately not in the 1-D sweep
        // set: each has its own executor and its own plan shape
        assert!(!ShardAxis::ALL.contains(&ShardAxis::Grid));
        assert!(!ShardAxis::ALL.contains(&ShardAxis::FeatureTiles));
    }

    #[test]
    fn feature_tiles_cover_and_balance() {
        // uniform weights → near-equal widths, exact coverage
        for (m, tiles) in [(8usize, 3usize), (96, 4), (7, 7), (5, 1), (3, 10)] {
            let w = vec![1u32; m];
            let ts = split_feature_tiles(&w, tiles);
            assert_eq!(ts.len(), tiles.min(m));
            let mut next = 0usize;
            for &(lo, hi) in &ts {
                assert_eq!(lo, next, "contiguous");
                assert!(hi > lo, "non-empty");
                next = hi;
            }
            assert_eq!(next, m, "tiles the whole feature set");
        }
        // skewed weights: the heavy feature's tile stays narrow, so the
        // summed weight per tile is balanced rather than the width
        let mut w = vec![1u32; 12];
        w[0] = 30; // feature 0 appears in 30 trees, the rest in 1
        let ts = split_feature_tiles(&w, 3);
        assert_eq!(ts.len(), 3);
        assert_eq!(ts[0], (0, 1), "heavy feature isolated in its own tile");
        // zero-weight features still receive slots
        let ts = split_feature_tiles(&[0, 0, 0, 0], 2);
        assert_eq!(ts.iter().map(|t| t.1 - t.0).sum::<usize>(), 4);
        // degenerate: no features
        assert_eq!(split_feature_tiles(&[], 4), vec![(0, 0)]);
    }

    #[test]
    fn grid_factorizations_cover_and_clamp() {
        // 8 cells over ≥8 trees: 1×8, 2×4, 4×2, 8×1
        let grids = ShardGrid::factorizations(8, 10);
        assert_eq!(grids.len(), 4);
        for g in &grids {
            assert_eq!(g.total(), 8);
        }
        assert!(grids.contains(&ShardGrid::new(2, 4)));
        assert!(grids.contains(&ShardGrid::new(8, 1)));
        // the tree side clamps to the ensemble: 8 cells over 4 trees
        // loses the 1×8 shape but keeps the 2×4 the ISSUE example wants
        let clamped = ShardGrid::factorizations(8, 4);
        assert!(clamped.iter().all(|g| g.tree_shards <= 4));
        assert!(clamped.contains(&ShardGrid::new(2, 4)));
        assert!(!clamped.contains(&ShardGrid::new(1, 8)));
        // primes only factor trivially
        let prime = ShardGrid::factorizations(7, 16);
        assert!(prime.iter().all(|g| g.is_trivial()));
        // degenerate inputs
        assert_eq!(ShardGrid::factorizations(1, 1), vec![ShardGrid::new(1, 1)]);
        assert!(ShardGrid::new(1, 1).is_trivial());
        assert!(!ShardGrid::new(2, 2).is_trivial());
        assert_eq!(ShardGrid::new(2, 4).to_string(), "2r×4t");
    }

    #[test]
    fn row_chunks_cover_exactly() {
        for (rows, chunks) in [(10, 3), (1, 4), (7, 7), (100, 1), (5, 8)] {
            let cs = row_chunks(rows, chunks);
            assert!(cs.len() <= chunks.min(rows));
            let mut next = 0usize;
            for (start, len) in &cs {
                assert_eq!(*start, next, "contiguous");
                assert!(*len > 0);
                next = start + len;
            }
            assert_eq!(next, rows, "covers all rows");
        }
    }

    #[test]
    fn weighted_chunks_tile_rows_and_respect_weights() {
        // equal weights reproduce the even split
        let even = weighted_chunks(96, &[1.0, 1.0, 1.0], 4);
        assert_eq!(even.len(), 3);
        for shard in &even {
            let span: usize = shard.iter().map(|c| c.1).sum();
            assert_eq!(span, 32);
        }
        // skewed weights: fast shard's span ≈ its proportional share,
        // and the whole batch is tiled contiguously exactly once
        for weights in [vec![3.0, 1.0], vec![10.0, 1.0, 1.0], vec![0.5, 0.25, 0.25]] {
            let chunks = weighted_chunks(100, &weights, 4);
            assert_eq!(chunks.len(), weights.len());
            let mut next = 0usize;
            let total: f64 = weights.iter().sum();
            for (s, shard) in chunks.iter().enumerate() {
                let span: usize = shard.iter().map(|c| c.1).sum();
                for &(r0, rc) in shard {
                    assert_eq!(r0, next, "contiguous tiling");
                    assert!(rc > 0);
                    next = r0 + rc;
                }
                let share = 100.0 * weights[s] / total;
                assert!(
                    (span as f64 - share).abs() <= 1.0,
                    "shard {s}: span {span} vs share {share}"
                );
            }
            assert_eq!(next, 100, "covers all rows");
        }
        // extreme skew: the slow shard may receive nothing
        let skew = weighted_chunks(10, &[1e6, 1.0], 4);
        let slow_span: usize = skew[1].iter().map(|c| c.1).sum();
        assert_eq!(slow_span, 0);
        let fast_span: usize = skew[0].iter().map(|c| c.1).sum();
        assert_eq!(fast_span, 10);
        // degenerate weights (zero / NaN / negative) → even split
        let fallback = weighted_chunks(8, &[0.0, f64::NAN, -3.0, 0.0], 2);
        for shard in &fallback {
            let span: usize = shard.iter().map(|c| c.1).sum();
            assert_eq!(span, 2);
        }
    }

    #[test]
    fn split_trees_partitions_and_balances() {
        let d = SynthSpec::cal_housing(0.01).generate();
        let model =
            train(&d, &TrainParams { rounds: 9, max_depth: 4, ..Default::default() });
        for shards in [1usize, 2, 3, 4, 9, 20] {
            let subs = split_trees(&model, shards);
            assert_eq!(subs.len(), shards.min(model.trees.len()));
            let total: usize = subs.iter().map(|s| s.trees.len()).sum();
            assert_eq!(total, model.trees.len(), "every tree assigned once");
            for sub in &subs {
                assert!(!sub.trees.is_empty());
                assert_eq!(sub.trees.len(), sub.tree_group.len());
                assert_eq!(sub.num_features, model.num_features);
            }
            // leaf balance: no shard holds more than ~2 proportional shares
            if shards <= model.trees.len() {
                let per = (model.total_leaves() / shards).max(1);
                let heaviest_tree =
                    model.trees.iter().map(|t| t.num_leaves()).max().unwrap_or(0);
                for sub in &subs {
                    assert!(
                        sub.total_leaves() <= 2 * per + heaviest_tree,
                        "shard too heavy: {} of {} total",
                        sub.total_leaves(),
                        model.total_leaves()
                    );
                }
            }
        }
    }

    #[test]
    fn base_correction_targets_only_base_slots() {
        let (rows, groups, m, shards) = (2usize, 2usize, 3usize, 3usize);
        let stride = groups * (m + 1);
        let mut phi = vec![1.0f32; rows * stride];
        correct_base(&mut phi, ShardTask::Contributions, shards, 0.5, rows, groups, m);
        for r in 0..rows {
            for g in 0..groups {
                for f in 0..=m {
                    let v = phi[r * stride + g * (m + 1) + f];
                    if f == m {
                        assert!((v - 0.0).abs() < 1e-6, "base slot corrected by (K−1)·b");
                    } else {
                        assert_eq!(v, 1.0, "feature slots untouched");
                    }
                }
            }
        }
        // shards == 1 is the identity
        let mut one = vec![1.0f32; rows * stride];
        correct_base(&mut one, ShardTask::Contributions, 1, 0.5, rows, groups, m);
        assert!(one.iter().all(|&v| v == 1.0));
    }
}
