//! The unified SHAP execution layer: every way of computing φ — the
//! recursive CPU baseline, the host-native packed DP, and the XLA/PJRT
//! engines (warp-packed and padded layouts) — implements [`ShapBackend`]
//! behind one trait, and the [`Planner`] picks among them with the
//! Fig 4 crossover heuristic.
//!
//! The coordinator, CLI, benches and parity tests all dispatch through
//! this trait; no caller outside this module touches `host_kernel` or
//! `ShapEngine` directly. Linear TreeShap's O(tree-size) φ kernel ships
//! as [`BackendKind::Linear`]; further algorithm backends (Fast
//! TreeSHAP's precomputation variants) slot in the same way, as
//! additional [`BackendKind`]s with their own [`BackendCaps`].

pub mod calibrate;
pub mod fast_v2;
pub mod grid;
pub mod host;
pub mod linear;
pub mod planner;
pub mod prepared;
pub mod recursive;
pub mod shard;
pub mod sharded;
pub mod tiles;
#[cfg(feature = "xla")]
pub mod xla;

use std::path::PathBuf;
use std::sync::Arc;

use crate::gbdt::Model;
use crate::shap::Packing;
use crate::util::error::Result;

pub use calibrate::Observations;
pub use fast_v2::FastV2Backend;
pub use grid::GridBackend;
pub use host::HostPackedBackend;
pub use linear::LinearBackend;
pub use planner::{CostEstimate, ModelShape, Plan, Planner};
pub use prepared::{prepare, PrepStats, PreparedModel};
pub use recursive::RecursiveBackend;
pub use shard::{ShardAxis, ShardGrid};
pub use sharded::ShardedBackend;
pub use tiles::TilesBackend;
#[cfg(feature = "xla")]
pub use xla::{XlaPaddedBackend, XlaWarpBackend};

/// Callback invoked after every per-shard execution of a
/// [`ShardedBackend`]: `(shard index, rows executed, wall time)`. The
/// coordinator installs one to surface per-shard rows/p50/p99 in its
/// metrics without the backend layer depending on it.
pub type ShardObserver =
    Arc<dyn Fn(usize, usize, std::time::Duration) + Send + Sync>;

/// What a backend can do, and the cost metadata the planner compares.
#[derive(Clone, Copy, Debug)]
pub struct BackendCaps {
    /// can this instance serve `interactions()`?
    pub supports_interactions: bool,
    /// one-time prepare cost (packing, device upload, compilation), s
    pub setup_cost_s: f64,
    /// fixed overhead paid per executed batch, s
    pub batch_overhead_s: f64,
    /// sustained contributions throughput estimate, rows/s
    pub rows_per_s: f64,
}

/// One prepared SHAP execution engine over one model.
///
/// Output layouts (shared by every implementation):
/// - `contributions`: `[rows × groups × (M+1)]`, base value in slot M.
/// - `interactions`:  `[rows × groups × (M+1)²]`, base value at [M, M].
/// - `predictions`:   `[rows × groups]` raw margin scores.
/// `Send + Sync` is a trait bound because the sharded executor fans one
/// call out across scoped worker threads sharing `&self`.
pub trait ShapBackend: Send + Sync {
    fn name(&self) -> &'static str;
    fn caps(&self) -> BackendCaps;
    fn num_features(&self) -> usize;
    fn num_groups(&self) -> usize;
    fn contributions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>>;
    fn interactions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>>;
    /// One off-diagonal column-block of the interaction matrix, in f64:
    /// for conditioned features `lo..hi`, returns
    /// `[rows × groups × M × (hi−lo)]` cells `Φ[i][j]` for `j ∈ lo..hi`.
    /// Optional — only backends a [`tiles::TilesBackend`] can drive
    /// implement it; the coordinator assembles blocks and fills the
    /// diagonal/base slots from [`ShapBackend::contributions_f64`].
    /// Implementations declare their block layout via the tile executor
    /// (full columns vs owner-symmetric upper triangle), not here.
    fn interactions_block(
        &self,
        _x: &[f32],
        _rows: usize,
        _lo: usize,
        _hi: usize,
    ) -> Result<Vec<f64>> {
        Err(crate::anyhow!(
            "backend '{}' does not serve interaction column-blocks",
            self.name()
        ))
    }
    /// Unconditioned φ in f64, `[rows × groups × M]` (no base slot),
    /// accumulated in the oracle's per-tree order — the diagonal/base
    /// input for tile assembly (Eq. 6 needs full-precision φ to stay
    /// bit-compatible with the unsharded kernel). Optional, like
    /// [`ShapBackend::interactions_block`].
    fn contributions_f64(&self, _x: &[f32], _rows: usize) -> Result<Vec<f64>> {
        Err(crate::anyhow!(
            "backend '{}' does not serve f64 contributions",
            self.name()
        ))
    }
    /// Raw predictions; optional (not every backend carries leaf routing).
    fn predictions(&self, _x: &[f32], _rows: usize) -> Result<Vec<f32>> {
        Err(crate::anyhow!("backend '{}' does not serve predictions", self.name()))
    }
    /// Install a per-shard execution observer; a no-op everywhere except
    /// [`ShardedBackend`], so callers can wire metrics without downcasts.
    fn set_shard_observer(&mut self, _obs: ShardObserver) {}
    /// How many device shards this backend currently spans (1 for
    /// unsharded backends; shrinks under quarantine, grows on hot-add).
    fn shard_count(&self) -> usize {
        1
    }
    /// Shard indices that failed in the most recent execution — empty
    /// for unsharded backends and after a clean run. Drives the
    /// coordinator's quarantine decision without downcasts.
    fn failed_shards(&self) -> Vec<usize> {
        Vec::new()
    }
    /// Remove the given shards from the topology, keeping the backend
    /// serving from the survivors (elastic quarantine). Errs on
    /// unsharded backends and when no shard would survive. Returns how
    /// many shards were removed.
    fn quarantine(&mut self, _failed: &[usize]) -> Result<usize> {
        Err(crate::anyhow!("backend '{}' has no shards to quarantine", self.name()))
    }
    /// Whether the most recent [`ShapBackend::quarantine`] only removed
    /// instances — every survivor is the same device, shifted down in
    /// index — so callers may *remap* per-shard history (metrics,
    /// throughput seeds) instead of dropping it. `false` when the
    /// quarantine rebuilt the topology (tree-axis / grid-slice
    /// re-splits), where retained samples would describe shards that no
    /// longer exist.
    fn quarantine_remaps_survivors(&self) -> bool {
        false
    }
    /// Grow the shard topology back out to `target` shards (hot-add
    /// recovery after quarantine). Errs on unsharded backends; returns
    /// how many shards were added (0 when already at or above `target`).
    fn hot_add(&mut self, _target: usize) -> Result<usize> {
        Err(crate::anyhow!("backend '{}' has no shard topology to grow", self.name()))
    }
    /// Seed per-shard throughput estimates (`(shard, rows/s)` pairs) for
    /// heterogeneous row-chunk sizing; a no-op everywhere except
    /// [`ShardedBackend`]. The coordinator feeds the throughputs its
    /// metrics derive from per-shard batch samples.
    fn set_shard_throughputs(&self, _rows_per_s: &[(usize, f64)]) {}
    /// The prepared-model cache entry this backend executes from, when
    /// it runs over one ([`ShardedBackend`] surfaces its first shard's;
    /// mock/test backends have none). Lets callers inspect prep
    /// build/reuse stats without downcasts.
    fn prepared(&self) -> Option<&Arc<PreparedModel>> {
        None
    }

    /// Human-readable detail (artifact bucket, packing, …) for logs.
    fn describe(&self) -> String {
        self.name().to_string()
    }
}

/// The registered backend kinds. `XlaWarp`/`XlaPadded` parse and plan on
/// every build, but construct only when compiled with `--features xla`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// recursive Algorithm 1 on the raw trees (`shap::treeshap`)
    Recursive,
    /// packed-path DP executed rust-native (`shap::host_kernel`)
    Host,
    /// Linear TreeShap (`shap::linear`): exact φ in O(tree-size) per
    /// row via per-tree polynomial summaries. φ **only** — its
    /// [`BackendCaps::supports_interactions`] is `false`, so
    /// [`build_auto`] skips it for Φ requests and routes them to a
    /// Φ-capable backend; an explicit `--backend linear` interactions
    /// call errs with that guidance.
    Linear,
    /// Fast TreeSHAP v2 (`shap::fast_v2`): exact φ in O(leaves · depth)
    /// per row from precomputed O(leaves · 2^D) subset weight tables.
    /// φ **only**, like [`BackendKind::Linear`]. Construction is gated
    /// by the memory guardrail (`BackendConfig::fastv2_max_mb`): the
    /// planner never plans it over budget and an explicit build errs
    /// instead of OOMing.
    FastV2,
    /// AOT HLO artifacts over the warp-packed layout (PJRT)
    XlaWarp,
    /// AOT HLO artifacts over the padded-path layout (PJRT)
    XlaPadded,
}

impl BackendKind {
    pub const ALL: [BackendKind; 6] = [
        BackendKind::Recursive,
        BackendKind::Host,
        BackendKind::Linear,
        BackendKind::FastV2,
        BackendKind::XlaWarp,
        BackendKind::XlaPadded,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Recursive => "cpu",
            BackendKind::Host => "host",
            BackendKind::Linear => "linear",
            BackendKind::FastV2 => "fastv2",
            BackendKind::XlaWarp => "xla",
            BackendKind::XlaPadded => "xla-padded",
        }
    }

    /// The alias table behind [`BackendKind::parse`]/[`BackendKind::name_list`]:
    /// first alias of each row is the canonical [`BackendKind::name`].
    const NAMES: &'static [crate::util::NameRow<BackendKind>] = &[
        (BackendKind::Recursive, &["cpu", "recursive"]),
        (BackendKind::Host, &["host"]),
        (BackendKind::Linear, &["linear"]),
        (BackendKind::FastV2, &["fastv2", "fast-v2", "fast_v2"]),
        (BackendKind::XlaWarp, &["xla", "warp", "xla-warp"]),
        (BackendKind::XlaPadded, &["xla-padded", "padded"]),
    ];

    /// Parse a backend name (case-insensitive; accepts the aliases the
    /// CLI documents). `None` for unknown names — callers list the
    /// valid set via [`BackendKind::name_list`] in their errors.
    pub fn parse(s: &str) -> Option<BackendKind> {
        crate::util::parse_named(Self::NAMES, s)
    }

    /// The registered backend names, `|`-joined for CLI error messages.
    pub fn name_list() -> String {
        crate::util::name_list(Self::NAMES)
    }

    /// Is this kind present in the current binary?
    pub fn compiled_in(&self) -> bool {
        match self {
            BackendKind::Recursive
            | BackendKind::Host
            | BackendKind::Linear
            | BackendKind::FastV2 => true,
            BackendKind::XlaWarp | BackendKind::XlaPadded => cfg!(feature = "xla"),
        }
    }
}

/// Construction parameters shared by all backends.
#[derive(Clone, Debug)]
pub struct BackendConfig {
    pub threads: usize,
    pub packing: Packing,
    pub artifacts_dir: PathBuf,
    /// expected batch size (artifact bucket selection)
    pub rows_hint: usize,
    /// also prepare the interaction pipeline (device backends prepare
    /// per-kind artifacts; host/recursive always support interactions)
    pub with_interactions: bool,
    /// also prepare the prediction pipeline where applicable
    pub with_predict: bool,
    /// device count: > 1 builds a [`ShardedBackend`] over that many
    /// inner instances of the requested kind
    pub devices: usize,
    /// shard axis override; `None` lets the planner pick per batch size
    pub shard_axis: Option<ShardAxis>,
    /// memory budget for [`BackendKind::FastV2`]'s subset weight tables,
    /// MiB (`--fastv2-max-mb`). The planner excludes `FastV2` from plans
    /// whose shape-estimated table exceeds this, and an explicit build
    /// errs on the exact size instead of OOMing.
    pub fastv2_max_mb: usize,
}

/// Default [`BackendConfig::fastv2_max_mb`]: tables up to 512 MiB.
pub const DEFAULT_FASTV2_MAX_MB: usize = 512;

impl Default for BackendConfig {
    fn default() -> Self {
        BackendConfig {
            threads: crate::parallel::default_threads(),
            packing: Packing::BestFitDecreasing,
            artifacts_dir: crate::runtime::default_artifacts_dir(),
            rows_hint: 256,
            with_interactions: false,
            with_predict: false,
            devices: 1,
            shard_axis: None,
            fastv2_max_mb: DEFAULT_FASTV2_MAX_MB,
        }
    }
}

/// A process-wide device budget shared by every co-resident serving
/// executor: each model registry entry leases its `devices` slots here
/// before building its (sharded) backend, so loading many models cannot
/// oversubscribe the physical topology. Leases release on drop (model
/// unload / alias-retire park), making slots available to the next
/// `load`/`deploy`. An unbounded pool (the default) keeps single-model
/// and test setups zero-config.
#[derive(Debug)]
pub struct DevicePool {
    total: usize,
    used: std::sync::Mutex<usize>,
}

impl DevicePool {
    /// A pool with `total` leasable device slots.
    pub fn new(total: usize) -> Arc<DevicePool> {
        Arc::new(DevicePool { total: total.max(1), used: std::sync::Mutex::new(0) })
    }

    /// No budget: every lease succeeds (single-model / test setups).
    pub fn unbounded() -> Arc<DevicePool> {
        DevicePool::new(usize::MAX)
    }

    pub fn total(&self) -> usize {
        self.total
    }

    /// Slots currently leased out.
    pub fn in_use(&self) -> usize {
        *self.used.lock().unwrap()
    }

    /// Lease `n` device slots, failing fast when the pool cannot cover
    /// them — the admission-control half of multi-model serving.
    pub fn lease(self: &Arc<DevicePool>, n: usize) -> Result<DeviceLease> {
        let n = n.max(1);
        let mut used = self.used.lock().unwrap();
        if used.saturating_add(n) > self.total {
            return Err(crate::anyhow!(
                "device pool exhausted: {} of {} slot(s) in use, {} requested \
                 (unload a model or lower --devices)",
                *used,
                self.total,
                n
            ));
        }
        *used += n;
        Ok(DeviceLease { pool: self.clone(), n })
    }
}

/// An active lease of `n` device slots; returns them on drop.
#[derive(Debug)]
pub struct DeviceLease {
    pool: Arc<DevicePool>,
    n: usize,
}

impl DeviceLease {
    pub fn devices(&self) -> usize {
        self.n
    }
}

impl Drop for DeviceLease {
    fn drop(&mut self) {
        *self.pool.used.lock().unwrap() -= self.n;
    }
}

/// Build the backend realizing one concrete [`Plan`] — the routing
/// shared by [`build`], [`build_auto`] and the serving executor's
/// rebuilds: grids go to [`GridBackend`], feature-tile plans to
/// [`TilesBackend`] (interactions) or degrade to rows (φ/predict has no
/// feature axis to split), multi-shard simple axes to
/// [`ShardedBackend`], single-shard plans to the plain construction.
pub fn build_for_plan(
    model: &Arc<Model>,
    cfg: &BackendConfig,
    plan: &Plan,
) -> Result<Box<dyn ShapBackend>> {
    if let (ShardAxis::Grid, Some(grid)) = (plan.axis, plan.grid) {
        return Ok(Box::new(GridBackend::build(model, plan.kind, cfg, grid)?));
    }
    if plan.axis == ShardAxis::FeatureTiles && plan.shards > 1 {
        // tiles split the conditioned-feature loop, which only exists
        // for Φ; a φ/predict-only request on a tile plan falls back to
        // the rows axis (same device count, exact either way)
        if cfg.with_interactions {
            return Ok(Box::new(TilesBackend::build(model, plan.kind, cfg, plan.shards)?));
        }
        return Ok(Box::new(ShardedBackend::build(
            model,
            plan.kind,
            cfg,
            plan.shards,
            ShardAxis::Rows,
        )?));
    }
    if plan.shards > 1 {
        return Ok(Box::new(ShardedBackend::build(
            model, plan.kind, cfg, plan.shards, plan.axis,
        )?));
    }
    let mut one = cfg.clone();
    one.devices = 1;
    one.shard_axis = None;
    build(model, plan.kind, &one)
}

/// Build one backend of the given kind over `model`, through the
/// prepared-model cache: path extraction, shape statistics and packed
/// layouts are computed once per model and shared by every build over
/// the same `Arc<Model>` (repeat builds, row shards, executor
/// rebuilds). With `cfg.devices > 1` the result spans that device
/// topology: a [`ShardedBackend`] on a simple axis, or a
/// [`GridBackend`] when `cfg.shard_axis` is `Some(Grid)` (or the
/// planner picks a grid for `cfg.rows_hint`-row batches when unset).
pub fn build(
    model: &Arc<Model>,
    kind: BackendKind,
    cfg: &BackendConfig,
) -> Result<Box<dyn ShapBackend>> {
    let prep = prepared::prepare(model);
    if cfg.devices > 1 {
        let planner = Planner::for_prepared(&prep)
            .with_devices(cfg.devices)
            .with_fastv2_budget_mb(cfg.fastv2_max_mb);
        let rows = cfg.rows_hint.max(1);
        // an explicit axis pins the layout at the full device count; auto
        // mode takes the best layout's axis, then sizes it to the devices
        let plan = match cfg.shard_axis {
            Some(axis) => planner.plan_pinned(kind, rows, axis, cfg.devices),
            None => planner
                .plan_for(kind, rows)
                .and_then(|p| planner.plan_pinned(kind, rows, p.axis, cfg.devices)),
        }
        .unwrap_or_else(|| Plan::fallback(kind, cfg.devices, cfg.shard_axis));
        return build_for_plan(model, cfg, &plan);
    }
    match kind {
        BackendKind::Recursive => {
            Ok(Box::new(RecursiveBackend::with_prepared(prep, cfg.threads)))
        }
        BackendKind::Host => {
            Ok(Box::new(HostPackedBackend::with_prepared(prep, cfg.packing, cfg.threads)))
        }
        BackendKind::Linear => Ok(Box::new(LinearBackend::with_prepared(prep, cfg.threads))),
        BackendKind::FastV2 => Ok(Box::new(FastV2Backend::with_prepared(
            prep,
            cfg.threads,
            cfg.fastv2_max_mb,
        )?)),
        #[cfg(feature = "xla")]
        BackendKind::XlaWarp => Ok(Box::new(XlaWarpBackend::with_prepared(&prep, cfg)?)),
        #[cfg(feature = "xla")]
        BackendKind::XlaPadded => Ok(Box::new(XlaPaddedBackend::with_prepared(&prep, cfg)?)),
        #[cfg(not(feature = "xla"))]
        BackendKind::XlaWarp | BackendKind::XlaPadded => Err(crate::anyhow!(
            "backend '{}' requires building with `--features xla`",
            kind.name()
        )),
    }
}

/// Every backend that actually constructs in this environment (compiled
/// in, artifacts present, …), paired with its kind. Order follows
/// `BackendKind::ALL`.
pub fn available(model: &Arc<Model>, cfg: &BackendConfig) -> Vec<(BackendKind, Box<dyn ShapBackend>)> {
    let mut out = Vec::new();
    for kind in BackendKind::ALL {
        if let Ok(b) = build(model, kind, cfg) {
            out.push((kind, b));
        }
    }
    out
}

/// Planner-driven construction: try backends in estimated-latency order
/// for `cfg.rows_hint`-row batches, returning the first that builds (and
/// supports interactions when `cfg.with_interactions` demands them).
/// With `cfg.devices > 1` each candidate plan carries the shard count
/// and axis the generalized crossover heuristic picked; an explicit
/// `cfg.shard_axis` pins the axis and the full device count instead.
pub fn build_auto(
    model: &Arc<Model>,
    cfg: &BackendConfig,
) -> Result<(Plan, Box<dyn ShapBackend>)> {
    let prep = prepared::prepare(model);
    let planner = Planner::for_prepared(&prep)
        .with_devices(cfg.devices.max(1))
        .with_fastv2_budget_mb(cfg.fastv2_max_mb);
    let rows = cfg.rows_hint.clamp(1, 1 << 24);
    // an explicit axis pins the layout for every candidate, and the
    // ranking prices that pinned layout (not each kind's best)
    let plans = match cfg.shard_axis {
        Some(axis) => planner.ranked_pinned(rows, axis, cfg.devices.max(1)),
        None => planner.ranked(rows),
    };
    let mut last_err = None;
    for plan in plans {
        let built = build_for_plan(model, cfg, &plan);
        match built {
            Ok(b) => {
                if cfg.with_interactions && !b.caps().supports_interactions {
                    continue;
                }
                return Ok((plan, b));
            }
            Err(e) => last_err = Some(e),
        }
    }
    Err(last_err.unwrap_or_else(|| crate::anyhow!("no backend available")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;
    use crate::gbdt::{train, TrainParams};

    fn tiny_model() -> Arc<Model> {
        let d = SynthSpec::cal_housing(0.004).generate();
        Arc::new(train(&d, &TrainParams { rounds: 2, max_depth: 3, ..Default::default() }))
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in BackendKind::ALL {
            assert_eq!(BackendKind::parse(k.name()), Some(k));
            // parsing is case-insensitive
            assert_eq!(BackendKind::parse(&k.name().to_ascii_uppercase()), Some(k));
        }
        assert_eq!(BackendKind::parse("recursive"), Some(BackendKind::Recursive));
        assert_eq!(BackendKind::parse("padded"), Some(BackendKind::XlaPadded));
        assert_eq!(BackendKind::parse("Linear"), Some(BackendKind::Linear));
        assert_eq!(BackendKind::parse("fast-v2"), Some(BackendKind::FastV2));
        assert_eq!(BackendKind::parse("FastV2"), Some(BackendKind::FastV2));
        assert_eq!(BackendKind::parse("nope"), None);
        assert!(BackendKind::name_list().contains("linear"));
        assert!(BackendKind::name_list().contains("fastv2"));
    }

    #[test]
    fn cpu_backends_always_available() {
        let model = tiny_model();
        let cfg = BackendConfig { threads: 1, ..Default::default() };
        let avail = available(&model, &cfg);
        let kinds: Vec<BackendKind> = avail.iter().map(|(k, _)| *k).collect();
        assert!(kinds.contains(&BackendKind::Recursive));
        assert!(kinds.contains(&BackendKind::Host));
        assert!(kinds.contains(&BackendKind::Linear));
        assert!(kinds.contains(&BackendKind::FastV2));
        for (_, b) in &avail {
            assert_eq!(b.num_features(), model.num_features);
            assert_eq!(b.num_groups(), model.num_groups);
        }
    }

    #[test]
    fn build_with_devices_shards_transparently() {
        let model = tiny_model();
        let d = SynthSpec::cal_housing(0.004).generate();
        let m = model.num_features;
        let rows = 8.min(d.rows);
        let x = &d.features[..rows * m];
        let plain = build(
            &model,
            BackendKind::Host,
            &BackendConfig { threads: 1, ..Default::default() },
        )
        .unwrap()
        .contributions(x, rows)
        .unwrap();
        for axis in [ShardAxis::Rows, ShardAxis::Trees] {
            let cfg = BackendConfig {
                threads: 1,
                devices: 3,
                shard_axis: Some(axis),
                rows_hint: rows,
                ..Default::default()
            };
            let b = build(&model, BackendKind::Host, &cfg).unwrap();
            assert!(b.describe().starts_with("sharded["), "{}", b.describe());
            assert_eq!(b.name(), "host", "sharding keeps the inner kind's name");
            let phis = b.contributions(x, rows).unwrap();
            assert_eq!(phis.len(), plain.len());
            for (a, b) in phis.iter().zip(&plain) {
                assert!((a - b).abs() < 1e-5, "{axis:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn tiles_axis_routes_by_pipeline() {
        let model = tiny_model();
        let cfg = BackendConfig {
            threads: 1,
            devices: 3,
            shard_axis: Some(ShardAxis::FeatureTiles),
            rows_hint: 4,
            with_interactions: true,
            ..Default::default()
        };
        // Φ pipeline on a tile plan → the tile executor
        let b = build(&model, BackendKind::Host, &cfg).unwrap();
        assert!(b.describe().starts_with("tiles["), "{}", b.describe());
        assert_eq!(b.name(), "host", "tiling keeps the inner kind's name");
        let d = SynthSpec::cal_housing(0.004).generate();
        let m = model.num_features;
        let inter = b.interactions(&d.features[..2 * m], 2).unwrap();
        assert_eq!(inter.len(), 2 * model.num_groups * (m + 1) * (m + 1));
        // φ-only pipeline on the same plan degrades to row shards
        let phi_cfg = BackendConfig { with_interactions: false, ..cfg };
        let b = build(&model, BackendKind::Host, &phi_cfg).unwrap();
        assert!(b.describe().starts_with("sharded["), "{}", b.describe());
    }

    #[test]
    fn build_auto_returns_a_working_backend() {
        let model = tiny_model();
        let cfg =
            BackendConfig { threads: 1, rows_hint: 4, with_interactions: true, ..Default::default() };
        let (plan, b) = build_auto(&model, &cfg).unwrap();
        assert!(plan.est_latency_s >= 0.0);
        assert!(b.caps().supports_interactions);
        let m = model.num_features;
        let d = SynthSpec::cal_housing(0.004).generate();
        let phis = b.contributions(&d.features[..4 * m], 4).unwrap();
        assert_eq!(phis.len(), 4 * model.num_groups * (m + 1));
    }
}
