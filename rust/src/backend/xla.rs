//! The PJRT/XLA [`ShapBackend`]s: AOT HLO artifacts executed on device,
//! in both model representations — warp-packed (faithful CUDA layout
//! adaptation) and padded-path (gather-free perf variant). Artifact
//! selection, device upload and compilation happen once at construction;
//! the reported setup cost is measured, so the planner's a-priori
//! estimate can be compared against reality.

use std::sync::Arc;
use std::time::Instant;

use crate::backend::{
    planner, prepared, BackendCaps, BackendConfig, BackendKind, PreparedModel, ShapBackend,
};
use crate::gbdt::Model;
use crate::runtime::engine::{Prepared, PreparedPadded, ShapEngine};
use crate::runtime::manifest::ArtifactKind;
use crate::shap::{PackedModel, PaddedModel};
use crate::util::error::Result;

/// Warp-packed layout: 32-lane bins, the paper's §3.3 representation.
pub struct XlaWarpBackend {
    pm: Arc<PackedModel>,
    prepared_model: Arc<PreparedModel>,
    engine: ShapEngine,
    prep: Prepared,
    prep_int: Option<Prepared>,
    /// why the interactions pipeline is unavailable, when it is
    int_err: Option<String>,
    prep_pred: Option<Prepared>,
    caps: BackendCaps,
}

impl XlaWarpBackend {
    pub fn new(model: &Arc<Model>, cfg: &BackendConfig) -> Result<XlaWarpBackend> {
        XlaWarpBackend::with_prepared(&prepared::prepare(model), cfg)
    }

    /// Construct over an existing prepared-model cache entry: the
    /// packed host tensors come from the cache; only the device work
    /// (artifact selection, upload, compilation) is per-instance.
    pub fn with_prepared(
        prep_model: &Arc<PreparedModel>,
        cfg: &BackendConfig,
    ) -> Result<XlaWarpBackend> {
        let shape = prep_model.shape();
        let t0 = Instant::now();
        let pm = prep_model.packed(cfg.packing);
        let mut engine = ShapEngine::new(&cfg.artifacts_dir)?;
        let prep = engine.prepare(&pm, ArtifactKind::Shap, cfg.rows_hint)?;
        // a missing/broken interactions artifact must not take the
        // contributions path down with it: degrade to
        // supports_interactions = false, but keep the cause
        let (prep_int, int_err) = if cfg.with_interactions {
            match engine.prepare(&pm, ArtifactKind::Interactions, cfg.rows_hint) {
                Ok(p) => (Some(p), None),
                Err(e) => (None, Some(format!("{e:#}"))),
            }
        } else {
            (None, Some("built without with_interactions".to_string()))
        };
        let prep_pred = if cfg.with_predict {
            engine.prepare(&pm, ArtifactKind::Predict, cfg.rows_hint).ok()
        } else {
            None
        };
        let est = planner::estimate(BackendKind::XlaWarp, &shape);
        let caps = BackendCaps {
            supports_interactions: prep_int.is_some(),
            setup_cost_s: t0.elapsed().as_secs_f64(),
            batch_overhead_s: est.batch_overhead_s,
            rows_per_s: est.rows_per_s,
        };
        Ok(XlaWarpBackend {
            pm,
            prepared_model: Arc::clone(prep_model),
            engine,
            prep,
            prep_int,
            int_err,
            prep_pred,
            caps,
        })
    }

    /// The artifact bucket serving contributions.
    pub fn artifact(&self) -> &str {
        &self.prep.artifact
    }
}

impl ShapBackend for XlaWarpBackend {
    fn name(&self) -> &'static str {
        BackendKind::XlaWarp.name()
    }

    fn caps(&self) -> BackendCaps {
        self.caps
    }

    fn num_features(&self) -> usize {
        self.pm.num_features
    }

    fn num_groups(&self) -> usize {
        self.pm.num_groups
    }

    fn contributions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        self.engine.shap_values(&self.pm, &self.prep, x, rows)
    }

    fn interactions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        match &self.prep_int {
            Some(p) => self.engine.interactions(&self.pm, p, x, rows),
            None => Err(crate::anyhow!(
                "xla backend cannot serve interactions: {}",
                self.int_err.as_deref().unwrap_or("no interactions artifact")
            )),
        }
    }

    fn predictions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        match &self.prep_pred {
            Some(p) => self.engine.predict(&self.pm, p, x, rows),
            None => Err(crate::anyhow!(
                "xla backend prepared without a predict artifact (set with_predict)"
            )),
        }
    }

    fn prepared(&self) -> Option<&Arc<PreparedModel>> {
        Some(&self.prepared_model)
    }

    fn describe(&self) -> String {
        format!("xla[warp, artifact {}]", self.prep.artifact)
    }
}

/// Padded-path layout: one row per path, element axis padded to the
/// artifact depth bucket (gather-free DP, the optimized default).
pub struct XlaPaddedBackend {
    pm: Arc<PaddedModel>,
    prepared_model: Arc<PreparedModel>,
    engine: ShapEngine,
    prep: PreparedPadded,
    /// interactions may need a different element width — own model+prep
    pad_int: Option<(Arc<PaddedModel>, PreparedPadded)>,
    /// why the interactions pipeline is unavailable, when it is
    int_err: Option<String>,
    caps: BackendCaps,
}

impl XlaPaddedBackend {
    pub fn new(model: &Arc<Model>, cfg: &BackendConfig) -> Result<XlaPaddedBackend> {
        XlaPaddedBackend::with_prepared(&prepared::prepare(model), cfg)
    }

    /// Construct over an existing prepared-model cache entry: padded
    /// host tensors (keyed by element width) come from the cache; only
    /// the device work is per-instance.
    pub fn with_prepared(
        prep_model: &Arc<PreparedModel>,
        cfg: &BackendConfig,
    ) -> Result<XlaPaddedBackend> {
        let shape = prep_model.shape();
        let m = shape.features;
        let depth = shape.max_path_len.saturating_sub(1).max(1);
        let t0 = Instant::now();
        let mut engine = ShapEngine::new(&cfg.artifacts_dir)?;
        let width = engine
            .manifest
            .select(ArtifactKind::ShapPadded, m, depth, cfg.rows_hint)?
            .depth
            + 1;
        let pm = prep_model.padded(width);
        let prep = engine.prepare_padded(&pm, cfg.rows_hint)?;
        // a missing/broken interactions artifact must not take the
        // contributions path down with it: degrade to
        // supports_interactions = false, but keep the cause
        let (pad_int, int_err) = if cfg.with_interactions {
            let picked = engine
                .manifest
                .select(ArtifactKind::InteractionsPadded, m, depth.max(2), cfg.rows_hint)
                .map(|s| s.depth + 1);
            match picked {
                Ok(w) => {
                    let pmi = prep_model.padded(w);
                    match engine.prepare_padded_kind(
                        &pmi,
                        ArtifactKind::InteractionsPadded,
                        cfg.rows_hint,
                    ) {
                        Ok(prepi) => (Some((pmi, prepi)), None),
                        Err(e) => (None, Some(format!("{e:#}"))),
                    }
                }
                Err(e) => (None, Some(format!("{e:#}"))),
            }
        } else {
            (None, Some("built without with_interactions".to_string()))
        };
        let est = planner::estimate(BackendKind::XlaPadded, &shape);
        let caps = BackendCaps {
            supports_interactions: pad_int.is_some(),
            setup_cost_s: t0.elapsed().as_secs_f64(),
            batch_overhead_s: est.batch_overhead_s,
            rows_per_s: est.rows_per_s,
        };
        Ok(XlaPaddedBackend {
            pm,
            prepared_model: Arc::clone(prep_model),
            engine,
            prep,
            pad_int,
            int_err,
            caps,
        })
    }

    /// The artifact bucket serving contributions.
    pub fn artifact(&self) -> &str {
        &self.prep.artifact
    }
}

impl ShapBackend for XlaPaddedBackend {
    fn name(&self) -> &'static str {
        BackendKind::XlaPadded.name()
    }

    fn caps(&self) -> BackendCaps {
        self.caps
    }

    fn num_features(&self) -> usize {
        self.pm.num_features
    }

    fn num_groups(&self) -> usize {
        self.pm.num_groups
    }

    fn contributions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        self.engine.shap_values_padded(&self.pm, &self.prep, x, rows)
    }

    fn interactions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        match &self.pad_int {
            Some((pmi, prepi)) => self.engine.interactions_padded(pmi, prepi, x, rows),
            None => Err(crate::anyhow!(
                "xla-padded backend cannot serve interactions: {}",
                self.int_err.as_deref().unwrap_or("no interactions artifact")
            )),
        }
    }

    fn prepared(&self) -> Option<&Arc<PreparedModel>> {
        Some(&self.prepared_model)
    }

    fn describe(&self) -> String {
        format!("xla[padded, artifact {}]", self.prep.artifact)
    }
}
