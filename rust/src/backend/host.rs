//! The host-native packed-DP [`ShapBackend`]: the GPU algorithm's
//! prepare→pack→execute pipeline run on CPU over `PackedGroup` tensors.
//! Both contributions and interactions flow through the packed
//! representation (§3.4 inputs; §3.5 per-feature-pair DP).
//!
//! Construction goes through the prepared-model cache: the packed
//! layout is built once per (model, packing algorithm) and shared by
//! every instance — the setup cost it reports is the *measured* time to
//! obtain the layout, which collapses to the cache-lookup cost on a
//! warm rebuild.

use std::sync::Arc;

use crate::backend::{
    planner, prepared, BackendCaps, BackendConfig, BackendKind, PreparedModel, ShapBackend,
};
use crate::gbdt::Model;
use crate::shap::{host_kernel, PackedModel, Packing};
use crate::util::error::Result;
use crate::util::time_it;

pub struct HostPackedBackend {
    pm: Arc<PackedModel>,
    prep: Arc<PreparedModel>,
    packing: Packing,
    threads: usize,
    caps: BackendCaps,
}

impl HostPackedBackend {
    pub fn new(model: &Arc<Model>, packing: Packing, threads: usize) -> HostPackedBackend {
        HostPackedBackend::with_prepared(prepared::prepare(model), packing, threads)
    }

    /// Construct over an existing prepared-model cache entry (the path
    /// every `backend::build` takes; `new` is the one-model shorthand).
    pub fn with_prepared(
        prep: Arc<PreparedModel>,
        packing: Packing,
        threads: usize,
    ) -> HostPackedBackend {
        let shape = prep.shape();
        let (pm, setup_s) = time_it(|| prep.packed(packing));
        let est = planner::estimate(BackendKind::Host, &shape);
        HostPackedBackend {
            pm,
            prep,
            packing,
            threads,
            caps: BackendCaps {
                supports_interactions: true,
                setup_cost_s: setup_s,
                batch_overhead_s: est.batch_overhead_s,
                rows_per_s: est.rows_per_s,
            },
        }
    }

    /// Construct from a [`BackendConfig`] (factory convenience).
    pub fn from_config(model: &Arc<Model>, cfg: &BackendConfig) -> HostPackedBackend {
        HostPackedBackend::new(model, cfg.packing, cfg.threads)
    }

    /// The packed representation this backend executes over.
    pub fn packed(&self) -> &PackedModel {
        &self.pm
    }
}

impl ShapBackend for HostPackedBackend {
    fn name(&self) -> &'static str {
        BackendKind::Host.name()
    }

    fn caps(&self) -> BackendCaps {
        self.caps
    }

    fn num_features(&self) -> usize {
        self.pm.num_features
    }

    fn num_groups(&self) -> usize {
        self.pm.num_groups
    }

    fn contributions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        Ok(host_kernel::shap_values(&self.pm, x, rows, self.threads))
    }

    fn interactions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        Ok(host_kernel::interaction_values(&self.pm, x, rows, self.threads))
    }

    fn interactions_block(
        &self,
        x: &[f32],
        rows: usize,
        lo: usize,
        hi: usize,
    ) -> Result<Vec<f64>> {
        Ok(host_kernel::interaction_block(&self.pm, x, rows, self.threads, lo, hi))
    }

    fn contributions_f64(&self, x: &[f32], rows: usize) -> Result<Vec<f64>> {
        Ok(host_kernel::phis_f64(&self.pm, x, rows, self.threads))
    }

    fn prepared(&self) -> Option<&Arc<PreparedModel>> {
        Some(&self.prep)
    }

    fn describe(&self) -> String {
        let bins: usize = self.pm.groups.iter().map(|g| g.num_bins).sum();
        format!(
            "host[packed-dp, {} packing, {} bins, depth {}, {} dead paths skipped]",
            self.packing.name(),
            bins,
            self.pm.max_depth,
            self.prep.dead_paths()
        )
    }
}
