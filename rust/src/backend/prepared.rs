//! The Fast-TreeSHAP-style prepared-model cache: every row-independent
//! preprocessing product — merged root→leaf paths (§3.1–3.2), shape
//! statistics, φ base values, Fast-TreeSHAP-v1-flavoured per-path
//! contribution bounds, and the packed / padded device layouts (§3.3–3.4) — is
//! computed **once per model** and reused across every backend build and
//! every subsequent batch.
//!
//! Before this cache, each backend construction re-extracted paths (the
//! planner's `ModelShape`, `pack_model` and `expected_values` each
//! walked the ensemble independently), every row shard of a
//! `ShardedBackend` re-packed the full model, and every executor rebuild
//! on the serving recalibration cadence repeated all of it. Now:
//!
//! - [`prepare`] returns the process-wide [`PreparedModel`] for an
//!   `Arc<Model>`, keyed by pointer identity in a registry of weak
//!   entries — the same model prepared twice is the same cache entry.
//! - Row-axis shards share one entry (the full model packs once, not
//!   once per device); tree-axis shards hold one entry per sub-ensemble,
//!   invalidated naturally when `quarantine`/`hot_add` rebuild the split
//!   (the old sub-models drop, their entries are reclaimed).
//! - Grid topologies (`backend::grid`) are cache-aware by construction:
//!   all row replicas of a tree slice are built from one shared
//!   sub-model `Arc`, so an r×t grid holds exactly `t` entries (each
//!   sub-ensemble packs once, not once per replica), and replica
//!   hot-adds rebuild against the slice's still-live entry instead of
//!   re-packing — pinned by `rust/tests/prepared.rs`.
//! - The serving executor's rebuilds (`recalibrate_every` cadence,
//!   replans, hot-adds) hit the cache because the service holds the same
//!   `Arc<Model>` for its whole life — steady-state rebuild cost is the
//!   cache lookup, not the packing.
//!
//! Cached layouts are built **lazily** under a per-entry lock, so
//! concurrent shard builds requesting the same packing wait for one
//! build instead of duplicating it. Every cached product is produced by
//! the same code path as the uncached one (`pack_model` ≡
//! `pack_model_from_paths` over freshly extracted paths), so φ/Φ from a
//! cached backend are **bit-identical** to an uncached build — pinned by
//! `rust/tests/prepared.rs`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};

use crate::backend::planner::ModelShape;
use crate::gbdt::Model;
use crate::shap::fast_v2::{self, FastV2Model};
use crate::shap::linear::{self, LinearModel};
use crate::shap::{
    expected_values_from_paths, model_paths, pack_model_from_paths, pad_model_from_paths,
    PackedModel, PaddedModel, Packing, Path,
};
use crate::util::time_it;

/// Counters for one prepared model: how often each cached product was
/// rebuilt vs reused, and the wall time spent building.
#[derive(Clone, Copy, Debug, Default)]
pub struct PrepStats {
    /// seconds spent extracting + merging paths (paid once)
    pub paths_s: f64,
    /// packed-layout builds (cache misses) and reuses (hits)
    pub packed_builds: u64,
    pub packed_hits: u64,
    /// padded-layout builds and reuses
    pub padded_builds: u64,
    pub padded_hits: u64,
    /// Linear TreeShap summary-table builds and reuses
    pub linear_builds: u64,
    pub linear_hits: u64,
    /// Fast TreeSHAP v2 weight-table builds and reuses
    pub fastv2_builds: u64,
    pub fastv2_hits: u64,
    /// per-tree feature-presence index builds and reuses (tile sharding)
    pub tilefeat_builds: u64,
    pub tilefeat_hits: u64,
    /// total seconds spent building packed/padded/linear/fastv2 layouts
    pub layout_s: f64,
}

impl PrepStats {
    /// Total one-time preparation seconds accumulated so far.
    pub fn total_s(&self) -> f64 {
        self.paths_s + self.layout_s
    }

    /// Fold another entry's counters into this one (registry totals).
    pub fn merge(&mut self, other: &PrepStats) {
        self.paths_s += other.paths_s;
        self.packed_builds += other.packed_builds;
        self.packed_hits += other.packed_hits;
        self.padded_builds += other.padded_builds;
        self.padded_hits += other.padded_hits;
        self.linear_builds += other.linear_builds;
        self.linear_hits += other.linear_hits;
        self.fastv2_builds += other.fastv2_builds;
        self.fastv2_hits += other.fastv2_hits;
        self.tilefeat_builds += other.tilefeat_builds;
        self.tilefeat_hits += other.tilefeat_hits;
        self.layout_s += other.layout_s;
    }
}

/// All row-independent preprocessing products of one model, computed
/// once and shared (`Arc`) by every backend instance built over it.
pub struct PreparedModel {
    model: Arc<Model>,
    /// merged root→leaf paths tagged with output group — the §3.1–3.2
    /// extraction every representation below derives from
    paths: Vec<(usize, Path)>,
    shape: ModelShape,
    /// φ base values per group (E[f] + base_score)
    expected: Vec<f64>,
    /// Fast-TreeSHAP-v1-flavoured per-path contribution bound: every
    /// EXTEND weight is a probability-weighted Shapley coefficient in
    /// `[0, 1]` (zero_fractions are cover ratios ≤ 1), so no row can
    /// draw more than `|leaf value|` from a path. Exactly-zero bounds
    /// mark dead leaves (leaf value 0), skippable without changing a
    /// single output bit; anything sharper would break bit-identity
    /// with the uncached kernel, so the bounds otherwise inform stats
    /// and cost modelling only.
    max_weights: Vec<f64>,
    /// lazily built packed layouts, one per packing algorithm
    packed: Mutex<BTreeMap<&'static str, Arc<PackedModel>>>,
    /// lazily built padded layouts, one per element width
    padded: Mutex<BTreeMap<usize, Arc<PaddedModel>>>,
    /// lazily built Linear TreeShap summary tables (one per model)
    linear: Mutex<Option<Arc<LinearModel>>>,
    /// lazily built Fast TreeSHAP v2 subset weight tables (one per model)
    fastv2: Mutex<Option<Arc<FastV2Model>>>,
    /// lazily built per-tree feature-presence index (one per model)
    tilefeat: Mutex<Option<Arc<TileFeatures>>>,
    stats: Mutex<PrepStats>,
}

/// Per-tree feature-presence index for feature-tile sharding: which
/// features each tree splits on (sorted, deduplicated) and, per
/// feature, how many trees reference it. The conditioned-pass cost of a
/// feature is proportional to its tree count, so the tile splitter
/// balances tiles by summed counts, and each tile shard skips trees
/// whose list has no entry inside its range — the M ≫ D sparsity win.
#[derive(Debug)]
pub struct TileFeatures {
    /// sorted unique split features per tree (model order)
    pub per_tree: Vec<Vec<i32>>,
    /// number of trees splitting on each feature, length `num_features`
    pub tree_counts: Vec<u32>,
}

impl std::fmt::Debug for PreparedModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PreparedModel")
            .field("trees", &self.shape.trees)
            .field("leaves", &self.shape.leaves)
            .field("paths", &self.paths.len())
            .field("stats", &self.stats.lock().unwrap())
            .finish()
    }
}

impl PreparedModel {
    /// Extract and summarize the model's paths (the eager half of the
    /// prepare step; layouts build lazily on first request).
    fn build(model: &Arc<Model>) -> PreparedModel {
        let (paths, paths_s) = time_it(|| model_paths(model));
        let shape = ModelShape::from_paths(model, &paths);
        let expected = expected_values_from_paths(model.base_score, model.num_groups, &paths);
        let max_weights =
            paths.iter().map(|(_, p)| f64::from(p.leaf_value()).abs()).collect();
        PreparedModel {
            model: Arc::clone(model),
            paths,
            shape,
            expected,
            max_weights,
            packed: Mutex::new(BTreeMap::new()),
            padded: Mutex::new(BTreeMap::new()),
            linear: Mutex::new(None),
            fastv2: Mutex::new(None),
            tilefeat: Mutex::new(None),
            stats: Mutex::new(PrepStats { paths_s, ..PrepStats::default() }),
        }
    }

    pub fn model(&self) -> &Arc<Model> {
        &self.model
    }

    /// The merged, group-tagged paths (shared by all layouts).
    pub fn paths(&self) -> &[(usize, Path)] {
        &self.paths
    }

    /// Shape statistics for the planner's cost model — derived from the
    /// cached paths, not a fresh extraction.
    pub fn shape(&self) -> ModelShape {
        self.shape
    }

    /// φ base values per group (E[f] + base_score).
    pub fn expected_values(&self) -> &[f64] {
        &self.expected
    }

    /// Per-path contribution bounds (see the field docs).
    pub fn max_weights(&self) -> &[f64] {
        &self.max_weights
    }

    /// Paths whose contribution bound is exactly zero — contributing
    /// nothing to any row, skippable without changing a single bit.
    pub fn dead_paths(&self) -> usize {
        self.max_weights.iter().filter(|&&w| w == 0.0).count()
    }

    /// The packed 32-lane layout under `algorithm`, built on first
    /// request and shared afterwards. Concurrent first requests for the
    /// same algorithm serialize on the entry lock, so the layout is
    /// built exactly once.
    pub fn packed(&self, algorithm: Packing) -> Arc<PackedModel> {
        let mut map = self.packed.lock().unwrap();
        if let Some(pm) = map.get(algorithm.name()) {
            self.stats.lock().unwrap().packed_hits += 1;
            return Arc::clone(pm);
        }
        let (pm, dt) = time_it(|| {
            let model = self.model.as_ref();
            Arc::new(pack_model_from_paths(model, &self.paths, &self.expected, algorithm))
        });
        {
            let mut s = self.stats.lock().unwrap();
            s.packed_builds += 1;
            s.layout_s += dt;
        }
        map.insert(algorithm.name(), Arc::clone(&pm));
        pm
    }

    /// The padded-path layout with element axis `width`, built on first
    /// request and shared afterwards.
    pub fn padded(&self, width: usize) -> Arc<PaddedModel> {
        let mut map = self.padded.lock().unwrap();
        if let Some(pm) = map.get(&width) {
            self.stats.lock().unwrap().padded_hits += 1;
            return Arc::clone(pm);
        }
        let (pm, dt) = time_it(|| {
            let model = self.model.as_ref();
            Arc::new(pad_model_from_paths(model, &self.paths, &self.expected, width))
        });
        {
            let mut s = self.stats.lock().unwrap();
            s.padded_builds += 1;
            s.layout_s += dt;
        }
        map.insert(width, Arc::clone(&pm));
        pm
    }

    /// The Linear TreeShap summary tables (per-tree cover ratios,
    /// heights, and the interpolation grid), built on first request and
    /// shared afterwards — one per model, reused by every row shard,
    /// grid replica and executor rebuild.
    pub fn linear(&self) -> Arc<LinearModel> {
        let mut slot = self.linear.lock().unwrap();
        if let Some(lm) = slot.as_ref() {
            self.stats.lock().unwrap().linear_hits += 1;
            return Arc::clone(lm);
        }
        let (lm, dt) = time_it(|| {
            Arc::new(linear::summarize_model_with_expected(self.model.as_ref(), &self.expected))
        });
        {
            let mut s = self.stats.lock().unwrap();
            s.linear_builds += 1;
            s.layout_s += dt;
        }
        *slot = Some(Arc::clone(&lm));
        lm
    }

    /// The Fast TreeSHAP v2 subset weight tables (`shap::fast_v2`),
    /// built from the cached merged paths on first request and shared
    /// afterwards — one per model, reused by every row shard, grid
    /// replica and executor rebuild. Callers enforce the memory budget
    /// *before* requesting (via [`PreparedModel::fastv2_table_bytes`]);
    /// this method only builds.
    pub fn fastv2(&self) -> Arc<FastV2Model> {
        let mut slot = self.fastv2.lock().unwrap();
        if let Some(fm) = slot.as_ref() {
            self.stats.lock().unwrap().fastv2_hits += 1;
            return Arc::clone(fm);
        }
        let (fm, dt) = time_it(|| {
            Arc::new(fast_v2::precompute_from_paths(
                self.model.num_features,
                self.model.num_groups,
                &self.paths,
                &self.expected,
            ))
        });
        {
            let mut s = self.stats.lock().unwrap();
            s.fastv2_builds += 1;
            s.layout_s += dt;
        }
        *slot = Some(Arc::clone(&fm));
        fm
    }

    /// The per-tree feature-presence index ([`TileFeatures`]), built on
    /// first request and shared afterwards — one per model, reused by
    /// the interactions kernel (which previously re-sorted/deduped the
    /// lists every call), the tile splitter, and every tile shard.
    pub fn tile_features(&self) -> Arc<TileFeatures> {
        let mut slot = self.tilefeat.lock().unwrap();
        if let Some(tf) = slot.as_ref() {
            self.stats.lock().unwrap().tilefeat_hits += 1;
            return Arc::clone(tf);
        }
        let (tf, dt) = time_it(|| {
            let per_tree = crate::shap::interactions::model_tree_features(self.model.as_ref());
            let mut tree_counts = vec![0u32; self.model.num_features];
            for feats in &per_tree {
                for &f in feats {
                    if (f as usize) < tree_counts.len() {
                        tree_counts[f as usize] += 1;
                    }
                }
            }
            Arc::new(TileFeatures { per_tree, tree_counts })
        });
        {
            let mut s = self.stats.lock().unwrap();
            s.tilefeat_builds += 1;
            s.layout_s += dt;
        }
        *slot = Some(Arc::clone(&tf));
        tf
    }

    /// Exact bytes the Fast TreeSHAP v2 tables occupy (or would occupy),
    /// computed from the cached paths without building anything — the
    /// backend-side memory guardrail compares this against
    /// `--fastv2-max-mb` before triggering the build.
    pub fn fastv2_table_bytes(&self) -> f64 {
        fast_v2::table_bytes_for_paths(&self.paths)
    }

    /// This entry's build/reuse counters.
    pub fn stats(&self) -> PrepStats {
        *self.stats.lock().unwrap()
    }
}

/// Registry entry liveness: a `PreparedModel` holds one strong model
/// reference itself, so an entry is dead once nothing *outside* the
/// cache keeps the model alive (`strong_count() <= 1`).
type Registry = Vec<(Weak<Model>, Arc<PreparedModel>)>;

static REGISTRY: Mutex<Registry> = Mutex::new(Vec::new());
static REGISTRY_HITS: AtomicU64 = AtomicU64::new(0);
static REGISTRY_MISSES: AtomicU64 = AtomicU64::new(0);

/// The prepared-model cache entry for `model`, creating it on first
/// request. Keyed by `Arc` pointer identity: every caller holding a
/// clone of the same `Arc<Model>` — row shards, executor rebuilds,
/// repeated pool calls — shares one entry. Entries are reclaimed once
/// the model's last external reference drops.
///
/// The heavy path extraction runs *outside* the registry lock
/// (double-checked), so preparing one model never blocks lookups of
/// another; the rare concurrent first-prepare builds twice and adopts
/// the winner.
pub fn prepare(model: &Arc<Model>) -> Arc<PreparedModel> {
    let key = Arc::as_ptr(model);
    {
        let mut reg = REGISTRY.lock().unwrap();
        reg.retain(|(w, _)| w.strong_count() > 1);
        if let Some((_, p)) = reg.iter().find(|(w, _)| w.as_ptr() == key) {
            REGISTRY_HITS.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(p);
        }
    }
    let built = Arc::new(PreparedModel::build(model));
    let mut reg = REGISTRY.lock().unwrap();
    if let Some((_, p)) = reg.iter().find(|(w, _)| w.as_ptr() == key && w.strong_count() > 1) {
        // someone else prepared the same model while we were building
        REGISTRY_HITS.fetch_add(1, Ordering::Relaxed);
        return Arc::clone(p);
    }
    REGISTRY_MISSES.fetch_add(1, Ordering::Relaxed);
    reg.push((Arc::downgrade(model), Arc::clone(&built)));
    built
}

/// Live registry entries (models still externally referenced).
pub fn registry_len() -> usize {
    let mut reg = REGISTRY.lock().unwrap();
    reg.retain(|(w, _)| w.strong_count() > 1);
    reg.len()
}

/// Process-wide cache counters: `(lookup hits, lookup misses)`.
pub fn registry_counters() -> (u64, u64) {
    (REGISTRY_HITS.load(Ordering::Relaxed), REGISTRY_MISSES.load(Ordering::Relaxed))
}

/// Aggregate build/reuse stats over all live registry entries.
pub fn registry_stats() -> PrepStats {
    let reg = REGISTRY.lock().unwrap();
    let mut total = PrepStats::default();
    for (w, p) in reg.iter() {
        if w.strong_count() > 1 {
            total.merge(&p.stats());
        }
    }
    total
}

/// The registry state as JSON, for service metrics snapshots.
pub fn registry_snapshot() -> crate::util::Json {
    use crate::util::Json;
    let (hits, misses) = registry_counters();
    let s = registry_stats();
    Json::obj(vec![
        ("entries", Json::from(registry_len())),
        ("lookup_hits", Json::from(hits as usize)),
        ("lookup_misses", Json::from(misses as usize)),
        ("packed_builds", Json::from(s.packed_builds as usize)),
        ("packed_hits", Json::from(s.packed_hits as usize)),
        ("padded_builds", Json::from(s.padded_builds as usize)),
        ("padded_hits", Json::from(s.padded_hits as usize)),
        ("linear_builds", Json::from(s.linear_builds as usize)),
        ("linear_hits", Json::from(s.linear_hits as usize)),
        ("fastv2_builds", Json::from(s.fastv2_builds as usize)),
        ("fastv2_hits", Json::from(s.fastv2_hits as usize)),
        ("tilefeat_builds", Json::from(s.tilefeat_builds as usize)),
        ("tilefeat_hits", Json::from(s.tilefeat_hits as usize)),
        ("prep_s", Json::from(s.total_s())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;
    use crate::gbdt::{train, TrainParams};

    fn tiny_model() -> Arc<Model> {
        let d = SynthSpec::cal_housing(0.004).generate();
        Arc::new(train(&d, &TrainParams { rounds: 3, max_depth: 4, ..Default::default() }))
    }

    #[test]
    fn prepare_is_identity_cached_per_arc() {
        let model = tiny_model();
        let a = prepare(&model);
        let b = prepare(&model);
        assert!(Arc::ptr_eq(&a, &b), "same Arc<Model> must share one entry");
        // a clone of the Arc is the same pointer → same entry
        let c = prepare(&Arc::clone(&model));
        assert!(Arc::ptr_eq(&a, &c));
        // a different model (even if equal in content) is a new entry
        let other = tiny_model();
        let d = prepare(&other);
        assert!(!Arc::ptr_eq(&a, &d));
    }

    #[test]
    fn layouts_build_once_and_hit_afterwards() {
        let model = tiny_model();
        let prep = prepare(&model);
        let before = prep.stats();
        let p1 = prep.packed(Packing::BestFitDecreasing);
        let p2 = prep.packed(Packing::BestFitDecreasing);
        assert!(Arc::ptr_eq(&p1, &p2), "same packing must share the layout");
        let after = prep.stats();
        assert_eq!(after.packed_builds, before.packed_builds + 1);
        assert!(after.packed_hits >= before.packed_hits + 1);
        // a different algorithm is a separate build
        let p3 = prep.packed(Packing::None);
        assert!(!Arc::ptr_eq(&p1, &p3));
        // padded layouts key on width
        let w = prep.shape().max_path_len.max(2);
        let q1 = prep.padded(w);
        let q2 = prep.padded(w);
        assert!(Arc::ptr_eq(&q1, &q2));
        assert!(!Arc::ptr_eq(&q1, &prep.padded(w + 3)));
        // linear summaries build once per model
        let l1 = prep.linear();
        let l2 = prep.linear();
        assert!(Arc::ptr_eq(&l1, &l2), "linear summaries must be shared");
        let s = prep.stats();
        assert_eq!(s.linear_builds, 1);
        assert!(s.linear_hits >= 1);
        // fastv2 weight tables build once per model
        let f1 = prep.fastv2();
        let f2 = prep.fastv2();
        assert!(Arc::ptr_eq(&f1, &f2), "fastv2 tables must be shared");
        let s = prep.stats();
        assert_eq!(s.fastv2_builds, 1);
        assert!(s.fastv2_hits >= 1);
        assert_eq!(prep.fastv2_table_bytes(), f1.table_bytes() as f64);
        // per-tree feature index builds once per model
        let t1 = prep.tile_features();
        let t2 = prep.tile_features();
        assert!(Arc::ptr_eq(&t1, &t2), "tile-feature index must be shared");
        let s = prep.stats();
        assert_eq!(s.tilefeat_builds, 1);
        assert!(s.tilefeat_hits >= 1);
        assert_eq!(t1.per_tree.len(), prep.model().trees.len());
        assert_eq!(t1.tree_counts.len(), prep.model().num_features);
        // the lists match the kernel's own derivation
        let fresh = crate::shap::interactions::model_tree_features(prep.model());
        assert_eq!(t1.per_tree, fresh);
    }

    #[test]
    fn cached_products_match_uncached_builders_exactly() {
        let model = tiny_model();
        let prep = prepare(&model);
        // shape identical to a fresh extraction
        let fresh = ModelShape::of(&model);
        let cached = prep.shape();
        assert_eq!(cached.leaves, fresh.leaves);
        assert_eq!(cached.max_path_len, fresh.max_path_len);
        assert_eq!(cached.avg_path_len, fresh.avg_path_len);
        // packed layout identical to pack_model
        let a = prep.packed(Packing::BestFitDecreasing);
        let b = crate::shap::pack_model(&model, Packing::BestFitDecreasing);
        assert_eq!(a.expected_values, b.expected_values);
        assert_eq!(a.max_depth, b.max_depth);
        for (ga, gb) in a.groups.iter().zip(&b.groups) {
            assert_eq!(ga.fidx, gb.fidx);
            assert_eq!(ga.v, gb.v);
            assert_eq!(ga.zfrac, gb.zfrac);
        }
        // contribution bounds: one per path, all finite and ≥ 0
        assert_eq!(prep.max_weights().len(), prep.paths().len());
        assert!(prep.max_weights().iter().all(|w| w.is_finite() && *w >= 0.0));
        assert!(prep.dead_paths() <= prep.paths().len());
    }

    #[test]
    fn registry_reclaims_dropped_models() {
        let model = tiny_model();
        let prep = prepare(&model);
        let weak = Arc::downgrade(&prep);
        drop(prep);
        drop(model);
        // pruning happens on the next registry access: with the model's
        // last external reference gone, the cache drops its entry (and
        // with it the last strong PreparedModel reference)
        let _ = registry_len();
        assert_eq!(weak.strong_count(), 0, "entry must be reclaimed");
    }
}
