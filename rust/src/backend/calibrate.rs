//! Measured cost calibration: turn the `(rows, latency)` batch samples
//! the service records into [`CostEstimate`]s, replacing the planner's
//! a-priori constants with the machine's own numbers.
//!
//! Every backend's batch latency is modelled as the paper's two-term
//! line `latency(rows) = batch_overhead + rows · per_row`. Given enough
//! observed batches at varying sizes, ordinary least squares recovers
//! both terms directly. Two guards keep noisy telemetry from
//! destabilizing plans:
//!
//! - **degenerate sample sets** (all batches the same size, or a
//!   negative fitted slope) fall back to a through-origin fit, which is
//!   exact at the observed batch size and conservative elsewhere;
//! - **small sample sets** are blended with the a-priori estimate via
//!   an exponential weight `α = 1 − exp(−n / BLEND_TAU)`, so the first
//!   few (noisy) batches nudge the prior instead of replacing it, and
//!   the measurement only dominates once the evidence accumulates.
//!
//! [`Observations`] is the transport type between the layers: the
//! coordinator's metrics fill it from their per-backend / per-shard
//! sample rings, and `Planner::recalibrate` consumes it. It also
//! derives per-shard throughputs, which the sharded executor uses to
//! skew row-chunk sizes toward faster devices.

use std::collections::BTreeMap;

use crate::backend::planner::CostEstimate;

/// Fewest samples before a fit is attempted at all.
pub const MIN_SAMPLES: usize = 4;

/// Sample-count scale of the prior→measurement blend: at `n = BLEND_TAU`
/// the measurement carries `1 − e⁻¹ ≈ 63%` of the weight.
pub const BLEND_TAU: f64 = 8.0;

/// Observed `(rows, latency_s)` batch samples, keyed by backend name and
/// by device-shard index. Filled by `Metrics::observations()`; consumed
/// by `Planner::recalibrate` and `ShapBackend::set_shard_throughputs`.
#[derive(Clone, Debug, Default)]
pub struct Observations {
    pub per_backend: BTreeMap<String, Vec<(f64, f64)>>,
    pub per_shard: BTreeMap<usize, Vec<(f64, f64)>>,
}

impl Observations {
    pub fn new() -> Observations {
        Observations::default()
    }

    pub fn record_backend(&mut self, name: &str, rows: usize, latency_s: f64) {
        self.per_backend
            .entry(name.to_string())
            .or_default()
            .push((rows as f64, latency_s));
    }

    pub fn record_shard(&mut self, shard: usize, rows: usize, latency_s: f64) {
        self.per_shard.entry(shard).or_default().push((rows as f64, latency_s));
    }

    /// Sustained throughput per shard, `(shard, rows/s)`: total observed
    /// rows over total observed wall time. Shards with no samples (or
    /// zero observed time) are omitted — the executor keeps its own
    /// estimate for those.
    pub fn shard_throughputs(&self) -> Vec<(usize, f64)> {
        self.per_shard
            .iter()
            .filter_map(|(&shard, samples)| {
                let rows: f64 = samples.iter().map(|s| s.0).sum();
                let secs: f64 = samples.iter().map(|s| s.1).sum();
                (secs > 0.0 && rows > 0.0).then_some((shard, rows / secs))
            })
            .collect()
    }
}

/// A fitted two-term latency line.
#[derive(Clone, Copy, Debug)]
pub struct LineFit {
    pub batch_overhead_s: f64,
    pub per_row_s: f64,
    /// samples the fit was computed from (drives the blend weight)
    pub samples: usize,
}

/// Least-squares fit of `latency = batch_overhead + rows · per_row` over
/// `(rows, latency_s)` samples. `None` below [`MIN_SAMPLES`]. Degenerate
/// inputs (a single batch size, or a non-positive fitted slope) fall
/// back to the through-origin line `latency = rows · (ȳ/x̄)`.
pub fn fit_line(samples: &[(f64, f64)]) -> Option<LineFit> {
    let n = samples.len();
    if n < MIN_SAMPLES {
        return None;
    }
    let nf = n as f64;
    let mean_x = samples.iter().map(|s| s.0).sum::<f64>() / nf;
    let mean_y = samples.iter().map(|s| s.1).sum::<f64>() / nf;
    if mean_x <= 0.0 || mean_y <= 0.0 {
        return None;
    }
    let var_x: f64 = samples.iter().map(|s| (s.0 - mean_x) * (s.0 - mean_x)).sum::<f64>() / nf;
    let cov: f64 =
        samples.iter().map(|s| (s.0 - mean_x) * (s.1 - mean_y)).sum::<f64>() / nf;
    let (mut overhead, mut per_row) = if var_x > 1e-12 {
        let slope = cov / var_x;
        (mean_y - slope * mean_x, slope)
    } else {
        (0.0, mean_y / mean_x)
    };
    if per_row <= 0.0 {
        // latency not increasing in rows on this window: price everything
        // into the per-row term at the observed operating point
        overhead = 0.0;
        per_row = mean_y / mean_x;
    }
    if overhead < 0.0 {
        overhead = 0.0;
    }
    Some(LineFit { batch_overhead_s: overhead, per_row_s: per_row.max(1e-12), samples: n })
}

/// Blend a fitted line into the a-priori estimate with exponential
/// weight `α = 1 − exp(−samples / BLEND_TAU)`. Overhead blends linearly;
/// throughput blends in per-row-seconds space (the quantity the fit
/// actually measures). `setup_s` is construction-time and not observable
/// from batch samples, so the prior's value is kept.
pub fn blend(prior: &CostEstimate, fit: &LineFit) -> CostEstimate {
    let alpha = 1.0 - (-(fit.samples as f64) / BLEND_TAU).exp();
    let prior_per_row = 1.0 / prior.rows_per_s.max(1e-12);
    let per_row = (1.0 - alpha) * prior_per_row + alpha * fit.per_row_s;
    CostEstimate {
        setup_s: prior.setup_s,
        batch_overhead_s: (1.0 - alpha) * prior.batch_overhead_s
            + alpha * fit.batch_overhead_s,
        rows_per_s: 1.0 / per_row.max(1e-12),
    }
}

/// Fit + blend in one step: the calibrated estimate for `prior` given
/// the observed samples, or `None` when there is not enough signal yet.
pub fn calibrate(prior: &CostEstimate, samples: &[(f64, f64)]) -> Option<CostEstimate> {
    fit_line(samples).map(|fit| blend(prior, &fit))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth_samples(
        overhead: f64,
        rows_per_s: f64,
        sizes: &[usize],
        reps: usize,
    ) -> Vec<(f64, f64)> {
        let mut out = Vec::new();
        let mut rng = crate::util::Rng::new(7);
        for _ in 0..reps {
            for &rows in sizes {
                let exact = overhead + rows as f64 / rows_per_s;
                // ±1% deterministic multiplicative noise
                let noisy = exact * (1.0 + 0.02 * (rng.f64() - 0.5));
                out.push((rows as f64, noisy));
            }
        }
        out
    }

    #[test]
    fn fit_recovers_the_generating_line() {
        let (overhead, rate) = (4e-3, 1e5);
        let samples = synth_samples(overhead, rate, &[1, 8, 64, 256, 1024], 8);
        let fit = fit_line(&samples).expect("enough samples");
        assert!(
            (fit.batch_overhead_s - overhead).abs() / overhead < 0.1,
            "overhead {} vs {}",
            fit.batch_overhead_s,
            overhead
        );
        let fitted_rate = 1.0 / fit.per_row_s;
        assert!(
            (fitted_rate - rate).abs() / rate < 0.1,
            "rate {fitted_rate} vs {rate}"
        );
    }

    #[test]
    fn fit_guards_degenerate_inputs() {
        // below MIN_SAMPLES
        assert!(fit_line(&[(8.0, 1e-3); 3]).is_none());
        // one batch size only: through-origin fallback, exact there
        let fit = fit_line(&[(8.0, 2e-3); 6]).unwrap();
        assert_eq!(fit.batch_overhead_s, 0.0);
        assert!((fit.per_row_s - 2.5e-4).abs() < 1e-9);
        // latency *decreasing* in rows (pure noise): positive per-row cost
        let fit = fit_line(&[(1.0, 4e-3), (10.0, 3e-3), (100.0, 2e-3), (1000.0, 1e-3)]).unwrap();
        assert!(fit.per_row_s > 0.0);
        assert_eq!(fit.batch_overhead_s, 0.0);
    }

    #[test]
    fn blend_moves_from_prior_to_measurement_with_evidence() {
        let prior = CostEstimate { setup_s: 0.5, batch_overhead_s: 5e-3, rows_per_s: 1e4 };
        let fit = LineFit { batch_overhead_s: 1e-4, per_row_s: 1e-6, samples: 4 };
        let few = blend(&prior, &fit);
        let fit_many = LineFit { samples: 64, ..fit };
        let many = blend(&prior, &fit_many);
        // setup is never touched by batch samples
        assert_eq!(few.setup_s, prior.setup_s);
        // few samples: still close to the prior; many: close to the fit
        assert!(few.batch_overhead_s > many.batch_overhead_s);
        assert!(many.batch_overhead_s < 2e-4, "{}", many.batch_overhead_s);
        assert!(many.rows_per_s > 0.9e6, "{}", many.rows_per_s);
        assert!(few.rows_per_s < many.rows_per_s);
    }

    #[test]
    fn shard_throughputs_from_observations() {
        let mut obs = Observations::new();
        obs.record_shard(0, 100, 0.1); // 1000 rows/s
        obs.record_shard(0, 300, 0.3);
        obs.record_shard(2, 100, 1.0); // 100 rows/s
        obs.record_shard(3, 0, 0.0); // no signal → omitted
        let tputs = obs.shard_throughputs();
        assert_eq!(tputs.len(), 2);
        assert_eq!(tputs[0].0, 0);
        assert!((tputs[0].1 - 1000.0).abs() < 1e-6);
        assert_eq!(tputs[1].0, 2);
        assert!((tputs[1].1 - 100.0).abs() < 1e-6);
    }
}
