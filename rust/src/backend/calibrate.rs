//! Measured cost calibration: turn the `(rows, latency)` batch samples
//! the service records into [`CostEstimate`]s, replacing the planner's
//! a-priori constants with the machine's own numbers.
//!
//! Every backend's batch latency is modelled as the paper's two-term
//! line `latency(rows) = batch_overhead + rows · per_row`. Given enough
//! observed batches at varying sizes, ordinary least squares recovers
//! both terms directly. Two guards keep noisy telemetry from
//! destabilizing plans:
//!
//! - **degenerate sample sets** (all batches the same size, or a
//!   negative fitted slope) fall back to a through-origin fit, which is
//!   exact at the observed batch size and conservative elsewhere;
//! - **small sample sets** are blended with the a-priori estimate via
//!   an exponential weight `α = 1 − exp(−n / BLEND_TAU)`, so the first
//!   few (noisy) batches nudge the prior instead of replacing it, and
//!   the measurement only dominates once the evidence accumulates.
//!
//! [`Observations`] is the transport type between the layers: the
//! coordinator's metrics fill it from their per-backend / per-shard
//! sample rings, and `Planner::recalibrate` consumes it. It also
//! derives per-shard throughputs, which the sharded executor uses to
//! skew row-chunk sizes toward faster devices.

use std::collections::BTreeMap;

use crate::backend::planner::CostEstimate;

/// Fewest samples before a fit is attempted at all.
pub const MIN_SAMPLES: usize = 4;

/// Sample-count scale of the prior→measurement blend: at `n = BLEND_TAU`
/// the measurement carries `1 − e⁻¹ ≈ 63%` of the weight.
pub const BLEND_TAU: f64 = 8.0;

/// Observed `(rows, latency_s)` batch samples, keyed by backend name and
/// by device-shard index. Filled by `Metrics::observations()`; consumed
/// by `Planner::recalibrate` and `ShapBackend::set_shard_throughputs`.
///
/// Steady-state and first-batch samples are kept on separate lines:
/// the first batch after a backend (re)build pays warmup/prep that the
/// per-batch cost model must not absorb into its slope, and conversely
/// is exactly the signal that calibrates the one-time `setup_s` term.
#[derive(Clone, Debug, Default)]
pub struct Observations {
    pub per_backend: BTreeMap<String, Vec<(f64, f64)>>,
    /// first-batch (prep-inclusive) samples, one per backend (re)build
    pub per_backend_first: BTreeMap<String, Vec<(f64, f64)>>,
    pub per_shard: BTreeMap<usize, Vec<(f64, f64)>>,
}

impl Observations {
    pub fn new() -> Observations {
        Observations::default()
    }

    pub fn record_backend(&mut self, name: &str, rows: usize, latency_s: f64) {
        self.per_backend
            .entry(name.to_string())
            .or_default()
            .push((rows as f64, latency_s));
    }

    /// Record a first-batch (prep-inclusive) sample for `name`.
    pub fn record_backend_first(&mut self, name: &str, rows: usize, latency_s: f64) {
        self.per_backend_first
            .entry(name.to_string())
            .or_default()
            .push((rows as f64, latency_s));
    }

    pub fn record_shard(&mut self, shard: usize, rows: usize, latency_s: f64) {
        self.per_shard.entry(shard).or_default().push((rows as f64, latency_s));
    }

    /// Sustained throughput per shard, `(shard, rows/s)`: total observed
    /// rows over total observed wall time. Shards with no samples (or
    /// zero observed time) are omitted — the executor keeps its own
    /// estimate for those.
    pub fn shard_throughputs(&self) -> Vec<(usize, f64)> {
        self.per_shard
            .iter()
            .filter_map(|(&shard, samples)| {
                let rows: f64 = samples.iter().map(|s| s.0).sum();
                let secs: f64 = samples.iter().map(|s| s.1).sum();
                (secs > 0.0 && rows > 0.0).then_some((shard, rows / secs))
            })
            .collect()
    }
}

/// A fitted two-term latency line.
#[derive(Clone, Copy, Debug)]
pub struct LineFit {
    pub batch_overhead_s: f64,
    pub per_row_s: f64,
    /// samples the fit was computed from (drives the blend weight)
    pub samples: usize,
}

/// Least-squares fit of `latency = batch_overhead + rows · per_row` over
/// `(rows, latency_s)` samples. `None` below [`MIN_SAMPLES`]. Degenerate
/// inputs (a single batch size, or a non-positive fitted slope) fall
/// back to the through-origin line `latency = rows · (ȳ/x̄)`.
pub fn fit_line(samples: &[(f64, f64)]) -> Option<LineFit> {
    let n = samples.len();
    if n < MIN_SAMPLES {
        return None;
    }
    let nf = n as f64;
    let mean_x = samples.iter().map(|s| s.0).sum::<f64>() / nf;
    let mean_y = samples.iter().map(|s| s.1).sum::<f64>() / nf;
    if mean_x <= 0.0 || mean_y <= 0.0 {
        return None;
    }
    let var_x: f64 = samples.iter().map(|s| (s.0 - mean_x) * (s.0 - mean_x)).sum::<f64>() / nf;
    let cov: f64 =
        samples.iter().map(|s| (s.0 - mean_x) * (s.1 - mean_y)).sum::<f64>() / nf;
    let (mut overhead, mut per_row) = if var_x > 1e-12 {
        let slope = cov / var_x;
        (mean_y - slope * mean_x, slope)
    } else {
        (0.0, mean_y / mean_x)
    };
    if per_row <= 0.0 {
        // latency not increasing in rows on this window: price everything
        // into the per-row term at the observed operating point
        overhead = 0.0;
        per_row = mean_y / mean_x;
    }
    if overhead < 0.0 {
        overhead = 0.0;
    }
    Some(LineFit { batch_overhead_s: overhead, per_row_s: per_row.max(1e-12), samples: n })
}

/// Blend a fitted line into the a-priori estimate with exponential
/// weight `α = 1 − exp(−samples / BLEND_TAU)`. Overhead blends linearly;
/// throughput blends in per-row-seconds space (the quantity the fit
/// actually measures). `setup_s` is construction-time and not observable
/// from batch samples, so the prior's value is kept.
pub fn blend(prior: &CostEstimate, fit: &LineFit) -> CostEstimate {
    let alpha = 1.0 - (-(fit.samples as f64) / BLEND_TAU).exp();
    let prior_per_row = 1.0 / prior.rows_per_s.max(1e-12);
    let per_row = (1.0 - alpha) * prior_per_row + alpha * fit.per_row_s;
    CostEstimate {
        setup_s: prior.setup_s,
        batch_overhead_s: (1.0 - alpha) * prior.batch_overhead_s
            + alpha * fit.batch_overhead_s,
        rows_per_s: 1.0 / per_row.max(1e-12),
    }
}

/// Fit + blend in one step: the calibrated estimate for `prior` given
/// the observed samples, or `None` when there is not enough signal yet.
pub fn calibrate(prior: &CostEstimate, samples: &[(f64, f64)]) -> Option<CostEstimate> {
    fit_line(samples).map(|fit| blend(prior, &fit))
}

/// Calibrate the one-time `setup_s` term from first-batch samples: each
/// first batch's excess over the steady-state line is an observation of
/// the prep cost, averaged and blended against the prior's `setup_s`
/// with the same exponential weight as the line fit. First batches are
/// rare (one per rebuild), so a single sample already counts — warmup
/// is observed directly, not inferred from a spread of batch sizes.
pub fn calibrate_setup(
    prior: &CostEstimate,
    steady: &CostEstimate,
    first: &[(f64, f64)],
) -> Option<f64> {
    if first.is_empty() {
        return None;
    }
    let mut excess = 0.0f64;
    for &(rows, latency) in first {
        let predicted = steady.batch_overhead_s + rows / steady.rows_per_s.max(1e-12);
        excess += (latency - predicted).max(0.0);
    }
    let fitted = excess / first.len() as f64;
    let alpha = 1.0 - (-(first.len() as f64) / BLEND_TAU).exp();
    Some((1.0 - alpha) * prior.setup_s + alpha * fitted)
}

// ---------------------------------------------------------------------------
// persistence: calibrated estimates survive process restarts
// ---------------------------------------------------------------------------

/// File format version for persisted calibration state.
const CALIBRATION_VERSION: usize = 1;

/// Serialize calibrated estimates (`backend name → cost line + sample
/// count`) as JSON next to the model artifact, so a restarted service
/// can plan from measurements immediately (`Planner::seed_calibration`).
/// The write is tmp+rename, so a crash mid-save can never leave a torn
/// file where a good one stood (the executor saves while serving).
pub fn save_calibration(
    path: &std::path::Path,
    entries: &[(String, CostEstimate, usize)],
) -> crate::util::error::Result<()> {
    use crate::util::Json;
    let backends = Json::Obj(
        entries
            .iter()
            .map(|(name, est, samples)| {
                (
                    name.clone(),
                    Json::obj(vec![
                        ("setup_s", Json::from(est.setup_s)),
                        ("batch_overhead_s", Json::from(est.batch_overhead_s)),
                        ("rows_per_s", Json::from(est.rows_per_s)),
                        ("samples", Json::from(*samples)),
                    ]),
                )
            })
            .collect(),
    );
    let doc = Json::obj(vec![
        ("version", Json::from(CALIBRATION_VERSION)),
        ("backends", backends),
    ]);
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, doc.to_string_pretty())
        .map_err(|e| crate::anyhow!("writing calibration {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| crate::anyhow!("publishing calibration {}: {e}", path.display()))
}

/// Load persisted calibration state written by [`save_calibration`].
pub fn load_calibration(
    path: &std::path::Path,
) -> crate::util::error::Result<Vec<(String, CostEstimate, usize)>> {
    use crate::util::error::Context;
    use crate::util::Json;
    let text = std::fs::read_to_string(path)
        .map_err(|e| crate::anyhow!("reading calibration {}: {e}", path.display()))?;
    let doc = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
    let version = doc.get("version")?.as_usize()?;
    if version != CALIBRATION_VERSION {
        crate::bail!("unsupported calibration version {version}");
    }
    let Json::Obj(backends) = doc.get("backends")? else {
        crate::bail!("calibration 'backends' must be an object");
    };
    let mut out = Vec::with_capacity(backends.len());
    for (name, entry) in backends {
        let est = CostEstimate {
            setup_s: entry.get("setup_s")?.as_f64()?,
            batch_overhead_s: entry.get("batch_overhead_s")?.as_f64()?,
            rows_per_s: entry.get("rows_per_s")?.as_f64()?,
        };
        if !est.setup_s.is_finite()
            || !est.batch_overhead_s.is_finite()
            || !est.rows_per_s.is_finite()
            || est.rows_per_s <= 0.0
        {
            crate::bail!("calibration entry '{name}' has non-finite constants");
        }
        out.push((name.clone(), est, entry.get("samples")?.as_usize()?));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth_samples(
        overhead: f64,
        rows_per_s: f64,
        sizes: &[usize],
        reps: usize,
    ) -> Vec<(f64, f64)> {
        let mut out = Vec::new();
        let mut rng = crate::util::Rng::new(7);
        for _ in 0..reps {
            for &rows in sizes {
                let exact = overhead + rows as f64 / rows_per_s;
                // ±1% deterministic multiplicative noise
                let noisy = exact * (1.0 + 0.02 * (rng.f64() - 0.5));
                out.push((rows as f64, noisy));
            }
        }
        out
    }

    #[test]
    fn fit_recovers_the_generating_line() {
        let (overhead, rate) = (4e-3, 1e5);
        let samples = synth_samples(overhead, rate, &[1, 8, 64, 256, 1024], 8);
        let fit = fit_line(&samples).expect("enough samples");
        assert!(
            (fit.batch_overhead_s - overhead).abs() / overhead < 0.1,
            "overhead {} vs {}",
            fit.batch_overhead_s,
            overhead
        );
        let fitted_rate = 1.0 / fit.per_row_s;
        assert!(
            (fitted_rate - rate).abs() / rate < 0.1,
            "rate {fitted_rate} vs {rate}"
        );
    }

    #[test]
    fn fit_guards_degenerate_inputs() {
        // below MIN_SAMPLES
        assert!(fit_line(&[(8.0, 1e-3); 3]).is_none());
        // one batch size only: through-origin fallback, exact there
        let fit = fit_line(&[(8.0, 2e-3); 6]).unwrap();
        assert_eq!(fit.batch_overhead_s, 0.0);
        assert!((fit.per_row_s - 2.5e-4).abs() < 1e-9);
        // latency *decreasing* in rows (pure noise): positive per-row cost
        let fit = fit_line(&[(1.0, 4e-3), (10.0, 3e-3), (100.0, 2e-3), (1000.0, 1e-3)]).unwrap();
        assert!(fit.per_row_s > 0.0);
        assert_eq!(fit.batch_overhead_s, 0.0);
    }

    #[test]
    fn blend_moves_from_prior_to_measurement_with_evidence() {
        let prior = CostEstimate { setup_s: 0.5, batch_overhead_s: 5e-3, rows_per_s: 1e4 };
        let fit = LineFit { batch_overhead_s: 1e-4, per_row_s: 1e-6, samples: 4 };
        let few = blend(&prior, &fit);
        let fit_many = LineFit { samples: 64, ..fit };
        let many = blend(&prior, &fit_many);
        // setup is never touched by batch samples
        assert_eq!(few.setup_s, prior.setup_s);
        // few samples: still close to the prior; many: close to the fit
        assert!(few.batch_overhead_s > many.batch_overhead_s);
        assert!(many.batch_overhead_s < 2e-4, "{}", many.batch_overhead_s);
        assert!(many.rows_per_s > 0.9e6, "{}", many.rows_per_s);
        assert!(few.rows_per_s < many.rows_per_s);
    }

    #[test]
    fn setup_calibration_measures_first_batch_excess() {
        let prior = CostEstimate { setup_s: 0.5, batch_overhead_s: 1e-3, rows_per_s: 1e5 };
        let steady = CostEstimate { setup_s: 0.5, batch_overhead_s: 1e-3, rows_per_s: 1e5 };
        // no first batches → nothing to say
        assert!(calibrate_setup(&prior, &steady, &[]).is_none());
        // one first batch 20ms over the steady line: blended toward it
        let rows = 100.0;
        let base = steady.batch_overhead_s + rows / steady.rows_per_s;
        let one = calibrate_setup(&prior, &steady, &[(rows, base + 0.02)]).unwrap();
        assert!(one < prior.setup_s && one > 0.02, "one sample nudges: {one}");
        // many consistent first batches: the measurement dominates
        let many: Vec<(f64, f64)> = (0..32).map(|_| (rows, base + 0.02)).collect();
        let dominated = calibrate_setup(&prior, &steady, &many).unwrap();
        assert!((dominated - 0.02).abs() < 0.02, "{dominated}");
        // first batch *faster* than steady (noise): clamps at zero excess
        let fast = calibrate_setup(&prior, &steady, &[(rows, base / 2.0); 32]).unwrap();
        assert!(fast < 0.05, "{fast}");
    }

    #[test]
    fn calibration_roundtrips_through_disk() {
        let dir = std::env::temp_dir().join(format!("gts_calib_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.calib.json");
        let entries = vec![
            (
                "host".to_string(),
                CostEstimate { setup_s: 0.01, batch_overhead_s: 2e-5, rows_per_s: 123456.0 },
                40,
            ),
            (
                "cpu".to_string(),
                CostEstimate { setup_s: 0.0, batch_overhead_s: 0.0, rows_per_s: 9999.5 },
                0,
            ),
        ];
        save_calibration(&path, &entries).unwrap();
        let back = load_calibration(&path).unwrap();
        assert_eq!(back.len(), entries.len());
        for (name, est, n) in &entries {
            let (_, got, gn) =
                back.iter().find(|(b, _, _)| b == name).expect("entry survives");
            assert_eq!(gn, n);
            assert!((got.setup_s - est.setup_s).abs() < 1e-12);
            assert!((got.batch_overhead_s - est.batch_overhead_s).abs() < 1e-12);
            assert!((got.rows_per_s - est.rows_per_s).abs() < 1e-6);
        }
        // corrupt files are rejected, not half-loaded
        std::fs::write(&path, "{not json").unwrap();
        assert!(load_calibration(&path).is_err());
        std::fs::write(&path, r#"{"version": 99, "backends": {}}"#).unwrap();
        assert!(load_calibration(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_throughputs_from_observations() {
        let mut obs = Observations::new();
        obs.record_shard(0, 100, 0.1); // 1000 rows/s
        obs.record_shard(0, 300, 0.3);
        obs.record_shard(2, 100, 1.0); // 100 rows/s
        obs.record_shard(3, 0, 0.0); // no signal → omitted
        let tputs = obs.shard_throughputs();
        assert_eq!(tputs.len(), 2);
        assert_eq!(tputs[0].0, 0);
        assert!((tputs[0].1 - 1000.0).abs() < 1e-6);
        assert_eq!(tputs[1].0, 2);
        assert!((tputs[1].1 - 100.0).abs() < 1e-6);
    }
}
