//! Bin packing of path sub-problems onto 32-lane "warps" (paper §3.3).
//!
//! Heuristics: the `None` baseline (one item per bin), Next-Fit O(n),
//! First-Fit-Decreasing and Best-Fit-Decreasing O(n log n). FFD uses a
//! max-residual segment tree packed into an array (Johnson 1974 — the
//! structure the paper credits for FFD's cache efficiency); BFD uses an
//! ordered multiset (`BTreeMap`), mirroring the paper's `std::set`
//! implementation note.

use std::collections::BTreeMap;

/// SIMT lane width — maximum path length, and bin capacity.
pub const LANES: usize = 32;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Packing {
    None,
    NextFit,
    FirstFitDecreasing,
    BestFitDecreasing,
}

impl Packing {
    pub const ALL: [Packing; 4] = [
        Packing::None,
        Packing::NextFit,
        Packing::FirstFitDecreasing,
        Packing::BestFitDecreasing,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Packing::None => "none",
            Packing::NextFit => "nf",
            Packing::FirstFitDecreasing => "ffd",
            Packing::BestFitDecreasing => "bfd",
        }
    }

    pub fn parse(s: &str) -> Option<Packing> {
        Some(match s {
            "none" => Packing::None,
            "nf" => Packing::NextFit,
            "ffd" => Packing::FirstFitDecreasing,
            "bfd" => Packing::BestFitDecreasing,
            _ => return None,
        })
    }
}

/// Result: `bins[b]` lists item indices; utilisation = Σsize / (B·LANES).
#[derive(Clone, Debug)]
pub struct PackResult {
    pub bins: Vec<Vec<u32>>,
    pub utilisation: f64,
}

pub fn pack(sizes: &[usize], algorithm: Packing, capacity: usize) -> PackResult {
    debug_assert!(sizes.iter().all(|&s| 1 <= s && s <= capacity));
    let bins = match algorithm {
        Packing::None => sizes.iter().enumerate().map(|(i, _)| vec![i as u32]).collect(),
        Packing::NextFit => next_fit(sizes, capacity),
        Packing::FirstFitDecreasing => ffd(sizes, capacity),
        Packing::BestFitDecreasing => bfd(sizes, capacity),
    };
    let total: usize = sizes.iter().sum();
    let used = bins.len() * capacity;
    PackResult {
        utilisation: if used == 0 { 1.0 } else { total as f64 / used as f64 },
        bins,
    }
}

fn next_fit(sizes: &[usize], capacity: usize) -> Vec<Vec<u32>> {
    let mut bins = Vec::new();
    let mut cur: Vec<u32> = Vec::new();
    let mut used = 0usize;
    for (i, &s) in sizes.iter().enumerate() {
        if used + s > capacity {
            bins.push(std::mem::take(&mut cur));
            used = 0;
        }
        cur.push(i as u32);
        used += s;
    }
    if !cur.is_empty() {
        bins.push(cur);
    }
    bins
}

/// Sort indices by decreasing size (counting sort — sizes ≤ capacity).
fn decreasing_order(sizes: &[usize], capacity: usize) -> Vec<u32> {
    let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); capacity + 1];
    for (i, &s) in sizes.iter().enumerate() {
        buckets[s].push(i as u32);
    }
    let mut order = Vec::with_capacity(sizes.len());
    for s in (1..=capacity).rev() {
        order.extend_from_slice(&buckets[s]);
    }
    order
}

/// Segment tree over bin residuals supporting "first bin with residual ≥ s"
/// in O(log n). Bins are appended lazily; the tree doubles as needed.
struct FirstFitTree {
    /// max residual in each subtree; 1-indexed heap layout
    tree: Vec<usize>,
    /// number of leaf slots
    cap: usize,
    /// residual per open bin
    residual: Vec<usize>,
    bin_capacity: usize,
}

impl FirstFitTree {
    fn new(bin_capacity: usize) -> Self {
        FirstFitTree { tree: vec![0; 2], cap: 1, residual: Vec::new(), bin_capacity }
    }

    fn grow(&mut self) {
        let old_cap = self.cap;
        self.cap *= 2;
        let mut t = vec![0usize; 2 * self.cap];
        t[self.cap..self.cap + old_cap].copy_from_slice(&self.tree[old_cap..2 * old_cap]);
        for i in (1..self.cap).rev() {
            t[i] = t[2 * i].max(t[2 * i + 1]);
        }
        self.tree = t;
    }

    fn set(&mut self, idx: usize, val: usize) {
        let mut i = self.cap + idx;
        self.tree[i] = val;
        while i > 1 {
            i /= 2;
            self.tree[i] = self.tree[2 * i].max(self.tree[2 * i + 1]);
        }
    }

    /// First (lowest-index) bin with residual ≥ s, opening one if needed.
    fn place(&mut self, s: usize) -> usize {
        if self.tree[1] >= s {
            let mut i = 1;
            while i < self.cap {
                i = if self.tree[2 * i] >= s { 2 * i } else { 2 * i + 1 };
            }
            let idx = i - self.cap;
            self.residual[idx] -= s;
            self.set(idx, self.residual[idx]);
            return idx;
        }
        // open a new bin
        let idx = self.residual.len();
        if idx >= self.cap {
            self.grow();
        }
        self.residual.push(self.bin_capacity - s);
        self.set(idx, self.bin_capacity - s);
        idx
    }
}

fn ffd(sizes: &[usize], capacity: usize) -> Vec<Vec<u32>> {
    let order = decreasing_order(sizes, capacity);
    let mut tree = FirstFitTree::new(capacity);
    let mut bins: Vec<Vec<u32>> = Vec::new();
    for i in order {
        let b = tree.place(sizes[i as usize]);
        if b == bins.len() {
            bins.push(Vec::new());
        }
        bins[b].push(i);
    }
    bins
}

fn bfd(sizes: &[usize], capacity: usize) -> Vec<Vec<u32>> {
    let order = decreasing_order(sizes, capacity);
    // residual -> stack of bin ids with that residual (ordered multiset)
    let mut by_residual: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    let mut bins: Vec<Vec<u32>> = Vec::new();
    let mut residuals: Vec<usize> = Vec::new();
    for i in order {
        let s = sizes[i as usize];
        // feasible bin with the smallest residual ≥ s
        let found = by_residual.range_mut(s..).next().map(|(r, v)| (*r, v.pop().unwrap()));
        let b = match found {
            Some((r, b)) => {
                if by_residual.get(&r).is_some_and(|v| v.is_empty()) {
                    by_residual.remove(&r);
                }
                residuals[b] -= s;
                b
            }
            None => {
                bins.push(Vec::new());
                residuals.push(capacity - s);
                bins.len() - 1
            }
        };
        bins[b].push(i);
        if residuals[b] > 0 {
            by_residual.entry(residuals[b]).or_default().push(b);
        }
    }
    bins
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_sizes(seed: u64, n: usize) -> Vec<usize> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| 1 + rng.below(LANES as u64) as usize).collect()
    }

    fn check_valid(sizes: &[usize], res: &PackResult, capacity: usize) {
        let mut seen = vec![false; sizes.len()];
        for b in &res.bins {
            let mut used = 0;
            for &i in b {
                assert!(!seen[i as usize], "item packed twice");
                seen[i as usize] = true;
                used += sizes[i as usize];
            }
            assert!(used <= capacity);
        }
        assert!(seen.iter().all(|&s| s), "item dropped");
    }

    #[test]
    fn all_algorithms_valid() {
        let sizes = random_sizes(1, 500);
        for alg in Packing::ALL {
            let res = pack(&sizes, alg, LANES);
            check_valid(&sizes, &res, LANES);
        }
    }

    #[test]
    fn quality_ordering_matches_table5() {
        for seed in 0..5 {
            let sizes = random_sizes(seed, 800);
            let n_none = pack(&sizes, Packing::None, LANES).bins.len();
            let n_nf = pack(&sizes, Packing::NextFit, LANES).bins.len();
            let n_ffd = pack(&sizes, Packing::FirstFitDecreasing, LANES).bins.len();
            let n_bfd = pack(&sizes, Packing::BestFitDecreasing, LANES).bins.len();
            assert!(n_ffd <= n_nf && n_nf <= n_none);
            assert!(n_bfd <= n_nf);
        }
    }

    #[test]
    fn approximation_bounds() {
        let sizes = random_sizes(7, 1000);
        let lower = sizes.iter().sum::<usize>().div_ceil(LANES);
        assert!(pack(&sizes, Packing::NextFit, LANES).bins.len() <= 2 * lower);
        let ffd_bins = pack(&sizes, Packing::FirstFitDecreasing, LANES).bins.len();
        assert!(ffd_bins as f64 <= 1.222 * lower as f64 + 1.0);
        let bfd_bins = pack(&sizes, Packing::BestFitDecreasing, LANES).bins.len();
        assert!(bfd_bins as f64 <= 1.222 * lower as f64 + 1.0);
    }

    #[test]
    fn utilisation_formula() {
        let sizes = vec![16, 16, 16, 16];
        let res = pack(&sizes, Packing::NextFit, LANES);
        assert_eq!(res.bins.len(), 2);
        assert!((res.utilisation - 1.0).abs() < 1e-12);
        let res = pack(&sizes, Packing::None, LANES);
        assert!((res.utilisation - 0.5).abs() < 1e-12);
    }

    #[test]
    fn bfd_picks_tightest_bin() {
        // After 20 and 18 open two bins, 12 must go to the 20-bin
        // (residual 12) not the 18-bin (residual 14).
        let sizes = vec![20, 18, 12, 10];
        let res = pack(&sizes, Packing::BestFitDecreasing, LANES);
        let mut bins: Vec<Vec<u32>> = res.bins.iter().map(|b| {
            let mut s = b.clone();
            s.sort_unstable();
            s
        }).collect();
        bins.sort();
        assert_eq!(bins, vec![vec![0, 2], vec![1, 3]]);
    }

    #[test]
    fn ffd_takes_first_feasible_bin() {
        // 12 fits the first-opened bin (residual 12 after 20) in FFD.
        let sizes = vec![20, 17, 12];
        let res = pack(&sizes, Packing::FirstFitDecreasing, LANES);
        assert_eq!(res.bins.len(), 2);
        assert!(res.bins[0].contains(&0) && res.bins[0].contains(&2));
    }

    #[test]
    fn ffd_equals_bfd_bin_count_on_typical_inputs() {
        // the paper observes identical utilisation on all its models
        for seed in 0..4 {
            let sizes = random_sizes(100 + seed, 2000);
            let f = pack(&sizes, Packing::FirstFitDecreasing, LANES).bins.len();
            let b = pack(&sizes, Packing::BestFitDecreasing, LANES).bins.len();
            assert_eq!(f, b);
        }
    }

    #[test]
    fn segment_tree_grows() {
        // force many bins to exercise grow()
        let sizes = vec![LANES; 300];
        let res = pack(&sizes, Packing::FirstFitDecreasing, LANES);
        assert_eq!(res.bins.len(), 300);
    }

    #[test]
    fn empty_input() {
        let res = pack(&[], Packing::BestFitDecreasing, LANES);
        assert!(res.bins.is_empty());
        assert_eq!(res.utilisation, 1.0);
    }
}
