//! Linear TreeShap (Bi et al., arXiv 2209.08192): exact φ in time
//! **linear in tree size** via per-tree polynomial summaries, instead of
//! the recursive algorithm's O(L·D²) EXTEND/UNWIND or the packed DP's
//! per-path quadratic unwind.
//!
//! ## The polynomial view
//!
//! For a leaf whose merged path (duplicates merged as in
//! [`crate::shap::path`]) carries unique features `S` with activation
//! indicators `õ_g ∈ {0,1}` and cover ratios `z̃_g`, the recursive
//! algorithm's per-leaf contribution to feature `f` is
//!
//! ```text
//! Δφ_f = (õ_f − z̃_f) · Ψ_{m−1}( v · Π_{g∈S∖f} (õ_g·y + z̃_g) ),  m = |S|
//! ```
//!
//! where `Ψ_d(Σ_k c_k y^k) = Σ_k c_k · k!(d−k)!/(d+1)!` sums the
//! Shapley weights. Since `k!(d−k)!/(d+1)! = ∫₀¹ u^k(1−u)^{d−k} du`,
//! substituting `s = 1−u` gives the integral form
//!
//! ```text
//! Ψ_d(p) = ∫₀¹ s^d · p((1−s)/s) ds
//! ```
//!
//! whose integrand is a degree-`d` polynomial in `s` — evaluated
//! **exactly** by an N-point Gauss–Legendre rule on (0,1) for every
//! `d ≤ N−1`. Polynomials are therefore represented by their values at
//! the interpolation points `y_j = (1−s_j)/s_j`, and `Ψ_d` becomes an
//! inner product with the positive weights `ω_d[j] = λ_j·s_j^d`. (A
//! monomial-basis Vandermonde solve at the same points would be
//! catastrophically ill-conditioned by depth ~12; the quadrature form
//! never inverts anything.)
//!
//! Per-leaf degrees differ, so subtree sums are normalized with the
//! exact identity `Ψ_{d+1}((y+1)·p) = Ψ_d(p)` — pointwise,
//! `y_j + 1 = 1/s_j`, so padding a summary by `(y+1)^Δ` just shifts the
//! `ω` row in use.
//!
//! ## Per-row sweep
//!
//! One DFS per (row, tree): descending an edge multiplies the running
//! path product `C` by the edge factor `(õ·y + z̃)` (replacing a
//! repeated feature's previous merged factor); a leaf emits `v·C`;
//! unwinding folds child summaries into the parent padded to a common
//! degree (`height` below) and accumulates each edge feature's φ via
//! one `ω` inner product. A feature repeated along a path adds its
//! fully-merged term at each occurrence and subtracts the
//! ancestor-merged term recorded at descent — the terms telescope so
//! only the deepest occurrence's correct contribution survives.
//! Everything is O(nodes · N) per row per tree.
//!
//! Activation/NaN semantics mirror `shap::treeshap` exactly (the parity
//! oracle): the hot child is `left` iff `!x.is_nan() && x < threshold`.

use crate::gbdt::{Model, Tree};
use crate::parallel;
use crate::shap::path::expected_values;

/// Row-independent summary of one tree: the flattened node arrays the
/// per-row sweep walks, per-edge cover ratios, and per-node `height` —
/// the polynomial degree of the node's subtree summary.
pub struct LinearTree {
    feature: Vec<i32>,
    threshold: Vec<f32>,
    left: Vec<i32>,
    right: Vec<i32>,
    value: Vec<f32>,
    /// cover ratio of this node vs its parent (root: 1.0)
    zfrac: Vec<f64>,
    /// max over leaves below of the unique-feature count of the full
    /// root→leaf path; equals that count at leaves
    height: Vec<u32>,
}

impl LinearTree {
    fn is_leaf(&self, i: usize) -> bool {
        self.left[i] < 0
    }

    /// Single-leaf trees carry no edges: they contribute only to the
    /// expected value and are skipped by the sweep.
    fn is_stump(&self) -> bool {
        self.is_leaf(0)
    }
}

/// The precomputed Linear TreeShap state of one model: per-tree
/// summaries plus the shared interpolation grid (`N` Gauss–Legendre
/// points sized to the deepest unique path in the ensemble).
pub struct LinearModel {
    trees: Vec<LinearTree>,
    tree_group: Vec<usize>,
    pub num_features: usize,
    pub num_groups: usize,
    /// interpolation points / quadrature size
    n: usize,
    /// deepest node depth across trees (scratch sizing)
    max_node_depth: usize,
    /// interpolation points y_j = (1−s_j)/s_j
    y: Vec<f64>,
    /// ω table, row-major: omega[d·n + j] = λ_j·s_j^d, d = 0..n
    omega: Vec<f64>,
    /// padding powers, row-major: pad[k·n + j] = (y_j+1)^k, k = 0..=n
    pad: Vec<f64>,
    /// φ base values per group (E[f] incl. base_score)
    expected: Vec<f64>,
}

impl LinearModel {
    /// Number of interpolation points (= deepest unique path length).
    pub fn points(&self) -> usize {
        self.n
    }

    pub fn expected_values(&self) -> &[f64] {
        &self.expected
    }
}

/// Evaluate the Legendre polynomial `P_n` and its derivative at `x`.
fn legendre(n: usize, x: f64) -> (f64, f64) {
    if n == 0 {
        return (1.0, 0.0);
    }
    let (mut p0, mut p1) = (1.0, x);
    for k in 2..=n {
        let p2 = ((2 * k - 1) as f64 * x * p1 - (k - 1) as f64 * p0) / k as f64;
        p0 = p1;
        p1 = p2;
    }
    // (x² − 1)·P'_n = n·(x·P_n − P_{n−1}); roots are interior so x ≠ ±1
    let dp = n as f64 * (x * p1 - p0) / (x * x - 1.0);
    (p1, dp)
}

/// N-point Gauss–Legendre nodes and weights on (0, 1), exact for
/// polynomials of degree ≤ 2N−1. Newton iteration from the classic
/// Chebyshev initial guess; no external dependencies.
pub fn gauss_legendre_01(n: usize) -> (Vec<f64>, Vec<f64>) {
    let mut s = vec![0.0f64; n];
    let mut w = vec![0.0f64; n];
    for (i, (si, wi)) in s.iter_mut().zip(w.iter_mut()).enumerate() {
        let mut t = (std::f64::consts::PI * (i as f64 + 0.75) / (n as f64 + 0.5)).cos();
        for _ in 0..64 {
            let (p, dp) = legendre(n, t);
            let dt = p / dp;
            t -= dt;
            if dt.abs() < 1e-16 {
                break;
            }
        }
        let (_, dp) = legendre(n, t);
        // map (−1,1) → (0,1): node (t+1)/2, weight 2/((1−t²)dp²) halved
        *si = 0.5 * (t + 1.0);
        *wi = 1.0 / ((1.0 - t * t) * dp * dp);
    }
    (s, w)
}

/// Per-node `height`: the unique-feature count of the deepest full
/// root→leaf path through each node. `counts` tracks occurrences of
/// each feature on the current path so repeats don't inflate the count.
fn heights(t: &Tree, node: usize, q: u32, counts: &mut [u32], out: &mut [u32]) -> u32 {
    if t.is_leaf(node) {
        out[node] = q;
        return q;
    }
    let f = t.feature[node] as usize;
    let q2 = q + u32::from(counts[f] == 0);
    counts[f] += 1;
    let hl = heights(t, t.left[node] as usize, q2, counts, out);
    let hr = heights(t, t.right[node] as usize, q2, counts, out);
    counts[f] -= 1;
    out[node] = hl.max(hr);
    out[node]
}

fn summarize_tree(t: &Tree, num_features: usize) -> LinearTree {
    let n = t.num_nodes();
    let mut zfrac = vec![1.0f64; n];
    for i in 0..n {
        if !t.is_leaf(i) {
            let c = f64::from(t.cover[i]);
            let (l, r) = (t.left[i] as usize, t.right[i] as usize);
            zfrac[l] = f64::from(t.cover[l]) / c;
            zfrac[r] = f64::from(t.cover[r]) / c;
        }
    }
    let mut height = vec![0u32; n];
    let mut counts = vec![0u32; num_features];
    heights(t, 0, 0, &mut counts, &mut height);
    LinearTree {
        feature: t.feature.clone(),
        threshold: t.threshold.clone(),
        left: t.left.clone(),
        right: t.right.clone(),
        value: t.value.clone(),
        zfrac,
        height,
    }
}

/// Build the Linear TreeShap summary of `model` with the φ base values
/// supplied by the caller (the prepared-model cache passes its cached
/// expectation so cached and uncached builds agree bit-for-bit).
pub fn summarize_model_with_expected(model: &Model, expected: &[f64]) -> LinearModel {
    let trees: Vec<LinearTree> = model
        .trees
        .iter()
        .map(|t| summarize_tree(t, model.num_features))
        .collect();
    let n = trees.iter().map(|t| t.height[0] as usize).max().unwrap_or(0).max(1);
    let (s, lambda) = gauss_legendre_01(n);
    let y: Vec<f64> = s.iter().map(|&sj| (1.0 - sj) / sj).collect();
    // ω rows: omega[d][j] = λ_j·s_j^d — all positive, magnitudes ≤ λ_j
    let mut omega = vec![0.0f64; n * n];
    for j in 0..n {
        let mut p = lambda[j];
        for d in 0..n {
            omega[d * n + j] = p;
            p *= s[j];
        }
    }
    // padding powers (y_j+1)^k for degree normalization up the tree
    let mut pad = vec![0.0f64; (n + 1) * n];
    for j in 0..n {
        let mut p = 1.0f64;
        for k in 0..=n {
            pad[k * n + j] = p;
            p *= y[j] + 1.0;
        }
    }
    LinearModel {
        max_node_depth: model.max_depth(),
        trees,
        tree_group: model.tree_group.clone(),
        num_features: model.num_features,
        num_groups: model.num_groups,
        n,
        y,
        omega,
        pad,
        expected: expected.to_vec(),
    }
}

/// As [`summarize_model_with_expected`], computing the base values from
/// the model (standalone entry point for tests and one-off callers).
pub fn summarize_model(model: &Model) -> LinearModel {
    summarize_model_with_expected(model, &expected_values(model))
}

/// Per-thread scratch for the sweep: the running path product `C`, one
/// subtree-summary buffer per tree depth, and the per-feature merged
/// `(o, z)` state of the current path (undone on unwind, so it stays
/// clean across trees and rows).
struct Scratch {
    c: Vec<f64>,
    bufs: Vec<Vec<f64>>,
    feat: Vec<(f64, f64, bool)>,
}

impl Scratch {
    fn new(lm: &LinearModel) -> Scratch {
        Scratch {
            c: vec![1.0; lm.n],
            bufs: vec![vec![0.0; lm.n]; lm.max_node_depth + 2],
            feat: vec![(1.0, 1.0, false); lm.num_features],
        }
    }
}

/// One DFS node visit: fills `scratch.bufs[depth]` with the node's
/// degree-`height[node]` subtree summary and accumulates φ for every
/// edge feature unwound beneath it.
fn walk(
    lt: &LinearTree,
    lm: &LinearModel,
    x: &[f32],
    node: usize,
    depth: usize,
    scratch: &mut Scratch,
    phi: &mut [f64],
) {
    let n = lm.n;
    if lt.is_leaf(node) {
        let v = f64::from(lt.value[node]);
        let buf = &mut scratch.bufs[depth];
        for j in 0..n {
            buf[j] = v * scratch.c[j];
        }
        return;
    }
    scratch.bufs[depth][..n].fill(0.0);
    let f = lt.feature[node] as usize;
    let xv = x[f];
    let hot_left = !xv.is_nan() && xv < lt.threshold[node];
    let hn = lt.height[node] as usize;
    let kids = [(lt.left[node] as usize, hot_left), (lt.right[node] as usize, !hot_left)];
    for (child, hot) in kids {
        let oe = f64::from(u8::from(hot));
        let ze = lt.zfrac[child];
        let (ob, zb, present) = scratch.feat[f];
        // merged values over every occurrence of f down to this edge
        let (om, zm) = if present { (ob * oe, zb * ze) } else { (oe, ze) };
        // descend: swap f's factor in the path product (covers are
        // positive, so o·y + z > 0 and the division is safe)
        if present {
            for j in 0..n {
                scratch.c[j] *= (om * lm.y[j] + zm) / (ob * lm.y[j] + zb);
            }
        } else {
            for j in 0..n {
                scratch.c[j] *= om * lm.y[j] + zm;
            }
        }
        scratch.feat[f] = (om, zm, true);
        walk(lt, lm, x, child, depth + 1, scratch, phi);
        // unwind: the child summary (degree h_c) yields this edge's φ
        // share via one ω inner product; a repeated feature also
        // subtracts the ancestor-merged term so occurrences telescope
        let hc = lt.height[child] as usize;
        let (head, tail) = scratch.bufs.split_at_mut(depth + 1);
        let acc = &mut head[depth];
        let child_buf = &tail[0];
        let w = &lm.omega[(hc - 1) * n..hc * n];
        let mut add = 0.0f64;
        for j in 0..n {
            add += child_buf[j] / (om * lm.y[j] + zm) * w[j];
        }
        phi[f] += (om - zm) * add;
        if present {
            let mut sub = 0.0f64;
            for j in 0..n {
                sub += child_buf[j] / (ob * lm.y[j] + zb) * w[j];
            }
            phi[f] -= (ob - zb) * sub;
        }
        // fold the child into this node's summary at degree h_n
        let padrow = &lm.pad[(hn - hc) * n..(hn - hc + 1) * n];
        for j in 0..n {
            acc[j] += child_buf[j] * padrow[j];
        }
        // restore path state for the sibling
        scratch.feat[f] = (ob, zb, present);
        if present {
            for j in 0..n {
                scratch.c[j] *= (ob * lm.y[j] + zb) / (om * lm.y[j] + zm);
            }
        } else {
            for j in 0..n {
                scratch.c[j] /= om * lm.y[j] + zm;
            }
        }
    }
}

/// SHAP values for a batch through the linear kernel: output
/// `[rows × groups × (M+1)]` row-major, base value E[f] in slot M —
/// the same layout as `treeshap::shap_values`.
pub fn shap_values(lm: &LinearModel, x: &[f32], rows: usize, threads: usize) -> Vec<f32> {
    let m = lm.num_features;
    let groups = lm.num_groups;
    let stride = groups * (m + 1);
    let mut out = vec![0.0f32; rows * stride];
    parallel::parallel_for_rows(threads, &mut out, stride, 8, |range, chunk| {
        let mut scratch = Scratch::new(lm);
        let mut phis = vec![0.0f64; stride];
        for (k, r) in range.enumerate() {
            phis.fill(0.0);
            let xr = &x[r * m..(r + 1) * m];
            for (lt, &g) in lm.trees.iter().zip(&lm.tree_group) {
                if lt.is_stump() {
                    continue;
                }
                scratch.c.fill(1.0);
                walk(lt, lm, xr, 0, 0, &mut scratch, &mut phis[g * (m + 1)..(g + 1) * (m + 1)]);
            }
            for g in 0..groups {
                phis[g * (m + 1) + m] += lm.expected[g];
            }
            let dst = &mut chunk[k * stride..(k + 1) * stride];
            for (d, s) in dst.iter_mut().zip(&phis) {
                *d = *s as f32;
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;
    use crate::gbdt::{train, TrainParams};
    use crate::shap::treeshap;

    #[test]
    fn gauss_legendre_integrates_monomials_exactly() {
        for n in 1..=20usize {
            let (s, w) = gauss_legendre_01(n);
            assert!(s.iter().all(|&v| v > 0.0 && v < 1.0));
            assert!(w.iter().all(|&v| v > 0.0));
            // ∫₀¹ s^k ds = 1/(k+1), exact for k ≤ 2n−1
            for k in 0..2 * n {
                let q: f64 = s.iter().zip(&w).map(|(&sj, &wj)| wj * sj.powi(k as i32)).sum();
                assert!(
                    (q - 1.0 / (k + 1) as f64).abs() < 1e-13,
                    "n={n} k={k}: {q} vs {}",
                    1.0 / (k + 1) as f64
                );
            }
        }
    }

    #[test]
    fn quadrature_psi_matches_closed_form() {
        // Ψ_d(Σ c_k y^k) = Σ c_k·k!(d−k)!/(d+1)! — check the ω inner
        // product against the factorial formula for random coefficients
        let n = 12usize;
        let (s, lambda) = gauss_legendre_01(n);
        let y: Vec<f64> = s.iter().map(|&sj| (1.0 - sj) / sj).collect();
        let fact = |k: usize| (1..=k).map(|v| v as f64).product::<f64>();
        let mut rng = crate::util::Rng::new(9);
        for d in 0..n {
            let coeffs: Vec<f64> = (0..=d).map(|_| rng.normal()).collect();
            let want: f64 = coeffs
                .iter()
                .enumerate()
                .map(|(k, c)| c * fact(k) * fact(d - k) / fact(d + 1))
                .sum();
            let got: f64 = (0..n)
                .map(|j| {
                    let p: f64 = coeffs
                        .iter()
                        .enumerate()
                        .map(|(k, c)| c * y[j].powi(k as i32))
                        .sum();
                    lambda[j] * s[j].powi(d as i32) * p
                })
                .sum();
            assert!((got - want).abs() < 1e-12 * (1.0 + want.abs()), "d={d}: {got} vs {want}");
        }
    }

    fn assert_matches_recursive(model: &Model, x: &[f32], rows: usize, what: &str) {
        let m = model.num_features;
        let a = treeshap::shap_values(model, x, rows, 1);
        let lm = summarize_model(model);
        let b = shap_values(&lm, x, rows, 1);
        assert_eq!(a.len(), b.len());
        for (i, (p, q)) in a.iter().zip(&b).enumerate() {
            assert!(
                (p - q).abs() <= 1e-6 + 1e-5 * p.abs().max(q.abs()),
                "{what}: idx {i} ({} per row-group): {p} vs {q}",
                m + 1
            );
        }
    }

    #[test]
    fn matches_recursive_on_trained_model() {
        let d = SynthSpec::cal_housing(0.01).generate();
        let model = train(&d, &TrainParams { rounds: 8, max_depth: 5, ..Default::default() });
        let rows = 48.min(d.rows);
        assert_matches_recursive(&model, &d.features[..rows * model.num_features], rows, "cal");
    }

    #[test]
    fn matches_recursive_on_deep_model() {
        // deep trees stress the quadrature degree and the padding table
        let d = SynthSpec::covtype(0.001).generate();
        let model = train(&d, &TrainParams { rounds: 2, max_depth: 12, ..Default::default() });
        let rows = 12.min(d.rows);
        assert_matches_recursive(&model, &d.features[..rows * model.num_features], rows, "deep");
    }

    #[test]
    fn matches_recursive_on_multiclass() {
        let d = SynthSpec::covtype(0.001).generate();
        let model = train(&d, &TrainParams { rounds: 2, max_depth: 4, ..Default::default() });
        let rows = 16.min(d.rows);
        assert_matches_recursive(&model, &d.features[..rows * model.num_features], rows, "multi");
    }

    #[test]
    fn nan_rows_follow_the_oracle_convention() {
        // NaN routes to the cold-on-left convention of treeshap (not
        // predict_row's majority direction): parity must still hold
        let d = SynthSpec::adult(0.004).generate();
        let model = train(&d, &TrainParams { rounds: 3, max_depth: 4, ..Default::default() });
        let m = model.num_features;
        let rows = 6.min(d.rows);
        let mut x = d.features[..rows * m].to_vec();
        for r in 0..rows {
            x[r * m + (r % m)] = f32::NAN;
        }
        let a = treeshap::shap_values(&model, &x, rows, 1);
        let lm = summarize_model(&model);
        let b = shap_values(&lm, &x, rows, 1);
        for (p, q) in a.iter().zip(&b) {
            assert!((p - q).abs() <= 1e-6 + 1e-5 * p.abs().max(q.abs()), "{p} vs {q}");
        }
    }

    #[test]
    fn repeated_feature_tree_parity_and_local_accuracy() {
        let model = crate::bench::zoo::repeated_feature_model();
        // probe values straddling every threshold, incl. a NaN row
        let probes: &[[f32; 2]] = &[
            [-2.0, 0.0],
            [-0.5, 0.0],
            [-0.5, 2.0],
            [0.5, 1.5],
            [3.0, -1.0],
            [f32::NAN, 0.5],
        ];
        let mut x = Vec::new();
        for p in probes {
            x.extend_from_slice(p);
        }
        let rows = probes.len();
        assert_matches_recursive(&model, &x, rows, "repeated-feature");
        // local accuracy Σφ = f(x) on the non-NaN rows
        let lm = summarize_model(&model);
        let phis = shap_values(&lm, &x, rows, 1);
        let m = model.num_features;
        for (r, p) in probes.iter().enumerate().take(rows - 1) {
            let pred = f64::from(model.predict_row_raw(p)[0]);
            let total: f64 = phis[r * (m + 1)..(r + 1) * (m + 1)]
                .iter()
                .map(|&v| f64::from(v))
                .sum();
            assert!((total - pred).abs() < 1e-5, "row {r}: Σφ {total} vs f(x) {pred}");
        }
    }

    #[test]
    fn threads_do_not_change_result() {
        let d = SynthSpec::cal_housing(0.005).generate();
        let model = train(&d, &TrainParams { rounds: 4, max_depth: 4, ..Default::default() });
        let m = model.num_features;
        let rows = 16.min(d.rows);
        let lm = summarize_model(&model);
        let a = shap_values(&lm, &d.features[..rows * m], rows, 1);
        let b = shap_values(&lm, &d.features[..rows * m], rows, 8);
        assert_eq!(a, b);
    }

    #[test]
    fn stump_trees_only_shift_the_base_value() {
        let mut model = {
            let d = SynthSpec::cal_housing(0.005).generate();
            train(&d, &TrainParams { rounds: 2, max_depth: 3, ..Default::default() })
        };
        model.trees.push(crate::gbdt::Tree::leaf(2.5, 10.0));
        model.tree_group.push(0);
        let d = SynthSpec::cal_housing(0.005).generate();
        let rows = 4.min(d.rows);
        assert_matches_recursive(&model, &d.features[..rows * model.num_features], rows, "stump");
    }
}
