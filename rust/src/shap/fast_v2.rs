//! Fast TreeSHAP v2 (Yang, arXiv 2109.09847) in this repo's merged-path
//! formulation: precompute one weight row per leaf × feature-subset slot
//! so the per-row kernel drops a whole depth factor — O(d) per leaf
//! instead of the recursive/packed DP's O(d²) unwind — at the price of
//! O(leaves · 2^D) row-independent table memory.
//!
//! ## The subset-table view
//!
//! For a leaf whose merged path (duplicates merged as in
//! [`crate::shap::path`]) carries `d` unique features with activation
//! indicators `o_g ∈ {0,1}` and cover ratios `z_g`, the recursive
//! algorithm's per-leaf contribution to feature `f` is
//!
//! ```text
//! Δφ_f = (o_f − z_f) · v · Ψ_{d−1}( Π_{g∈S∖f} (o_g·y + z_g) )
//! ```
//!
//! with `Ψ_{d−1}(Σ_k c_k y^k) = Σ_k c_k · k!(d−1−k)!/d!` summing the
//! Shapley weights. Splitting the product over the row's active set `A`
//! (`o_g = 1`) and inactive set `I` (`o_g = 0`) factors out everything
//! row-dependent as scalars:
//!
//! ```text
//! Π_{g∈S∖f}(o_g·y + z_g) = (Π_{g∈I∖f} z_g) · Π_{g∈A∖f}(y + z_g)
//! ```
//!
//! The polynomial part depends on the row only through *which subset*
//! `A∖f` (or `A`) it is — so precompute, per leaf, per subset `B` of its
//! path elements, the scalar
//!
//! ```text
//! S[B] = Ψ_{d−1}( Π_{g∈B}(y + z_g) )
//! ```
//!
//! (2^d entries per leaf; only `|B| ≤ d−1` is ever read, matching
//! `Ψ_{d−1}`'s degree). Per row, per leaf, everything left is O(d):
//! one interval check per element gives the active bitmask `A` and
//! `zprod = Π_{g∈I} z_g`, then
//!
//! ```text
//! f ∈ A:  Δφ_f = (1 − z_f) · v · zprod · S[A∖{f}]
//! f ∈ I:  Δφ_f = −z_f · v · (zprod / z_f) · S[A]  =  −v · zprod · S[A]
//! ```
//!
//! — the inactive term's `z_f` cancels, so no per-feature division and
//! one shared scalar for every inactive feature of the leaf.
//!
//! Activation/NaN semantics mirror `shap::treeshap` exactly (the parity
//! oracle): an element is active iff `lower ≤ x < upper`, which is false
//! for NaN — the same convention the packed host kernel checks.
//!
//! The tables are the memory trade the planner guards: exact bytes are
//! `Σ_leaves 2^d · 8` ([`table_bytes_for_paths`]), estimated from shape
//! alone as `leaves · 2^D · 8` by `backend::planner::fastv2_table_bytes`.

use crate::gbdt::Model;
use crate::parallel;
use crate::shap::path::{expected_values, model_paths, Path};

/// Hard ceiling on unique features per path: beyond this the table for a
/// *single* leaf would exceed 2^57 bytes, so no budget can admit it and
/// the shift arithmetic below would overflow. The planner's byte
/// guardrail rejects such models long before this assert can fire.
const MAX_UNIQUE: usize = 48;

/// The precomputed Fast TreeSHAP v2 state of one model: flattened
/// per-path element arrays plus the concatenated subset weight tables.
pub struct FastV2Model {
    /// per merged element (root element excluded), path-concatenated
    feat: Vec<u32>,
    lower: Vec<f32>,
    upper: Vec<f32>,
    zfrac: Vec<f64>,
    /// element range of path `p`: `elem_start[p]..elem_start[p+1]`
    elem_start: Vec<usize>,
    /// table range of path `p`: `table_start[p]..table_start[p+1]`
    /// (2^d entries, indexed by the active bitmask over the elements)
    table_start: Vec<usize>,
    group: Vec<u32>,
    /// leaf value of path `p`
    v: Vec<f64>,
    /// concatenated S tables (see module docs)
    table: Vec<f64>,
    pub num_features: usize,
    pub num_groups: usize,
    /// φ base values per group (E[f] incl. base_score)
    expected: Vec<f64>,
    /// largest unique-feature count over the live paths
    max_unique: usize,
}

impl FastV2Model {
    pub fn expected_values(&self) -> &[f64] {
        &self.expected
    }

    /// Paths carrying a table (stumps and dead leaves are dropped).
    pub fn num_paths(&self) -> usize {
        self.v.len()
    }

    /// Largest unique-feature count `d` over the stored paths.
    pub fn max_unique_features(&self) -> usize {
        self.max_unique
    }

    /// Bytes held by the subset weight tables.
    pub fn table_bytes(&self) -> usize {
        self.table.len() * std::mem::size_of::<f64>()
    }
}

/// Whether a path contributes φ terms at all: stump paths (root only)
/// only shift the expected value, and exactly-zero leaves contribute ±0
/// to every feature (the prepared-model dead-leaf bound) — both are
/// skipped by the build and the kernel, value-identically.
fn is_live(p: &Path) -> bool {
    p.len() > 1 && p.leaf_value() != 0.0
}

/// Exact table bytes [`precompute_from_paths`] would allocate for these
/// paths: `Σ 2^d × 8` over live paths. `f64` so deep ensembles report a
/// (huge) size instead of overflowing — the guardrail compares, never
/// allocates.
pub fn table_bytes_for_paths(paths: &[(usize, Path)]) -> f64 {
    paths
        .iter()
        .filter(|(_, p)| is_live(p))
        .map(|(_, p)| (p.len() - 1) as i32)
        .map(|d| 8.0 * (2f64).powi(d))
        .sum()
}

/// The Shapley weight row for a path of `d` unique features:
/// `w[k] = k!(d−1−k)!/d!` for `k = 0..d`, via the overflow-free ratio
/// recurrence `w[k+1]/w[k] = (k+1)/(d−1−k)`.
fn shapley_weights(d: usize, out: &mut Vec<f64>) {
    out.clear();
    let mut w = 1.0 / d as f64;
    out.push(w);
    for k in 0..d - 1 {
        w *= (k + 1) as f64 / (d - 1 - k) as f64;
        out.push(w);
    }
}

/// DFS subset enumeration filling one leaf's S table. The current
/// subset's polynomial coefficients live in `scratch[..=deg]`; the
/// include-branch writes its child's coefficients just past them, so the
/// whole recursion runs in one `(d+1)(d+2)/2` scratch buffer with no
/// per-subset allocation. Each of the 2^d masks is visited exactly once.
fn enumerate_subsets(
    z: &[f64],
    w: &[f64],
    i: usize,
    mask: usize,
    scratch: &mut [f64],
    deg: usize,
    table: &mut [f64],
) {
    let d = z.len();
    if i == d {
        // the full set (degree d) is never read — Ψ_{d−1} caps at d−1
        if mask + 1 != 1 << d {
            table[mask] = scratch[..=deg].iter().zip(w).map(|(c, wk)| c * wk).sum();
        }
        return;
    }
    // exclude element i: same coefficients, descendants write deeper
    enumerate_subsets(z, w, i + 1, mask, scratch, deg, table);
    // include element i: multiply the polynomial by (y + z_i)
    let (cur, rest) = scratch.split_at_mut(deg + 1);
    rest[..deg + 2].fill(0.0);
    for (k, c) in cur.iter().enumerate() {
        rest[k] += c * z[i];
        rest[k + 1] += c;
    }
    enumerate_subsets(z, w, i + 1, mask | (1 << i), rest, deg + 1, table);
}

/// Build the Fast TreeSHAP v2 tables from already-extracted merged paths
/// with caller-supplied φ base values — the prepared-model cache's entry
/// point, so cached and uncached builds agree bit-for-bit.
pub fn precompute_from_paths(
    num_features: usize,
    num_groups: usize,
    paths: &[(usize, Path)],
    expected: &[f64],
) -> FastV2Model {
    let live: Vec<(usize, &Path)> =
        paths.iter().filter(|(_, p)| is_live(p)).map(|(g, p)| (*g, p)).collect();
    let max_unique = live.iter().map(|(_, p)| p.len() - 1).max().unwrap_or(0);
    assert!(
        max_unique <= MAX_UNIQUE,
        "fast_v2: a path with {max_unique} unique features needs a 2^{max_unique}-entry \
         table; the planner byte guardrail must exclude such models"
    );
    let mut fm = FastV2Model {
        feat: Vec::new(),
        lower: Vec::new(),
        upper: Vec::new(),
        zfrac: Vec::new(),
        elem_start: vec![0],
        table_start: vec![0],
        group: Vec::new(),
        v: Vec::new(),
        table: Vec::new(),
        num_features,
        num_groups,
        expected: expected.to_vec(),
        max_unique,
    };
    let total_table: usize = live.iter().map(|(_, p)| 1usize << (p.len() - 1)).sum();
    fm.table = vec![0.0f64; total_table];
    let mut weights = Vec::with_capacity(max_unique);
    let mut scratch = vec![0.0f64; (max_unique + 1) * (max_unique + 2) / 2];
    let mut z = Vec::with_capacity(max_unique);
    let mut offset = 0usize;
    for (g, p) in live {
        let d = p.len() - 1;
        z.clear();
        for e in &p.elements[1..] {
            fm.feat.push(e.feature as u32);
            fm.lower.push(e.lower);
            fm.upper.push(e.upper);
            fm.zfrac.push(f64::from(e.zero_fraction));
            z.push(f64::from(e.zero_fraction));
        }
        fm.elem_start.push(fm.feat.len());
        fm.group.push(g as u32);
        fm.v.push(f64::from(p.leaf_value()));
        shapley_weights(d, &mut weights);
        scratch[0] = 1.0; // the empty subset's polynomial is 1
        let table = &mut fm.table[offset..offset + (1 << d)];
        enumerate_subsets(&z, &weights, 0, 0, &mut scratch, 0, table);
        offset += 1 << d;
        fm.table_start.push(offset);
    }
    fm
}

/// As [`precompute_from_paths`], extracting paths and base values from
/// the model (standalone entry point for tests and one-off callers).
pub fn precompute_model(model: &Model) -> FastV2Model {
    let paths = model_paths(model);
    precompute_from_paths(model.num_features, model.num_groups, &paths, &expected_values(model))
}

/// φ contributions of one path for one row, added into `phis[0..=M]`
/// (slot M untouched — base value is the caller's job).
#[inline]
fn path_row(fm: &FastV2Model, p: usize, x: &[f32], phis: &mut [f64]) {
    let es = fm.elem_start[p];
    let ee = fm.elem_start[p + 1];
    let mut mask = 0usize;
    let mut zprod = 1.0f64;
    for (j, e) in (es..ee).enumerate() {
        let xv = x[fm.feat[e] as usize];
        if xv >= fm.lower[e] && xv < fm.upper[e] {
            mask |= 1 << j;
        } else {
            zprod *= fm.zfrac[e];
        }
    }
    let table = &fm.table[fm.table_start[p]..fm.table_start[p + 1]];
    let vz = fm.v[p] * zprod;
    // one shared term for every inactive feature (the z_f cancels);
    // table[full-mask] is 0.0 but then no inactive element reads it
    let inactive = -vz * table[mask];
    for (j, e) in (es..ee).enumerate() {
        let f = fm.feat[e] as usize;
        if mask & (1 << j) != 0 {
            phis[f] += (1.0 - fm.zfrac[e]) * vz * table[mask ^ (1 << j)];
        } else {
            phis[f] += inactive;
        }
    }
}

/// SHAP values for a batch through the weight-table kernel: output
/// `[rows × groups × (M+1)]` row-major, base value E[f] in slot M —
/// the same layout as `treeshap::shap_values`.
pub fn shap_values(fm: &FastV2Model, x: &[f32], rows: usize, threads: usize) -> Vec<f32> {
    let m = fm.num_features;
    let groups = fm.num_groups;
    let stride = groups * (m + 1);
    let mut out = vec![0.0f32; rows * stride];
    parallel::parallel_for_rows(threads, &mut out, stride, 8, |range, chunk| {
        let mut phis = vec![0.0f64; stride];
        for (k, r) in range.enumerate() {
            phis.fill(0.0);
            let xr = &x[r * m..(r + 1) * m];
            for p in 0..fm.num_paths() {
                let g = fm.group[p] as usize;
                path_row(fm, p, xr, &mut phis[g * (m + 1)..(g + 1) * (m + 1)]);
            }
            for g in 0..groups {
                phis[g * (m + 1) + m] += fm.expected[g];
            }
            let dst = &mut chunk[k * stride..(k + 1) * stride];
            for (d, s) in dst.iter_mut().zip(&phis) {
                *d = *s as f32;
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;
    use crate::gbdt::{train, TrainParams};
    use crate::shap::treeshap;

    #[test]
    fn subset_tables_match_the_factorial_formula() {
        // hand-evaluate S[B] = Σ_k c_k(B)·k!(d−1−k)!/d! for a 3-feature
        // path and check every table entry the DFS produced
        let z = [0.3f64, 0.6, 0.8];
        let d = z.len();
        let fact = |k: usize| (1..=k).map(|v| v as f64).product::<f64>();
        let mut weights = Vec::new();
        shapley_weights(d, &mut weights);
        for (k, w) in weights.iter().enumerate() {
            let want = fact(k) * fact(d - 1 - k) / fact(d);
            assert!((w - want).abs() < 1e-15, "w[{k}]: {w} vs {want}");
        }
        let mut table = vec![0.0f64; 1 << d];
        let mut scratch = vec![0.0f64; (d + 1) * (d + 2) / 2];
        scratch[0] = 1.0;
        enumerate_subsets(&z, &weights, 0, 0, &mut scratch, 0, &mut table);
        for mask in 0..(1usize << d) - 1 {
            // expand Π_{g∈B}(y + z_g) coefficient by coefficient
            let mut coeffs = vec![1.0f64];
            for (i, &zi) in z.iter().enumerate() {
                if mask & (1 << i) != 0 {
                    let mut next = vec![0.0; coeffs.len() + 1];
                    for (k, c) in coeffs.iter().enumerate() {
                        next[k] += c * zi;
                        next[k + 1] += c;
                    }
                    coeffs = next;
                }
            }
            let want: f64 =
                coeffs.iter().enumerate().map(|(k, c)| c * weights[k]).sum();
            assert!(
                (table[mask] - want).abs() < 1e-14,
                "mask {mask:#b}: {} vs {want}",
                table[mask]
            );
        }
        assert_eq!(table[(1 << d) - 1], 0.0, "full-set slot stays unwritten");
    }

    fn assert_matches_recursive(model: &Model, x: &[f32], rows: usize, what: &str) {
        let m = model.num_features;
        let a = treeshap::shap_values(model, x, rows, 1);
        let fm = precompute_model(model);
        let b = shap_values(&fm, x, rows, 1);
        assert_eq!(a.len(), b.len());
        for (i, (p, q)) in a.iter().zip(&b).enumerate() {
            assert!(
                (p - q).abs() <= 1e-6 + 1e-5 * p.abs().max(q.abs()),
                "{what}: idx {i} ({} per row-group): {p} vs {q}",
                m + 1
            );
        }
    }

    #[test]
    fn matches_recursive_on_trained_model() {
        let d = SynthSpec::cal_housing(0.01).generate();
        let model = train(&d, &TrainParams { rounds: 8, max_depth: 5, ..Default::default() });
        let rows = 48.min(d.rows);
        assert_matches_recursive(&model, &d.features[..rows * model.num_features], rows, "cal");
    }

    #[test]
    fn matches_recursive_on_multiclass() {
        let d = SynthSpec::covtype(0.001).generate();
        let model = train(&d, &TrainParams { rounds: 2, max_depth: 4, ..Default::default() });
        let rows = 16.min(d.rows);
        assert_matches_recursive(&model, &d.features[..rows * model.num_features], rows, "multi");
    }

    #[test]
    fn nan_rows_follow_the_oracle_convention() {
        let d = SynthSpec::adult(0.004).generate();
        let model = train(&d, &TrainParams { rounds: 3, max_depth: 4, ..Default::default() });
        let m = model.num_features;
        let rows = 6.min(d.rows);
        let mut x = d.features[..rows * m].to_vec();
        for r in 0..rows {
            x[r * m + (r % m)] = f32::NAN;
        }
        assert_matches_recursive(&model, &x, rows, "nan");
    }

    #[test]
    fn repeated_feature_tree_parity_and_local_accuracy() {
        let model = crate::bench::zoo::repeated_feature_model();
        let probes: &[[f32; 2]] = &[
            [-2.0, 0.0],
            [-0.5, 0.0],
            [-0.5, 2.0],
            [0.5, 1.5],
            [3.0, -1.0],
            [f32::NAN, 0.5],
        ];
        let mut x = Vec::new();
        for p in probes {
            x.extend_from_slice(p);
        }
        let rows = probes.len();
        assert_matches_recursive(&model, &x, rows, "repeated-feature");
        // local accuracy Σφ = f(x) on the non-NaN rows
        let fm = precompute_model(&model);
        let phis = shap_values(&fm, &x, rows, 1);
        let m = model.num_features;
        for (r, p) in probes.iter().enumerate().take(rows - 1) {
            let pred = f64::from(model.predict_row_raw(p)[0]);
            let total: f64 = phis[r * (m + 1)..(r + 1) * (m + 1)]
                .iter()
                .map(|&v| f64::from(v))
                .sum();
            assert!((total - pred).abs() < 1e-5, "row {r}: Σφ {total} vs f(x) {pred}");
        }
    }

    #[test]
    fn threads_do_not_change_result() {
        let d = SynthSpec::cal_housing(0.005).generate();
        let model = train(&d, &TrainParams { rounds: 4, max_depth: 4, ..Default::default() });
        let m = model.num_features;
        let rows = 16.min(d.rows);
        let fm = precompute_model(&model);
        let a = shap_values(&fm, &d.features[..rows * m], rows, 1);
        let b = shap_values(&fm, &d.features[..rows * m], rows, 8);
        assert_eq!(a, b);
    }

    #[test]
    fn stump_trees_only_shift_the_base_value() {
        let mut model = {
            let d = SynthSpec::cal_housing(0.005).generate();
            train(&d, &TrainParams { rounds: 2, max_depth: 3, ..Default::default() })
        };
        model.trees.push(crate::gbdt::Tree::leaf(2.5, 10.0));
        model.tree_group.push(0);
        let d = SynthSpec::cal_housing(0.005).generate();
        let rows = 4.min(d.rows);
        assert_matches_recursive(&model, &d.features[..rows * model.num_features], rows, "stump");
    }

    #[test]
    fn table_bytes_accounting_is_exact() {
        let d = SynthSpec::cal_housing(0.006).generate();
        let model = train(&d, &TrainParams { rounds: 3, max_depth: 4, ..Default::default() });
        let paths = model_paths(&model);
        let fm = precompute_model(&model);
        assert_eq!(table_bytes_for_paths(&paths), fm.table_bytes() as f64);
        assert!(fm.table_bytes() > 0);
        assert!(fm.max_unique_features() >= 1);
        // the estimate counts live paths only: stumps and dead leaves
        // carry no table
        let stump = (0usize, Path::default());
        assert_eq!(table_bytes_for_paths(&[stump]), 0.0);
    }
}
