//! CPU TreeShap baseline: the recursive Algorithm 1 of the paper
//! (Lundberg et al. 2020), multithreaded over rows — the comparator for
//! Tables 6/7 and Figs 4/6, functionally matching XGBoost's
//! `pred_contribs` implementation.
//!
//! The path state lives in a per-thread triangular slab (depth d owns
//! `d+1` slots at offset d(d+1)/2), so recursion performs no heap
//! allocation per node — the baseline must be honest to make measured
//! speedups meaningful.

use crate::gbdt::{Model, Tree};
use crate::parallel;
use crate::shap::path::expected_values;

/// Conditioning mode for interaction values (Eq. 5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Condition {
    None,
    /// feature fixed to present
    On(i32),
    /// feature fixed to absent
    Off(i32),
}

/// Per-element path state of Algorithm 1 (struct-of-arrays slab).
struct PathSlab {
    d: Vec<i32>,
    z: Vec<f64>,
    o: Vec<f64>,
    w: Vec<f64>,
}

impl PathSlab {
    fn new(max_depth: usize) -> PathSlab {
        let cap = (max_depth + 2) * (max_depth + 3) / 2;
        PathSlab {
            d: vec![0; cap],
            z: vec![0.0; cap],
            o: vec![0.0; cap],
            w: vec![0.0; cap],
        }
    }
}

#[inline]
fn slab_offset(depth: usize) -> usize {
    depth * (depth + 1) / 2
}

/// EXTEND at slab offset `off`, path currently `len` elements long.
#[inline]
fn extend(slab: &mut PathSlab, off: usize, len: usize, pz: f64, po: f64, pi: i32) {
    let l = len;
    slab.d[off + l] = pi;
    slab.z[off + l] = pz;
    slab.o[off + l] = po;
    slab.w[off + l] = if l == 0 { 1.0 } else { 0.0 };
    for i in (0..l).rev() {
        slab.w[off + i + 1] += po * slab.w[off + i] * (i + 1) as f64 / (l + 1) as f64;
        slab.w[off + i] *= pz * (l - i) as f64 / (l + 1) as f64;
    }
}

/// UNWIND element `i` in place; caller decrements the path length.
#[inline]
fn unwind(slab: &mut PathSlab, off: usize, len: usize, i: usize) {
    let l = len - 1;
    let o_i = slab.o[off + i];
    let z_i = slab.z[off + i];
    let mut n = slab.w[off + l];
    if o_i != 0.0 {
        for j in (0..l).rev() {
            let t = slab.w[off + j];
            slab.w[off + j] = n * (l + 1) as f64 / ((j + 1) as f64 * o_i);
            n = t - slab.w[off + j] * z_i * (l - j) as f64 / (l + 1) as f64;
        }
    } else {
        for j in (0..l).rev() {
            slab.w[off + j] = slab.w[off + j] * (l + 1) as f64 / (z_i * (l - j) as f64);
        }
    }
    for j in i..l {
        slab.d[off + j] = slab.d[off + j + 1];
        slab.z[off + j] = slab.z[off + j + 1];
        slab.o[off + j] = slab.o[off + j + 1];
    }
}

/// Σ of weights after hypothetically unwinding element `i`.
#[inline]
fn unwound_sum(slab: &PathSlab, off: usize, len: usize, i: usize) -> f64 {
    let l = len - 1;
    let o_i = slab.o[off + i];
    let z_i = slab.z[off + i];
    let mut nxt = slab.w[off + l];
    let mut total = 0.0;
    if o_i != 0.0 {
        for j in (0..l).rev() {
            let tmp = nxt / ((j + 1) as f64 * o_i);
            total += tmp;
            nxt = slab.w[off + j] - tmp * z_i * (l - j) as f64;
        }
    } else {
        for j in (0..l).rev() {
            total += slab.w[off + j] / (z_i * (l - j) as f64);
        }
    }
    total * (l + 1) as f64
}

/// TreeShap for a single tree and row, accumulating into `phis[0..=M]`.
/// `condition`/`cond_feature` implement Eq. 5 conditioning.
#[allow(clippy::too_many_arguments)]
pub fn tree_shap_row(
    tree: &Tree,
    x: &[f32],
    phis: &mut [f64],
    condition: Condition,
    slab: &mut Scratch,
) {
    let slab = &mut slab.0;
    recurse(tree, x, phis, condition, slab, 0, 0, 0, 1.0, 1.0, -1, 1.0);
}

/// Opaque reusable scratch (wraps the slab so callers can preallocate).
pub struct Scratch(PathSlab);

impl Scratch {
    pub fn new(max_depth: usize) -> Self {
        Scratch(PathSlab::new(max_depth))
    }
}

#[allow(clippy::too_many_arguments)]
fn recurse(
    tree: &Tree,
    x: &[f32],
    phis: &mut [f64],
    condition: Condition,
    slab: &mut PathSlab,
    node: usize,
    depth: usize,
    parent_len: usize,
    pz: f64,
    po: f64,
    pi: i32,
    cond_frac: f64,
) {
    if cond_frac == 0.0 {
        return;
    }
    let off = slab_offset(depth);
    // copy parent path into this level's slab range
    if depth > 0 {
        let poff = slab_offset(depth - 1);
        for k in 0..parent_len {
            slab.d[off + k] = slab.d[poff + k];
            slab.z[off + k] = slab.z[poff + k];
            slab.o[off + k] = slab.o[poff + k];
            slab.w[off + k] = slab.w[poff + k];
        }
    }
    let mut len = parent_len;
    let mut cond_frac = cond_frac;

    let conditioned = match condition {
        Condition::None => false,
        Condition::On(f) => pi == f,
        Condition::Off(f) => pi == f,
    };
    if conditioned {
        // feature is fixed: never enters the path, scales everything below
        cond_frac *= match condition {
            Condition::On(_) => po,
            Condition::Off(_) => pz,
            Condition::None => unreachable!(),
        };
    } else {
        extend(slab, off, len, pz, po, pi);
        len += 1;
    }

    if tree.is_leaf(node) {
        let v = tree.value[node] as f64;
        for i in 1..len {
            let w = unwound_sum(slab, off, len, i);
            phis[slab.d[off + i] as usize] +=
                w * (slab.o[off + i] - slab.z[off + i]) * v * cond_frac;
        }
        return;
    }

    let f = tree.feature[node];
    let t = tree.threshold[node];
    let l = tree.left[node] as usize;
    let r = tree.right[node] as usize;
    let xv = x[f as usize];
    let (hot, cold) = if !xv.is_nan() && xv < t { (l, r) } else { (r, l) };
    let cov = tree.cover[node] as f64;

    let mut iz = 1.0;
    let mut io = 1.0;
    // duplicate feature on the path: unwind the old occurrence
    if let Some(k) = (1..len).find(|&k| slab.d[off + k] == f) {
        iz = slab.z[off + k];
        io = slab.o[off + k];
        unwind(slab, off, len, k);
        len -= 1;
    }

    let zh = tree.cover[hot] as f64 / cov;
    let zc = tree.cover[cold] as f64 / cov;
    recurse(tree, x, phis, condition, slab, hot, depth + 1, len, iz * zh, io, f, cond_frac);
    recurse(tree, x, phis, condition, slab, cold, depth + 1, len, iz * zc, 0.0, f, cond_frac);
}

/// SHAP values for a batch: output [rows × groups × (M+1)] row-major,
/// base value E[f] (incl. base_score) in slot M. The paper's baseline:
/// parallel-for over rows, recursive algorithm per (row, tree).
pub fn shap_values(
    model: &Model,
    x: &[f32],
    rows: usize,
    threads: usize,
) -> Vec<f32> {
    let m = model.num_features;
    let groups = model.num_groups;
    let ev = expected_values(model);
    let stride = groups * (m + 1);
    let mut out = vec![0.0f32; rows * stride];
    let max_depth = model.max_depth();
    parallel::parallel_for_rows(threads, &mut out, stride, 8, |range, chunk| {
        let mut slab = Scratch::new(max_depth);
        let mut phis = vec![0.0f64; stride];
        for (k, r) in range.enumerate() {
            phis.iter_mut().for_each(|p| *p = 0.0);
            let xr = &x[r * m..(r + 1) * m];
            for (tree, &g) in model.trees.iter().zip(&model.tree_group) {
                tree_shap_row(
                    tree,
                    xr,
                    &mut phis[g * (m + 1)..(g + 1) * (m + 1)],
                    Condition::None,
                    &mut slab,
                );
            }
            for g in 0..groups {
                phis[g * (m + 1) + m] += ev[g];
            }
            let dst = &mut chunk[k * stride..(k + 1) * stride];
            for (d, s) in dst.iter_mut().zip(&phis) {
                *d = *s as f32;
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;
    use crate::gbdt::{train, TrainParams};

    fn model_and_data(scale: f64, rounds: usize, depth: usize) -> (Model, crate::data::Dataset) {
        let d = SynthSpec::cal_housing(scale).generate();
        let m = train(&d, &TrainParams { rounds, max_depth: depth, ..Default::default() });
        (m, d)
    }

    #[test]
    fn local_accuracy() {
        let (model, d) = model_and_data(0.01, 8, 5);
        let m = model.num_features;
        let rows = 32.min(d.rows);
        let phis = shap_values(&model, &d.features[..rows * m], rows, 2);
        for r in 0..rows {
            let pred = model.predict_row_raw(d.row(r))[0] as f64;
            let total: f64 = phis[r * (m + 1)..(r + 1) * (m + 1)]
                .iter()
                .map(|&v| v as f64)
                .sum();
            assert!((total - pred).abs() < 1e-3, "row {r}: {total} vs {pred}");
        }
    }

    #[test]
    fn multiclass_local_accuracy() {
        let d = SynthSpec::covtype(0.0008).generate();
        let model = train(&d, &TrainParams { rounds: 2, max_depth: 4, ..Default::default() });
        let m = model.num_features;
        let g = model.num_groups;
        let rows = 8;
        let phis = shap_values(&model, &d.features[..rows * m], rows, 1);
        for r in 0..rows {
            let preds = model.predict_row_raw(d.row(r));
            for k in 0..g {
                let s: f64 = phis
                    [r * g * (m + 1) + k * (m + 1)..r * g * (m + 1) + (k + 1) * (m + 1)]
                    .iter()
                    .map(|&v| v as f64)
                    .sum();
                assert!((s - preds[k] as f64).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn threads_do_not_change_result() {
        let (model, d) = model_and_data(0.005, 4, 4);
        let m = model.num_features;
        let rows = 16.min(d.rows);
        let a = shap_values(&model, &d.features[..rows * m], rows, 1);
        let b = shap_values(&model, &d.features[..rows * m], rows, 8);
        assert_eq!(a, b);
    }

    #[test]
    fn null_feature_gets_zero_phi() {
        // feature never split on ⇒ φ = 0 exactly
        let (model, d) = model_and_data(0.01, 6, 3);
        let m = model.num_features;
        let mut used = vec![false; m];
        for t in &model.trees {
            for (i, &f) in t.feature.iter().enumerate() {
                if !t.is_leaf(i) {
                    used[f as usize] = true;
                }
            }
        }
        let rows = 8;
        let phis = shap_values(&model, &d.features[..rows * m], rows, 1);
        for r in 0..rows {
            for f in 0..m {
                if !used[f] {
                    assert_eq!(phis[r * (m + 1) + f], 0.0);
                }
            }
        }
    }
}
