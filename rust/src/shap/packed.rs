//! Packed device-tensor layout: bins × 32 lanes, one element per lane
//! (paper §3.3–3.4). This is the host-side twin of
//! `python/compile/kernels/packing.py` — layouts must match bit-for-bit,
//! since these arrays are the runtime inputs to the AOT HLO artifacts.

use crate::gbdt::Model;
use crate::shap::binpack::{pack, PackResult, Packing, LANES};
use crate::shap::path::{expected_values, model_paths, Path};

/// ±inf replaced by ±F32_MAX to keep HLO literals finite-friendly
/// (mirrors packing.py).
pub const F32_BIG: f32 = f32::MAX;

/// Packed paths of one output group. All arrays are `[num_bins × LANES]`
/// row-major.
#[derive(Clone, Debug)]
pub struct PackedGroup {
    pub fidx: Vec<i32>,
    pub lower: Vec<f32>,
    pub upper: Vec<f32>,
    pub zfrac: Vec<f32>,
    pub v: Vec<f32>,
    pub pos: Vec<i32>,
    pub plen: Vec<i32>,
    pub num_bins: usize,
    /// longest path length − 1 (DP trip-count requirement)
    pub max_depth: usize,
    pub utilisation: f64,
}

impl PackedGroup {
    fn empty(bins: usize) -> PackedGroup {
        PackedGroup {
            fidx: vec![-1; bins * LANES],
            lower: vec![-F32_BIG; bins * LANES],
            upper: vec![F32_BIG; bins * LANES],
            zfrac: vec![1.0; bins * LANES],
            v: vec![0.0; bins * LANES],
            pos: vec![0; bins * LANES],
            plen: vec![0; bins * LANES],
            num_bins: bins,
            max_depth: 0,
            utilisation: 1.0,
        }
    }

    /// Pad the bin axis to `bins` (plen = 0 marks padding lanes).
    pub fn padded_to(&self, bins: usize) -> PackedGroup {
        assert!(bins >= self.num_bins);
        let mut out = PackedGroup::empty(bins);
        let n = self.num_bins * LANES;
        out.fidx[..n].copy_from_slice(&self.fidx);
        out.lower[..n].copy_from_slice(&self.lower);
        out.upper[..n].copy_from_slice(&self.upper);
        out.zfrac[..n].copy_from_slice(&self.zfrac);
        out.v[..n].copy_from_slice(&self.v);
        out.pos[..n].copy_from_slice(&self.pos);
        out.plen[..n].copy_from_slice(&self.plen);
        out.max_depth = self.max_depth;
        out.utilisation = self.utilisation;
        out
    }

    /// Bins `[start, end)` as a standalone group (for chunked execution).
    pub fn slice_bins(&self, start: usize, end: usize) -> PackedGroup {
        let end = end.min(self.num_bins);
        let (a, b) = (start * LANES, end * LANES);
        PackedGroup {
            fidx: self.fidx[a..b].to_vec(),
            lower: self.lower[a..b].to_vec(),
            upper: self.upper[a..b].to_vec(),
            zfrac: self.zfrac[a..b].to_vec(),
            v: self.v[a..b].to_vec(),
            pos: self.pos[a..b].to_vec(),
            plen: self.plen[a..b].to_vec(),
            num_bins: end - start,
            max_depth: self.max_depth,
            utilisation: self.utilisation,
        }
    }
}

/// A whole model in packed form: one `PackedGroup` per output group.
#[derive(Clone, Debug)]
pub struct PackedModel {
    pub groups: Vec<PackedGroup>,
    pub num_features: usize,
    pub num_groups: usize,
    /// φ base values per group (E[f] + base_score)
    pub expected_values: Vec<f64>,
    /// raw-score offset of the originating model (for predictions)
    pub base_score: f32,
    pub max_depth: usize,
}

/// Pack paths (already merged) of one group into bins.
pub fn pack_paths(paths: &[&Path], algorithm: Packing) -> PackedGroup {
    let sizes: Vec<usize> = paths.iter().map(|p| p.len()).collect();
    assert!(
        sizes.iter().all(|&s| s >= 1 && s <= LANES),
        "path length must be in 1..=32 (tree depth ≤ 31 after merging)"
    );
    let PackResult { bins, utilisation } = pack(&sizes, algorithm, LANES);
    let mut g = PackedGroup::empty(bins.len());
    g.utilisation = utilisation;
    for (b, items) in bins.iter().enumerate() {
        let mut lane = 0usize;
        for &pi in items {
            let p = paths[pi as usize];
            let e_count = p.len();
            g.max_depth = g.max_depth.max(e_count - 1);
            for (k, e) in p.elements.iter().enumerate() {
                let i = b * LANES + lane;
                g.fidx[i] = e.feature;
                g.lower[i] = e.lower.max(-F32_BIG);
                g.upper[i] = e.upper.min(F32_BIG);
                g.zfrac[i] = e.zero_fraction;
                g.v[i] = e.v;
                g.pos[i] = k as i32;
                g.plen[i] = e_count as i32;
                lane += 1;
            }
        }
        debug_assert!(lane <= LANES);
    }
    g
}

/// Pack a full model, segregating paths by output group.
pub fn pack_model(model: &Model, algorithm: Packing) -> PackedModel {
    let tagged = model_paths(model);
    let expected = expected_values(model);
    pack_model_from_paths(model, &tagged, &expected, algorithm)
}

/// As [`pack_model`], over already-extracted tagged paths and base
/// values — the prepared-model cache's entry point. Runs the identical
/// packing code over the identical path data, so the resulting layout
/// (and every φ/Φ computed from it) is bit-identical to an uncached
/// [`pack_model`] call.
pub fn pack_model_from_paths(
    model: &Model,
    tagged: &[(usize, Path)],
    expected: &[f64],
    algorithm: Packing,
) -> PackedModel {
    let mut groups = Vec::with_capacity(model.num_groups);
    for g in 0..model.num_groups {
        let paths: Vec<&Path> =
            tagged.iter().filter(|(tg, _)| *tg == g).map(|(_, p)| p).collect();
        groups.push(pack_paths(&paths, algorithm));
    }
    let max_depth = groups.iter().map(|g| g.max_depth).max().unwrap_or(0);
    PackedModel {
        num_features: model.num_features,
        num_groups: model.num_groups,
        expected_values: expected.to_vec(),
        base_score: model.base_score,
        groups,
        max_depth,
    }
}

/// Padded-path layout (perf variant, DESIGN.md §Perf): one row per path,
/// element axis padded to `width = depth_bucket + 1`. Gather-free on the
/// device at the cost of padding (utilisation = Σlen / (paths·width)).
#[derive(Clone, Debug)]
pub struct PaddedGroup {
    /// [num_paths × width] element tensors
    pub fidx: Vec<i32>,
    pub lower: Vec<f32>,
    pub upper: Vec<f32>,
    pub zfrac: Vec<f32>,
    /// [num_paths] leaf value / path length
    pub v: Vec<f32>,
    pub plen: Vec<i32>,
    pub num_paths: usize,
    pub width: usize,
    pub utilisation: f64,
}

impl PaddedGroup {
    fn empty(paths: usize, width: usize) -> PaddedGroup {
        PaddedGroup {
            fidx: vec![-1; paths * width],
            lower: vec![-F32_BIG; paths * width],
            upper: vec![F32_BIG; paths * width],
            zfrac: vec![1.0; paths * width],
            v: vec![0.0; paths],
            plen: vec![0; paths],
            num_paths: paths,
            width,
            utilisation: 1.0,
        }
    }

    /// Paths `[start, end)` as a standalone group padded to `paths` rows.
    pub fn slice_padded(&self, start: usize, end: usize, paths: usize) -> PaddedGroup {
        let end = end.min(self.num_paths);
        let n = end - start;
        assert!(paths >= n);
        let w = self.width;
        let mut out = PaddedGroup::empty(paths, w);
        out.fidx[..n * w].copy_from_slice(&self.fidx[start * w..end * w]);
        out.lower[..n * w].copy_from_slice(&self.lower[start * w..end * w]);
        out.upper[..n * w].copy_from_slice(&self.upper[start * w..end * w]);
        out.zfrac[..n * w].copy_from_slice(&self.zfrac[start * w..end * w]);
        out.v[..n].copy_from_slice(&self.v[start..end]);
        out.plen[..n].copy_from_slice(&self.plen[start..end]);
        out.utilisation = self.utilisation;
        out
    }
}

/// A model in padded-path form: one `PaddedGroup` per output group.
#[derive(Clone, Debug)]
pub struct PaddedModel {
    pub groups: Vec<PaddedGroup>,
    pub num_features: usize,
    pub num_groups: usize,
    pub expected_values: Vec<f64>,
    pub base_score: f32,
    pub max_depth: usize,
}

/// Build the padded layout with element axis `width ≥ max path length`.
pub fn pad_model(model: &Model, width: usize) -> PaddedModel {
    let tagged = model_paths(model);
    let expected = expected_values(model);
    pad_model_from_paths(model, &tagged, &expected, width)
}

/// As [`pad_model`], over already-extracted tagged paths and base
/// values (prepared-model cache entry point; bit-identical layouts).
pub fn pad_model_from_paths(
    model: &Model,
    tagged: &[(usize, Path)],
    expected: &[f64],
    width: usize,
) -> PaddedModel {
    let max_len = tagged.iter().map(|(_, p)| p.len()).max().unwrap_or(1);
    assert!(width >= max_len, "width {width} < deepest path {max_len}");
    let mut groups = Vec::with_capacity(model.num_groups);
    for g in 0..model.num_groups {
        let paths: Vec<&Path> =
            tagged.iter().filter(|(tg, _)| *tg == g).map(|(_, p)| p).collect();
        let mut out = PaddedGroup::empty(paths.len().max(1), width);
        let mut used = 0usize;
        for (i, p) in paths.iter().enumerate() {
            for (k, e) in p.elements.iter().enumerate() {
                let idx = i * width + k;
                out.fidx[idx] = e.feature;
                out.lower[idx] = e.lower.max(-F32_BIG);
                out.upper[idx] = e.upper.min(F32_BIG);
                out.zfrac[idx] = e.zero_fraction;
            }
            out.v[i] = p.leaf_value();
            out.plen[i] = p.len() as i32;
            used += p.len();
        }
        out.utilisation = used as f64 / (out.num_paths * width) as f64;
        groups.push(out);
    }
    PaddedModel {
        num_features: model.num_features,
        num_groups: model.num_groups,
        expected_values: expected.to_vec(),
        base_score: model.base_score,
        max_depth: max_len - 1,
        groups,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;
    use crate::gbdt::{train, TrainParams};

    fn packed() -> (Model, PackedModel) {
        let d = SynthSpec::adult(0.004).generate();
        let model = train(&d, &TrainParams { rounds: 3, max_depth: 4, ..Default::default() });
        let pm = pack_model(&model, Packing::BestFitDecreasing);
        (model, pm)
    }

    use crate::gbdt::Model;

    #[test]
    fn lane_layout_invariants() {
        let (_, pm) = packed();
        for g in &pm.groups {
            for b in 0..g.num_bins {
                let mut lane = 0;
                while lane < LANES && g.plen[b * LANES + lane] > 0 {
                    let e = g.plen[b * LANES + lane] as usize;
                    assert_eq!(g.pos[b * LANES + lane], 0);
                    assert_eq!(g.fidx[b * LANES + lane], -1);
                    for k in 0..e {
                        assert_eq!(g.plen[b * LANES + lane + k] as usize, e);
                        assert_eq!(g.pos[b * LANES + lane + k] as usize, k);
                    }
                    lane += e;
                }
                for k in lane..LANES {
                    assert_eq!(g.plen[b * LANES + k], 0);
                }
            }
        }
    }

    #[test]
    fn leaf_count_preserved() {
        let (model, pm) = packed();
        let total_paths: usize = pm
            .groups
            .iter()
            .flat_map(|g| (0..g.num_bins * LANES).filter(|&i| g.pos[i] == 0 && g.plen[i] > 0))
            .count();
        assert_eq!(total_paths, model.total_leaves());
    }

    #[test]
    fn padding_and_slicing() {
        let (_, pm) = packed();
        let g = &pm.groups[0];
        let padded = g.padded_to(g.num_bins + 5);
        assert_eq!(padded.num_bins, g.num_bins + 5);
        assert_eq!(&padded.fidx[..g.num_bins * LANES], &g.fidx[..]);
        let s = padded.slice_bins(1, 3);
        assert_eq!(s.num_bins, 2);
        assert_eq!(s.fidx[..], padded.fidx[LANES..3 * LANES]);
    }

    #[test]
    fn utilisation_reasonable_for_bfd() {
        let (_, pm) = packed();
        for g in &pm.groups {
            assert!(g.utilisation > 0.5, "BFD utilisation {}", g.utilisation);
        }
    }

    #[test]
    fn padded_layout_roundtrips_paths() {
        let (model, _) = packed();
        let pm = pad_model(&model, 17);
        assert_eq!(pm.groups.len(), model.num_groups);
        let total_paths: usize = pm.groups.iter().map(|g| {
            (0..g.num_paths).filter(|&i| g.plen[i] > 0).count()
        }).sum();
        assert_eq!(total_paths, model.total_leaves());
        for g in &pm.groups {
            for i in 0..g.num_paths {
                let e = g.plen[i] as usize;
                if e == 0 {
                    continue;
                }
                assert_eq!(g.fidx[i * g.width], -1); // root first
                for k in e..g.width {
                    assert_eq!(g.fidx[i * g.width + k], -1); // padding
                }
            }
            assert!(g.utilisation > 0.0 && g.utilisation <= 1.0);
        }
    }

    #[test]
    fn padded_slice_preserves_rows() {
        let (model, _) = packed();
        let pm = pad_model(&model, 9);
        let g = &pm.groups[0];
        let s = g.slice_padded(1, 3.min(g.num_paths), 8);
        assert_eq!(s.num_paths, 8);
        assert_eq!(s.width, g.width);
        assert_eq!(s.plen[0], g.plen[1]);
        assert_eq!(s.fidx[..s.width], g.fidx[g.width..2 * g.width]);
    }
}
