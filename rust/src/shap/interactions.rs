//! CPU SHAP interaction values baseline — the O(T·L·D²·M) algorithm the
//! paper's Table 7 compares against: for every feature j present in a
//! tree, evaluate TreeShap twice (j fixed present / fixed absent);
//! φ_ij = (φ_i|on − φ_i|off)/2, diagonal via Eq. 6, base value at [M, M].
//!
//! The conditioned-feature loop is embarrassingly parallel across j,
//! which is what the feature-tile shard axis exploits: the ranged
//! [`interaction_block`] kernel evaluates only the conditioned passes
//! for j ∈ [lo, hi), producing one f64 column block of the (M+1)²
//! matrix. Blocks accumulate per cell in the same per-tree order as the
//! full kernel, so an assembled tiled matrix is bit-identical to the
//! unsharded one.

use crate::gbdt::{Model, Tree};
use crate::parallel;
use crate::shap::path::expected_values;
use crate::shap::treeshap::{tree_shap_row, Condition, Scratch};

/// Sorted, deduplicated split features of one tree.
pub fn tree_features(tree: &Tree) -> Vec<i32> {
    let mut feats: Vec<i32> = (0..tree.num_nodes())
        .filter(|&i| !tree.is_leaf(i))
        .map(|i| tree.feature[i])
        .collect();
    feats.sort_unstable();
    feats.dedup();
    feats
}

/// Per-tree unique-feature lists for a whole model. This is the uncached
/// path; backends go through `PreparedModel::tile_features()`, which
/// computes these lists once per model and shares them across calls,
/// shards, and the tile splitter.
pub fn model_tree_features(model: &Model) -> Vec<Vec<i32>> {
    model.trees.iter().map(tree_features).collect()
}

/// Interaction matrices for a batch: [rows × groups × (M+1)²] row-major.
pub fn interaction_values(
    model: &Model,
    x: &[f32],
    rows: usize,
    threads: usize,
) -> Vec<f32> {
    let feats = model_tree_features(model);
    let ev = expected_values(model);
    interaction_values_with(model, x, rows, threads, &feats, &ev)
}

/// [`interaction_values`] over precomputed per-tree feature lists and
/// base values — the entry point backends use so the prepared-model
/// cache pays for both exactly once per model.
pub fn interaction_values_with(
    model: &Model,
    x: &[f32],
    rows: usize,
    threads: usize,
    feats: &[Vec<i32>],
    ev: &[f64],
) -> Vec<f32> {
    let m = model.num_features;
    let groups = model.num_groups;
    let mstride = (m + 1) * (m + 1);
    let stride = groups * mstride;
    let max_depth = model.max_depth();

    let mut out = vec![0.0f32; rows * stride];
    parallel::parallel_for_rows(threads, &mut out, stride, 2, |range, chunk| {
        let mut slab = Scratch::new(max_depth);
        let mut mat = vec![0.0f64; stride];
        let mut phis = vec![0.0f64; groups * (m + 1)];
        // zeroed once; the conditioned passes only ever write entries in
        // the tree's own feature list, which we re-zero after each use —
        // O(|tree features|) instead of O(M) per conditioned pass
        let mut on = vec![0.0f64; m + 1];
        let mut off = vec![0.0f64; m + 1];
        for (k, r) in range.enumerate() {
            mat.iter_mut().for_each(|v| *v = 0.0);
            phis.iter_mut().for_each(|v| *v = 0.0);
            let xr = &x[r * m..(r + 1) * m];
            for (ti, (tree, &g)) in model.trees.iter().zip(&model.tree_group).enumerate() {
                tree_shap_row(
                    tree,
                    xr,
                    &mut phis[g * (m + 1)..(g + 1) * (m + 1)],
                    Condition::None,
                    &mut slab,
                );
                for &j in &feats[ti] {
                    tree_shap_row(tree, xr, &mut on, Condition::On(j), &mut slab);
                    tree_shap_row(tree, xr, &mut off, Condition::Off(j), &mut slab);
                    let gm = &mut mat[g * mstride..(g + 1) * mstride];
                    // a conditioned pass only touches the tree's own
                    // features, so every other i contributes (0−0)/2
                    for &i in &feats[ti] {
                        let i = i as usize;
                        gm[i * (m + 1) + j as usize] += (on[i] - off[i]) / 2.0;
                    }
                    for &i in &feats[ti] {
                        on[i as usize] = 0.0;
                        off[i as usize] = 0.0;
                    }
                }
            }
            // diagonal (Eq. 6) + base value
            for g in 0..groups {
                let gm = &mut mat[g * mstride..(g + 1) * mstride];
                for i in 0..m {
                    let row_sum: f64 = (0..m)
                        .filter(|&j| j != i)
                        .map(|j| gm[i * (m + 1) + j])
                        .sum();
                    gm[i * (m + 1) + i] = phis[g * (m + 1) + i] - row_sum;
                }
                gm[m * (m + 1) + m] = ev[g];
            }
            let dst = &mut chunk[k * stride..(k + 1) * stride];
            for (d, s) in dst.iter_mut().zip(&mat) {
                *d = *s as f32;
            }
        }
    });
    out
}

/// Unconditioned per-feature φ in f64: [rows × groups × M], accumulated
/// per tree in the same order as [`interaction_values_with`]'s φ pass —
/// the coordinator's input to the Eq. 6 diagonal on assembled tiles.
/// No base-value slot: the caller places E[f] at [M, M] itself.
pub fn phis_f64(model: &Model, x: &[f32], rows: usize, threads: usize) -> Vec<f64> {
    let m = model.num_features;
    let groups = model.num_groups;
    let stride = groups * (m + 1);
    let max_depth = model.max_depth();
    let mut out = vec![0.0f64; rows * groups * m];
    parallel::parallel_for_rows(threads, &mut out, groups * m, 8, |range, chunk| {
        let mut slab = Scratch::new(max_depth);
        let mut phis = vec![0.0f64; stride];
        for (k, r) in range.enumerate() {
            phis.iter_mut().for_each(|v| *v = 0.0);
            let xr = &x[r * m..(r + 1) * m];
            for (tree, &g) in model.trees.iter().zip(&model.tree_group) {
                tree_shap_row(
                    tree,
                    xr,
                    &mut phis[g * (m + 1)..(g + 1) * (m + 1)],
                    Condition::None,
                    &mut slab,
                );
            }
            for g in 0..groups {
                let dst = &mut chunk[k * groups * m + g * m..k * groups * m + (g + 1) * m];
                dst.copy_from_slice(&phis[g * (m + 1)..g * (m + 1) + m]);
            }
        }
    });
    out
}

/// One feature tile of the off-diagonal interaction matrix, exact:
/// f64 [rows × groups × M × (hi−lo)] where entry (r, g, i, j−lo) is
/// Σ_trees (φ_i|j on − φ_i|j off)/2 — the full column j of the matrix
/// for every conditioned feature j ∈ [lo, hi). Cell sums run over trees
/// in model order, so assembling tiles side by side reproduces the
/// unsharded [`interaction_values`] f64 accumulations bit-for-bit.
/// Trees with no split feature inside the tile are skipped entirely —
/// the M ≫ D sparsity win that makes narrow tiles cheap on wide models.
pub fn interaction_block(
    model: &Model,
    x: &[f32],
    rows: usize,
    threads: usize,
    lo: usize,
    hi: usize,
    feats: &[Vec<i32>],
) -> Vec<f64> {
    let m = model.num_features;
    let groups = model.num_groups;
    let width = hi - lo;
    let bstride = groups * m * width;
    let max_depth = model.max_depth();
    // per-tree sub-ranges of the sorted feature lists that fall in the tile
    let spans: Vec<(usize, usize)> = feats
        .iter()
        .map(|f| {
            let a = f.partition_point(|&j| (j as usize) < lo);
            let b = f.partition_point(|&j| (j as usize) < hi);
            (a, b)
        })
        .collect();
    let mut out = vec![0.0f64; rows * bstride];
    parallel::parallel_for_rows(threads, &mut out, bstride, 2, |range, chunk| {
        let mut slab = Scratch::new(max_depth);
        let mut on = vec![0.0f64; m + 1];
        let mut off = vec![0.0f64; m + 1];
        for (k, r) in range.enumerate() {
            let xr = &x[r * m..(r + 1) * m];
            let block = &mut chunk[k * bstride..(k + 1) * bstride];
            for (ti, (tree, &g)) in model.trees.iter().zip(&model.tree_group).enumerate() {
                let (a, b) = spans[ti];
                if a == b {
                    continue; // tree has no feature in this tile
                }
                for &j in &feats[ti][a..b] {
                    tree_shap_row(tree, xr, &mut on, Condition::On(j), &mut slab);
                    tree_shap_row(tree, xr, &mut off, Condition::Off(j), &mut slab);
                    let gb = &mut block[g * m * width..(g + 1) * m * width];
                    let col = j as usize - lo;
                    for &i in &feats[ti] {
                        let i = i as usize;
                        gb[i * width + col] += (on[i] - off[i]) / 2.0;
                    }
                    for &i in &feats[ti] {
                        on[i as usize] = 0.0;
                        off[i as usize] = 0.0;
                    }
                }
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;
    use crate::gbdt::{train, TrainParams};
    use crate::shap::treeshap::shap_values;

    #[test]
    fn rows_sum_to_phi() {
        let d = SynthSpec::cal_housing(0.005).generate();
        let model = train(&d, &TrainParams { rounds: 4, max_depth: 4, ..Default::default() });
        let m = model.num_features;
        let rows = 6;
        let inter = interaction_values(&model, &d.features[..rows * m], rows, 1);
        let phis = shap_values(&model, &d.features[..rows * m], rows, 1);
        let ms = (m + 1) * (m + 1);
        for r in 0..rows {
            for i in 0..m {
                let s: f64 = (0..m)
                    .map(|j| inter[r * ms + i * (m + 1) + j] as f64)
                    .sum();
                let phi = phis[r * (m + 1) + i] as f64;
                assert!((s - phi).abs() < 1e-3, "row {r} feat {i}: {s} vs {phi}");
            }
        }
    }

    #[test]
    fn matrix_symmetric() {
        let d = SynthSpec::adult(0.003).generate();
        let model = train(&d, &TrainParams { rounds: 3, max_depth: 4, ..Default::default() });
        let m = model.num_features;
        let rows = 4;
        let inter = interaction_values(&model, &d.features[..rows * m], rows, 2);
        let ms = (m + 1) * (m + 1);
        for r in 0..rows {
            for i in 0..m {
                for j in 0..m {
                    let a = inter[r * ms + i * (m + 1) + j];
                    let b = inter[r * ms + j * (m + 1) + i];
                    assert!((a - b).abs() < 2e-4, "asym at ({i},{j}): {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn total_sums_to_prediction() {
        // Σ_ij φ_ij + E[f] == f(x)
        let d = SynthSpec::cal_housing(0.004).generate();
        let model = train(&d, &TrainParams { rounds: 3, max_depth: 3, ..Default::default() });
        let m = model.num_features;
        let rows = 4;
        let inter = interaction_values(&model, &d.features[..rows * m], rows, 1);
        let ms = (m + 1) * (m + 1);
        for r in 0..rows {
            let total: f64 = inter[r * ms..(r + 1) * ms].iter().map(|&v| v as f64).sum();
            let pred = model.predict_row_raw(d.row(r))[0] as f64;
            assert!((total - pred).abs() < 1e-3, "{total} vs {pred}");
        }
    }

    #[test]
    fn blocks_assemble_to_full_matrix_bitwise() {
        // tiles of the off-diagonal columns + the f64 φ pass reproduce
        // the full kernel exactly (same f64 sums in the same order)
        let d = SynthSpec::adult(0.004).generate();
        let model = train(&d, &TrainParams { rounds: 4, max_depth: 5, ..Default::default() });
        let m = model.num_features;
        let groups = model.num_groups;
        let rows = 5;
        let x = &d.features[..rows * m];
        let full = interaction_values(&model, x, rows, 1);
        let feats = model_tree_features(&model);
        let ev = expected_values(&model);
        let phis = phis_f64(&model, x, rows, 1);
        let cuts = [0, 2, 3, m];
        let ms = (m + 1) * (m + 1);
        let mut asm = vec![0.0f64; rows * groups * ms];
        for w in cuts.windows(2) {
            let (lo, hi) = (w[0], w[1]);
            let width = hi - lo;
            let block = interaction_block(&model, x, rows, 1, lo, hi, &feats);
            for r in 0..rows {
                for g in 0..groups {
                    for i in 0..m {
                        for j in lo..hi {
                            asm[(r * groups + g) * ms + i * (m + 1) + j] =
                                block[(r * groups + g) * m * width + i * width + (j - lo)];
                        }
                    }
                }
            }
        }
        for r in 0..rows {
            for g in 0..groups {
                let gm = &mut asm[(r * groups + g) * ms..(r * groups + g + 1) * ms];
                for i in 0..m {
                    let row_sum: f64 = (0..m)
                        .filter(|&j| j != i)
                        .map(|j| gm[i * (m + 1) + j])
                        .sum();
                    gm[i * (m + 1) + i] =
                        phis[(r * groups + g) * m + i] - row_sum;
                }
                gm[m * (m + 1) + m] = ev[g];
            }
        }
        for (i, (a, b)) in full.iter().zip(&asm).enumerate() {
            assert!(
                *a == *b as f32,
                "tile assembly not bit-identical at {i}: {a} vs {b}"
            );
        }
    }
}
