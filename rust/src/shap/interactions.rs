//! CPU SHAP interaction values baseline — the O(T·L·D²·M) algorithm the
//! paper's Table 7 compares against: for every feature j present in a
//! tree, evaluate TreeShap twice (j fixed present / fixed absent);
//! φ_ij = (φ_i|on − φ_i|off)/2, diagonal via Eq. 6, base value at [M, M].

use crate::gbdt::{Model, Tree};
use crate::parallel;
use crate::shap::path::expected_values;
use crate::shap::treeshap::{tree_shap_row, Condition, Scratch};

fn tree_features(tree: &Tree) -> Vec<i32> {
    let mut feats: Vec<i32> = (0..tree.num_nodes())
        .filter(|&i| !tree.is_leaf(i))
        .map(|i| tree.feature[i])
        .collect();
    feats.sort_unstable();
    feats.dedup();
    feats
}

/// Interaction matrices for a batch: [rows × groups × (M+1)²] row-major.
pub fn interaction_values(
    model: &Model,
    x: &[f32],
    rows: usize,
    threads: usize,
) -> Vec<f32> {
    let m = model.num_features;
    let groups = model.num_groups;
    let ev = expected_values(model);
    let mstride = (m + 1) * (m + 1);
    let stride = groups * mstride;
    let max_depth = model.max_depth();
    // precompute per-tree feature lists once
    let feats: Vec<Vec<i32>> = model.trees.iter().map(tree_features).collect();

    let mut out = vec![0.0f32; rows * stride];
    parallel::parallel_for_rows(threads, &mut out, stride, 2, |range, chunk| {
        let mut slab = Scratch::new(max_depth);
        let mut mat = vec![0.0f64; stride];
        let mut phis = vec![0.0f64; groups * (m + 1)];
        let mut on = vec![0.0f64; m + 1];
        let mut off = vec![0.0f64; m + 1];
        for (k, r) in range.enumerate() {
            mat.iter_mut().for_each(|v| *v = 0.0);
            phis.iter_mut().for_each(|v| *v = 0.0);
            let xr = &x[r * m..(r + 1) * m];
            for (ti, (tree, &g)) in model.trees.iter().zip(&model.tree_group).enumerate() {
                tree_shap_row(
                    tree,
                    xr,
                    &mut phis[g * (m + 1)..(g + 1) * (m + 1)],
                    Condition::None,
                    &mut slab,
                );
                for &j in &feats[ti] {
                    on.iter_mut().for_each(|v| *v = 0.0);
                    off.iter_mut().for_each(|v| *v = 0.0);
                    tree_shap_row(tree, xr, &mut on, Condition::On(j), &mut slab);
                    tree_shap_row(tree, xr, &mut off, Condition::Off(j), &mut slab);
                    let gm = &mut mat[g * mstride..(g + 1) * mstride];
                    for i in 0..m {
                        gm[i * (m + 1) + j as usize] += (on[i] - off[i]) / 2.0;
                    }
                }
            }
            // diagonal (Eq. 6) + base value
            for g in 0..groups {
                let gm = &mut mat[g * mstride..(g + 1) * mstride];
                for i in 0..m {
                    let row_sum: f64 = (0..m)
                        .filter(|&j| j != i)
                        .map(|j| gm[i * (m + 1) + j])
                        .sum();
                    gm[i * (m + 1) + i] = phis[g * (m + 1) + i] - row_sum;
                }
                gm[m * (m + 1) + m] = ev[g];
            }
            let dst = &mut chunk[k * stride..(k + 1) * stride];
            for (d, s) in dst.iter_mut().zip(&mat) {
                *d = *s as f32;
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;
    use crate::gbdt::{train, TrainParams};
    use crate::shap::treeshap::shap_values;

    #[test]
    fn rows_sum_to_phi() {
        let d = SynthSpec::cal_housing(0.005).generate();
        let model = train(&d, &TrainParams { rounds: 4, max_depth: 4, ..Default::default() });
        let m = model.num_features;
        let rows = 6;
        let inter = interaction_values(&model, &d.features[..rows * m], rows, 1);
        let phis = shap_values(&model, &d.features[..rows * m], rows, 1);
        let ms = (m + 1) * (m + 1);
        for r in 0..rows {
            for i in 0..m {
                let s: f64 = (0..m)
                    .map(|j| inter[r * ms + i * (m + 1) + j] as f64)
                    .sum();
                let phi = phis[r * (m + 1) + i] as f64;
                assert!((s - phi).abs() < 1e-3, "row {r} feat {i}: {s} vs {phi}");
            }
        }
    }

    #[test]
    fn matrix_symmetric() {
        let d = SynthSpec::adult(0.003).generate();
        let model = train(&d, &TrainParams { rounds: 3, max_depth: 4, ..Default::default() });
        let m = model.num_features;
        let rows = 4;
        let inter = interaction_values(&model, &d.features[..rows * m], rows, 2);
        let ms = (m + 1) * (m + 1);
        for r in 0..rows {
            for i in 0..m {
                for j in 0..m {
                    let a = inter[r * ms + i * (m + 1) + j];
                    let b = inter[r * ms + j * (m + 1) + i];
                    assert!((a - b).abs() < 2e-4, "asym at ({i},{j}): {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn total_sums_to_prediction() {
        // Σ_ij φ_ij + E[f] == f(x)
        let d = SynthSpec::cal_housing(0.004).generate();
        let model = train(&d, &TrainParams { rounds: 3, max_depth: 3, ..Default::default() });
        let m = model.num_features;
        let rows = 4;
        let inter = interaction_values(&model, &d.features[..rows * m], rows, 1);
        let ms = (m + 1) * (m + 1);
        for r in 0..rows {
            let total: f64 = inter[r * ms..(r + 1) * ms].iter().map(|&v| v as f64).sum();
            let pred = model.predict_row_raw(d.row(r))[0] as f64;
            assert!((total - pred).abs() < 1e-3, "{total} vs {pred}");
        }
    }
}
