//! Path extraction and duplicate-feature merging (paper §3.1–3.2).
//!
//! Every unique root→leaf path of a decision tree becomes a list of
//! `PathElement`s: the root/bias element (feature −1) followed by one
//! element per *unique* feature split on the path. Repeated features are
//! merged by intersecting their value intervals (a path is a
//! hyperrectangle) and multiplying their zero_fractions — removing the
//! FINDFIRST/UNWIND branching of the recursive algorithm.

use crate::gbdt::{Model, Tree};

/// One merged feature occurrence on a path (paper Listing 1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PathElement {
    /// feature index, −1 for the root/bias element
    pub feature: i32,
    /// stay on this path iff lower ≤ x < upper (when feature present)
    pub lower: f32,
    pub upper: f32,
    /// P(stay on path | feature missing) — product of cover ratios
    pub zero_fraction: f32,
    /// leaf value of the owning path
    pub v: f32,
}

/// A unique root→leaf path; `elements[0]` is always the root element.
#[derive(Clone, Debug, Default)]
pub struct Path {
    pub elements: Vec<PathElement>,
}

impl Path {
    pub fn len(&self) -> usize {
        self.elements.len()
    }

    pub fn is_empty(&self) -> bool {
        self.elements.is_empty()
    }

    pub fn leaf_value(&self) -> f32 {
        self.elements.last().map_or(0.0, |e| e.v)
    }

    /// P(reach this leaf) under cover weighting: Π zero_fraction.
    pub fn reach_probability(&self) -> f64 {
        self.elements.iter().map(|e| e.zero_fraction as f64).product()
    }
}

/// Extract all unique paths of `tree` with duplicates merged.
pub fn extract_paths(tree: &Tree) -> Vec<Path> {
    let mut out = Vec::with_capacity(tree.num_leaves());
    let mut stack: Vec<PathElement> = vec![PathElement {
        feature: -1,
        lower: f32::NEG_INFINITY,
        upper: f32::INFINITY,
        zero_fraction: 1.0,
        v: 0.0,
    }];
    walk(tree, 0, &mut stack, &mut out);
    out
}

fn walk(tree: &Tree, node: usize, stack: &mut Vec<PathElement>, out: &mut Vec<Path>) {
    if tree.is_leaf(node) {
        let v = tree.value[node];
        let mut merged = merge_duplicates(stack);
        for e in &mut merged.elements {
            e.v = v;
        }
        out.push(merged);
        return;
    }
    let f = tree.feature[node];
    let t = tree.threshold[node];
    let cov = tree.cover[node];
    let (l, r) = (tree.left[node] as usize, tree.right[node] as usize);

    stack.push(PathElement {
        feature: f,
        lower: f32::NEG_INFINITY,
        upper: t,
        zero_fraction: tree.cover[l] / cov,
        v: 0.0,
    });
    walk(tree, l, stack, out);
    stack.pop();

    stack.push(PathElement {
        feature: f,
        lower: t,
        upper: f32::INFINITY,
        zero_fraction: tree.cover[r] / cov,
        v: 0.0,
    });
    walk(tree, r, stack, out);
    stack.pop();
}

/// Merge repeated features: intervals intersect, zero_fractions multiply.
/// Elements are sorted by feature (EXTEND/UNWIND commute, order is free).
pub fn merge_duplicates(raw: &[PathElement]) -> Path {
    debug_assert_eq!(raw[0].feature, -1);
    let mut merged: Vec<PathElement> = Vec::with_capacity(raw.len());
    merged.push(raw[0]);
    for e in &raw[1..] {
        match merged[1..].iter_mut().find(|m| m.feature == e.feature) {
            Some(m) => {
                m.lower = m.lower.max(e.lower);
                m.upper = m.upper.min(e.upper);
                m.zero_fraction *= e.zero_fraction;
            }
            None => merged.push(*e),
        }
    }
    merged[1..].sort_by_key(|e| e.feature);
    Path { elements: merged }
}

/// All paths of a model, tagged with the tree's output group.
pub fn model_paths(model: &Model) -> Vec<(usize, Path)> {
    let mut out = Vec::new();
    for (tree, &g) in model.trees.iter().zip(&model.tree_group) {
        for p in extract_paths(tree) {
            out.push((g, p));
        }
    }
    out
}

/// E[f] per output group under cover weighting (the φ base values),
/// including the model's base_score.
pub fn expected_values(model: &Model) -> Vec<f64> {
    expected_values_from_paths(model.base_score, model.num_groups, &model_paths(model))
}

/// As [`expected_values`], over already-extracted tagged paths — the
/// prepared-model cache's entry point, so one extraction serves the
/// base values, the shape statistics and every packed layout. Summation
/// order matches [`expected_values`] exactly (bit-identical results).
pub fn expected_values_from_paths(
    base_score: f32,
    num_groups: usize,
    paths: &[(usize, Path)],
) -> Vec<f64> {
    let mut ev = vec![base_score as f64; num_groups];
    for (g, p) in paths {
        ev[*g] += p.reach_probability() * p.leaf_value() as f64;
    }
    ev
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;
    use crate::gbdt::{train, TrainParams};

    fn small_model() -> Model {
        let d = SynthSpec::adult(0.005).generate();
        train(&d, &TrainParams { rounds: 3, max_depth: 4, ..Default::default() })
    }

    #[test]
    fn one_path_per_leaf() {
        let model = small_model();
        for t in &model.trees {
            assert_eq!(extract_paths(t).len(), t.num_leaves());
        }
    }

    #[test]
    fn paths_start_at_root_and_carry_leaf_value() {
        let model = small_model();
        for t in &model.trees {
            for p in extract_paths(t) {
                assert_eq!(p.elements[0].feature, -1);
                assert!(p.elements.iter().all(|e| e.v == p.leaf_value()));
            }
        }
    }

    #[test]
    fn features_unique_and_sorted_after_merge() {
        let model = small_model();
        for t in &model.trees {
            for p in extract_paths(t) {
                let feats: Vec<i32> = p.elements[1..].iter().map(|e| e.feature).collect();
                let mut sorted = feats.clone();
                sorted.sort_unstable();
                sorted.dedup();
                assert_eq!(feats, sorted, "not unique+sorted: {feats:?}");
            }
        }
    }

    #[test]
    fn reach_probabilities_sum_to_one_per_tree() {
        let model = small_model();
        for t in &model.trees {
            let total: f64 = extract_paths(t).iter().map(|p| p.reach_probability()).sum();
            assert!((total - 1.0).abs() < 1e-4, "{total}");
        }
    }

    #[test]
    fn intervals_consistent_with_tree_walk() {
        // a row inside every interval of a path must reach that leaf
        let model = small_model();
        let m = model.num_features;
        for t in model.trees.iter().take(2) {
            for p in extract_paths(t) {
                let mut x = vec![0.0f32; m];
                let mut representable = true;
                for e in &p.elements[1..] {
                    if e.lower >= e.upper {
                        representable = false;
                        break;
                    }
                    let mid = if e.lower.is_infinite() && e.upper.is_infinite() {
                        0.0
                    } else if e.lower.is_infinite() {
                        e.upper - 1.0
                    } else if e.upper.is_infinite() {
                        e.lower + 1.0
                    } else {
                        0.5 * (e.lower + e.upper)
                    };
                    x[e.feature as usize] = mid;
                }
                if representable {
                    assert_eq!(t.predict_row(&x), p.leaf_value());
                }
            }
        }
    }

    #[test]
    fn expected_value_matches_mean_prediction() {
        // E[f] under cover weighting == cover-weighted mean of leaves; for
        // squared loss cover == row count, so it equals the mean training
        // prediction of each tree.
        let d = SynthSpec::cal_housing(0.005).generate();
        let model = train(&d, &TrainParams { rounds: 4, max_depth: 4, ..Default::default() });
        let ev = expected_values(&model)[0];
        let mut mean = 0.0f64;
        for r in 0..d.rows {
            mean += model.predict_row_raw(d.row(r))[0] as f64;
        }
        mean /= d.rows as f64;
        assert!((ev - mean).abs() < 1e-3, "ev {ev} mean {mean}");
    }
}
