//! Host (rust-native) evaluation of the packed-path DP — the same math
//! the L1 Pallas kernel vectorizes, executed directly over `PackedGroup`
//! tensors. Two roles:
//!
//! 1. **Parity oracle**: runtime output must equal this bit-for-bit-ish
//!    (same f32 inputs, same DP recurrence) — checked in `tests/parity.rs`.
//! 2. **Ablation backend**: "the GPU algorithm on a CPU", isolating the
//!    gain from the algorithm reformulation vs the accelerator.

use crate::parallel;
use crate::shap::packed::{PackedGroup, PackedModel};
use crate::shap::LANES;

#[inline]
fn one_fraction(g: &PackedGroup, i: usize, x: &[f32]) -> f64 {
    let f = g.fidx[i];
    if f < 0 {
        return 0.0;
    }
    let v = x[f as usize];
    if v >= g.lower[i] && v < g.upper[i] {
        1.0
    } else {
        0.0
    }
}

/// Fill `of[k] = one_fraction(start + k)` for one (row, path). The DP
/// (`path_weights`), the unwind (`unwound_sum`) and the outer φ/Φ loops
/// all consume the same activations — ~O(len) interval checks per DP
/// step before, exactly `len` per (row, path) now. Value-identical:
/// `one_fraction` yields exact 0.0/1.0, and buffering changes no
/// arithmetic, only where the indicator is evaluated.
#[inline]
fn activations(g: &PackedGroup, start: usize, len: usize, x: &[f32], of: &mut [f64; LANES]) {
    for k in 0..len {
        of[k] = one_fraction(g, start + k, x);
    }
}

/// EXTEND over one path (lanes [start, start+len)), weights out.
/// `of[k]` is the precomputed activation of in-path offset `k`
/// (see [`activations`]) — computed once per (row, path) by the caller
/// instead of re-deriving it inside every DP step.
fn path_weights(g: &PackedGroup, start: usize, len: usize, of: &[f64], w: &mut [f64], skip: usize) {
    let eff_len = if skip < len { len - 1 } else { len };
    let map = |q: usize| if skip < len && q >= skip { q + 1 } else { q };
    for wi in w.iter_mut().take(eff_len) {
        *wi = 0.0;
    }
    w[0] = 1.0;
    let mut prev = [0.0f64; LANES];
    for d in 1..eff_len {
        let ed = start + map(d);
        let zd = g.zfrac[ed] as f64;
        let od = of[map(d)];
        prev[..eff_len].copy_from_slice(&w[..eff_len]);
        for p in 0..eff_len {
            let lw = if p > 0 { prev[p - 1] } else { 0.0 };
            w[p] = zd * prev[p] * (d as f64 - p as f64) / (d + 1) as f64
                + od * lw * p as f64 / (d + 1) as f64;
        }
    }
}

/// UNWOUNDSUM for the element at remapped position `i`. `of` as in
/// [`path_weights`]: the row's precomputed per-offset activations.
fn unwound_sum(
    g: &PackedGroup,
    start: usize,
    len: usize,
    of: &[f64],
    w: &[f64],
    i: usize,
    skip: usize,
) -> f64 {
    let eff_len = if skip < len { len - 1 } else { len };
    let map = |q: usize| if skip < len && q >= skip { q + 1 } else { q };
    let l = eff_len - 1;
    let e = start + map(i);
    let o = of[map(i)];
    let z = g.zfrac[e] as f64;
    let mut nxt = w[l];
    let mut total = 0.0;
    if o != 0.0 {
        for j in (0..l).rev() {
            let tmp = nxt / ((j + 1) as f64 * o);
            total += tmp;
            nxt = w[j] - tmp * z * (l - j) as f64;
        }
    } else {
        for j in (0..l).rev() {
            total += w[j] / (z * (l - j) as f64);
        }
    }
    total * (l + 1) as f64
}

/// φ contributions of one packed group for one row, added into
/// `phis[0..=M]` (slot M untouched — base value is the caller's job).
pub fn shap_row(g: &PackedGroup, x: &[f32], phis: &mut [f64]) {
    let mut w = [0.0f64; LANES];
    let mut of = [0.0f64; LANES];
    for b in 0..g.num_bins {
        let mut lane = 0usize;
        while lane < LANES {
            let i0 = b * LANES + lane;
            let len = g.plen[i0] as usize;
            if len == 0 {
                break;
            }
            let start = i0;
            let v = g.v[start] as f64;
            // dead-leaf skip (the prepared-model contribution bound at
            // exactly zero): every term this path could add is ±0, so
            // skipping is value-identical and saves the whole DP
            if v == 0.0 {
                lane += len;
                continue;
            }
            activations(g, start, len, x, &mut of);
            path_weights(g, start, len, &of, &mut w, usize::MAX);
            for k in 1..len {
                let e = start + k;
                let s = unwound_sum(g, start, len, &of, &w, k, usize::MAX);
                phis[g.fidx[e] as usize] += s * (of[k] - g.zfrac[e] as f64) * v;
            }
            lane += len;
        }
    }
}

/// Off-diagonal interaction contributions of one group for one row,
/// added into `mat[(M+1)²]`. The O(TLD³) formulation: condition only on
/// on-path positions; one DP serves the present and absent cases.
pub fn interactions_row(g: &PackedGroup, x: &[f32], m: usize, mat: &mut [f64]) {
    let mut w = [0.0f64; LANES];
    let mut of = [0.0f64; LANES];
    for b in 0..g.num_bins {
        let mut lane = 0usize;
        while lane < LANES {
            let i0 = b * LANES + lane;
            let len = g.plen[i0] as usize;
            if len == 0 {
                break;
            }
            let start = i0;
            let v = g.v[start] as f64;
            // dead-leaf skip: as in `shap_row`, exactly-zero leaves
            // contribute ±0 to every pair — skipping is value-identical
            if v == 0.0 {
                lane += len;
                continue;
            }
            activations(g, start, len, x, &mut of);
            for k in 1..len {
                let ek = start + k;
                let ok = of[k];
                let zk = g.zfrac[ek] as f64;
                let fk = g.fidx[ek] as usize;
                path_weights(g, start, len, &of, &mut w, k);
                for q in 1..len - 1 {
                    // remapped position q corresponds to original q + (q>=k)
                    let orig = if q >= k { q + 1 } else { q };
                    let e = start + orig;
                    let s = unwound_sum(g, start, len, &of, &w, q, k);
                    let contrib = s * (of[orig] - g.zfrac[e] as f64) * v;
                    let fi = g.fidx[e] as usize;
                    mat[fi * (m + 1) + fk] += 0.5 * contrib * (ok - zk);
                }
            }
            lane += len;
        }
    }
}

/// Remove one on-path element (activation `o`, zero-fraction `z`) from a
/// full EXTEND weight vector of length `len`, writing the `len − 1`
/// weights the DP would have produced had the element never been
/// extended. EXTEND steps commute, so unwinding the element is exact
/// regardless of its position; this replaces an O(len²) DP re-run per
/// conditioned position with an O(len) unwind off one shared DP.
fn unwind_weights(w: &[f64], len: usize, o: f64, z: f64, out: &mut [f64]) {
    let lf = len as f64;
    if o != 0.0 {
        let mut next = 0.0f64;
        for p in (1..len).rev() {
            let v = (w[p] - z * next * (len - 1 - p) as f64 / lf) * lf / (o * p as f64);
            out[p - 1] = v;
            next = v;
        }
    } else {
        for p in 0..len - 1 {
            out[p] = w[p] * lf / (z * (len - 1 - p) as f64);
        }
    }
}

/// One feature tile of the off-diagonal interaction matrix, f64
/// [M × (hi−lo)] per (row, group), in **owner-symmetric** layout: each
/// unordered feature pair {a, b} (a < b) is computed exactly once, by
/// the tile owning b = max(a, b), and stored at (row a, col b − lo).
/// The coordinator reads cell (i, j) from the owner block's
/// (min, max − lo) entry — valid because φ_ab = φ_ba holds per path.
///
/// Work per tile: one full DP per path (O(len²)), one O(len) unwind per
/// in-tile conditioned position, one O(len) UNWOUNDSUM per surviving
/// pair — summed over tiles each pair is priced once, where the legacy
/// [`interactions_row`] pays a DP re-run per conditioned position and
/// prices every ordered pair. The legacy kernel stays as-is: it is the
/// Pallas parity oracle, and its accumulation order is pinned by tests.
pub fn interactions_row_block(
    g: &PackedGroup,
    x: &[f32],
    lo: usize,
    hi: usize,
    block: &mut [f64],
) {
    let width = hi - lo;
    let mut w = [0.0f64; LANES];
    let mut wk = [0.0f64; LANES];
    let mut of = [0.0f64; LANES];
    for b in 0..g.num_bins {
        let mut lane = 0usize;
        while lane < LANES {
            let i0 = b * LANES + lane;
            let len = g.plen[i0] as usize;
            if len == 0 {
                break;
            }
            let start = i0;
            let v = g.v[start] as f64;
            // dead-leaf skip: exactly-zero leaves contribute ±0 everywhere
            if v == 0.0 || len < 3 {
                lane += len;
                continue;
            }
            activations(g, start, len, x, &mut of);
            path_weights(g, start, len, &of, &mut w, usize::MAX);
            for k in 1..len {
                let ek = start + k;
                let fk = g.fidx[ek] as usize;
                if fk < lo || fk >= hi {
                    continue;
                }
                let ok = of[k];
                let zk = g.zfrac[ek] as f64;
                unwind_weights(&w[..len], len, ok, zk, &mut wk);
                for q in 1..len - 1 {
                    // remapped position q corresponds to original q + (q>=k)
                    let orig = if q >= k { q + 1 } else { q };
                    let e = start + orig;
                    let fq = g.fidx[e] as usize;
                    // owner-symmetric: keep only pairs this tile owns
                    // (fq < fk); fq == fk is a diagonal cell the
                    // coordinator overwrites via Eq. 6 anyway
                    if fq >= fk {
                        continue;
                    }
                    let s = unwound_sum(g, start, len, &of, &wk, q, k);
                    let contrib = s * (of[orig] - g.zfrac[e] as f64) * v;
                    block[fq * width + (fk - lo)] += 0.5 * contrib * (ok - zk);
                }
            }
            lane += len;
        }
    }
}

/// Batched owner-symmetric interaction tile (see
/// [`interactions_row_block`]): f64 [rows × groups × M × (hi−lo)].
pub fn interaction_block(
    pm: &PackedModel,
    x: &[f32],
    rows: usize,
    threads: usize,
    lo: usize,
    hi: usize,
) -> Vec<f64> {
    let m = pm.num_features;
    let groups = pm.num_groups;
    let width = hi - lo;
    let bstride = groups * m * width;
    let mut out = vec![0.0f64; rows * bstride];
    parallel::parallel_for_rows(threads, &mut out, bstride, 2, |range, chunk| {
        for (k, r) in range.enumerate() {
            let xr = &x[r * m..(r + 1) * m];
            for (gi, g) in pm.groups.iter().enumerate() {
                let gb = &mut chunk
                    [k * bstride + gi * m * width..k * bstride + (gi + 1) * m * width];
                interactions_row_block(g, xr, lo, hi, gb);
            }
        }
    });
    out
}

/// Unconditioned per-feature φ in f64: [rows × groups × M] — the
/// coordinator's input to the Eq. 6 diagonal on assembled tiles. No
/// base-value slot; the caller places E[f] at [M, M] itself.
pub fn phis_f64(pm: &PackedModel, x: &[f32], rows: usize, threads: usize) -> Vec<f64> {
    let m = pm.num_features;
    let groups = pm.num_groups;
    let stride = groups * m;
    let mut out = vec![0.0f64; rows * stride];
    parallel::parallel_for_rows(threads, &mut out, stride, 8, |range, chunk| {
        let mut phis = vec![0.0f64; m + 1];
        for (k, r) in range.enumerate() {
            let xr = &x[r * m..(r + 1) * m];
            for (gi, g) in pm.groups.iter().enumerate() {
                phis.iter_mut().for_each(|p| *p = 0.0);
                shap_row(g, xr, &mut phis);
                chunk[k * stride + gi * m..k * stride + (gi + 1) * m]
                    .copy_from_slice(&phis[..m]);
            }
        }
    });
    out
}

/// Batched SHAP values over all groups: [rows × groups × (M+1)],
/// base values included (mirrors `treeshap::shap_values` output layout).
pub fn shap_values(pm: &PackedModel, x: &[f32], rows: usize, threads: usize) -> Vec<f32> {
    let m = pm.num_features;
    let groups = pm.num_groups;
    let stride = groups * (m + 1);
    let mut out = vec![0.0f32; rows * stride];
    parallel::parallel_for_rows(threads, &mut out, stride, 8, |range, chunk| {
        let mut phis = vec![0.0f64; m + 1];
        for (k, r) in range.enumerate() {
            let xr = &x[r * m..(r + 1) * m];
            for (gi, g) in pm.groups.iter().enumerate() {
                phis.iter_mut().for_each(|p| *p = 0.0);
                shap_row(g, xr, &mut phis);
                phis[m] += pm.expected_values[gi];
                let dst =
                    &mut chunk[k * stride + gi * (m + 1)..k * stride + (gi + 1) * (m + 1)];
                for (d, s) in dst.iter_mut().zip(&phis) {
                    *d = *s as f32;
                }
            }
        }
    });
    out
}

/// Batched interaction values: [rows × groups × (M+1)²], diagonal via
/// Eq. 6, base at [M, M].
pub fn interaction_values(pm: &PackedModel, x: &[f32], rows: usize, threads: usize) -> Vec<f32> {
    let m = pm.num_features;
    let groups = pm.num_groups;
    let ms = (m + 1) * (m + 1);
    let stride = groups * ms;
    let mut out = vec![0.0f32; rows * stride];
    parallel::parallel_for_rows(threads, &mut out, stride, 2, |range, chunk| {
        let mut mat = vec![0.0f64; ms];
        let mut phis = vec![0.0f64; m + 1];
        for (k, r) in range.enumerate() {
            let xr = &x[r * m..(r + 1) * m];
            for (gi, g) in pm.groups.iter().enumerate() {
                mat.iter_mut().for_each(|v| *v = 0.0);
                phis.iter_mut().for_each(|v| *v = 0.0);
                interactions_row(g, xr, m, &mut mat);
                shap_row(g, xr, &mut phis);
                for i in 0..m {
                    let row_sum: f64 = (0..m)
                        .filter(|&j| j != i)
                        .map(|j| mat[i * (m + 1) + j])
                        .sum();
                    mat[i * (m + 1) + i] = phis[i] - row_sum;
                }
                mat[m * (m + 1) + m] = pm.expected_values[gi];
                let dst = &mut chunk[k * stride + gi * ms..k * stride + (gi + 1) * ms];
                for (d, s) in dst.iter_mut().zip(&mat) {
                    *d = *s as f32;
                }
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;
    use crate::gbdt::{train, TrainParams};
    use crate::shap::packed::pack_model;
    use crate::shap::Packing;
    use crate::shap::treeshap;

    fn setup(depth: usize) -> (crate::gbdt::Model, PackedModel, crate::data::Dataset) {
        let d = SynthSpec::cal_housing(0.006).generate();
        let model =
            train(&d, &TrainParams { rounds: 5, max_depth: depth, ..Default::default() });
        let pm = pack_model(&model, Packing::BestFitDecreasing);
        (model, pm, d)
    }

    #[test]
    fn matches_recursive_baseline() {
        let (model, pm, d) = setup(5);
        let m = model.num_features;
        let rows = 24;
        let a = treeshap::shap_values(&model, &d.features[..rows * m], rows, 1);
        let b = shap_values(&pm, &d.features[..rows * m], rows, 1);
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!((x - y).abs() < 2e-4, "idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn interactions_match_recursive_baseline() {
        let (model, pm, d) = setup(4);
        let m = model.num_features;
        let rows = 4;
        let a = crate::shap::interactions::interaction_values(
            &model, &d.features[..rows * m], rows, 1,
        );
        let b = interaction_values(&pm, &d.features[..rows * m], rows, 1);
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!((x - y).abs() < 2e-4, "idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn multiclass_groups() {
        let d = SynthSpec::covtype(0.0006).generate();
        let model = train(&d, &TrainParams { rounds: 2, max_depth: 4, ..Default::default() });
        let pm = pack_model(&model, Packing::BestFitDecreasing);
        let m = model.num_features;
        let rows = 4;
        let a = treeshap::shap_values(&model, &d.features[..rows * m], rows, 1);
        let b = shap_values(&pm, &d.features[..rows * m], rows, 1);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 2e-4);
        }
    }

    #[test]
    fn unwind_matches_skip_dp() {
        // unwinding element k off the full DP must reproduce the
        // DP-with-skip weight vector (exact algebra, fp noise only)
        let (_, pm, d) = setup(6);
        let m = pm.num_features;
        let xr = &d.features[..m];
        let g = &pm.groups[0];
        let mut of = [0.0f64; LANES];
        let mut full = [0.0f64; LANES];
        let mut skip = [0.0f64; LANES];
        let mut unw = [0.0f64; LANES];
        let mut checked = 0usize;
        let mut lane = 0usize;
        while lane < LANES {
            let len = g.plen[lane] as usize;
            if len == 0 {
                break;
            }
            if len >= 3 && g.v[lane] != 0.0 {
                activations(g, lane, len, xr, &mut of);
                path_weights(g, lane, len, &of, &mut full, usize::MAX);
                for k in 1..len {
                    path_weights(g, lane, len, &of, &mut skip, k);
                    unwind_weights(&full[..len], len, of[k], g.zfrac[lane + k] as f64, &mut unw);
                    for p in 0..len - 1 {
                        assert!(
                            (skip[p] - unw[p]).abs() < 1e-9,
                            "k={k} p={p}: {} vs {}",
                            skip[p],
                            unw[p]
                        );
                    }
                    checked += 1;
                }
            }
            lane += len;
        }
        assert!(checked > 0, "no paths exercised");
    }

    #[test]
    fn owner_blocks_assemble_to_legacy_interactions() {
        let (_, pm, d) = setup(6);
        let m = pm.num_features;
        let groups = pm.num_groups;
        let rows = 6;
        let x = &d.features[..rows * m];
        let legacy = interaction_values(&pm, x, rows, 1);
        let phis = phis_f64(&pm, x, rows, 1);
        let cuts = [0usize, 3, 4, m];
        let ms = (m + 1) * (m + 1);
        let mut asm = vec![0.0f64; rows * groups * ms];
        let blocks: Vec<(usize, usize, Vec<f64>)> = cuts
            .windows(2)
            .map(|w| (w[0], w[1], interaction_block(&pm, x, rows, 1, w[0], w[1])))
            .collect();
        let tile_of = |f: usize| blocks.iter().find(|(lo, hi, _)| f >= *lo && f < *hi).unwrap();
        for r in 0..rows {
            for g in 0..groups {
                let base = (r * groups + g) * ms;
                for i in 0..m {
                    for j in 0..m {
                        if i == j {
                            continue;
                        }
                        let (a, b) = (i.min(j), i.max(j));
                        let (lo, hi, blk) = tile_of(b);
                        let w = hi - lo;
                        asm[base + i * (m + 1) + j] =
                            blk[(r * groups + g) * m * w + a * w + (b - lo)];
                    }
                }
                for i in 0..m {
                    let row_sum: f64 = (0..m)
                        .filter(|&j| j != i)
                        .map(|j| asm[base + i * (m + 1) + j])
                        .sum();
                    asm[base + i * (m + 1) + i] = phis[(r * groups + g) * m + i] - row_sum;
                }
                asm[base + m * (m + 1) + m] = pm.expected_values[g];
            }
        }
        for (i, (a, b)) in legacy.iter().zip(&asm).enumerate() {
            assert!(
                (*a as f64 - b).abs() < 1e-6,
                "owner-block assembly off at {i}: {a} vs {b}"
            );
        }
    }

    #[test]
    fn packing_choice_does_not_change_values() {
        let (_, pm_bfd, d) = setup(4);
        let d2 = d.clone();
        let model =
            train(&d2, &TrainParams { rounds: 5, max_depth: 4, ..Default::default() });
        let pm_none = pack_model(&model, Packing::None);
        let m = model.num_features;
        let rows = 8;
        let a = shap_values(&pm_bfd, &d.features[..rows * m], rows, 1);
        let b = shap_values(&pm_none, &d.features[..rows * m], rows, 1);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 2e-4);
        }
    }
}
