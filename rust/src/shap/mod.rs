//! The GPUTreeShap pipeline: path extraction (§3.1), duplicate merging
//! (§3.2), bin packing (§3.3), packed tensors (§3.4 inputs), plus the CPU
//! baselines (recursive Algorithm 1 and its interactions variant) and a
//! rust-native evaluation of the packed DP.
//!
//! ## Canonical surface
//!
//! The packing vocabulary is re-exported **here and only here** — use
//! `shap::{LANES, Packing, pack, PackResult}` and the packed types
//! `shap::{PackedModel, PaddedModel, …}`; the `binpack` module itself is
//! private so `shap::binpack::LANES`-style paths cannot leak. Execution
//! entry points live behind `backend::ShapBackend`; the modules below
//! are the algorithm substrate it is built from.

mod binpack;
pub mod fast_v2;
pub mod host_kernel;
pub mod interactions;
pub mod linear;
pub mod packed;
pub mod path;
pub mod summary;
pub mod treeshap;

pub use binpack::{pack, PackResult, Packing, LANES};
pub use packed::{
    pack_model, pack_model_from_paths, pad_model, pad_model_from_paths, PackedGroup,
    PackedModel, PaddedGroup, PaddedModel,
};
pub use path::{
    expected_values, expected_values_from_paths, extract_paths, model_paths, Path, PathElement,
};
