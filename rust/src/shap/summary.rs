//! Aggregation utilities over φ matrices: global importance (mean |φ|),
//! top-k rankings, and interaction-pair rankings — the views the shap
//! package's summary plots are built from, as plain data.

/// Mean |φ| per feature for one output group.
/// `phis` is the `[rows × groups × (M+1)]` layout of the engines.
pub fn mean_abs_phi(
    phis: &[f32],
    rows: usize,
    groups: usize,
    m: usize,
    group: usize,
) -> Vec<f64> {
    let stride = groups * (m + 1);
    let mut out = vec![0.0f64; m];
    for r in 0..rows {
        let base = r * stride + group * (m + 1);
        for (f, o) in out.iter_mut().enumerate() {
            *o += phis[base + f].abs() as f64;
        }
    }
    for o in out.iter_mut() {
        *o /= rows.max(1) as f64;
    }
    out
}

/// Features ranked by mean |φ| descending: (feature, importance).
pub fn top_features(
    phis: &[f32],
    rows: usize,
    groups: usize,
    m: usize,
    group: usize,
    k: usize,
) -> Vec<(usize, f64)> {
    let imp = mean_abs_phi(phis, rows, groups, m, group);
    let mut order: Vec<(usize, f64)> = imp.into_iter().enumerate().collect();
    order.sort_by(|a, b| b.1.total_cmp(&a.1));
    order.truncate(k);
    order
}

/// Off-diagonal pairs ranked by mean |φ_ij|: (i, j, strength), i < j.
/// `inter` is the `[rows × groups × (M+1)²]` layout.
pub fn top_interactions(
    inter: &[f32],
    rows: usize,
    groups: usize,
    m: usize,
    group: usize,
    k: usize,
) -> Vec<(usize, usize, f64)> {
    let ms = (m + 1) * (m + 1);
    let stride = groups * ms;
    let mut pairs = Vec::new();
    for i in 0..m {
        for j in (i + 1)..m {
            let mut s = 0.0f64;
            for r in 0..rows {
                s += inter[r * stride + group * ms + i * (m + 1) + j].abs() as f64;
            }
            pairs.push((i, j, s / rows.max(1) as f64));
        }
    }
    pairs.sort_by(|a, b| b.2.total_cmp(&a.2));
    pairs.truncate(k);
    pairs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_abs_and_ranking() {
        // 2 rows, 1 group, m=3 (+bias): f1 dominates
        let phis = vec![
            0.1, -2.0, 0.0, 9.0, // row 0 (last = bias)
            -0.3, 1.0, 0.0, 9.0, // row 1
        ];
        let imp = mean_abs_phi(&phis, 2, 1, 3, 0);
        assert!((imp[0] - 0.2).abs() < 1e-6);
        assert!((imp[1] - 1.5).abs() < 1e-6);
        assert_eq!(imp[2], 0.0);
        let top = top_features(&phis, 2, 1, 3, 0, 2);
        assert_eq!(top[0].0, 1);
        assert_eq!(top[1].0, 0);
    }

    #[test]
    fn interaction_ranking() {
        let m = 2;
        let ms = (m + 1) * (m + 1);
        let mut inter = vec![0.0f32; 2 * ms];
        // rows 0 and 1: pair (0,1) strength 0.5 / 1.5
        inter[0 * ms + 0 * (m + 1) + 1] = 0.5;
        inter[1 * ms + 0 * (m + 1) + 1] = -1.5;
        let top = top_interactions(&inter, 2, 1, m, 0, 5);
        assert_eq!(top[0], (0, 1, 1.0));
    }

    #[test]
    fn multigroup_indexing() {
        let m = 1;
        // 1 row, 2 groups: φ differs per group
        let phis = vec![1.0, 0.0, 3.0, 0.0];
        assert_eq!(mean_abs_phi(&phis, 1, 2, m, 0)[0], 1.0);
        assert_eq!(mean_abs_phi(&phis, 1, 2, m, 1)[0], 3.0);
    }
}
