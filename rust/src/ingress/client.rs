//! Blocking client for the wire protocol: one TCP connection, one
//! request/response exchange at a time. The typed helpers mirror the
//! registry API one-to-one and return the same [`Response`] struct the
//! in-process service yields, so a caller can swap between in-process
//! and over-the-wire explanation without touching its result handling.

use std::net::{TcpStream, ToSocketAddrs};

use crate::anyhow;
use crate::coordinator::{Class, Request, Response, Task};
use crate::ingress::frame::{read_frame, write_frame};
use crate::ingress::wire::{self, Command};
use crate::util::error::Result;
use crate::util::Json;

pub struct Client {
    conn: TcpStream,
}

impl Client {
    pub fn connect<A: ToSocketAddrs + std::fmt::Debug>(addr: A) -> Result<Client> {
        let conn = TcpStream::connect(&addr).map_err(|e| anyhow!("connect {addr:?}: {e}"))?;
        let _ = conn.set_nodelay(true);
        Ok(Client { conn })
    }

    /// One raw exchange: send a command, read the reply frame.
    pub fn call(&mut self, cmd: &Command) -> Result<Json> {
        write_frame(&mut self.conn, &cmd.encode())?;
        read_frame(&mut self.conn)?
            .ok_or_else(|| anyhow!("server closed the connection mid-exchange"))
    }

    /// Submit one typed [`Request`] routed to `model` and decode the
    /// service [`Response`] out of the reply.
    pub fn submit(&mut self, model: &str, req: Request) -> Result<Response> {
        let reply =
            self.call(&Command::Submit { model: model.to_string(), req })?;
        wire::decode_response(&reply)
    }

    /// Contribution φ for `rows` feature rows, routed to `model`.
    pub fn explain(&mut self, model: &str, x: Vec<f32>, rows: usize) -> Result<Vec<f32>> {
        self.submit(model, Request::contributions(x, rows))?.into_values()
    }

    /// [`Client::explain`] at interactive priority: the request jumps
    /// the batch-class queue and the scheduler closes its batch against
    /// the interactive latency target instead of `max_wait`.
    pub fn explain_interactive(
        &mut self,
        model: &str,
        x: Vec<f32>,
        rows: usize,
    ) -> Result<Vec<f32>> {
        self.submit(
            model,
            Request::contributions(x, rows).with_priority(Class::Interactive),
        )?
        .into_values()
    }

    /// Interaction Φ, routed to `model`.
    pub fn explain_interactions(
        &mut self,
        model: &str,
        x: Vec<f32>,
        rows: usize,
    ) -> Result<Vec<f32>> {
        self.submit(model, Request::interactions(x, rows))?.into_values()
    }

    /// Raw margin predictions, routed to `model`.
    pub fn predict(&mut self, model: &str, x: Vec<f32>, rows: usize) -> Result<Vec<f32>> {
        self.submit(model, Request::predictions(x, rows))?.into_values()
    }

    /// Generic task submit by name (`Task::parse` verbs).
    pub fn run_task(
        &mut self,
        model: &str,
        task: Task,
        x: Vec<f32>,
        rows: usize,
    ) -> Result<Response> {
        self.submit(model, Request::new(task, x, rows))
    }

    /// Load a model artifact server-side and register it as `name`.
    pub fn load(&mut self, name: &str, path: &str) -> Result<Json> {
        let reply = self
            .call(&Command::Load { name: name.to_string(), path: path.to_string() })?;
        wire::check_ok(&reply)?;
        Ok(reply)
    }

    pub fn unload(&mut self, name: &str) -> Result<Json> {
        let reply = self.call(&Command::Unload { name: name.to_string() })?;
        wire::check_ok(&reply)?;
        Ok(reply)
    }

    /// Hot-deploy: atomically point `alias` at `model`.
    pub fn deploy(&mut self, alias: &str, model: &str, retire_old: bool) -> Result<Json> {
        let reply = self.call(&Command::Deploy {
            alias: alias.to_string(),
            model: model.to_string(),
            retire_old,
        })?;
        wire::check_ok(&reply)?;
        Ok(reply)
    }

    pub fn list(&mut self) -> Result<Json> {
        let reply = self.call(&Command::List)?;
        wire::check_ok(&reply)?;
        Ok(reply.get("registry")?.clone())
    }

    /// Server stats (all models, or one).
    pub fn stats(&mut self, model: Option<&str>) -> Result<Json> {
        let reply = self.call(&Command::Stats { model: model.map(str::to_string) })?;
        wire::check_ok(&reply)?;
        Ok(reply.get("stats")?.clone())
    }

    /// Liveness check; returns the names currently routable.
    pub fn ping(&mut self) -> Result<Vec<String>> {
        let reply = self.call(&Command::Ping)?;
        wire::check_ok(&reply)?;
        reply
            .get("serving")?
            .as_arr()?
            .iter()
            .map(|j| Ok(j.as_str()?.to_string()))
            .collect()
    }

    /// Ask the server to stop accepting and drain.
    pub fn shutdown(&mut self) -> Result<()> {
        let reply = self.call(&Command::Shutdown)?;
        wire::check_ok(&reply)
    }
}
