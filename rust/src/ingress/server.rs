//! The TCP front end: a thread-per-connection accept loop serving the
//! wire protocol over length-prefixed JSON frames, routing every
//! request into a shared [`ModelRegistry`].
//!
//! Admission control is two-layer, matching the service's own design:
//! a connection cap here (over-cap connects get one error frame and a
//! close — the client sees *why*, not a hang), and per-request
//! backpressure below (each model's bounded ingress queue rejects with
//! "queue full" when the executor falls behind). Neither layer ever
//! queues unboundedly on behalf of a slow client: a connection thread
//! runs one request at a time, so a client gets exactly as much
//! pipelining as it asks for.
//!
//! Shutdown (`{"cmd":"shutdown"}` or [`ServerHandle::stop`]) flips the
//! stop flag and self-connects to unblock the acceptor; the accept loop
//! then waits a short grace for in-flight connections to finish their
//! current exchange. Draining the registry's executors is the caller's
//! job ([`ModelRegistry::drain_all`]) — the server owns sockets, not
//! models.

use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::anyhow;
use crate::coordinator::ModelRegistry;
use crate::ingress::frame::{read_frame, write_frame};
use crate::ingress::wire::{self, Command};
use crate::util::error::Result;
use crate::util::Json;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// concurrent-connection cap; connects past it are refused with an
    /// error frame (the request-level backpressure still applies under
    /// the cap)
    pub max_conns: usize,
    /// how long shutdown waits for in-flight connections to finish
    pub drain_grace: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_conns: 64, drain_grace: Duration::from_secs(5) }
    }
}

/// A bound, not-yet-running ingress: `bind` then `run` (blocking), or
/// hold a [`ServerHandle`] to stop it from another thread.
pub struct IngressServer {
    listener: TcpListener,
    registry: Arc<ModelRegistry>,
    cfg: ServerConfig,
    stop: Arc<AtomicBool>,
    active: Arc<AtomicUsize>,
}

/// Clonable remote control for a running server.
#[derive(Clone)]
pub struct ServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
}

impl ServerHandle {
    /// Stop the server: flip the flag and wake the blocking acceptor.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // a throwaway connect unblocks `TcpListener::accept`
        let _ = TcpStream::connect(self.addr);
    }
}

impl IngressServer {
    /// Bind `addr` (e.g. `127.0.0.1:7878`; port 0 picks a free port —
    /// read it back via [`IngressServer::local_addr`]).
    pub fn bind<A: ToSocketAddrs + std::fmt::Debug>(
        addr: A,
        registry: Arc<ModelRegistry>,
        cfg: ServerConfig,
    ) -> Result<IngressServer> {
        let listener =
            TcpListener::bind(&addr).map_err(|e| anyhow!("bind {addr:?}: {e}"))?;
        Ok(IngressServer {
            listener,
            registry,
            cfg,
            stop: Arc::new(AtomicBool::new(false)),
            active: Arc::new(AtomicUsize::new(0)),
        })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().map_err(|e| anyhow!("local_addr: {e}"))
    }

    pub fn handle(&self) -> Result<ServerHandle> {
        Ok(ServerHandle { addr: self.local_addr()?, stop: self.stop.clone() })
    }

    /// Serve until a `shutdown` command (or [`ServerHandle::stop`])
    /// arrives, then wait up to `drain_grace` for in-flight connections
    /// to finish. Connection threads are detached — each serves one
    /// client serially and exits when the client closes.
    pub fn run(&self) -> Result<()> {
        let addr = self.local_addr()?;
        loop {
            let (conn, peer) = match self.listener.accept() {
                Ok(c) => c,
                Err(e) => {
                    if self.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    eprintln!("ingress: accept failed: {e}");
                    continue;
                }
            };
            if self.stop.load(Ordering::SeqCst) {
                // the wake-up connect (or a straggler racing shutdown)
                break;
            }
            // admission control: past the cap, say why and close
            if self.active.fetch_add(1, Ordering::SeqCst) >= self.cfg.max_conns {
                self.active.fetch_sub(1, Ordering::SeqCst);
                let mut conn = conn;
                let _ = write_frame(
                    &mut conn,
                    &wire::err_frame(&format!(
                        "server at capacity ({} connections); retry later",
                        self.cfg.max_conns
                    )),
                );
                continue;
            }
            let registry = self.registry.clone();
            let stop = self.stop.clone();
            let active = self.active.clone();
            let handle = ServerHandle { addr, stop: stop.clone() };
            std::thread::spawn(move || {
                let _guard = ActiveGuard(active);
                let _ = conn.set_nodelay(true);
                if let Err(e) = serve_conn(conn, &registry, &handle) {
                    // per-connection failures are logged, never fatal
                    eprintln!("ingress: connection {peer}: {e:#}");
                }
            });
        }
        // grace period: let connections mid-exchange finish
        let deadline = Instant::now() + self.cfg.drain_grace;
        while self.active.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        Ok(())
    }
}

struct ActiveGuard(Arc<AtomicUsize>);

impl Drop for ActiveGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// One connection: read a frame, execute, answer, repeat until the
/// client closes. Malformed frames get an error frame back (the
/// connection survives command-level errors; only transport errors end
/// it).
fn serve_conn(
    mut conn: TcpStream,
    registry: &ModelRegistry,
    server: &ServerHandle,
) -> Result<()> {
    while let Some(msg) = read_frame(&mut conn)? {
        let reply = match Command::parse(&msg) {
            Ok(Command::Shutdown) => {
                let _ = write_frame(&mut conn, &wire::ok_with(vec![("stopping", Json::Bool(true))]));
                server.stop();
                return Ok(());
            }
            Ok(cmd) => execute(registry, cmd),
            Err(e) => wire::err_frame(&format!("{e:#}")),
        };
        write_frame(&mut conn, &reply)?;
    }
    Ok(())
}

/// Execute one non-shutdown command against the registry, folding every
/// error into an error frame.
fn execute(registry: &ModelRegistry, cmd: Command) -> Json {
    let result: Result<Json> = match cmd {
        Command::Submit { model, req } => registry
            .run_response(&model, req)
            .map(wire::encode_response),
        Command::Load { name, path } => registry
            .load_path(&name, std::path::Path::new(&path))
            .map(|()| wire::ok_with(vec![("loaded", Json::from(name.as_str()))])),
        Command::Unload { name } => registry
            .unload(&name)
            .map(|()| wire::ok_with(vec![("unloaded", Json::from(name.as_str()))])),
        Command::Deploy { alias, model, retire_old } => {
            registry.deploy(&alias, &model, retire_old).map(|outcome| {
                wire::ok_with(vec![
                    ("alias", Json::from(alias.as_str())),
                    ("model", Json::from(model.as_str())),
                    (
                        "previous",
                        outcome.previous.map(Json::Str).unwrap_or(Json::Null),
                    ),
                    (
                        "retired",
                        outcome.retired.map(Json::Str).unwrap_or(Json::Null),
                    ),
                ])
            })
        }
        Command::List => Ok(wire::ok_with(vec![("registry", registry.list())])),
        Command::Stats { model } => registry
            .stats(model.as_deref())
            .map(|stats| wire::ok_with(vec![("stats", stats)])),
        Command::Ping => Ok(wire::ok_with(vec![(
            "serving",
            Json::Arr(registry.names().into_iter().map(Json::Str).collect()),
        )])),
        // handled by the caller before execute
        Command::Shutdown => Ok(wire::ok_with(vec![("stopping", Json::Bool(true))])),
    };
    result.unwrap_or_else(|e| wire::err_frame(&format!("{e:#}")))
}
