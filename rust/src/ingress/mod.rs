//! Network ingress: SHAP-as-a-service over TCP, std-only.
//!
//! Layers, bottom up:
//!
//! - [`frame`] — length-prefixed JSON framing (4-byte big-endian
//!   length + compact UTF-8 JSON), symmetric both directions.
//! - [`wire`] — the command protocol inside each frame. Submit verbs
//!   are [`Task`](crate::coordinator::Task) aliases and a submit reply
//!   is the service's [`Response`](crate::coordinator::Response)
//!   serialized verbatim, so the wire, the CLI and the in-process API
//!   share one vocabulary.
//! - [`server`] — thread-per-connection accept loop with a connection
//!   cap, routing into a shared
//!   [`ModelRegistry`](crate::coordinator::ModelRegistry); per-request
//!   backpressure comes from each model's bounded ingress queue.
//! - [`client`] — blocking typed client mirroring the registry API.
//!
//! f32 values ride the wire as JSON numbers printed by f64 `Display`
//! (shortest round-trip); f32 → f64 is exact, so explanations arrive
//! bit-identical to an in-process backend call.

pub mod client;
pub mod frame;
pub mod server;
pub mod wire;

pub use client::Client;
pub use frame::{read_frame, write_frame, MAX_FRAME};
pub use server::{IngressServer, ServerConfig, ServerHandle};
pub use wire::Command;
