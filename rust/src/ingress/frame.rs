//! Length-prefixed JSON framing: every message on the wire is a 4-byte
//! big-endian `u32` payload length followed by that many bytes of UTF-8
//! JSON (compact, single line). Symmetric in both directions — requests
//! and responses use the same codec — and self-delimiting, so one
//! connection carries any number of request/response exchanges.

use std::io::{ErrorKind, Read, Write};

use crate::anyhow;
use crate::util::error::Result;
use crate::util::Json;

/// Upper bound on one frame's payload (64 MiB): a malformed or hostile
/// length prefix must not become an allocation. 64 MiB fits ~2M f32
/// values serialized, far past any sane explain batch.
pub const MAX_FRAME: u32 = 64 << 20;

/// Write one frame: length prefix + compact JSON payload.
pub fn write_frame<W: Write>(w: &mut W, msg: &Json) -> Result<()> {
    let payload = msg.to_string_compact();
    let bytes = payload.as_bytes();
    if bytes.len() > MAX_FRAME as usize {
        return Err(anyhow!(
            "frame too large: {} bytes (max {})",
            bytes.len(),
            MAX_FRAME
        ));
    }
    let len = (bytes.len() as u32).to_be_bytes();
    w.write_all(&len).map_err(|e| anyhow!("write frame header: {e}"))?;
    w.write_all(bytes).map_err(|e| anyhow!("write frame payload: {e}"))?;
    w.flush().map_err(|e| anyhow!("flush frame: {e}"))?;
    Ok(())
}

/// Read one frame. `Ok(None)` means the peer closed the connection
/// cleanly at a frame boundary; a close mid-frame is an error.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Json>> {
    let mut header = [0u8; 4];
    match read_exact_or_eof(r, &mut header)? {
        ReadOutcome::Eof => return Ok(None),
        ReadOutcome::Full => {}
    }
    let len = u32::from_be_bytes(header);
    if len > MAX_FRAME {
        return Err(anyhow!("frame too large: {len} bytes (max {MAX_FRAME})"));
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)
        .map_err(|e| anyhow!("read frame payload: {e}"))?;
    let text = std::str::from_utf8(&payload).map_err(|e| anyhow!("frame not UTF-8: {e}"))?;
    Ok(Some(Json::parse(text)?))
}

enum ReadOutcome {
    Full,
    Eof,
}

/// `read_exact`, except a clean EOF before the first byte is
/// distinguished from a mid-buffer close.
fn read_exact_or_eof<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<ReadOutcome> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) if filled == 0 => return Ok(ReadOutcome::Eof),
            Ok(0) => {
                return Err(anyhow!(
                    "connection closed mid-frame ({filled} of {} header bytes)",
                    buf.len()
                ))
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(anyhow!("read frame: {e}")),
        }
    }
    Ok(ReadOutcome::Full)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_and_eof() {
        let msg = Json::obj(vec![
            ("cmd", Json::from("explain")),
            ("x", Json::Arr(vec![Json::from(1.5f64), Json::from(-0.25f64)])),
        ]);
        let mut buf = Vec::new();
        write_frame(&mut buf, &msg).unwrap();
        write_frame(&mut buf, &Json::Null).unwrap();
        let mut cursor = &buf[..];
        assert_eq!(read_frame(&mut cursor).unwrap(), Some(msg));
        assert_eq!(read_frame(&mut cursor).unwrap(), Some(Json::Null));
        // clean EOF at a frame boundary is None, not an error
        assert_eq!(read_frame(&mut cursor).unwrap(), None);
    }

    #[test]
    fn f32_values_survive_the_wire_bitwise() {
        // f32 → f64 is exact and f64 Display prints shortest
        // round-trip, so every finite f32 crosses the wire bit-exactly
        // — the property the routed-parity acceptance test leans on
        let values: Vec<f32> = vec![0.1, -3.5e-8, 1.0, f32::MIN_POSITIVE, 123456.78];
        let msg = Json::Arr(values.iter().map(|v| Json::from(*v as f64)).collect());
        let mut buf = Vec::new();
        write_frame(&mut buf, &msg).unwrap();
        let back = read_frame(&mut &buf[..]).unwrap().unwrap();
        let decoded: Vec<f32> =
            back.as_arr().unwrap().iter().map(|j| j.as_f64().unwrap() as f32).collect();
        for (a, b) in values.iter().zip(&decoded) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn oversized_frame_rejected() {
        // a hostile header must not become a 4 GiB allocation
        let mut buf = Vec::new();
        buf.extend_from_slice(&u32::MAX.to_be_bytes());
        let err = read_frame(&mut &buf[..]).unwrap_err();
        assert!(format!("{err:#}").contains("frame too large"));
    }

    #[test]
    fn mid_frame_close_is_an_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Json::from("hello")).unwrap();
        buf.truncate(buf.len() - 2);
        assert!(read_frame(&mut &buf[..]).is_err());
        // close inside the header is also an error
        assert!(read_frame(&mut &buf[..2]).is_err());
    }
}
