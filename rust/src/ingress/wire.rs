//! The wire protocol (v2): what goes inside each frame. One JSON object
//! per frame; requests carry a `cmd` verb, responses carry `ok` plus
//! either the payload or an `error` string.
//!
//! The explain verbs are exactly [`Task::parse`]'s alias table — the
//! same parse serves the CLI, the in-process API and the wire — and a
//! submit response is the service's [`Response`] struct serialized
//! verbatim (`task`/`rows`/`cols`/`values`), so every consumer sees one
//! shape.
//!
//! ```text
//!   {"cmd":"explain","model":"best","rows":2,"x":[...],
//!    "priority":"interactive","deadline_ms":40}              → submit
//!   {"cmd":"load","name":"m2","path":"artifacts/m2.gtsm"}    → registry
//!   {"cmd":"deploy","alias":"best","model":"m2"}             → hot swap
//!   {"cmd":"list"} {"cmd":"stats"} {"cmd":"ping"}            → introspect
//!   {"cmd":"shutdown"}                                       → stop server
//! ```
//!
//! v2 over v1: submit frames may carry the scheduling fields
//! `priority` (`interactive`|`batch`, default `batch`) and
//! `deadline_ms`, and every verb now REJECTS unknown fields with an
//! in-band error naming the field — a v1 server silently dropped
//! extras, so a typo'd `priorty` degraded to batch class without any
//! signal. Default-class frames are byte-identical to v1, so v1 clients
//! interoperate unchanged.

use crate::anyhow;
use crate::coordinator::{Class, Request, Response, Task};
use crate::util::error::Result;
use crate::util::Json;

/// Registry/control verbs (everything that is not a [`Task`] alias).
const CONTROL_VERBS: &[&str] =
    &["load", "unload", "deploy", "list", "stats", "ping", "shutdown"];

/// One decoded client command.
#[derive(Clone, Debug)]
pub enum Command {
    /// An explain/interactions/predict request routed to `model`.
    Submit { model: String, req: Request },
    Load { name: String, path: String },
    Unload { name: String },
    Deploy { alias: String, model: String, retire_old: bool },
    List,
    Stats { model: Option<String> },
    Ping,
    Shutdown,
}

/// Reject fields the verb does not know, naming the first offender —
/// a typo'd scheduling field must fail loudly, not silently degrade to
/// the default class (wire v2; v1 dropped extras).
fn reject_unknown_fields(msg: &Json, verb: &str, allowed: &[&str]) -> Result<()> {
    let Json::Obj(map) = msg else {
        return Err(anyhow!("request frame must be a JSON object, got {msg:?}"));
    };
    for key in map.keys() {
        if !allowed.contains(&key.as_str()) {
            return Err(anyhow!(
                "unknown field '{key}' for '{verb}' (known: {})",
                allowed.join(", ")
            ));
        }
    }
    Ok(())
}

impl Command {
    /// Decode one request frame. Unknown verbs list the full valid set;
    /// unknown fields on a known verb name the field.
    pub fn parse(msg: &Json) -> Result<Command> {
        let verb = msg.get("cmd")?.as_str()?;
        if let Some(task) = Task::parse(verb) {
            reject_unknown_fields(
                msg,
                verb,
                &["cmd", "model", "rows", "x", "priority", "deadline_ms"],
            )?;
            let model = msg.get("model")?.as_str()?.to_string();
            let rows = msg.get("rows")?.as_usize()?;
            let x = decode_f32s(msg.get("x")?)?;
            let mut req = Request::new(task, x, rows);
            if let Ok(p) = msg.get("priority") {
                let s = p.as_str()?;
                let class = Class::parse(s).ok_or_else(|| {
                    anyhow!("unknown priority '{s}' (one of: {})", Class::name_list())
                })?;
                req = req.with_priority(class);
            }
            if let Ok(d) = msg.get("deadline_ms") {
                req = req.with_deadline_ms(d.as_usize()? as u64);
            }
            return Ok(Command::Submit { model, req });
        }
        match verb.to_ascii_lowercase().as_str() {
            "load" => {
                reject_unknown_fields(msg, verb, &["cmd", "name", "path"])?;
                Ok(Command::Load {
                    name: msg.get("name")?.as_str()?.to_string(),
                    path: msg.get("path")?.as_str()?.to_string(),
                })
            }
            "unload" => {
                reject_unknown_fields(msg, verb, &["cmd", "name"])?;
                Ok(Command::Unload { name: msg.get("name")?.as_str()?.to_string() })
            }
            "deploy" => {
                reject_unknown_fields(msg, verb, &["cmd", "alias", "model", "retire_old"])?;
                Ok(Command::Deploy {
                    alias: msg.get("alias")?.as_str()?.to_string(),
                    model: msg.get("model")?.as_str()?.to_string(),
                    // hot swaps retire the abandoned target by default;
                    // pass false to keep it serving (e.g. under a canary)
                    retire_old: match msg.get("retire_old") {
                        Ok(Json::Bool(b)) => *b,
                        Ok(other) => {
                            return Err(anyhow!("retire_old must be a bool, got {other:?}"))
                        }
                        Err(_) => true,
                    },
                })
            }
            "list" => {
                reject_unknown_fields(msg, verb, &["cmd"])?;
                Ok(Command::List)
            }
            "stats" => {
                reject_unknown_fields(msg, verb, &["cmd", "model"])?;
                Ok(Command::Stats {
                    model: msg
                        .get("model")
                        .ok()
                        .map(|j| j.as_str().map(str::to_string))
                        .transpose()?,
                })
            }
            "ping" => {
                reject_unknown_fields(msg, verb, &["cmd"])?;
                Ok(Command::Ping)
            }
            "shutdown" => {
                reject_unknown_fields(msg, verb, &["cmd"])?;
                Ok(Command::Shutdown)
            }
            _ => Err(anyhow!(
                "unknown command '{verb}' (one of: {}|{})",
                Task::name_list(),
                CONTROL_VERBS.join("|")
            )),
        }
    }

    /// Encode this command as a request frame (the client side of
    /// [`Command::parse`]).
    pub fn encode(&self) -> Json {
        match self {
            Command::Submit { model, req } => {
                let mut fields = vec![
                    ("cmd", Json::from(req.task.name())),
                    ("model", Json::from(model.as_str())),
                    ("rows", Json::from(req.rows)),
                    ("x", encode_f32s(&req.x)),
                ];
                // scheduling fields ride only when non-default, so
                // default-class frames stay byte-identical to wire v1
                if req.priority != Class::default() {
                    fields.push(("priority", Json::from(req.priority.name())));
                }
                if let Some(ms) = req.deadline_ms {
                    fields.push(("deadline_ms", Json::from(ms as usize)));
                }
                Json::obj(fields)
            }
            Command::Load { name, path } => Json::obj(vec![
                ("cmd", Json::from("load")),
                ("name", Json::from(name.as_str())),
                ("path", Json::from(path.as_str())),
            ]),
            Command::Unload { name } => Json::obj(vec![
                ("cmd", Json::from("unload")),
                ("name", Json::from(name.as_str())),
            ]),
            Command::Deploy { alias, model, retire_old } => Json::obj(vec![
                ("cmd", Json::from("deploy")),
                ("alias", Json::from(alias.as_str())),
                ("model", Json::from(model.as_str())),
                ("retire_old", Json::Bool(*retire_old)),
            ]),
            Command::List => Json::obj(vec![("cmd", Json::from("list"))]),
            Command::Stats { model } => {
                let mut fields = vec![("cmd", Json::from("stats"))];
                if let Some(m) = model {
                    fields.push(("model", Json::from(m.as_str())));
                }
                Json::obj(fields)
            }
            Command::Ping => Json::obj(vec![("cmd", Json::from("ping"))]),
            Command::Shutdown => Json::obj(vec![("cmd", Json::from("shutdown"))]),
        }
    }
}

/// `{"ok":true, ...payload}` — success with extra fields.
pub fn ok_with(fields: Vec<(&str, Json)>) -> Json {
    let mut all = vec![("ok", Json::Bool(true))];
    all.extend(fields);
    Json::obj(all)
}

/// `{"ok":false,"error":...}` — any failure, serialized uniformly.
pub fn err_frame(msg: &str) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::from(msg))])
}

/// Serialize a service [`Response`] as a success frame (or its
/// per-request error as an error frame) — the `Response` struct
/// verbatim: task, rows, cols, values.
pub fn encode_response(resp: Response) -> Json {
    let task = resp.task;
    let rows = resp.rows;
    let cols = resp.cols;
    match resp.into_values() {
        Ok(values) => ok_with(vec![
            ("task", Json::from(task.name())),
            ("rows", Json::from(rows)),
            ("cols", Json::from(cols)),
            ("values", encode_f32s(&values)),
        ]),
        Err(e) => err_frame(&format!("{e:#}")),
    }
}

/// Decode a response frame back into the service [`Response`] shape;
/// `{"ok":false}` frames surface as `Err`.
pub fn decode_response(msg: &Json) -> Result<Response> {
    check_ok(msg)?;
    let task = Task::parse(msg.get("task")?.as_str()?)
        .ok_or_else(|| anyhow!("bad task in response"))?;
    Ok(Response {
        task,
        rows: msg.get("rows")?.as_usize()?,
        cols: msg.get("cols")?.as_usize()?,
        values: Ok(decode_f32s(msg.get("values")?)?),
    })
}

/// Surface an `{"ok":false,"error":...}` frame as the error it carries.
pub fn check_ok(msg: &Json) -> Result<()> {
    match msg.get("ok") {
        Ok(Json::Bool(true)) => Ok(()),
        Ok(Json::Bool(false)) => {
            let detail = msg
                .get("error")
                .ok()
                .and_then(|j| j.as_str().ok())
                .unwrap_or("unspecified server error");
            Err(anyhow!("{detail}"))
        }
        _ => Err(anyhow!("malformed response frame: {msg:?}")),
    }
}

/// f32s on the wire ride as JSON numbers; f32 → f64 is exact and the
/// serializer prints shortest-round-trip, so this is lossless.
pub fn encode_f32s(values: &[f32]) -> Json {
    Json::Arr(values.iter().map(|v| Json::from(*v as f64)).collect())
}

pub fn decode_f32s(msg: &Json) -> Result<Vec<f32>> {
    msg.as_arr()?.iter().map(|j| Ok(j.as_f64()? as f32)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_verbs_share_task_aliases() {
        for (verb, task) in [
            ("explain", Task::Contributions),
            ("SHAP", Task::Contributions),
            ("interactions", Task::Interactions),
            ("predict", Task::Predictions),
        ] {
            let msg = Json::obj(vec![
                ("cmd", Json::from(verb)),
                ("model", Json::from("m1")),
                ("rows", Json::from(2usize)),
                ("x", encode_f32s(&[1.0, 2.0, 3.0, 4.0])),
            ]);
            match Command::parse(&msg).unwrap() {
                Command::Submit { model, req } => {
                    assert_eq!(model, "m1");
                    assert_eq!(req.task, task);
                    assert_eq!(req.rows, 2);
                    assert_eq!(req.x, vec![1.0, 2.0, 3.0, 4.0]);
                }
                other => panic!("expected Submit, got {other:?}"),
            }
        }
    }

    #[test]
    fn commands_round_trip_through_encode_parse() {
        let cmds = vec![
            Command::Load { name: "m2".into(), path: "a/b.gtsm".into() },
            Command::Unload { name: "m2".into() },
            Command::Deploy { alias: "best".into(), model: "m2".into(), retire_old: false },
            Command::List,
            Command::Stats { model: Some("m1".into()) },
            Command::Stats { model: None },
            Command::Ping,
            Command::Shutdown,
        ];
        for cmd in cmds {
            let re = Command::parse(&cmd.encode()).unwrap();
            assert_eq!(format!("{re:?}"), format!("{cmd:?}"));
        }
    }

    #[test]
    fn deploy_defaults_to_retire() {
        let msg = Json::obj(vec![
            ("cmd", Json::from("deploy")),
            ("alias", Json::from("best")),
            ("model", Json::from("m2")),
        ]);
        match Command::parse(&msg).unwrap() {
            Command::Deploy { retire_old, .. } => assert!(retire_old),
            other => panic!("expected Deploy, got {other:?}"),
        }
    }

    #[test]
    fn unknown_verb_lists_the_valid_set() {
        let msg = Json::obj(vec![("cmd", Json::from("frobnicate"))]);
        let err = format!("{:#}", Command::parse(&msg).unwrap_err());
        assert!(err.contains("explain"), "{err}");
        assert!(err.contains("deploy"), "{err}");
    }

    #[test]
    fn priority_and_deadline_round_trip() {
        let req = Request::new(Task::Contributions, vec![1.0, 2.0], 1)
            .with_priority(Class::Interactive)
            .with_deadline_ms(40);
        let cmd = Command::Submit { model: "m1".into(), req };
        let frame = cmd.encode();
        assert!(frame.get("priority").is_ok(), "non-default class rides the frame");
        match Command::parse(&frame).unwrap() {
            Command::Submit { req, .. } => {
                assert_eq!(req.priority, Class::Interactive);
                assert_eq!(req.deadline_ms, Some(40));
            }
            other => panic!("expected Submit, got {other:?}"),
        }
        // default-class, no-deadline frames carry neither field —
        // byte-identical to wire v1
        let v1 = Command::Submit {
            model: "m1".into(),
            req: Request::new(Task::Contributions, vec![1.0, 2.0], 1),
        }
        .encode();
        assert!(v1.get("priority").is_err());
        assert!(v1.get("deadline_ms").is_err());
        match Command::parse(&v1).unwrap() {
            Command::Submit { req, .. } => {
                assert_eq!(req.priority, Class::Batch);
                assert_eq!(req.deadline_ms, None);
            }
            other => panic!("expected Submit, got {other:?}"),
        }
    }

    #[test]
    fn unknown_fields_fail_loudly_naming_the_field() {
        // the motivating typo: 'priorty' must not silently degrade to
        // the default class
        let msg = Json::obj(vec![
            ("cmd", Json::from("explain")),
            ("model", Json::from("m1")),
            ("rows", Json::from(1usize)),
            ("x", encode_f32s(&[1.0])),
            ("priorty", Json::from("interactive")),
        ]);
        let err = format!("{:#}", Command::parse(&msg).unwrap_err());
        assert!(err.contains("unknown field 'priorty'"), "{err}");
        assert!(err.contains("priority"), "known-field list names the fix: {err}");
        // control verbs reject extras too
        let msg = Json::obj(vec![("cmd", Json::from("ping")), ("extra", Json::from(1usize))]);
        let err = format!("{:#}", Command::parse(&msg).unwrap_err());
        assert!(err.contains("unknown field 'extra'"), "{err}");
    }

    #[test]
    fn bad_priority_value_lists_the_classes() {
        let msg = Json::obj(vec![
            ("cmd", Json::from("explain")),
            ("model", Json::from("m1")),
            ("rows", Json::from(1usize)),
            ("x", encode_f32s(&[1.0])),
            ("priority", Json::from("urgent")),
        ]);
        let err = format!("{:#}", Command::parse(&msg).unwrap_err());
        assert!(err.contains("unknown priority 'urgent'"), "{err}");
        assert!(err.contains("interactive"), "{err}");
    }

    #[test]
    fn response_round_trip_preserves_values_bitwise() {
        let resp = Response {
            task: Task::Contributions,
            rows: 1,
            cols: 3,
            values: Ok(vec![0.1f32, -2.5e-7, 42.0]),
        };
        let frame = encode_response(resp);
        let back = decode_response(&frame).unwrap();
        assert_eq!(back.rows, 1);
        assert_eq!(back.cols, 3);
        let vals = back.into_values().unwrap();
        for (a, b) in [0.1f32, -2.5e-7, 42.0].iter().zip(&vals) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let err = decode_response(&err_frame("boom")).unwrap_err();
        assert!(format!("{err:#}").contains("boom"));
    }
}
