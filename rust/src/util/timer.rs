//! Timing and summary-statistics helpers shared by benches and metrics.

use std::time::Instant;

/// Measure wall time of a closure in seconds.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed().as_secs_f64())
}

/// Summary statistics over a set of timing samples.
#[derive(Clone, Debug, Default)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Stats {
    pub fn from_samples(samples: &[f64]) -> Stats {
        if samples.is_empty() {
            return Stats::default();
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples
            .iter()
            .map(|x| (x - mean) * (x - mean))
            .sum::<f64>()
            / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let q = |p: f64| sorted[((n as f64 - 1.0) * p).round() as usize];
        Stats {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: q(0.5),
            p95: q(0.95),
            p99: q(0.99),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = Stats::from_samples(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.p99, 5.0);
    }

    #[test]
    fn stats_empty() {
        let s = Stats::from_samples(&[]);
        assert_eq!(s.n, 0);
    }

    #[test]
    fn time_it_positive() {
        let (_, dt) = time_it(|| std::thread::sleep(std::time::Duration::from_millis(2)));
        assert!(dt >= 0.002);
    }
}
