//! One parse idiom for every named-constant surface: case-insensitive
//! alias lookup over a static table, with a canonical `|`-joined name
//! list for error messages. `BackendKind::parse`, `ShardAxis::parse`
//! and the coordinator's `Task`/wire-command parsing all route through
//! here instead of hand-rolling the same match three ways.

/// One row of a name table: the value and its accepted spellings. The
/// first spelling is canonical (it is what [`name_list`] prints and
/// what `name()` accessors should return).
pub type NameRow<T> = (T, &'static [&'static str]);

/// Case-insensitive lookup of `s` across every alias in `table`.
pub fn parse_named<T: Copy>(table: &[NameRow<T>], s: &str) -> Option<T> {
    let lower = s.to_ascii_lowercase();
    table
        .iter()
        .find(|(_, aliases)| aliases.iter().any(|a| *a == lower))
        .map(|(v, _)| *v)
}

/// The canonical names (first alias of each row), `|`-joined — the
/// vocabulary every "unknown X" error lists.
pub fn name_list<T: Copy>(table: &[NameRow<T>]) -> String {
    table.iter().map(|(_, aliases)| aliases[0]).collect::<Vec<_>>().join("|")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Clone, Copy, Debug, PartialEq)]
    enum Fruit {
        Apple,
        Pear,
    }

    const FRUITS: &[NameRow<Fruit>] =
        &[(Fruit::Apple, &["apple", "malus"]), (Fruit::Pear, &["pear"])];

    #[test]
    fn parses_aliases_case_insensitively() {
        assert_eq!(parse_named(FRUITS, "apple"), Some(Fruit::Apple));
        assert_eq!(parse_named(FRUITS, "MALUS"), Some(Fruit::Apple));
        assert_eq!(parse_named(FRUITS, "Pear"), Some(Fruit::Pear));
        assert_eq!(parse_named(FRUITS, "plum"), None);
    }

    #[test]
    fn name_list_is_canonical_first_aliases() {
        assert_eq!(name_list(FRUITS), "apple|pear");
    }
}
