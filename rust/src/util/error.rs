//! Minimal `anyhow`-compatible error substrate (no external crates
//! offline): a context-chain error type, `Result` alias, `Context`
//! extension trait, and the `anyhow!` / `bail!` macros exported at the
//! crate root. `{e}` prints the outermost message, `{e:#}` the full
//! chain (`outer: inner: root`), matching the `anyhow` conventions the
//! codebase was written against.

use std::fmt;

/// An error as a chain of messages, outermost context first.
pub struct Error {
    msgs: Vec<String>,
}

impl Error {
    /// Construct from a single displayable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msgs: vec![m.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, c: impl fmt::Display) -> Error {
        self.msgs.insert(0, c.to_string());
        self
    }

    /// The messages, outermost first.
    pub fn chain(&self) -> &[String] {
        &self.msgs
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.msgs.join(": "))
        } else {
            write!(f, "{}", self.msgs.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msgs.join(": "))
    }
}

// NB: `Error` deliberately does NOT implement `std::error::Error`; that
// keeps this blanket conversion coherent (no overlap with `From<T> for T`).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        Error { msgs }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`-style extension: attach context to any error that
/// converts into [`Error`].
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::util::error::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`] built as by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
    }

    #[test]
    fn macros_build_errors() {
        let x = 3;
        let e = crate::anyhow!("bad value {x}");
        assert_eq!(format!("{e}"), "bad value 3");
        let e = crate::anyhow!("bad {} of {}", "kind", 7);
        assert_eq!(format!("{e:#}"), "bad kind of 7");
        fn fails() -> Result<()> {
            crate::bail!("nope");
        }
        assert_eq!(format!("{}", fails().unwrap_err()), "nope");
    }

    #[test]
    fn with_context_lazy() {
        let ok: Result<u32, std::io::Error> = Ok(5);
        let v = ok.with_context(|| "unused").unwrap();
        assert_eq!(v, 5);
    }
}
