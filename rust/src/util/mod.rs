//! Shared substrates: deterministic RNG, minimal JSON, errors,
//! timing/stats.

pub mod error;
pub mod json;
pub mod rng;
pub mod timer;

pub use error::{Context, Error, Result};
pub use json::Json;
pub use rng::Rng;
pub use timer::{time_it, Stats};
