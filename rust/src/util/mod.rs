//! Shared substrates: deterministic RNG, minimal JSON, timing/stats.

pub mod json;
pub mod rng;
pub mod timer;

pub use json::Json;
pub use rng::Rng;
pub use timer::{time_it, Stats};
