//! Shared substrates: deterministic RNG, minimal JSON, errors,
//! timing/stats.

pub mod error;
pub mod json;
pub mod names;
pub mod rng;
pub mod timer;

pub use error::{Context, Error, Result};
pub use json::Json;
pub use names::{name_list, parse_named, NameRow};
pub use rng::Rng;
pub use timer::{time_it, Stats};
