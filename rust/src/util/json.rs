//! Minimal JSON substrate (no `serde` offline): a value tree, a
//! recursive-descent parser, and a writer. Used for the artifact
//! manifest, model metadata, bench result dumps and metrics snapshots.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::error::Result;
use crate::{anyhow, bail};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Single-line serialization (the wire format): no whitespace, same
    /// number formatting as pretty — integers verbatim, non-integers via
    /// f64 `Display` (shortest round-trip), so values survive a
    /// serialize→parse cycle bit-exactly.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !items.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(if pretty { ": " } else { ":" });
                    v.write(out, indent + 1, pretty);
                }
                if !map.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected '{}' at byte {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            map.insert(key, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}' got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']' got '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos..self.pos + 4],
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?,
                            );
                        }
                        c => bail!("bad escape '\\{}'", c as char),
                    }
                }
                _ => {
                    // copy raw UTF-8 bytes through
                    let startpos = self.pos - 1;
                    while self.pos < self.bytes.len()
                        && self.bytes[self.pos] != b'"'
                        && self.bytes[self.pos] != b'\\'
                    {
                        self.pos += 1;
                    }
                    s.push_str(std::str::from_utf8(
                        &self.bytes[startpos..self.pos],
                    )?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{"version": 1, "artifacts": [{"name": "shap_r64", "rows": 64, "ok": true, "x": null}]}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize().unwrap(), 1);
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str().unwrap(), "shap_r64");
        let again = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn parses_numbers() {
        for (s, want) in [("0", 0.0), ("-1.5", -1.5), ("2e3", 2000.0), ("1.25e-2", 0.0125)] {
            assert_eq!(Json::parse(s).unwrap().as_f64().unwrap(), want);
        }
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\c\nd\u{41}");
        let s = Json::Str("x\"y\nz".into()).to_string_pretty();
        assert_eq!(Json::parse(&s).unwrap().as_str().unwrap(), "x\"y\nz");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{}x").is_err());
    }

    #[test]
    fn nested_structures() {
        let v = Json::parse(r#"[[1,2],[3,[4,{"a":[]}]]]"#).unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
    }
}
