//! Deterministic RNG substrate (no `rand` crate offline).
//!
//! SplitMix64 for seeding / integers plus xoshiro256++ for the main
//! stream, with uniform/normal/choice helpers used by data generation,
//! property tests and the benchmark harness.

/// SplitMix64: tiny, decent-quality generator used to seed xoshiro.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare: None,
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Rejection-free Lemire reduction.
    pub fn below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u = self.f64();
            if u <= f64::EPSILON {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let theta = std::f64::consts::TAU * v;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Choose one element uniformly.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Fork a statistically independent child stream (for per-thread RNGs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA02BDBF7BB3C0A7)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.range(-5, 7);
            assert!((-5..7).contains(&y));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 8];
        for _ in 0..80_000 {
            counts[r.below(8) as usize] += 1;
        }
        for c in counts {
            assert!((8_500..11_500).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn forked_streams_diverge() {
        let mut a = Rng::new(5);
        let mut b = a.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
