//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime. Lists every AOT HLO artifact with its shape bucket
//! (rows, bins, features, depth); the runtime selects the cheapest
//! compatible bucket and tiles workloads over it.

use std::path::{Path, PathBuf};

use crate::bail;
use crate::util::error::{Context, Result};
use crate::util::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    Shap,
    /// padded-path perf variant (lanes = paths); `bins` counts paths
    ShapPadded,
    Interactions,
    /// padded-path interactions; `bins` counts paths
    InteractionsPadded,
    Predict,
}

impl ArtifactKind {
    pub fn parse(s: &str) -> Result<ArtifactKind> {
        Ok(match s {
            "shap" => ArtifactKind::Shap,
            "shap_padded" => ArtifactKind::ShapPadded,
            "interactions" => ArtifactKind::Interactions,
            "interactions_padded" => ArtifactKind::InteractionsPadded,
            "predict" => ArtifactKind::Predict,
            _ => bail!("unknown artifact kind '{s}'"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub kind: ArtifactKind,
    pub rows: usize,
    pub bins: usize,
    pub features: usize,
    pub depth: usize,
    pub lanes: usize,
    pub file: PathBuf,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let v = Json::parse(&text)?;
        let mut artifacts = Vec::new();
        for a in v.get("artifacts")?.as_arr()? {
            artifacts.push(ArtifactSpec {
                name: a.get("name")?.as_str()?.to_string(),
                kind: ArtifactKind::parse(a.get("kind")?.as_str()?)?,
                rows: a.get("rows")?.as_usize()?,
                bins: a.get("bins")?.as_usize()?,
                features: a.get("features")?.as_usize()?,
                depth: a.get("depth")?.as_usize()?,
                lanes: a.get("lanes")?.as_usize()?,
                file: dir.join(a.get("file")?.as_str()?),
            });
        }
        if artifacts.is_empty() {
            bail!("manifest has no artifacts");
        }
        Ok(Manifest { artifacts, dir: dir.to_path_buf() })
    }

    /// Cheapest compatible bucket: features ≥ m, depth ≥ d (shap /
    /// interactions). Cost model = padded work per row-chunk execution,
    /// rows·bins·features·(depth+1), preferring small-row buckets when
    /// `rows_hint` is small (latency) and large ones otherwise.
    pub fn select(
        &self,
        kind: ArtifactKind,
        m: usize,
        depth: usize,
        rows_hint: usize,
    ) -> Result<&ArtifactSpec> {
        self.select_with_units(kind, m, depth, rows_hint, usize::MAX)
    }

    /// Like `select`, also weighing work-unit padding: `units_hint` is
    /// the typical number of bins (warp layout) or paths (padded layout)
    /// per group, so a 230-path group prefers a 256-path bucket over a
    /// 1024-path one.
    pub fn select_with_units(
        &self,
        kind: ArtifactKind,
        m: usize,
        depth: usize,
        rows_hint: usize,
        units_hint: usize,
    ) -> Result<&ArtifactSpec> {
        let need_depth = if kind == ArtifactKind::Predict { 0 } else { depth };
        let mut best: Option<(&ArtifactSpec, f64)> = None;
        for a in &self.artifacts {
            if a.kind != kind || a.features < m || a.depth < need_depth {
                continue;
            }
            // row padding waste: requests smaller than the bucket pay it
            let eff_rows = a.rows.max(rows_hint.min(a.rows)) as f64;
            let row_waste = a.rows as f64 / eff_rows.max(1.0);
            // unit padding waste: last chunk is padded to a.bins
            let unit_waste = if units_hint == usize::MAX {
                1.0
            } else {
                let h = units_hint.max(1) as f64;
                let chunks = (h / a.bins as f64).ceil().max(1.0);
                chunks * a.bins as f64 / h
            };
            let cost =
                a.features as f64 * (a.depth + 1) as f64 * row_waste * unit_waste;
            if best.map_or(true, |(_, c)| cost < c) {
                best = Some((a, cost));
            }
        }
        best.map(|(a, _)| a).ok_or_else(|| {
            crate::anyhow!(
                "no artifact for kind={kind:?} features≥{m} depth≥{need_depth}; \
                 add a bucket to python/compile/aot.py CONFIGS"
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_manifest() -> Option<Manifest> {
        Manifest::load(&crate::runtime::default_artifacts_dir()).ok()
    }

    #[test]
    fn loads_and_selects() {
        let Some(man) = repo_manifest() else {
            eprintln!("skipped: artifacts not built");
            return;
        };
        assert!(man.artifacts.len() >= 5);
        let a = man.select(ArtifactKind::Shap, 8, 4, 1000).unwrap();
        assert!(a.features >= 8 && a.depth >= 4);
        // wide-feature bucket exists for fashion_mnist-like models
        let w = man.select(ArtifactKind::Shap, 784, 8, 64).unwrap();
        assert!(w.features >= 784);
        // impossible request errors cleanly
        assert!(man.select(ArtifactKind::Shap, 10_000, 8, 64).is_err());
    }

    #[test]
    fn small_requests_prefer_small_row_buckets() {
        let Some(man) = repo_manifest() else {
            return;
        };
        let small = man.select(ArtifactKind::Shap, 8, 4, 8).unwrap();
        assert!(small.rows <= 64, "picked {} for 8 rows", small.name);
    }
}
