//! The execution engine: tiles (rows × groups × bins) workloads over
//! fixed-shape artifact executions and accumulates φ.
//!
//! Packed model tensors are uploaded to the device **once** per
//! (model, artifact) as `PjRtBuffer`s and reused across every batch
//! (`execute_b`) — only the feature matrix X is uploaded per row chunk.
//! This mirrors the paper's amortisation of preprocessing/packing cost
//! over the test set, extended to device residency.

use std::path::Path;

use xla::PjRtBuffer;

use crate::runtime::device::Device;
use crate::util::error::Result;
use crate::runtime::manifest::{ArtifactKind, Manifest};
use crate::shap::packed::{PackedModel, PaddedModel};
use crate::shap::LANES;

/// Device-resident packed model for one artifact bucket:
/// `chunks[group][chunk]` = the 7 path tensors of one bin chunk.
pub struct Prepared {
    pub artifact: String,
    pub rows: usize,
    pub bins: usize,
    pub features: usize,
    pub kind: ArtifactKind,
    chunks: Vec<Vec<[PjRtBuffer; 7]>>,
}

/// Engine over one device. Multi-device scaling composes several engines
/// (see `runtime::pool`).
pub struct ShapEngine {
    pub device: Device,
    pub manifest: Manifest,
}

impl ShapEngine {
    pub fn new(artifacts_dir: &Path) -> Result<ShapEngine> {
        Ok(ShapEngine { device: Device::cpu()?, manifest: Manifest::load(artifacts_dir)? })
    }

    /// Select a bucket, compile it, and upload the packed model.
    pub fn prepare(
        &mut self,
        pm: &PackedModel,
        kind: ArtifactKind,
        rows_hint: usize,
    ) -> Result<Prepared> {
        let spec = self
            .manifest
            .select(kind, pm.num_features, pm.max_depth.max(1), rows_hint)?
            .clone();
        self.device.load(&spec)?;
        let mut chunks = Vec::with_capacity(pm.groups.len());
        for g in &pm.groups {
            let mut group_chunks = Vec::new();
            let mut b = 0;
            while b < g.num_bins.max(1) {
                let end = (b + spec.bins).min(g.num_bins);
                let chunk = g.slice_bins(b, end).padded_to(spec.bins);
                let dims = [spec.bins, LANES];
                group_chunks.push([
                    self.device.upload_i32(&chunk.fidx, &dims)?,
                    self.device.upload_f32(&chunk.lower, &dims)?,
                    self.device.upload_f32(&chunk.upper, &dims)?,
                    self.device.upload_f32(&chunk.zfrac, &dims)?,
                    self.device.upload_f32(&chunk.v, &dims)?,
                    self.device.upload_i32(&chunk.pos, &dims)?,
                    self.device.upload_i32(&chunk.plen, &dims)?,
                ]);
                b = end.max(b + spec.bins);
            }
            chunks.push(group_chunks);
        }
        Ok(Prepared {
            artifact: spec.name,
            rows: spec.rows,
            bins: spec.bins,
            features: spec.features,
            kind,
            chunks,
        })
    }

    /// Device-upload the padded-path layout (perf variant). Each chunk
    /// holds `spec.bins` paths of width `spec.depth + 1`.
    pub fn prepare_padded(
        &mut self,
        pm: &PaddedModel,
        rows_hint: usize,
    ) -> Result<PreparedPadded> {
        self.prepare_padded_kind(pm, ArtifactKind::ShapPadded, rows_hint)
    }

    /// As `prepare_padded` for any padded-layout artifact kind.
    pub fn prepare_padded_kind(
        &mut self,
        pm: &PaddedModel,
        kind: ArtifactKind,
        rows_hint: usize,
    ) -> Result<PreparedPadded> {
        let units = pm.groups.iter().map(|g| g.num_paths).max().unwrap_or(1);
        let spec = self
            .manifest
            .select_with_units(
                kind,
                pm.num_features,
                pm.max_depth.max(1),
                rows_hint,
                units,
            )?
            .clone();
        self.device.load(&spec)?;
        let width = spec.depth + 1;
        let mut chunks = Vec::with_capacity(pm.groups.len());
        for g in &pm.groups {
            // re-pad the group to the artifact width
            assert!(g.width <= width, "group width {} > artifact {}", g.width, width);
            let mut group_chunks = Vec::new();
            let mut p0 = 0;
            while p0 < g.num_paths.max(1) {
                let end = (p0 + spec.bins).min(g.num_paths);
                let chunk = repad(g, p0, end, spec.bins, width);
                let dims2 = [spec.bins, width];
                let dims1 = [spec.bins];
                group_chunks.push([
                    self.device.upload_i32(&chunk.fidx, &dims2)?,
                    self.device.upload_f32(&chunk.lower, &dims2)?,
                    self.device.upload_f32(&chunk.upper, &dims2)?,
                    self.device.upload_f32(&chunk.zfrac, &dims2)?,
                    self.device.upload_f32(&chunk.v, &dims1)?,
                    self.device.upload_i32(&chunk.plen, &dims1)?,
                ]);
                p0 = end.max(p0 + spec.bins);
            }
            chunks.push(group_chunks);
        }
        Ok(PreparedPadded {
            artifact: spec.name,
            rows: spec.rows,
            paths: spec.bins,
            features: spec.features,
            chunks,
        })
    }

    /// SHAP values through the padded-path artifact.
    pub fn shap_values_padded(
        &self,
        pm: &PaddedModel,
        prep: &PreparedPadded,
        x: &[f32],
        rows: usize,
    ) -> Result<Vec<f32>> {
        let m = pm.num_features;
        let groups = pm.num_groups;
        let stride = groups * (m + 1);
        let mut out = vec![0.0f32; rows * stride];
        let mb = prep.features;

        let mut xpad = vec![0.0f32; prep.rows * mb];
        let mut r0 = 0;
        while r0 < rows {
            let rc = (rows - r0).min(prep.rows);
            pad_x(x, m, r0, rc, &mut xpad, mb);
            let xbuf = self.device.upload_f32(&xpad, &[prep.rows, mb])?;
            for (g, group_chunks) in prep.chunks.iter().enumerate() {
                for bufs in group_chunks {
                    let args: Vec<&PjRtBuffer> =
                        std::iter::once(&xbuf).chain(bufs.iter()).collect();
                    let lit = self.device.execute(&prep.artifact, &args)?;
                    let vals: Vec<f32> = lit.to_vec()?;
                    for r in 0..rc {
                        let src = &vals[r * (mb + 1)..(r + 1) * (mb + 1)];
                        let dst = &mut out[(r0 + r) * stride + g * (m + 1)
                            ..(r0 + r) * stride + (g + 1) * (m + 1)];
                        for f in 0..m {
                            dst[f] += src[f];
                        }
                        dst[m] += src[mb];
                    }
                }
            }
            r0 += rc;
        }
        for r in 0..rows {
            for g in 0..groups {
                out[r * stride + g * (m + 1) + m] += pm.expected_values[g] as f32;
            }
        }
        Ok(out)
    }

    /// Interactions through the padded-path artifact:
    /// output [rows × groups × (m+1)²], base value at [M, M].
    pub fn interactions_padded(
        &self,
        pm: &PaddedModel,
        prep: &PreparedPadded,
        x: &[f32],
        rows: usize,
    ) -> Result<Vec<f32>> {
        let m = pm.num_features;
        let groups = pm.num_groups;
        let ms = (m + 1) * (m + 1);
        let stride = groups * ms;
        let mut out = vec![0.0f32; rows * stride];
        let mb = prep.features;
        let msb = (mb + 1) * (mb + 1);

        let mut xpad = vec![0.0f32; prep.rows * mb];
        let mut r0 = 0;
        while r0 < rows {
            let rc = (rows - r0).min(prep.rows);
            pad_x(x, m, r0, rc, &mut xpad, mb);
            let xbuf = self.device.upload_f32(&xpad, &[prep.rows, mb])?;
            for (g, group_chunks) in prep.chunks.iter().enumerate() {
                for bufs in group_chunks {
                    let args: Vec<&PjRtBuffer> =
                        std::iter::once(&xbuf).chain(bufs.iter()).collect();
                    let lit = self.device.execute(&prep.artifact, &args)?;
                    let vals: Vec<f32> = lit.to_vec()?;
                    for r in 0..rc {
                        let src = &vals[r * msb..(r + 1) * msb];
                        let dst = &mut out
                            [(r0 + r) * stride + g * ms..(r0 + r) * stride + (g + 1) * ms];
                        for i in 0..m {
                            for j in 0..m {
                                dst[i * (m + 1) + j] += src[i * (mb + 1) + j];
                            }
                        }
                    }
                }
            }
            r0 += rc;
        }
        for r in 0..rows {
            for g in 0..groups {
                out[r * stride + g * ms + m * (m + 1) + m] += pm.expected_values[g] as f32;
            }
        }
        Ok(out)
    }

    /// SHAP values: output [rows × groups × (m+1)], base values included.
    pub fn shap_values(
        &self,
        pm: &PackedModel,
        prep: &Prepared,
        x: &[f32],
        rows: usize,
    ) -> Result<Vec<f32>> {
        debug_assert_eq!(prep.kind, ArtifactKind::Shap);
        let m = pm.num_features;
        let groups = pm.num_groups;
        let stride = groups * (m + 1);
        let mut out = vec![0.0f32; rows * stride];
        let mb = prep.features;

        let mut xpad = vec![0.0f32; prep.rows * mb];
        let mut r0 = 0;
        while r0 < rows {
            let rc = (rows - r0).min(prep.rows);
            pad_x(x, m, r0, rc, &mut xpad, mb);
            let xbuf = self.device.upload_f32(&xpad, &[prep.rows, mb])?;
            for (g, group_chunks) in prep.chunks.iter().enumerate() {
                for bufs in group_chunks {
                    let args: Vec<&PjRtBuffer> = std::iter::once(&xbuf)
                        .chain(bufs.iter())
                        .collect();
                    let lit = self.device.execute(&prep.artifact, &args)?;
                    let vals: Vec<f32> = lit.to_vec()?;
                    // accumulate [rc, mb+1] into out
                    for r in 0..rc {
                        let src = &vals[r * (mb + 1)..(r + 1) * (mb + 1)];
                        let dst = &mut out
                            [(r0 + r) * stride + g * (m + 1)..(r0 + r) * stride + (g + 1) * (m + 1)];
                        for f in 0..m {
                            dst[f] += src[f];
                        }
                        dst[m] += src[mb]; // bias lanes (always ~0)
                    }
                }
            }
            r0 += rc;
        }
        // base values
        for r in 0..rows {
            for g in 0..groups {
                out[r * stride + g * (m + 1) + m] += pm.expected_values[g] as f32;
            }
        }
        Ok(out)
    }

    /// Interaction values: output [rows × groups × (m+1)²].
    pub fn interactions(
        &self,
        pm: &PackedModel,
        prep: &Prepared,
        x: &[f32],
        rows: usize,
    ) -> Result<Vec<f32>> {
        debug_assert_eq!(prep.kind, ArtifactKind::Interactions);
        let m = pm.num_features;
        let groups = pm.num_groups;
        let ms = (m + 1) * (m + 1);
        let stride = groups * ms;
        let mut out = vec![0.0f32; rows * stride];
        let mb = prep.features;
        let msb = (mb + 1) * (mb + 1);

        let mut xpad = vec![0.0f32; prep.rows * mb];
        let mut r0 = 0;
        while r0 < rows {
            let rc = (rows - r0).min(prep.rows);
            pad_x(x, m, r0, rc, &mut xpad, mb);
            let xbuf = self.device.upload_f32(&xpad, &[prep.rows, mb])?;
            for (g, group_chunks) in prep.chunks.iter().enumerate() {
                for bufs in group_chunks {
                    let args: Vec<&PjRtBuffer> =
                        std::iter::once(&xbuf).chain(bufs.iter()).collect();
                    let lit = self.device.execute(&prep.artifact, &args)?;
                    let vals: Vec<f32> = lit.to_vec()?;
                    for r in 0..rc {
                        let src = &vals[r * msb..(r + 1) * msb];
                        let dst = &mut out
                            [(r0 + r) * stride + g * ms..(r0 + r) * stride + (g + 1) * ms];
                        // Eq. 6 diagonals are additive across bin chunks
                        for i in 0..m {
                            for j in 0..m {
                                dst[i * (m + 1) + j] += src[i * (mb + 1) + j];
                            }
                        }
                    }
                }
            }
            r0 += rc;
        }
        for r in 0..rows {
            for g in 0..groups {
                out[r * stride + g * ms + m * (m + 1) + m] += pm.expected_values[g] as f32;
            }
        }
        Ok(out)
    }

    /// Predictions: output [rows × groups], raw scores.
    pub fn predict(
        &self,
        pm: &PackedModel,
        prep: &Prepared,
        x: &[f32],
        rows: usize,
    ) -> Result<Vec<f32>> {
        debug_assert_eq!(prep.kind, ArtifactKind::Predict);
        let m = pm.num_features;
        let groups = pm.num_groups;
        let mut out = vec![pm.base_score; rows * groups];
        let mb = prep.features;

        let mut xpad = vec![0.0f32; prep.rows * mb];
        let mut r0 = 0;
        while r0 < rows {
            let rc = (rows - r0).min(prep.rows);
            pad_x(x, m, r0, rc, &mut xpad, mb);
            let xbuf = self.device.upload_f32(&xpad, &[prep.rows, mb])?;
            for (g, group_chunks) in prep.chunks.iter().enumerate() {
                for bufs in group_chunks {
                    let args: Vec<&PjRtBuffer> =
                        std::iter::once(&xbuf).chain(bufs.iter()).collect();
                    let lit = self.device.execute(&prep.artifact, &args)?;
                    let vals: Vec<f32> = lit.to_vec()?;
                    for r in 0..rc {
                        out[(r0 + r) * groups + g] += vals[r];
                    }
                }
            }
            r0 += rc;
        }
        Ok(out)
    }
}

/// Device-resident padded-path model for one artifact bucket.
pub struct PreparedPadded {
    pub artifact: String,
    pub rows: usize,
    pub paths: usize,
    pub features: usize,
    chunks: Vec<Vec<[PjRtBuffer; 6]>>,
}

/// Slice paths [start, end) of a padded group and re-pad to
/// (`paths` rows × `width` elements) for a fixed artifact shape.
fn repad(
    g: &crate::shap::packed::PaddedGroup,
    start: usize,
    end: usize,
    paths: usize,
    width: usize,
) -> crate::shap::packed::PaddedGroup {
    let narrow = g.slice_padded(start, end, paths);
    if narrow.width == width {
        return narrow;
    }
    let mut out = crate::shap::packed::PaddedGroup {
        fidx: vec![-1; paths * width],
        lower: vec![-crate::shap::packed::F32_BIG; paths * width],
        upper: vec![crate::shap::packed::F32_BIG; paths * width],
        zfrac: vec![1.0; paths * width],
        v: narrow.v.clone(),
        plen: narrow.plen.clone(),
        num_paths: paths,
        width,
        utilisation: narrow.utilisation,
    };
    for p in 0..paths {
        let (src, dst) = (p * narrow.width, p * width);
        let w = narrow.width.min(width);
        out.fidx[dst..dst + w].copy_from_slice(&narrow.fidx[src..src + w]);
        out.lower[dst..dst + w].copy_from_slice(&narrow.lower[src..src + w]);
        out.upper[dst..dst + w].copy_from_slice(&narrow.upper[src..src + w]);
        out.zfrac[dst..dst + w].copy_from_slice(&narrow.zfrac[src..src + w]);
    }
    out
}

/// Copy rows [r0, r0+rc) of x (m cols) into the padded [R × mb] buffer.
fn pad_x(x: &[f32], m: usize, r0: usize, rc: usize, xpad: &mut [f32], mb: usize) {
    xpad.iter_mut().for_each(|v| *v = 0.0);
    for r in 0..rc {
        let src = &x[(r0 + r) * m..(r0 + r + 1) * m];
        xpad[r * mb..r * mb + m].copy_from_slice(src);
    }
}
