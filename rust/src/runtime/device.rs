//! PJRT device wrapper: compiles HLO-text artifacts once and caches the
//! loaded executables (adapted from /opt/xla-example/load_hlo).

use std::collections::HashMap;

use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::util::error::{Context, Result};

use crate::runtime::manifest::ArtifactSpec;

/// One PJRT device (CPU client here; `PjRtClient::gpu/tpu` on real HW)
/// plus its compiled-executable cache.
pub struct Device {
    pub client: PjRtClient,
    execs: HashMap<String, PjRtLoadedExecutable>,
}

impl Device {
    pub fn cpu() -> Result<Device> {
        Ok(Device { client: PjRtClient::cpu()?, execs: HashMap::new() })
    }

    /// Compile (or fetch cached) the executable for an artifact.
    pub fn load(&mut self, spec: &ArtifactSpec) -> Result<&PjRtLoadedExecutable> {
        if !self.execs.contains_key(&spec.name) {
            let proto = HloModuleProto::from_text_file(&spec.file)
                .with_context(|| format!("parsing {}", spec.file.display()))?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", spec.name))?;
            self.execs.insert(spec.name.clone(), exe);
        }
        Ok(&self.execs[&spec.name])
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.execs.contains_key(name)
    }

    /// Upload a host f32 array to the device.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload a host i32 array to the device.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Execute a loaded artifact on device buffers; returns the first
    /// element of the 1-tuple output as a host literal.
    pub fn execute(&self, name: &str, args: &[&PjRtBuffer]) -> Result<Literal> {
        let exe = self
            .execs
            .get(name)
            .ok_or_else(|| crate::anyhow!("artifact '{name}' not loaded"))?;
        let out = exe.execute_b(args)?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple1()?)
    }
}
