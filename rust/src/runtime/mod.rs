//! The PJRT runtime: loads the AOT HLO artifacts produced by
//! `python/compile/aot.py` and executes them on the request path with no
//! python anywhere. `Device` wraps a PJRT client + executable cache;
//! `ShapEngine` tiles workloads over fixed-shape executions with
//! device-resident packed models; `pool` scales across devices.
//!
//! Everything that needs the `xla` bindings crate is gated behind the
//! `xla` cargo feature; the manifest (a pure-JSON contract) is always
//! available so planners and tools can inspect artifact buckets without
//! a device runtime, and `pool` (a thin wrapper over the sharded
//! backend) works on every backend kind. Callers outside this layer
//! should reach execution through `backend::ShapBackend`, never
//! `ShapEngine` directly.

#[cfg(feature = "xla")]
pub mod device;
#[cfg(feature = "xla")]
pub mod engine;
pub mod manifest;
pub mod pool;

#[cfg(feature = "xla")]
pub use device::Device;
#[cfg(feature = "xla")]
pub use engine::{Prepared, PreparedPadded, ShapEngine};
pub use manifest::{ArtifactKind, ArtifactSpec, Manifest};

use std::path::PathBuf;

/// Default artifacts directory: `$GTS_ARTIFACTS` or `<repo>/rust/artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("GTS_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust").join("artifacts")
}
