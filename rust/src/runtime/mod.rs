//! The PJRT runtime: loads the AOT HLO artifacts produced by
//! `python/compile/aot.py` and executes them on the request path with no
//! python anywhere. `Device` wraps a PJRT client + executable cache;
//! `ShapEngine` tiles workloads over fixed-shape executions with
//! device-resident packed models; `pool` scales across devices.

pub mod device;
pub mod engine;
pub mod manifest;
pub mod pool;

pub use device::Device;
pub use engine::{Prepared, PreparedPadded, ShapEngine};
pub use manifest::{ArtifactKind, ArtifactSpec, Manifest};

use std::path::PathBuf;

/// Default artifacts directory: `$GTS_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("GTS_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
