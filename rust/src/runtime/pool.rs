//! Multi-device execution pool — a one-call convenience over the
//! backend layer's [`ShardedBackend`] for callers that want the Fig-5
//! row-sharded scheme without touching planner or backend types: pick
//! the best backend for this batch size, split it over `devices`, run.
//! The fig5 bench itself drives `ShardedBackend` directly (it sweeps
//! axes and shard counts); this wrapper is the minimal embedding API.
//!
//! The original implementation here was XLA-only, reachable only from
//! the fig5 bench, swallowed all but one worker error and kept feeding
//! chunks to healthy workers after a failure. All of that now lives in
//! `backend::sharded`, which this module merely parameterises: worker
//! errors are aggregated into the returned error, a failed shard aborts
//! the remaining work promptly, and results are only returned when every
//! chunk completed (see `rust/tests/backends.rs` failure-semantics
//! tests). On a DGX the shards would be 8 GPU clients; here every
//! "device" is an independent backend instance, so scaling flattens
//! once physical cores saturate (DESIGN.md §5 scale substitutions).

use std::path::Path;
use std::sync::Arc;

use crate::backend::{self, BackendConfig, ShardAxis};
use crate::gbdt::Model;
use crate::util::error::Result;

/// SHAP values over `devices` row shards, each an independent instance
/// of the planner's best backend for this batch size. Output layout
/// matches `ShapBackend::contributions`.
///
/// Repeated calls with the same `Arc<Model>` hit the prepared-model
/// cache (`backend::prepare`): path extraction and packing are paid on
/// the first call only, so the per-call build here costs a cache lookup
/// in steady state.
///
/// Elastic: when the sharded execution fails and names the failed
/// shards, they are quarantined (row-axis survivors hold the full
/// model) and the batch is retried once over the survivors — a lost
/// device degrades throughput instead of failing the call. Errors with
/// no shard attribution (or with no survivors) propagate unchanged.
pub fn shap_values_multi(
    model: &Arc<Model>,
    x: &[f32],
    rows: usize,
    devices: usize,
    artifacts_dir: &Path,
) -> Result<Vec<f32>> {
    let cfg = BackendConfig {
        rows_hint: rows.max(1),
        devices: devices.max(1),
        shard_axis: Some(ShardAxis::Rows),
        artifacts_dir: artifacts_dir.to_path_buf(),
        ..Default::default()
    };
    let (_plan, mut b) = backend::build_auto(model, &cfg)?;
    match b.contributions(x, rows) {
        Ok(out) => Ok(out),
        Err(e) => {
            let failed = b.failed_shards();
            if failed.is_empty() || b.quarantine(&failed).is_err() {
                return Err(e);
            }
            b.contributions(x, rows)
                .map_err(|retry| retry.context("retry over surviving shards"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;
    use crate::gbdt::{train, TrainParams};
    use crate::runtime::default_artifacts_dir;

    #[test]
    fn pool_matches_single_device() {
        let d = SynthSpec::cal_housing(0.005).generate();
        let model = Arc::new(train(
            &d,
            &TrainParams { rounds: 3, max_depth: 3, ..Default::default() },
        ));
        let m = model.num_features;
        let rows = 12.min(d.rows);
        let x = &d.features[..rows * m];
        let dir = default_artifacts_dir();
        let one = shap_values_multi(&model, x, rows, 1, &dir).unwrap();
        let three = shap_values_multi(&model, x, rows, 3, &dir).unwrap();
        assert_eq!(one.len(), three.len());
        for (a, b) in one.iter().zip(&three) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }
}
