//! Multi-device execution pool (Fig 5): one engine per simulated device,
//! each on its own worker thread with its own PJRT client and compiled
//! executables; row chunks are handed out via a shared cursor and the
//! results are assembled on the coordinating thread (no shared mutable
//! output, no raw pointers).
//!
//! On a DGX this would be 8 GPU clients; here every "device" is a CPU
//! PJRT client, so scaling flattens once physical cores saturate — the
//! bench records the curve either way (DESIGN.md §5 scale substitutions).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::runtime::engine::ShapEngine;
use crate::runtime::manifest::ArtifactKind;
use crate::shap::packed::PackedModel;
use crate::util::error::{Error, Result};

/// SHAP values over `devices` simulated devices. Output layout matches
/// `ShapEngine::shap_values`.
pub fn shap_values_multi(
    pm: &PackedModel,
    x: &[f32],
    rows: usize,
    devices: usize,
    artifacts_dir: &Path,
) -> Result<Vec<f32>> {
    let devices = devices.max(1);
    let m = pm.num_features;
    let stride = pm.num_groups * (m + 1);
    let mut out = vec![0.0f32; rows * stride];
    let cursor = AtomicUsize::new(0);
    let dir: PathBuf = artifacts_dir.to_path_buf();
    let errs: std::sync::Mutex<Vec<Error>> = std::sync::Mutex::new(Vec::new());
    let (tx, rx) = std::sync::mpsc::channel::<(usize, Vec<f32>)>();

    std::thread::scope(|scope| {
        for _ in 0..devices {
            let tx = tx.clone();
            let dir = &dir;
            let errs = &errs;
            let cursor = &cursor;
            scope.spawn(move || {
                let run = || -> Result<()> {
                    let mut engine = ShapEngine::new(dir)?;
                    let prep = engine.prepare(pm, ArtifactKind::Shap, rows)?;
                    let chunk = prep.rows;
                    loop {
                        let r0 = cursor.fetch_add(chunk, Ordering::Relaxed);
                        if r0 >= rows {
                            return Ok(());
                        }
                        let rc = (rows - r0).min(chunk);
                        let vals =
                            engine.shap_values(pm, &prep, &x[r0 * m..(r0 + rc) * m], rc)?;
                        let _ = tx.send((r0, vals));
                    }
                };
                if let Err(e) = run() {
                    errs.lock().unwrap().push(e);
                }
            });
        }
        drop(tx);
        // assemble chunks as workers produce them; `rx` closes once every
        // worker has dropped its sender, which also bounds this loop
        for (r0, vals) in rx.iter() {
            out[r0 * stride..r0 * stride + vals.len()].copy_from_slice(&vals);
        }
    });
    if let Some(e) = errs.into_inner().unwrap().pop() {
        return Err(e);
    }
    Ok(out)
}
