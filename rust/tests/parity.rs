//! Three-way parity: XLA runtime (AOT Pallas kernel) vs rust host DP vs
//! recursive Algorithm 1 — the end-to-end correctness proof that all
//! three layers compose. Requires `make artifacts`.

use gputreeshap::data::SynthSpec;
use gputreeshap::gbdt::{train, TrainParams};
use gputreeshap::runtime::{default_artifacts_dir, ArtifactKind, ShapEngine};
use gputreeshap::shap::{host_kernel, pack_model, pad_model, treeshap, Packing};

fn artifacts_ready() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}

fn close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol + 1e-3 * x.abs().max(y.abs()),
            "{what}: idx {i}: {x} vs {y}"
        );
    }
}

#[test]
fn shap_values_three_way_parity() {
    if !artifacts_ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let d = SynthSpec::cal_housing(0.01).generate();
    let model = train(&d, &TrainParams { rounds: 8, max_depth: 5, ..Default::default() });
    let pm = pack_model(&model, Packing::BestFitDecreasing);
    let rows = 100;
    let m = model.num_features;
    let x = &d.features[..rows * m];

    let baseline = treeshap::shap_values(&model, x, rows, 2);
    let host = host_kernel::shap_values(&pm, x, rows, 2);
    close(&baseline, &host, 2e-4, "recursive vs host DP");

    let mut engine = ShapEngine::new(&default_artifacts_dir()).unwrap();
    let prep = engine.prepare(&pm, ArtifactKind::Shap, rows).unwrap();
    let xla = engine.shap_values(&pm, &prep, x, rows).unwrap();
    close(&baseline, &xla, 2e-3, "recursive vs XLA runtime");
}

#[test]
fn shap_values_multiclass_parity() {
    if !artifacts_ready() {
        return;
    }
    let d = SynthSpec::covtype(0.001).generate();
    let model = train(&d, &TrainParams { rounds: 2, max_depth: 4, ..Default::default() });
    let pm = pack_model(&model, Packing::BestFitDecreasing);
    let rows = 40;
    let m = model.num_features;
    let x = &d.features[..rows * m];

    let baseline = treeshap::shap_values(&model, x, rows, 2);
    let mut engine = ShapEngine::new(&default_artifacts_dir()).unwrap();
    let prep = engine.prepare(&pm, ArtifactKind::Shap, rows).unwrap();
    let xla = engine.shap_values(&pm, &prep, x, rows).unwrap();
    close(&baseline, &xla, 2e-3, "multiclass recursive vs XLA");
}

#[test]
fn interactions_parity() {
    if !artifacts_ready() {
        return;
    }
    let d = SynthSpec::cal_housing(0.005).generate();
    let model = train(&d, &TrainParams { rounds: 4, max_depth: 4, ..Default::default() });
    let pm = pack_model(&model, Packing::BestFitDecreasing);
    let rows = 8;
    let m = model.num_features;
    let x = &d.features[..rows * m];

    let baseline = gputreeshap::shap::interactions::interaction_values(&model, x, rows, 2);
    let host = host_kernel::interaction_values(&pm, x, rows, 2);
    close(&baseline, &host, 5e-4, "interactions recursive vs host");

    let mut engine = ShapEngine::new(&default_artifacts_dir()).unwrap();
    let prep = engine.prepare(&pm, ArtifactKind::Interactions, rows).unwrap();
    let xla = engine.interactions(&pm, &prep, x, rows).unwrap();
    close(&baseline, &xla, 5e-3, "interactions recursive vs XLA");
}

#[test]
fn padded_interactions_parity() {
    if !artifacts_ready() {
        return;
    }
    let d = SynthSpec::adult(0.004).generate();
    let model = train(&d, &TrainParams { rounds: 3, max_depth: 4, ..Default::default() });
    let rows = 8;
    let m = model.num_features;
    let x = &d.features[..rows * m];

    let baseline = gputreeshap::shap::interactions::interaction_values(&model, x, rows, 2);
    let mut engine = ShapEngine::new(&default_artifacts_dir()).unwrap();
    let depth = pack_model(&model, Packing::BestFitDecreasing).max_depth.max(2);
    let width = engine
        .manifest
        .select(ArtifactKind::InteractionsPadded, m, depth, rows)
        .unwrap()
        .depth
        + 1;
    let pad = pad_model(&model, width);
    let prep = engine
        .prepare_padded_kind(&pad, ArtifactKind::InteractionsPadded, rows)
        .unwrap();
    let xla = engine.interactions_padded(&pad, &prep, x, rows).unwrap();
    close(&baseline, &xla, 5e-3, "interactions recursive vs padded XLA");
}

#[test]
fn predict_parity_and_additivity() {
    if !artifacts_ready() {
        return;
    }
    let d = SynthSpec::adult(0.005).generate();
    let model = train(&d, &TrainParams { rounds: 5, max_depth: 5, ..Default::default() });
    let pm = pack_model(&model, Packing::BestFitDecreasing);
    let rows = 64;
    let m = model.num_features;
    let x = &d.features[..rows * m];

    let mut engine = ShapEngine::new(&default_artifacts_dir()).unwrap();
    let prep = engine.prepare(&pm, ArtifactKind::Predict, rows).unwrap();
    let preds = engine.predict(&pm, &prep, x, rows).unwrap();
    for r in 0..rows {
        let want = model.predict_row_raw(d.row(r))[0];
        assert!((preds[r] - want).abs() < 1e-4, "row {r}: {} vs {want}", preds[r]);
    }

    // additivity: Σφ == prediction, through the XLA path end to end
    let sprep = engine.prepare(&pm, ArtifactKind::Shap, rows).unwrap();
    let phis = engine.shap_values(&pm, &sprep, x, rows).unwrap();
    for r in 0..rows {
        let total: f32 = phis[r * (m + 1)..(r + 1) * (m + 1)].iter().sum();
        assert!(
            (total - preds[r]).abs() < 5e-3,
            "row {r}: Σφ {total} vs f(x) {}",
            preds[r]
        );
    }
}

#[test]
fn padded_layout_matches_warp_layout_and_baseline() {
    if !artifacts_ready() {
        return;
    }
    let d = SynthSpec::covtype(0.001).generate();
    let model = train(&d, &TrainParams { rounds: 2, max_depth: 5, ..Default::default() });
    let rows = 64;
    let m = model.num_features;
    let x = &d.features[..rows * m];

    let baseline = treeshap::shap_values(&model, x, rows, 2);
    let mut engine = ShapEngine::new(&default_artifacts_dir()).unwrap();

    let pm = pack_model(&model, Packing::BestFitDecreasing);
    let warp_prep = engine.prepare(&pm, ArtifactKind::Shap, rows).unwrap();
    let warp = engine.shap_values(&pm, &warp_prep, x, rows).unwrap();

    let spec_depth = engine
        .manifest
        .select(ArtifactKind::ShapPadded, m, pm.max_depth.max(1), rows)
        .unwrap()
        .depth;
    let pad = pad_model(&model, spec_depth + 1);
    let pad_prep = engine.prepare_padded(&pad, rows).unwrap();
    let padded = engine.shap_values_padded(&pad, &pad_prep, x, rows).unwrap();

    close(&baseline, &warp, 2e-3, "recursive vs warp layout");
    close(&baseline, &padded, 2e-3, "recursive vs padded layout");
    close(&warp, &padded, 2e-3, "warp vs padded layout");
}

#[test]
fn packing_algorithm_is_invisible_to_results() {
    if !artifacts_ready() {
        return;
    }
    let d = SynthSpec::adult(0.004).generate();
    let model = train(&d, &TrainParams { rounds: 3, max_depth: 4, ..Default::default() });
    let rows = 32;
    let m = model.num_features;
    let x = &d.features[..rows * m];
    let mut engine = ShapEngine::new(&default_artifacts_dir()).unwrap();
    let mut results = Vec::new();
    for alg in [
        Packing::None,
        Packing::NextFit,
        Packing::FirstFitDecreasing,
        Packing::BestFitDecreasing,
    ] {
        let pm = pack_model(&model, alg);
        let prep = engine.prepare(&pm, ArtifactKind::Shap, rows).unwrap();
        results.push(engine.shap_values(&pm, &prep, x, rows).unwrap());
    }
    for r in &results[1..] {
        close(&results[0], r, 1e-4, "packing invariance");
    }
}

#[test]
fn deep_model_uses_deep_bucket() {
    if !artifacts_ready() {
        return;
    }
    // depth-12 trees over 54 features: merged paths stay deep (> 8
    // unique features per path), forcing the d16 artifact
    let d = SynthSpec::covtype(0.002).generate();
    let model = train(&d, &TrainParams { rounds: 1, max_depth: 12, ..Default::default() });
    let pm = pack_model(&model, Packing::BestFitDecreasing);
    assert!(pm.max_depth > 8, "test needs deep paths, got {}", pm.max_depth);
    let rows = 16;
    let m = model.num_features;
    let x = &d.features[..rows * m];
    let baseline = treeshap::shap_values(&model, x, rows, 2);
    let mut engine = ShapEngine::new(&default_artifacts_dir()).unwrap();
    let prep = engine.prepare(&pm, ArtifactKind::Shap, rows).unwrap();
    assert!(prep.artifact.contains("d16"), "picked {}", prep.artifact);
    let xla = engine.shap_values(&pm, &prep, x, rows).unwrap();
    close(&baseline, &xla, 5e-3, "deep model recursive vs XLA");
}
