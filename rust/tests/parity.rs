//! Cross-backend parity through the unified `ShapBackend` trait: the
//! recursive Algorithm 1 oracle vs the host packed DP (always compiled)
//! and vs the XLA runtime engines (with `--features xla` + `make
//! artifacts`) — the end-to-end correctness proof that every execution
//! path computes the same φ and Φ.

use std::sync::Arc;

use gputreeshap::backend::{self, BackendConfig, BackendKind, ShapBackend};
use gputreeshap::data::SynthSpec;
use gputreeshap::gbdt::{train, Model, TrainParams};
use gputreeshap::shap::Packing;

fn close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol + 1e-3 * x.abs().max(y.abs()),
            "{what}: idx {i}: {x} vs {y}"
        );
    }
}

fn cfg(rows: usize) -> BackendConfig {
    BackendConfig { threads: 2, rows_hint: rows, with_interactions: true, ..Default::default() }
}

fn contributions(model: &Arc<Model>, kind: BackendKind, x: &[f32], rows: usize) -> Vec<f32> {
    backend::build(model, kind, &cfg(rows))
        .unwrap_or_else(|e| panic!("build {}: {e:#}", kind.name()))
        .contributions(x, rows)
        .unwrap()
}

fn interactions(model: &Arc<Model>, kind: BackendKind, x: &[f32], rows: usize) -> Vec<f32> {
    backend::build(model, kind, &cfg(rows))
        .unwrap_or_else(|e| panic!("build {}: {e:#}", kind.name()))
        .interactions(x, rows)
        .unwrap()
}

#[test]
fn host_backend_matches_recursive_oracle() {
    let d = SynthSpec::cal_housing(0.01).generate();
    let model =
        Arc::new(train(&d, &TrainParams { rounds: 8, max_depth: 5, ..Default::default() }));
    let rows = 100;
    let m = model.num_features;
    let x = &d.features[..rows * m];
    let baseline = contributions(&model, BackendKind::Recursive, x, rows);
    let host = contributions(&model, BackendKind::Host, x, rows);
    close(&baseline, &host, 2e-4, "recursive vs host DP");
}

#[test]
fn host_interactions_match_recursive_oracle() {
    let d = SynthSpec::cal_housing(0.005).generate();
    let model =
        Arc::new(train(&d, &TrainParams { rounds: 4, max_depth: 4, ..Default::default() }));
    let rows = 8;
    let m = model.num_features;
    let x = &d.features[..rows * m];
    let baseline = interactions(&model, BackendKind::Recursive, x, rows);
    let host = interactions(&model, BackendKind::Host, x, rows);
    close(&baseline, &host, 5e-4, "interactions recursive vs host");
}

#[test]
fn multiclass_host_parity() {
    let d = SynthSpec::covtype(0.001).generate();
    let model =
        Arc::new(train(&d, &TrainParams { rounds: 2, max_depth: 4, ..Default::default() }));
    let rows = 40;
    let m = model.num_features;
    let x = &d.features[..rows * m];
    let baseline = contributions(&model, BackendKind::Recursive, x, rows);
    let host = contributions(&model, BackendKind::Host, x, rows);
    close(&baseline, &host, 2e-4, "multiclass recursive vs host");
}

#[test]
fn linear_backend_matches_recursive_oracle() {
    let d = SynthSpec::cal_housing(0.01).generate();
    let model =
        Arc::new(train(&d, &TrainParams { rounds: 8, max_depth: 5, ..Default::default() }));
    let rows = 100;
    let m = model.num_features;
    let x = &d.features[..rows * m];
    let baseline = contributions(&model, BackendKind::Recursive, x, rows);
    let linear = contributions(&model, BackendKind::Linear, x, rows);
    close(&baseline, &linear, 1e-6, "recursive vs linear TreeShap");
}

#[test]
fn multiclass_linear_parity() {
    let d = SynthSpec::covtype(0.001).generate();
    let model =
        Arc::new(train(&d, &TrainParams { rounds: 2, max_depth: 4, ..Default::default() }));
    let rows = 40;
    let m = model.num_features;
    let x = &d.features[..rows * m];
    let baseline = contributions(&model, BackendKind::Recursive, x, rows);
    let linear = contributions(&model, BackendKind::Linear, x, rows);
    close(&baseline, &linear, 1e-6, "multiclass recursive vs linear");
}

#[test]
fn deep_model_linear_parity() {
    // depth 12: the regime Linear TreeShap exists for — long merged
    // paths stress the quadrature degree and padding tables
    let d = SynthSpec::covtype(0.002).generate();
    let model =
        Arc::new(train(&d, &TrainParams { rounds: 1, max_depth: 12, ..Default::default() }));
    let rows = 16;
    let m = model.num_features;
    let x = &d.features[..rows * m];
    let baseline = contributions(&model, BackendKind::Recursive, x, rows);
    let linear = contributions(&model, BackendKind::Linear, x, rows);
    close(&baseline, &linear, 1e-6, "deep recursive vs linear");
}

#[test]
fn linear_phi_matches_oracle_across_the_zoo() {
    // the acceptance sweep: every zoo dataset shape (Small grid covers
    // all four cheaply), the medium/large depth regimes on the cheap
    // datasets, and the hand-built repeated-feature model — φ within
    // 1e-6 of the recursive oracle plus local accuracy per row
    use gputreeshap::bench::zoo;
    use gputreeshap::gbdt::ZooSize;
    let mut cases: Vec<(String, Arc<Model>, Vec<f32>, usize)> = Vec::new();
    for e in zoo::zoo_entries() {
        let cheap = e.spec.name == "cal_housing" || e.spec.name == "adult";
        let keep = e.size == ZooSize::Small
            || (cheap && e.size == ZooSize::Medium)
            || (e.spec.name == "cal_housing" && e.size == ZooSize::Large);
        if !keep {
            continue;
        }
        let (model, data) = zoo::build(&e);
        let rows = 16.min(data.rows);
        let x = data.features[..rows * model.num_features].to_vec();
        cases.push((e.name, Arc::new(model), x, rows));
    }
    {
        let model = Arc::new(zoo::repeated_feature_model());
        let x = vec![-2.0, 0.0, -0.5, 0.0, -0.5, 2.0, 0.5, 1.5, 3.0, -1.0];
        cases.push(("repeated-feature".to_string(), model, x, 5));
    }
    for (name, model, x, rows) in &cases {
        let m = model.num_features;
        let g = model.num_groups;
        let baseline = contributions(model, BackendKind::Recursive, x, *rows);
        let linear = contributions(model, BackendKind::Linear, x, *rows);
        close(&baseline, &linear, 1e-6, &format!("{name}: recursive vs linear"));
        // local accuracy: Σφ + base == f(x) per row and group
        for r in 0..*rows {
            let preds = model.predict_row_raw(&x[r * m..(r + 1) * m]);
            for k in 0..g {
                let o = r * g * (m + 1) + k * (m + 1);
                let s: f64 = linear[o..o + m + 1].iter().map(|&v| f64::from(v)).sum();
                assert!(
                    (s - f64::from(preds[k])).abs() < 2e-3,
                    "{name} row {r} group {k}: Σφ {s} vs f(x) {}",
                    preds[k]
                );
            }
        }
    }
}

#[test]
fn linear_backend_is_phi_only() {
    let d = SynthSpec::cal_housing(0.004).generate();
    let model =
        Arc::new(train(&d, &TrainParams { rounds: 2, max_depth: 3, ..Default::default() }));
    let rows = 4;
    let b = backend::build(&model, BackendKind::Linear, &cfg(rows)).unwrap();
    assert!(!b.caps().supports_interactions, "linear is φ-only");
    let m = model.num_features;
    let x = &d.features[..rows * m];
    let err = b.interactions(x, rows).unwrap_err();
    assert!(err.to_string().contains("auto"), "error should point at --backend auto: {err:#}");
    // predictions ARE served (raw tree routing)
    let preds = b.predictions(x, rows).unwrap();
    for r in 0..rows {
        let want = model.predict_row_raw(&x[r * m..(r + 1) * m])[0];
        assert_eq!(preds[r], want);
    }
    // and the capability system routes Φ requests past linear: auto
    // with interactions demanded never lands on a φ-only backend
    let (_, auto) = backend::build_auto(&model, &cfg(rows)).unwrap();
    assert!(auto.caps().supports_interactions);
    auto.interactions(x, rows).unwrap();
}

#[test]
fn fastv2_backend_matches_recursive_oracle() {
    let d = SynthSpec::cal_housing(0.01).generate();
    let model =
        Arc::new(train(&d, &TrainParams { rounds: 8, max_depth: 5, ..Default::default() }));
    let rows = 100;
    let m = model.num_features;
    let x = &d.features[..rows * m];
    let baseline = contributions(&model, BackendKind::Recursive, x, rows);
    let fastv2 = contributions(&model, BackendKind::FastV2, x, rows);
    close(&baseline, &fastv2, 1e-6, "recursive vs fastv2 weight tables");
}

#[test]
fn multiclass_fastv2_parity() {
    let d = SynthSpec::covtype(0.001).generate();
    let model =
        Arc::new(train(&d, &TrainParams { rounds: 2, max_depth: 4, ..Default::default() }));
    let rows = 40;
    let m = model.num_features;
    let x = &d.features[..rows * m];
    let baseline = contributions(&model, BackendKind::Recursive, x, rows);
    let fastv2 = contributions(&model, BackendKind::FastV2, x, rows);
    close(&baseline, &fastv2, 1e-6, "multiclass recursive vs fastv2");
}

#[test]
fn deep_model_fastv2_parity() {
    // depth 12: long merged paths stress the 2^d subset enumeration and
    // the per-path Shapley weight rows (d up to 12 here, so the tables
    // stay well under the default budget while exercising deep masks)
    let d = SynthSpec::covtype(0.002).generate();
    let model =
        Arc::new(train(&d, &TrainParams { rounds: 1, max_depth: 12, ..Default::default() }));
    let rows = 16;
    let m = model.num_features;
    let x = &d.features[..rows * m];
    let baseline = contributions(&model, BackendKind::Recursive, x, rows);
    let fastv2 = contributions(&model, BackendKind::FastV2, x, rows);
    close(&baseline, &fastv2, 1e-6, "deep recursive vs fastv2");
}

#[test]
fn fastv2_phi_matches_oracle_across_the_zoo() {
    // the acceptance sweep, mirroring the linear one: every zoo dataset
    // shape (Small grid), the medium/large regimes on the cheap
    // datasets, NaN probes, and the hand-built repeated-feature model —
    // φ within 1e-6 of the recursive oracle plus local accuracy per row
    use gputreeshap::bench::zoo;
    use gputreeshap::gbdt::ZooSize;
    let mut cases: Vec<(String, Arc<Model>, Vec<f32>, usize, usize)> = Vec::new();
    for e in zoo::zoo_entries() {
        let cheap = e.spec.name == "cal_housing" || e.spec.name == "adult";
        let keep = e.size == ZooSize::Small
            || (cheap && e.size == ZooSize::Medium)
            || (e.spec.name == "cal_housing" && e.size == ZooSize::Large);
        if !keep {
            continue;
        }
        let (model, data) = zoo::build(&e);
        let rows = 16.min(data.rows);
        let mut x = data.features[..rows * model.num_features].to_vec();
        // poison one feature in the first half of the rows with NaN:
        // missing values must follow the oracle's activation convention
        // (NaN matches no split interval, so the feature is inactive)
        let m = model.num_features;
        let nan_rows = rows / 2;
        for r in 0..nan_rows {
            x[r * m + (r % m)] = f32::NAN;
        }
        cases.push((e.name, Arc::new(model), x, rows, nan_rows));
    }
    {
        let model = Arc::new(zoo::repeated_feature_model());
        let x = vec![-2.0, 0.0, -0.5, 0.0, -0.5, 2.0, 0.5, 1.5, 3.0, -1.0];
        cases.push(("repeated-feature".to_string(), model, x, 5, 0));
    }
    for (name, model, x, rows, nan_rows) in &cases {
        let m = model.num_features;
        let g = model.num_groups;
        let baseline = contributions(model, BackendKind::Recursive, x, *rows);
        let fastv2 = contributions(model, BackendKind::FastV2, x, *rows);
        close(&baseline, &fastv2, 1e-6, &format!("{name}: recursive vs fastv2"));
        // local accuracy: Σφ + base == f(x) per row and group — only on
        // NaN-free rows (a missing feature is marginalized out, so Σφ
        // intentionally differs from routing the raw row)
        for r in *nan_rows..*rows {
            let preds = model.predict_row_raw(&x[r * m..(r + 1) * m]);
            for k in 0..g {
                let o = r * g * (m + 1) + k * (m + 1);
                let s: f64 = fastv2[o..o + m + 1].iter().map(|&v| f64::from(v)).sum();
                assert!(
                    (s - f64::from(preds[k])).abs() < 2e-3,
                    "{name} row {r} group {k}: Σφ {s} vs f(x) {}",
                    preds[k]
                );
            }
        }
    }
}

#[test]
fn fastv2_backend_is_phi_only() {
    let d = SynthSpec::cal_housing(0.004).generate();
    let model =
        Arc::new(train(&d, &TrainParams { rounds: 2, max_depth: 3, ..Default::default() }));
    let rows = 4;
    let b = backend::build(&model, BackendKind::FastV2, &cfg(rows)).unwrap();
    assert!(!b.caps().supports_interactions, "fastv2 is φ-only");
    let m = model.num_features;
    let x = &d.features[..rows * m];
    let err = b.interactions(x, rows).unwrap_err();
    assert!(err.to_string().contains("auto"), "error should point at --backend auto: {err:#}");
    // predictions ARE served (raw tree routing)
    let preds = b.predictions(x, rows).unwrap();
    for r in 0..rows {
        let want = model.predict_row_raw(&x[r * m..(r + 1) * m])[0];
        assert_eq!(preds[r], want);
    }
    // and auto with interactions demanded never lands on a φ-only backend
    let (_, auto) = backend::build_auto(&model, &cfg(rows)).unwrap();
    assert!(auto.caps().supports_interactions);
    auto.interactions(x, rows).unwrap();
}

#[test]
fn fastv2_guardrail_refuses_construction_over_budget() {
    // a depth-14 ensemble: merged paths up to 14 unique features, so the
    // subset tables are the largest this repo can build. With the budget
    // forced below the table size the build must REFUSE — before any
    // allocation — and say which knob raises the cap.
    let d = SynthSpec::cal_housing(0.01).generate();
    let model =
        Arc::new(train(&d, &TrainParams { rounds: 2, max_depth: 14, ..Default::default() }));
    let mut c = cfg(4);
    c.fastv2_max_mb = 0;
    let err = backend::build(&model, BackendKind::FastV2, &c).unwrap_err();
    let msg = err.to_string();
    assert!(
        msg.contains("fastv2-max-mb") && msg.contains("budget"),
        "refusal must name the budget knob: {msg}"
    );
    // the same model constructs fine under the default budget, and
    // matches the oracle — the guardrail is the budget, not the depth
    let rows = 4;
    let m = model.num_features;
    let x = &d.features[..rows * m];
    let baseline = contributions(&model, BackendKind::Recursive, x, rows);
    let fastv2 = contributions(&model, BackendKind::FastV2, x, rows);
    close(&baseline, &fastv2, 1e-6, "depth-14 recursive vs fastv2");
}

#[test]
fn packing_algorithm_is_invisible_to_results() {
    let d = SynthSpec::adult(0.004).generate();
    let model =
        Arc::new(train(&d, &TrainParams { rounds: 3, max_depth: 4, ..Default::default() }));
    let rows = 32;
    let m = model.num_features;
    let x = &d.features[..rows * m];
    let mut results = Vec::new();
    for alg in Packing::ALL {
        let c = BackendConfig { threads: 1, packing: alg, rows_hint: rows, ..Default::default() };
        let b = backend::build(&model, BackendKind::Host, &c).unwrap();
        results.push(b.contributions(x, rows).unwrap());
    }
    for r in &results[1..] {
        close(&results[0], r, 1e-4, "packing invariance");
    }
}

#[cfg(feature = "xla")]
mod xla {
    use super::*;
    use gputreeshap::runtime::default_artifacts_dir;

    fn artifacts_ready() -> bool {
        default_artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn shap_values_three_way_parity() {
        if !artifacts_ready() {
            eprintln!("SKIP: run `make artifacts` first");
            return;
        }
        let d = SynthSpec::cal_housing(0.01).generate();
        let model =
            Arc::new(train(&d, &TrainParams { rounds: 8, max_depth: 5, ..Default::default() }));
        let rows = 100;
        let m = model.num_features;
        let x = &d.features[..rows * m];
        let baseline = contributions(&model, BackendKind::Recursive, x, rows);
        let host = contributions(&model, BackendKind::Host, x, rows);
        let warp = contributions(&model, BackendKind::XlaWarp, x, rows);
        let padded = contributions(&model, BackendKind::XlaPadded, x, rows);
        close(&baseline, &host, 2e-4, "recursive vs host DP");
        close(&baseline, &warp, 2e-3, "recursive vs XLA warp");
        close(&baseline, &padded, 2e-3, "recursive vs XLA padded");
        close(&warp, &padded, 2e-3, "warp vs padded layout");
    }

    #[test]
    fn multiclass_xla_parity() {
        if !artifacts_ready() {
            return;
        }
        let d = SynthSpec::covtype(0.001).generate();
        let model =
            Arc::new(train(&d, &TrainParams { rounds: 2, max_depth: 4, ..Default::default() }));
        let rows = 40;
        let m = model.num_features;
        let x = &d.features[..rows * m];
        let baseline = contributions(&model, BackendKind::Recursive, x, rows);
        let xla = contributions(&model, BackendKind::XlaWarp, x, rows);
        close(&baseline, &xla, 2e-3, "multiclass recursive vs XLA");
    }

    #[test]
    fn interactions_parity_all_backends() {
        if !artifacts_ready() {
            return;
        }
        let d = SynthSpec::cal_housing(0.005).generate();
        let model =
            Arc::new(train(&d, &TrainParams { rounds: 4, max_depth: 4, ..Default::default() }));
        let rows = 8;
        let m = model.num_features;
        let x = &d.features[..rows * m];
        let baseline = interactions(&model, BackendKind::Recursive, x, rows);
        let warp = interactions(&model, BackendKind::XlaWarp, x, rows);
        close(&baseline, &warp, 5e-3, "interactions recursive vs XLA warp");
    }

    #[test]
    fn padded_interactions_parity() {
        if !artifacts_ready() {
            return;
        }
        let d = SynthSpec::adult(0.004).generate();
        let model =
            Arc::new(train(&d, &TrainParams { rounds: 3, max_depth: 4, ..Default::default() }));
        let rows = 8;
        let m = model.num_features;
        let x = &d.features[..rows * m];
        let baseline = interactions(&model, BackendKind::Recursive, x, rows);
        let padded = interactions(&model, BackendKind::XlaPadded, x, rows);
        close(&baseline, &padded, 5e-3, "interactions recursive vs padded XLA");
    }

    #[test]
    fn predict_parity_and_additivity() {
        if !artifacts_ready() {
            return;
        }
        let d = SynthSpec::adult(0.005).generate();
        let model =
            Arc::new(train(&d, &TrainParams { rounds: 5, max_depth: 5, ..Default::default() }));
        let rows = 64;
        let m = model.num_features;
        let x = &d.features[..rows * m];
        let mut c = cfg(rows);
        c.with_predict = true;
        let b = backend::build(&model, BackendKind::XlaWarp, &c).unwrap();
        let preds = b.predictions(x, rows).unwrap();
        for r in 0..rows {
            let want = model.predict_row_raw(d.row(r))[0];
            assert!((preds[r] - want).abs() < 1e-4, "row {r}: {} vs {want}", preds[r]);
        }
        // additivity: Σφ == prediction, through the XLA path end to end
        let phis = b.contributions(x, rows).unwrap();
        for r in 0..rows {
            let total: f32 = phis[r * (m + 1)..(r + 1) * (m + 1)].iter().sum();
            assert!(
                (total - preds[r]).abs() < 5e-3,
                "row {r}: Σφ {total} vs f(x) {}",
                preds[r]
            );
        }
    }

    #[test]
    fn deep_model_uses_deep_bucket() {
        if !artifacts_ready() {
            return;
        }
        // depth-12 trees over 54 features: merged paths stay deep (> 8
        // unique features per path), forcing the d16 artifact
        let d = SynthSpec::covtype(0.002).generate();
        let model =
            Arc::new(train(&d, &TrainParams { rounds: 1, max_depth: 12, ..Default::default() }));
        let rows = 16;
        let m = model.num_features;
        let x = &d.features[..rows * m];
        let b = backend::build(&model, BackendKind::XlaWarp, &cfg(rows)).unwrap();
        assert!(b.describe().contains("d16"), "picked {}", b.describe());
        let baseline = contributions(&model, BackendKind::Recursive, x, rows);
        let xla = b.contributions(x, rows).unwrap();
        close(&baseline, &xla, 5e-3, "deep model recursive vs XLA");
    }
}
