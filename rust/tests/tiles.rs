//! Feature-tile sharded Φ end to end: tiled interaction values against
//! the unsharded recursive oracle across the zoo (multiclass, NaN
//! probes, the repeated-feature model), awkward tile shapes (M not
//! divisible by the tile count, 1-feature tiles), assembled-matrix
//! invariants (symmetry, Eq. 6 row sums, local accuracy), mid-stream
//! tile death → quarantine → re-split recovery (directly and through
//! the serving executor), and the build routing that sends a pinned
//! `tiles` axis to the tile executor only when the pipeline is Φ.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use gputreeshap::backend::{
    self, BackendCaps, BackendConfig, BackendKind, RecursiveBackend, ShapBackend, ShardAxis,
    TilesBackend,
};
use gputreeshap::bench::zoo;
use gputreeshap::coordinator::{BackendFactory, ServiceConfig, ShapService};
use gputreeshap::gbdt::{Model, ZooSize};
use gputreeshap::util::error::Result;

fn close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol + 1e-3 * x.abs().max(y.abs()),
            "{what}: idx {i}: {x} vs {y}"
        );
    }
}

fn cfg(rows: usize) -> BackendConfig {
    BackendConfig { threads: 1, rows_hint: rows, with_interactions: true, ..Default::default() }
}

/// Zoo sweep cases: every Small dataset shape except fashion_mnist
/// (M=784 makes the (M+1)² oracle output enormous — table7 skips it for
/// the same reason), with NaN probes on the first half of the rows,
/// plus the hand-built repeated-feature model.
fn zoo_cases() -> Vec<(String, Arc<Model>, Vec<f32>, usize, usize)> {
    let mut cases: Vec<(String, Arc<Model>, Vec<f32>, usize, usize)> = Vec::new();
    for e in zoo::zoo_entries() {
        if e.size != ZooSize::Small || e.spec.name == "fashion_mnist" {
            continue;
        }
        let (model, data) = zoo::build(&e);
        let m = model.num_features;
        let rows = 8.min(data.rows);
        let mut x = data.features[..rows * m].to_vec();
        // missing values must follow the oracle's activation convention
        // (NaN matches no split interval) through the tiled path too
        let nan_rows = rows / 2;
        for r in 0..nan_rows {
            x[r * m + (r % m)] = f32::NAN;
        }
        cases.push((e.name, Arc::new(model), x, rows, nan_rows));
    }
    let model = Arc::new(zoo::repeated_feature_model());
    let x = vec![-2.0, 0.0, -0.5, 0.0, -0.5, 2.0, 0.5, 1.5, 3.0, -1.0];
    cases.push(("repeated-feature".to_string(), model, x, 5, 0));
    cases
}

#[test]
fn tiled_interactions_match_oracle_across_the_zoo() {
    for (name, model, x, rows, _) in &zoo_cases() {
        let m = model.num_features;
        let oracle =
            RecursiveBackend::new(model.clone(), 1).interactions(x, *rows).unwrap();
        // tile counts chosen so M is not divisible (covtype 54 / adult 14
        // / cal_housing 8 against 3 and 4), plus 1-feature tiles via a
        // count ≥ M on the narrow models (build clamps to M)
        for tiles in [2usize, 3, 4, m] {
            let tiled = TilesBackend::build(model, BackendKind::Recursive, &cfg(*rows), tiles)
                .unwrap();
            let got = tiled.interactions(x, *rows).unwrap();
            assert_eq!(got.len(), oracle.len(), "{name}");
            for (i, (a, o)) in got.iter().zip(&oracle).enumerate() {
                assert!(
                    (a.is_nan() && o.is_nan()) || *a == *o,
                    "{name}, {tiles} tiles: cell {i}: {a} vs {o} (recursive units are bitwise)"
                );
            }
            if tiles > 1 && m > 1 {
                let ranges = tiled.tile_ranges();
                assert_eq!(ranges[0].0, 0, "{name}: tiles must start at feature 0");
                assert_eq!(ranges.last().unwrap().1, m, "{name}: tiles must end at M");
                for w in ranges.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "{name}: tiles must be contiguous");
                }
            }
            // host units: the ranged DP kernel against the same oracle
            let host = TilesBackend::build(model, BackendKind::Host, &cfg(*rows), tiles)
                .unwrap()
                .interactions(x, *rows)
                .unwrap();
            close(&host, &oracle, 1e-6, &format!("{name}, {tiles} host tiles vs oracle"));
        }
    }
}

#[test]
fn assembled_matrices_keep_the_interaction_invariants() {
    for (name, model, x, rows, nan_rows) in &zoo_cases() {
        let m = model.num_features;
        let g = model.num_groups;
        let ms = (m + 1) * (m + 1);
        let tiled = TilesBackend::build(model, BackendKind::Host, &cfg(*rows), 3).unwrap();
        let mat = tiled.interactions(x, *rows).unwrap();
        let phis = tiled.contributions(x, *rows).unwrap();
        for r in 0..*rows {
            for k in 0..g {
                let base = r * g * ms + k * ms;
                // exact symmetry: owner-symmetric blocks are mirrored
                for i in 0..=m {
                    for j in 0..i {
                        assert_eq!(
                            mat[base + i * (m + 1) + j],
                            mat[base + j * (m + 1) + i],
                            "{name} row {r} group {k}: Φ[{i}][{j}] ≠ Φ[{j}][{i}]"
                        );
                    }
                }
                // Eq. 6 row sums: Σ_j Φ[i][j] == φ_i
                let pbase = r * g * (m + 1) + k * (m + 1);
                for i in 0..m {
                    let row_sum: f64 =
                        (0..m).map(|j| f64::from(mat[base + i * (m + 1) + j])).sum();
                    let phi = f64::from(phis[pbase + i]);
                    assert!(
                        (row_sum - phi).abs() < 1e-4 + 1e-3 * phi.abs(),
                        "{name} row {r} group {k}: ΣΦ[{i}][·] {row_sum} vs φ {phi}"
                    );
                }
                // local accuracy on NaN-free rows: the whole matrix
                // (diagonal + base cell) sums to the raw prediction
                if r >= *nan_rows {
                    let total: f64 = mat[base..base + ms].iter().map(|&v| f64::from(v)).sum();
                    let pred = f64::from(model.predict_row_raw(&x[r * m..(r + 1) * m])[k]);
                    assert!(
                        (total - pred).abs() < 2e-3,
                        "{name} row {r} group {k}: ΣΦ {total} vs f(x) {pred}"
                    );
                }
            }
        }
    }
}

/// Delegates until `dead` flips, then fails every ranged-block call —
/// the mid-stream "tile device lost" stand-in. Full-kernel calls
/// delegate untouched so the oracle side of the test stays live.
struct FlakyTile {
    inner: Box<dyn ShapBackend>,
    dead: Arc<AtomicBool>,
}

impl ShapBackend for FlakyTile {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn caps(&self) -> BackendCaps {
        self.inner.caps()
    }

    fn num_features(&self) -> usize {
        self.inner.num_features()
    }

    fn num_groups(&self) -> usize {
        self.inner.num_groups()
    }

    fn contributions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        self.inner.contributions(x, rows)
    }

    fn interactions(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        self.inner.interactions(x, rows)
    }

    fn interactions_block(
        &self,
        x: &[f32],
        rows: usize,
        lo: usize,
        hi: usize,
    ) -> Result<Vec<f64>> {
        if self.dead.load(Ordering::Relaxed) {
            return Err(gputreeshap::anyhow!("device lost"));
        }
        self.inner.interactions_block(x, rows, lo, hi)
    }

    fn contributions_f64(&self, x: &[f32], rows: usize) -> Result<Vec<f64>> {
        self.inner.contributions_f64(x, rows)
    }
}

fn small_zoo_model() -> (Arc<Model>, gputreeshap::data::Dataset) {
    let entry = zoo::zoo_entries()
        .into_iter()
        .find(|e| e.spec.name == "cal_housing" && e.size == ZooSize::Small)
        .unwrap();
    let (model, data) = zoo::build(&entry);
    (Arc::new(model), data)
}

#[test]
fn mid_stream_tile_death_quarantines_and_resplits() {
    let (model, data) = small_zoo_model();
    let m = model.num_features;
    let rows = 6.min(data.rows);
    let x = data.features[..rows * m].to_vec();
    let oracle = RecursiveBackend::new(model.clone(), 1).interactions(&x, rows).unwrap();

    let dead = Arc::new(AtomicBool::new(false));
    let mut units: Vec<Box<dyn ShapBackend>> = Vec::new();
    for i in 0..4 {
        let inner: Box<dyn ShapBackend> = Box::new(RecursiveBackend::new(model.clone(), 1));
        units.push(if i == 2 {
            Box::new(FlakyTile { inner, dead: dead.clone() })
        } else {
            inner
        });
    }
    let mut tiled = TilesBackend::from_units(units, backend::prepare(&model));

    // healthy: 4 tiles, bitwise vs the oracle
    assert_eq!(tiled.interactions(&x, rows).unwrap(), oracle);
    assert_eq!(tiled.tile_ranges().len(), 4);
    assert!(tiled.failed_shards().is_empty());

    // kill unit 2 mid-stream: the batch fails naming the tile, the
    // failure is attributed, and nothing partial escapes
    dead.store(true, Ordering::Relaxed);
    let err = tiled.interactions(&x, rows).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("tile 2"), "failed tile must be named: {msg}");
    assert!(msg.contains("device lost"), "cause must be preserved: {msg}");
    assert_eq!(tiled.failed_shards(), vec![2]);

    // quarantine → survivors re-split the feature range and the next
    // batch is served complete and still bitwise-correct
    assert_eq!(tiled.quarantine(&[2]).unwrap(), 1);
    assert_eq!(tiled.shard_count(), 3);
    assert_eq!(tiled.interactions(&x, rows).unwrap(), oracle);
    assert_eq!(tiled.tile_ranges().len(), 3, "survivors re-split the feature range");

    // from_units topologies carry no rebuild recipe: hot-add must refuse
    let err = tiled.hot_add(4).unwrap_err();
    assert!(format!("{err:#}").contains("rebuild recipe"), "{err:#}");
}

#[test]
fn service_survives_a_tile_death_and_keeps_serving_interactions() {
    let (model, data) = small_zoo_model();
    let m = model.num_features;
    let rows = 4.min(data.rows);
    let x = data.features[..rows * m].to_vec();
    let oracle = RecursiveBackend::new(model.clone(), 1).interactions(&x, rows).unwrap();

    let dead = Arc::new(AtomicBool::new(false));
    let factory: Arc<BackendFactory> = {
        let model = model.clone();
        let dead = dead.clone();
        Arc::new(move || {
            let mut units: Vec<Box<dyn ShapBackend>> = Vec::new();
            for i in 0..3 {
                let inner: Box<dyn ShapBackend> =
                    Box::new(RecursiveBackend::new(model.clone(), 1));
                units.push(if i == 1 {
                    Box::new(FlakyTile { inner, dead: dead.clone() })
                } else {
                    inner
                });
            }
            Ok(Box::new(TilesBackend::from_units(units, backend::prepare(&model)))
                as Box<dyn ShapBackend>)
        })
    };
    let svc = ShapService::start_with_factory(
        factory,
        ServiceConfig {
            max_batch_rows: 64,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        },
    )
    .unwrap();

    assert_eq!(svc.explain_interactions(x.clone(), rows).unwrap(), oracle);

    // kill the middle tile: requests may fail until the executor
    // quarantines it, then the survivors re-split and serving resumes —
    // every successful response is the complete, correct matrix
    dead.store(true, Ordering::Relaxed);
    let mut saw_error = false;
    let mut recovered = false;
    for _ in 0..100 {
        match svc.explain_interactions(x.clone(), rows) {
            Err(_) => saw_error = true,
            Ok(v) => {
                assert_eq!(v, oracle, "a served response must be complete and correct");
                if saw_error {
                    recovered = true;
                    break;
                }
            }
        }
    }
    assert!(saw_error, "the dead tile must surface at least one request error");
    assert!(recovered, "the service must keep serving after the tile quarantine");
    assert!(svc.metrics.quarantines.load(Ordering::Relaxed) >= 1);
    svc.shutdown();
}

#[test]
fn pinned_tiles_axis_builds_the_tile_executor_for_interaction_pipelines() {
    let (model, data) = small_zoo_model();
    let m = model.num_features;
    let rows = 4.min(data.rows);
    let x = &data.features[..rows * m];
    let mut c = cfg(rows);
    c.devices = 4;
    c.shard_axis = Some(ShardAxis::FeatureTiles);
    // explicit kind
    let b = backend::build(&model, BackendKind::Host, &c).unwrap();
    assert!(b.describe().starts_with("tiles["), "{}", b.describe());
    let oracle = RecursiveBackend::new(model.clone(), 1).interactions(x, rows).unwrap();
    close(&b.interactions(x, rows).unwrap(), &oracle, 1e-6, "pinned tiles build");
    // planner-driven: the pinned axis carries through ranked candidates
    let (plan, b) = backend::build_auto(&model, &c).unwrap();
    assert_eq!(plan.axis, ShardAxis::FeatureTiles);
    assert!(plan.shards > 1);
    assert!(b.describe().starts_with("tiles["), "{}", b.describe());
    // a φ-only pipeline on the same topology degrades to row shards
    let mut phi = c.clone();
    phi.with_interactions = false;
    let b = backend::build(&model, BackendKind::Host, &phi).unwrap();
    assert!(b.describe().starts_with("sharded["), "{}", b.describe());
}
